//! Locality-optimizing vertex relabeling (§7 "Locality Optimizing"):
//! renumbering vertices so neighbors get nearby IDs improves the
//! compression ratio r of gap-based formats — the knob §6's
//! "trading-off decompression bandwidth and compression ratio" turns.
//!
//! [`bfs_order`] is the classic lightweight reordering (Cuthill–McKee
//! flavor without degree sorting); [`apply_permutation`] renumbers a
//! graph by any bijection.

use std::collections::VecDeque;

use super::{CsrGraph, VertexId};

/// BFS traversal order from the lowest-ID vertex of each component:
/// `perm[old] = new`.
pub fn bfs_order(g: &CsrGraph) -> Vec<VertexId> {
    let n = g.num_vertices();
    let t = g.transpose();
    let mut perm = vec![VertexId::MAX; n];
    let mut next = 0 as VertexId;
    let mut q = VecDeque::new();
    for s in 0..n {
        if perm[s] != VertexId::MAX {
            continue;
        }
        perm[s] = next;
        next += 1;
        q.push_back(s as VertexId);
        while let Some(v) = q.pop_front() {
            for &u in g.neighbors(v).iter().chain(t.neighbors(v)) {
                if perm[u as usize] == VertexId::MAX {
                    perm[u as usize] = next;
                    next += 1;
                    q.push_back(u);
                }
            }
        }
    }
    perm
}

/// Renumber `g` by `perm` (`perm[old] = new`; must be a bijection).
pub fn apply_permutation(g: &CsrGraph, perm: &[VertexId]) -> CsrGraph {
    assert_eq!(perm.len(), g.num_vertices());
    if g.is_weighted() {
        let edges: Vec<(VertexId, VertexId, f32)> = (0..g.num_vertices())
            .flat_map(|v| {
                let ns = g.neighbors(v as VertexId);
                let ws = g.neighbor_weights(v as VertexId);
                ns.iter()
                    .zip(ws)
                    .map(|(&d, &w)| (perm[v], perm[d as usize], w))
                    .collect::<Vec<_>>()
            })
            .collect();
        CsrGraph::from_weighted_edges(g.num_vertices(), &edges)
    } else {
        let edges: Vec<(VertexId, VertexId)> =
            g.iter_edges().map(|(s, d)| (perm[s as usize], perm[d as usize])).collect();
        CsrGraph::from_edges(g.num_vertices(), &edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::webgraph::{compress, WgParams};
    use crate::graph::generators;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn bfs_order_is_a_permutation() {
        let g = generators::rmat(8, 6, 3);
        let perm = bfs_order(&g);
        let mut sorted = perm.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..g.num_vertices() as VertexId).collect::<Vec<_>>());
    }

    #[test]
    fn permutation_preserves_structure() {
        let g = generators::barabasi_albert(500, 4, 7);
        let perm = bfs_order(&g);
        let h = apply_permutation(&g, &perm);
        assert_eq!(h.num_vertices(), g.num_vertices());
        assert_eq!(h.num_edges(), g.num_edges());
        // Degree multiset preserved.
        let mut dg: Vec<u64> = (0..g.num_vertices()).map(|v| g.degree(v as u32)).collect();
        let mut dh: Vec<u64> = (0..h.num_vertices()).map(|v| h.degree(v as u32)).collect();
        dg.sort_unstable();
        dh.sort_unstable();
        assert_eq!(dg, dh);
        // Component count preserved.
        use crate::algorithms::{bfs::wcc_by_bfs, count_components};
        assert_eq!(count_components(&wcc_by_bfs(&g)), count_components(&wcc_by_bfs(&h)));
    }

    #[test]
    fn bfs_relabel_recovers_compression_lost_to_shuffling() {
        // Take a locality-rich graph, destroy locality with a random
        // permutation, then recover (much of) it with BFS reordering —
        // the §7 claim that relabeling improves compression.
        let g = generators::web_locality(3000, 8, 0.9, 0.6, 5);
        let bits = |g: &CsrGraph| compress(g, WgParams::default()).2.total_bits;
        let original = bits(&g);

        let mut rng = Xoshiro256::seed_from_u64(9);
        let mut shuffle: Vec<VertexId> = (0..g.num_vertices() as VertexId).collect();
        rng.shuffle(&mut shuffle);
        let shuffled = apply_permutation(&g, &shuffle);
        let shuffled_bits = bits(&shuffled);
        assert!(
            shuffled_bits > original * 2,
            "random relabeling must hurt compression: {original} -> {shuffled_bits}"
        );

        let recovered = apply_permutation(&shuffled, &bfs_order(&shuffled));
        let recovered_bits = bits(&recovered);
        assert!(
            recovered_bits < shuffled_bits * 3 / 4,
            "BFS order must recover locality: shuffled {shuffled_bits} -> bfs {recovered_bits}"
        );
    }

    #[test]
    fn weighted_permutation_keeps_weights_attached() {
        let g = CsrGraph::from_weighted_edges(4, &[(0, 1, 5.0), (1, 2, 6.0), (3, 0, 7.0)]);
        let perm = vec![3, 2, 1, 0];
        let h = apply_permutation(&g, &perm);
        // (0,1,5.0) -> (3,2,5.0)
        assert_eq!(h.neighbors(3), &[2]);
        assert_eq!(h.neighbor_weights(3), &[5.0]);
    }
}
