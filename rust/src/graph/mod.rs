//! In-memory graph representations (CSR/CSX and COO) and synthetic dataset
//! generators standing in for the paper's Table 3 datasets.

pub mod coo;
pub mod csr;
pub mod generators;
pub mod relabel;

pub use coo::CooEdges;
pub use csr::CsrGraph;

/// Vertex identifier. The paper encodes 4-byte IDs (|V| < 2^32); we keep u32
/// on edge arrays and u64 on offsets (|E| may exceed 2^32) exactly like the
/// paper's binary CSX layout (§5: "4 Bytes ID per vertex ... offsets array
/// requires 8 Bytes per entry").
pub type VertexId = u32;

/// Edge weight type for WG404-style edge-weighted graphs.
pub type Weight = f32;
