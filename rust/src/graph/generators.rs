//! Synthetic graph generators — scaled-down stand-ins for the paper's
//! Table 3 datasets (the real ones are 58 M – 124 B edges and/or only
//! published in WebGraph format; see DESIGN.md §3).
//!
//! * [`rmat`] — Graph500-style R-MAT, the paper's G5 dataset.
//! * [`road_lattice`] — 2-D lattice with diagonal shortcuts: low, nearly
//!   uniform degree and strong locality, like the US-roads RD dataset.
//! * [`barabasi_albert`] — preferential attachment: power-law degrees like
//!   the Twitter/ClueWeb web-style graphs (TW/CW/SH analogues).
//! * [`similarity_blocks`] — dense overlapping cliques-with-noise, like the
//!   MS50 sequence-similarity graph (high average degree).

use super::{CsrGraph, VertexId};
use crate::util::rng::Xoshiro256;

/// R-MAT generator (Chakrabarti et al.) with Graph500 parameters
/// a=0.57, b=0.19, c=0.19, d=0.05. Produces `2^scale` vertices and
/// `edge_factor * 2^scale` directed edges (duplicates removed).
pub fn rmat(scale: u32, edge_factor: usize, seed: u64) -> CsrGraph {
    let n = 1usize << scale;
    let m = edge_factor * n;
    let (a, b, c) = (0.57, 0.19, 0.19);
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut edges: Vec<(VertexId, VertexId)> = Vec::with_capacity(m);
    for _ in 0..m {
        let (mut x0, mut x1) = (0usize, n);
        let (mut y0, mut y1) = (0usize, n);
        while x1 - x0 > 1 {
            let r = rng.next_f64();
            let half_x = (x0 + x1) / 2;
            let half_y = (y0 + y1) / 2;
            if r < a {
                x1 = half_x;
                y1 = half_y;
            } else if r < a + b {
                x1 = half_x;
                y0 = half_y;
            } else if r < a + b + c {
                x0 = half_x;
                y1 = half_y;
            } else {
                x0 = half_x;
                y0 = half_y;
            }
        }
        edges.push((x0 as VertexId, y0 as VertexId));
    }
    edges.sort_unstable();
    edges.dedup();
    CsrGraph::from_edges(n, &edges)
}

/// Road-network-like graph: a w×h lattice (4-neighborhood) plus a sprinkle
/// of random shortcuts; symmetric, degree ≈ 4, high locality (small gaps —
/// compresses extremely well with interval codes, like real road graphs).
pub fn road_lattice(width: usize, height: usize, shortcut_per_mille: u32, seed: u64) -> CsrGraph {
    let n = width * height;
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut edges: Vec<(VertexId, VertexId)> = Vec::with_capacity(n * 4);
    let id = |x: usize, y: usize| (y * width + x) as VertexId;
    for y in 0..height {
        for x in 0..width {
            let v = id(x, y);
            if x + 1 < width {
                edges.push((v, id(x + 1, y)));
                edges.push((id(x + 1, y), v));
            }
            if y + 1 < height {
                edges.push((v, id(x, y + 1)));
                edges.push((id(x, y + 1), v));
            }
            if shortcut_per_mille > 0 && rng.next_below(1000) < shortcut_per_mille as u64 {
                let u = rng.next_below(n as u64) as VertexId;
                if u != v {
                    edges.push((v, u));
                    edges.push((u, v));
                }
            }
        }
    }
    edges.sort_unstable();
    edges.dedup();
    CsrGraph::from_edges(n, &edges)
}

/// Barabási–Albert preferential attachment: each new vertex attaches to
/// `m_attach` existing vertices chosen ∝ degree. Power-law degree tail,
/// web/social-like. Directed edges new→old plus reverse, like a symmetrized
/// crawl.
pub fn barabasi_albert(n: usize, m_attach: usize, seed: u64) -> CsrGraph {
    assert!(n > m_attach && m_attach >= 1);
    let mut rng = Xoshiro256::seed_from_u64(seed);
    // Repeated-endpoint list: sampling uniformly from it = degree-biased pick.
    let mut endpoints: Vec<VertexId> = Vec::with_capacity(2 * n * m_attach);
    let mut edges: Vec<(VertexId, VertexId)> = Vec::with_capacity(2 * n * m_attach);
    // Seed clique over the first m_attach+1 vertices.
    for i in 0..=(m_attach as u32) {
        for j in 0..i {
            edges.push((i, j));
            edges.push((j, i));
            endpoints.push(i);
            endpoints.push(j);
        }
    }
    for v in (m_attach as u32 + 1)..(n as u32) {
        let mut picked = Vec::with_capacity(m_attach);
        let mut guard = 0;
        while picked.len() < m_attach && guard < 100 * m_attach {
            let u = endpoints[rng.next_below(endpoints.len() as u64) as usize];
            if u != v && !picked.contains(&u) {
                picked.push(u);
            }
            guard += 1;
        }
        for &u in &picked {
            edges.push((v, u));
            edges.push((u, v));
            endpoints.push(v);
            endpoints.push(u);
        }
    }
    edges.sort_unstable();
    edges.dedup();
    CsrGraph::from_edges(n, &edges)
}

/// Web-crawl-like graph with *locality* and *similarity* — the two
/// properties WebGraph compression exploits (§2): URLs sorted
/// lexicographically put most links within the same host (small gaps), and
/// nearby pages share successors. Each vertex gets `m_out` successors:
/// with probability `locality`, a power-law-distributed *nearby* vertex;
/// otherwise a uniformly random one; and with probability `similarity` the
/// whole suffix of the previous vertex's list is reused (reference-style
/// similarity). This is what makes the CW/SH analogues land in the paper's
/// compression regime (r ≈ 8–17) — a plain BA graph with random IDs
/// compresses ~2× only.
pub fn web_locality(
    n: usize,
    m_out: usize,
    locality: f64,
    similarity: f64,
    seed: u64,
) -> CsrGraph {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut edges: Vec<(VertexId, VertexId)> = Vec::with_capacity(n * m_out);
    let mut prev_list: Vec<VertexId> = Vec::new();
    let mut list: Vec<VertexId> = Vec::new();
    for v in 0..n {
        list.clear();
        if v > 0 && rng.next_bool(similarity) {
            // Copy a chunk of the previous vertex's successors.
            let keep = prev_list.len().min(m_out * 3 / 4);
            list.extend_from_slice(&prev_list[..keep]);
        }
        while list.len() < m_out {
            let d = if rng.next_bool(locality) {
                // Power-law offset around v: gap ~ 1 + pareto.
                let u = rng.next_f64().max(1e-9);
                let gap = (u.powf(-0.7) - 1.0) as i64; // heavy tail
                let sign = if rng.next_bool(0.5) { 1 } else { -1 };
                let t = v as i64 + sign * (1 + gap.min(n as i64 / 8));
                t.rem_euclid(n as i64) as VertexId
            } else {
                rng.next_below(n as u64) as VertexId
            };
            if d as usize != v {
                list.push(d);
            }
        }
        list.sort_unstable();
        list.dedup();
        for &d in &list {
            edges.push((v as VertexId, d));
        }
        std::mem::swap(&mut prev_list, &mut list);
    }
    CsrGraph::from_edges(n, &edges)
}

/// Per-vertex successor oracle for out-of-core experiments: deterministic
/// in `(v, n, deg, seed)` alone, O(deg) time and memory — the streaming
/// compressor and the verification oracle call it independently, so a
/// larger-than-RAM graph never has to exist materialized anywhere.
///
/// Lists mimic [`web_locality`]'s structure: a consecutive run right after
/// `v` (interval-friendly, and heavily overlapping between neighbors so
/// reference compression fires), power-law local gaps (small ζ residuals),
/// and occasional far jumps. Output is sorted, duplicate-free, and never
/// contains `v` itself; its length is ≤ `deg` (dedup may trim a few).
pub fn synthetic_successors(v: usize, n: usize, deg: usize, seed: u64, out: &mut Vec<VertexId>) {
    out.clear();
    if n <= 1 || deg == 0 {
        return;
    }
    let mut rng =
        Xoshiro256::seed_from_u64(seed ^ (v as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    // Consecutive run after v. Values (v + 1 + i) mod n with i < n - 1
    // never land back on v.
    let run = (deg / 2).min(n - 1);
    for i in 0..run {
        out.push(((v + 1 + i) % n) as VertexId);
    }
    let target = deg.min(n - 1);
    while out.len() < target {
        let d = if rng.next_bool(0.9) {
            // Power-law gap around v, as in `web_locality`.
            let u = rng.next_f64().max(1e-9);
            let gap = (u.powf(-0.7) - 1.0) as i64;
            let sign = if rng.next_bool(0.5) { 1 } else { -1 };
            (v as i64 + sign * (1 + gap.min(n as i64 / 8))).rem_euclid(n as i64) as VertexId
        } else {
            rng.next_below(n as u64) as VertexId
        };
        if d as usize != v {
            out.push(d);
        }
    }
    out.sort_unstable();
    out.dedup();
}

/// Erdős–Rényi G(n, m): m distinct directed edges chosen uniformly.
pub fn erdos_renyi(n: usize, m: usize, seed: u64) -> CsrGraph {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut edges: Vec<(VertexId, VertexId)> = Vec::with_capacity(m);
    while edges.len() < m {
        let s = rng.next_below(n as u64) as VertexId;
        let d = rng.next_below(n as u64) as VertexId;
        if s != d {
            edges.push((s, d));
        }
        if edges.len() == m {
            edges.sort_unstable();
            edges.dedup();
        }
    }
    CsrGraph::from_edges(n, &edges)
}

/// Sequence-similarity-like graph (MS50 analogue): vertices fall into
/// overlapping blocks (sequence families); each block is densely connected.
/// High average degree, strong similarity between adjacent vertices — the
/// regime where WebGraph reference-compression shines.
pub fn similarity_blocks(n: usize, block: usize, overlap: usize, seed: u64) -> CsrGraph {
    assert!(block > 1 && overlap < block);
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let stride = block - overlap;
    let mut edges: Vec<(VertexId, VertexId)> = Vec::new();
    let mut start = 0usize;
    while start < n {
        let end = (start + block).min(n);
        for i in start..end {
            for j in start..end {
                // ~70% of intra-block pairs, to avoid perfect cliques.
                if i != j && rng.next_below(10) < 7 {
                    edges.push((i as VertexId, j as VertexId));
                }
            }
        }
        start += stride;
    }
    edges.sort_unstable();
    edges.dedup();
    CsrGraph::from_edges(n, &edges)
}

/// The scaled-down dataset suite mirroring the paper's Table 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataset {
    /// RD — US-roads analogue (lattice).
    Rd,
    /// TW — Twitter analogue (power-law).
    Tw,
    /// G5 — Graph500 RMAT.
    G5,
    /// SH — Software-Heritage analogue (sparse power-law, many vertices).
    Sh,
    /// CW — ClueWeb analogue (web-like, high compression).
    Cw,
    /// MS — MS50 similarity analogue (dense blocks).
    Ms,
}

impl Dataset {
    pub const ALL: [Dataset; 6] =
        [Dataset::Rd, Dataset::Tw, Dataset::G5, Dataset::Sh, Dataset::Cw, Dataset::Ms];

    pub fn abbr(&self) -> &'static str {
        match self {
            Dataset::Rd => "RD",
            Dataset::Tw => "TW",
            Dataset::G5 => "G5",
            Dataset::Sh => "SH",
            Dataset::Cw => "CW",
            Dataset::Ms => "MS",
        }
    }

    pub fn parse(s: &str) -> Option<Dataset> {
        Self::ALL.iter().copied().find(|d| d.abbr().eq_ignore_ascii_case(s))
    }

    /// Generate at a given scale factor (1 = default small suite; larger
    /// values multiply the vertex counts). Asymmetric generators are
    /// symmetrized, as the paper does with its datasets (§5: "we
    /// symmetrized the asymmetric ones").
    pub fn generate(&self, scale: usize, seed: u64) -> CsrGraph {
        let s = scale.max(1);
        match self {
            Dataset::Rd => road_lattice(64 * s, 48 * s, 5, seed),
            Dataset::Tw => barabasi_albert(6_000 * s, 12, seed),
            Dataset::G5 => {
                let extra = (s as f64).log2().round() as u32;
                rmat(12 + extra, 16, seed).symmetrize()
            }
            Dataset::Sh => web_locality(20_000 * s, 4, 0.85, 0.5, seed).symmetrize(),
            Dataset::Cw => web_locality(10_000 * s, 10, 0.9, 0.65, seed).symmetrize(),
            Dataset::Ms => similarity_blocks(2_000 * s, 64, 16, seed).symmetrize(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmat_valid_and_deterministic() {
        let a = rmat(8, 8, 1);
        let b = rmat(8, 8, 1);
        assert_eq!(a, b);
        a.validate().unwrap();
        assert_eq!(a.num_vertices(), 256);
        assert!(a.num_edges() > 500, "rmat should generate plenty of edges");
    }

    #[test]
    fn rmat_is_skewed() {
        let g = rmat(10, 16, 7);
        let max_deg = (0..g.num_vertices()).map(|v| g.degree(v as VertexId)).max().unwrap();
        let avg = g.num_edges() as f64 / g.num_vertices() as f64;
        assert!(max_deg as f64 > 5.0 * avg, "rmat should have hubs: max {max_deg} avg {avg}");
    }

    #[test]
    fn lattice_symmetric_low_degree() {
        let g = road_lattice(16, 16, 0, 3);
        g.validate().unwrap();
        assert_eq!(g.num_vertices(), 256);
        let max_deg = (0..g.num_vertices()).map(|v| g.degree(v as VertexId)).max().unwrap();
        assert!(max_deg <= 4);
        for (s, d) in g.iter_edges().collect::<Vec<_>>() {
            assert!(g.neighbors(d).contains(&s));
        }
    }

    #[test]
    fn ba_powerlaw_tail() {
        let g = barabasi_albert(2000, 4, 5);
        g.validate().unwrap();
        let max_deg = (0..g.num_vertices()).map(|v| g.degree(v as VertexId)).max().unwrap();
        let avg = g.num_edges() as f64 / g.num_vertices() as f64;
        assert!(max_deg as f64 > 8.0 * avg, "BA must grow hubs: max {max_deg} avg {avg}");
    }

    #[test]
    fn er_edge_count_close() {
        let g = erdos_renyi(500, 3000, 11);
        g.validate().unwrap();
        assert!(g.num_edges() > 2700, "dedup shouldn't remove too much");
    }

    #[test]
    fn similarity_blocks_dense() {
        let g = similarity_blocks(512, 64, 16, 2);
        g.validate().unwrap();
        let avg = g.num_edges() as f64 / g.num_vertices() as f64;
        assert!(avg > 20.0, "similarity graph should be dense, avg {avg}");
    }

    #[test]
    fn synthetic_successors_deterministic_sorted_unique() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        for v in [0usize, 1, 500, 999] {
            synthetic_successors(v, 1000, 16, 7, &mut a);
            synthetic_successors(v, 1000, 16, 7, &mut b);
            assert_eq!(a, b, "vertex {v} must be reproducible");
            assert!(a.windows(2).all(|w| w[0] < w[1]), "sorted unique at {v}");
            assert!(!a.contains(&(v as VertexId)), "no self-loop at {v}");
            assert!(!a.is_empty() && a.len() <= 16, "bounded degree at {v}");
        }
    }

    #[test]
    fn dataset_suite_generates() {
        for d in Dataset::ALL {
            let g = d.generate(1, 42);
            g.validate().unwrap();
            assert!(g.num_edges() > 1000, "{} too small: {}", d.abbr(), g.num_edges());
        }
        assert_eq!(Dataset::parse("tw"), Some(Dataset::Tw));
        assert_eq!(Dataset::parse("nope"), None);
    }
}

#[cfg(test)]
mod web_tests {
    use super::*;

    #[test]
    fn web_locality_valid_and_deterministic() {
        let a = web_locality(2000, 8, 0.9, 0.6, 5);
        let b = web_locality(2000, 8, 0.9, 0.6, 5);
        assert_eq!(a, b);
        a.validate().unwrap();
        assert!(a.num_edges() > 10_000);
    }

    #[test]
    fn web_locality_compresses_like_a_web_graph() {
        use crate::formats::webgraph::{compress, WgParams};
        let g = web_locality(4000, 10, 0.9, 0.65, 7);
        let (_, _, stats) = compress(&g, WgParams::default());
        let bpe = stats.total_bits as f64 / g.num_edges() as f64;
        assert!(bpe < 10.0, "web-like graph must compress strongly, got {bpe:.1} bits/edge");
        assert!(stats.copied_edges > 0, "similarity must trigger reference compression");
    }
}
