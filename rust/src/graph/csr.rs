//! Compressed-sparse (CSX) graph: the canonical in-memory layout every
//! format loader produces and every algorithm consumes.

use super::{CooEdges, VertexId, Weight};
use crate::util::prefix::exclusive_prefix_sum;

/// CSR/CSC graph: `offsets[v]..offsets[v+1]` indexes `edges` (and `weights`
/// when edge-weighted). Whether it is "R" (out-edges) or "C" (in-edges) is a
/// matter of interpretation, hence CSX.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrGraph {
    pub offsets: Vec<u64>,
    pub edges: Vec<VertexId>,
    /// Edge weights, parallel to `edges`; empty for unweighted graphs.
    pub weights: Vec<Weight>,
}

impl CsrGraph {
    /// Build from an unsorted edge list (counting sort into CSR).
    pub fn from_edges(num_vertices: usize, edge_list: &[(VertexId, VertexId)]) -> Self {
        let mut counts = vec![0u64; num_vertices + 1];
        for &(src, _) in edge_list {
            counts[src as usize + 1] += 1;
        }
        // counts[1..] holds per-vertex degree; prefix-sum into offsets.
        let mut offsets = counts;
        exclusive_prefix_sum(&mut offsets[1..]);
        // offsets[0] is already 0; offsets[v+1] currently = start of v's slot.
        let mut cursor: Vec<u64> = offsets[1..].to_vec();
        let mut edges = vec![0 as VertexId; edge_list.len()];
        for &(src, dst) in edge_list {
            let c = &mut cursor[src as usize];
            edges[*c as usize] = dst;
            *c += 1;
        }
        let mut offs = vec![0u64];
        offs.extend_from_slice(&cursor[..]);
        // cursor[v] is now the END of v's range == offsets[v+1].
        let mut g = CsrGraph { offsets: offs, edges, weights: Vec::new() };
        g.sort_neighbors();
        g
    }

    /// Build a weighted graph from an edge list with weights.
    pub fn from_weighted_edges(
        num_vertices: usize,
        edge_list: &[(VertexId, VertexId, Weight)],
    ) -> Self {
        let unweighted: Vec<(VertexId, VertexId)> =
            edge_list.iter().map(|&(s, d, _)| (s, d)).collect();
        let mut counts = vec![0u64; num_vertices + 1];
        for &(src, _) in &unweighted {
            counts[src as usize + 1] += 1;
        }
        let mut offsets = counts;
        exclusive_prefix_sum(&mut offsets[1..]);
        let mut cursor: Vec<u64> = offsets[1..].to_vec();
        let mut edges = vec![0 as VertexId; edge_list.len()];
        let mut weights = vec![0.0 as Weight; edge_list.len()];
        for &(src, dst, w) in edge_list {
            let c = &mut cursor[src as usize];
            edges[*c as usize] = dst;
            weights[*c as usize] = w;
            *c += 1;
        }
        let mut offs = vec![0u64];
        offs.extend_from_slice(&cursor[..]);
        let mut g = CsrGraph { offsets: offs, edges, weights };
        g.sort_neighbors();
        g
    }

    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn num_edges(&self) -> u64 {
        *self.offsets.last().unwrap_or(&0)
    }

    pub fn is_weighted(&self) -> bool {
        !self.weights.is_empty()
    }

    pub fn degree(&self, v: VertexId) -> u64 {
        self.offsets[v as usize + 1] - self.offsets[v as usize]
    }

    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        let s = self.offsets[v as usize] as usize;
        let e = self.offsets[v as usize + 1] as usize;
        &self.edges[s..e]
    }

    pub fn neighbor_weights(&self, v: VertexId) -> &[Weight] {
        debug_assert!(self.is_weighted());
        let s = self.offsets[v as usize] as usize;
        let e = self.offsets[v as usize + 1] as usize;
        &self.weights[s..e]
    }

    /// Sort each neighbor list ascending (required by the WebGraph encoder:
    /// gaps must be non-negative after the first residual). Weights follow
    /// their edges.
    pub fn sort_neighbors(&mut self) {
        let n = self.num_vertices();
        if self.weights.is_empty() {
            for v in 0..n {
                let s = self.offsets[v] as usize;
                let e = self.offsets[v + 1] as usize;
                self.edges[s..e].sort_unstable();
            }
        } else {
            for v in 0..n {
                let s = self.offsets[v] as usize;
                let e = self.offsets[v + 1] as usize;
                let mut pairs: Vec<(VertexId, Weight)> = self.edges[s..e]
                    .iter()
                    .copied()
                    .zip(self.weights[s..e].iter().copied())
                    .collect();
                pairs.sort_unstable_by_key(|&(d, _)| d);
                for (i, (d, w)) in pairs.into_iter().enumerate() {
                    self.edges[s + i] = d;
                    self.weights[s + i] = w;
                }
            }
        }
    }

    /// Transposed graph (CSR <-> CSC).
    pub fn transpose(&self) -> CsrGraph {
        let n = self.num_vertices();
        let mut counts = vec![0u64; n + 1];
        for &d in &self.edges {
            counts[d as usize + 1] += 1;
        }
        let mut offsets = counts;
        exclusive_prefix_sum(&mut offsets[1..]);
        let mut cursor: Vec<u64> = offsets[1..].to_vec();
        let mut edges = vec![0 as VertexId; self.edges.len()];
        let mut weights =
            if self.is_weighted() { vec![0.0; self.edges.len()] } else { Vec::new() };
        for v in 0..n {
            let s = self.offsets[v] as usize;
            let e = self.offsets[v + 1] as usize;
            for i in s..e {
                let d = self.edges[i] as usize;
                let c = &mut cursor[d];
                edges[*c as usize] = v as VertexId;
                if !weights.is_empty() {
                    weights[*c as usize] = self.weights[i];
                }
                *c += 1;
            }
        }
        let mut offs = vec![0u64];
        offs.extend_from_slice(&cursor[..]);
        let mut g = CsrGraph { offsets: offs, edges, weights };
        g.sort_neighbors();
        g
    }

    /// Symmetrized graph: union of edges and reverse edges, deduplicated.
    /// (The paper symmetrizes asymmetric datasets before evaluation.)
    pub fn symmetrize(&self) -> CsrGraph {
        let n = self.num_vertices();
        let mut pairs: Vec<(VertexId, VertexId)> = Vec::with_capacity(self.edges.len() * 2);
        for v in 0..n {
            for &d in self.neighbors(v as VertexId) {
                pairs.push((v as VertexId, d));
                pairs.push((d, v as VertexId));
            }
        }
        pairs.sort_unstable();
        pairs.dedup();
        CsrGraph::from_edges(n, &pairs)
    }

    /// Iterate all edges as (src, dst) in CSR order.
    pub fn iter_edges(&self) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
        (0..self.num_vertices()).flat_map(move |v| {
            self.neighbors(v as VertexId).iter().map(move |&d| (v as VertexId, d))
        })
    }

    /// Convert to a COO edge list.
    pub fn to_coo(&self) -> CooEdges {
        let mut src = Vec::with_capacity(self.edges.len());
        let mut dst = Vec::with_capacity(self.edges.len());
        for (s, d) in self.iter_edges() {
            src.push(s);
            dst.push(d);
        }
        CooEdges { num_vertices: self.num_vertices(), src, dst, weights: self.weights.clone() }
    }

    /// Structural invariants; used by tests and the format round-trips.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.num_vertices();
        if self.offsets.is_empty() || self.offsets[0] != 0 {
            return Err("offsets must start at 0".into());
        }
        for v in 0..n {
            if self.offsets[v] > self.offsets[v + 1] {
                return Err(format!("offsets not monotone at {v}"));
            }
        }
        if *self.offsets.last().unwrap() != self.edges.len() as u64 {
            return Err("last offset != edge count".into());
        }
        if !self.weights.is_empty() && self.weights.len() != self.edges.len() {
            return Err("weights length mismatch".into());
        }
        for &d in &self.edges {
            if (d as usize) >= n {
                return Err(format!("edge endpoint {d} out of range ({n} vertices)"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> CsrGraph {
        // 0 -> 1,2 ; 1 -> 2 ; 2 -> 0 ; 3 isolated
        CsrGraph::from_edges(4, &[(0, 2), (0, 1), (1, 2), (2, 0)])
    }

    #[test]
    fn from_edges_builds_sorted_csr() {
        let g = tiny();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.offsets, vec![0, 2, 3, 4, 4]);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(1), &[2]);
        assert_eq!(g.neighbors(2), &[0]);
        assert_eq!(g.neighbors(3), &[] as &[VertexId]);
        g.validate().unwrap();
    }

    #[test]
    fn degree_and_iter() {
        let g = tiny();
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(3), 0);
        let edges: Vec<_> = g.iter_edges().collect();
        assert_eq!(edges, vec![(0, 1), (0, 2), (1, 2), (2, 0)]);
    }

    #[test]
    fn transpose_involution() {
        let g = tiny();
        let t = g.transpose();
        assert_eq!(t.neighbors(2), &[0, 1]);
        assert_eq!(t.neighbors(0), &[2]);
        let tt = t.transpose();
        assert_eq!(g, tt);
    }

    #[test]
    fn symmetrize_makes_symmetric() {
        let g = tiny().symmetrize();
        for (s, d) in g.iter_edges().collect::<Vec<_>>() {
            assert!(g.neighbors(d).contains(&s), "missing reverse of ({s},{d})");
        }
        // 0<->1, 0<->2, 1<->2 = 6 directed edges
        assert_eq!(g.num_edges(), 6);
    }

    #[test]
    fn weighted_roundtrip_preserves_pairing() {
        let g = CsrGraph::from_weighted_edges(
            3,
            &[(0, 2, 2.5), (0, 1, 1.5), (2, 0, 0.25)],
        );
        assert!(g.is_weighted());
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbor_weights(0), &[1.5, 2.5]);
        let t = g.transpose();
        assert_eq!(t.neighbors(0), &[2]);
        assert_eq!(t.neighbor_weights(0), &[0.25]);
        t.validate().unwrap();
    }

    #[test]
    fn empty_graph() {
        let g = CsrGraph::from_edges(0, &[]);
        assert_eq!(g.num_vertices(), 0);
        assert_eq!(g.num_edges(), 0);
        g.validate().unwrap();
    }

    #[test]
    fn validate_catches_corruption() {
        let mut g = tiny();
        g.edges[0] = 99;
        assert!(g.validate().is_err());
        let mut g2 = tiny();
        g2.offsets[1] = 100;
        assert!(g2.validate().is_err());
    }
}
