//! COO (coordinate / edge-list) representation — the layout of the textual
//! Matrix-Market-style inputs the paper compares against.

use super::{CsrGraph, VertexId, Weight};

/// Parallel-array edge list.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CooEdges {
    pub num_vertices: usize,
    pub src: Vec<VertexId>,
    pub dst: Vec<VertexId>,
    /// Parallel weights; empty when unweighted.
    pub weights: Vec<Weight>,
}

impl CooEdges {
    pub fn num_edges(&self) -> u64 {
        self.src.len() as u64
    }

    pub fn is_weighted(&self) -> bool {
        !self.weights.is_empty()
    }

    /// Convert to CSR (sorting neighbors).
    pub fn to_csr(&self) -> CsrGraph {
        if self.is_weighted() {
            let list: Vec<(VertexId, VertexId, Weight)> = self
                .src
                .iter()
                .zip(&self.dst)
                .zip(&self.weights)
                .map(|((&s, &d), &w)| (s, d, w))
                .collect();
            CsrGraph::from_weighted_edges(self.num_vertices, &list)
        } else {
            let list: Vec<(VertexId, VertexId)> =
                self.src.iter().zip(&self.dst).map(|(&s, &d)| (s, d)).collect();
            CsrGraph::from_edges(self.num_vertices, &list)
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.src.len() != self.dst.len() {
            return Err("src/dst length mismatch".into());
        }
        if !self.weights.is_empty() && self.weights.len() != self.src.len() {
            return Err("weights length mismatch".into());
        }
        let n = self.num_vertices as u64;
        for (&s, &d) in self.src.iter().zip(&self.dst) {
            if s as u64 >= n || d as u64 >= n {
                return Err(format!("edge ({s},{d}) out of range ({n} vertices)"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coo_to_csr_and_back() {
        let coo = CooEdges {
            num_vertices: 3,
            src: vec![0, 2, 0],
            dst: vec![2, 1, 1],
            weights: vec![],
        };
        coo.validate().unwrap();
        let csr = coo.to_csr();
        assert_eq!(csr.neighbors(0), &[1, 2]);
        assert_eq!(csr.neighbors(2), &[1]);
        let coo2 = csr.to_coo();
        assert_eq!(coo2.num_edges(), 3);
        coo2.validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_vertex() {
        let coo =
            CooEdges { num_vertices: 2, src: vec![0], dst: vec![5], weights: vec![] };
        assert!(coo.validate().is_err());
    }
}
