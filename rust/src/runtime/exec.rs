//! Loading and executing the AOT artifacts through the PJRT CPU client.
//!
//! The `xla` crate's client/executable types are `!Send` (`Rc` internals),
//! so the runtime owns them on a dedicated *service thread*; the rest of
//! the system talks to it through a channel. This mirrors a realistic
//! deployment where a fixed set of runtime threads own device contexts.

use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{bail, Context, Result};

use super::ScanEngine;

/// Fixed block length of the gap-scan executable (must match
/// `python/compile/aot.py`).
pub const GAP_SCAN_BLOCK: usize = 65_536;
/// Fixed edge-block / label-array length of the WCC step executable.
pub const WCC_BLOCK: usize = 65_536;

/// One compiled artifact (lives on the service thread).
struct Artifact {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Artifact {
    fn load(client: &xla::PjRtClient, dir: &Path, name: &str) -> Result<Self> {
        let path = dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            bail!("artifact {} missing — run `make artifacts`", path.display());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not UTF-8")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        Ok(Self { exe, name: name.to_string() })
    }

    /// Execute with literal inputs; returns the first element of the
    /// result tuple (aot.py lowers with `return_tuple=True`).
    fn run(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {}: {e:?}", self.name))?;
        lit.to_tuple1().map_err(|e| anyhow::anyhow!("untuple {}: {e:?}", self.name))
    }
}

enum Request {
    Scan { gaps: Vec<i64>, carry: i64, reply: Sender<Result<Vec<i64>>> },
    WccStep { labels: Vec<i32>, src: Vec<i32>, dst: Vec<i32>, reply: Sender<Result<Vec<i32>>> },
    Platform { reply: Sender<String> },
}

/// Handle to the XLA service thread. Cheap to clone via `Arc`; `Send+Sync`.
pub struct ArtifactSet {
    tx: Mutex<Sender<Request>>,
    worker: Mutex<Option<JoinHandle<()>>>,
    dir: PathBuf,
}

impl ArtifactSet {
    /// Start the service thread and load every artifact from `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Arc<Self>> {
        let dir = dir.as_ref().to_path_buf();
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let dir2 = dir.clone();
        let worker = std::thread::Builder::new()
            .name("pg-xla-service".into())
            .spawn(move || {
                let init = (|| -> Result<(xla::PjRtClient, Artifact, Artifact)> {
                    let client = xla::PjRtClient::cpu()
                        .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
                    let gap_scan = Artifact::load(&client, &dir2, "gap_scan")?;
                    let wcc_step = Artifact::load(&client, &dir2, "wcc_step")?;
                    Ok((client, gap_scan, wcc_step))
                })();
                let (client, gap_scan, wcc_step) = match init {
                    Ok(t) => {
                        let _ = ready_tx.send(Ok(()));
                        t
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Scan { gaps, carry, reply } => {
                            let _ = reply.send(run_scan(&gap_scan, &gaps, carry));
                        }
                        Request::WccStep { labels, src, dst, reply } => {
                            let _ = reply.send(run_wcc(&wcc_step, &labels, &src, &dst));
                        }
                        Request::Platform { reply } => {
                            let _ = reply.send(client.platform_name());
                        }
                    }
                }
            })
            .context("spawn xla service")?;
        ready_rx.recv().context("xla service died during init")??;
        Ok(Arc::new(Self { tx: Mutex::new(tx), worker: Mutex::new(Some(worker)), dir }))
    }

    /// Default artifacts directory: `$PARAGRAPHER_ARTIFACTS`, else
    /// `<workspace>/artifacts` (repo layout), else `./artifacts`.
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("PARAGRAPHER_ARTIFACTS") {
            return PathBuf::from(d);
        }
        let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let repo = manifest.parent().map(|p| p.join("artifacts"));
        match repo {
            Some(p) if p.exists() => p,
            _ => PathBuf::from("artifacts"),
        }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn send(&self, req: Request) -> Result<()> {
        self.tx
            .lock()
            .expect("xla tx lock")
            .send(req)
            .map_err(|_| anyhow::anyhow!("xla service thread gone"))
    }

    pub fn platform(&self) -> Result<String> {
        let (reply, rx) = channel();
        self.send(Request::Platform { reply })?;
        rx.recv().context("xla service reply")
    }

    /// Inclusive i64 scan of exactly [`GAP_SCAN_BLOCK`] elements with a
    /// scalar carry added to every prefix.
    pub fn gap_scan_block(&self, gaps: &[i64], carry: i64) -> Result<Vec<i64>> {
        if gaps.len() != GAP_SCAN_BLOCK {
            bail!("gap_scan expects {GAP_SCAN_BLOCK} elements, got {}", gaps.len());
        }
        let (reply, rx) = channel();
        self.send(Request::Scan { gaps: gaps.to_vec(), carry, reply })?;
        rx.recv().context("xla service reply")?
    }

    /// One WCC label-propagation step over a fixed-shape edge block:
    /// `labels'[i] = min(labels[i], min over edges (u,v) incident labels)`.
    /// Pad unused edge slots with `(0, 0)` self-edges.
    pub fn wcc_step_block(&self, labels: &[i32], src: &[i32], dst: &[i32]) -> Result<Vec<i32>> {
        if labels.len() != WCC_BLOCK || src.len() != WCC_BLOCK || dst.len() != WCC_BLOCK {
            bail!("wcc_step expects {WCC_BLOCK}-length arrays");
        }
        let (reply, rx) = channel();
        self.send(Request::WccStep {
            labels: labels.to_vec(),
            src: src.to_vec(),
            dst: dst.to_vec(),
            reply,
        })?;
        rx.recv().context("xla service reply")?
    }
}

impl Drop for ArtifactSet {
    fn drop(&mut self) {
        // Close the channel, then join the service thread.
        {
            let (tx, _rx) = channel();
            let mut guard = self.tx.lock().expect("xla tx lock");
            *guard = tx; // drop the real sender
        }
        if let Some(h) = self.worker.lock().expect("worker lock").take() {
            let _ = h.join();
        }
    }
}

fn run_scan(art: &Artifact, gaps: &[i64], carry: i64) -> Result<Vec<i64>> {
    let x = xla::Literal::vec1(gaps);
    let c = xla::Literal::scalar(carry);
    let out = art.run(&[x, c])?;
    out.to_vec::<i64>().map_err(|e| anyhow::anyhow!("gap_scan output: {e:?}"))
}

fn run_wcc(art: &Artifact, labels: &[i32], src: &[i32], dst: &[i32]) -> Result<Vec<i32>> {
    let l = xla::Literal::vec1(labels);
    let s = xla::Literal::vec1(src);
    let d = xla::Literal::vec1(dst);
    let out = art.run(&[l, s, d])?;
    out.to_vec::<i32>().map_err(|e| anyhow::anyhow!("wcc_step output: {e:?}"))
}

/// [`ScanEngine`] backed by the AOT Pallas gap-scan kernel. Arbitrary-length
/// arrays are processed in [`GAP_SCAN_BLOCK`] chunks, chaining the carry
/// through the executable's scalar input.
pub struct XlaScanEngine {
    artifacts: Arc<ArtifactSet>,
}

impl XlaScanEngine {
    pub fn new(artifacts: Arc<ArtifactSet>) -> Self {
        Self { artifacts }
    }
}

impl ScanEngine for XlaScanEngine {
    fn name(&self) -> &'static str {
        "xla-pallas"
    }

    fn inclusive_scan_i64(&self, gaps: &mut [i64]) -> Result<()> {
        let mut carry = 0i64;
        let mut pos = 0usize;
        let mut padded = vec![0i64; GAP_SCAN_BLOCK];
        while pos < gaps.len() {
            let take = (gaps.len() - pos).min(GAP_SCAN_BLOCK);
            let out = if take == GAP_SCAN_BLOCK {
                self.artifacts.gap_scan_block(&gaps[pos..pos + take], carry)?
            } else {
                padded[..take].copy_from_slice(&gaps[pos..pos + take]);
                for p in padded[take..].iter_mut() {
                    *p = 0;
                }
                self.artifacts.gap_scan_block(&padded, carry)?
            };
            gaps[pos..pos + take].copy_from_slice(&out[..take]);
            carry = gaps[pos + take - 1];
            pos += take;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeScan;

    fn artifacts() -> Option<Arc<ArtifactSet>> {
        let dir = ArtifactSet::default_dir();
        match ArtifactSet::load(&dir) {
            Ok(a) => Some(a),
            Err(e) => {
                eprintln!("skipping XLA tests ({e}); run `make artifacts`");
                None
            }
        }
    }

    #[test]
    fn xla_scan_matches_native() {
        let Some(arts) = artifacts() else { return };
        let engine = XlaScanEngine::new(arts);
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(4);
        for len in [0usize, 1, 100, GAP_SCAN_BLOCK - 1, GAP_SCAN_BLOCK, GAP_SCAN_BLOCK + 13] {
            let base: Vec<i64> =
                (0..len).map(|_| rng.next_below(1000) as i64 - 300).collect();
            let mut a = base.clone();
            let mut b = base.clone();
            engine.inclusive_scan_i64(&mut a).unwrap();
            NativeScan.inclusive_scan_i64(&mut b).unwrap();
            assert_eq!(a, b, "len={len}");
        }
    }

    #[test]
    fn wcc_step_executes() {
        let Some(arts) = artifacts() else { return };
        let mut labels: Vec<i32> = (0..WCC_BLOCK as i32).collect();
        let mut src = vec![0i32; WCC_BLOCK];
        let mut dst = vec![0i32; WCC_BLOCK];
        // A chain 0-1, 1-2, 2-3 (padding slots are (0,0) self-edges).
        src[0] = 0;
        dst[0] = 1;
        src[1] = 1;
        dst[1] = 2;
        src[2] = 2;
        dst[2] = 3;
        for _ in 0..3 {
            labels = arts.wcc_step_block(&labels, &src, &dst).unwrap();
        }
        assert_eq!(&labels[..5], &[0, 0, 0, 0, 4]);
    }
}
