//! PJRT runtime: loads the AOT-compiled XLA executables produced by
//! `python/compile/aot.py` (HLO *text* — see DESIGN.md; serialized protos
//! from jax ≥ 0.5 are rejected by xla_extension 0.5.1) and exposes them to
//! the L3 hot path.
//!
//! Python never runs at load/serve time: `make artifacts` runs once at
//! build time; this module only reads `artifacts/*.hlo.txt`.
//!
//! Exposed engines:
//! * [`ScanEngine`] — the gap→ID inclusive scan used by the decoder's
//!   phase 2 ([`NativeScan`] in Rust, [`XlaScanEngine`] through the Pallas
//!   kernel's HLO).
//! * `ArtifactSet::wcc_step_block` — one label-propagation step over a fixed-shape edge
//!   block (the analytics consumer used by examples/benches).

mod exec;

pub use exec::{ArtifactSet, XlaScanEngine, GAP_SCAN_BLOCK, WCC_BLOCK};

use anyhow::Result;

/// Inclusive scan over i64 gaps: `out[i] = sum(gaps[0..=i])`. The decoder
/// concatenates all residual gaps of a decoded block into one array and
/// calls this once per block (phase 2 of decoding).
pub trait ScanEngine: Send + Sync {
    fn name(&self) -> &'static str;
    fn inclusive_scan_i64(&self, gaps: &mut [i64]) -> Result<()>;
}

/// Pure-Rust scan (the default, and the oracle for the XLA path).
pub struct NativeScan;

impl ScanEngine for NativeScan {
    fn name(&self) -> &'static str {
        "native"
    }

    fn inclusive_scan_i64(&self, gaps: &mut [i64]) -> Result<()> {
        let mut acc = 0i64;
        for g in gaps.iter_mut() {
            acc += *g;
            *g = acc;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_scan_basics() {
        let mut v = vec![5i64, -2, 3, 0, -6];
        NativeScan.inclusive_scan_i64(&mut v).unwrap();
        assert_eq!(v, vec![5, 3, 6, 6, 0]);
        let mut empty: Vec<i64> = vec![];
        NativeScan.inclusive_scan_i64(&mut empty).unwrap();
    }
}
