//! PJRT runtime: loads the AOT-compiled XLA executables produced by
//! `python/compile/aot.py` (HLO *text* — see DESIGN.md; serialized protos
//! from jax ≥ 0.5 are rejected by xla_extension 0.5.1) and exposes them to
//! the L3 hot path.
//!
//! Python never runs at load/serve time: `make artifacts` runs once at
//! build time; this module only reads `artifacts/*.hlo.txt`.
//!
//! Exposed engines:
//! * [`ScanEngine`] — the gap→ID inclusive scan used by the decoder's
//!   phase 2 ([`NativeScan`] in Rust, [`XlaScanEngine`] through the Pallas
//!   kernel's HLO). The trait also carries the *fused* variant
//!   ([`ScanEngine::scan_validate_u32`]) that folds the decoder's former
//!   separate validation walk into the scan itself.
//! * `ArtifactSet::wcc_step_block` — one label-propagation step over a fixed-shape edge
//!   block (the analytics consumer used by examples/benches).

mod exec;

pub use exec::{ArtifactSet, XlaScanEngine, GAP_SCAN_BLOCK, WCC_BLOCK};

use anyhow::Result;

/// First element of a fused scan whose running sum left `[0, upper)` —
/// returned by [`ScanEngine::scan_validate_u32`] so the decoder can map the
/// flat index back to the offending vertex on the (cold) error path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScanViolation {
    /// Index into the gap array of the first out-of-range running sum.
    pub index: usize,
    /// The out-of-range running sum itself.
    pub value: i64,
}

/// Inclusive scan over i64 gaps: `out[i] = sum(gaps[0..=i])`. The decoder
/// concatenates all residual gaps of a decoded block into one array and
/// calls this once per block (phase 2 of decoding).
pub trait ScanEngine: Send + Sync {
    fn name(&self) -> &'static str;

    fn inclusive_scan_i64(&self, gaps: &mut [i64]) -> Result<()>;

    /// Fused gap→absolute inclusive scan + bounds validation + `u32`
    /// narrowing: scans `gaps` in place, writes each running sum (narrowed
    /// to `u32`) into `out` (cleared first), and reports the first sum
    /// outside `[0, upper)` as `Ok(Some(_))`.
    ///
    /// This is the decoder's phase-2 hot loop: the former pipeline scanned
    /// the block's gap array, then *re-walked* every per-vertex segment to
    /// range-check and narrow the absolutes — two passes over the same
    /// cache lines. Strict monotonicity folds into this single pass
    /// structurally: in-segment gaps are validated `≥ 1` at parse time, so
    /// every in-range running sum is automatically strictly increasing
    /// within its segment, and the old `r <= prev` walk is subsumed.
    ///
    /// On a violation, the contents of `gaps`/`out` beyond the reported
    /// index are unspecified (the caller is about to fail the decode).
    ///
    /// The default implementation composes `inclusive_scan_i64` with a
    /// separate validation walk, so offload engines (XLA/Pallas) keep
    /// working unchanged; [`NativeScan`] overrides it with a single
    /// unrolled, auto-vectorizable pass.
    fn scan_validate_u32(
        &self,
        gaps: &mut [i64],
        upper: u64,
        out: &mut Vec<u32>,
    ) -> Result<Option<ScanViolation>> {
        self.inclusive_scan_i64(gaps)?;
        out.clear();
        out.reserve(gaps.len());
        let hi = upper.min(i64::MAX as u64) as i64;
        for (i, &s) in gaps.iter().enumerate() {
            if s < 0 || s >= hi {
                return Ok(Some(ScanViolation { index: i, value: s }));
            }
            out.push(s as u32);
        }
        Ok(None)
    }
}

/// Pure-Rust scan (the default, and the oracle for the XLA path).
pub struct NativeScan;

impl ScanEngine for NativeScan {
    fn name(&self) -> &'static str {
        "native"
    }

    fn inclusive_scan_i64(&self, gaps: &mut [i64]) -> Result<()> {
        let mut acc = 0i64;
        for g in gaps.iter_mut() {
            acc = acc.wrapping_add(*g);
            *g = acc;
        }
        Ok(())
    }

    /// One pass, 8-wide unrolled: the only loop-carried dependency is the
    /// running accumulator (one chain per 8 elements); the bounds folds and
    /// the narrowing stores have no cross-iteration dependence, so the
    /// compiler vectorizes them. Violations accumulate into a sign-bit mask
    /// (`s` in `[0, hi)` iff `s | (hi-1 - s)` is non-negative) and the
    /// exact index is recovered by a scalar re-walk only on the error path.
    fn scan_validate_u32(
        &self,
        gaps: &mut [i64],
        upper: u64,
        out: &mut Vec<u32>,
    ) -> Result<Option<ScanViolation>> {
        // Resize without clearing first: the loop below unconditionally
        // writes every element, and a clear-then-resize would memset the
        // whole (warmed, steady-state) output before overwriting it again.
        out.resize(gaps.len(), 0);
        let hi = upper.min(i64::MAX as u64) as i64;
        let n1 = hi.wrapping_sub(1);
        let mut acc = 0i64;
        let mut bad = 0i64;
        for (g, o) in gaps.chunks_exact_mut(8).zip(out.chunks_exact_mut(8)) {
            let s0 = acc.wrapping_add(g[0]);
            let s1 = s0.wrapping_add(g[1]);
            let s2 = s1.wrapping_add(g[2]);
            let s3 = s2.wrapping_add(g[3]);
            let s4 = s3.wrapping_add(g[4]);
            let s5 = s4.wrapping_add(g[5]);
            let s6 = s5.wrapping_add(g[6]);
            let s7 = s6.wrapping_add(g[7]);
            acc = s7;
            g[0] = s0;
            g[1] = s1;
            g[2] = s2;
            g[3] = s3;
            g[4] = s4;
            g[5] = s5;
            g[6] = s6;
            g[7] = s7;
            bad |= s0 | n1.wrapping_sub(s0);
            bad |= s1 | n1.wrapping_sub(s1);
            bad |= s2 | n1.wrapping_sub(s2);
            bad |= s3 | n1.wrapping_sub(s3);
            bad |= s4 | n1.wrapping_sub(s4);
            bad |= s5 | n1.wrapping_sub(s5);
            bad |= s6 | n1.wrapping_sub(s6);
            bad |= s7 | n1.wrapping_sub(s7);
            o[0] = s0 as u32;
            o[1] = s1 as u32;
            o[2] = s2 as u32;
            o[3] = s3 as u32;
            o[4] = s4 as u32;
            o[5] = s5 as u32;
            o[6] = s6 as u32;
            o[7] = s7 as u32;
        }
        let tail = gaps.len() - gaps.len() % 8;
        for (g, o) in gaps[tail..].iter_mut().zip(out[tail..].iter_mut()) {
            let s = acc.wrapping_add(*g);
            acc = s;
            *g = s;
            bad |= s | n1.wrapping_sub(s);
            *o = s as u32;
        }
        if bad < 0 {
            // Cold path: some element left the range — find the first.
            for (i, &s) in gaps.iter().enumerate() {
                if s < 0 || s >= hi {
                    return Ok(Some(ScanViolation { index: i, value: s }));
                }
            }
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn native_scan_basics() {
        let mut v = vec![5i64, -2, 3, 0, -6];
        NativeScan.inclusive_scan_i64(&mut v).unwrap();
        assert_eq!(v, vec![5, 3, 6, 6, 0]);
        let mut empty: Vec<i64> = vec![];
        NativeScan.inclusive_scan_i64(&mut empty).unwrap();
    }

    /// The trait-default (scan + walk) is the oracle for the fused override.
    struct DefaultPath;
    impl ScanEngine for DefaultPath {
        fn name(&self) -> &'static str {
            "default-path"
        }
        fn inclusive_scan_i64(&self, gaps: &mut [i64]) -> Result<()> {
            NativeScan.inclusive_scan_i64(gaps)
        }
    }

    #[test]
    fn fused_matches_scan_then_validate_on_clean_input() {
        let mut rng = Xoshiro256::seed_from_u64(11);
        for len in [0usize, 1, 7, 8, 9, 15, 16, 17, 100, 1000] {
            // Small non-negative gaps: sums stay well inside [0, upper).
            let gaps: Vec<i64> = (0..len).map(|_| rng.next_below(5) as i64).collect();
            let upper = (gaps.iter().sum::<i64>() + 1) as u64;
            let mut a = gaps.clone();
            let mut b = gaps.clone();
            let mut out_a = Vec::new();
            let mut out_b = Vec::new();
            let va = NativeScan.scan_validate_u32(&mut a, upper, &mut out_a).unwrap();
            let vb = DefaultPath.scan_validate_u32(&mut b, upper, &mut out_b).unwrap();
            assert_eq!(va, None, "len {len}");
            assert_eq!(vb, None, "len {len}");
            assert_eq!(a, b, "len {len}: in-place absolutes");
            assert_eq!(out_a, out_b, "len {len}: narrowed output");
            let expect: Vec<u32> = gaps
                .iter()
                .scan(0i64, |acc, &g| {
                    *acc += g;
                    Some(*acc as u32)
                })
                .collect();
            assert_eq!(out_a, expect, "len {len}");
        }
    }

    #[test]
    fn fused_flags_first_violation() {
        // Below zero, at the upper bound, and far above — at every lane
        // alignment — must report the same first index as the oracle walk.
        for len in [1usize, 5, 8, 9, 16, 33] {
            for bad_at in 0..len {
                for bad_gap in [-1000i64, 100, 1_000_000] {
                    let mut gaps = vec![1i64; len];
                    gaps[bad_at] = bad_gap;
                    let upper = 50u64;
                    let mut a = gaps.clone();
                    let mut out = Vec::new();
                    let va = NativeScan.scan_validate_u32(&mut a, upper, &mut out).unwrap();
                    let mut b = gaps.clone();
                    let mut out_b = Vec::new();
                    let vb =
                        DefaultPath.scan_validate_u32(&mut b, upper, &mut out_b).unwrap();
                    assert_eq!(va, vb, "len {len} bad_at {bad_at} gap {bad_gap}");
                    // Prefix sums before `bad_at` are 1..=bad_at, all in
                    // range; the spiked element is always the first (and
                    // only reported) violation.
                    let v = va.expect("spiked sum must be flagged");
                    assert_eq!(v.index, bad_at);
                    assert_eq!(v.value, bad_at as i64 + bad_gap);
                }
            }
        }
    }

    #[test]
    fn fused_rejects_everything_on_empty_range() {
        // upper = 0: no value is in range.
        let mut gaps = vec![0i64, 1];
        let mut out = Vec::new();
        let v = NativeScan.scan_validate_u32(&mut gaps, 0, &mut out).unwrap();
        assert_eq!(v, Some(ScanViolation { index: 0, value: 0 }));
        // And an empty array is clean regardless of the bound.
        let mut empty: Vec<i64> = Vec::new();
        assert_eq!(NativeScan.scan_validate_u32(&mut empty, 0, &mut out).unwrap(), None);
        assert!(out.is_empty());
    }

    #[test]
    fn fused_randomized_against_oracle() {
        let mut rng = Xoshiro256::seed_from_u64(77);
        for case in 0..200 {
            let len = rng.next_below(64) as usize;
            let upper = 1 + rng.next_below(1000);
            let gaps: Vec<i64> = (0..len)
                .map(|_| rng.next_below(40) as i64 - 4) // occasionally negative
                .collect();
            let mut a = gaps.clone();
            let mut b = gaps.clone();
            let mut out_a = Vec::new();
            let mut out_b = Vec::new();
            let va = NativeScan.scan_validate_u32(&mut a, upper, &mut out_a).unwrap();
            let vb = DefaultPath.scan_validate_u32(&mut b, upper, &mut out_b).unwrap();
            assert_eq!(va, vb, "case {case}");
            if va.is_none() {
                assert_eq!(out_a, out_b, "case {case}");
                assert_eq!(a, b, "case {case}");
            }
        }
    }
}
