//! Always-on span tracing with dual clocks, bounded per-thread rings,
//! and a Chrome trace-event (Perfetto-viewable) exporter.
//!
//! Design points:
//!
//! * **Ownership** — each recording thread owns one bounded [`Ring`]
//!   (registered with the process [`Tracer`] on first use). Recording
//!   locks only the thread's own ring (uncontended in steady state), so
//!   a span is a timestamp read plus one short critical section: spans
//!   are never torn, and a full ring drops the *oldest* span, never
//!   blocks the recorder.
//! * **Dual clocks** — every span carries a real monotonic duration and,
//!   where the site computes one, the §3 model's virtual-clock duration.
//!   The exporter emits two process lanes: pid 1 is the real timeline;
//!   pid 2 replays the same spans on a per-thread virtual timeline built
//!   by accumulating modeled durations, so Perfetto shows measured vs
//!   modeled side by side.
//! * **Kill-switch** — recording is gated on [`super::enabled`]
//!   (`PG_OBS=off`); a disabled record is a single relaxed load.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use super::registry::Histo;
use crate::util::json::Json;

/// Spans retained per thread before the oldest is dropped.
pub const RING_CAPACITY: usize = 8192;

/// One completed span. `cat`/`name` are static so recording never
/// allocates; `arg` carries the site's one interesting number (vertex,
/// tile, block index…) into the exported event's `args`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Span {
    pub cat: &'static str,
    pub name: &'static str,
    /// Start, nanoseconds since the tracer epoch (real clock).
    pub start_ns: u64,
    /// Real monotonic duration.
    pub dur_ns: u64,
    /// §3 model virtual-clock duration (0 when the site has no model).
    pub virt_dur_ns: u64,
    /// Tracer-assigned recording-thread id.
    pub tid: u64,
    pub arg: u64,
}

/// A bounded span ring: push is O(1), overflow evicts the oldest.
#[derive(Debug)]
pub struct Ring {
    buf: VecDeque<Span>,
    cap: usize,
    dropped: u64,
}

impl Ring {
    pub fn with_capacity(cap: usize) -> Ring {
        Ring { buf: VecDeque::with_capacity(cap.min(RING_CAPACITY)), cap, dropped: 0 }
    }

    pub fn push(&mut self, span: Span) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(span);
    }

    pub fn spans(&self) -> impl Iterator<Item = &Span> {
        self.buf.iter()
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Spans evicted so far (oldest-first drop policy).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

/// The process-wide tracer: an epoch plus the registry of thread rings.
pub struct Tracer {
    epoch: Instant,
    rings: Mutex<Vec<Arc<Mutex<Ring>>>>,
    next_tid: AtomicU64,
}

thread_local! {
    static THREAD_RING: std::cell::OnceCell<(u64, Arc<Mutex<Ring>>)> =
        const { std::cell::OnceCell::new() };
}

/// The process tracer (lazily initialized).
pub fn tracer() -> &'static Tracer {
    static TRACER: OnceLock<Tracer> = OnceLock::new();
    TRACER.get_or_init(|| Tracer {
        epoch: Instant::now(),
        rings: Mutex::new(Vec::new()),
        next_tid: AtomicU64::new(1),
    })
}

impl Tracer {
    fn with_thread_ring(&self, f: impl FnOnce(u64, &Mutex<Ring>)) {
        THREAD_RING.with(|cell| {
            let (tid, ring) = cell.get_or_init(|| {
                let tid = self.next_tid.fetch_add(1, Ordering::Relaxed);
                let ring = Arc::new(Mutex::new(Ring::with_capacity(RING_CAPACITY)));
                crate::coordinator::lock_recover(&self.rings).push(Arc::clone(&ring));
                (tid, ring)
            });
            f(*tid, ring);
        });
    }

    /// Record one completed span (no-op when `PG_OBS=off`).
    pub fn record(
        &self,
        cat: &'static str,
        name: &'static str,
        start: Instant,
        dur: Duration,
        virt_dur_ns: u64,
        arg: u64,
    ) {
        if !super::enabled() {
            return;
        }
        let start_ns =
            u64::try_from(start.saturating_duration_since(self.epoch).as_nanos()).unwrap_or(u64::MAX);
        let dur_ns = u64::try_from(dur.as_nanos()).unwrap_or(u64::MAX);
        self.with_thread_ring(|tid, ring| {
            crate::coordinator::lock_recover(ring)
                .push(Span { cat, name, start_ns, dur_ns, virt_dur_ns, tid, arg });
        });
    }

    /// All retained spans across every thread ring plus the total
    /// dropped-span count, sorted by real start time.
    pub fn snapshot(&self) -> (Vec<Span>, u64) {
        let rings = crate::coordinator::lock_recover(&self.rings).clone();
        let mut spans = Vec::new();
        let mut dropped = 0;
        for ring in rings {
            let ring = crate::coordinator::lock_recover(&ring);
            spans.extend(ring.spans().cloned());
            dropped += ring.dropped();
        }
        spans.sort_by_key(|s| s.start_ns);
        (spans, dropped)
    }

    /// Chrome trace-event JSON: pid 1 = real clock, pid 2 = virtual
    /// clock (per-thread cumulative modeled timeline). Timestamps in µs.
    pub fn chrome_trace(&self) -> Json {
        let (spans, dropped) = self.snapshot();
        let mut events = Json::Arr(Vec::new());
        for (pid, label) in [(1u64, "real clock"), (2, "virtual clock (§3 model)")] {
            let mut meta = Json::obj();
            let mut args = Json::obj();
            args.set("name", label);
            meta.set("ph", "M").set("name", "process_name").set("pid", pid).set("args", args);
            events.push(meta);
        }
        let event = |span: &Span, pid: u64, ts_us: f64, dur_us: f64| {
            let mut e = Json::obj();
            let mut args = Json::obj();
            args.set("arg", span.arg).set("virt_dur_us", span.virt_dur_ns as f64 / 1e3);
            e.set("name", span.name)
                .set("cat", span.cat)
                .set("ph", "X")
                .set("ts", ts_us)
                .set("dur", dur_us)
                .set("pid", pid)
                .set("tid", span.tid)
                .set("args", args);
            e
        };
        for span in &spans {
            events.push(event(span, 1, span.start_ns as f64 / 1e3, span.dur_ns as f64 / 1e3));
        }
        // Virtual lane: replay modeled spans per thread, back to back —
        // the modeled timeline has no global origin, only durations.
        let mut cum: std::collections::BTreeMap<u64, f64> = std::collections::BTreeMap::new();
        for span in &spans {
            if span.virt_dur_ns == 0 {
                continue;
            }
            let ts = cum.entry(span.tid).or_insert(0.0);
            let dur_us = span.virt_dur_ns as f64 / 1e3;
            events.push(event(span, 2, *ts, dur_us));
            *ts += dur_us;
        }
        let mut other = Json::obj();
        other.set("dropped_spans", dropped).set("span_count", spans.len());
        let mut doc = Json::obj();
        doc.set("traceEvents", events).set("displayTimeUnit", "ms").set("otherData", other);
        doc
    }

    /// Write the Chrome trace to a file (the `Options::trace_path` /
    /// `paragrapher trace` exporter).
    pub fn export(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.chrome_trace().to_string_pretty())
    }
}

/// RAII span: times from construction to drop, records into the process
/// tracer and (optionally) a latency histogram — one guard covers every
/// exit path of a request function.
pub struct SpanGuard {
    cat: &'static str,
    name: &'static str,
    start: Instant,
    virt_dur_ns: u64,
    arg: u64,
    hist: Option<Histo>,
}

impl SpanGuard {
    pub fn new(cat: &'static str, name: &'static str) -> SpanGuard {
        SpanGuard { cat, name, start: Instant::now(), virt_dur_ns: 0, arg: 0, hist: None }
    }

    /// Also record the real duration into `hist` on drop.
    pub fn with_hist(mut self, hist: Histo) -> SpanGuard {
        self.hist = Some(hist);
        self
    }

    pub fn set_arg(&mut self, arg: u64) {
        self.arg = arg;
    }

    /// Attach the site's modeled (virtual-clock) duration.
    pub fn set_virt_secs(&mut self, secs: f64) {
        if secs >= 0.0 {
            self.virt_dur_ns = (secs * 1e9) as u64;
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let dur = self.start.elapsed();
        if let Some(hist) = &self.hist {
            hist.record_duration(dur);
        }
        tracer().record(self.cat, self.name, self.start, dur, self.virt_dur_ns, self.arg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_wraparound_drops_oldest_never_tears() {
        let mut ring = Ring::with_capacity(4);
        for i in 0..10u64 {
            ring.push(Span {
                cat: "t",
                name: "s",
                start_ns: i * 100,
                dur_ns: i * 100 + 1,
                virt_dur_ns: i * 100 + 2,
                tid: 1,
                arg: i,
            });
        }
        assert_eq!(ring.len(), 4);
        assert_eq!(ring.dropped(), 6);
        let retained: Vec<u64> = ring.spans().map(|s| s.arg).collect();
        // Newest 4 survive, in order.
        assert_eq!(retained, vec![6, 7, 8, 9]);
        // Never torn: every retained span's fields are self-consistent.
        for s in ring.spans() {
            assert_eq!(s.start_ns, s.arg * 100);
            assert_eq!(s.dur_ns, s.arg * 100 + 1);
            assert_eq!(s.virt_dur_ns, s.arg * 100 + 2);
        }
    }

    #[test]
    fn tracer_records_and_exports_dual_lanes() {
        let _guard = super::super::test_toggle_lock();
        super::super::set_enabled(true);
        let t = tracer();
        let start = Instant::now();
        t.record("unit-test-cat", "span-a", start, Duration::from_micros(5), 2_000, 7);
        t.record("unit-test-cat", "span-b", start, Duration::from_micros(3), 0, 8);
        let (spans, _) = t.snapshot();
        assert!(spans.iter().any(|s| s.cat == "unit-test-cat" && s.name == "span-a"));
        let doc = t.chrome_trace();
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        // Both clock lanes are present…
        let pids: std::collections::BTreeSet<u64> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .filter_map(|e| e.get("pid").and_then(Json::as_u64))
            .collect();
        assert!(pids.contains(&1));
        assert!(pids.contains(&2), "virtual lane missing: {pids:?}");
        // …and the export re-parses as valid JSON.
        let text = doc.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), doc);
    }

    #[test]
    fn kill_switch_suppresses_recording() {
        let _guard = super::super::test_toggle_lock();
        let t = tracer();
        super::super::set_enabled(false);
        let before = t.snapshot().0.len();
        t.record("killed-cat", "x", Instant::now(), Duration::from_nanos(1), 0, 0);
        super::super::set_enabled(true);
        let after: usize = t.snapshot().0.iter().filter(|s| s.cat == "killed-cat").count();
        assert_eq!(after, 0, "span recorded despite kill-switch (before={before})");
    }

    #[test]
    fn span_guard_records_on_drop() {
        let _guard = super::super::test_toggle_lock();
        super::super::set_enabled(true);
        let hist = Histo::detached();
        {
            let mut g = SpanGuard::new("guard-test-cat", "guarded").with_hist(hist.clone());
            g.set_arg(42);
            g.set_virt_secs(1e-6);
        }
        assert_eq!(hist.snapshot().total, 1);
        let (spans, _) = tracer().snapshot();
        let s = spans.iter().find(|s| s.cat == "guard-test-cat").expect("guard span");
        assert_eq!(s.arg, 42);
        assert_eq!(s.virt_dur_ns, 1_000);
    }
}
