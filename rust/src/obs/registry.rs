//! The metrics registry: named counters and histograms with cheap typed
//! handles, plus plain-data snapshots that merge across threads and
//! processes.
//!
//! A registry is *instantiable* — each opened graph owns one, so tests
//! and concurrent graphs stay isolated — and aggregation happens on
//! snapshots, not on live registries: `snapshot()` → `merge()` →
//! `to_json()` is the whole cross-process story (the distributed worker
//! ships its snapshot in its final frame; the leader merges by name).

use std::collections::BTreeMap;
use std::ops::Deref;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::hist::{HistSnapshot, Histogram};
use crate::util::json::Json;

/// A counter/gauge handle: one shared relaxed atomic. `Deref`s to
/// [`AtomicU64`] so legacy counter-struct call sites
/// (`stats.foo.fetch_add(..)`, `.load(..)`) keep working after the
/// struct's fields become registry views.
#[derive(Clone, Debug)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// A counter not attached to any registry (default construction of
    /// counter structs outside a coordinator).
    pub fn detached() -> Counter {
        Counter(Arc::new(AtomicU64::new(0)))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Gauge-style overwrite.
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl Default for Counter {
    fn default() -> Self {
        Counter::detached()
    }
}

impl Deref for Counter {
    type Target = AtomicU64;
    fn deref(&self) -> &AtomicU64 {
        &self.0
    }
}

/// A histogram handle. Recording respects the `PG_OBS` kill-switch.
#[derive(Clone)]
pub struct Histo(Arc<Histogram>);

impl Histo {
    pub fn detached() -> Histo {
        Histo(Arc::new(Histogram::new()))
    }

    #[inline]
    pub fn record(&self, v: u64) {
        if super::enabled() {
            self.0.record(v);
        }
    }

    #[inline]
    pub fn record_duration(&self, d: Duration) {
        self.record(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    /// Record modeled (virtual-clock) seconds as nanoseconds.
    pub fn record_secs(&self, s: f64) {
        if s >= 0.0 {
            self.record((s * 1e9) as u64);
        }
    }

    pub fn snapshot(&self) -> HistSnapshot {
        self.0.snapshot()
    }
}

impl Default for Histo {
    fn default() -> Self {
        Histo::detached()
    }
}

impl std::fmt::Debug for Histo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histo").field("total", &self.0.total()).finish()
    }
}

/// Named metrics, get-or-create by name. Handle resolution takes a lock;
/// recording through a resolved handle is lock-free — resolve once at
/// construction time, never on the hot path.
#[derive(Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, Counter>>,
    hists: Mutex<BTreeMap<String, Histo>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    pub fn counter(&self, name: &str) -> Counter {
        let mut map = crate::coordinator::lock_recover(&self.counters);
        map.entry(name.to_string()).or_insert_with(Counter::detached).clone()
    }

    pub fn histogram(&self, name: &str) -> Histo {
        let mut map = crate::coordinator::lock_recover(&self.hists);
        map.entry(name.to_string()).or_insert_with(Histo::detached).clone()
    }

    /// Point-in-time plain-data view of every registered metric.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let counters = crate::coordinator::lock_recover(&self.counters)
            .iter()
            .map(|(k, c)| (k.clone(), c.get()))
            .collect();
        let hists = crate::coordinator::lock_recover(&self.hists)
            .iter()
            .map(|(k, h)| (k.clone(), h.snapshot()))
            .collect();
        MetricsSnapshot { counters, hists }
    }
}

/// Plain-data snapshot of a registry: mergeable by name, JSON
/// round-trippable (this is the `BENCH_metrics.json` schema and the
/// distributed metrics frame payload).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub hists: BTreeMap<String, HistSnapshot>,
}

impl MetricsSnapshot {
    /// Merge by name: counters add, histograms merge bucket-wise.
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, h) in &other.hists {
            self.hists.entry(k.clone()).or_insert_with(HistSnapshot::empty).merge(h);
        }
    }

    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (k, v) in &self.counters {
            counters.set(k, *v);
        }
        let mut hists = Json::obj();
        for (k, h) in &self.hists {
            hists.set(k, h.to_json());
        }
        let mut o = Json::obj();
        o.set("counters", counters).set("histograms", hists);
        o
    }

    pub fn from_json(doc: &Json) -> Result<MetricsSnapshot, String> {
        let mut s = MetricsSnapshot::default();
        match doc.get("counters") {
            Some(Json::Obj(map)) => {
                for (k, v) in map {
                    let v = v.as_u64().ok_or_else(|| format!("counter {k:?} not a u64"))?;
                    s.counters.insert(k.clone(), v);
                }
            }
            _ => return Err("metrics snapshot: missing counters".to_string()),
        }
        match doc.get("histograms") {
            Some(Json::Obj(map)) => {
                for (k, v) in map {
                    s.hists.insert(k.clone(), HistSnapshot::from_json(v)?);
                }
            }
            _ => return Err("metrics snapshot: missing histograms".to_string()),
        }
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_share_state_by_name() {
        let r = MetricsRegistry::new();
        let a = r.counter("x");
        let b = r.counter("x");
        a.add(3);
        b.inc();
        assert_eq!(r.counter("x").get(), 4);
        let h1 = r.histogram("h");
        let h2 = r.histogram("h");
        h1.record(10);
        h2.record(20);
        assert_eq!(r.histogram("h").snapshot().total, 2);
    }

    #[test]
    fn snapshot_merge_and_json_round_trip() {
        let r1 = MetricsRegistry::new();
        r1.counter("c").add(5);
        r1.histogram("lat").record(100);
        let r2 = MetricsRegistry::new();
        r2.counter("c").add(7);
        r2.counter("only2").inc();
        r2.histogram("lat").record(300);
        let mut merged = r1.snapshot();
        merged.merge(&r2.snapshot());
        assert_eq!(merged.counters["c"], 12);
        assert_eq!(merged.counters["only2"], 1);
        assert_eq!(merged.hists["lat"].total, 2);
        let back = MetricsSnapshot::from_json(&merged.to_json()).unwrap();
        assert_eq!(back, merged);
    }

    #[test]
    fn counter_derefs_to_atomic() {
        let c = Counter::detached();
        c.fetch_add(2, Ordering::Relaxed);
        assert_eq!(c.load(Ordering::Relaxed), 2);
        assert_eq!(c.get(), 2);
    }
}
