//! Log-bucketed mergeable histogram (HdrHistogram-style layout).
//!
//! Values (u64, typically nanoseconds) land in power-of-two ranges split
//! into [`SUB_COUNT`] linear sub-buckets, so every bucket's width is at
//! most 1/16 of its lower bound — percentile queries are exact to ~6%
//! relative error while the whole table is 976 counters covering the
//! full u64 range. Recording is one relaxed `fetch_add` per value
//! (lock-free, any thread); reads go through [`Histogram::snapshot`],
//! and snapshots merge bucket-wise, which is what makes cross-thread and
//! cross-process (JSON round-trip) aggregation trivial.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// log2 of the linear sub-bucket count per power-of-two range.
pub const SUB_BITS: u32 = 4;
/// Linear sub-buckets per power-of-two range (16).
pub const SUB_COUNT: usize = 1 << SUB_BITS;
/// Total buckets: `[0, 16)` one-per-value, then 60 ranges × 16 covering
/// `[16, u64::MAX]`.
pub const BUCKETS: usize = SUB_COUNT + (64 - SUB_BITS as usize) * SUB_COUNT;

/// Bucket index for a value. Total order preserving: `a <= b` implies
/// `index_of(a) <= index_of(b)`.
pub fn index_of(v: u64) -> usize {
    if v < SUB_COUNT as u64 {
        v as usize
    } else {
        let top = 63 - v.leading_zeros(); // >= SUB_BITS
        let shift = top - SUB_BITS;
        let sub = ((v >> shift) as usize) & (SUB_COUNT - 1);
        SUB_COUNT + (top - SUB_BITS) as usize * SUB_COUNT + sub
    }
}

/// Inclusive-exclusive `[lo, hi)` value bounds of bucket `idx`
/// (saturating at `u64::MAX` for the last bucket).
pub fn bounds_of(idx: usize) -> (u64, u64) {
    if idx < SUB_COUNT {
        (idx as u64, idx as u64 + 1)
    } else {
        let k = ((idx - SUB_COUNT) / SUB_COUNT) as u32;
        let sub = ((idx - SUB_COUNT) % SUB_COUNT) as u64;
        let lo = (SUB_COUNT as u64 + sub) << k;
        (lo, lo.saturating_add(1u64 << k))
    }
}

/// The bucket's representative value (midpoint): what percentile queries
/// return. Always inside the bucket's own bounds.
fn representative(idx: usize) -> u64 {
    let (lo, hi) = bounds_of(idx);
    lo + (hi - lo - 1) / 2
}

/// The concurrent recording side: a fixed table of relaxed atomics.
pub struct Histogram {
    counts: Box<[AtomicU64]>,
    total: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        let counts = (0..BUCKETS).map(|_| AtomicU64::new(0)).collect::<Vec<_>>();
        Histogram {
            counts: counts.into_boxed_slice(),
            total: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one value: three relaxed adds and a `fetch_max`.
    pub fn record(&self, v: u64) {
        self.counts[index_of(v)].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Copy the current counts into a plain-data snapshot. Concurrent
    /// recorders may land between the per-bucket loads — the snapshot is
    /// a consistent-enough point-in-time view, never torn per bucket.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut s = HistSnapshot::empty();
        for (i, c) in self.counts.iter().enumerate() {
            let n = c.load(Ordering::Relaxed);
            if n > 0 {
                s.counts.push((i as u32, n));
                s.total += n;
            }
        }
        // Derive total from the buckets (not self.total) so the snapshot
        // is internally consistent even mid-record.
        s.sum = self.sum.load(Ordering::Relaxed);
        s.max = self.max.load(Ordering::Relaxed);
        s
    }
}

/// Plain-data histogram snapshot: sparse `(bucket, count)` pairs.
/// Mergeable (bucket-wise add) and JSON round-trippable for the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct HistSnapshot {
    /// Sparse nonzero buckets, ascending by index.
    pub counts: Vec<(u32, u64)>,
    pub total: u64,
    pub sum: u64,
    pub max: u64,
}

impl HistSnapshot {
    pub fn empty() -> HistSnapshot {
        HistSnapshot { counts: Vec::new(), total: 0, sum: 0, max: 0 }
    }

    /// Bucket-wise merge (the cross-thread / cross-worker aggregation).
    pub fn merge(&mut self, other: &HistSnapshot) {
        let mut merged: Vec<(u32, u64)> = Vec::with_capacity(self.counts.len() + other.counts.len());
        let (mut a, mut b) = (self.counts.iter().peekable(), other.counts.iter().peekable());
        loop {
            match (a.peek(), b.peek()) {
                (Some(&&(ia, na)), Some(&&(ib, nb))) => {
                    if ia == ib {
                        merged.push((ia, na + nb));
                        a.next();
                        b.next();
                    } else if ia < ib {
                        merged.push((ia, na));
                        a.next();
                    } else {
                        merged.push((ib, nb));
                        b.next();
                    }
                }
                (Some(&&x), None) => {
                    merged.push(x);
                    a.next();
                }
                (None, Some(&&x)) => {
                    merged.push(x);
                    b.next();
                }
                (None, None) => break,
            }
        }
        self.counts = merged;
        self.total += other.total;
        self.sum = self.sum.saturating_add(other.sum);
        self.max = self.max.max(other.max);
    }

    /// Value at quantile `q` in `[0, 1]`: the representative (midpoint)
    /// of the bucket holding the `ceil(q * total)`-th recorded value.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cum = 0u64;
        for &(idx, n) in &self.counts {
            cum += n;
            if cum >= target {
                return representative(idx as usize);
            }
        }
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum as f64 / self.total as f64
    }

    pub fn to_json(&self) -> Json {
        let mut buckets = Json::Arr(Vec::new());
        for &(idx, n) in &self.counts {
            buckets.push(Json::Arr(vec![Json::from(u64::from(idx)), Json::from(n)]));
        }
        let mut o = Json::obj();
        o.set("total", self.total)
            .set("sum", self.sum)
            .set("max", self.max)
            .set("p50", self.percentile(0.50))
            .set("p95", self.percentile(0.95))
            .set("p99", self.percentile(0.99))
            .set("p999", self.percentile(0.999))
            .set("buckets", buckets);
        o
    }

    pub fn from_json(doc: &Json) -> Result<HistSnapshot, String> {
        let field = |k: &str| {
            doc.get(k).and_then(Json::as_u64).ok_or_else(|| format!("hist: missing {k:?}"))
        };
        let mut s = HistSnapshot::empty();
        s.total = field("total")?;
        s.sum = field("sum")?;
        s.max = field("max")?;
        let buckets =
            doc.get("buckets").and_then(Json::as_arr).ok_or("hist: missing buckets")?;
        for pair in buckets {
            let pair = pair.as_arr().ok_or("hist: bucket entry is not a pair")?;
            let (idx, n) = match pair {
                [i, n] => (
                    i.as_u64().ok_or("hist: bad bucket index")?,
                    n.as_u64().ok_or("hist: bad bucket count")?,
                ),
                _ => return Err("hist: bucket entry is not a pair".to_string()),
            };
            if idx as usize >= BUCKETS {
                return Err(format!("hist: bucket index {idx} out of range"));
            }
            s.counts.push((idx as u32, n));
        }
        s.counts.sort_unstable_by_key(|&(i, _)| i);
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_bounds_agree() {
        // Every probe value lands in a bucket whose bounds contain it,
        // and indices are monotone in the value.
        let probes: Vec<u64> = (0..200)
            .chain((0..63).map(|k| 1u64 << k))
            .chain((0..63).map(|k| (1u64 << k) + 1))
            .chain((1..64).map(|k| (1u64 << k) - 1))
            .chain([u64::MAX, u64::MAX - 1])
            .collect();
        let mut sorted = probes.clone();
        sorted.sort_unstable();
        for w in sorted.windows(2) {
            assert!(index_of(w[0]) <= index_of(w[1]), "monotone at {w:?}");
        }
        for v in probes {
            let idx = index_of(v);
            assert!(idx < BUCKETS);
            let (lo, hi) = bounds_of(idx);
            assert!(lo <= v && (v < hi || hi == u64::MAX), "{v} not in [{lo},{hi})");
        }
    }

    #[test]
    fn exact_bucket_boundaries() {
        // The first 16 values get their own buckets…
        for v in 0..16u64 {
            assert_eq!(index_of(v), v as usize);
            assert_eq!(bounds_of(v as usize), (v, v + 1));
        }
        // …then each power-of-two range starts a fresh run of 16.
        assert_eq!(index_of(16), 16);
        assert_eq!(index_of(31), 31);
        assert_eq!(index_of(32), 32);
        assert_eq!(bounds_of(32), (32, 34));
        assert_eq!(index_of(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn percentiles_match_sorted_vector_oracle() {
        // Deterministic pseudo-random values (xorshift), heavy-tailed.
        let mut x = 0x9E3779B97F4A7C15u64;
        let mut vals = Vec::new();
        for _ in 0..10_000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            vals.push(x % 1_000_000 + (x % 97) * (x % 89) * 1000);
        }
        let h = Histogram::new();
        for &v in &vals {
            h.record(v);
        }
        let snap = h.snapshot();
        let mut sorted = vals.clone();
        sorted.sort_unstable();
        for q in [0.5, 0.9, 0.95, 0.99, 0.999] {
            let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
            let oracle = sorted[rank - 1];
            let got = snap.percentile(q);
            // The histogram's answer must fall in the same bucket as the
            // exact order statistic (the quantization guarantee).
            assert_eq!(
                index_of(got),
                index_of(oracle),
                "q={q}: got {got}, oracle {oracle}"
            );
        }
        assert_eq!(snap.total, vals.len() as u64);
        assert_eq!(snap.max, *sorted.last().unwrap());
    }

    #[test]
    fn concurrent_record_then_merge_equals_single() {
        // N threads record disjoint shards into their own histograms;
        // merging the shard snapshots equals one histogram fed everything.
        let all: Vec<u64> = (0..8_000u64).map(|i| i * 37 % 50_021).collect();
        let reference = Histogram::new();
        for &v in &all {
            reference.record(v);
        }
        let shards: Vec<Histogram> = (0..4).map(|_| Histogram::new()).collect();
        std::thread::scope(|scope| {
            for (t, shard) in shards.iter().enumerate() {
                let chunk = &all[t * 2000..(t + 1) * 2000];
                scope.spawn(move || {
                    for &v in chunk {
                        shard.record(v);
                    }
                });
            }
        });
        let mut merged = HistSnapshot::empty();
        for shard in &shards {
            merged.merge(&shard.snapshot());
        }
        assert_eq!(merged, reference.snapshot());
    }

    #[test]
    fn json_round_trip() {
        let h = Histogram::new();
        for v in [0, 1, 15, 16, 1000, 123_456_789, u64::MAX] {
            h.record(v);
        }
        let snap = h.snapshot();
        let back = HistSnapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(back, snap);
        assert_eq!(back.percentile(0.5), snap.percentile(0.5));
    }

    #[test]
    fn empty_histogram_is_sane() {
        let snap = Histogram::new().snapshot();
        assert_eq!(snap.total, 0);
        assert_eq!(snap.percentile(0.99), 0);
        assert_eq!(snap.mean(), 0.0);
    }
}
