//! Unified observability: mergeable latency histograms, a metrics
//! registry, and always-on dual-clock span tracing.
//!
//! Three pillars (DESIGN.md § Observability):
//!
//! * [`MetricsRegistry`] — named lock-free counters/gauges plus
//!   log-bucketed [`Histogram`]s (power-of-two buckets with linear
//!   sub-buckets, p50/p95/p99/p99.9 queries). Registries snapshot to
//!   plain-data [`MetricsSnapshot`]s that merge across threads and — via
//!   the JSON round-trip — across processes, which is how the distributed
//!   leader aggregates worker tails.
//! * [`Tracer`] — low-overhead span recording into bounded per-thread
//!   ring buffers. Every span carries **two** durations: real monotonic
//!   time and the §3 model's virtual clock, exported as Chrome
//!   trace-event JSON with one process lane per clock so a Perfetto view
//!   lines the measured timeline up against the modeled one.
//! * A process-wide kill-switch: `PG_OBS=off` (or `0`) disables span and
//!   histogram recording for the pathological case; counters are single
//!   relaxed atomic adds and stay on. [`set_enabled`] overrides the
//!   environment at runtime (the overhead-guard bench flips it).
//!
//! The legacy counter structs (`GraphStats`, `CacheCounters`,
//! `StreamCounters`) remain as *views*: their hot fields are
//! [`Counter`] handles resolved from the owning graph's registry, so one
//! snapshot covers everything.

pub mod hist;
pub mod registry;
pub mod trace;

pub use hist::{HistSnapshot, Histogram};
pub use registry::{Counter, Histo, MetricsRegistry, MetricsSnapshot};
pub use trace::{tracer, Span, SpanGuard, Tracer};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

fn enabled_flag() -> &'static AtomicBool {
    static ENABLED: OnceLock<AtomicBool> = OnceLock::new();
    ENABLED.get_or_init(|| {
        let off = std::env::var("PG_OBS")
            .map(|v| matches!(v.as_str(), "off" | "0" | "false"))
            .unwrap_or(false);
        AtomicBool::new(!off)
    })
}

/// Is recording (spans + histograms) enabled? One relaxed load.
#[inline]
pub fn enabled() -> bool {
    enabled_flag().load(Ordering::Relaxed)
}

/// Runtime override of the `PG_OBS` kill-switch (used by the overhead
/// bench to compare tracing-on vs tracing-off in one process).
pub fn set_enabled(on: bool) {
    enabled_flag().store(on, Ordering::Relaxed);
}

/// Canonical metric names for the load path, so every layer (coordinator,
/// cache, stream, distributed, CLI) agrees on the registry keys.
pub mod names {
    /// Request latency per kind (histograms, nanoseconds).
    pub const REQ_SUCCESSORS: &str = "req.successors.ns";
    pub const REQ_CSX: &str = "req.csx.ns";
    pub const REQ_COO: &str = "req.coo.ns";
    pub const REQ_PARTITION: &str = "req.partition.ns";
    /// Buffer-claim wait (histogram, nanoseconds).
    pub const BUFFER_CLAIM_WAIT: &str = "buffer.claim_wait.ns";
    /// Per-block decode time (histograms, nanoseconds): real clock and
    /// the §3 model's virtual clock for the same blocks.
    pub const DECODE_BLOCK_REAL: &str = "decode.block.real_ns";
    pub const DECODE_BLOCK_VIRT: &str = "decode.block.virt_ns";
    /// Decoded-block cache (counters).
    pub const CACHE_HITS: &str = "cache.decoded.hits";
    pub const CACHE_MISSES: &str = "cache.decoded.misses";
    pub const CACHE_EVICTIONS: &str = "cache.decoded.evictions";
    /// Partition stream (counters).
    pub const STREAM_PRODUCED: &str = "stream.produced";
    pub const STREAM_CONSUMED: &str = "stream.consumed";
    pub const STREAM_PREFETCH_HITS: &str = "stream.prefetch_hits";
    pub const STREAM_CONSUMER_STALLS: &str = "stream.consumer_stalls";
    pub const STREAM_PRODUCER_STALLS: &str = "stream.producer_stalls";
    /// Distributed harness (counters, leader side).
    pub const DIST_RETILES: &str = "dist.retiles";
    pub const DIST_WORKERS_LOST: &str = "dist.workers_lost";
    /// Fault injection & self-healing reads (counters; all 0 on a clean
    /// run — the ci-summary baseline asserts exactly that).
    pub const FAULT_INJECTED: &str = "fault.injected";
    pub const READ_RETRIES: &str = "read.retries";
    pub const READ_DEGRADED: &str = "read.degraded";
    pub const BLOCK_QUARANTINED: &str = "block.quarantined";
    /// The fault counters in display order (CLI tail rows).
    pub const FAULT_COUNTERS: [&str; 4] =
        [FAULT_INJECTED, READ_RETRIES, READ_DEGRADED, BLOCK_QUARANTINED];
    /// The request-kind histograms in display order (CLI tail rows).
    pub const REQUEST_KINDS: [(&str, &str); 4] = [
        ("successors", REQ_SUCCESSORS),
        ("csx", REQ_CSX),
        ("coo", REQ_COO),
        ("partition", REQ_PARTITION),
    ];

    /// Per-tenant decoded-cache attribution (counters, resolved in the
    /// owning *graph's* registry, so the label is per-graph × per-tenant).
    pub fn cache_tenant_hits(tenant: &str) -> String {
        format!("{CACHE_HITS}.{tenant}")
    }
    pub fn cache_tenant_evictions(tenant: &str) -> String {
        format!("{CACHE_EVICTIONS}.{tenant}")
    }

    /// Serving front-end, per tenant (resolved in the *server's* registry).
    /// End-to-end request latency, submit → reply, nanoseconds (histogram);
    /// expired requests are billed here too — cancelled, never silent.
    pub fn serve_tenant_lat(tenant: &str) -> String {
        format!("serve.tenant.{tenant}.ns")
    }
    /// Requests accepted into the tenant's admission queue (counter).
    pub fn serve_tenant_admitted(tenant: &str) -> String {
        format!("serve.tenant.{tenant}.admitted")
    }
    /// Requests rejected with `PgError::Overloaded` (counter).
    pub fn serve_tenant_shed(tenant: &str) -> String {
        format!("serve.tenant.{tenant}.shed")
    }
    /// Requests completed successfully (counter).
    pub fn serve_tenant_completed(tenant: &str) -> String {
        format!("serve.tenant.{tenant}.completed")
    }
    /// Requests cancelled at their deadline (counter).
    pub fn serve_tenant_expired(tenant: &str) -> String {
        format!("serve.tenant.{tenant}.expired")
    }
    /// Requests that failed with a request error (counter).
    pub fn serve_tenant_failed(tenant: &str) -> String {
        format!("serve.tenant.{tenant}.failed")
    }
}

/// Serializes tests that toggle the process-wide kill-switch (they would
/// otherwise race in the parallel test runner).
#[cfg(test)]
pub(crate) fn test_toggle_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: OnceLock<std::sync::Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| std::sync::Mutex::new(()))
        .lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kill_switch_toggles() {
        let _guard = test_toggle_lock();
        let was = enabled();
        set_enabled(false);
        assert!(!enabled());
        set_enabled(true);
        assert!(enabled());
        set_enabled(was);
    }
}
