//! MSB-first bit streams, the substrate of the WebGraph-style codec.
//!
//! WebGraph's instantaneous codes are defined on an MSB-first bit order: the
//! first bit written is the most significant bit of the first byte. Both
//! sides work a word at a time (this matters: bit decoding is the sequential
//! phase of graph decompression and bounds the paper's decompression
//! bandwidth `d`):
//!
//! * [`BitReader`] keeps up to 128 buffered bits refilled by 8-byte
//!   big-endian loads, so `read_bits`/`read_unary` are a couple of shifts
//!   and a `leading_zeros` with no per-byte loop, and [`BitReader::peek_bits`]
//!   can expose the next word-window without consuming it — the hook the
//!   table-driven code decoders in [`codes`](super::codes) build on.
//! * [`BitWriter`] merges pending bits and the incoming value in one `u128`
//!   and flushes whole bytes in a single pass (the former byte-at-a-time
//!   loop carried dead `if`/`continue` branches and cost one shift+mask per
//!   byte).

/// Append-only MSB-first bit writer backed by a `Vec<u8>`.
#[derive(Debug, Default, Clone)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Pending bits not yet flushed to `buf`, left-aligned (MSB of `acc` is
    /// the oldest pending bit). Always fewer than 8 after any public call.
    acc: u64,
    /// Number of valid bits in `acc` (0..8).
    acc_bits: u32,
    /// Bytes handed out through [`Self::drain_full_bytes_into`]. Length
    /// queries stay *stream-absolute* across drains, so offset bookkeeping
    /// built on [`Self::bit_len`] is oblivious to streaming flushes.
    drained: u64,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bytes: usize) -> Self {
        Self { buf: Vec::with_capacity(bytes), acc: 0, acc_bits: 0, drained: 0 }
    }

    /// Total number of bits written so far (including drained bytes).
    #[inline]
    pub fn bit_len(&self) -> u64 {
        (self.drained + self.buf.len() as u64) * 8 + self.acc_bits as u64
    }

    /// Write the lowest `n` bits of `value`, MSB first. `n <= 64`.
    ///
    /// Single pass: the (< 8) pending bits and the incoming value are merged
    /// left-aligned in a `u128`, whole bytes are flushed, and the tail stays
    /// pending — no per-byte shift/mask loop.
    pub fn write_bits(&mut self, value: u64, n: u32) {
        debug_assert!(n <= 64);
        if n == 0 {
            return;
        }
        let value = if n == 64 { value } else { value & ((1u64 << n) - 1) };
        // acc_bits < 8 and n <= 64, so the value lands at shift >= 56.
        let mut merged =
            ((self.acc as u128) << 64) | ((value as u128) << (128 - self.acc_bits - n));
        let mut total = self.acc_bits + n;
        if total >= 64 {
            self.buf.extend_from_slice(&((merged >> 64) as u64).to_be_bytes());
            merged <<= 64;
            total -= 64;
        }
        while total >= 8 {
            self.buf.push((merged >> 120) as u8);
            merged <<= 8;
            total -= 8;
        }
        self.acc = (merged >> 64) as u64;
        self.acc_bits = total;
    }

    /// Write a single bit.
    #[inline]
    pub fn write_bit(&mut self, bit: bool) {
        self.write_bits(bit as u64, 1);
    }

    /// Write `n` zero bits followed by a one bit (unary code for n).
    pub fn write_unary(&mut self, n: u64) {
        let mut left = n;
        while left >= 64 {
            self.write_bits(0, 64);
            left -= 64;
        }
        // left <= 63, so left + 1 <= 64.
        self.write_bits(1, left as u32 + 1);
    }

    /// Pad to a byte boundary and return the underlying bytes (only the
    /// bytes *not yet* drained — the whole stream when the writer was never
    /// drained, the padded tail of a streaming writer otherwise).
    pub fn into_bytes(mut self) -> Vec<u8> {
        if self.acc_bits > 0 {
            // Pending bits are left-aligned; the low bits of the final byte
            // stay zero (the historical padding).
            self.buf.push((self.acc >> 56) as u8);
        }
        self.buf
    }

    /// Current length in bytes (including drained bytes and the partial
    /// byte).
    pub fn byte_len(&self) -> usize {
        self.drained as usize + self.buf.len() + (self.acc_bits > 0) as usize
    }

    /// Move every *complete* byte accumulated so far into `out`, keeping
    /// only the sub-byte pending tail. This is the streaming hook of the
    /// out-of-core compressor: the caller flushes drained bytes to disk and
    /// the writer's memory footprint stays bounded by the flush cadence
    /// while [`Self::bit_len`]/[`Self::byte_len`] keep reporting
    /// stream-absolute positions.
    pub fn drain_full_bytes_into(&mut self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.buf);
        self.drained += self.buf.len() as u64;
        self.buf.clear();
    }
}

/// MSB-first bit reader over a byte slice with a 128-bit refill buffer
/// (two 8-byte big-endian loads' worth, so any `read_bits(n <= 64)` is
/// served without an intra-read refill).
#[derive(Debug, Clone)]
pub struct BitReader<'a> {
    data: &'a [u8],
    /// Index of the next byte to refill from.
    next_byte: usize,
    /// Bits buffered, left-aligned (MSB of `buf` is the next bit). Bits
    /// below the valid region are always zero — [`Self::peek_bits`] relies
    /// on that for its zero-padded end-of-stream window.
    buf: u128,
    /// Number of valid bits in `buf`.
    valid: u32,
    /// Total bits consumed so far.
    consumed: u64,
}

/// Error produced when a read runs past the end of the stream.
/// (`Display`/`Error` implemented by hand: the offline build has no
/// `thiserror`.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BitstreamExhausted {
    pub wanted: u32,
    pub at: u64,
}

impl std::fmt::Display for BitstreamExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "bit stream exhausted (wanted {} bits at bit {})", self.wanted, self.at)
    }
}

impl std::error::Error for BitstreamExhausted {}

impl<'a> BitReader<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        Self { data, next_byte: 0, buf: 0, valid: 0, consumed: 0 }
    }

    /// Start reading at an absolute bit offset (random access — this is what
    /// makes selective loading possible: the offsets sidecar stores per-vertex
    /// bit offsets into the compressed stream).
    pub fn at_bit(data: &'a [u8], bit_offset: u64) -> Result<Self, BitstreamExhausted> {
        let byte = (bit_offset / 8) as usize;
        let bit = (bit_offset % 8) as u32;
        if byte > data.len() || (byte == data.len() && bit > 0) {
            return Err(BitstreamExhausted { wanted: 1, at: bit_offset });
        }
        let mut r = Self { data, next_byte: byte, buf: 0, valid: 0, consumed: bit_offset };
        if bit > 0 {
            // byte < data.len() here, so the refill buffers >= 8 bits.
            r.refill();
            // Drop the bits before the offset inside the first byte.
            r.buf <<= bit;
            r.valid -= bit;
        }
        Ok(r)
    }

    /// Total bits consumed so far (absolute position in the stream).
    #[inline]
    pub fn bit_pos(&self) -> u64 {
        self.consumed
    }

    /// Remaining bits available.
    #[inline]
    pub fn remaining_bits(&self) -> u64 {
        (self.data.len() - self.next_byte) as u64 * 8 + self.valid as u64
    }

    /// Top up the buffer: whole 8-byte big-endian words while they fit (and
    /// exist), then single bytes for the stream tail. Post-condition: either
    /// `valid > 64` or every remaining stream bit is buffered.
    #[inline]
    fn refill(&mut self) {
        while self.valid <= 64 {
            if self.next_byte + 8 <= self.data.len() {
                let word = u64::from_be_bytes(
                    self.data[self.next_byte..self.next_byte + 8].try_into().unwrap(),
                );
                // valid <= 64, so the word lands at shift 64 - valid >= 0.
                self.buf |= (word as u128) << (64 - self.valid);
                self.valid += 64;
                self.next_byte += 8;
            } else if self.next_byte < self.data.len() {
                self.buf |= (self.data[self.next_byte] as u128) << (120 - self.valid);
                self.valid += 8;
                self.next_byte += 1;
            } else {
                break;
            }
        }
    }

    /// Read `n` bits (MSB first), `n <= 64`.
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> Result<u64, BitstreamExhausted> {
        debug_assert!(n <= 64);
        if n == 0 {
            return Ok(0);
        }
        if self.valid < n {
            self.refill();
            if self.valid < n {
                return Err(BitstreamExhausted { wanted: n, at: self.consumed });
            }
        }
        let v = (self.buf >> (128 - n)) as u64;
        self.buf <<= n;
        self.valid -= n;
        self.consumed += n as u64;
        Ok(v)
    }

    /// Peek at the next `n` bits (MSB first, `1 <= n <= 64`) without
    /// consuming them. Bits past the end of the stream read as zero — the
    /// caller (the table-driven decoders) discovers genuine exhaustion when
    /// it tries to [`Self::skip_bits`] the matched codeword.
    #[inline]
    pub fn peek_bits(&mut self, n: u32) -> u64 {
        debug_assert!((1..=64).contains(&n));
        if self.valid < n {
            self.refill();
        }
        // Bits of `buf` below the valid region are zero, so a short window
        // near the stream end is implicitly zero-padded.
        (self.buf >> (128 - n)) as u64
    }

    /// Consume `n` bits previously examined with [`Self::peek_bits`]
    /// (`n <= 64`). Errors — consuming nothing — if fewer than `n` bits
    /// remain (the peek window was zero-padded).
    #[inline]
    pub fn skip_bits(&mut self, n: u32) -> Result<(), BitstreamExhausted> {
        debug_assert!(n <= 64);
        if n == 0 {
            return Ok(());
        }
        if self.valid < n {
            self.refill();
            if self.valid < n {
                return Err(BitstreamExhausted { wanted: n, at: self.consumed });
            }
        }
        self.buf <<= n;
        self.valid -= n;
        self.consumed += n as u64;
        Ok(())
    }

    /// Read one bit.
    #[inline]
    pub fn read_bit(&mut self) -> Result<bool, BitstreamExhausted> {
        Ok(self.read_bits(1)? == 1)
    }

    /// Read a unary-coded value: the number of 0 bits before the next 1.
    /// Branchless across refills: each iteration consumes either the whole
    /// zero-prefix of the buffer via one `leading_zeros`, or the terminating
    /// one — no per-bit loop.
    pub fn read_unary(&mut self) -> Result<u64, BitstreamExhausted> {
        let mut count = 0u64;
        loop {
            if self.valid == 0 {
                self.refill();
                if self.valid == 0 {
                    return Err(BitstreamExhausted { wanted: 1, at: self.consumed });
                }
            }
            let zeros = self.buf.leading_zeros();
            if zeros < self.valid {
                // The terminating 1 is inside the buffer. `used` can be 128
                // (a full buffer of 127 zeros + the one).
                let used = zeros + 1;
                self.buf = if used == 128 { 0 } else { self.buf << used };
                self.valid -= used;
                self.consumed += used as u64;
                return Ok(count + zeros as u64);
            }
            // All buffered bits are zero (leading_zeros saturates past the
            // valid region only when the region itself is all-zero).
            count += self.valid as u64;
            self.consumed += self.valid as u64;
            self.buf = 0;
            self.valid = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn roundtrip_fixed_width() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.write_bits(0xFFFF, 16);
        w.write_bits(0, 1);
        w.write_bits(u64::MAX, 64);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(3).unwrap(), 0b101);
        assert_eq!(r.read_bits(16).unwrap(), 0xFFFF);
        assert_eq!(r.read_bits(1).unwrap(), 0);
        assert_eq!(r.read_bits(64).unwrap(), u64::MAX);
    }

    #[test]
    fn unary_roundtrip() {
        let values = [0u64, 1, 2, 7, 8, 31, 32, 33, 63, 64, 65, 100, 127, 128, 129, 1000];
        let mut w = BitWriter::new();
        for &v in &values {
            w.write_unary(v);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &v in &values {
            assert_eq!(r.read_unary().unwrap(), v);
        }
    }

    #[test]
    fn random_mixed_roundtrip() {
        let mut rng = Xoshiro256::seed_from_u64(42);
        for _ in 0..50 {
            let n = 200;
            let mut ops = Vec::new();
            let mut w = BitWriter::new();
            for _ in 0..n {
                match rng.next_u64() % 3 {
                    0 => {
                        let width = 1 + (rng.next_u64() % 64) as u32;
                        let v = rng.next_u64() & (if width == 64 { u64::MAX } else { (1 << width) - 1 });
                        w.write_bits(v, width);
                        ops.push((0u8, v, width));
                    }
                    1 => {
                        let v = rng.next_u64() % 200;
                        w.write_unary(v);
                        ops.push((1, v, 0));
                    }
                    _ => {
                        let b = rng.next_u64() & 1;
                        w.write_bit(b == 1);
                        ops.push((2, b, 0));
                    }
                }
            }
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            for (kind, v, width) in ops {
                let got = match kind {
                    0 => r.read_bits(width).unwrap(),
                    1 => r.read_unary().unwrap(),
                    _ => r.read_bit().unwrap() as u64,
                };
                assert_eq!(got, v);
            }
        }
    }

    #[test]
    fn random_access_at_bit() {
        let mut w = BitWriter::new();
        for i in 0..100u64 {
            w.write_bits(i, 7);
        }
        let bytes = w.into_bytes();
        // Jump straight to the 50th value.
        let mut r = BitReader::at_bit(&bytes, 50 * 7).unwrap();
        assert_eq!(r.read_bits(7).unwrap(), 50);
        assert_eq!(r.read_bits(7).unwrap(), 51);
        assert_eq!(r.bit_pos(), 52 * 7);
    }

    #[test]
    fn exhaustion_is_an_error_not_a_panic() {
        let bytes = [0u8; 2];
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(16).unwrap(), 0);
        assert!(r.read_bits(1).is_err());
        // Unary over all-zero bits must also error out, not spin.
        let mut r2 = BitReader::new(&bytes);
        assert!(r2.read_unary().is_err());
    }

    #[test]
    fn at_bit_out_of_range() {
        let bytes = [0u8; 4];
        assert!(BitReader::at_bit(&bytes, 32).is_ok()); // exactly at end: ok, 0 bits left
        assert!(BitReader::at_bit(&bytes, 33).is_err());
    }

    #[test]
    fn bit_len_tracks_writes() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        assert_eq!(w.byte_len(), 0);
        w.write_bits(1, 1);
        assert_eq!(w.bit_len(), 1);
        assert_eq!(w.byte_len(), 1);
        w.write_bits(0, 7);
        assert_eq!(w.bit_len(), 8);
        assert_eq!(w.byte_len(), 1);
        w.write_bits(0b1010, 4);
        assert_eq!(w.bit_len(), 12);
        assert_eq!(w.byte_len(), 2);
    }

    /// Streaming drains must not perturb the emitted bytes or the
    /// stream-absolute length counters the offsets sidecar is built from.
    #[test]
    fn draining_preserves_stream_and_global_lengths() {
        let mut w = BitWriter::new();
        let mut file = Vec::new();
        let mut undrained = BitWriter::new();
        for i in 0..1000u64 {
            w.write_bits(i, 11);
            undrained.write_bits(i, 11);
            if i % 37 == 0 {
                w.drain_full_bytes_into(&mut file);
            }
            assert_eq!(w.bit_len(), undrained.bit_len());
            assert_eq!(w.byte_len(), undrained.byte_len());
        }
        file.extend_from_slice(&w.into_bytes());
        assert_eq!(file, undrained.into_bytes());
    }

    /// Satellite regression for the write_bits rewrite: every value of every
    /// small width, at every bit misalignment, written then read back — the
    /// write path has no untested (alignment × width) corner.
    #[test]
    fn exhaustive_small_width_roundtrip() {
        for misalign in 0u32..8 {
            for width in 1u32..=11 {
                let mut w = BitWriter::new();
                // Shift the stream start by `misalign` one-bits so the
                // value crosses byte boundaries at every phase.
                for _ in 0..misalign {
                    w.write_bit(true);
                }
                let count = 1u64 << width;
                for v in 0..count {
                    w.write_bits(v, width);
                }
                assert_eq!(w.bit_len(), misalign as u64 + count * width as u64);
                let bytes = w.into_bytes();
                let mut r = BitReader::new(&bytes);
                for _ in 0..misalign {
                    assert!(r.read_bit().unwrap());
                }
                for v in 0..count {
                    assert_eq!(
                        r.read_bits(width).unwrap(),
                        v,
                        "width {width} misalign {misalign}"
                    );
                }
            }
        }
    }

    /// Wide writes at every misalignment (the u128 merge path where
    /// `acc_bits + n` crosses 64).
    #[test]
    fn wide_write_roundtrip() {
        for misalign in 0u32..8 {
            for width in 57u32..=64 {
                let vals = [
                    0u64,
                    1,
                    u64::MAX >> (64 - width),
                    0xDEAD_BEEF_CAFE_F00D & (u64::MAX >> (64 - width)),
                ];
                let mut w = BitWriter::new();
                for _ in 0..misalign {
                    w.write_bit(false);
                }
                for &v in &vals {
                    w.write_bits(v, width);
                }
                let bytes = w.into_bytes();
                let mut r = BitReader::at_bit(&bytes, misalign as u64).unwrap();
                for &v in &vals {
                    assert_eq!(r.read_bits(width).unwrap(), v, "width {width} misalign {misalign}");
                }
            }
        }
    }

    #[test]
    fn peek_then_skip_matches_read() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let mut w = BitWriter::new();
        let vals: Vec<(u64, u32)> = (0..500)
            .map(|_| {
                let width = 1 + (rng.next_u64() % 64) as u32;
                let v = rng.next_u64() & (if width == 64 { u64::MAX } else { (1 << width) - 1 });
                (v, width)
            })
            .collect();
        for &(v, width) in &vals {
            w.write_bits(v, width);
        }
        let bytes = w.into_bytes();
        let mut peeked = BitReader::new(&bytes);
        let mut read = BitReader::new(&bytes);
        for &(v, width) in &vals {
            // A peek of up to 64 bits whose top `width` bits are the value.
            let window = peeked.peek_bits(64);
            assert_eq!(window >> (64 - width), v);
            peeked.skip_bits(width).unwrap();
            assert_eq!(read.read_bits(width).unwrap(), v);
            assert_eq!(peeked.bit_pos(), read.bit_pos());
        }
    }

    #[test]
    fn peek_past_end_is_zero_padded_and_skip_errors() {
        let mut w = BitWriter::new();
        w.write_bits(0b1011, 4);
        let bytes = w.into_bytes(); // one byte: 1011_0000
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(8).unwrap(), 0b1011_0000);
        // Stream exhausted: peek reads zeros, skip refuses.
        assert_eq!(r.peek_bits(11), 0);
        assert!(r.skip_bits(1).is_err());
        assert_eq!(r.bit_pos(), 8, "failed skip consumes nothing");
        // Mid-stream: the peek window extends past the end zero-padded.
        let mut r2 = BitReader::new(&bytes);
        assert_eq!(r2.read_bits(2).unwrap(), 0b10);
        // 6 real bits "110000" left-aligned in the 11-bit window.
        assert_eq!(r2.peek_bits(11), 0b110000 << 5);
        assert!(r2.skip_bits(6).is_ok());
        assert!(r2.skip_bits(1).is_err());
    }

    #[test]
    fn remaining_bits_is_exact() {
        let bytes = [0xAAu8; 20];
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.remaining_bits(), 160);
        r.read_bits(3).unwrap();
        assert_eq!(r.remaining_bits(), 157);
        r.read_unary().unwrap(); // "0" then "1": consumes 2 bits (0xAA = 10101010)
        assert_eq!(r.remaining_bits() + r.bit_pos(), 160);
        let mut r3 = BitReader::at_bit(&bytes, 155).unwrap();
        assert_eq!(r3.remaining_bits(), 5);
    }
}
