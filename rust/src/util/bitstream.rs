//! MSB-first bit streams, the substrate of the WebGraph-style codec.
//!
//! WebGraph's instantaneous codes are defined on an MSB-first bit order: the
//! first bit written is the most significant bit of the first byte. The
//! reader keeps a 64-bit refill buffer so that the per-symbol cost is a few
//! shifts (this matters: bit decoding is the sequential phase of graph
//! decompression and bounds the paper's decompression bandwidth `d`).

/// Append-only MSB-first bit writer backed by a `Vec<u8>`.
#[derive(Debug, Default, Clone)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bits already written into the final partial byte (0..8).
    partial_bits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bytes: usize) -> Self {
        Self { buf: Vec::with_capacity(bytes), partial_bits: 0 }
    }

    /// Total number of bits written so far.
    #[inline]
    pub fn bit_len(&self) -> u64 {
        if self.partial_bits == 0 {
            self.buf.len() as u64 * 8
        } else {
            (self.buf.len() as u64 - 1) * 8 + self.partial_bits as u64
        }
    }

    /// Write the lowest `n` bits of `value`, MSB first. `n <= 64`.
    pub fn write_bits(&mut self, value: u64, n: u32) {
        debug_assert!(n <= 64);
        if n == 0 {
            return;
        }
        let value = if n == 64 { value } else { value & ((1u64 << n) - 1) };
        let mut remaining = n;
        while remaining > 0 {
            if self.partial_bits == 0 {
                self.buf.push(0);
                self.partial_bits = 0;
            }
            let free = 8 - self.partial_bits;
            let take = free.min(remaining);
            let shift = remaining - take;
            let chunk = ((value >> shift) & ((1u64 << take) - 1)) as u8;
            let last = self.buf.last_mut().expect("buffer non-empty");
            *last |= chunk << (free - take);
            self.partial_bits = (self.partial_bits + take) % 8;
            if self.partial_bits == 0 && remaining > take {
                // Next iteration pushes a fresh byte.
            }
            remaining -= take;
            if self.partial_bits == 0 && remaining > 0 {
                continue;
            }
        }
    }

    /// Write a single bit.
    #[inline]
    pub fn write_bit(&mut self, bit: bool) {
        self.write_bits(bit as u64, 1);
    }

    /// Write `n` zero bits followed by a one bit (unary code for n).
    pub fn write_unary(&mut self, n: u64) {
        let mut left = n;
        while left >= 32 {
            self.write_bits(0, 32);
            left -= 32;
        }
        self.write_bits(1, left as u32 + 1);
    }

    /// Pad to a byte boundary and return the underlying bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Current length in bytes (including the partial byte).
    pub fn byte_len(&self) -> usize {
        self.buf.len()
    }
}

/// MSB-first bit reader over a byte slice with a 64-bit refill buffer.
#[derive(Debug, Clone)]
pub struct BitReader<'a> {
    data: &'a [u8],
    /// Index of the next byte to refill from.
    next_byte: usize,
    /// Bits buffered, left-aligned (MSB of `acc` is the next bit).
    acc: u64,
    /// Number of valid bits in `acc`.
    acc_bits: u32,
    /// Total bits consumed so far.
    consumed: u64,
}

/// Error produced when a read runs past the end of the stream.
/// (`Display`/`Error` implemented by hand: the offline build has no
/// `thiserror`.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BitstreamExhausted {
    pub wanted: u32,
    pub at: u64,
}

impl std::fmt::Display for BitstreamExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "bit stream exhausted (wanted {} bits at bit {})", self.wanted, self.at)
    }
}

impl std::error::Error for BitstreamExhausted {}

impl<'a> BitReader<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        Self { data, next_byte: 0, acc: 0, acc_bits: 0, consumed: 0 }
    }

    /// Start reading at an absolute bit offset (random access — this is what
    /// makes selective loading possible: the offsets sidecar stores per-vertex
    /// bit offsets into the compressed stream).
    pub fn at_bit(data: &'a [u8], bit_offset: u64) -> Result<Self, BitstreamExhausted> {
        let byte = (bit_offset / 8) as usize;
        let bit = (bit_offset % 8) as u32;
        if byte > data.len() || (byte == data.len() && bit > 0) {
            return Err(BitstreamExhausted { wanted: 1, at: bit_offset });
        }
        let mut r = Self { data, next_byte: byte, acc: 0, acc_bits: 0, consumed: bit_offset };
        if bit > 0 {
            r.refill();
            // Drop the bits before the offset inside the first byte.
            r.acc <<= bit;
            r.acc_bits -= bit;
        }
        Ok(r)
    }

    /// Total bits consumed so far (absolute position in the stream).
    #[inline]
    pub fn bit_pos(&self) -> u64 {
        self.consumed
    }

    /// Remaining bits available.
    #[inline]
    pub fn remaining_bits(&self) -> u64 {
        (self.data.len() - self.next_byte) as u64 * 8 + self.acc_bits as u64
    }

    #[inline]
    fn refill(&mut self) {
        // Fast path: top up from a single 8-byte load (the symbol-decode
        // hot loop refills every few symbols; byte-at-a-time refill was
        // ~25% of decode time — EXPERIMENTS §Perf).
        if self.acc_bits == 0 && self.next_byte + 8 <= self.data.len() {
            let word = u64::from_be_bytes(
                self.data[self.next_byte..self.next_byte + 8].try_into().unwrap(),
            );
            self.acc = word;
            self.acc_bits = 64;
            self.next_byte += 8;
            return;
        }
        while self.acc_bits <= 56 && self.next_byte < self.data.len() {
            self.acc |= (self.data[self.next_byte] as u64) << (56 - self.acc_bits);
            self.acc_bits += 8;
            self.next_byte += 1;
        }
    }

    /// Read `n` bits (MSB first), `n <= 64`.
    pub fn read_bits(&mut self, n: u32) -> Result<u64, BitstreamExhausted> {
        debug_assert!(n <= 64);
        if n == 0 {
            return Ok(0);
        }
        if n <= 57 {
            self.refill();
            if self.acc_bits < n {
                return Err(BitstreamExhausted { wanted: n, at: self.consumed });
            }
            let v = self.acc >> (64 - n);
            self.acc <<= n;
            self.acc_bits -= n;
            self.consumed += n as u64;
            Ok(v)
        } else {
            let hi = self.read_bits(32)?;
            let lo = self.read_bits(n - 32)?;
            Ok((hi << (n - 32)) | lo)
        }
    }

    /// Read one bit.
    #[inline]
    pub fn read_bit(&mut self) -> Result<bool, BitstreamExhausted> {
        Ok(self.read_bits(1)? == 1)
    }

    /// Read a unary-coded value: the number of 0 bits before the next 1.
    pub fn read_unary(&mut self) -> Result<u64, BitstreamExhausted> {
        let mut count = 0u64;
        loop {
            self.refill();
            if self.acc_bits == 0 {
                return Err(BitstreamExhausted { wanted: 1, at: self.consumed });
            }
            if self.acc == 0 {
                // All buffered bits are zero.
                count += self.acc_bits as u64;
                self.consumed += self.acc_bits as u64;
                self.acc_bits = 0;
                continue;
            }
            let zeros = self.acc.leading_zeros();
            if zeros < self.acc_bits {
                // The terminating 1 is inside the buffer.
                let used = zeros + 1;
                // `used` can be 64 (a full buffer of 63 zeros + the one).
                self.acc = if used == 64 { 0 } else { self.acc << used };
                self.acc_bits -= used;
                self.consumed += used as u64;
                return Ok(count + zeros as u64);
            } else {
                count += self.acc_bits as u64;
                self.consumed += self.acc_bits as u64;
                self.acc = 0;
                self.acc_bits = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn roundtrip_fixed_width() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.write_bits(0xFFFF, 16);
        w.write_bits(0, 1);
        w.write_bits(u64::MAX, 64);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(3).unwrap(), 0b101);
        assert_eq!(r.read_bits(16).unwrap(), 0xFFFF);
        assert_eq!(r.read_bits(1).unwrap(), 0);
        assert_eq!(r.read_bits(64).unwrap(), u64::MAX);
    }

    #[test]
    fn unary_roundtrip() {
        let values = [0u64, 1, 2, 7, 8, 31, 32, 33, 63, 64, 65, 100, 1000];
        let mut w = BitWriter::new();
        for &v in &values {
            w.write_unary(v);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &v in &values {
            assert_eq!(r.read_unary().unwrap(), v);
        }
    }

    #[test]
    fn random_mixed_roundtrip() {
        let mut rng = Xoshiro256::seed_from_u64(42);
        for _ in 0..50 {
            let n = 200;
            let mut ops = Vec::new();
            let mut w = BitWriter::new();
            for _ in 0..n {
                match rng.next_u64() % 3 {
                    0 => {
                        let width = 1 + (rng.next_u64() % 64) as u32;
                        let v = rng.next_u64() & (if width == 64 { u64::MAX } else { (1 << width) - 1 });
                        w.write_bits(v, width);
                        ops.push((0u8, v, width));
                    }
                    1 => {
                        let v = rng.next_u64() % 200;
                        w.write_unary(v);
                        ops.push((1, v, 0));
                    }
                    _ => {
                        let b = rng.next_u64() & 1;
                        w.write_bit(b == 1);
                        ops.push((2, b, 0));
                    }
                }
            }
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            for (kind, v, width) in ops {
                let got = match kind {
                    0 => r.read_bits(width).unwrap(),
                    1 => r.read_unary().unwrap(),
                    _ => r.read_bit().unwrap() as u64,
                };
                assert_eq!(got, v);
            }
        }
    }

    #[test]
    fn random_access_at_bit() {
        let mut w = BitWriter::new();
        for i in 0..100u64 {
            w.write_bits(i, 7);
        }
        let bytes = w.into_bytes();
        // Jump straight to the 50th value.
        let mut r = BitReader::at_bit(&bytes, 50 * 7).unwrap();
        assert_eq!(r.read_bits(7).unwrap(), 50);
        assert_eq!(r.read_bits(7).unwrap(), 51);
        assert_eq!(r.bit_pos(), 52 * 7);
    }

    #[test]
    fn exhaustion_is_an_error_not_a_panic() {
        let bytes = [0u8; 2];
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(16).unwrap(), 0);
        assert!(r.read_bits(1).is_err());
        // Unary over all-zero bits must also error out, not spin.
        let mut r2 = BitReader::new(&bytes);
        assert!(r2.read_unary().is_err());
    }

    #[test]
    fn at_bit_out_of_range() {
        let bytes = [0u8; 4];
        assert!(BitReader::at_bit(&bytes, 32).is_ok()); // exactly at end: ok, 0 bits left
        assert!(BitReader::at_bit(&bytes, 33).is_err());
    }

    #[test]
    fn bit_len_tracks_writes() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.write_bits(1, 1);
        assert_eq!(w.bit_len(), 1);
        w.write_bits(0, 7);
        assert_eq!(w.bit_len(), 8);
        w.write_bits(0b1010, 4);
        assert_eq!(w.bit_len(), 12);
    }
}
