//! Deterministic PRNGs (SplitMix64 seeding + Xoshiro256**), implemented
//! in-repo because the `rand` crate is not available in the offline build.
//! Used by the graph generators, property tests and the JT-CC random
//! union-find.

/// SplitMix64 — used to expand a 64-bit seed into Xoshiro state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the general-purpose generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased enough
    /// for simulation purposes; exact rejection for small bounds).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Split off an independent stream (jump-free: reseed via SplitMix of
    /// the current state — adequate for test-case generation).
    pub fn split(&mut self) -> Xoshiro256 {
        Xoshiro256::seed_from_u64(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Xoshiro256::seed_from_u64(123);
        let mut b = Xoshiro256::seed_from_u64(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} should be near 0.5");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::seed_from_u64(11);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle should move things");
    }
}
