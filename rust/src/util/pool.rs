//! A small fixed-size thread pool plus scoped parallel-for helpers.
//!
//! The coordinator's "Java side" (decoder workers) and the parallel format
//! readers run on these. The pool guarantees the paper's §4.1 requirement
//! that library threads are joined and stop consuming CPU after completion:
//! dropping the pool joins every worker.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool. Jobs are `FnOnce() + Send`.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    active: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let active = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                let active = Arc::clone(&active);
                std::thread::Builder::new()
                    .name(format!("pg-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            // Poison contract: a panicked sibling must not
                            // wedge the whole pool — recover the guard (the
                            // channel receiver stays structurally valid).
                            let guard = crate::coordinator::lock_recover(&rx);
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                active.fetch_add(1, Ordering::SeqCst);
                                // A panicking job must not take down the worker:
                                // the coordinator relies on the pool surviving
                                // user-callback panics (failure injection tests).
                                let _ = catch_unwind(AssertUnwindSafe(job));
                                active.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { tx: Some(tx), workers, active }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Jobs currently executing (approximate; for metrics/backpressure).
    pub fn active_jobs(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(job))
            .expect("pool workers gone");
    }

    /// Run `f(0..parts)` across this pool's workers *borrowing from the
    /// caller* — the scoped/borrowed-job submission the coordinator's
    /// intra-block decode fan-out needs (ROADMAP item: `decode_workers > 1`
    /// used to spawn that many scoped OS threads per block).
    ///
    /// Up to `max_helpers` helper jobs are enqueued on the pool; the caller
    /// always participates in the index loop itself, so the call makes
    /// progress even when every worker is busy (in particular when the
    /// caller *is* a pool worker — no deadlock by construction). Blocks
    /// until every index has finished, which is what makes handing
    /// non-`'static` borrows to pool workers sound: the borrow provably
    /// outlives every access.
    ///
    /// A panicking index is counted as finished (mirroring the pool's
    /// catch-unwind policy) so the caller never hangs; error reporting
    /// belongs in `f`'s own channel (e.g. a `Result` slot per index).
    pub fn scoped_for<F>(&self, parts: usize, max_helpers: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if parts == 0 {
            return;
        }
        let done = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let next = Arc::new(AtomicUsize::new(0));
        // Lifetime erasure: hand `&f` to 'static pool jobs as a raw fat
        // pointer. A helper only reconstructs the reference *after*
        // claiming a valid index, and an index can only be claimable while
        // this call is still blocked in `WaitAll` below (the caller loop
        // drains the counter before it can return) — so the borrow is
        // provably live at every dereference, even for helper jobs that
        // reach the front of a saturated queue long after we returned
        // (those see an exhausted counter and exit without touching `f`).
        let f_wide: &(dyn Fn(usize) + Sync) = &f;
        let f_ptr = ErasedFn(unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Sync),
                *const (dyn Fn(usize) + Sync + 'static),
            >(f_wide)
        });
        let helpers = max_helpers.min(self.threads()).min(parts.saturating_sub(1));
        for _ in 0..helpers {
            let f_ptr = f_ptr;
            let next = Arc::clone(&next);
            let done = Arc::clone(&done);
            self.execute(move || loop {
                let Some(i) = next_claim(&next, parts) else { break };
                // Count the index as done even if f(i) panics (drop
                // guard), so the submitter's wait always terminates.
                let _guard = DoneGuard(&done);
                // SAFETY: a valid index was claimed, so the submitting
                // scoped_for is still parked in WaitAll and `f` is alive.
                let fp = unsafe { &*f_ptr.0 };
                fp(i);
            });
        }
        // Declared before the caller loop so it drops *after* the loop's
        // guards: even if `f` panics on the caller thread, unwinding blocks
        // here until every helper is done touching the borrow.
        let _wait_all = WaitAll { done: &done, parts };
        // Declared after WaitAll so it drops *first* during unwind: if the
        // caller's `f` panics, the never-claimed tail of the index space
        // would otherwise keep WaitAll parked forever (helpers may be
        // absent or stuck behind the panicking caller's own pool slot).
        // The guard retires that tail: it poisons the claim counter and
        // counts every index that no one will ever claim, so WaitAll only
        // waits for indices actually claimed by someone.
        let mut abort = AbortGuard { next: &next, done: &done, parts, armed: true };
        // The caller participates too: progress is guaranteed even when
        // every pool worker is busy (e.g. when the caller IS one).
        loop {
            let Some(i) = next_claim(&next, parts) else { break };
            let _guard = DoneGuard(&done);
            f(i);
        }
        abort.armed = false; // clean exit: the counter is exhausted
    }
}

/// Unwind-path bookkeeping for [`ThreadPool::scoped_for`]: retires the
/// never-claimed tail of the index space so the final wait terminates
/// even when the caller's closure panicked mid-loop.
struct AbortGuard<'a> {
    next: &'a Arc<AtomicUsize>,
    done: &'a Arc<(Mutex<usize>, std::sync::Condvar)>,
    parts: usize,
    armed: bool,
}

impl Drop for AbortGuard<'_> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        // Poison the counter (helpers see an exhausted range; usize::MAX/2
        // leaves headroom for their subsequent fetch_adds). `claimed` is
        // exact: an index i was handed to some claimant iff i < claimed,
        // and that claimant's DoneGuard counts it — so counting the tail
        // here double-counts nothing.
        let claimed = self.next.swap(usize::MAX / 2, Ordering::SeqCst).min(self.parts);
        let missing = self.parts - claimed;
        if missing > 0 {
            let (mx, cv) = &**self.done;
            let mut g = crate::coordinator::lock_recover(mx);
            *g += missing;
            cv.notify_all();
        }
    }
}

/// Lifetime-erased closure pointer for [`ThreadPool::scoped_for`]. Only
/// dereferenced after claiming a valid index (see the SAFETY argument at
/// the use site).
struct ErasedFn(*const (dyn Fn(usize) + Sync + 'static));
impl Clone for ErasedFn {
    fn clone(&self) -> Self {
        *self
    }
}
impl Copy for ErasedFn {}
// SAFETY: the pointee is `Sync`, and liveness at every dereference is
// guaranteed by the scoped_for claim protocol.
unsafe impl Send for ErasedFn {}

/// Blocks (on drop — so also during unwind) until all indices of a
/// [`ThreadPool::scoped_for`] call are finished.
struct WaitAll<'a> {
    done: &'a Arc<(Mutex<usize>, std::sync::Condvar)>,
    parts: usize,
}

impl Drop for WaitAll<'_> {
    fn drop(&mut self) {
        let (mx, cv) = &**self.done;
        let mut g = crate::coordinator::lock_recover(mx);
        while *g < self.parts {
            g = cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }
}

/// Claim the next index below `parts`, or `None` when exhausted.
#[inline]
fn next_claim(next: &AtomicUsize, parts: usize) -> Option<usize> {
    let i = next.fetch_add(1, Ordering::Relaxed);
    if i < parts {
        Some(i)
    } else {
        None
    }
}

/// Counts one finished index on drop (also on unwind).
struct DoneGuard<'a>(&'a Arc<(Mutex<usize>, std::sync::Condvar)>);

impl Drop for DoneGuard<'_> {
    fn drop(&mut self) {
        let (mx, cv) = &**self.0;
        let mut g = crate::coordinator::lock_recover(mx);
        *g += 1;
        cv.notify_all();
    }
}

/// Ordered parallel map over `0..parts` executed on `pool` workers (plus
/// the caller), borrowing from the caller like [`ThreadPool::scoped_for`].
/// The pooled twin of [`parallel_map`].
pub fn parallel_map_on<T, F>(pool: &ThreadPool, parts: usize, max_helpers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut slots: Vec<Option<T>> = (0..parts).map(|_| None).collect();
    {
        let slots_ptr = SendPtr(slots.as_mut_ptr());
        pool.scoped_for(parts, max_helpers, |i| {
            let value = f(i);
            // SAFETY: each index is claimed exactly once, so writes are
            // disjoint; scoped_for joins before `slots` is read.
            unsafe {
                slots_ptr.write(i, Some(value));
            }
        });
    }
    slots.into_iter().map(|s| s.expect("every slot filled")).collect()
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(chunk_index)` for `parts` chunks on up to `threads` OS threads and
/// wait for all of them (scoped — may borrow from the caller).
pub fn parallel_for<F>(parts: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(parts.max(1));
    if threads <= 1 || parts <= 1 {
        for i in 0..parts {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= parts {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Map `f` over `0..parts` in parallel, collecting results in order.
pub fn parallel_map<T, F>(parts: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut slots: Vec<Option<T>> = (0..parts).map(|_| None).collect();
    {
        let slots_ptr = SendPtr(slots.as_mut_ptr());
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            let threads = threads.max(1).min(parts.max(1));
            for _ in 0..threads {
                let slots_ptr = slots_ptr;
                let (f, next) = (&f, &next);
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= parts {
                        break;
                    }
                    let value = f(i);
                    // SAFETY: each index i is claimed by exactly one thread
                    // via the atomic counter, so writes are disjoint.
                    unsafe {
                        slots_ptr.write(i, Some(value));
                    }
                });
            }
        });
    }
    slots.into_iter().map(|s| s.expect("every slot filled")).collect()
}

/// Raw-pointer wrapper asserting cross-thread use is safe. Methods (rather
/// than direct field access) matter: edition-2021 closures capture disjoint
/// fields, which would capture the bare `*mut T` and lose the `Send` impl.
struct SendPtr<T>(*mut T);
// Manual Copy/Clone: derive would demand `T: Copy`, which is not needed for
// copying a raw pointer.
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// # Safety
    /// Caller must guarantee `idx` is in bounds and not concurrently written.
    unsafe fn write(&self, idx: usize, value: T) {
        *self.0.add(idx) = value;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs_and_joins() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop joins: all jobs must have run afterwards.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_survives_panicking_job() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            pool.execute(|| panic!("injected failure"));
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        }
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_for_covers_all_chunks() {
        let hits: Vec<AtomicU64> = (0..37).map(|_| AtomicU64::new(0)).collect();
        parallel_for(37, 4, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::SeqCst), 1);
        }
    }

    #[test]
    fn parallel_map_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_map_zero_parts() {
        let out: Vec<u32> = parallel_map(0, 4, |_| unreachable!());
        assert!(out.is_empty());
    }

    #[test]
    fn scoped_for_borrows_and_covers_all() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU64> = (0..123).map(|_| AtomicU64::new(0)).collect();
        // `hits` is a caller borrow handed to pool workers — the ROADMAP
        // borrowed-job semantics.
        pool.scoped_for(123, 3, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn scoped_for_progresses_when_pool_is_saturated() {
        // Every worker is parked on a gate; the caller's own loop must
        // still finish all indices (no-deadlock-by-construction).
        let pool = ThreadPool::new(2);
        let gate = Arc::new(AtomicU64::new(0));
        for _ in 0..2 {
            let gate = Arc::clone(&gate);
            pool.execute(move || {
                while gate.load(Ordering::SeqCst) == 0 {
                    std::thread::yield_now();
                }
            });
        }
        let sum = AtomicU64::new(0);
        pool.scoped_for(50, 2, |i| {
            sum.fetch_add(i as u64, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), (0..50u64).sum());
        gate.store(1, Ordering::SeqCst); // release parked workers
    }

    #[test]
    fn scoped_for_nested_from_a_pool_worker() {
        // A pool job fanning out over the same pool (the coordinator's
        // per-block decode pattern) must not deadlock.
        let pool = Arc::new(ThreadPool::new(2));
        let (tx, rx) = std::sync::mpsc::channel();
        let p2 = Arc::clone(&pool);
        pool.execute(move || {
            let acc = AtomicU64::new(0);
            p2.scoped_for(40, 4, |i| {
                acc.fetch_add(i as u64 + 1, Ordering::SeqCst);
            });
            tx.send(acc.load(Ordering::SeqCst)).unwrap();
        });
        let got = rx.recv_timeout(std::time::Duration::from_secs(30)).expect("nested fan-out");
        assert_eq!(got, (1..=40u64).sum());
    }

    #[test]
    fn scoped_for_caller_panic_unwinds_instead_of_hanging() {
        // No helpers: the caller is the only claimant. A panic mid-loop
        // must propagate (AbortGuard retires the unclaimed tail) rather
        // than leave the unwinding thread parked in WaitAll forever.
        let pool = ThreadPool::new(2);
        let ran = AtomicU64::new(0);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.scoped_for(10, 0, |i| {
                ran.fetch_add(1, Ordering::SeqCst);
                if i == 0 {
                    panic!("injected caller panic");
                }
            });
        }));
        assert!(result.is_err(), "panic must propagate");
        assert_eq!(ran.load(Ordering::SeqCst), 1, "loop stopped at the panic");
        // The pool is still usable afterwards.
        let sum = AtomicU64::new(0);
        pool.scoped_for(5, 2, |i| {
            sum.fetch_add(i as u64, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_map_on_matches_parallel_map() {
        let pool = ThreadPool::new(3);
        let out = parallel_map_on(&pool, 77, 2, |i| i * i);
        assert_eq!(out, (0..77).map(|i| i * i).collect::<Vec<_>>());
        let empty: Vec<u32> = parallel_map_on(&pool, 0, 2, |_| unreachable!());
        assert!(empty.is_empty());
    }
}
