//! A small fixed-size thread pool plus scoped parallel-for helpers.
//!
//! The coordinator's "Java side" (decoder workers) and the parallel format
//! readers run on these. The pool guarantees the paper's §4.1 requirement
//! that library threads are joined and stop consuming CPU after completion:
//! dropping the pool joins every worker.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool. Jobs are `FnOnce() + Send`.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    active: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let active = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                let active = Arc::clone(&active);
                std::thread::Builder::new()
                    .name(format!("pg-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().expect("pool queue poisoned");
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                active.fetch_add(1, Ordering::SeqCst);
                                // A panicking job must not take down the worker:
                                // the coordinator relies on the pool surviving
                                // user-callback panics (failure injection tests).
                                let _ = catch_unwind(AssertUnwindSafe(job));
                                active.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { tx: Some(tx), workers, active }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Jobs currently executing (approximate; for metrics/backpressure).
    pub fn active_jobs(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(job))
            .expect("pool workers gone");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(chunk_index)` for `parts` chunks on up to `threads` OS threads and
/// wait for all of them (scoped — may borrow from the caller).
pub fn parallel_for<F>(parts: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(parts.max(1));
    if threads <= 1 || parts <= 1 {
        for i in 0..parts {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= parts {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Map `f` over `0..parts` in parallel, collecting results in order.
pub fn parallel_map<T, F>(parts: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut slots: Vec<Option<T>> = (0..parts).map(|_| None).collect();
    {
        let slots_ptr = SendPtr(slots.as_mut_ptr());
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            let threads = threads.max(1).min(parts.max(1));
            for _ in 0..threads {
                let slots_ptr = slots_ptr;
                let (f, next) = (&f, &next);
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= parts {
                        break;
                    }
                    let value = f(i);
                    // SAFETY: each index i is claimed by exactly one thread
                    // via the atomic counter, so writes are disjoint.
                    unsafe {
                        slots_ptr.write(i, Some(value));
                    }
                });
            }
        });
    }
    slots.into_iter().map(|s| s.expect("every slot filled")).collect()
}

/// Raw-pointer wrapper asserting cross-thread use is safe. Methods (rather
/// than direct field access) matter: edition-2021 closures capture disjoint
/// fields, which would capture the bare `*mut T` and lose the `Send` impl.
struct SendPtr<T>(*mut T);
// Manual Copy/Clone: derive would demand `T: Copy`, which is not needed for
// copying a raw pointer.
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// # Safety
    /// Caller must guarantee `idx` is in bounds and not concurrently written.
    unsafe fn write(&self, idx: usize, value: T) {
        *self.0.add(idx) = value;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs_and_joins() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop joins: all jobs must have run afterwards.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_survives_panicking_job() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            pool.execute(|| panic!("injected failure"));
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        }
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_for_covers_all_chunks() {
        let hits: Vec<AtomicU64> = (0..37).map(|_| AtomicU64::new(0)).collect();
        parallel_for(37, 4, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::SeqCst), 1);
        }
    }

    #[test]
    fn parallel_map_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_map_zero_parts() {
        let out: Vec<u32> = parallel_map(0, 4, |_| unreachable!());
        assert!(out.is_empty());
    }
}
