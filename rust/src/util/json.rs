//! A minimal JSON value, writer and parser (serde is unavailable offline).
//! The writer emits machine-readable bench results next to the human
//! tables; the parser ([`Json::parse`]) completes the round-trip so
//! serialized artifacts — notably
//! [`PartitionPlan::to_json`](crate::partition::PartitionPlan::to_json) —
//! can be shipped across processes and read back.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. `Object` uses a BTreeMap so output is deterministic.
///
/// Numbers come in two variants: `Num` (f64, the general case) and `Uint`
/// (exact u64, so large counters survive a round-trip without the 2^53
/// precision cliff). Equality treats them as one numeric domain —
/// `Num(42.0) == Uint(42)` — so callers never care which one a parse
/// produced.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Uint(u64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl PartialEq for Json {
    fn eq(&self, other: &Json) -> bool {
        match (self, other) {
            (Json::Null, Json::Null) => true,
            (Json::Bool(a), Json::Bool(b)) => a == b,
            (Json::Num(a), Json::Num(b)) => a == b,
            (Json::Uint(a), Json::Uint(b)) => a == b,
            (Json::Num(a), Json::Uint(b)) | (Json::Uint(b), Json::Num(a)) => *a == *b as f64,
            (Json::Str(a), Json::Str(b)) => a == b,
            (Json::Arr(a), Json::Arr(b)) => a == b,
            (Json::Obj(a), Json::Obj(b)) => a == b,
            _ => false,
        }
    }
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        if let Json::Obj(map) = self {
            map.insert(key.to_string(), value.into());
        } else {
            panic!("set() on non-object Json");
        }
        self
    }

    pub fn push(&mut self, value: impl Into<Json>) -> &mut Self {
        if let Json::Arr(items) = self {
            items.push(value.into());
        } else {
            panic!("push() on non-array Json");
        }
        self
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    /// Parse a JSON document (full JSON: nested containers, string escapes
    /// incl. `\uXXXX` surrogate pairs, signed/fractional/exponent numbers).
    /// Errors carry a byte position. Trailing non-whitespace is rejected.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { text, bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field access (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            Json::Uint(n) => Some(*n as f64),
            _ => None,
        }
    }

    /// Exact unsigned access: `Uint` verbatim, or a `Num` that is a whole
    /// non-negative value within u64 range.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Uint(n) => Some(*n),
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 && *n < u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Uint(n) => {
                let _ = write!(out, "{}", n);
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !items.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    Json::Str(k.clone()).write(out, indent + 1, pretty);
                    out.push_str(": ");
                    v.write(out, indent + 1, pretty);
                }
                if !map.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

/// Recursive-descent parser over the raw bytes (ASCII structure; string
/// contents decoded as UTF-8/escapes). Depth-limited so adversarial
/// nesting cannot overflow the stack.
struct Parser<'a> {
    /// The document as text (for one-scalar decodes in strings) …
    text: &'a str,
    /// … and the same bytes (for all ASCII structure scanning).
    bytes: &'a [u8],
    pos: usize,
}

const MAX_DEPTH: usize = 128;

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} at byte {}", self.pos));
        }
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.pos += 1;
                let mut items = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    self.skip_ws();
                    items.push(self.value(depth + 1)?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut map = BTreeMap::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                loop {
                    self.skip_ws();
                    let key = self.string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    self.skip_ws();
                    let val = self.value(depth + 1)?;
                    map.insert(key, val);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(map));
                        }
                        _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
                    }
                }
            }
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(format!("unexpected input at byte {}", self.pos)),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let digits = |p: &mut Self| {
            let before = p.pos;
            while matches!(p.peek(), Some(b'0'..=b'9')) {
                p.pos += 1;
            }
            p.pos > before
        };
        if !digits(self) {
            return Err(format!("expected digits at byte {}", self.pos));
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !digits(self) {
                return Err(format!("expected fraction digits at byte {}", self.pos));
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !digits(self) {
                return Err(format!("expected exponent digits at byte {}", self.pos));
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| format!("non-UTF-8 number at byte {start}"))?;
        // Unsigned integer literals parse exactly (no f64 round-trip), so
        // 64-bit counters survive the wire; anything signed, fractional,
        // exponential or past u64::MAX falls back to f64.
        if let Ok(u) = text.parse::<u64>() {
            return Ok(Json::Uint(u));
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number {text:?} at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(b) = self.peek() else {
                return Err(format!("unterminated string at byte {}", self.pos));
            };
            match b {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let Some(esc) = self.peek() else {
                        return Err(format!("dangling escape at byte {}", self.pos));
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // High surrogate: require \uXXXX low half.
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(format!(
                                            "bad low surrogate at byte {}",
                                            self.pos
                                        ));
                                    }
                                    let cp = 0x10000
                                        + ((hi - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(cp)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(hi)
                            };
                            match c {
                                Some(c) => out.push(c),
                                None => {
                                    return Err(format!(
                                        "bad \\u escape at byte {}",
                                        self.pos
                                    ))
                                }
                            }
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos - 1)),
                    }
                }
                _ if b < 0x20 => {
                    return Err(format!("raw control char at byte {}", self.pos));
                }
                _ if b < 0x80 => {
                    out.push(b as char);
                    self.pos += 1;
                }
                _ => {
                    // One multi-byte UTF-8 scalar. `pos` only ever advances
                    // by whole scalars, so it sits on a char boundary and
                    // the O(1) str slice below cannot fail; decoding one
                    // `char` (not re-validating the whole tail) keeps
                    // string parsing linear.
                    let c = self
                        .text
                        .get(self.pos..)
                        .and_then(|rest| rest.chars().next())
                        .ok_or_else(|| format!("bad UTF-8 at byte {}", self.pos))?;
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.pos + 4 > self.bytes.len() {
            return Err(format!("truncated \\u escape at byte {}", self.pos));
        }
        // Exactly 4 hex digits (from_str_radix alone would also accept a
        // leading '+').
        let digits = &self.bytes[self.pos..self.pos + 4];
        if !digits.iter().all(u8::is_ascii_hexdigit) {
            return Err(format!("bad \\u escape at byte {}", self.pos));
        }
        let s = std::str::from_utf8(digits).expect("hex digits are ASCII");
        let v = u32::from_str_radix(s, 16)
            .map_err(|_| format!("bad \\u escape at byte {}", self.pos))?;
        self.pos += 4;
        Ok(v)
    }
}

/// Frames larger than this are rejected by [`read_frame`] — a corrupt or
/// misaligned length prefix must fail the connection, not allocate 4 GiB.
pub const MAX_FRAME_BYTES: u32 = 64 << 20;

/// Write one length-prefixed JSON frame: a 4-byte big-endian byte length
/// followed by the document's UTF-8 bytes. This is the distributed wire
/// format (leader↔worker plan shipping and tile results).
pub fn write_frame<W: std::io::Write>(w: &mut W, v: &Json) -> std::io::Result<()> {
    let body = v.to_string_pretty();
    let len = u32::try_from(body.len()).map_err(|_| {
        std::io::Error::new(std::io::ErrorKind::InvalidInput, "frame over 4 GiB")
    })?;
    if len > MAX_FRAME_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!("frame of {len} bytes exceeds MAX_FRAME_BYTES"),
        ));
    }
    w.write_all(&len.to_be_bytes())?;
    w.write_all(body.as_bytes())?;
    w.flush()
}

/// Read one frame written by [`write_frame`].
///
/// * `Ok(None)` — clean EOF at a frame boundary (peer closed between
///   frames).
/// * `Err(UnexpectedEof)` — the peer died mid-frame (torn length prefix or
///   payload); distinct from a clean close so the leader can treat it as a
///   worker loss.
/// * `Err(InvalidData)` — oversized length prefix or unparseable payload.
///
/// Timeout-typed errors (`WouldBlock`/`TimedOut` from a socket read
/// deadline) pass through untouched for the caller to classify.
pub fn read_frame<R: std::io::Read>(r: &mut R) -> std::io::Result<Option<Json>> {
    let mut len_buf = [0u8; 4];
    // Hand-rolled first read: EOF before any length byte is a clean close,
    // EOF after one is a torn frame — read_exact cannot tell those apart.
    let mut got = 0;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "torn frame: EOF inside the length prefix",
                ))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    let len = u32::from_be_bytes(len_buf);
    if len > MAX_FRAME_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds MAX_FRAME_BYTES (corrupt or misaligned stream)"),
        ));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                format!("torn frame: EOF inside a {len}-byte payload"),
            )
        } else {
            e
        }
    })?;
    let text = std::str::from_utf8(&body).map_err(|_| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, "frame payload is not UTF-8")
    })?;
    Json::parse(text)
        .map(Some)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Uint(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Uint(v as u64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Self {
        Json::Uint(u64::from(v))
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_scalars() {
        assert_eq!(Json::Null.to_string_pretty(), "null");
        assert_eq!(Json::from(true).to_string_pretty(), "true");
        assert_eq!(Json::from(42u64).to_string_pretty(), "42");
        assert_eq!(Json::from(1.5).to_string_pretty(), "1.5");
        assert_eq!(Json::from("a\"b\n").to_string_pretty(), "\"a\\\"b\\n\"");
    }

    #[test]
    fn writes_nested() {
        let mut o = Json::obj();
        o.set("name", "fig5").set("rows", vec![1u64, 2, 3]);
        let s = o.to_string_pretty();
        assert!(s.contains("\"name\": \"fig5\""));
        assert!(s.contains('['));
        // Deterministic key order (BTreeMap).
        let name_pos = s.find("name").unwrap();
        let rows_pos = s.find("rows").unwrap();
        assert!(name_pos < rows_pos);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::Arr(vec![]).to_string_pretty(), "[]");
        assert_eq!(Json::obj().to_string_pretty(), "{}");
    }

    #[test]
    fn parse_round_trips_writer_output() {
        let mut o = Json::obj();
        o.set("name", "plan \"x\"\n")
            .set("count", 42u64)
            .set("ratio", 1.625)
            .set("neg", -3.5)
            .set("flag", true)
            .set("nothing", Json::Null)
            .set("rows", vec![1u64, 2, 3]);
        let mut nested = Json::Arr(vec![]);
        nested.push(Json::obj().set("v", vec![0u64, 10]).clone());
        o.set("parts", nested);
        let text = o.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, o);
        // And the parse→write→parse fixpoint holds.
        assert_eq!(Json::parse(&back.to_string_pretty()).unwrap(), back);
    }

    #[test]
    fn parse_scalars_and_numbers() {
        assert_eq!(Json::parse(" null ").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("0").unwrap(), Json::Num(0.0));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("2.5e3").unwrap(), Json::Num(2500.0));
        assert_eq!(Json::parse("1E-2").unwrap(), Json::Num(0.01));
        assert_eq!(
            Json::parse("9007199254740991").unwrap(),
            Json::Num(9007199254740991.0)
        );
    }

    #[test]
    fn large_u64_round_trips_exactly() {
        for v in [u64::MAX, u64::MAX - 1, (1u64 << 53) + 1, 9_223_372_036_854_775_807] {
            let text = Json::from(v).to_string_pretty();
            assert_eq!(text, v.to_string());
            let back = Json::parse(&text).unwrap();
            assert_eq!(back.as_u64(), Some(v), "{v} must survive the round-trip");
        }
        // Num↔Uint numeric cross-equality (one numeric domain).
        assert_eq!(Json::Num(42.0), Json::Uint(42));
        assert_ne!(Json::Num(42.5), Json::Uint(42));
        // Past-2^53 values differ from their f64 rounding only in Uint form.
        assert_eq!(Json::parse("18446744073709551615").unwrap(), Json::Uint(u64::MAX));
        // Overflowing u64 falls back to f64.
        assert!(matches!(Json::parse("18446744073709551616").unwrap(), Json::Num(_)));
        // Signed stays f64.
        assert!(matches!(Json::parse("-17").unwrap(), Json::Num(_)));
    }

    #[test]
    fn parse_string_escapes() {
        assert_eq!(
            Json::parse(r#""a\"b\\c\nd\te\u0041\u00e9""#).unwrap(),
            Json::Str("a\"b\\c\nd\teAé".to_string())
        );
        // Surrogate pair (U+1F600).
        assert_eq!(
            Json::parse(r#""\ud83d\ude00""#).unwrap(),
            Json::Str("😀".to_string())
        );
        // Raw multi-byte UTF-8 passes through.
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".to_string()));
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "", "{", "[1,", "[1 2]", "{\"a\"}", "{\"a\":}", "01x", "nul", "\"\\q\"",
            "\"unterminated", "[1]extra", "\"\\ud800\"", "--1", "1.", "+1",
            "\"\\u+041\"", "\"\\u00g1\"",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} must not parse");
        }
        // Deep nesting is bounded, not a stack overflow.
        let deep = "[".repeat(100_000) + &"]".repeat(100_000);
        assert!(Json::parse(&deep).is_err());
    }

    #[test]
    fn frames_round_trip_back_to_back() {
        let mut a = Json::obj();
        a.set("type", "assign").set("tile", 7u64);
        let b = Json::from(vec![1u64, 2, 3]);
        let mut wire = Vec::new();
        write_frame(&mut wire, &a).unwrap();
        write_frame(&mut wire, &b).unwrap();
        let mut r = wire.as_slice();
        assert_eq!(read_frame(&mut r).unwrap(), Some(a));
        assert_eq!(read_frame(&mut r).unwrap(), Some(b));
        // Clean EOF at the frame boundary.
        assert_eq!(read_frame(&mut r).unwrap(), None);
    }

    #[test]
    fn torn_frames_are_unexpected_eof_not_clean_close() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &Json::from("hello")).unwrap();
        // Torn inside the payload.
        let mut r = &wire[..wire.len() - 2];
        let e = read_frame(&mut r).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof);
        // Torn inside the length prefix.
        let mut r = &wire[..2];
        let e = read_frame(&mut r).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn hostile_frames_are_invalid_data() {
        // A length prefix past the cap must be rejected before allocating.
        let mut r: &[u8] = &u32::MAX.to_be_bytes();
        let e = read_frame(&mut r).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::InvalidData);
        // A well-framed but unparseable payload.
        let mut wire = 3u32.to_be_bytes().to_vec();
        wire.extend_from_slice(b"nul");
        let mut r = wire.as_slice();
        let e = read_frame(&mut r).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::InvalidData);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"k": [1, 2], "s": "x"}"#).unwrap();
        assert_eq!(v.get("k").and_then(Json::as_arr).map(<[Json]>::len), Some(2));
        assert_eq!(v.get("s").and_then(Json::as_str), Some("x"));
        assert_eq!(v.get("k").unwrap().as_arr().unwrap()[0].as_f64(), Some(1.0));
        assert!(v.get("missing").is_none());
        assert!(Json::Null.get("k").is_none());
    }
}
