//! A minimal JSON value + writer (serde is unavailable offline). Used by the
//! bench harness to emit machine-readable results next to the human tables.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. `Object` uses a BTreeMap so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        if let Json::Obj(map) = self {
            map.insert(key.to_string(), value.into());
        } else {
            panic!("set() on non-object Json");
        }
        self
    }

    pub fn push(&mut self, value: impl Into<Json>) -> &mut Self {
        if let Json::Arr(items) = self {
            items.push(value.into());
        } else {
            panic!("push() on non-array Json");
        }
        self
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !items.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    Json::Str(k.clone()).write(out, indent + 1, pretty);
                    out.push_str(": ");
                    v.write(out, indent + 1, pretty);
                }
                if !map.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_scalars() {
        assert_eq!(Json::Null.to_string_pretty(), "null");
        assert_eq!(Json::from(true).to_string_pretty(), "true");
        assert_eq!(Json::from(42u64).to_string_pretty(), "42");
        assert_eq!(Json::from(1.5).to_string_pretty(), "1.5");
        assert_eq!(Json::from("a\"b\n").to_string_pretty(), "\"a\\\"b\\n\"");
    }

    #[test]
    fn writes_nested() {
        let mut o = Json::obj();
        o.set("name", "fig5").set("rows", vec![1u64, 2, 3]);
        let s = o.to_string_pretty();
        assert!(s.contains("\"name\": \"fig5\""));
        assert!(s.contains('['));
        // Deterministic key order (BTreeMap).
        let name_pos = s.find("name").unwrap();
        let rows_pos = s.find("rows").unwrap();
        assert!(name_pos < rows_pos);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::Arr(vec![]).to_string_pretty(), "[]");
        assert_eq!(Json::obj().to_string_pretty(), "{}");
    }
}
