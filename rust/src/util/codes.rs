//! Instantaneous (prefix-free) integer codes used by the WebGraph-style
//! compressed format: unary, Elias γ, Elias δ, ζ_k (Boldi–Vigna), Golomb,
//! and minimal-binary codes, plus the signed↔unsigned zig-zag used for
//! residual gaps that can be negative.
//!
//! All codes operate on the MSB-first [`BitWriter`]/[`BitReader`] streams.

use super::bitstream::{BitReader, BitWriter, BitstreamExhausted};

/// Number of bits needed to represent `x` (0 -> 0).
#[inline]
pub fn bit_width(x: u64) -> u32 {
    64 - x.leading_zeros()
}

/// Zig-zag: map a signed integer to unsigned so small magnitudes stay small.
/// WebGraph's `Fast.int2nat`: v >= 0 -> 2v, v < 0 -> 2|v| - 1.
#[inline]
pub fn int_to_nat(v: i64) -> u64 {
    if v >= 0 {
        (v as u64) << 1
    } else {
        (((-v) as u64) << 1) - 1
    }
}

/// Inverse of [`int_to_nat`].
#[inline]
pub fn nat_to_int(n: u64) -> i64 {
    if n & 1 == 0 {
        (n >> 1) as i64
    } else {
        -(((n + 1) >> 1) as i64)
    }
}

/// Elias γ code of `x` (codes any `x >= 0` via the x+1 shift).
pub fn write_gamma(w: &mut BitWriter, x: u64) {
    let x1 = x + 1;
    let width = bit_width(x1); // >= 1
    w.write_unary(width as u64 - 1);
    if width > 1 {
        w.write_bits(x1, width - 1); // implicit leading 1 dropped
    }
}

pub fn read_gamma(r: &mut BitReader<'_>) -> Result<u64, BitstreamExhausted> {
    let unary = r.read_unary()?;
    if unary >= 64 {
        // Corrupt stream: a genuine γ code never has a 64+-bit mantissa.
        return Err(BitstreamExhausted { wanted: unary.min(u32::MAX as u64) as u32, at: r.bit_pos() });
    }
    let width = unary as u32 + 1;
    if width == 1 {
        return Ok(0);
    }
    let rest = r.read_bits(width - 1)?;
    Ok(((1u64 << (width - 1)) | rest) - 1)
}

/// Elias δ code: like γ but the width field is itself γ-coded; shorter than
/// γ for large values, used for very long gaps.
pub fn write_delta(w: &mut BitWriter, x: u64) {
    let x1 = x + 1;
    let width = bit_width(x1);
    write_gamma(w, width as u64 - 1);
    if width > 1 {
        w.write_bits(x1, width - 1);
    }
}

pub fn read_delta(r: &mut BitReader<'_>) -> Result<u64, BitstreamExhausted> {
    let w = read_gamma(r)?;
    if w >= 64 {
        return Err(BitstreamExhausted { wanted: w.min(u32::MAX as u64) as u32, at: r.bit_pos() });
    }
    let width = w as u32 + 1;
    if width == 1 {
        return Ok(0);
    }
    let rest = r.read_bits(width - 1)?;
    Ok(((1u64 << (width - 1)) | rest) - 1)
}

/// ζ_k code (Boldi–Vigna 2004), tuned for power-law distributed gaps; k = 3
/// is WebGraph's default for web graph residuals.
pub fn write_zeta(w: &mut BitWriter, x: u64, k: u32) {
    debug_assert!(k >= 1);
    let x1 = x + 1;
    let msb = bit_width(x1) - 1; // floor(log2(x+1))
    let h = msb / k;
    w.write_unary(h as u64);
    let left = 1u64 << (h * k);
    let range_bits = h * k + k; // codes [left, left*2^k)
    // Minimal binary code of x1 - left in a range of size left*(2^k - 1)... —
    // following the reference implementation: if x1 - left < left*(2^k-1)
    // truncated form may save one bit; we use the simple full-width form of
    // the reference decoder's "unshifted" variant for clarity & symmetry.
    let offset = x1 - left;
    let max = (left << k) - left; // number of values in this shell
    write_minimal_binary(w, offset, max, range_bits);
}

pub fn read_zeta(r: &mut BitReader<'_>, k: u32) -> Result<u64, BitstreamExhausted> {
    let h = r.read_unary()? as u32;
    if h.saturating_mul(k).saturating_add(k) > 63 {
        // Corrupt stream (or value ≥ 2^63, outside the supported range).
        return Err(BitstreamExhausted { wanted: h.saturating_mul(k), at: r.bit_pos() });
    }
    let left = 1u64 << (h * k);
    let max = (left << k) - left;
    let offset = read_minimal_binary(r, max, h * k + k)?;
    Ok(left + offset - 1)
}

/// Minimal binary (truncated) code of `x` in `[0, max)` where values below
/// the threshold use `bits-1` bits and the rest use `bits` bits;
/// `bits = ceil(log2(max))` is passed by the caller (both sides derive it
/// from shared state, keeping the code instantaneous).
fn write_minimal_binary(w: &mut BitWriter, x: u64, max: u64, bits_hint: u32) {
    debug_assert!(x < max || (max == 0 && x == 0));
    if max <= 1 {
        return; // zero bits needed
    }
    let bits = bits_needed(max, bits_hint);
    let threshold = (1u64 << bits) - max; // values < threshold: bits-1 bits
    if x < threshold {
        w.write_bits(x, bits - 1);
    } else {
        w.write_bits(x + threshold, bits);
    }
}

fn read_minimal_binary(
    r: &mut BitReader<'_>,
    max: u64,
    bits_hint: u32,
) -> Result<u64, BitstreamExhausted> {
    if max <= 1 {
        return Ok(0);
    }
    let bits = bits_needed(max, bits_hint);
    let threshold = (1u64 << bits) - max;
    let hi = r.read_bits(bits - 1)?;
    if hi < threshold {
        Ok(hi)
    } else {
        let low = r.read_bits(1)?;
        Ok(((hi << 1) | low) - threshold)
    }
}

#[inline]
fn bits_needed(max: u64, hint: u32) -> u32 {
    // ceil(log2(max)); hint is an upper bound used to avoid recomputation
    // in the zeta hot path when it is already exact.
    let b = bit_width(max - 1).max(1);
    debug_assert!(b <= hint.max(b));
    b
}

/// Golomb code with parameter `m` (quotient unary, remainder minimal-binary).
/// Good when gaps are geometrically distributed (road-like graphs).
pub fn write_golomb(w: &mut BitWriter, x: u64, m: u64) {
    debug_assert!(m >= 1);
    let q = x / m;
    let rem = x % m;
    w.write_unary(q);
    write_minimal_binary(w, rem, m, bit_width(m));
}

pub fn read_golomb(r: &mut BitReader<'_>, m: u64) -> Result<u64, BitstreamExhausted> {
    let q = r.read_unary()?;
    let rem = read_minimal_binary(r, m, bit_width(m))?;
    Ok(q * m + rem)
}

/// The code families the WebGraph-style encoder can choose per component.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Code {
    Unary,
    Gamma,
    Delta,
    Zeta(u32),
    Golomb(u64),
}

impl Code {
    pub fn write(self, w: &mut BitWriter, x: u64) {
        match self {
            Code::Unary => w.write_unary(x),
            Code::Gamma => write_gamma(w, x),
            Code::Delta => write_delta(w, x),
            Code::Zeta(k) => write_zeta(w, x, k),
            Code::Golomb(m) => write_golomb(w, x, m),
        }
    }

    pub fn read(self, r: &mut BitReader<'_>) -> Result<u64, BitstreamExhausted> {
        match self {
            Code::Unary => r.read_unary(),
            Code::Gamma => read_gamma(r),
            Code::Delta => read_delta(r),
            Code::Zeta(k) => read_zeta(r, k),
            Code::Golomb(m) => read_golomb(r, m),
        }
    }

    /// Length in bits of coding `x` (used by the size model / Table 1).
    pub fn len_bits(self, x: u64) -> u64 {
        match self {
            Code::Unary => x + 1,
            Code::Gamma => {
                let w = bit_width(x + 1);
                (2 * w - 1) as u64
            }
            Code::Delta => {
                let w = bit_width(x + 1);
                let ww = bit_width(w as u64);
                (2 * ww - 1 + w - 1) as u64
            }
            Code::Zeta(k) => {
                let mut bw = BitWriter::new();
                write_zeta(&mut bw, x, k);
                bw.bit_len()
            }
            Code::Golomb(m) => {
                let mut bw = BitWriter::new();
                write_golomb(&mut bw, x, m);
                bw.bit_len()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn roundtrip_one(code: Code, values: &[u64]) {
        let mut w = BitWriter::new();
        for &v in values {
            code.write(&mut w, v);
        }
        let expected_bits: u64 = values.iter().map(|&v| code.len_bits(v)).sum();
        assert_eq!(w.bit_len(), expected_bits, "len_bits must match actual encoding ({code:?})");
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &v in values {
            assert_eq!(code.read(&mut r).unwrap(), v, "value {v} under {code:?}");
        }
    }

    #[test]
    fn gamma_delta_zeta_golomb_roundtrip_small() {
        let values: Vec<u64> = (0..300).collect();
        for code in [
            Code::Gamma,
            Code::Delta,
            Code::Zeta(1),
            Code::Zeta(2),
            Code::Zeta(3),
            Code::Zeta(5),
            Code::Golomb(1),
            Code::Golomb(3),
            Code::Golomb(8),
            Code::Golomb(100),
        ] {
            roundtrip_one(code, &values);
        }
    }

    #[test]
    fn roundtrip_large_values() {
        let values = [u64::MAX >> 2, 1 << 40, (1 << 33) + 7, u32::MAX as u64, 1 << 62];
        for code in [Code::Gamma, Code::Delta, Code::Zeta(3), Code::Golomb(1 << 50)] {
            // NB: Golomb with a small m on huge values is pathological (the
            // unary quotient would be astronomically long), so the large
            // test uses a large m.
            roundtrip_one(code, &values);
        }
    }

    #[test]
    fn roundtrip_random() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        for _ in 0..20 {
            let values: Vec<u64> = (0..500)
                .map(|_| {
                    let shift = rng.next_u64() % 48;
                    rng.next_u64() >> (16 + shift % 48)
                })
                .collect();
            for code in [Code::Gamma, Code::Delta, Code::Zeta(3)] {
                roundtrip_one(code, &values);
            }
            // Golomb's unary quotient is linear in x/m: keep x/m bounded.
            let golomb_values: Vec<u64> =
                values.iter().map(|&v| v % (64 * 4096)).collect();
            roundtrip_one(Code::Golomb(64), &golomb_values);
        }
    }

    #[test]
    fn zig_zag() {
        for v in [-1000i64, -3, -2, -1, 0, 1, 2, 3, 1000, i64::MIN / 2, i64::MAX / 2] {
            assert_eq!(nat_to_int(int_to_nat(v)), v);
        }
        assert_eq!(int_to_nat(0), 0);
        assert_eq!(int_to_nat(-1), 1);
        assert_eq!(int_to_nat(1), 2);
        assert_eq!(int_to_nat(-2), 3);
    }

    #[test]
    fn gamma_known_lengths() {
        // gamma(0) = "1" (1 bit), gamma(1)="010", gamma(2)="011" (3 bits)...
        assert_eq!(Code::Gamma.len_bits(0), 1);
        assert_eq!(Code::Gamma.len_bits(1), 3);
        assert_eq!(Code::Gamma.len_bits(2), 3);
        assert_eq!(Code::Gamma.len_bits(3), 5);
        assert_eq!(Code::Gamma.len_bits(6), 5);
        assert_eq!(Code::Gamma.len_bits(7), 7);
    }

    #[test]
    fn zeta_beats_gamma_on_powerlaw_tail() {
        // The point of zeta_k: shorter codes for the heavy tail.
        let big = 100_000u64;
        assert!(Code::Zeta(3).len_bits(big) <= Code::Gamma.len_bits(big));
    }

    #[test]
    fn minimal_binary_edge_cases() {
        // max == 1 encodes in zero bits.
        let mut w = BitWriter::new();
        write_minimal_binary(&mut w, 0, 1, 1);
        assert_eq!(w.bit_len(), 0);
        // Exhaustive check for small ranges.
        for max in 2u64..20 {
            let mut w = BitWriter::new();
            for x in 0..max {
                write_minimal_binary(&mut w, x, max, bit_width(max));
            }
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            for x in 0..max {
                assert_eq!(read_minimal_binary(&mut r, max, bit_width(max)).unwrap(), x);
            }
        }
    }

    #[test]
    fn truncated_decoder_rejects_garbage_gracefully() {
        // Decoding arbitrary bytes must never panic — only Ok or Err.
        let mut rng = Xoshiro256::seed_from_u64(99);
        for _ in 0..200 {
            let bytes: Vec<u8> = (0..16).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
            let mut r = BitReader::new(&bytes);
            for code in [Code::Gamma, Code::Delta, Code::Zeta(3), Code::Golomb(7)] {
                let _ = code.read(&mut r); // must not panic
            }
        }
    }
}
