//! Instantaneous (prefix-free) integer codes used by the WebGraph-style
//! compressed format: unary, Elias γ, Elias δ, ζ_k (Boldi–Vigna), Golomb,
//! and minimal-binary codes, plus the signed↔unsigned zig-zag used for
//! residual gaps that can be negative.
//!
//! All codes operate on the MSB-first [`BitWriter`]/[`BitReader`] streams.
//!
//! Two decode paths exist per family:
//!
//! * the **slow path** (`read_gamma`/`read_delta`/`read_zeta`/…) decodes
//!   field by field and is the retained reference implementation (the
//!   differential fuzz suite pins the fast path against it);
//! * the **table path** ([`CodeReader`]) peeks [`PEEK_BITS`] bits once and
//!   resolves any short codeword with a single lookup — the common case for
//!   WebGraph streams, where degrees, copy blocks, interval fields and
//!   residual gaps are overwhelmingly small. Long codewords fall back to
//!   the slow path. Process-global tables exist for γ, δ, ζ_k (k = 1..=4)
//!   and unary, built once ([`decode_table`]); Golomb — parameterized by an
//!   unbounded `m`, so unsuitable for a global registry — gets a
//!   *per-reader* table built at [`CodeReader::new`] whenever any of its
//!   codewords fits the peek window (small `m`, the geometric-gap regime
//!   Golomb residual streams actually use).

use std::sync::OnceLock;

use super::bitstream::{BitReader, BitWriter, BitstreamExhausted};

/// Number of bits needed to represent `x` (0 -> 0).
#[inline]
pub fn bit_width(x: u64) -> u32 {
    64 - x.leading_zeros()
}

/// Zig-zag: map a signed integer to unsigned so small magnitudes stay small.
/// WebGraph's `Fast.int2nat`: v >= 0 -> 2v, v < 0 -> 2|v| - 1.
#[inline]
pub fn int_to_nat(v: i64) -> u64 {
    if v >= 0 {
        (v as u64) << 1
    } else {
        (((-v) as u64) << 1) - 1
    }
}

/// Inverse of [`int_to_nat`].
#[inline]
pub fn nat_to_int(n: u64) -> i64 {
    if n & 1 == 0 {
        (n >> 1) as i64
    } else {
        -(((n + 1) >> 1) as i64)
    }
}

/// Elias γ code of `x` (codes any `x >= 0` via the x+1 shift).
pub fn write_gamma(w: &mut BitWriter, x: u64) {
    let x1 = x + 1;
    let width = bit_width(x1); // >= 1
    w.write_unary(width as u64 - 1);
    if width > 1 {
        w.write_bits(x1, width - 1); // implicit leading 1 dropped
    }
}

pub fn read_gamma(r: &mut BitReader<'_>) -> Result<u64, BitstreamExhausted> {
    let unary = r.read_unary()?;
    if unary >= 64 {
        // Corrupt stream: a genuine γ code never has a 64+-bit mantissa.
        return Err(BitstreamExhausted { wanted: unary.min(u32::MAX as u64) as u32, at: r.bit_pos() });
    }
    let width = unary as u32 + 1;
    if width == 1 {
        return Ok(0);
    }
    let rest = r.read_bits(width - 1)?;
    Ok(((1u64 << (width - 1)) | rest) - 1)
}

/// Elias δ code: like γ but the width field is itself γ-coded; shorter than
/// γ for large values, used for very long gaps.
pub fn write_delta(w: &mut BitWriter, x: u64) {
    let x1 = x + 1;
    let width = bit_width(x1);
    write_gamma(w, width as u64 - 1);
    if width > 1 {
        w.write_bits(x1, width - 1);
    }
}

pub fn read_delta(r: &mut BitReader<'_>) -> Result<u64, BitstreamExhausted> {
    let w = read_gamma(r)?;
    if w >= 64 {
        return Err(BitstreamExhausted { wanted: w.min(u32::MAX as u64) as u32, at: r.bit_pos() });
    }
    let width = w as u32 + 1;
    if width == 1 {
        return Ok(0);
    }
    let rest = r.read_bits(width - 1)?;
    Ok(((1u64 << (width - 1)) | rest) - 1)
}

/// ζ_k code (Boldi–Vigna 2004), tuned for power-law distributed gaps; k = 3
/// is WebGraph's default for web graph residuals.
pub fn write_zeta(w: &mut BitWriter, x: u64, k: u32) {
    debug_assert!(k >= 1);
    let x1 = x + 1;
    let msb = bit_width(x1) - 1; // floor(log2(x+1))
    let h = msb / k;
    w.write_unary(h as u64);
    let left = 1u64 << (h * k);
    let range_bits = h * k + k; // codes [left, left*2^k)
    // Minimal binary code of x1 - left in a range of size left*(2^k - 1)... —
    // following the reference implementation: if x1 - left < left*(2^k-1)
    // truncated form may save one bit; we use the simple full-width form of
    // the reference decoder's "unshifted" variant for clarity & symmetry.
    let offset = x1 - left;
    let max = (left << k) - left; // number of values in this shell
    write_minimal_binary(w, offset, max, range_bits);
}

pub fn read_zeta(r: &mut BitReader<'_>, k: u32) -> Result<u64, BitstreamExhausted> {
    let h = r.read_unary()? as u32;
    if h.saturating_mul(k).saturating_add(k) > 63 {
        // Corrupt stream (or value ≥ 2^63, outside the supported range).
        return Err(BitstreamExhausted { wanted: h.saturating_mul(k), at: r.bit_pos() });
    }
    let left = 1u64 << (h * k);
    let max = (left << k) - left;
    let offset = read_minimal_binary(r, max, h * k + k)?;
    Ok(left + offset - 1)
}

/// Minimal binary (truncated) code of `x` in `[0, max)` where values below
/// the threshold use `bits-1` bits and the rest use `bits` bits;
/// `bits = ceil(log2(max))` is passed by the caller (both sides derive it
/// from shared state, keeping the code instantaneous).
fn write_minimal_binary(w: &mut BitWriter, x: u64, max: u64, bits_hint: u32) {
    debug_assert!(x < max || (max == 0 && x == 0));
    if max <= 1 {
        return; // zero bits needed
    }
    let bits = bits_needed(max, bits_hint);
    let threshold = (1u64 << bits) - max; // values < threshold: bits-1 bits
    if x < threshold {
        w.write_bits(x, bits - 1);
    } else {
        w.write_bits(x + threshold, bits);
    }
}

fn read_minimal_binary(
    r: &mut BitReader<'_>,
    max: u64,
    bits_hint: u32,
) -> Result<u64, BitstreamExhausted> {
    if max <= 1 {
        return Ok(0);
    }
    let bits = bits_needed(max, bits_hint);
    let threshold = (1u64 << bits) - max;
    let hi = r.read_bits(bits - 1)?;
    if hi < threshold {
        Ok(hi)
    } else {
        let low = r.read_bits(1)?;
        Ok(((hi << 1) | low) - threshold)
    }
}

#[inline]
fn bits_needed(max: u64, hint: u32) -> u32 {
    // ceil(log2(max)); hint is an upper bound used to avoid recomputation
    // in the zeta hot path when it is already exact.
    let b = bit_width(max - 1).max(1);
    debug_assert!(b <= hint.max(b));
    b
}

/// Golomb code with parameter `m` (quotient unary, remainder minimal-binary).
/// Good when gaps are geometrically distributed (road-like graphs).
pub fn write_golomb(w: &mut BitWriter, x: u64, m: u64) {
    debug_assert!(m >= 1);
    let q = x / m;
    let rem = x % m;
    w.write_unary(q);
    write_minimal_binary(w, rem, m, bit_width(m));
}

pub fn read_golomb(r: &mut BitReader<'_>, m: u64) -> Result<u64, BitstreamExhausted> {
    let q = r.read_unary()?;
    let rem = read_minimal_binary(r, m, bit_width(m))?;
    Ok(q * m + rem)
}

/// The code families the WebGraph-style encoder can choose per component.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Code {
    Unary,
    Gamma,
    Delta,
    Zeta(u32),
    Golomb(u64),
}

impl Code {
    pub fn write(self, w: &mut BitWriter, x: u64) {
        match self {
            Code::Unary => w.write_unary(x),
            Code::Gamma => write_gamma(w, x),
            Code::Delta => write_delta(w, x),
            Code::Zeta(k) => write_zeta(w, x, k),
            Code::Golomb(m) => write_golomb(w, x, m),
        }
    }

    pub fn read(self, r: &mut BitReader<'_>) -> Result<u64, BitstreamExhausted> {
        match self {
            Code::Unary => r.read_unary(),
            Code::Gamma => read_gamma(r),
            Code::Delta => read_delta(r),
            Code::Zeta(k) => read_zeta(r, k),
            Code::Golomb(m) => read_golomb(r, m),
        }
    }

    /// Length in bits of coding `x` (used by the size model / Table 1).
    pub fn len_bits(self, x: u64) -> u64 {
        match self {
            Code::Unary => x + 1,
            Code::Gamma => {
                let w = bit_width(x + 1);
                (2 * w - 1) as u64
            }
            Code::Delta => {
                let w = bit_width(x + 1);
                let ww = bit_width(w as u64);
                (2 * ww - 1 + w - 1) as u64
            }
            Code::Zeta(k) => {
                let mut bw = BitWriter::new();
                write_zeta(&mut bw, x, k);
                bw.bit_len()
            }
            Code::Golomb(m) => {
                let mut bw = BitWriter::new();
                write_golomb(&mut bw, x, m);
                bw.bit_len()
            }
        }
    }
}

/// Width of the table-driven decode peek. 11 bits covers γ(x) for x < 63,
/// δ(x) for x < 127 and the first few ζ shells — in practice well over 90%
/// of the symbols of a WebGraph stream — while keeping each table at
/// 2^11 entries (16 KiB).
pub const PEEK_BITS: u32 = 11;
const TABLE_LEN: usize = 1 << PEEK_BITS;

/// Precomputed decode table for one code family: for every [`PEEK_BITS`]-bit
/// window, the decoded value and codeword length when the window starts with
/// a short (≤ `PEEK_BITS`-bit) codeword; length 0 marks a long codeword
/// (slow-path fallback).
pub struct DecodeTable {
    entries: Vec<(u32, u8)>,
}

impl DecodeTable {
    /// Build by enumerating coded values until the first codeword longer
    /// than the peek window. Codeword lengths are non-decreasing in the
    /// value for every tabled family — γ, δ, ζ_k trivially; unary is
    /// `x + 1`; Golomb's quotient grows by whole shells and its
    /// minimal-binary remainder is non-decreasing within a shell, with the
    /// last codeword of shell `q` exactly as long as the first of shell
    /// `q + 1` — so nothing short is skipped.
    fn build(code: Code) -> Self {
        let mut entries = vec![(0u32, 0u8); TABLE_LEN];
        for x in 0..(2 * TABLE_LEN as u64) {
            let mut w = BitWriter::new();
            code.write(&mut w, x);
            let len = w.bit_len();
            if len > PEEK_BITS as u64 {
                break;
            }
            let len = len as u32;
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            let cw = r.read_bits(len).expect("codeword bits");
            // Every window starting with this codeword maps to (x, len).
            let lo = (cw << (PEEK_BITS - len)) as usize;
            for slot in &mut entries[lo..lo + (1 << (PEEK_BITS - len))] {
                debug_assert_eq!(slot.1, 0, "prefix-free codewords cannot collide");
                *slot = (x as u32, len as u8);
            }
        }
        Self { entries }
    }

    /// Resolve a [`PEEK_BITS`]-bit window: `(value, codeword_len)`, len 0 =
    /// long codeword.
    #[inline]
    pub fn lookup(&self, window: u64) -> (u32, u8) {
        self.entries[window as usize]
    }

    /// Does any codeword of the family fit the peek window? A table with no
    /// short codewords (e.g. Golomb with a large `m`) is pure overhead —
    /// every lookup would miss — so [`CodeReader::new`] discards it.
    fn has_short_codewords(&self) -> bool {
        self.entries.iter().any(|&(_, len)| len != 0)
    }
}

static GAMMA_TABLE: OnceLock<DecodeTable> = OnceLock::new();
static DELTA_TABLE: OnceLock<DecodeTable> = OnceLock::new();
static UNARY_TABLE: OnceLock<DecodeTable> = OnceLock::new();
static ZETA_TABLES: [OnceLock<DecodeTable>; 4] =
    [OnceLock::new(), OnceLock::new(), OnceLock::new(), OnceLock::new()];

/// The shared decode table for `code`, built on first use; `None` for
/// families without a process-global one (Golomb is parameterized by an
/// unbounded `m` — [`CodeReader::new`] builds a per-reader table for small
/// `m` instead; ζ_k beyond 4 is unused by the WebGraph encoder).
pub fn decode_table(code: Code) -> Option<&'static DecodeTable> {
    match code {
        Code::Gamma => Some(GAMMA_TABLE.get_or_init(|| DecodeTable::build(code))),
        Code::Delta => Some(DELTA_TABLE.get_or_init(|| DecodeTable::build(code))),
        Code::Unary => Some(UNARY_TABLE.get_or_init(|| DecodeTable::build(code))),
        Code::Zeta(k @ 1..=4) => {
            Some(ZETA_TABLES[(k - 1) as usize].get_or_init(|| DecodeTable::build(code)))
        }
        _ => None,
    }
}

/// The decode table a [`CodeReader`] drives: shared (process-global
/// families) or owned (per-reader Golomb tables, whose `m` cannot index a
/// static registry).
enum TableHandle {
    None,
    Shared(&'static DecodeTable),
    Owned(Box<DecodeTable>),
}

impl TableHandle {
    #[inline]
    fn get(&self) -> Option<&DecodeTable> {
        match self {
            TableHandle::None => None,
            TableHandle::Shared(t) => Some(t),
            TableHandle::Owned(t) => Some(t),
        }
    }
}

/// Table-accelerated decoder for one code family, selected once per stream:
/// the per-symbol cost of a short codeword is one peek, one table load and
/// one skip. Carries hit/miss counters (the CI table-hit-rate canary).
pub struct CodeReader {
    code: Code,
    table: TableHandle,
    /// Symbols decoded through the table fast path.
    pub table_hits: u64,
    /// Symbols that fell back to the slow path (long codeword or a family
    /// without a table).
    pub table_misses: u64,
}

impl CodeReader {
    pub fn new(code: Code) -> Self {
        let table = match decode_table(code) {
            Some(t) => TableHandle::Shared(t),
            // Golomb residual streams: build a per-reader table when the
            // family has short codewords at all (the shortest is
            // `1 + ceil(log2 m) - 1` bits for remainder 0, so any
            // `m ≤ 2^PEEK_BITS` is worth probing). The build enumerates at
            // most `2 · 2^PEEK_BITS` codewords once per reader — and a
            // reader decodes a whole stream, so the cost amortizes exactly
            // like the per-stream table *selection* already does.
            None => match code {
                Code::Golomb(m) if m >= 1 && m <= (1 << PEEK_BITS) => {
                    let t = DecodeTable::build(code);
                    if t.has_short_codewords() {
                        TableHandle::Owned(Box::new(t))
                    } else {
                        TableHandle::None
                    }
                }
                _ => TableHandle::None,
            },
        };
        Self { code, table, table_hits: 0, table_misses: 0 }
    }

    /// The code family this reader decodes.
    #[inline]
    pub fn code(&self) -> Code {
        self.code
    }

    /// Decode one symbol: table fast path, slow-path fallback. Exactly
    /// equivalent to [`Code::read`] — same values, same bit positions, same
    /// error-ness (the differential fuzz suite asserts this).
    #[inline]
    pub fn read(&mut self, r: &mut BitReader<'_>) -> Result<u64, BitstreamExhausted> {
        if let Some(t) = self.table.get() {
            let (v, len) = t.lookup(r.peek_bits(PEEK_BITS));
            if len != 0 {
                // A zero-padded window can only match an entry whose length
                // exceeds the remaining bits — skip_bits turns that into
                // the same exhaustion error the slow path would produce.
                r.skip_bits(len as u32)?;
                self.table_hits += 1;
                return Ok(v as u64);
            }
        }
        self.table_misses += 1;
        self.code.read(r)
    }

    /// Batched run decode (the residual-run shape): `count` symbols appended
    /// to `out`. Amortizes the table dispatch across the run — one peek and
    /// one lookup per symbol, no per-symbol match on the code family.
    pub fn read_run(
        &mut self,
        r: &mut BitReader<'_>,
        count: usize,
        out: &mut Vec<u64>,
    ) -> Result<(), BitstreamExhausted> {
        out.reserve(count);
        if let Some(t) = self.table.get() {
            for _ in 0..count {
                let (v, len) = t.lookup(r.peek_bits(PEEK_BITS));
                if len != 0 {
                    r.skip_bits(len as u32)?;
                    self.table_hits += 1;
                    out.push(v as u64);
                } else {
                    self.table_misses += 1;
                    out.push(self.code.read(r)?);
                }
            }
        } else {
            self.table_misses += count as u64;
            for _ in 0..count {
                out.push(self.code.read(r)?);
            }
        }
        Ok(())
    }

    /// Fraction of symbols served by the table (1.0 when nothing decoded).
    pub fn hit_rate(&self) -> f64 {
        hit_rate(self.table_hits, self.table_misses)
    }
}

/// Shared hit/miss → rate convention (1.0 when nothing was decoded) — one
/// definition for the reader, the decode scratch, and the calibration
/// report, so the CI canary and the bench numbers cannot silently diverge.
pub fn hit_rate(hits: u64, misses: u64) -> f64 {
    let total = hits + misses;
    if total == 0 {
        1.0
    } else {
        hits as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn roundtrip_one(code: Code, values: &[u64]) {
        let mut w = BitWriter::new();
        for &v in values {
            code.write(&mut w, v);
        }
        let expected_bits: u64 = values.iter().map(|&v| code.len_bits(v)).sum();
        assert_eq!(w.bit_len(), expected_bits, "len_bits must match actual encoding ({code:?})");
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &v in values {
            assert_eq!(code.read(&mut r).unwrap(), v, "value {v} under {code:?}");
        }
    }

    #[test]
    fn gamma_delta_zeta_golomb_roundtrip_small() {
        let values: Vec<u64> = (0..300).collect();
        for code in [
            Code::Gamma,
            Code::Delta,
            Code::Zeta(1),
            Code::Zeta(2),
            Code::Zeta(3),
            Code::Zeta(5),
            Code::Golomb(1),
            Code::Golomb(3),
            Code::Golomb(8),
            Code::Golomb(100),
        ] {
            roundtrip_one(code, &values);
        }
    }

    #[test]
    fn roundtrip_large_values() {
        let values = [u64::MAX >> 2, 1 << 40, (1 << 33) + 7, u32::MAX as u64, 1 << 62];
        for code in [Code::Gamma, Code::Delta, Code::Zeta(3), Code::Golomb(1 << 50)] {
            // NB: Golomb with a small m on huge values is pathological (the
            // unary quotient would be astronomically long), so the large
            // test uses a large m.
            roundtrip_one(code, &values);
        }
    }

    #[test]
    fn roundtrip_random() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        for _ in 0..20 {
            let values: Vec<u64> = (0..500)
                .map(|_| {
                    let shift = rng.next_u64() % 48;
                    rng.next_u64() >> (16 + shift % 48)
                })
                .collect();
            for code in [Code::Gamma, Code::Delta, Code::Zeta(3)] {
                roundtrip_one(code, &values);
            }
            // Golomb's unary quotient is linear in x/m: keep x/m bounded.
            let golomb_values: Vec<u64> =
                values.iter().map(|&v| v % (64 * 4096)).collect();
            roundtrip_one(Code::Golomb(64), &golomb_values);
        }
    }

    #[test]
    fn zig_zag() {
        for v in [-1000i64, -3, -2, -1, 0, 1, 2, 3, 1000, i64::MIN / 2, i64::MAX / 2] {
            assert_eq!(nat_to_int(int_to_nat(v)), v);
        }
        assert_eq!(int_to_nat(0), 0);
        assert_eq!(int_to_nat(-1), 1);
        assert_eq!(int_to_nat(1), 2);
        assert_eq!(int_to_nat(-2), 3);
    }

    #[test]
    fn gamma_known_lengths() {
        // gamma(0) = "1" (1 bit), gamma(1)="010", gamma(2)="011" (3 bits)...
        assert_eq!(Code::Gamma.len_bits(0), 1);
        assert_eq!(Code::Gamma.len_bits(1), 3);
        assert_eq!(Code::Gamma.len_bits(2), 3);
        assert_eq!(Code::Gamma.len_bits(3), 5);
        assert_eq!(Code::Gamma.len_bits(6), 5);
        assert_eq!(Code::Gamma.len_bits(7), 7);
    }

    #[test]
    fn zeta_beats_gamma_on_powerlaw_tail() {
        // The point of zeta_k: shorter codes for the heavy tail.
        let big = 100_000u64;
        assert!(Code::Zeta(3).len_bits(big) <= Code::Gamma.len_bits(big));
    }

    #[test]
    fn minimal_binary_edge_cases() {
        // max == 1 encodes in zero bits.
        let mut w = BitWriter::new();
        write_minimal_binary(&mut w, 0, 1, 1);
        assert_eq!(w.bit_len(), 0);
        // Exhaustive check for small ranges.
        for max in 2u64..20 {
            let mut w = BitWriter::new();
            for x in 0..max {
                write_minimal_binary(&mut w, x, max, bit_width(max));
            }
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            for x in 0..max {
                assert_eq!(read_minimal_binary(&mut r, max, bit_width(max)).unwrap(), x);
            }
        }
    }

    #[test]
    fn table_reader_matches_slow_path_exactly() {
        // Every value around the short/long codeword boundary for every
        // tabled family, plus large values forcing the slow-path fallback.
        let mut values: Vec<u64> = (0..2048).collect();
        values.extend([4095, 4096, 100_000, 1 << 33, u64::MAX >> 2]);
        for code in [
            Code::Gamma,
            Code::Delta,
            Code::Zeta(1),
            Code::Zeta(2),
            Code::Zeta(3),
            Code::Zeta(4),
            Code::Zeta(5), // no table: pure fallback
            Code::Unary,   // static table (short runs) + slow-path tail
        ] {
            let vals: Vec<u64> = match code {
                Code::Unary => values.iter().map(|&v| v % 500).collect(),
                // The ζ writer's shell arithmetic (`left << k`) needs
                // h·k + k ≤ 63, i.e. values below ~2^58; stay well under.
                Code::Zeta(_) => values.iter().map(|&v| v.min(1 << 40)).collect(),
                _ => values.clone(),
            };
            let mut w = BitWriter::new();
            for &v in &vals {
                code.write(&mut w, v);
            }
            let bytes = w.into_bytes();
            let mut fast = BitReader::new(&bytes);
            let mut slow = BitReader::new(&bytes);
            let mut reader = CodeReader::new(code);
            for &v in &vals {
                assert_eq!(reader.read(&mut fast).unwrap(), v, "{code:?} value {v}");
                assert_eq!(code.read(&mut slow).unwrap(), v);
                assert_eq!(fast.bit_pos(), slow.bit_pos(), "{code:?} value {v}");
            }
            assert_eq!(reader.table_hits + reader.table_misses, vals.len() as u64);
            if matches!(code, Code::Gamma | Code::Delta | Code::Unary) {
                assert!(reader.table_hits > 0, "{code:?} small values must hit the table");
            }
        }
    }

    #[test]
    fn unary_and_golomb_tables_match_slow_path() {
        // The unary static table and the per-reader Golomb tables must be
        // bit-exact with the field-by-field reference, across the
        // short/long codeword boundary, and carry honest hit counters.
        let mut rng = Xoshiro256::seed_from_u64(47);
        let mut cases: Vec<(Code, Vec<u64>)> = vec![(
            Code::Unary,
            (0..500).map(|_| rng.next_below(40)).collect(),
        )];
        for m in [1u64, 2, 3, 5, 8, 16, 63, 100, 512] {
            // Keep x/m bounded so the unary quotient stays sane, while
            // still crossing the table edge (quotients past the window);
            // every 4th value is tiny so table hits are guaranteed, not
            // left to the draw.
            let vals: Vec<u64> = (0..500)
                .map(|i| {
                    if i % 4 == 0 {
                        rng.next_below(8)
                    } else {
                        rng.next_below(m * 40)
                    }
                })
                .collect();
            cases.push((Code::Golomb(m), vals));
        }
        for (code, vals) in cases {
            let mut w = BitWriter::new();
            for &v in &vals {
                code.write(&mut w, v);
            }
            let bytes = w.into_bytes();
            let mut fast = BitReader::new(&bytes);
            let mut slow = BitReader::new(&bytes);
            let mut reader = CodeReader::new(code);
            for &v in &vals {
                assert_eq!(reader.read(&mut fast).unwrap(), v, "{code:?} value {v}");
                assert_eq!(code.read(&mut slow).unwrap(), v, "{code:?} value {v}");
                assert_eq!(fast.bit_pos(), slow.bit_pos(), "{code:?} value {v}");
            }
            assert_eq!(reader.table_hits + reader.table_misses, vals.len() as u64);
            assert!(reader.table_hits > 0, "{code:?}: small codewords must hit the table");
            assert!(reader.hit_rate() > 0.0);
        }
        // Large m: every codeword is longer than the window — the reader
        // must degrade to a no-table fallback, not a 100%-miss table.
        for m in [2048u64, 4096, 1 << 40] {
            let code = Code::Golomb(m);
            let vals: Vec<u64> = (0..50).map(|i| i * (m / 2).max(1) % (m * 4)).collect();
            let mut w = BitWriter::new();
            for &v in &vals {
                code.write(&mut w, v);
            }
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            let mut reader = CodeReader::new(code);
            for &v in &vals {
                assert_eq!(reader.read(&mut r).unwrap(), v, "m={m} value {v}");
            }
            assert_eq!(reader.table_hits, 0, "m={m}: nothing fits the window");
        }
        // Batched runs take the same table path.
        let mut reader = CodeReader::new(Code::Golomb(16));
        let vals: Vec<u64> = (0..2000).map(|i| (i * 7) % 600).collect();
        let mut w = BitWriter::new();
        for &v in &vals {
            Code::Golomb(16).write(&mut w, v);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        let mut out = Vec::new();
        reader.read_run(&mut r, vals.len(), &mut out).unwrap();
        assert_eq!(out, vals);
        assert!(reader.table_hits > 0);
    }

    #[test]
    fn batched_run_matches_symbol_by_symbol() {
        let mut rng = Xoshiro256::seed_from_u64(31);
        for code in [Code::Gamma, Code::Zeta(3), Code::Golomb(16)] {
            let vals: Vec<u64> = (0..3000).map(|_| rng.next_below(1 << 14)).collect();
            let mut w = BitWriter::new();
            for &v in &vals {
                code.write(&mut w, v);
            }
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            let mut reader = CodeReader::new(code);
            let mut out = Vec::new();
            reader.read_run(&mut r, vals.len(), &mut out).unwrap();
            assert_eq!(out, vals, "{code:?}");
            // And a truncated run errors instead of inventing symbols.
            let cut = &bytes[..bytes.len() / 2];
            let mut r2 = BitReader::new(cut);
            let mut out2 = Vec::new();
            assert!(reader.read_run(&mut r2, vals.len(), &mut out2).is_err(), "{code:?}");
        }
    }

    #[test]
    fn table_reader_near_stream_end() {
        // A single short codeword at the very end of the stream: the peek
        // window is zero-padded but the decode must still be exact, and one
        // more read must error.
        for code in [Code::Gamma, Code::Delta, Code::Zeta(3)] {
            for v in 0..64u64 {
                let mut w = BitWriter::new();
                code.write(&mut w, v);
                let bit_len = w.bit_len();
                let bytes = w.into_bytes();
                let mut r = BitReader::new(&bytes);
                let mut reader = CodeReader::new(code);
                assert_eq!(reader.read(&mut r).unwrap(), v, "{code:?} value {v}");
                assert_eq!(r.bit_pos(), bit_len);
                // Whatever padding remains is under 8 zero bits — another
                // symbol read must fail, identically to the slow path.
                let fast_err = reader.read(&mut r).is_err();
                let mut slow = BitReader::at_bit(&bytes, bit_len).unwrap();
                assert_eq!(fast_err, code.read(&mut slow).is_err(), "{code:?} value {v}");
            }
        }
    }

    #[test]
    fn truncated_decoder_rejects_garbage_gracefully() {
        // Decoding arbitrary bytes must never panic — only Ok or Err.
        let mut rng = Xoshiro256::seed_from_u64(99);
        for _ in 0..200 {
            let bytes: Vec<u8> = (0..16).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
            let mut r = BitReader::new(&bytes);
            for code in [Code::Gamma, Code::Delta, Code::Zeta(3), Code::Golomb(7)] {
                let _ = code.read(&mut r); // must not panic
            }
        }
    }
}
