//! Elias–Fano encoding of monotone integer sequences.
//!
//! The WebGraph offsets sidecar stores two monotone sequences per graph
//! (per-vertex *bit* offsets into the compressed stream and the CSR *edge*
//! offsets). Fully materialized as `Vec<u64>` they cost 16 B/vertex — the
//! paper's Table 3 datasets (up to 3.6 B vertices) would need ~58 GB of
//! offsets alone. Elias–Fano stores an n-element monotone sequence with
//! universe u in `n * (2 + ceil(log2(u/n)))` bits — ~9–12 bits/entry for
//! typical compressed graphs, i.e. under 20% of the plain footprint — while
//! keeping O(1) random access via quantum-sampled select, which is exactly
//! what webgraph-rs (`sux`'s `EliasFano`) uses for its offsets.
//!
//! Layout: each value is split into `low_bits` low bits (packed verbatim)
//! and the remaining high bits (stored as a unary-gap bit vector: value `i`
//! sets bit `(v_i >> low_bits) + i`). `get(i)` finds the position of the
//! i-th set bit with a sampled select (one sample every [`SELECT_QUANTUM`]
//! ones, then a popcount scan). The scan covers one inter-sample span,
//! which averages ~2·[`SELECT_QUANTUM`] bits (global density of the
//! high-bits vector is ~1/2), so access is O(1) *expected*. Spans
//! stretched by one giant value gap — e.g. the edge-offsets entry of a
//! hub vertex whose degree is far above the mean — would degrade the scan
//! to O(gap / 64) words, so quanta wider than [`SPILL_SPAN_BITS`] carry a
//! sux-style *spill*: the explicit position of every set bit in the
//! quantum, making `get` worst-case O(1) on extreme hubs too.

use std::fmt;

/// One select sample per this many set bits. 64 keeps the scan within a
/// couple of words (the high-bits vector holds ~2 bits per element).
const SELECT_QUANTUM: usize = 64;

/// A quantum whose set bits stretch over more than this many bits of the
/// high vector gets an explicit spill (positions of all its ones). At the
/// ~1/2 global density the typical span is ~2·[`SELECT_QUANTUM`] bits, so
/// 16× that only triggers on genuinely skewed gaps; the spill then costs
/// ≤ [`SELECT_QUANTUM`] words per stretched quantum.
const SPILL_SPAN_BITS: usize = SELECT_QUANTUM * 32;

/// Sentinel in `spill_index` marking a quantum without a spill.
const NO_SPILL: u64 = u64::MAX;

/// Errors from [`EliasFanoBuilder::push`] — a corrupt sidecar must surface
/// as `Err`, never as a panic or an unbounded allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EfError {
    /// Value smaller than its predecessor.
    NonMonotone { index: usize },
    /// Value above the declared universe.
    AboveUniverse { index: usize },
    /// More values pushed than the builder was sized for.
    TooMany,
    /// `finish` called before all declared values were pushed.
    TooFew { pushed: usize, expected: usize },
}

impl fmt::Display for EfError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EfError::NonMonotone { index } => {
                write!(f, "elias-fano: value {index} smaller than its predecessor")
            }
            EfError::AboveUniverse { index } => {
                write!(f, "elias-fano: value {index} above the declared universe")
            }
            EfError::TooMany => write!(f, "elias-fano: more values than declared"),
            EfError::TooFew { pushed, expected } => {
                write!(f, "elias-fano: {pushed} values pushed, {expected} declared")
            }
        }
    }
}

impl std::error::Error for EfError {}

/// A monotone (non-decreasing) sequence of `u64`, Elias–Fano compressed,
/// with O(1) `get`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EliasFano {
    len: usize,
    universe: u64,
    low_bits: u32,
    /// Packed `low_bits`-bit values, LSB-first within each word; one pad
    /// word so the straddling read in `get_low` never goes out of bounds.
    lows: Vec<u64>,
    /// Upper-bits unary vector: bit `(v_i >> low_bits) + i` is set.
    highs: Vec<u64>,
    /// Bit position (in `highs`) of every `SELECT_QUANTUM`-th set bit.
    select_samples: Vec<u64>,
    /// Per-quantum offset into `spill`, or [`NO_SPILL`]. Only quanta whose
    /// span exceeds [`SPILL_SPAN_BITS`] are materialized.
    spill_index: Vec<u64>,
    /// Explicit bit positions of every one in each spilled quantum,
    /// quantum-major.
    spill: Vec<u64>,
}

/// Streaming builder: declare `len` and `universe` up front (both are in
/// the v2 sidecar header), then push values in order. Memory is allocated
/// once, proportional to the *compressed* size.
#[derive(Debug)]
pub struct EliasFanoBuilder {
    ef: EliasFano,
    pushed: usize,
    last: u64,
}

/// `low_bits` choice: floor(log2(universe / len)) (0 when the sequence is
/// denser than its universe).
fn low_bits_for(universe: u64, len: usize) -> u32 {
    if len == 0 {
        return 0;
    }
    let q = universe / len as u64;
    if q <= 1 {
        0
    } else {
        63 - q.leading_zeros()
    }
}

impl EliasFanoBuilder {
    pub fn new(len: usize, universe: u64) -> Self {
        let low_bits = low_bits_for(universe, len);
        let low_words = crate::util::ceil_div(len * low_bits as usize, 64) + 1;
        // Highest possible set bit: (universe >> low_bits) + len - 1.
        let high_bits = (universe >> low_bits) as usize + len + 1;
        let high_words = crate::util::ceil_div(high_bits, 64) + 1;
        EliasFanoBuilder {
            ef: EliasFano {
                len,
                universe,
                low_bits,
                lows: vec![0u64; low_words],
                highs: vec![0u64; high_words],
                select_samples: Vec::with_capacity(len / SELECT_QUANTUM + 1),
                spill_index: Vec::new(),
                spill: Vec::new(),
            },
            pushed: 0,
            last: 0,
        }
    }

    /// Append the next value (must be ≥ the previous and ≤ the universe).
    pub fn push(&mut self, value: u64) -> Result<(), EfError> {
        if self.pushed >= self.ef.len {
            return Err(EfError::TooMany);
        }
        if value < self.last {
            return Err(EfError::NonMonotone { index: self.pushed });
        }
        if value > self.ef.universe {
            return Err(EfError::AboveUniverse { index: self.pushed });
        }
        let i = self.pushed;
        let l = self.ef.low_bits;
        if l > 0 {
            let low = value & ((1u64 << l) - 1);
            let bitpos = i * l as usize;
            let (word, off) = (bitpos / 64, (bitpos % 64) as u32);
            self.ef.lows[word] |= low << off;
            if off + l > 64 {
                self.ef.lows[word + 1] |= low >> (64 - off);
            }
        }
        let pos = (value >> l) as usize + i;
        self.ef.highs[pos / 64] |= 1u64 << (pos % 64);
        if i % SELECT_QUANTUM == 0 {
            self.ef.select_samples.push(pos as u64);
        }
        self.pushed = i + 1;
        self.last = value;
        Ok(())
    }

    pub fn finish(self) -> Result<EliasFano, EfError> {
        if self.pushed != self.ef.len {
            return Err(EfError::TooFew { pushed: self.pushed, expected: self.ef.len });
        }
        let mut ef = self.ef;
        ef.build_spill();
        Ok(ef)
    }
}

impl EliasFano {
    /// Compress a pre-materialized monotone slice (tests, the v1 sidecar
    /// compatibility path, and conversions from in-memory CSR offsets).
    pub fn from_monotone(values: &[u64]) -> Result<Self, EfError> {
        let universe = values.last().copied().unwrap_or(0);
        let mut b = EliasFanoBuilder::new(values.len(), universe);
        for &v in values {
            b.push(v)?;
        }
        b.finish()
    }

    /// Number of stored values.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Declared universe (upper bound of every stored value).
    pub fn universe(&self) -> u64 {
        self.universe
    }

    /// The i-th value. O(1) amortized. Panics if `i >= len` (like slice
    /// indexing; all callers range-check the vertex id first).
    #[inline]
    pub fn get(&self, i: usize) -> u64 {
        assert!(i < self.len, "elias-fano index {i} out of range (len {})", self.len);
        let high = (self.select1(i) - i) as u64;
        (high << self.low_bits) | self.get_low(i)
    }

    #[inline]
    fn get_low(&self, i: usize) -> u64 {
        let l = self.low_bits;
        if l == 0 {
            return 0;
        }
        let bitpos = i * l as usize;
        let (word, off) = (bitpos / 64, (bitpos % 64) as u32);
        let mut v = self.lows[word] >> off;
        if off + l > 64 {
            v |= self.lows[word + 1] << (64 - off);
        }
        v & ((1u64 << l) - 1)
    }

    /// One linear pass over the high-bits vector after construction:
    /// any quantum of `SELECT_QUANTUM` consecutive ones spanning more than
    /// [`SPILL_SPAN_BITS`] bits gets its positions materialized, so
    /// [`Self::select1`] never scans a stretched span. O(highs) time, run
    /// once per build; the spill is empty for well-behaved sequences.
    fn build_spill(&mut self) {
        let quanta = crate::util::ceil_div(self.len.max(1), SELECT_QUANTUM);
        self.spill_index = vec![NO_SPILL; quanta];
        self.spill.clear();
        if self.len == 0 {
            return;
        }
        let mut scratch: Vec<u64> = Vec::with_capacity(SELECT_QUANTUM);
        let mut q = 0usize;
        let mut word_idx = 0usize;
        let mut word = self.highs[0];
        let mut i = 0usize;
        while i < self.len {
            while word == 0 {
                word_idx += 1;
                word = self.highs[word_idx];
            }
            let pos = (word_idx * 64 + word.trailing_zeros() as usize) as u64;
            word &= word - 1;
            scratch.push(pos);
            i += 1;
            if i % SELECT_QUANTUM == 0 || i == self.len {
                let span = (scratch[scratch.len() - 1] - scratch[0]) as usize;
                if span > SPILL_SPAN_BITS {
                    self.spill_index[q] = self.spill.len() as u64;
                    self.spill.extend_from_slice(&scratch);
                }
                scratch.clear();
                q += 1;
            }
        }
    }

    /// Number of quanta carrying an explicit spill (hub-span diagnostics).
    pub fn spilled_quanta(&self) -> usize {
        self.spill_index.iter().filter(|&&o| o != NO_SPILL).count()
    }

    /// Bit position in `highs` of the i-th set bit.
    #[inline]
    fn select1(&self, i: usize) -> usize {
        // Worst-case O(1) fast path: stretched quanta are materialized.
        let spilled = self.spill_index[i / SELECT_QUANTUM];
        if spilled != NO_SPILL {
            return self.spill[spilled as usize + i % SELECT_QUANTUM] as usize;
        }
        let sample = self.select_samples[i / SELECT_QUANTUM] as usize;
        // Ones still to skip; the sampled bit itself is the 0th.
        let mut remaining = i % SELECT_QUANTUM;
        let mut word_idx = sample / 64;
        let mut word = self.highs[word_idx] & (u64::MAX << (sample % 64));
        loop {
            let ones = word.count_ones() as usize;
            if remaining < ones {
                let mut w = word;
                for _ in 0..remaining {
                    w &= w - 1; // clear lowest set bit
                }
                return word_idx * 64 + w.trailing_zeros() as usize;
            }
            remaining -= ones;
            word_idx += 1;
            word = self.highs[word_idx];
        }
    }

    /// First index in `0..=len` where `pred(get(index))` is false, given
    /// `pred` holds on a prefix (the `slice::partition_point` contract).
    /// O(log n) `get`s — used for edge→vertex and bit→vertex searches.
    pub fn partition_point(&self, pred: impl Fn(u64) -> bool) -> usize {
        let mut lo = 0usize;
        let mut hi = self.len;
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if pred(self.get(mid)) {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo
    }

    /// Heap footprint of the compressed structure in bytes.
    pub fn size_bytes(&self) -> usize {
        (self.lows.len()
            + self.highs.len()
            + self.select_samples.len()
            + self.spill_index.len()
            + self.spill.len())
            * 8
            + std::mem::size_of::<Self>()
    }

    /// Footprint of the same sequence as a plain `Vec<u64>`.
    pub fn plain_size_bytes(&self) -> usize {
        self.len * 8
    }

    /// Materialize `[start, end)` as a plain vector in one linear pass:
    /// a single select for the first element, then a sequential walk of
    /// the high-bits words (independent `get`s would re-scan the same
    /// words from the nearest sample for every element).
    pub fn to_vec_range(&self, start: usize, end: usize) -> Vec<u64> {
        assert!(start <= end && end <= self.len, "bad range {start}..{end} (len {})", self.len);
        let mut out = Vec::with_capacity(end - start);
        if start == end {
            return out;
        }
        let first = self.select1(start);
        let mut word_idx = first / 64;
        let mut word = self.highs[word_idx] & (u64::MAX << (first % 64));
        for i in start..end {
            while word == 0 {
                word_idx += 1;
                word = self.highs[word_idx];
            }
            let bit = word_idx * 64 + word.trailing_zeros() as usize;
            word &= word - 1; // consume the i-th set bit
            out.push((((bit - i) as u64) << self.low_bits) | self.get_low(i));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn check_equals(values: &[u64]) {
        let ef = EliasFano::from_monotone(values).expect("build");
        assert_eq!(ef.len(), values.len());
        for (i, &v) in values.iter().enumerate() {
            assert_eq!(ef.get(i), v, "index {i}");
        }
    }

    #[test]
    fn small_sequences_roundtrip() {
        check_equals(&[0]);
        check_equals(&[7]);
        check_equals(&[0, 0, 0, 0]);
        check_equals(&[0, 1, 2, 3, 4, 5]);
        check_equals(&[0, 0, 5, 5, 5, 1000]);
        check_equals(&[u64::MAX >> 2]);
        check_equals(&(0..1000).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn random_monotone_roundtrip_vs_vec_oracle() {
        let mut rng = Xoshiro256::seed_from_u64(0xEF);
        for case in 0..30 {
            let n = 1 + rng.next_below(3000) as usize;
            let max_gap = 1 << rng.next_below(20);
            let mut acc = 0u64;
            let values: Vec<u64> = (0..n)
                .map(|_| {
                    acc += rng.next_below(max_gap);
                    acc
                })
                .collect();
            let ef = EliasFano::from_monotone(&values).expect("build");
            for (i, &v) in values.iter().enumerate() {
                assert_eq!(ef.get(i), v, "case {case} index {i}");
            }
            // Linear-scan materialization agrees with per-element access.
            let a = rng.next_below(n as u64) as usize;
            let b = a + rng.next_below((n - a) as u64 + 1) as usize;
            assert_eq!(ef.to_vec_range(a, b), values[a..b].to_vec(), "case {case} {a}..{b}");
            // partition_point agrees with the slice implementation.
            for _ in 0..20 {
                let probe = rng.next_below(values.last().unwrap() + 2);
                assert_eq!(
                    ef.partition_point(|v| v < probe),
                    values.partition_point(|&v| v < probe),
                    "case {case} probe {probe} (<)"
                );
                assert_eq!(
                    ef.partition_point(|v| v <= probe),
                    values.partition_point(|&v| v <= probe),
                    "case {case} probe {probe} (<=)"
                );
            }
        }
    }

    #[test]
    fn dense_and_sparse_extremes() {
        // Dense: universe == len (every value distinct by 1).
        let dense: Vec<u64> = (0..5000u64).collect();
        check_equals(&dense);
        // Sparse: few values, huge universe.
        check_equals(&[0, 1 << 40, (1 << 40) + 1, 1 << 62]);
        // Constant plateau crossing many sample quanta.
        let plateau: Vec<u64> = vec![42; 1000];
        check_equals(&plateau);
    }

    #[test]
    fn builder_validates_input() {
        let mut b = EliasFanoBuilder::new(3, 100);
        b.push(10).unwrap();
        assert_eq!(b.push(5), Err(EfError::NonMonotone { index: 1 }));
        b.push(10).unwrap();
        assert_eq!(b.push(101), Err(EfError::AboveUniverse { index: 2 }));
        b.push(100).unwrap();
        assert_eq!(b.push(100), Err(EfError::TooMany));
        let ef = b.finish().unwrap();
        assert_eq!((ef.get(0), ef.get(1), ef.get(2)), (10, 10, 100));

        let b2 = EliasFanoBuilder::new(4, 100);
        assert_eq!(b2.finish(), Err(EfError::TooFew { pushed: 0, expected: 4 }));
    }

    #[test]
    fn footprint_is_a_fraction_of_plain_vectors() {
        // Offsets-like sequence: ~120 bits per record, 50k entries.
        let mut acc = 0u64;
        let mut rng = Xoshiro256::seed_from_u64(7);
        let values: Vec<u64> = (0..50_000)
            .map(|_| {
                acc += 40 + rng.next_below(160);
                acc
            })
            .collect();
        let ef = EliasFano::from_monotone(&values).unwrap();
        assert!(
            ef.size_bytes() * 100 <= ef.plain_size_bytes() * 40,
            "EF must be ≤ 40% of plain: {} vs {}",
            ef.size_bytes(),
            ef.plain_size_bytes()
        );
    }

    #[test]
    fn hub_spans_are_spilled_and_exact() {
        // An edge-offsets-like sequence with extreme hubs: mostly small
        // degrees, but a few vertices whose degree stretches one select
        // quantum far past SPILL_SPAN_BITS. Without the spill, get() inside
        // those quanta scans O(gap/64) words; with it, every index is O(1)
        // — and, crucially, still exact.
        // low_bits adapts to the universe (≈ log2(u/n)), so a hub's jump in
        // the high vector is ≈ gap / (u/n) ≈ n / hubs bits: two hubs among
        // 10k values stretch their quanta by ~5000 bits — past the bar.
        let mut values = Vec::new();
        let mut acc = 0u64;
        for v in 0..10_000u64 {
            acc += if v == 2500 || v == 7500 { 1 << 30 } else { 1 + v % 3 };
            values.push(acc);
        }
        let ef = EliasFano::from_monotone(&values).expect("build");
        assert!(
            ef.spilled_quanta() > 0,
            "hub gaps of 2^22 must stretch at least one quantum past the spill bar"
        );
        for (i, &v) in values.iter().enumerate() {
            assert_eq!(ef.get(i), v, "index {i}");
        }
        // partition_point (binary search over get) stays consistent too.
        for probe in [0u64, 1 << 21, 1 << 22, acc, acc + 1] {
            assert_eq!(
                ef.partition_point(|v| v < probe),
                values.partition_point(|&v| v < probe),
                "probe {probe}"
            );
        }
        // A smooth sequence must not pay for the machinery.
        let smooth: Vec<u64> = (0..10_000).map(|i| i * 3).collect();
        let smooth_ef = EliasFano::from_monotone(&smooth).unwrap();
        assert_eq!(smooth_ef.spilled_quanta(), 0, "no spill on uniform gaps");
    }

    #[test]
    fn empty_sequence() {
        let ef = EliasFano::from_monotone(&[]).unwrap();
        assert!(ef.is_empty());
        assert_eq!(ef.partition_point(|v| v < 10), 0);
        assert_eq!(ef.to_vec_range(0, 0), Vec::<u64>::new());
    }

    #[test]
    fn to_vec_range_slices() {
        let values: Vec<u64> = (0..100).map(|i| i * i).collect();
        let ef = EliasFano::from_monotone(&values).unwrap();
        assert_eq!(ef.to_vec_range(10, 20), values[10..20].to_vec());
        assert_eq!(ef.to_vec_range(0, 100), values);
    }
}
