//! Low-level utilities: bit streams, instantaneous codes, PRNG, prefix sums,
//! a minimal JSON writer and a thread pool.
//!
//! These are the substrates everything else builds on. The offline build has
//! no access to `rand`, `serde` or `rayon`, so the implementations live here.

pub mod bitstream;
pub mod codes;
pub mod elias_fano;
pub mod json;
pub mod pool;
pub mod prefix;
pub mod rng;

/// Ceiling division for unsigned integers.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Split `n` items into `parts` contiguous chunks as evenly as possible.
/// Returns the `(start, end)` half-open range of chunk `idx`.
#[inline]
pub fn chunk_range(n: usize, parts: usize, idx: usize) -> (usize, usize) {
    debug_assert!(parts > 0 && idx < parts);
    let base = n / parts;
    let rem = n % parts;
    let start = idx * base + idx.min(rem);
    let len = base + usize::from(idx < rem);
    (start, start + len)
}

/// Human-readable byte size (e.g. "1.5 GB").
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KB", "MB", "GB", "TB", "PB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", bytes, UNITS[0])
    } else {
        format!("{:.1} {}", v, UNITS[u])
    }
}

/// Human-readable count (e.g. "2.4 B" edges).
pub fn fmt_count(n: u64) -> String {
    const UNITS: [(u64, &str); 3] = [(1_000_000_000, "B"), (1_000_000, "M"), (1_000, "K")];
    for (div, suffix) in UNITS {
        if n >= div {
            return format!("{:.1} {}", n as f64 / div as f64, suffix);
        }
    }
    n.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn chunk_ranges_cover_exactly() {
        for n in [0usize, 1, 7, 64, 100, 1023] {
            for parts in [1usize, 2, 3, 7, 16] {
                let mut covered = 0usize;
                let mut prev_end = 0usize;
                for i in 0..parts {
                    let (s, e) = chunk_range(n, parts, i);
                    assert_eq!(s, prev_end, "chunks must be contiguous");
                    assert!(e >= s);
                    covered += e - s;
                    prev_end = e;
                }
                assert_eq!(covered, n);
                assert_eq!(prev_end, n);
            }
        }
    }

    #[test]
    fn chunk_sizes_balanced() {
        let (s0, e0) = chunk_range(10, 3, 0);
        let (s1, e1) = chunk_range(10, 3, 1);
        let (s2, e2) = chunk_range(10, 3, 2);
        assert_eq!((e0 - s0, e1 - s1, e2 - s2), (4, 3, 3));
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KB");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(2_400_000_000), "2.4 B");
    }
}
