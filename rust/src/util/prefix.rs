//! Prefix sums — sequential and chunk-parallel.
//!
//! The two-pass parallel text parse (GAPBS-style COO loading) and the CSR
//! builder both hinge on an exclusive prefix sum over per-chunk counts; the
//! gap-decode hot path is an inclusive scan (offloaded to the Pallas kernel,
//! with these as the Rust fallback/oracle).

/// In-place exclusive prefix sum; returns the total.
pub fn exclusive_prefix_sum(values: &mut [u64]) -> u64 {
    let mut acc = 0u64;
    for v in values.iter_mut() {
        let next = acc + *v;
        *v = acc;
        acc = next;
    }
    acc
}

/// In-place inclusive prefix sum; returns the total (last element) or 0.
pub fn inclusive_prefix_sum(values: &mut [u64]) -> u64 {
    let mut acc = 0u64;
    for v in values.iter_mut() {
        acc += *v;
        *v = acc;
    }
    acc
}

/// Inclusive scan of i64 gaps starting from `base`, writing absolute values.
/// This is exactly the semantics of the L1 `gap_decode` kernel and serves as
/// its Rust-side oracle and fallback.
pub fn gap_to_absolute(base: i64, gaps: &[i64], out: &mut [i64]) {
    debug_assert_eq!(gaps.len(), out.len());
    let mut acc = base;
    for (o, &g) in out.iter_mut().zip(gaps) {
        acc += g;
        *o = acc;
    }
}

/// Blocked inclusive scan: scan each block independently, then add carries.
/// Mirrors the tile decomposition the Pallas kernel uses, so tests can check
/// the decomposition logic itself against the flat scan.
pub fn blocked_inclusive_scan(values: &mut [u64], block: usize) {
    assert!(block > 0);
    let mut carry = 0u64;
    for chunk in values.chunks_mut(block) {
        let mut acc = carry;
        for v in chunk.iter_mut() {
            acc += *v;
            *v = acc;
        }
        carry = acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn exclusive_basics() {
        let mut v = vec![3, 1, 4, 1, 5];
        let total = exclusive_prefix_sum(&mut v);
        assert_eq!(v, vec![0, 3, 4, 8, 9]);
        assert_eq!(total, 14);
        let mut empty: Vec<u64> = vec![];
        assert_eq!(exclusive_prefix_sum(&mut empty), 0);
    }

    #[test]
    fn inclusive_basics() {
        let mut v = vec![3, 1, 4, 1, 5];
        let total = inclusive_prefix_sum(&mut v);
        assert_eq!(v, vec![3, 4, 8, 9, 14]);
        assert_eq!(total, 14);
    }

    #[test]
    fn gap_decode_oracle() {
        let gaps = [5i64, -2, 0, 7, -1];
        let mut out = [0i64; 5];
        gap_to_absolute(10, &gaps, &mut out);
        assert_eq!(out, [15, 13, 13, 20, 19]);
    }

    #[test]
    fn blocked_scan_matches_flat_scan() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        for n in [0usize, 1, 5, 64, 100, 257] {
            for block in [1usize, 2, 16, 64, 300] {
                let base: Vec<u64> = (0..n).map(|_| rng.next_below(1000)).collect();
                let mut flat = base.clone();
                inclusive_prefix_sum(&mut flat);
                let mut blocked = base.clone();
                blocked_inclusive_scan(&mut blocked, block);
                assert_eq!(flat, blocked, "n={n} block={block}");
            }
        }
    }
}
