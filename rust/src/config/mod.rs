//! Configuration: a TOML-subset file format plus CLI-style overrides.
//!
//! Experiments are driven by key=value settings (dataset scale, device,
//! thread counts, buffer sizes, format lists). The parser supports the
//! subset of TOML the configs need: `[sections]`, `key = value` with
//! strings, integers, floats, booleans and flat arrays, and `#` comments.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A parsed configuration value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    List(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Flat config: `section.key` -> value.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, parse_value(v.trim(), lineno + 1)?);
        }
        Ok(Config { values })
    }

    pub fn from_file(path: &str) -> Result<Config> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Self::parse(&text)
    }

    /// Apply a `key=value` override (CLI `--set key=value`).
    pub fn set_override(&mut self, spec: &str) -> Result<()> {
        let (k, v) = spec.split_once('=').context("override must be key=value")?;
        self.values.insert(k.trim().to_string(), parse_value(v.trim(), 0)?);
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn get_int(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_int()).unwrap_or(default)
    }

    pub fn get_float(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_float()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // A `#` outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, lineno: usize) -> Result<Value> {
    let t = text.trim();
    if t.is_empty() {
        bail!("line {lineno}: empty value");
    }
    if let Some(inner) = t.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = t.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let p = part.trim();
            if !p.is_empty() {
                items.push(parse_value(p, lineno)?);
            }
        }
        return Ok(Value::List(items));
    }
    match t {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = t.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = t.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    // Bare word: treat as string (ergonomic for device/format names).
    Ok(Value::Str(t.to_string()))
}

fn split_top_level(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth -= 1;
                cur.push(c);
            }
            ',' if depth == 0 && !in_str => {
                parts.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let cfg = Config::parse(
            r#"
            # experiment config
            scale = 2
            [storage]
            device = "HDD"
            bandwidth = 160.5   # MB/s
            cache = true
            [load]
            formats = ["webgraph", "bin_csx"]
            threads = [1, 18, 36]
            "#,
        )
        .unwrap();
        assert_eq!(cfg.get_int("scale", 0), 2);
        assert_eq!(cfg.get_str("storage.device", ""), "HDD");
        assert!((cfg.get_float("storage.bandwidth", 0.0) - 160.5).abs() < 1e-9);
        assert!(cfg.get_bool("storage.cache", false));
        match cfg.get("load.threads") {
            Some(Value::List(items)) => {
                assert_eq!(items.len(), 3);
                assert_eq!(items[2].as_int(), Some(36));
            }
            other => panic!("bad list: {other:?}"),
        }
    }

    #[test]
    fn bare_words_are_strings() {
        let cfg = Config::parse("device = SSD\n").unwrap();
        assert_eq!(cfg.get_str("device", ""), "SSD");
    }

    #[test]
    fn overrides() {
        let mut cfg = Config::parse("a = 1\n").unwrap();
        cfg.set_override("a=5").unwrap();
        cfg.set_override("b.c=\"x\"").unwrap();
        assert_eq!(cfg.get_int("a", 0), 5);
        assert_eq!(cfg.get_str("b.c", ""), "x");
        assert!(cfg.set_override("nope").is_err());
    }

    #[test]
    fn defaults_on_missing() {
        let cfg = Config::default();
        assert_eq!(cfg.get_int("x", 7), 7);
        assert_eq!(cfg.get_str("y", "d"), "d");
    }

    #[test]
    fn bad_syntax_is_error() {
        assert!(Config::parse("just a line\n").is_err());
        assert!(Config::parse("k =\n").is_err());
    }
}
