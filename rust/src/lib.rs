//! # ParaGrapher (Rust + JAX + Pallas reproduction)
//!
//! A reproduction of *“Selective Parallel Loading of Large-Scale Compressed
//! Graphs with ParaGrapher”* (CS.AR 2024) as a three-layer stack:
//!
//! * **L3 (this crate)** — the ParaGrapher coordinator: the graph-loading
//!   API ([`coordinator`], event-driven over a sharded buffer pool), the
//!   WebGraph-style compressed format, the GAPBS-style baseline formats and
//!   the [`formats::GraphSource`] loading contract (block streaming plus
//!   cached per-vertex random access), the partitioned request subsystem
//!   ([`partition`]: edge-balanced 1D/2D/COO plans, model-driven prefetch,
//!   multi-consumer [`partition::PartitionStream`]s), the multi-process
//!   distributed harness ([`distributed`]: leader/worker plan shipping
//!   over length-prefixed JSON frames, tile leasing, fault retiling), a
//!   calibrated
//!   virtual-time storage simulator ([`storage`], including the
//!   decoded-block LRU), graph algorithms ([`algorithms`], with
//!   out-of-core `*_on` and interleaved `partitioned` variants) and the §3
//!   performance model ([`model`]).
//! * **L2/L1 (build-time Python)** — the vectorizable decode phase
//!   (gap→ID prefix-sum) and WCC label-propagation step, written in JAX +
//!   Pallas, AOT-lowered to HLO text and executed from Rust via the PJRT C
//!   API ([`runtime`]).
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

pub mod algorithms;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod distributed;
pub mod formats;
pub mod graph;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod partition;
pub mod runtime;
pub mod serve;
pub mod storage;
pub mod util;
