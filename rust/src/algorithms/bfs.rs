//! Breadth-first search and the BFS-based WCC oracle.
//!
//! Two flavors: [`bfs_distances`] runs over a fully-loaded [`CsrGraph`]
//! (the full-load baseline), while [`bfs_distances_on`] pulls each frontier
//! neighborhood through [`GraphSource::successors`] — the out-of-core
//! pattern where only the touched vertices' adjacency is ever decoded, with
//! the decoded-block cache absorbing re-visits.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::formats::GraphSource;
use crate::graph::{CsrGraph, VertexId};

/// BFS distances from `source` (u32::MAX = unreachable). Treats the graph
/// as directed.
pub fn bfs_distances(g: &CsrGraph, source: VertexId) -> Vec<u32> {
    let n = g.num_vertices();
    let mut dist = vec![u32::MAX; n];
    let mut q = VecDeque::new();
    dist[source as usize] = 0;
    q.push_back(source);
    while let Some(v) = q.pop_front() {
        let d = dist[v as usize];
        for &u in g.neighbors(v) {
            if dist[u as usize] == u32::MAX {
                dist[u as usize] = d + 1;
                q.push_back(u);
            }
        }
    }
    dist
}

/// BFS distances pulled through [`GraphSource::successors`] (random access,
/// no full load). Produces exactly the distances of [`bfs_distances`].
pub fn bfs_distances_on(src: &dyn GraphSource, source: VertexId) -> Result<Vec<u32>> {
    let n = src.num_vertices();
    if source as usize >= n {
        bail!("BFS source {source} out of range (n={n})");
    }
    let mut dist = vec![u32::MAX; n];
    let mut q = VecDeque::new();
    dist[source as usize] = 0;
    q.push_back(source);
    while let Some(v) = q.pop_front() {
        let d = dist[v as usize];
        for u in src.successors(v as usize)? {
            if dist[u as usize] == u32::MAX {
                dist[u as usize] = d + 1;
                q.push_back(u);
            }
        }
    }
    Ok(dist)
}

/// Weakly-connected components by BFS over the undirected view — the
/// ground-truth oracle for the WCC implementations. Labels are the
/// smallest vertex of each component.
pub fn wcc_by_bfs(g: &CsrGraph) -> Vec<VertexId> {
    let n = g.num_vertices();
    let t = g.transpose();
    let mut label = vec![VertexId::MAX; n];
    let mut q = VecDeque::new();
    for s in 0..n {
        if label[s] != VertexId::MAX {
            continue;
        }
        label[s] = s as VertexId;
        q.push_back(s as VertexId);
        while let Some(v) = q.pop_front() {
            for &u in g.neighbors(v).iter().chain(t.neighbors(v)) {
                if label[u as usize] == VertexId::MAX {
                    label[u as usize] = s as VertexId;
                    q.push_back(u);
                }
            }
        }
    }
    label
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn distances_on_a_path() {
        let g = CsrGraph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        assert_eq!(bfs_distances(&g, 0), vec![0, 1, 2, 3]);
        assert_eq!(bfs_distances(&g, 3), vec![u32::MAX, u32::MAX, u32::MAX, 0]);
    }

    #[test]
    fn source_pull_matches_full_load() {
        let g = generators::barabasi_albert(400, 4, 7);
        for s in [0u32, 17, 399] {
            assert_eq!(bfs_distances_on(&g, s).unwrap(), bfs_distances(&g, s), "source {s}");
        }
    }

    #[test]
    fn wcc_ignores_direction() {
        let g = CsrGraph::from_edges(4, &[(1, 0), (2, 3)]);
        let labels = wcc_by_bfs(&g);
        assert_eq!(labels, vec![0, 0, 2, 2]);
    }

    #[test]
    fn lattice_is_one_component() {
        let g = generators::road_lattice(10, 10, 0, 1);
        let labels = wcc_by_bfs(&g);
        assert!(labels.iter().all(|&l| l == 0));
    }
}
