//! Jayanti–Tarjan randomized concurrent union-find WCC (JT-CC).
//!
//! The paper's partial-processing workload (§5.3): each edge is processed
//! once, independently — so the algorithm composes with ParaGrapher's
//! asynchronous block delivery and never needs the whole graph in memory.
//! This implementation follows the "randomized linking by index" variant:
//! union by comparing (random-priority) roots with CAS, splitting paths on
//! find, safe for concurrent use from callback threads.

use std::sync::atomic::{AtomicU32, Ordering};

use crate::graph::VertexId;

/// Concurrent disjoint-set forest over `n` vertices.
pub struct JtUnionFind {
    parent: Vec<AtomicU32>,
    /// Random priorities breaking symmetry (Jayanti–Tarjan's randomization).
    priority: Vec<u32>,
}

impl JtUnionFind {
    pub fn new(n: usize, seed: u64) -> Self {
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(seed);
        let parent = (0..n).map(|v| AtomicU32::new(v as u32)).collect();
        let priority = (0..n).map(|_| rng.next_u64() as u32).collect();
        Self { parent, priority }
    }

    pub fn len(&self) -> usize {
        self.parent.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Find with path splitting (lock-free).
    pub fn find(&self, mut v: VertexId) -> VertexId {
        loop {
            let p = self.parent[v as usize].load(Ordering::Acquire);
            if p == v {
                return v;
            }
            let gp = self.parent[p as usize].load(Ordering::Acquire);
            if gp == p {
                return p;
            }
            // Path splitting: point v at its grandparent.
            let _ = self.parent[v as usize].compare_exchange_weak(
                p,
                gp,
                Ordering::AcqRel,
                Ordering::Acquire,
            );
            v = gp;
        }
    }

    /// Union the sets of `a` and `b` (processes one edge). Lock-free;
    /// links lower-priority root under higher-priority root.
    pub fn union(&self, a: VertexId, b: VertexId) {
        let mut x = a;
        let mut y = b;
        loop {
            x = self.find(x);
            y = self.find(y);
            if x == y {
                return;
            }
            // Order by (priority, id) so linking direction is consistent.
            let (lo, hi) = if (self.priority[x as usize], x) < (self.priority[y as usize], y)
            {
                (x, y)
            } else {
                (y, x)
            };
            match self.parent[lo as usize].compare_exchange(
                lo,
                hi,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return,
                Err(_) => {
                    // Someone moved lo; retry from the new roots.
                    x = lo;
                    y = hi;
                }
            }
        }
    }

    /// Final component labels (canonical root per vertex).
    pub fn labels(&self) -> Vec<VertexId> {
        (0..self.parent.len() as u32).map(|v| self.find(v)).collect()
    }

    /// Number of components.
    pub fn count_components(&self) -> usize {
        (0..self.parent.len() as u32).filter(|&v| self.find(v) == v).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::bfs::wcc_by_bfs;
    use crate::graph::generators;
    use crate::util::pool::parallel_for;

    #[test]
    fn chain_becomes_one_component() {
        let uf = JtUnionFind::new(5, 1);
        uf.union(0, 1);
        uf.union(1, 2);
        uf.union(3, 4);
        assert_eq!(uf.count_components(), 2);
        uf.union(4, 0);
        assert_eq!(uf.count_components(), 1);
    }

    #[test]
    fn matches_bfs_ground_truth() {
        for seed in [1u64, 2, 3] {
            let g = generators::rmat(8, 4, seed);
            let uf = JtUnionFind::new(g.num_vertices(), 9);
            for (s, d) in g.iter_edges() {
                uf.union(s, d);
            }
            let truth = wcc_by_bfs(&g);
            assert_eq!(
                uf.count_components(),
                crate::algorithms::count_components(&truth),
                "seed {seed}"
            );
        }
    }

    #[test]
    fn concurrent_unions_are_safe_and_correct() {
        let g = generators::barabasi_albert(2000, 4, 7);
        let edges: Vec<(VertexId, VertexId)> = g.iter_edges().collect();
        let uf = JtUnionFind::new(g.num_vertices(), 3);
        let parts = 16;
        parallel_for(parts, 8, |i| {
            let (s, e) = crate::util::chunk_range(edges.len(), parts, i);
            for &(a, b) in &edges[s..e] {
                uf.union(a, b);
            }
        });
        let truth = wcc_by_bfs(&g);
        assert_eq!(uf.count_components(), crate::algorithms::count_components(&truth));
    }

    #[test]
    fn edge_order_invariance() {
        let g = generators::erdos_renyi(300, 900, 5);
        let mut edges: Vec<(VertexId, VertexId)> = g.iter_edges().collect();
        let uf1 = JtUnionFind::new(g.num_vertices(), 1);
        for &(a, b) in &edges {
            uf1.union(a, b);
        }
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(42);
        rng.shuffle(&mut edges);
        let uf2 = JtUnionFind::new(g.num_vertices(), 2);
        for &(a, b) in &edges {
            uf2.union(a, b);
        }
        assert_eq!(uf1.count_components(), uf2.count_components());
        assert_eq!(
            crate::algorithms::canonicalize(&uf1.labels()),
            crate::algorithms::canonicalize(&uf2.labels())
        );
    }
}
