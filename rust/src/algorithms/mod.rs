//! Graph algorithms used by the evaluation (§5.3):
//!
//! * [`jtcc`] — Jayanti–Tarjan concurrent union-find WCC: one pass over
//!   edges, each edge processed independently — the streaming workload the
//!   paper pairs with ParaGrapher's partial loading (use cases B/D).
//! * [`afforest`] — the GAPBS-side baseline (Afforest-style subgraph
//!   sampling + final sweep), run after a *full* load.
//! * [`label_prop`] — label-propagation WCC over fixed-shape edge blocks,
//!   the consumer of the XLA/Pallas `wcc_step` executable.
//! * [`bfs`] — breadth-first search (use case A's repeated-access pattern
//!   and the ground-truth oracle for component tests).
//! * [`partitioned`] — interleaved ports of BFS / WCC / Afforest that
//!   consume [`PartitionStream`](crate::partition::PartitionStream)s, so
//!   computation runs while later partitions load.

pub mod afforest;
pub mod bfs;
pub mod jtcc;
pub mod label_prop;
pub mod partitioned;

use crate::graph::VertexId;

/// Count distinct components from a per-vertex representative/label array.
pub fn count_components(labels: &[VertexId]) -> usize {
    let mut sorted: Vec<VertexId> = labels.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    sorted.len()
}

/// Normalize labels so each component is named by its smallest member
/// (makes algorithm outputs comparable).
pub fn canonicalize(labels: &[VertexId]) -> Vec<VertexId> {
    use std::collections::HashMap;
    let mut min_of: HashMap<VertexId, VertexId> = HashMap::new();
    for (v, &l) in labels.iter().enumerate() {
        let e = min_of.entry(l).or_insert(v as VertexId);
        *e = (*e).min(v as VertexId);
    }
    labels.iter().map(|l| min_of[l]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn component_counting() {
        assert_eq!(count_components(&[0, 0, 2, 2, 4]), 3);
        assert_eq!(count_components(&[]), 0);
    }

    #[test]
    fn canonical_labels() {
        // Vertices {0,1} share label 7; {2} has 9.
        let canon = canonicalize(&[7, 7, 9]);
        assert_eq!(canon, vec![0, 0, 2]);
    }
}
