//! Algorithm ports that run *while* later partitions load — the
//! interleaving the paper's headline 5.2× end-to-end speedup comes from.
//!
//! Each port pulls [`LoadedPartition`]s from one or more
//! [`PartitionStream`]s with `consumers` threads draining the same stream
//! (work-stealing hand-off), so computation on already-staged partitions
//! overlaps the decode of later ones. Algorithms needing several passes
//! (label propagation rounds, BFS levels) re-open a fresh stream per pass
//! through the `open` factory — every pass interleaves again.
//!
//! Equivalence contracts (asserted in `tests/partition_tests.rs`):
//!
//! * [`wcc_jtcc_partitioned`] equals the full-load JT-CC labels — union
//!   results are edge-order invariant.
//! * [`wcc_label_prop_partitioned`] equals the canonicalized full-load
//!   [`label_prop`](super::label_prop) labels — min-label propagation
//!   converges to the per-component minimum regardless of schedule.
//! * [`bfs_partitioned`] equals [`bfs_distances`](super::bfs) — it is the
//!   level-synchronous edge-centric formulation, one streamed pass per
//!   level.
//! * [`afforest_partitioned`] equals the full-load Afforest on
//!   symmetrized inputs for the same seed: phase 1 links the same edge
//!   set, so the sampled giant component matches, and Afforest's
//!   correctness argument is schedule-independent from there.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};

use anyhow::{bail, Result};

use super::afforest::{SAMPLE_NEIGHBORS, SAMPLE_PROBES};
use super::jtcc::JtUnionFind;
use crate::graph::VertexId;
use crate::partition::{LoadedPartition, PartitionStream};

/// Drain `stream` with `consumers` threads, applying `f` to every
/// delivered partition. Returns the first error (decode failures poison
/// the stream; `f` errors cancel it).
pub fn for_each_partition(
    stream: &PartitionStream,
    consumers: usize,
    f: impl Fn(&LoadedPartition) -> Result<()> + Sync,
) -> Result<()> {
    let consumers = consumers.max(1);
    let failed: std::sync::Mutex<Option<anyhow::Error>> = std::sync::Mutex::new(None);
    std::thread::scope(|s| {
        for _ in 0..consumers {
            s.spawn(|| {
                loop {
                    match stream.next() {
                        Ok(Some(p)) => {
                            if let Err(e) = f(&p) {
                                failed.lock().expect("failed lock").get_or_insert(e);
                                stream.cancel();
                                break;
                            }
                        }
                        Ok(None) => break,
                        Err(e) => {
                            failed.lock().expect("failed lock").get_or_insert(e);
                            break;
                        }
                    }
                }
            });
        }
    });
    match failed.into_inner().expect("failed lock").take() {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Streaming JT-CC over one partitioned pass: every edge unioned exactly
/// once, by whichever consumer pulled its partition. Works with any plan
/// kind (1D, 2D tiles, COO splits — each covers the edges exactly once).
/// Returns canonical labels, equal to a full-load JT-CC run.
pub fn wcc_jtcc_partitioned(
    open: impl FnOnce() -> Result<PartitionStream>,
    num_vertices: usize,
    consumers: usize,
    seed: u64,
) -> Result<Vec<VertexId>> {
    let stream = open()?;
    let uf = JtUnionFind::new(num_vertices, seed);
    for_each_partition(&stream, consumers, |p| {
        for (s, d) in p.iter_edges() {
            uf.union(s, d);
        }
        Ok(())
    })?;
    Ok(super::canonicalize(&uf.labels()))
}

/// Min-label propagation WCC over repeated partitioned passes: each round
/// streams every partition once (interleaved with loading), atomically
/// lowering both endpoints of every edge; rounds repeat until a fixpoint.
/// Converges to the per-component minimum label — the canonicalized
/// result of [`wcc_label_prop`](super::label_prop::wcc_label_prop) — for
/// any schedule and any plan kind.
pub fn wcc_label_prop_partitioned(
    open: impl Fn() -> Result<PartitionStream>,
    num_vertices: usize,
    consumers: usize,
) -> Result<Vec<VertexId>> {
    let labels: Vec<AtomicU32> =
        (0..num_vertices).map(|v| AtomicU32::new(v as u32)).collect();
    // Labels only decrease, and each round either changes something or
    // terminates, so `num_vertices` rounds is a safe bound (typically a
    // handful).
    for _round in 0..num_vertices.max(1) {
        let changed = AtomicBool::new(false);
        let stream = open()?;
        for_each_partition(&stream, consumers, |p| {
            for (s, d) in p.iter_edges() {
                let (s, d) = (s as usize, d as usize);
                let ls = labels[s].load(Ordering::Relaxed);
                let ld = labels[d].load(Ordering::Relaxed);
                let m = ls.min(ld);
                // No short-circuit: both endpoints must be lowered.
                let lowered_s = labels[s].fetch_min(m, Ordering::Relaxed) > m;
                let lowered_d = labels[d].fetch_min(m, Ordering::Relaxed) > m;
                if lowered_s || lowered_d {
                    changed.store(true, Ordering::Relaxed);
                }
            }
            Ok(())
        })?;
        if !changed.load(Ordering::Relaxed) {
            break;
        }
    }
    let raw: Vec<VertexId> = labels.iter().map(|l| l.load(Ordering::Relaxed)).collect();
    Ok(super::canonicalize(&raw))
}

/// Level-synchronous edge-centric BFS: one partitioned pass per frontier
/// level, relaxing edges whose source sits on the current frontier.
/// Produces exactly the distances of
/// [`bfs_distances`](super::bfs::bfs_distances) (directed semantics).
pub fn bfs_partitioned(
    open: impl Fn() -> Result<PartitionStream>,
    num_vertices: usize,
    consumers: usize,
    source: VertexId,
) -> Result<Vec<u32>> {
    if source as usize >= num_vertices {
        bail!("BFS source {source} out of range (n={num_vertices})");
    }
    let dist: Vec<AtomicU32> =
        (0..num_vertices).map(|_| AtomicU32::new(u32::MAX)).collect();
    dist[source as usize].store(0, Ordering::Relaxed);
    for level in 0.. {
        let advanced = AtomicBool::new(false);
        let stream = open()?;
        for_each_partition(&stream, consumers, |p| {
            for (s, d) in p.iter_edges() {
                if dist[s as usize].load(Ordering::Relaxed) == level
                    && dist[d as usize]
                        .compare_exchange(
                            u32::MAX,
                            level + 1,
                            Ordering::Relaxed,
                            Ordering::Relaxed,
                        )
                        .is_ok()
                {
                    advanced.store(true, Ordering::Relaxed);
                }
            }
            Ok(())
        })?;
        if !advanced.load(Ordering::Relaxed) {
            break;
        }
    }
    Ok(dist.iter().map(|d| d.load(Ordering::Relaxed)).collect())
}

/// Afforest over partitioned passes (requires a *1D CSX* stream factory:
/// the phases take/skip the first [`SAMPLE_NEIGHBORS`] entries of each
/// vertex's complete row, which only vertex-aligned partitions deliver).
///
/// Phase 1 streams the graph linking each row's first neighbors; phase 2
/// samples the emerging giant component (same seeded probes as the
/// full-load run); phase 3 streams again, finishing rows outside the
/// giant. Canonical labels equal the full-load
/// [`afforest`](super::afforest::afforest) for the same seed on
/// symmetrized inputs.
pub fn afforest_partitioned(
    open: impl Fn() -> Result<PartitionStream>,
    num_vertices: usize,
    consumers: usize,
    seed: u64,
) -> Result<Vec<VertexId>> {
    let uf = JtUnionFind::new(num_vertices, seed);

    // Phase 1: link the first k neighbors of every vertex, interleaved.
    // The take/skip semantics need *complete rows*: reject 2D tiles
    // (filtered targets) immediately, and COO splits (a row cut across
    // partitions appears in several of them) by the row count below —
    // erroring beats silently dropping up to SAMPLE_NEIGHBORS edges of
    // every split row.
    let rows_seen = std::sync::atomic::AtomicUsize::new(0);
    let stream = open()?;
    for_each_partition(&stream, consumers, |p| {
        if p.part.targets.start != 0 || p.part.targets.end != num_vertices {
            bail!("afforest_partitioned requires a 1D CSX plan (tile has filtered targets)");
        }
        rows_seen.fetch_add(p.block.num_vertices(), Ordering::Relaxed);
        for i in 0..p.block.num_vertices() {
            let v = (p.block.first_vertex + i) as VertexId;
            for &u in p.block.neighbors(i).iter().take(SAMPLE_NEIGHBORS) {
                uf.union(v, u);
            }
        }
        Ok(())
    })?;
    if rows_seen.load(Ordering::Relaxed) != num_vertices {
        bail!(
            "afforest_partitioned requires a 1D CSX plan: saw {} rows for {} vertices \
             (COO splits cut rows across partitions)",
            rows_seen.load(Ordering::Relaxed),
            num_vertices
        );
    }

    // Phase 2: sample to find the most common component (identical probe
    // sequence to the full-load run — the phase-1 forest is edge-set
    // determined, so the estimate matches).
    let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(seed ^ 0xAFF0);
    let mut counts: HashMap<VertexId, usize> = HashMap::new();
    if num_vertices > 0 {
        for _ in 0..SAMPLE_PROBES {
            let v = rng.next_below(num_vertices as u64) as VertexId;
            *counts.entry(uf.find(v)).or_insert(0) += 1;
        }
    }
    let giant = counts.into_iter().max_by_key(|&(_, c)| c).map(|(r, _)| r);

    // Phase 3: finish remaining edges of rows outside the giant.
    let stream = open()?;
    for_each_partition(&stream, consumers, |p| {
        for i in 0..p.block.num_vertices() {
            let v = (p.block.first_vertex + i) as VertexId;
            if Some(uf.find(v)) == giant {
                continue;
            }
            for &u in p.block.neighbors(i).iter().skip(SAMPLE_NEIGHBORS) {
                uf.union(v, u);
            }
        }
        Ok(())
    })?;
    Ok(super::canonicalize(&uf.labels()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::VertexRange;
    use crate::formats::webgraph::DecodedBlock;
    use crate::graph::CsrGraph;
    use crate::partition::stream::StreamShared;
    use crate::partition::Partition;
    use std::sync::Arc;

    /// In-memory stand-in stream: 1D partitions cut from a CsrGraph,
    /// produced by a plain thread (no coordinator needed for unit tests).
    fn csr_stream(g: &CsrGraph, parts: usize) -> PartitionStream {
        let n = g.num_vertices();
        let shared = StreamShared::new(parts, 2);
        let bounds: Vec<usize> = (0..=parts).map(|k| n * k / parts).collect();
        let blocks: Vec<(usize, usize, DecodedBlock)> = bounds
            .windows(2)
            .map(|w| {
                let (lo, hi) = (w[0], w[1]);
                let base = g.offsets[lo];
                (
                    lo,
                    hi,
                    DecodedBlock {
                        first_vertex: lo,
                        offsets: g.offsets[lo..=hi].iter().map(|o| o - base).collect(),
                        edges: g.edges[base as usize..g.offsets[hi] as usize].to_vec(),
                    },
                )
            })
            .collect();
        let spans: Vec<(u64, u64)> =
            bounds.windows(2).map(|w| (g.offsets[w[0]], g.offsets[w[1]])).collect();
        let shared2 = Arc::clone(&shared);
        let producer = std::thread::spawn(move || {
            for (index, (lo, hi, block)) in blocks.into_iter().enumerate() {
                if !shared2.wait_for_window() {
                    break;
                }
                shared2.push(crate::partition::LoadedPartition {
                    part: Partition {
                        index,
                        vertices: VertexRange::new(lo, hi),
                        edge_span: spans[index],
                        targets: VertexRange::new(0, n),
                    },
                    block,
                });
            }
            shared2.finish_producing();
        });
        PartitionStream::new(shared, producer)
    }

    #[test]
    fn partitioned_wcc_matches_oracle() {
        let g = crate::graph::generators::barabasi_albert(600, 4, 9);
        let truth = crate::algorithms::canonicalize(&crate::algorithms::bfs::wcc_by_bfs(&g));
        // JT-CC: same components (labels are canonical minima in both).
        let jt = wcc_jtcc_partitioned(|| Ok(csr_stream(&g, 7)), g.num_vertices(), 2, 5).unwrap();
        assert_eq!(
            crate::algorithms::count_components(&jt),
            crate::algorithms::count_components(&truth)
        );
        // Label prop converges to per-component minimum = canonical BFS
        // labels on the undirected view... but our edges are directed here:
        // compare against the directed full-load label-prop instead.
        let full = crate::algorithms::label_prop::wcc_label_prop(
            &g,
            crate::algorithms::label_prop::StepEngine::Native,
        )
        .unwrap();
        let part =
            wcc_label_prop_partitioned(|| Ok(csr_stream(&g, 5)), g.num_vertices(), 2).unwrap();
        assert_eq!(part, full);
    }

    #[test]
    fn partitioned_bfs_matches_oracle() {
        let g = crate::graph::generators::rmat(8, 6, 3);
        for src in [0u32, 17, 200] {
            let truth = crate::algorithms::bfs::bfs_distances(&g, src);
            let got =
                bfs_partitioned(|| Ok(csr_stream(&g, 6)), g.num_vertices(), 2, src).unwrap();
            assert_eq!(got, truth, "source {src}");
        }
    }

    #[test]
    fn partitioned_afforest_matches_oracle() {
        let g = crate::graph::generators::rmat(8, 4, 11).symmetrize();
        let truth = crate::algorithms::afforest::afforest(&g, 7);
        let got =
            afforest_partitioned(|| Ok(csr_stream(&g, 5)), g.num_vertices(), 2, 7).unwrap();
        assert_eq!(
            crate::algorithms::count_components(&got),
            crate::algorithms::count_components(&truth)
        );
    }
}
