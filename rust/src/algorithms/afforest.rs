//! Afforest-style WCC (Sutton, Ben-Nun, Barak 2018) — the algorithm GAPBS
//! ships and the paper's baseline in Fig. 6.
//!
//! Afforest's insight: link a small number of neighbors per vertex first
//! ("subgraph sampling"), find the largest emerging component, then only
//! process remaining edges of vertices *outside* it. It needs random access
//! to the whole CSR — i.e. a full load first — which is exactly the
//! contrast with JT-CC + partial loading the paper draws.

use std::collections::HashMap;

use anyhow::Result;

use crate::formats::GraphSource;
use crate::graph::{CsrGraph, VertexId};

use super::jtcc::JtUnionFind;

/// Number of neighbors linked in the sampling phase (GAPBS default: 2).
/// Shared with the partitioned port so the two stay bit-compatible.
pub(crate) const SAMPLE_NEIGHBORS: usize = 2;
/// Vertices probed to estimate the largest component (GAPBS: 1024).
pub(crate) const SAMPLE_PROBES: usize = 1024;

/// Run Afforest over a fully-loaded CSR. Returns canonical labels.
pub fn afforest(g: &CsrGraph, seed: u64) -> Vec<VertexId> {
    let n = g.num_vertices();
    let uf = JtUnionFind::new(n, seed);

    // Phase 1: link the first k neighbors of every vertex.
    for v in 0..n as u32 {
        for &u in g.neighbors(v).iter().take(SAMPLE_NEIGHBORS) {
            uf.union(v, u);
        }
    }

    // Phase 2: sample to find the most common component.
    let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(seed ^ 0xAFF0);
    let mut counts: HashMap<VertexId, usize> = HashMap::new();
    if n > 0 {
        for _ in 0..SAMPLE_PROBES {
            let v = rng.next_below(n as u64) as VertexId;
            *counts.entry(uf.find(v)).or_insert(0) += 1;
        }
    }
    let giant = counts.into_iter().max_by_key(|&(_, c)| c).map(|(r, _)| r);

    // Phase 3: finish remaining edges, skipping vertices already absorbed
    // by the giant component.
    for v in 0..n as u32 {
        if Some(uf.find(v)) == giant {
            continue;
        }
        for &u in g.neighbors(v).iter().skip(SAMPLE_NEIGHBORS) {
            uf.union(v, u);
        }
    }
    super::canonicalize(&uf.labels())
}

/// Afforest pulling neighborhoods through [`GraphSource::successors`]
/// instead of a fully-loaded CSR — the out-of-core variant (§4.1 D): the
/// graph is decoded block-by-block on demand and never materialized whole.
/// Deterministic for a fixed `seed` and identical to [`afforest`] on the
/// same graph.
pub fn afforest_on(src: &dyn GraphSource, seed: u64) -> Result<Vec<VertexId>> {
    let n = src.num_vertices();
    let uf = JtUnionFind::new(n, seed);

    // Phase 1: link the first k neighbors of every vertex.
    for v in 0..n as u32 {
        for &u in src.successors(v as usize)?.iter().take(SAMPLE_NEIGHBORS) {
            uf.union(v, u);
        }
    }

    // Phase 2: sample to find the most common component.
    let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(seed ^ 0xAFF0);
    let mut counts: HashMap<VertexId, usize> = HashMap::new();
    if n > 0 {
        for _ in 0..SAMPLE_PROBES {
            let v = rng.next_below(n as u64) as VertexId;
            *counts.entry(uf.find(v)).or_insert(0) += 1;
        }
    }
    let giant = counts.into_iter().max_by_key(|&(_, c)| c).map(|(r, _)| r);

    // Phase 3: finish remaining edges, skipping vertices already absorbed
    // by the giant component. Re-pulling the neighborhood here is a cache
    // hit when the decoded-block cache is sized sanely.
    for v in 0..n as u32 {
        if Some(uf.find(v)) == giant {
            continue;
        }
        for &u in src.successors(v as usize)?.iter().skip(SAMPLE_NEIGHBORS) {
            uf.union(v, u);
        }
    }
    Ok(super::canonicalize(&uf.labels()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::bfs::wcc_by_bfs;
    use crate::algorithms::count_components;
    use crate::graph::generators;

    #[test]
    fn matches_bfs_on_symmetric_graphs() {
        // Afforest (like GAPBS's) assumes a symmetrized input.
        for (i, g) in [
            generators::road_lattice(12, 12, 0, 1),
            generators::barabasi_albert(500, 3, 2),
            generators::erdos_renyi(400, 300, 3).symmetrize(),
            generators::rmat(7, 2, 4).symmetrize(),
        ]
        .into_iter()
        .enumerate()
        {
            let ours = afforest(&g, 7);
            let truth = wcc_by_bfs(&g);
            assert_eq!(
                count_components(&ours),
                count_components(&truth),
                "graph {i}"
            );
        }
    }

    #[test]
    fn empty_and_singleton() {
        let empty = CsrGraph::from_edges(0, &[]);
        assert!(afforest(&empty, 1).is_empty());
        let lone = CsrGraph::from_edges(3, &[]);
        assert_eq!(count_components(&afforest(&lone, 1)), 3);
        assert!(afforest_on(&empty, 1).unwrap().is_empty());
        assert_eq!(count_components(&afforest_on(&lone, 1).unwrap()), 3);
    }

    #[test]
    fn source_pull_matches_full_load() {
        for (i, g) in [
            generators::road_lattice(10, 10, 0, 1),
            generators::barabasi_albert(400, 3, 5),
            generators::rmat(7, 2, 9).symmetrize(),
        ]
        .into_iter()
        .enumerate()
        {
            assert_eq!(afforest_on(&g, 7).unwrap(), afforest(&g, 7), "graph {i}");
        }
    }
}
