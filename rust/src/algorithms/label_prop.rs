//! Label-propagation WCC over fixed-shape edge blocks — the consumer of
//! the AOT-compiled `wcc_step` executable (L2 jax + L1 Pallas).
//!
//! Each iteration streams the edge list in [`WCC_BLOCK`]-sized chunks
//! through the XLA step (or a native step for comparison) until labels
//! stop changing. Works only for graphs with ≤ `WCC_BLOCK` vertices — the
//! fixed-shape AOT contract; larger graphs use JT-CC.

use anyhow::{bail, Result};

use crate::graph::{CsrGraph, VertexId};
use crate::runtime::{ArtifactSet, WCC_BLOCK};

/// One native label-propagation step (oracle for the XLA path).
pub fn native_step(labels: &mut [i32], src: &[i32], dst: &[i32]) {
    for (&s, &d) in src.iter().zip(dst) {
        let m = labels[s as usize].min(labels[d as usize]);
        labels[s as usize] = m;
        labels[d as usize] = m;
    }
}

/// Engine choice for [`wcc_label_prop`].
pub enum StepEngine<'a> {
    Native,
    Xla(&'a ArtifactSet),
}

/// Run label-propagation WCC to convergence. Returns canonical labels.
pub fn wcc_label_prop(g: &CsrGraph, engine: StepEngine<'_>) -> Result<Vec<VertexId>> {
    let n = g.num_vertices();
    if n > WCC_BLOCK {
        bail!("label-prop WCC supports up to {WCC_BLOCK} vertices, graph has {n}");
    }
    // Pack edges into fixed-shape blocks padded with (0,0) self-loops.
    let edges: Vec<(VertexId, VertexId)> = g.iter_edges().collect();
    let mut blocks: Vec<(Vec<i32>, Vec<i32>)> = Vec::new();
    for chunk in edges.chunks(WCC_BLOCK) {
        let mut src = vec![0i32; WCC_BLOCK];
        let mut dst = vec![0i32; WCC_BLOCK];
        for (i, &(s, d)) in chunk.iter().enumerate() {
            src[i] = s as i32;
            dst[i] = d as i32;
        }
        blocks.push((src, dst));
    }

    let mut labels: Vec<i32> = (0..WCC_BLOCK as i32).collect();
    // Convergence bound: labels strictly decrease; n iterations suffice
    // for any graph (diameter bound); typically far fewer.
    for _ in 0..n.max(1) {
        let before = labels.clone();
        for (src, dst) in &blocks {
            match engine {
                StepEngine::Native => native_step(&mut labels, src, dst),
                StepEngine::Xla(arts) => {
                    labels = arts.wcc_step_block(&labels, src, dst)?;
                }
            }
        }
        if labels == before {
            break;
        }
    }
    Ok(crate::algorithms::canonicalize(
        &labels[..n].iter().map(|&l| l as VertexId).collect::<Vec<_>>(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::bfs::wcc_by_bfs;
    use crate::algorithms::count_components;
    use crate::graph::generators;

    #[test]
    fn native_matches_bfs() {
        for g in [
            generators::road_lattice(10, 10, 0, 1),
            generators::erdos_renyi(200, 150, 2).symmetrize(),
            generators::barabasi_albert(300, 2, 3),
        ] {
            let ours = wcc_label_prop(&g, StepEngine::Native).unwrap();
            let truth = wcc_by_bfs(&g);
            assert_eq!(count_components(&ours), count_components(&truth));
        }
    }

    #[test]
    fn too_large_rejected() {
        // A graph with more vertices than the AOT block must error cleanly.
        let g = crate::graph::CsrGraph::from_edges(WCC_BLOCK + 1, &[]);
        assert!(wcc_label_prop(&g, StepEngine::Native).is_err());
    }

    #[test]
    fn xla_matches_native_when_artifacts_present() {
        let dir = ArtifactSet::default_dir();
        let Ok(arts) = ArtifactSet::load(&dir) else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let g = generators::erdos_renyi(400, 500, 9).symmetrize();
        let native = wcc_label_prop(&g, StepEngine::Native).unwrap();
        let xla = wcc_label_prop(&g, StepEngine::Xla(&arts)).unwrap();
        assert_eq!(native, xla);
    }
}
