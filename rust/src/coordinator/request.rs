//! Read requests and the user-facing edge-block views.
//!
//! Two request families share these types: the callback-driven block
//! requests (`csx_get_subgraph` / `coo_get_edges`, tracked by
//! [`ReadRequest`]) and the pull-driven partitioned requests
//! (`{csx,coo}_get_partitions`, tracked by
//! [`PartitionStream`](crate::partition::PartitionStream) — same
//! [`VertexRange`] vocabulary, consumer-pull instead of callback-push).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::graph::{VertexId, Weight};
use crate::obs::{self, Histo};

/// What the user asks for: a consecutive vertex range (CSX view) whose
/// edges are delivered in blocks. `whole()` requests the entire graph
/// (use case A); sub-ranges serve use cases B/C/D.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VertexRange {
    pub start: usize,
    /// Exclusive.
    pub end: usize,
}

impl VertexRange {
    pub fn new(start: usize, end: usize) -> Self {
        Self { start, end }
    }

    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.end <= self.start
    }

    /// Does the range contain vertex `v`?
    pub fn contains(&self, v: usize) -> bool {
        v >= self.start && v < self.end
    }
}

/// A delivered block of edges: a borrowed CSR slice over library buffers
/// (§4.2: "storing data in reusable buffers allocated and managed by the
/// library ... passed to the user").
#[derive(Debug)]
pub struct EdgeBlock<'a> {
    pub buffer_id: usize,
    pub start_vertex: usize,
    pub end_vertex: usize,
    /// Global index of the first edge in this block.
    pub start_edge: u64,
    /// Local offsets: `end_vertex - start_vertex + 1` entries from 0.
    pub offsets: &'a [u64],
    pub edges: &'a [VertexId],
    /// Present for WG404-style edge-weighted graphs.
    pub weights: Option<&'a [Weight]>,
}

impl<'a> EdgeBlock<'a> {
    pub fn num_vertices(&self) -> usize {
        self.end_vertex - self.start_vertex
    }

    pub fn num_edges(&self) -> u64 {
        *self.offsets.last().unwrap_or(&0)
    }

    /// Successors of global vertex `v` (must lie in the block).
    pub fn neighbors(&self, v: usize) -> &'a [VertexId] {
        debug_assert!(v >= self.start_vertex && v < self.end_vertex);
        let i = v - self.start_vertex;
        &self.edges[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }

    /// Iterate `(src, dst)` pairs of the block.
    pub fn iter_edges(&self) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
        (0..self.num_vertices()).flat_map(move |i| {
            let v = (self.start_vertex + i) as VertexId;
            self.edges[self.offsets[i] as usize..self.offsets[i + 1] as usize]
                .iter()
                .map(move |&d| (v, d))
        })
    }
}

/// Progress/completion tracking for one asynchronous read request — the
/// handle `csx_get_subgraph` returns. `get_set_options`-style queries
/// ("is loading completed, how many edges have been read", §4.3) map to
/// [`Self::edges_delivered`] / [`Self::is_complete`].
#[derive(Debug)]
pub struct ReadRequest {
    total_blocks: u64,
    blocks_done: AtomicU64,
    edges_delivered: AtomicU64,
    failed: AtomicBool,
    error: Mutex<Option<String>>,
    /// Typed classification of the first failure, when the producer had
    /// one (`Faulted`, `Corrupt`, `Closed`, …) — the serving layer routes
    /// on this instead of string-scraping `error`.
    error_kind: Mutex<Option<crate::coordinator::PgError>>,
    done_cv: Condvar,
    done_mx: Mutex<()>,
    cancelled: AtomicBool,
    issued_at: Instant,
    /// End-to-end latency sink: taken exactly once, by whichever block
    /// completion crosses the `total_blocks` threshold. Carries the
    /// histogram handle plus the request-kind span name.
    completion_obs: Mutex<Option<(Histo, &'static str)>>,
}

impl ReadRequest {
    pub fn new(total_blocks: u64) -> Self {
        Self {
            total_blocks,
            blocks_done: AtomicU64::new(0),
            edges_delivered: AtomicU64::new(0),
            failed: AtomicBool::new(false),
            error: Mutex::new(None),
            error_kind: Mutex::new(None),
            done_cv: Condvar::new(),
            done_mx: Mutex::new(()),
            cancelled: AtomicBool::new(false),
            issued_at: Instant::now(),
            completion_obs: Mutex::new(None),
        }
    }

    /// Arm end-to-end latency recording: when the final block lands,
    /// `issued → last delivery` is recorded into `hist` and emitted as a
    /// `request`-category span named `kind`. Called once at issue time.
    pub(crate) fn set_completion_obs(&self, hist: Histo, kind: &'static str) {
        *crate::coordinator::lock_recover(&self.completion_obs) = Some((hist, kind));
    }

    pub fn total_blocks(&self) -> u64 {
        self.total_blocks
    }

    pub fn blocks_done(&self) -> u64 {
        self.blocks_done.load(Ordering::Acquire)
    }

    pub fn edges_delivered(&self) -> u64 {
        self.edges_delivered.load(Ordering::Acquire)
    }

    pub fn is_complete(&self) -> bool {
        self.blocks_done() >= self.total_blocks
    }

    pub fn is_failed(&self) -> bool {
        self.failed.load(Ordering::Acquire)
    }

    pub fn error(&self) -> Option<String> {
        // Poison recovery: an `Option<String>` is never left torn by a
        // panicking writer, and the error message must stay readable even
        // after a dispatcher died — it is the request's failure report.
        crate::coordinator::lock_recover(&self.error).clone()
    }

    /// Typed class of the recorded failure, when the producer preserved
    /// one via [`record_failure_typed`](Self::record_failure_typed);
    /// `None` for untyped failures.
    pub fn error_kind(&self) -> Option<crate::coordinator::PgError> {
        crate::coordinator::lock_recover(&self.error_kind).clone()
    }

    /// Cancel: outstanding blocks may still complete, but unscheduled ones
    /// are dropped (counted as done so waiters wake).
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Acquire)
    }

    /// Producer side: record one completed block of `edges` edges.
    pub fn record_block(&self, edges: u64) {
        self.edges_delivered.fetch_add(edges, Ordering::AcqRel);
        let done = self.blocks_done.fetch_add(1, Ordering::AcqRel) + 1;
        if done >= self.total_blocks {
            // Exactly one completion crosses the threshold; `take()` keeps
            // over-completion (cancel racing the last block) from
            // double-recording.
            if let Some((hist, kind)) =
                crate::coordinator::lock_recover(&self.completion_obs).take()
            {
                let dur = self.issued_at.elapsed();
                hist.record_duration(dur);
                obs::tracer().record("request", kind, self.issued_at, dur, 0, self.total_blocks);
            }
            // The mutex only orders the notify against `wait`'s check —
            // poison (a waiter that panicked between check and park)
            // must not stop the completion signal.
            let _g = crate::coordinator::lock_recover(&self.done_mx);
            self.done_cv.notify_all();
        }
    }

    /// Producer side: record a failed block.
    pub fn record_failure(&self, message: String) {
        {
            let mut e = crate::coordinator::lock_recover(&self.error);
            e.get_or_insert(message);
        }
        self.failed.store(true, Ordering::Release);
        self.record_block(0);
    }

    /// [`record_failure`](Self::record_failure), preserving the typed
    /// [`PgError`](crate::coordinator::PgError) class when `err` carries
    /// one — blocking callers re-raise it instead of a flattened string.
    pub fn record_failure_typed(&self, err: &anyhow::Error) {
        if let Some(pg) = err.downcast_ref::<crate::coordinator::PgError>() {
            let _ = crate::coordinator::lock_recover(&self.error_kind).get_or_insert(pg.clone());
        }
        self.record_failure(format!("{err:#}"));
    }

    /// Block until all blocks are done (the blocking-mode primitive).
    pub fn wait(&self) {
        // The guarded state is the atomic counters, not the mutex payload
        // `()`, so a poisoned lock carries no torn data — recover and keep
        // waiting; `record_failure` already marked the request failed.
        let mut g = crate::coordinator::lock_recover(&self.done_mx);
        while !self.is_complete() {
            let (ng, _timeout) = self
                .done_cv
                .wait_timeout(g, std::time::Duration::from_millis(50))
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            g = ng;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn progress_accounting() {
        let r = ReadRequest::new(3);
        assert!(!r.is_complete());
        r.record_block(10);
        r.record_block(20);
        assert_eq!(r.edges_delivered(), 30);
        assert_eq!(r.blocks_done(), 2);
        assert!(!r.is_complete());
        r.record_block(5);
        assert!(r.is_complete());
        assert_eq!(r.edges_delivered(), 35);
    }

    #[test]
    fn wait_unblocks_on_completion() {
        let r = Arc::new(ReadRequest::new(2));
        let r2 = Arc::clone(&r);
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            r2.record_block(1);
            r2.record_block(1);
        });
        r.wait();
        assert!(r.is_complete());
        t.join().unwrap();
    }

    #[test]
    fn failure_recorded() {
        let r = ReadRequest::new(1);
        r.record_failure("boom".into());
        assert!(r.is_failed());
        assert!(r.is_complete());
        assert_eq!(r.error().as_deref(), Some("boom"));
        assert!(r.error_kind().is_none(), "untyped failure has no kind");
    }

    #[test]
    fn typed_failure_class_preserved() {
        use crate::coordinator::PgError;
        let r = ReadRequest::new(1);
        let e = anyhow::Error::from(PgError::Faulted("injected EIO".into()));
        r.record_failure_typed(&e);
        assert!(r.is_failed());
        assert!(matches!(r.error_kind(), Some(PgError::Faulted(_))));
        assert!(r.error().unwrap().contains("injected EIO"));
    }

    #[test]
    fn zero_block_request_complete_immediately() {
        let r = ReadRequest::new(0);
        assert!(r.is_complete());
        r.wait(); // must not hang
    }

    #[test]
    fn edge_block_views() {
        let offsets = [0u64, 2, 3];
        let edges = [5u32, 7, 1];
        let blk = EdgeBlock {
            buffer_id: 0,
            start_vertex: 10,
            end_vertex: 12,
            start_edge: 100,
            offsets: &offsets,
            edges: &edges,
            weights: None,
        };
        assert_eq!(blk.num_vertices(), 2);
        assert_eq!(blk.num_edges(), 3);
        assert_eq!(blk.neighbors(10), &[5, 7]);
        assert_eq!(blk.neighbors(11), &[1]);
        let pairs: Vec<_> = blk.iter_edges().collect();
        assert_eq!(pairs, vec![(10, 5), (10, 7), (11, 1)]);
    }
}
