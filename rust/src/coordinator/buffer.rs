//! The 5-status shared-buffer protocol (§4.4).
//!
//! ParaGrapher's C front-end and Java back-end communicate through shared
//! buffers whose `status` field is written by exactly one side per
//! transition and only observed by the other:
//!
//! ```text
//!  C_IDLE ──(consumer sets metadata)──▶ C_REQUESTED
//!  C_REQUESTED ──(producer claims)────▶ J_READING
//!  J_READING ──(producer fills)───────▶ J_READ_COMPLETED
//!  J_READ_COMPLETED ──(consumer)──────▶ C_USER_ACCESS
//!  C_USER_ACCESS ──(user releases)────▶ C_IDLE
//! ```
//!
//! In our Rust coordinator the "C side" is the request manager and the
//! "Java side" is the decoder worker pool; the protocol is kept verbatim —
//! including the property the paper argues correctness from: each status
//! value has a unique writer, and the writer orders its data writes before
//! the status store (Release) while observers read it with Acquire. One
//! deliberate exception: the requester *claims* C_IDLE -> C_REQUESTED by
//! CAS first and writes the block metadata after (see
//! [`BufferPool::request_idle`]) — the claim makes it the buffer's sole
//! owner, and the decode worker receives the metadata by value through the
//! job queue, so nothing observes `Buffer::meta` through the status flag.
//!
//! Scheduling over the statuses is *event-driven*, not polled: the pool is
//! split into shards scanned from a rotating hint (so concurrent requests
//! don't contend on buffer 0), and a requester that finds no idle buffer
//! parks on a condvar ([`BufferPool::acquire_idle`]) until a consumer
//! recycles one ([`BufferPool::recycle`]) or the pool closes
//! ([`BufferPool::close`]). Request latency therefore tracks actual buffer
//! turnaround instead of a tuned poll constant.
//!
//! Two producers drive the protocol: the block request manager (user
//! callbacks consume at `C_USER_ACCESS`, the full cycle) and the
//! partition manager, which uses a claim as its decode-concurrency
//! *token* only (`C_IDLE → C_REQUESTED → J_READING → C_IDLE`, the
//! failure-path transitions): partitioned consumers own their decoded
//! data outright, so the buffer recycles the moment the decode lands.
//! Both park on the same condvar, so the pool is also the cross-request
//! fairness point.
//!
//! **Zero-copy delivery — who owns `data` at each status.** On the block
//! path the producer does not decode into a scratch block and copy: while
//! the buffer is in `J_READING` the decoder writes *directly* into
//! [`BufferData`]'s vectors through a
//! [`DecodeSink`](crate::formats::webgraph::DecodeSink) — the claim made
//! the producer the buffer's sole owner, so holding the `data` mutex
//! across the decode contends with no one. At `J_READ_COMPLETED` →
//! `C_USER_ACCESS` the consumer borrows the same vectors as the user's
//! [`EdgeBlock`](crate::coordinator::EdgeBlock) views (edge-trimmed COO
//! callbacks *slice* them rather than copy); the recycle back to `C_IDLE`
//! only clears lengths, so the vectors' high-water capacity survives and
//! steady-state blocks decode into warmed, allocation-free storage. A
//! decode that fails mid-block leaves partial `data` behind — harmless,
//! because the failure path recycles straight to `C_IDLE` and the next
//! producer's sink clears before writing; no status ever exposes
//! partially-written data to a reader.

use std::sync::atomic::{AtomicBool, AtomicU8, AtomicUsize, Ordering};
use std::sync::Condvar;
use std::time::Duration;

use crate::graph::{VertexId, Weight};

/// Buffer lifecycle status. Discriminants are stable (used in metrics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum BufferStatus {
    /// Ready to be allocated for reading an edge block.
    CIdle = 0,
    /// Metadata set; producer may start reading.
    CRequested = 1,
    /// Producer worker is decoding into the buffer.
    JReading = 2,
    /// Producer finished; consumer may hand it to the user.
    JReadCompleted = 3,
    /// User owns the buffer until release.
    CUserAccess = 4,
}

impl BufferStatus {
    pub fn from_u8(v: u8) -> BufferStatus {
        match v {
            0 => BufferStatus::CIdle,
            1 => BufferStatus::CRequested,
            2 => BufferStatus::JReading,
            3 => BufferStatus::JReadCompleted,
            4 => BufferStatus::CUserAccess,
            _ => unreachable!("invalid buffer status {v}"),
        }
    }

    /// Legal transitions (enforced in debug builds and by tests).
    pub fn can_transition_to(self, next: BufferStatus) -> bool {
        use BufferStatus::*;
        matches!(
            (self, next),
            (CIdle, CRequested)
                | (CRequested, JReading)
                | (JReading, JReadCompleted)
                | (JReadCompleted, CUserAccess)
                | (CUserAccess, CIdle)
                // Failure/cancel paths: the buffer is returned directly.
                | (JReading, CIdle)
                | (CRequested, CIdle)
                | (JReadCompleted, CIdle)
        )
    }
}

/// Block metadata (§4.4: "the start and end vertex and edges").
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BlockMeta {
    pub start_vertex: usize,
    pub end_vertex: usize,
    pub start_edge: u64,
    pub end_edge: u64,
}

impl BlockMeta {
    pub fn num_edges(&self) -> u64 {
        self.end_edge - self.start_edge
    }

    pub fn num_vertices(&self) -> usize {
        self.end_vertex - self.start_vertex
    }
}

/// One reusable shared buffer.
pub struct Buffer {
    pub id: usize,
    status: AtomicU8,
    /// Filled by the producer side while J_READING; read by the user while
    /// C_USER_ACCESS. The status protocol serializes access.
    pub meta: parking::Mutex<BlockMeta>,
    pub data: parking::Mutex<BufferData>,
}

/// Decoded contents of a buffer (a CSR slice, like `DecodedBlock` but with
/// library-owned reusable storage).
#[derive(Debug, Default)]
pub struct BufferData {
    /// Local offsets (`meta.num_vertices()+1` entries when filled).
    pub offsets: Vec<u64>,
    pub edges: Vec<VertexId>,
    pub weights: Vec<Weight>,
}

impl BufferData {
    pub fn clear(&mut self) {
        self.offsets.clear();
        self.edges.clear();
        self.weights.clear();
    }
}

// Minimal Mutex alias module so the hot path can swap implementations in
// one place (std parking-lot-style crates are unavailable offline).
pub mod parking {
    pub type Mutex<T> = std::sync::Mutex<T>;
}

impl Buffer {
    pub fn new(id: usize) -> Self {
        Self {
            id,
            status: AtomicU8::new(BufferStatus::CIdle as u8),
            meta: parking::Mutex::new(BlockMeta::default()),
            data: parking::Mutex::new(BufferData::default()),
        }
    }

    pub fn status(&self) -> BufferStatus {
        BufferStatus::from_u8(self.status.load(Ordering::Acquire))
    }

    /// Transition the status; panics (debug) on illegal transitions.
    pub fn set_status(&self, next: BufferStatus) {
        let cur = self.status();
        debug_assert!(
            cur.can_transition_to(next),
            "illegal buffer transition {cur:?} -> {next:?}"
        );
        self.status.store(next as u8, Ordering::Release);
    }

    /// CAS-claim: the producer scheduler uses this so two pollers can never
    /// claim the same requested buffer.
    pub fn try_claim(&self, from: BufferStatus, to: BufferStatus) -> bool {
        debug_assert!(from.can_transition_to(to));
        self.status
            .compare_exchange(from as u8, to as u8, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }
}

/// Watchdog re-check period while parked in [`BufferPool::acquire_idle`].
/// Scheduling is notification-driven; this only bounds how long a *lost*
/// wakeup could stall progress if a recycle path ever bypassed the pool.
const ACQUIRE_WATCHDOG: Duration = Duration::from_millis(100);

/// The pool of reusable buffers ("number of buffers" × "buffer size" are
/// the two knobs of §5.5 / Fig. 8), sharded for claim scans and fronted by
/// a condvar so requesters block instead of polling.
pub struct BufferPool {
    buffers: Vec<Buffer>,
    /// Shard `s` covers ids `shard_bounds[s]..shard_bounds[s + 1]`.
    shard_bounds: Vec<usize>,
    /// Rotating start shard for claim scans.
    claim_hint: AtomicUsize,
    /// Parked requesters; recycles and close notify through it.
    idle_mx: parking::Mutex<()>,
    idle_cv: Condvar,
    closed: AtomicBool,
}

impl BufferPool {
    pub fn new(count: usize) -> Self {
        let count = count.max(1);
        let shards = count.min(8);
        let shard_bounds: Vec<usize> = (0..=shards).map(|s| s * count / shards).collect();
        Self {
            buffers: (0..count).map(Buffer::new).collect(),
            shard_bounds,
            claim_hint: AtomicUsize::new(0),
            idle_mx: parking::Mutex::new(()),
            idle_cv: Condvar::new(),
            closed: AtomicBool::new(false),
        }
    }

    pub fn len(&self) -> usize {
        self.buffers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buffers.is_empty()
    }

    /// Number of claim-scan shards.
    pub fn shards(&self) -> usize {
        self.shard_bounds.len() - 1
    }

    pub fn get(&self, id: usize) -> &Buffer {
        &self.buffers[id]
    }

    pub fn iter(&self) -> impl Iterator<Item = &Buffer> {
        self.buffers.iter()
    }

    /// Find and claim an idle buffer (C_IDLE -> C_REQUESTED) without
    /// blocking, setting its metadata. Returns the buffer id.
    ///
    /// The scan starts at a rotating shard so concurrent requesters spread
    /// over the pool instead of all hammering buffer 0. The claim (CAS)
    /// happens *before* the metadata write: once claimed, the requester owns
    /// the buffer exclusively, so the write is race-free — writing metadata
    /// first (as a naive reading of the protocol suggests) would let a
    /// losing claimant overwrite the winner's metadata.
    pub fn request_idle(&self, meta: BlockMeta) -> Option<usize> {
        let shards = self.shards();
        let start = self.claim_hint.fetch_add(1, Ordering::Relaxed) % shards;
        for k in 0..shards {
            let s = (start + k) % shards;
            for b in &self.buffers[self.shard_bounds[s]..self.shard_bounds[s + 1]] {
                if b.try_claim(BufferStatus::CIdle, BufferStatus::CRequested) {
                    // The winner overwrites the metadata wholesale, so a
                    // poisoned lock (panicked prior owner) carries no torn
                    // state worth propagating.
                    *crate::coordinator::lock_recover(&b.meta) = meta;
                    return Some(b.id);
                }
            }
        }
        None
    }

    /// Claim an idle buffer, blocking until one is recycled. Returns `None`
    /// once the pool is [`close`](Self::close)d. This replaces the request
    /// manager's former `poll_interval` sleep loop: the caller parks on the
    /// pool condvar and is woken by the next [`recycle`](Self::recycle).
    pub fn acquire_idle(&self, meta: BlockMeta) -> Option<usize> {
        loop {
            if self.is_closed() {
                return None;
            }
            if let Some(id) = self.request_idle(meta) {
                return Some(id);
            }
            // The payload is `()` — the lock only orders wakeups — so
            // poison (a requester that panicked while parked) is harmless;
            // recovering keeps every later request path alive.
            let guard = crate::coordinator::lock_recover(&self.idle_mx);
            // Re-check while holding the lock: a recycle between the scan
            // above and the wait below must not become a lost wakeup —
            // recyclers notify while holding the same lock.
            if self.is_closed() {
                return None;
            }
            if let Some(id) = self.request_idle(meta) {
                return Some(id);
            }
            let _ = self
                .idle_cv
                .wait_timeout(guard, ACQUIRE_WATCHDOG)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }

    /// Return a buffer to C_IDLE and wake one parked requester. Every
    /// failure/cancel/completion path must recycle through the pool (not
    /// via raw `set_status`) so waiters observe the transition.
    pub fn recycle(&self, id: usize) {
        self.get(id).set_status(BufferStatus::CIdle);
        let _guard = crate::coordinator::lock_recover(&self.idle_mx);
        self.idle_cv.notify_all();
    }

    /// Close the pool: [`acquire_idle`](Self::acquire_idle) returns `None`
    /// for all current and future callers (shutdown path).
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        // Shutdown must always complete — recover poison and wake everyone.
        let _guard = crate::coordinator::lock_recover(&self.idle_mx);
        self.idle_cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }

    /// Count buffers in a given status (metrics / tests).
    pub fn count(&self, status: BufferStatus) -> usize {
        self.buffers.iter().filter(|b| b.status() == status).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legal_cycle() {
        let b = Buffer::new(0);
        assert_eq!(b.status(), BufferStatus::CIdle);
        b.set_status(BufferStatus::CRequested);
        b.set_status(BufferStatus::JReading);
        b.set_status(BufferStatus::JReadCompleted);
        b.set_status(BufferStatus::CUserAccess);
        b.set_status(BufferStatus::CIdle);
    }

    #[test]
    #[should_panic(expected = "illegal buffer transition")]
    fn illegal_transition_panics_in_debug() {
        let b = Buffer::new(0);
        b.set_status(BufferStatus::JReadCompleted);
    }

    #[test]
    fn failure_path_allowed() {
        let b = Buffer::new(0);
        b.set_status(BufferStatus::CRequested);
        b.set_status(BufferStatus::JReading);
        b.set_status(BufferStatus::CIdle); // worker error returns the buffer
    }

    #[test]
    fn claim_is_exclusive() {
        let b = Buffer::new(0);
        b.set_status(BufferStatus::CRequested);
        assert!(b.try_claim(BufferStatus::CRequested, BufferStatus::JReading));
        assert!(!b.try_claim(BufferStatus::CRequested, BufferStatus::JReading));
    }

    #[test]
    fn pool_request_idle_sets_meta() {
        let pool = BufferPool::new(2);
        let meta = BlockMeta { start_vertex: 3, end_vertex: 9, start_edge: 10, end_edge: 99 };
        let id = pool.request_idle(meta).unwrap();
        let b = pool.get(id);
        assert_eq!(b.status(), BufferStatus::CRequested);
        assert_eq!(*b.meta.lock().unwrap(), meta);
        assert_eq!(pool.count(BufferStatus::CIdle), 1);
        // Exhaust the pool.
        assert!(pool.request_idle(meta).is_some());
        assert!(pool.request_idle(meta).is_none(), "no idle buffers left");
    }

    #[test]
    fn transition_table() {
        use BufferStatus::*;
        for s in [CIdle, CRequested, JReading, JReadCompleted, CUserAccess] {
            // No self-loops.
            assert!(!s.can_transition_to(s));
        }
        assert!(CIdle.can_transition_to(CRequested));
        assert!(!CIdle.can_transition_to(JReading));
        assert!(!CUserAccess.can_transition_to(CRequested));
        assert!(CUserAccess.can_transition_to(CIdle));
    }

    #[test]
    fn concurrent_claims_race_safely() {
        let pool = std::sync::Arc::new(BufferPool::new(4));
        let meta = BlockMeta::default();
        let mut handles = Vec::new();
        let claimed = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        for _ in 0..8 {
            let pool = std::sync::Arc::clone(&pool);
            let claimed = std::sync::Arc::clone(&claimed);
            handles.push(std::thread::spawn(move || {
                if let Some(id) = pool.request_idle(meta) {
                    claimed.lock().unwrap().push(id);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut got = claimed.lock().unwrap().clone();
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), claimed.lock().unwrap().len(), "no double-claims");
        assert_eq!(got.len(), 4, "exactly the pool size claimed");
    }

    #[test]
    fn shard_bounds_cover_all_buffers() {
        for count in [1usize, 2, 7, 8, 9, 33] {
            let pool = BufferPool::new(count);
            assert_eq!(pool.len(), count);
            assert!(pool.shards() <= count);
            // Every buffer claimable exactly once through the sharded scan.
            let mut ids: Vec<usize> =
                (0..count).filter_map(|_| pool.request_idle(BlockMeta::default())).collect();
            ids.sort_unstable();
            assert_eq!(ids, (0..count).collect::<Vec<_>>(), "count={count}");
            assert!(pool.request_idle(BlockMeta::default()).is_none());
        }
    }

    #[test]
    fn acquire_blocks_until_recycle() {
        let pool = std::sync::Arc::new(BufferPool::new(1));
        let meta = BlockMeta::default();
        let first = pool.acquire_idle(meta).expect("first claim");
        assert_eq!(pool.count(BufferStatus::CRequested), 1);
        let p2 = std::sync::Arc::clone(&pool);
        let waiter = std::thread::spawn(move || p2.acquire_idle(meta));
        // Give the waiter time to park, then recycle; it must wake and claim.
        std::thread::sleep(std::time::Duration::from_millis(20));
        pool.get(first).set_status(BufferStatus::JReading);
        pool.recycle(first);
        let got = waiter.join().unwrap();
        assert_eq!(got, Some(first));
        assert_eq!(pool.count(BufferStatus::CRequested), 1);
    }

    #[test]
    fn close_unblocks_waiters() {
        let pool = std::sync::Arc::new(BufferPool::new(1));
        let meta = BlockMeta::default();
        let _held = pool.acquire_idle(meta).expect("claim");
        let p2 = std::sync::Arc::clone(&pool);
        let waiter = std::thread::spawn(move || p2.acquire_idle(meta));
        std::thread::sleep(std::time::Duration::from_millis(20));
        pool.close();
        assert_eq!(waiter.join().unwrap(), None, "close wakes parked waiters");
        assert!(pool.is_closed());
        assert_eq!(pool.acquire_idle(meta), None, "closed pool refuses claims");
    }
}
