//! The ParaGrapher coordinator — the paper's system contribution (§4).
//!
//! The public API mirrors Appendix A:
//!
//! | paper                            | here                                   |
//! |----------------------------------|----------------------------------------|
//! | `paragrapher_init`               | [`Paragrapher::init`]                  |
//! | `paragrapher_open_graph`         | [`Paragrapher::open_graph`]            |
//! | `paragrapher_get_set_options`    | [`PgGraph::options`] / [`PgGraph::set_options`] + request queries |
//! | `csx_get_offsets`                | [`PgGraph::csx_get_offsets`]           |
//! | `csx_get_vertex_weights`         | [`PgGraph::csx_get_vertex_weights`]    |
//! | `csx_get_subgraph` (async)       | [`PgGraph::csx_get_subgraph`]          |
//! | `csx_get_subgraph` (blocking)    | [`PgGraph::csx_get_subgraph_sync`]     |
//! | `coo_get_edges`                  | [`PgGraph::coo_get_edges`]             |
//! | `csx_release_read_buffers`       | automatic at callback return (RAII)    |
//! | `paragrapher_release_graph`      | [`Paragrapher::release_graph`] / Drop  |
//!
//! Internally the coordinator implements §4.4's consumer–producer design:
//! the *request manager* ("C side") claims idle buffers and publishes block
//! metadata; the *decoder worker pool* ("Java side") observes requested
//! buffers, decodes the block, and publishes completion; a *callback
//! executor* hands completed buffers to the user and recycles them. All
//! handoffs go through the 5-status protocol in [`buffer`], and scheduling
//! over it is **event-driven**: a request manager that finds every buffer
//! busy parks on the sharded pool's condvar and is woken by the next
//! recycle (or by shutdown) — no code path sleeps on a poll interval.
//!
//! Besides block streaming, an opened graph is also a
//! [`GraphSource`](crate::formats::GraphSource): [`PgGraph::successors`]
//! serves per-vertex random access through a decoded-block LRU
//! ([`DecodedCache`]), the out-of-core access pattern of §4.1's use case D.

pub mod buffer;
pub mod request;

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::formats::source::{block_cost, GraphSource};
use crate::formats::webgraph::{self, DecodeSink, DecodedBlock, Decoder, WgMeta, WgOffsets};
use crate::graph::VertexId;
use crate::model::LoadModel;
use crate::obs::{self, names, Counter, Histo, MetricsRegistry, MetricsSnapshot, SpanGuard};
use crate::partition::{self, LoadedPartition, Partition, PartitionPlan, PartitionStream};
use crate::runtime::ScanEngine;
use crate::storage::cache::CacheCounters;
use crate::storage::sim::ReadCtx;
use crate::storage::{DecodedCache, IoAccount, SimStore};
use crate::util::pool::ThreadPool;
use buffer::{BlockMeta, BufferPool, BufferStatus};
pub use request::{EdgeBlock, ReadRequest, VertexRange};

/// Default calibrated single-core decompression bandwidth d (uncompressed
/// bytes/s) used by [`PgGraph::load_model`] — the order of magnitude the
/// `webgraph/calibrated-d` hot-path bench measures for this decoder.
const DEFAULT_DECODE_BPS: f64 = 1.0e9;

/// Graph types (paper Table 2). The trailing `_AP` of the paper's names
/// (Asynchronous, Parallel) is the coordinator's operating mode here.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphType {
    /// 4-byte vertex IDs, unweighted (`CSX_WG_400_AP`).
    CsxWg400,
    /// 8-byte vertex IDs, unweighted (`CSX_WG_800_AP`); accepted and served
    /// through the same u32-backed path while |V| < 2^32 (as in the paper).
    CsxWg800,
    /// 4-byte vertex IDs + 4-byte edge weights (`CSX_WG_404_AP`).
    CsxWg404,
}

impl GraphType {
    pub fn weighted(&self) -> bool {
        matches!(self, GraphType::CsxWg404)
    }

    pub fn parse(s: &str) -> Option<GraphType> {
        match s.to_ascii_uppercase().as_str() {
            "CSX_WG_400_AP" | "WG400" => Some(GraphType::CsxWg400),
            "CSX_WG_800_AP" | "WG800" => Some(GraphType::CsxWg800),
            "CSX_WG_404_AP" | "WG404" => Some(GraphType::CsxWg404),
            _ => None,
        }
    }
}

/// Structured coordinator errors, carried inside `anyhow::Error` on the
/// request paths so callers (and the distributed worker loop) can match on
/// the failure class instead of string-scraping.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PgError {
    /// The handle can no longer serve requests: it was released, its buffer
    /// pool closed, or internal state was poisoned by a panicked library
    /// thread. One panicked dispatcher must degrade the handle into clean
    /// errors like this — not cascade a panic into every later request.
    Closed(String),
    /// A persistent artifact failed validation: a truncated/corrupt sidecar
    /// or a shipped plan that disagrees with the opened graph.
    Corrupt(String),
    /// A read fault that could not be healed: the checksum sidecar says
    /// the data at rest is fine (or cannot say), but the read kept failing
    /// past the retry budget — the block is quarantined so one flaky
    /// region cannot wedge the request stream.
    Faulted(String),
    /// Load shedding: the serving front-end refused to queue the request
    /// because the tenant's admission queue is full (or the server is
    /// draining). `retry_after` is the §3 [`LoadModel`] backlog estimate —
    /// queued uncompressed bytes divided by the modeled load bandwidth
    /// upper bound — i.e. roughly when the backlog will have drained.
    Overloaded { retry_after: Duration },
    /// The request's deadline passed before it was dispatched (or before
    /// its result was consumed). Expired requests are *cancelled and
    /// billed* — counted against the tenant and visible in its latency
    /// histogram — never silently dropped.
    Expired { waited: Duration },
}

impl std::fmt::Display for PgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PgError::Closed(why) => write!(f, "graph handle closed: {why}"),
            PgError::Corrupt(why) => write!(f, "corrupt input: {why}"),
            PgError::Faulted(why) => write!(f, "unhealed read fault: {why}"),
            PgError::Overloaded { retry_after } => {
                write!(f, "overloaded: retry after {:.3}s", retry_after.as_secs_f64())
            }
            PgError::Expired { waited } => {
                write!(f, "deadline expired after {:.3}s", waited.as_secs_f64())
            }
        }
    }
}

impl std::error::Error for PgError {}

/// Lock `m`, mapping poisoning to a clean [`PgError::Closed`] instead of
/// propagating the sibling thread's panic. Request-path entry points go
/// through this so a panicked dispatcher turns subsequent requests into
/// orderly failures rather than a poisoned-lock panic cascade.
pub(crate) fn lock_clean<'a, T>(
    m: &'a Mutex<T>,
    what: &'static str,
) -> std::result::Result<std::sync::MutexGuard<'a, T>, PgError> {
    m.lock()
        .map_err(|_| PgError::Closed(format!("{what} poisoned by a panicked library thread")))
}

/// Lock `m`, recovering the guard from a poisoned mutex. Only for state
/// that stays structurally valid across a panic — plain counters/config,
/// or data the next owner fully overwrites before reading — and for
/// shutdown/recycle paths, which must always complete: a drop handler that
/// panics on a poisoned lock would abort the process mid-unwind.
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Library options (`get_set_options`): the two Fig. 8 knobs plus the read
/// context and the decode engine.
pub struct Options {
    /// Edges per buffer (paper default: 64 M; scaled default here).
    pub buffer_edges: u64,
    /// Number of buffers == number of decoder workers (§4.4: "the number of
    /// buffers ... specifies the number of parallel threads").
    pub buffers: usize,
    /// Chunks a single block's decode fans out over (intra-block
    /// parallelism through [`Decoder::decode_range_parallel`]); 1 (the
    /// default) decodes each block on its single pool worker with no extra
    /// threads. Each chunk worker carries its own [`IoAccount`], composed
    /// by max into [`GraphStats::decode_seconds`] so the §3 overlap model
    /// still holds. Values > 1 fan out as *borrowed scoped jobs* on the
    /// shared coordinator worker pool (`ThreadPool::scoped_for`): the
    /// decoding worker participates and idle pool workers help, so no
    /// extra OS threads are spawned per block and the thread count stays
    /// at `buffers` regardless of this knob.
    pub decode_workers: usize,
    /// Declared I/O pattern for the storage model.
    pub read_ctx: ReadCtx,
    /// Scan engine for the gap→ID phase (native Rust or the AOT-compiled
    /// XLA/Pallas executable).
    pub scan: Arc<dyn ScanEngine>,
    /// Staging depth of partitioned requests (decoded-but-unconsumed
    /// partitions a [`PartitionStream`] holds ahead of its consumers).
    /// 0 (the default) sizes the window from the §3 [`LoadModel`] for the
    /// opened store's device tier ([`PgGraph::auto_prefetch_window`]);
    /// nonzero pins it.
    pub prefetch_window: usize,
    /// Vertices per random-access decode unit ([`PgGraph::successors`]
    /// decodes the aligned block containing the requested vertex).
    pub source_block_vertices: usize,
    /// Simulated OS page-cache budget in bytes applied to the store at open
    /// time (`None` keeps the store's current capacity — the
    /// [`DEFAULT_CACHE_BYTES`](crate::storage::DEFAULT_CACHE_BYTES) 8 GiB
    /// unless the caller already sized it). On a rooted (mmap-backed) store,
    /// shrinking the budget also drops the evicted pages' real residency.
    pub cache_budget: Option<u64>,
    /// Decoded-block cache capacity in cost units (≈ edges + vertices);
    /// 0 disables caching. Like the buffer pool, fixed at open time.
    pub source_cache_cost: u64,
    /// When set, [`PgGraph::release`] exports the process-wide span trace
    /// as Chrome trace-event JSON (Perfetto-viewable) to this path.
    pub trace_path: Option<std::path::PathBuf>,
    /// Retry budget of the self-healing read path: how many times a
    /// *transient* decode/read fault (checksum sidecar says the data at
    /// rest is fine) is retried before the block is quarantined. Checksum
    /// mismatches never retry — corruption at rest cannot be outwaited.
    pub read_retries: u32,
    /// Backoff before the first retry; doubles per attempt.
    pub retry_backoff: Duration,
    /// Dead since the event-driven coordinator (PR 1): the request manager
    /// parks on the buffer pool's condvar and is woken by the next recycle;
    /// no code path reads or sleeps on this value.
    #[deprecated(
        since = "0.2.0",
        note = "the coordinator is event-driven; nothing sleeps on this value"
    )]
    pub poll_interval: Duration,
}

impl std::fmt::Debug for Options {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // `poll_interval` is deliberately omitted: the knob is deprecated
        // and ignored, and printing it would suggest otherwise.
        f.debug_struct("Options")
            .field("buffer_edges", &self.buffer_edges)
            .field("buffers", &self.buffers)
            .field("decode_workers", &self.decode_workers)
            .field("read_ctx", &self.read_ctx)
            .field("scan", &self.scan.name())
            .field("prefetch_window", &self.prefetch_window)
            .field("source_block_vertices", &self.source_block_vertices)
            .field("source_cache_cost", &self.source_cache_cost)
            .field("cache_budget", &self.cache_budget)
            .field("trace_path", &self.trace_path)
            .field("read_retries", &self.read_retries)
            .field("retry_backoff", &self.retry_backoff)
            .finish()
    }
}

// Manual impl (not derived) so the deprecated field can be copied without
// tripping `deny(warnings)` builds.
impl Clone for Options {
    #[allow(deprecated)]
    fn clone(&self) -> Self {
        Self {
            buffer_edges: self.buffer_edges,
            buffers: self.buffers,
            decode_workers: self.decode_workers,
            read_ctx: self.read_ctx,
            scan: Arc::clone(&self.scan),
            prefetch_window: self.prefetch_window,
            source_block_vertices: self.source_block_vertices,
            source_cache_cost: self.source_cache_cost,
            cache_budget: self.cache_budget,
            trace_path: self.trace_path.clone(),
            read_retries: self.read_retries,
            retry_backoff: self.retry_backoff,
            poll_interval: self.poll_interval,
        }
    }
}

impl Default for Options {
    #[allow(deprecated)]
    fn default() -> Self {
        Self {
            buffer_edges: 1 << 20,
            buffers: 4,
            decode_workers: 1,
            read_ctx: ReadCtx::default(),
            scan: Arc::new(crate::runtime::NativeScan),
            prefetch_window: 0,
            // One source of truth for random-access cache geometry: the
            // formats-layer defaults, so PgGraph and WebGraphSource agree.
            source_block_vertices: crate::formats::SourceConfig::default().block_vertices,
            source_cache_cost: crate::formats::SourceConfig::default().cache_cost,
            cache_budget: None,
            trace_path: None,
            read_retries: 2,
            retry_backoff: Duration::from_millis(1),
            poll_interval: Duration::from_micros(200),
        }
    }
}

/// The library instance (`paragrapher_init`).
pub struct Paragrapher {
    /// Formats the library discovered "iterating over its inner files"
    /// (§A.1) — here a static registry.
    supported: Vec<GraphType>,
}

impl Default for Paragrapher {
    fn default() -> Self {
        Self::init()
    }
}

impl Paragrapher {
    pub fn init() -> Self {
        Self {
            supported: vec![GraphType::CsxWg400, GraphType::CsxWg800, GraphType::CsxWg404],
        }
    }

    pub fn supported_types(&self) -> &[GraphType] {
        &self.supported
    }

    /// Open a graph stored under `base` in `store` (`paragrapher_open_graph`).
    ///
    /// Loads the metadata and the binary offsets sidecar — the *sequential*
    /// phase whose cost §5.6 identifies as the scalability limit; its time
    /// is recorded in [`PgGraph::stats`].
    pub fn open_graph(
        &self,
        store: Arc<SimStore>,
        base: &str,
        gtype: GraphType,
        options: Options,
    ) -> Result<PgGraph> {
        if !self.supported.contains(&gtype) {
            bail!("unsupported graph type {gtype:?}");
        }
        options.read_ctx.validate()?;
        if let Some(budget) = options.cache_budget {
            store.set_cache_capacity(budget);
        }
        let t0 = Instant::now();
        let meta_acct = IoAccount::new();
        let meta = webgraph::read_meta(&store, base, options.read_ctx, &meta_acct)?;
        if gtype.weighted() && !meta.weighted {
            bail!("{base}: opened as weighted (WG404) but dataset has no weights");
        }
        let offsets = webgraph::read_offsets(&store, base, options.read_ctx, &meta_acct)?;
        offsets.check_matches(&meta).with_context(|| base.to_string())?;
        // Open-time integrity gate: verify the `.graph` header chunk against
        // the checksums sidecar — O(1) in file size, catches a corrupted
        // stream before any request is issued. Directories written before
        // the sidecar existed are tolerated (no sidecar ⇒ no gate);
        // `verify_range` only fails here on a real mismatch.
        if store.file_len(&format!("{base}.checksums")).is_some() {
            if let Err(e) =
                webgraph::integrity::verify_range(&store, base, 0, 1, options.read_ctx, &meta_acct)
            {
                return Err(PgError::Corrupt(format!(
                    "{base}: header chunk failed open-time verification: {e}"
                ))
                .into());
            }
        }
        let sequential_cpu = t0.elapsed().as_secs_f64();
        let sequential_io = meta_acct.io_seconds();

        let workers = ThreadPool::new(options.buffers);
        let callbacks = ThreadPool::new(2);
        let metrics = Arc::new(MetricsRegistry::new());
        let decoded_cache = DecodedCache::with_counters(
            options.source_cache_cost,
            block_cost,
            metrics.counter(names::CACHE_HITS),
            metrics.counter(names::CACHE_MISSES),
            metrics.counter(names::CACHE_EVICTIONS),
        );
        let source_block_vertices = options.source_block_vertices.max(1);
        let inner = Arc::new(GraphInner {
            store,
            base: base.to_string(),
            gtype,
            meta,
            offsets,
            pool: BufferPool::new(options.buffers),
            options: Mutex::new(options),
            stats: GraphStats::registered(&metrics),
            shutdown: AtomicBool::new(false),
            decoded_cache,
            source_block_vertices,
            random_acct: IoAccount::new(),
            obs: ObsHandles::resolve(&metrics),
            metrics,
            quarantine: Mutex::new(HashSet::new()),
            fault_injected_seen: AtomicU64::new(0),
        });
        inner.stats.sequential_seconds.store(
            ((sequential_cpu + sequential_io) * 1e9) as u64,
            Ordering::Relaxed,
        );
        Ok(PgGraph {
            inner,
            workers: Arc::new(workers),
            callbacks: Arc::new(callbacks),
            dispatchers: Mutex::new(Vec::new()),
        })
    }

    /// Open a graph straight from an on-disk directory through the
    /// mmap-backed real-file store: builds a rooted
    /// [`GraphStore`](crate::storage::GraphStore) over `dir` (every sidecar
    /// mapped, borrowed reads serving true zero-copy slices of the mapping)
    /// and delegates to [`Self::open_graph`]. `device` picks the billing
    /// model for cold pages, so the §3 load model keeps holding on real
    /// files.
    pub fn open_graph_from_dir(
        &self,
        dir: &std::path::Path,
        device: crate::storage::DeviceKind,
        base: &str,
        gtype: GraphType,
        options: Options,
    ) -> Result<PgGraph> {
        let cache = options.cache_budget.unwrap_or(crate::storage::DEFAULT_CACHE_BYTES);
        let store =
            Arc::new(crate::storage::GraphStore::open_dir_with(dir, device.model(), cache)?);
        self.open_graph(store, base, gtype, options)
    }

    /// Release a graph (`paragrapher_release_graph`): joins library threads
    /// and drops the simulated OS cache — §4.1's "return the computational
    /// resources as they were before calling".
    pub fn release_graph(&self, graph: PgGraph) {
        graph.release();
    }
}

/// Cumulative per-graph statistics.
///
/// Since the observability PR the fields are [`Counter`] handles resolved
/// from the owning graph's [`MetricsRegistry`] ([`GraphStats::registered`]),
/// so one registry snapshot covers them; `Counter` `Deref`s to `AtomicU64`,
/// keeping every legacy `.load`/`.store`/`.fetch_add` call site intact.
#[derive(Debug, Default)]
pub struct GraphStats {
    /// Sequential metadata-load phase, nanoseconds (§5.6).
    pub sequential_seconds: Counter,
    pub blocks_decoded: Counter,
    pub edges_decoded: Counter,
    pub requests_issued: Counter,
    /// Per-vertex random accesses served via [`PgGraph::successors`].
    pub random_accesses: Counter,
    /// Partitioned requests issued ([`PgGraph::get_partitions`] family).
    pub partition_requests: Counter,
    /// Partitions decoded and staged by partitioned requests.
    pub partitions_staged: Counter,
    /// Modeled block-decode time, nanoseconds: per block, the max over its
    /// chunk workers' virtual clocks (I/O + CPU), summed across blocks —
    /// the §3 overlap composition at `decode_workers` granularity. A
    /// weighted graph's sidecar read is its own (post-decode) phase, added
    /// on top of the chunk-worker max.
    pub decode_seconds: Counter,
    /// Bytes of decoded payload (offsets, edges, weights) written straight
    /// into coordinator buffers or handed out as borrowed views — each one
    /// a byte the former decode-then-copy pipeline materialized twice.
    /// Grows on every sink-backed block decode and every COO trim view.
    pub copy_bytes_avoided: Counter,
    /// Bytes of decoded payload the block-request path *did* copy after
    /// decode. The zero-copy invariant: stays 0 on single- *and*
    /// multi-worker decodes — the fan-out pre-partitions the sink off the
    /// offsets sidecar and chunk workers write disjoint slices in place.
    /// The only remaining contributor is the stitched fallback a block
    /// larger than the sidecar-reserve guard takes.
    pub delivery_copy_bytes: Counter,
    /// Edges delivered through the block-request (callback) path, paired
    /// with [`Self::delivery_wall_ns`] for the delivery-throughput canary.
    pub delivery_edges: Counter,
    /// Wall nanoseconds spent producing block-request payloads (decode +
    /// weights read), summed across blocks.
    pub delivery_wall_ns: Counter,
}

impl GraphStats {
    /// Counter handles resolved from `reg`, so the graph's cumulative
    /// stats appear in registry snapshots under `graph.*` names.
    pub fn registered(reg: &MetricsRegistry) -> GraphStats {
        GraphStats {
            sequential_seconds: reg.counter("graph.sequential_ns"),
            blocks_decoded: reg.counter("graph.blocks_decoded"),
            edges_decoded: reg.counter("graph.edges_decoded"),
            requests_issued: reg.counter("graph.requests_issued"),
            random_accesses: reg.counter("graph.random_accesses"),
            partition_requests: reg.counter("graph.partition_requests"),
            partitions_staged: reg.counter("graph.partitions_staged"),
            decode_seconds: reg.counter("graph.decode_ns"),
            copy_bytes_avoided: reg.counter("graph.copy_bytes_avoided"),
            delivery_copy_bytes: reg.counter("graph.delivery_copy_bytes"),
            delivery_edges: reg.counter("graph.delivery_edges"),
            delivery_wall_ns: reg.counter("graph.delivery_wall_ns"),
        }
    }

    /// Delivered edges per wall second on the block-request path (0.0
    /// before anything was delivered) — the `delivery-throughput` counter
    /// proving the zero-copy pipeline's win end to end.
    pub fn delivery_throughput(&self) -> f64 {
        let ns = self.delivery_wall_ns.load(Ordering::Relaxed);
        if ns == 0 {
            return 0.0;
        }
        self.delivery_edges.load(Ordering::Relaxed) as f64 / (ns as f64 / 1e9)
    }
}

/// Pre-resolved histogram handles for the hot request path — resolved once
/// at open time so no request ever takes the registry lock.
struct ObsHandles {
    req_successors: Histo,
    req_csx: Histo,
    req_coo: Histo,
    req_partition: Histo,
    buffer_claim_wait: Histo,
    decode_block_real: Histo,
    decode_block_virt: Histo,
    /// Fault/self-healing counters. `fault_injected` and `read_degraded`
    /// mirror store-owned state (synced by [`GraphInner::sync_fault_obs`]);
    /// the other two are incremented directly by the healing path.
    fault_injected: Counter,
    read_retries: Counter,
    read_degraded: Counter,
    block_quarantined: Counter,
}

impl ObsHandles {
    fn resolve(reg: &MetricsRegistry) -> ObsHandles {
        ObsHandles {
            req_successors: reg.histogram(names::REQ_SUCCESSORS),
            req_csx: reg.histogram(names::REQ_CSX),
            req_coo: reg.histogram(names::REQ_COO),
            req_partition: reg.histogram(names::REQ_PARTITION),
            buffer_claim_wait: reg.histogram(names::BUFFER_CLAIM_WAIT),
            decode_block_real: reg.histogram(names::DECODE_BLOCK_REAL),
            decode_block_virt: reg.histogram(names::DECODE_BLOCK_VIRT),
            fault_injected: reg.counter(names::FAULT_INJECTED),
            read_retries: reg.counter(names::READ_RETRIES),
            read_degraded: reg.counter(names::READ_DEGRADED),
            block_quarantined: reg.counter(names::BLOCK_QUARANTINED),
        }
    }
}

struct GraphInner {
    store: Arc<SimStore>,
    base: String,
    gtype: GraphType,
    meta: WgMeta,
    offsets: WgOffsets,
    pool: BufferPool,
    options: Mutex<Options>,
    stats: GraphStats,
    shutdown: AtomicBool,
    /// Decoded-block LRU for the random-access path.
    decoded_cache: DecodedCache<DecodedBlock>,
    /// Vertices per random-access decode unit (from `Options`, ≥ 1).
    source_block_vertices: usize,
    /// I/O account charged by random accesses (selective reads).
    random_acct: IoAccount,
    /// Per-graph metrics registry; `stats`, the decoded cache and the
    /// request-path histograms all resolve their handles from it.
    metrics: Arc<MetricsRegistry>,
    /// Hot-path histogram handles (resolved once at open).
    obs: ObsHandles,
    /// Blocks (keyed by vertex range) the self-healing path gave up on:
    /// a checksum-confirmed corrupt block, or a transient fault that
    /// outlived the retry budget. Quarantined blocks fail fast with a
    /// typed error instead of burning the retry budget on every request.
    quarantine: Mutex<HashSet<(usize, usize)>>,
    /// Watermark of the store's injected-fault count at the last
    /// [`Self::sync_fault_obs`]: the store's count lives on the *installed*
    /// fault plan, so swapping plans resets it — the delta fold below is
    /// what keeps the registry's `fault.injected` cumulative per graph.
    fault_injected_seen: AtomicU64,
}

impl GraphInner {
    /// Record one buffer-claim wait that started at `t_claim`: the
    /// claim-wait histogram plus a `buffer`-category span.
    fn observe_buffer_claim(&self, t_claim: Instant, buffer_id: usize) {
        let dur = t_claim.elapsed();
        self.obs.buffer_claim_wait.record_duration(dur);
        obs::tracer().record("buffer", "claim-wait", t_claim, dur, 0, buffer_id as u64);
    }

    /// Mirror the store-owned fault state into the registry so one metrics
    /// snapshot carries it; called on every healing event and on snapshot,
    /// so a clean run reports exact zeros. `fault.injected` folds positive
    /// deltas over a watermark (cumulative across plan swaps — a swap
    /// resets the store-side count); `read.degraded` is a plain gauge of
    /// currently-degraded files.
    fn sync_fault_obs(&self) {
        let now = self.store.fault_injected();
        let prev = self.fault_injected_seen.swap(now, Ordering::Relaxed);
        if now > prev {
            self.obs.fault_injected.add(now - prev);
        } else if now < prev {
            // New plan epoch: everything it injected so far is new.
            self.obs.fault_injected.add(now);
        }
        self.obs.read_degraded.set(self.store.degraded_files());
    }
}

/// An opened graph (`paragrapher_graph*`).
pub struct PgGraph {
    inner: Arc<GraphInner>,
    workers: Arc<ThreadPool>,
    callbacks: Arc<ThreadPool>,
    dispatchers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

/// User callback invoked per completed edge block. The buffer is recycled
/// when the callback returns (`csx_release_read_buffers` is automatic).
pub type BlockCallback = Arc<dyn Fn(&EdgeBlock<'_>) + Send + Sync>;

thread_local! {
    /// Per-callback-thread offsets scratch for `coo_get_edges` trim views:
    /// the rebased offsets (the only per-block data the zero-copy trim
    /// still writes) reuse one warmed vector per thread.
    static COO_TRIM_SCRATCH: std::cell::RefCell<Vec<u64>> =
        std::cell::RefCell::new(Vec::new());
}

impl PgGraph {
    pub fn num_vertices(&self) -> usize {
        self.inner.meta.num_vertices
    }

    pub fn num_edges(&self) -> u64 {
        self.inner.meta.num_edges
    }

    pub fn graph_type(&self) -> GraphType {
        self.inner.gtype
    }

    pub fn stats(&self) -> &GraphStats {
        &self.inner.stats
    }

    /// Seconds spent in the sequential open phase (§5.6).
    pub fn sequential_seconds(&self) -> f64 {
        self.inner.stats.sequential_seconds.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Modeled block-decode seconds (see [`GraphStats::decode_seconds`]).
    pub fn decode_seconds(&self) -> f64 {
        self.inner.stats.decode_seconds.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Payload bytes delivered without a post-decode copy (see
    /// [`GraphStats::copy_bytes_avoided`]).
    pub fn copy_bytes_avoided(&self) -> u64 {
        self.inner.stats.copy_bytes_avoided.load(Ordering::Relaxed)
    }

    /// Payload bytes the block-request path copied after decode — 0 under
    /// the default single-worker decode (see
    /// [`GraphStats::delivery_copy_bytes`]).
    pub fn delivery_copy_bytes(&self) -> u64 {
        self.inner.stats.delivery_copy_bytes.load(Ordering::Relaxed)
    }

    /// Delivered edges per wall second on the block-request path (see
    /// [`GraphStats::delivery_throughput`]).
    pub fn delivery_throughput(&self) -> f64 {
        self.inner.stats.delivery_throughput()
    }

    /// Buffers currently in C_IDLE — equals the pool size whenever no
    /// request is in flight (the stress suite's leak check).
    pub fn idle_buffers(&self) -> usize {
        self.inner.pool.count(BufferStatus::CIdle)
    }

    /// Resident footprint of the Elias–Fano offsets index vs the former
    /// plain `Vec<u64>` representation, bytes: `(compressed, plain)`.
    pub fn offsets_footprint(&self) -> (usize, usize) {
        (self.inner.offsets.size_bytes(), self.inner.offsets.plain_size_bytes())
    }

    /// The resident Elias–Fano offsets index — the sidecar structure
    /// external partition planners build [`PartitionPlan`]s from
    /// (`csx_get_offsets` materializes plain slices of the same index).
    pub fn offsets_index(&self) -> &WgOffsets {
        &self.inner.offsets
    }

    pub fn options(&self) -> Options {
        // Recovery (not expect): `Options` is plain config, structurally
        // valid even if a user closure panicked inside `set_options` — that
        // panic must not wedge every later request behind a poisoned lock.
        lock_recover(&self.inner.options).clone()
    }

    /// Set options; takes effect for subsequent requests. (The buffer pool
    /// and worker count are fixed at open time, as in the library, where
    /// "the user may change these values" *before* starting to read.)
    /// A panicking `f` unwinds to the caller; the handle stays usable.
    pub fn set_options(&self, f: impl FnOnce(&mut Options)) {
        let mut o = lock_recover(&self.inner.options);
        f(&mut o);
    }

    /// `csx_get_offsets`: the CSR offsets of `[start, end]` vertices —
    /// an O(|V|) sidecar slice materialized from the Elias–Fano index, no
    /// graph data touched (§6).
    pub fn csx_get_offsets(&self, start_vertex: usize, end_vertex: usize) -> Result<Vec<u64>> {
        let n = self.inner.meta.num_vertices;
        if start_vertex > end_vertex || end_vertex > n {
            bail!("bad vertex range {start_vertex}..{end_vertex}");
        }
        Ok(self.inner.offsets.edge_offsets_vec(start_vertex, end_vertex))
    }

    /// `csx_get_vertex_weights`: none of the paper's shipped WebGraph types
    /// carry vertex weights (Table 2) — kept for API parity.
    pub fn csx_get_vertex_weights(&self, _start: usize, _end: usize) -> Result<Vec<f32>> {
        bail!("vertex-weighted WebGraph types are not published (Table 2: weight size 0)")
    }

    /// Split a vertex range into blocks of at most `buffer_edges` edges
    /// (vertex-aligned; a single vertex larger than the buffer gets its own
    /// oversized block).
    fn plan_blocks(&self, range: VertexRange, buffer_edges: u64) -> Vec<BlockMeta> {
        let offs = &self.inner.offsets;
        let mut blocks = Vec::new();
        let mut v = range.start;
        while v < range.end {
            let start_edge = offs.edge_offset(v);
            // Largest end with edge_offset(end) - start_edge <= buffer_edges.
            let limit = start_edge + buffer_edges;
            let mut end = offs.edge_partition_point(|e| e <= limit) - 1;
            end = end.min(range.end).max(v + 1);
            blocks.push(BlockMeta {
                start_vertex: v,
                end_vertex: end,
                start_edge,
                end_edge: offs.edge_offset(end),
            });
            v = end;
        }
        blocks
    }

    /// `csx_get_subgraph`, asynchronous: returns immediately; `callback`
    /// runs on a library thread per completed block.
    pub fn csx_get_subgraph(
        &self,
        range: VertexRange,
        callback: BlockCallback,
    ) -> Result<Arc<ReadRequest>> {
        self.issue_subgraph(range, callback, "csx", self.inner.obs.req_csx.clone())
    }

    /// Shared issue path of the block-request family. `kind`/`hist` name
    /// the request-latency histogram and span, so `coo_get_edges` records
    /// under its own kind rather than the csx path it delegates to.
    fn issue_subgraph(
        &self,
        range: VertexRange,
        callback: BlockCallback,
        kind: &'static str,
        hist: Histo,
    ) -> Result<Arc<ReadRequest>> {
        let n = self.inner.meta.num_vertices;
        if range.start > range.end || range.end > n {
            bail!("bad vertex range {}..{}", range.start, range.end);
        }
        let opts = self.options();
        let blocks = self.plan_blocks(range, opts.buffer_edges.max(1));
        let req = Arc::new(ReadRequest::new(blocks.len() as u64));
        req.set_completion_obs(hist, kind);
        self.inner.stats.requests_issued.fetch_add(1, Ordering::Relaxed);

        let inner = Arc::clone(&self.inner);
        let workers = Arc::clone(&self.workers);
        let callbacks = Arc::clone(&self.callbacks);
        let req2 = Arc::clone(&req);
        // The request manager ("C side"): claims idle buffers and publishes
        // block requests; a library thread so the call returns immediately.
        let handle = std::thread::Builder::new()
            .name("pg-request-manager".into())
            .spawn(move || {
                for meta in blocks {
                    if req2.is_cancelled() || inner.shutdown.load(Ordering::Acquire) {
                        req2.record_block(0);
                        continue;
                    }
                    // Wait for an idle buffer (the paper's tracking of free
                    // buffers in place of a queue): park on the pool condvar
                    // until a consumer recycles one. `None` means the pool
                    // closed (shutdown) — account the block so waiters
                    // terminate.
                    let t_claim = Instant::now();
                    let Some(buffer_id) = inner.pool.acquire_idle(meta) else {
                        req2.record_block(0);
                        continue;
                    };
                    inner.observe_buffer_claim(t_claim, buffer_id);
                    // Producer side ("Java"): decode the block on a worker.
                    let inner = Arc::clone(&inner);
                    let callbacks = Arc::clone(&callbacks);
                    let req3 = Arc::clone(&req2);
                    let callback = Arc::clone(&callback);
                    let scan = Arc::clone(&opts.scan);
                    let read_ctx = opts.read_ctx;
                    let decode_workers = opts.decode_workers;
                    let pool_for_chunks = Arc::clone(&workers);
                    workers.execute(move || {
                        let decoded = decode_into_buffer(
                            &inner, buffer_id, meta, read_ctx, scan.as_ref(), decode_workers,
                            &pool_for_chunks, &req3,
                        );
                        if !decoded {
                            return; // decode failed: buffer already recycled
                        }
                        if req3.is_failed() || req3.is_cancelled() {
                            // Another block failed or the user cancelled:
                            // recycle the buffer and account this block so
                            // waiters terminate (no buffer may be leaked in
                            // J_READ_COMPLETED — that would wedge the pool).
                            inner.pool.recycle(buffer_id);
                            req3.record_block(0);
                            return;
                        }
                        // Consumer side observes completion and runs the
                        // user callback on a callback thread.
                        let inner2 = Arc::clone(&inner);
                        let req4 = Arc::clone(&req3);
                        callbacks.execute(move || {
                            run_user_callback(&inner2, buffer_id, meta, &callback, &req4);
                        });
                    });
                }
            })
            .context("spawn request manager")?;
        // Recovery: the handle vector stays valid across a sibling panic,
        // and release()/Drop must still be able to join this dispatcher.
        lock_recover(&self.dispatchers).push(handle);
        Ok(req)
    }

    /// `csx_get_subgraph`, blocking: waits for completion and returns the
    /// assembled subgraph (Fig. 2's synchronous call).
    ///
    /// Assembly is write-in-place: the result's exact shape is known up
    /// front from the Elias–Fano sidecar (degree sums), so each delivered
    /// block copies its rows once into their final position — no per-block
    /// `to_vec`, no sort, no second concatenation pass. Blocks tile the
    /// range and every decoded block's shape is validated against the
    /// sidecar before delivery, so the slots are disjoint and exact
    /// regardless of completion order.
    pub fn csx_get_subgraph_sync(&self, range: VertexRange) -> Result<DecodedBlock> {
        let n = self.inner.meta.num_vertices;
        if range.start > range.end || range.end > n {
            bail!("bad vertex range {}..{}", range.start, range.end);
        }
        let offs = &self.inner.offsets;
        let base_edge = offs.edge_offset(range.start);
        let total_edges = (offs.edge_offset(range.end) - base_edge) as usize;
        let assembled = Arc::new(Mutex::new(DecodedBlock {
            first_vertex: range.start,
            offsets: vec![0u64; range.len() + 1],
            // Reserve exact capacity once, capped by the decoder's shared
            // forged-sidecar guard: blocks land by resize-to-fit, which is
            // a no-op within the reservation.
            edges: Vec::with_capacity(total_edges.min(webgraph::MAX_SIDECAR_RESERVE_EDGES)),
        }));
        let a2 = Arc::clone(&assembled);
        let delivered = Arc::new(AtomicU64::new(0));
        let d2 = Arc::clone(&delivered);
        let start_v = range.start;
        let req = self.csx_get_subgraph(
            range,
            Arc::new(move |blk: &EdgeBlock<'_>| {
                // Recovery is sound here: a sibling callback that panicked
                // mid-assembly never bumped `delivered`, so the truncation
                // guard below rejects the torn result regardless.
                let mut out = lock_recover(&a2);
                let lo = (blk.start_edge - base_edge) as usize;
                let hi = lo + blk.edges.len();
                if out.edges.len() < hi {
                    out.edges.resize(hi, 0);
                }
                out.edges[lo..hi].copy_from_slice(blk.edges);
                let vi0 = blk.start_vertex - start_v;
                for (i, &o) in blk.offsets.iter().enumerate().skip(1) {
                    out.offsets[vi0 + i] = lo as u64 + o;
                }
                d2.fetch_add(1, Ordering::AcqRel);
            }),
        )?;
        req.wait();
        if let Some(e) = req.error() {
            // Re-raise the typed class when the producer preserved one
            // (the serving layer routes Faulted/Corrupt/Closed on it).
            if let Some(pg) = req.error_kind() {
                return Err(pg.into());
            }
            bail!("load failed: {e}");
        }
        // In-place assembly needs *every* block to have landed; a quietly
        // truncated delivery (graph released mid-request) must not read as
        // a well-formed subgraph with zeroed holes.
        if delivered.load(Ordering::Acquire) != req.total_blocks() {
            bail!("blocking load truncated: graph released mid-request");
        }
        let mut out = lock_recover(&assembled);
        Ok(std::mem::replace(
            &mut *out,
            DecodedBlock { first_vertex: 0, offsets: Vec::new(), edges: Vec::new() },
        ))
    }

    /// `coo_get_edges`: edge-granular request `[start_edge, end_edge)` —
    /// the finest-granularity base of §4.2. Blocks are delivered with the
    /// first/last vertex lists trimmed to the requested edge range.
    ///
    /// Trimming is zero-copy: the delivered [`EdgeBlock`] *slices* the
    /// library buffer's edge (and weight) arrays in place; only the
    /// rebased offsets — a per-vertex quantity, small next to the edges —
    /// are written into the callback thread's reusable scratch.
    pub fn coo_get_edges(
        &self,
        start_edge: u64,
        end_edge: u64,
        callback: BlockCallback,
    ) -> Result<Arc<ReadRequest>> {
        let m = self.inner.meta.num_edges;
        if start_edge > end_edge || end_edge > m {
            bail!("bad edge range {start_edge}..{end_edge}");
        }
        let offs = &self.inner.offsets;
        // Vertex span covering the edge range.
        let v_first = offs.edge_partition_point(|e| e <= start_edge).saturating_sub(1);
        let v_last = offs.edge_partition_point(|e| e < end_edge);
        let user = callback;
        let inner = Arc::clone(&self.inner);
        let cb: BlockCallback = Arc::new(move |blk: &EdgeBlock<'_>| {
            // Trim the block's edges to [start_edge, end_edge).
            let blk_start = blk.start_edge;
            let blk_end = blk.start_edge + blk.num_edges();
            let lo = start_edge.max(blk_start);
            let hi = end_edge.min(blk_end);
            if lo >= hi {
                return;
            }
            let lo_local = (lo - blk_start) as usize;
            let hi_local = (hi - blk_start) as usize;
            // Rebase offsets to the trimmed window, into the callback
            // thread's reusable scratch — callback threads trim their
            // blocks concurrently (no request-wide serialization point),
            // and a panicking user callback unwinds cleanly (a RefCell
            // borrow releases on unwind; a mutex would stay poisoned).
            COO_TRIM_SCRATCH.with(|cell| {
                let mut offsets = cell.borrow_mut();
                offsets.clear();
                let mut first_v = None;
                for i in 0..blk.num_vertices() {
                    let (s, e) = (blk.offsets[i] as usize, blk.offsets[i + 1] as usize);
                    if e <= lo_local || s >= hi_local {
                        continue;
                    }
                    if first_v.is_none() {
                        first_v = Some(blk.start_vertex + i);
                        offsets.push(0);
                    }
                    offsets.push((e.min(hi_local) - lo_local) as u64);
                }
                let first_v = first_v.unwrap_or(blk.start_vertex);
                // The edges (and weights) the view borrows instead of
                // copying.
                let mut lane = std::mem::size_of::<VertexId>();
                if blk.weights.is_some() {
                    lane += std::mem::size_of::<crate::graph::Weight>();
                }
                let viewed = ((hi_local - lo_local) * lane) as u64;
                inner.stats.copy_bytes_avoided.fetch_add(viewed, Ordering::Relaxed);
                let trimmed = EdgeBlock {
                    buffer_id: blk.buffer_id,
                    start_vertex: first_v,
                    end_vertex: first_v + offsets.len().saturating_sub(1),
                    start_edge: lo,
                    offsets: &offsets,
                    edges: &blk.edges[lo_local..hi_local],
                    weights: blk.weights.map(|w| &w[lo_local..hi_local]),
                };
                user(&trimmed);
            });
        });
        self.issue_subgraph(
            VertexRange::new(v_first, v_last.max(v_first)),
            cb,
            "coo",
            self.inner.obs.req_coo.clone(),
        )
    }

    /// Convenience: load the full graph through the block pipeline
    /// (use case A, the Fig. 5 experiment).
    pub fn load_whole_graph(&self) -> Result<DecodedBlock> {
        self.csx_get_subgraph_sync(VertexRange::new(0, self.num_vertices()))
    }

    /// The §3 [`LoadModel`] of this opened graph on its store's device
    /// tier: σ from the device model at the configured read parallelism,
    /// r from the actual compressed footprint, d the calibrated
    /// decompression bandwidth (see `benches/hot_path.rs`,
    /// `webgraph/calibrated-d`).
    pub fn load_model(&self) -> LoadModel {
        let opts = self.options();
        let device = self.inner.store.device();
        let sigma = device.aggregate_bandwidth(
            opts.buffers.max(1),
            opts.read_ctx.block,
            opts.read_ctx.method,
            opts.read_ctx.sequential,
        );
        let uncompressed = crate::bench::workloads::full_load_memory_bytes(
            self.inner.meta.num_vertices,
            self.inner.meta.num_edges,
        );
        let compressed = self
            .inner
            .store
            .file_len(&format!("{}.graph", self.inner.base))
            .unwrap_or(uncompressed)
            .max(1);
        LoadModel {
            sigma,
            r: uncompressed as f64 / compressed as f64,
            d: DEFAULT_DECODE_BPS,
        }
    }

    /// Model-driven staging depth for partitioned requests: how many
    /// partitions the server keeps decoded ahead of consumption
    /// ([`partition::prefetch_depth`] over [`Self::load_model`], assuming
    /// consumers process about as fast as one decode core). Capped at
    /// 2× the buffer pool so staging memory stays proportional to the
    /// §5.5 buffer budget. Overridden by [`Options::prefetch_window`].
    pub fn auto_prefetch_window(&self) -> usize {
        let buffers = self.inner.pool.len();
        partition::prefetch_depth(&self.load_model(), DEFAULT_DECODE_BPS, (2 * buffers).max(2))
    }

    /// Partitioned CSX request (§2's `csx_get_partitions`): an
    /// edge-balanced 1D plan served as a [`PartitionStream`].
    pub fn csx_get_partitions(&self, parts: usize) -> Result<PartitionStream> {
        self.get_partitions(PartitionPlan::one_d(&self.inner.offsets, parts))
    }

    /// Partitioned CSX request over a 2D source×target tiling (the
    /// distributed-memory layout of §4.1 use case C).
    pub fn csx_get_partitions_2d(&self, rows: usize, cols: usize) -> Result<PartitionStream> {
        self.get_partitions(PartitionPlan::two_d(&self.inner.offsets, rows, cols))
    }

    /// Partitioned COO request (§2's `coo_get_partitions`): exact
    /// edge-split shares, cutting inside vertex rows when needed.
    pub fn coo_get_partitions(&self, parts: usize) -> Result<PartitionStream> {
        self.get_partitions(PartitionPlan::coo(&self.inner.offsets, parts))
    }

    /// Admission check for a plan before any decode is dispatched:
    /// structural `check()`, the `(n, m)` cross-check against this
    /// graph's metadata, and a per-partition span cross-check against
    /// this graph's EF sidecar. A worker MUST run this on every
    /// leader-shipped plan — a stale plan for a different build of the
    /// same-named graph otherwise fails deep inside decode (or worse,
    /// silently drops edges) instead of at admission.
    pub fn validate_plan(&self, plan: &PartitionPlan) -> Result<()> {
        plan.check()?;
        if plan.num_vertices != self.inner.meta.num_vertices
            || plan.num_edges != self.inner.meta.num_edges
        {
            bail!(
                "plan is for a {}v/{}e graph, this graph has {}v/{}e",
                plan.num_vertices,
                plan.num_edges,
                self.inner.meta.num_vertices,
                self.inner.meta.num_edges
            );
        }
        // `check()` is structural only; a foreign plan can tile [0, m)
        // while still disagreeing with THIS graph's degree distribution
        // (same n and m, different offsets). Cross-check every span
        // against the sidecar — O(p) EF lookups — so a stale
        // leader-shipped plan is rejected up front instead of underflowing
        // the trim arithmetic or silently dropping edges.
        for p in &plan.parts {
            self.partition_consistent(p, plan.kind)?;
        }
        Ok(())
    }

    /// One partition's span cross-checked against this graph's offsets.
    /// Also bounds-checks the vertex range, since the single-tile path
    /// ([`decode_partition_block`](Self::decode_partition_block)) has no
    /// surrounding `plan.check()` to catch an out-of-range row.
    fn partition_consistent(&self, p: &Partition, kind: partition::PlanKind) -> Result<()> {
        if p.vertices.end > self.inner.meta.num_vertices
            || p.vertices.start > p.vertices.end
            || p.edge_span.0 > p.edge_span.1
            || p.edge_span.1 > self.inner.meta.num_edges
        {
            bail!(
                "partition {}: rows {}..{} span {:?} out of range for a {}v/{}e graph",
                p.index,
                p.vertices.start,
                p.vertices.end,
                p.edge_span,
                self.inner.meta.num_vertices,
                self.inner.meta.num_edges
            );
        }
        let row_span = (
            self.inner.offsets.edge_offset(p.vertices.start),
            self.inner.offsets.edge_offset(p.vertices.end),
        );
        let consistent = match kind {
            // Vertex-aligned kinds own their rows' exact edge span.
            partition::PlanKind::OneD | partition::PlanKind::TwoD { .. } => {
                p.edge_span == row_span
            }
            // COO shares trim within their covering rows. Empty
            // shares (row-less, as the planner emits them) carry an
            // arbitrary empty span; anything with rows must contain
            // its span, or the trim arithmetic would underflow.
            partition::PlanKind::Coo => {
                (p.edge_span.0 == p.edge_span.1 && p.vertices.is_empty())
                    || (p.edge_span.0 >= row_span.0 && p.edge_span.1 <= row_span.1)
            }
        };
        if !consistent {
            bail!(
                "partition {}: edge span {:?} disagrees with this graph's offsets \
                 (rows {}..{} span {:?}) — stale or foreign plan",
                p.index,
                p.edge_span,
                p.vertices.start,
                p.vertices.end,
                row_span
            );
        }
        Ok(())
    }

    /// Decode ONE partition synchronously, blocking the caller until its
    /// block is staged. This is the distributed worker's entry point: the
    /// leader leases tiles one at a time, so a worker decodes exactly the
    /// tile it holds a lease on — no speculative prefetch of tiles that
    /// may be retiled to a sibling.
    ///
    /// The partition is cross-checked against this graph's sidecar first
    /// (same admission rule as [`validate_plan`](Self::validate_plan));
    /// on a closed/released handle this returns [`PgError::Closed`]
    /// instead of wedging on the drained buffer pool.
    pub fn decode_partition_block(
        &self,
        part: Partition,
        kind: partition::PlanKind,
    ) -> Result<LoadedPartition> {
        self.partition_consistent(&part, kind)?;
        if self.inner.shutdown.load(Ordering::Acquire) {
            return Err(PgError::Closed("graph released".into()).into());
        }
        let opts = self.options();
        let meta = BlockMeta {
            start_vertex: part.vertices.start,
            end_vertex: part.vertices.end,
            start_edge: part.edge_span.0,
            end_edge: part.edge_span.1,
        };
        let t_req = Instant::now();
        let Some(buffer_id) = self.inner.pool.acquire_idle(meta) else {
            return Err(PgError::Closed("buffer pool closed".into()).into());
        };
        self.inner.observe_buffer_claim(t_req, buffer_id);
        self.inner.stats.partition_requests.fetch_add(1, Ordering::Relaxed);
        let loaded = decode_partition(
            &self.inner,
            buffer_id,
            part,
            opts.read_ctx,
            opts.scan.as_ref(),
            opts.decode_workers,
            &self.workers,
        )?;
        self.inner.stats.partitions_staged.fetch_add(1, Ordering::Relaxed);
        let dur = t_req.elapsed();
        self.inner.obs.req_partition.record_duration(dur);
        obs::tracer().record("request", "partition", t_req, dur, 0, loaded.part.index as u64);
        Ok(loaded)
    }

    /// Serve an arbitrary [`PartitionPlan`] (computed here or received
    /// from a leader): partitions are decoded asynchronously ahead of
    /// consumption into a staging window sized by the §3 model, with
    /// decode concurrency backpressured through the buffer pool. Any
    /// number of consumer threads may drain the returned stream.
    pub fn get_partitions(&self, plan: PartitionPlan) -> Result<PartitionStream> {
        self.validate_plan(&plan)?;
        let opts = self.options();
        let window = if opts.prefetch_window > 0 {
            opts.prefetch_window
        } else {
            self.auto_prefetch_window()
        };
        self.inner.stats.partition_requests.fetch_add(1, Ordering::Relaxed);
        // Registry mirrors of the stream's counters: per-stream counts stay
        // authoritative in `StreamCounters`; these accumulate across every
        // stream of the graph for the one-snapshot view.
        let stream_obs = crate::partition::stream::StreamObs {
            produced: self.inner.metrics.counter(names::STREAM_PRODUCED),
            consumed: self.inner.metrics.counter(names::STREAM_CONSUMED),
            prefetch_hits: self.inner.metrics.counter(names::STREAM_PREFETCH_HITS),
            consumer_stalls: self.inner.metrics.counter(names::STREAM_CONSUMER_STALLS),
            producer_stalls: self.inner.metrics.counter(names::STREAM_PRODUCER_STALLS),
        };
        let shared = crate::partition::stream::StreamShared::new_with_obs(
            plan.num_parts(),
            window,
            stream_obs,
        );

        let inner = Arc::clone(&self.inner);
        let workers = Arc::clone(&self.workers);
        let shared2 = Arc::clone(&shared);
        // The partition manager: reserves a window slot, claims a buffer
        // (both block — backpressure), and hands the decode to a worker.
        let handle = std::thread::Builder::new()
            .name("pg-partition-manager".into())
            .spawn(move || {
                // Only a user cancel (or an already-poisoned stream) may end
                // production quietly; losing the graph mid-stream must
                // surface as an error, or consumers would mistake a
                // truncated drain for a complete one.
                let mut abort: Option<&str> = None;
                let mut terminal = false;
                for part in plan.parts {
                    if inner.shutdown.load(Ordering::Acquire) {
                        abort = Some("graph released while a partition stream was active");
                        break;
                    }
                    // Staging-window backpressure. `false` means the stream
                    // is already terminal: user-cancelled (quiet) or failed
                    // (already poisoned) — nothing further to report.
                    if !shared2.wait_for_window() {
                        terminal = true;
                        break;
                    }
                    // Decode-concurrency backpressure: park on the pool
                    // condvar until a buffer is recycled (None: closed).
                    let meta = BlockMeta {
                        start_vertex: part.vertices.start,
                        end_vertex: part.vertices.end,
                        start_edge: part.edge_span.0,
                        end_edge: part.edge_span.1,
                    };
                    let t_claim = Instant::now();
                    let Some(buffer_id) = inner.pool.acquire_idle(meta) else {
                        abort = Some("buffer pool closed while a partition stream was active");
                        break;
                    };
                    inner.observe_buffer_claim(t_claim, buffer_id);
                    let inner2 = Arc::clone(&inner);
                    let shared3 = Arc::clone(&shared2);
                    let scan = Arc::clone(&opts.scan);
                    let read_ctx = opts.read_ctx;
                    let decode_workers = opts.decode_workers;
                    let chunk_pool = Arc::clone(&workers);
                    workers.execute(move || {
                        let t_part = Instant::now();
                        match decode_partition(
                            &inner2, buffer_id, part, read_ctx, scan.as_ref(), decode_workers,
                            &chunk_pool,
                        ) {
                            Ok(loaded) => {
                                inner2.stats.partitions_staged.fetch_add(1, Ordering::Relaxed);
                                let dur = t_part.elapsed();
                                inner2.obs.req_partition.record_duration(dur);
                                obs::tracer().record(
                                    "request",
                                    "partition",
                                    t_part,
                                    dur,
                                    0,
                                    loaded.part.index as u64,
                                );
                                shared3.push(loaded);
                            }
                            // A shutdown-classed decode failure (handle
                            // released, pool closed, poisoned lock) keeps
                            // its type through the stream so churn reads
                            // as Closed, not corruption.
                            Err(e) => match e.downcast_ref::<PgError>() {
                                Some(PgError::Closed(_)) => shared3.fail_closed(e.to_string()),
                                _ => shared3.fail(e.to_string()),
                            },
                        }
                    });
                }
                if let Some(reason) = abort {
                    // Poison: a shutdown truncation must not read as a
                    // complete drain — and it is a *Closed*, not a decode
                    // failure, so serving-layer churn stays typed.
                    shared2.fail_closed(reason.to_string());
                } else if terminal {
                    // Cancelled/failed early exit: wake parked consumers.
                    shared2.finish_producing();
                }
                // Clean path: the final decode's push marks the stream
                // done once every partition has actually landed — marking
                // it here would race the in-flight decodes.
            })
            .context("spawn partition manager")?;
        Ok(PartitionStream::new(shared, handle))
    }

    /// Random access: the successor list of one vertex, served through the
    /// decoded-block LRU (the out-of-core request type of §4.1 use case D).
    ///
    /// The aligned `source_block_vertices`-vertex block containing `v` is
    /// decoded selectively — reference chains resolve within the block or
    /// by bounded recursion outside it — and parked in the cache, so hot
    /// neighborhoods skip re-decompression on subsequent accesses. The
    /// shared engine is [`cached_successors`](crate::formats::source::cached_successors).
    pub fn successors(&self, v: usize) -> Result<Vec<VertexId>> {
        self.successors_tagged(v, None)
    }

    /// [`successors`](Self::successors) billed to a per-tenant
    /// [`CacheTag`]: the decoded-block lookup counts on the tenant's
    /// `cache.decoded.hits.<tenant>` counter and the insert is charged
    /// against the tenant's resident-cost quota
    /// ([`DecodedCache::insert_tagged`] evicts the tenant's own LRU
    /// entries first). The serve layer resolves tags through
    /// [`register_cache_tenant`](Self::register_cache_tenant).
    pub fn successors_tagged(
        &self,
        v: usize,
        tag: Option<crate::storage::cache::CacheTag>,
    ) -> Result<Vec<VertexId>> {
        let inner = &self.inner;
        let mut span = SpanGuard::new("request", "successors")
            .with_hist(inner.obs.req_successors.clone());
        span.set_arg(v as u64);
        let list = crate::formats::source::cached_successors_tagged(
            &inner.decoded_cache,
            inner.source_block_vertices,
            inner.meta.num_vertices,
            v,
            tag,
            |lo, hi| {
                let opts = self.options();
                run_with_healing(inner, opts.read_ctx, lo, hi, || {
                    let dec = Decoder::open(
                        &inner.store,
                        &inner.base,
                        &inner.meta,
                        &inner.offsets,
                        opts.read_ctx,
                        &inner.random_acct,
                    )?;
                    let decoded = dec.decode_range_with_scan(
                        lo,
                        hi,
                        &inner.random_acct,
                        opts.scan.as_ref(),
                    )?;
                    inner.stats.blocks_decoded.fetch_add(1, Ordering::Relaxed);
                    Ok(decoded)
                })
            },
        )?;
        inner.stats.random_accesses.fetch_add(1, Ordering::Relaxed);
        Ok(list)
    }

    /// Counters of the random-access decoded-block cache.
    pub fn decoded_cache_counters(&self) -> CacheCounters {
        self.inner.decoded_cache.counters()
    }

    /// Register tenant `name` with this graph's decoded-block cache:
    /// resolves `cache.decoded.{hits,evictions}.<name>` counters from the
    /// graph's registry and installs `quota_cost` (cost units, 0 = no
    /// quota) as the tenant's resident ceiling. Returns the [`CacheTag`]
    /// to pass to [`successors_tagged`](Self::successors_tagged).
    /// Re-registering updates the quota and returns the same tag.
    pub fn register_cache_tenant(
        &self,
        name: &str,
        quota_cost: u64,
    ) -> crate::storage::cache::CacheTag {
        let metrics = &self.inner.metrics;
        self.inner.decoded_cache.register_tag(
            name,
            quota_cost,
            metrics.counter(&names::cache_tenant_hits(name)),
            metrics.counter(&names::cache_tenant_evictions(name)),
        )
    }

    /// Resident decoded-cache cost currently billed to `tag`.
    pub fn cache_tenant_resident(&self, tag: crate::storage::cache::CacheTag) -> u64 {
        self.inner.decoded_cache.tag_resident_cost(tag)
    }

    /// This graph's metrics registry (counters + latency histograms for
    /// the whole load path). Resolve handles by the names in
    /// [`crate::obs::names`].
    pub fn metrics_registry(&self) -> &Arc<MetricsRegistry> {
        &self.inner.metrics
    }

    /// Point-in-time snapshot of every metric of this graph — the
    /// mergeable/serializable unit the distributed worker ships to its
    /// leader and `ci-summary --json` exports.
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.inner.sync_fault_obs();
        self.inner.metrics.snapshot()
    }

    /// The store this graph reads from — the handle fault campaigns use to
    /// install/clear a [`FaultPlan`](crate::storage::FaultPlan) underneath
    /// a live graph.
    pub fn store(&self) -> &Arc<SimStore> {
        &self.inner.store
    }

    /// Blocks currently quarantined by the self-healing read path.
    pub fn quarantined_blocks(&self) -> usize {
        lock_recover(&self.inner.quarantine).len()
    }

    /// Lift every quarantine (e.g. after clearing a fault plan or
    /// repairing the underlying files); returns how many blocks were
    /// released. The obs counter keeps its cumulative count — it records
    /// quarantine *events*, not current membership.
    pub fn clear_quarantine(&self) -> usize {
        let mut q = lock_recover(&self.inner.quarantine);
        let n = q.len();
        q.clear();
        n
    }

    /// Virtual-I/O + CPU account charged by the random-access path
    /// (selective reads), mirroring `WebGraphSource::io_account`.
    pub fn random_access_account(&self) -> &IoAccount {
        &self.inner.random_acct
    }

    /// Join all library threads, drop the OS cache (§4.1 discipline).
    pub fn release(self) {
        self.shutdown_and_join();
    }

    /// [`release`](Self::release) through a shared reference — the serving
    /// front-end's churn path, where the handle lives in an `Arc` with
    /// clones still held by in-flight requests. Sets the shutdown flag,
    /// closes the buffer pool (poisoning in-flight streams into typed
    /// [`PgError::Closed`] failures instead of hangs), clears the decoded
    /// cache, joins every dispatcher this handle spawned, and drops the OS
    /// cache. Idempotent: a second call finds the dispatcher list empty
    /// and the flags already set.
    pub fn shutdown_and_join(&self) {
        let trace_path = lock_recover(&self.inner.options).trace_path.clone();
        self.inner.shutdown.store(true, Ordering::Release);
        self.inner.pool.close(); // wake any parked request managers
        self.inner.decoded_cache.clear();
        let handles: Vec<_> = {
            // Shutdown must complete even after a dispatcher panicked
            // (which poisons this lock); the Vec itself is never left
            // torn by a panic elsewhere.
            let mut d = lock_recover(&self.dispatchers);
            d.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
        // Worker/callback pools join on drop (Arc: last owner joins).
        self.inner.store.drop_cache();
        // Export after every library thread has quiesced, so the trace
        // covers the whole request history of this handle.
        if let Some(path) = trace_path {
            if let Err(e) = obs::tracer().export(&path) {
                eprintln!("trace export to {} failed: {e}", path.display());
            }
        }
    }
}

/// Both request types over the same opened handle: `successors` is the
/// random-access path (decoded-block cache), `decode_range` streams through
/// the event-driven block pipeline.
impl GraphSource for PgGraph {
    fn num_vertices(&self) -> usize {
        PgGraph::num_vertices(self)
    }

    fn num_edges(&self) -> u64 {
        PgGraph::num_edges(self)
    }

    fn successors(&self, v: usize) -> Result<Vec<VertexId>> {
        PgGraph::successors(self, v)
    }

    fn decode_range(&self, lo: usize, hi: usize) -> Result<DecodedBlock> {
        self.csx_get_subgraph_sync(VertexRange::new(lo, hi))
    }
}

impl Drop for PgGraph {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::Release);
        self.inner.pool.close(); // wake any parked request managers
        let handles: Vec<_> = {
            // Same poison recovery as `release`: drop must never panic.
            let mut d = lock_recover(&self.dispatchers);
            d.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

/// The self-healing read policy (DESIGN.md § Fault injection): run `body`
/// (a re-runnable decode attempt over vertices `start_vertex..end_vertex`),
/// and on failure classify the block's `.graph` byte range against the
/// checksums sidecar through the *infallible* store paths:
///
/// * **Mismatch** — the data at rest is corrupt: quarantine the block and
///   return [`PgError::Corrupt`] with the offending chunk. Retrying cannot
///   help, so no retry is burned.
/// * **Ok / Unverifiable** — the bytes at rest are fine (or no sidecar can
///   say): treat the failure as transient and retry with doubling backoff
///   up to `Options::read_retries` times; when the budget is exhausted,
///   quarantine the block and return [`PgError::Faulted`].
///
/// Already-typed errors pass straight through: [`PgError::Closed`] means
/// the handle (not the data) is the problem, and [`PgError::Corrupt`] was
/// already classified by a lower layer. A quarantined block fails fast on
/// entry — one flaky region must not re-pay the retry budget per request.
fn run_with_healing<T>(
    inner: &GraphInner,
    read_ctx: ReadCtx,
    start_vertex: usize,
    end_vertex: usize,
    mut body: impl FnMut() -> Result<T>,
) -> Result<T> {
    let key = (start_vertex, end_vertex);
    if lock_recover(&inner.quarantine).contains(&key) {
        return Err(PgError::Faulted(format!(
            "block {start_vertex}..{end_vertex} is quarantined after repeated read faults"
        ))
        .into());
    }
    let (retries, backoff) = {
        let o = lock_recover(&inner.options);
        (o.read_retries, o.retry_backoff)
    };
    let mut attempt = 0u32;
    loop {
        let err = match body() {
            Ok(v) => return Ok(v),
            Err(e) => e,
        };
        inner.sync_fault_obs();
        match err.downcast_ref::<PgError>() {
            Some(PgError::Closed(_)) | Some(PgError::Corrupt(_)) => return Err(err),
            _ => {}
        }
        // Classify the block's compressed byte range against the sidecar.
        let byte0 = inner.offsets.bit_offset(start_vertex) / 8;
        let byte1 = inner.offsets.bit_offset(end_vertex).div_ceil(8);
        let verdict = webgraph::integrity::classify_range(
            &inner.store,
            &inner.base,
            byte0,
            byte1,
            read_ctx,
            &inner.random_acct,
        );
        if let webgraph::integrity::Verdict::Mismatch { chunk } = verdict {
            lock_recover(&inner.quarantine).insert(key);
            inner.obs.block_quarantined.inc();
            return Err(PgError::Corrupt(format!(
                "checksum mismatch in chunk {chunk} covering vertices \
                 {start_vertex}..{end_vertex}: {err:#}"
            ))
            .into());
        }
        // Transient (sidecar says the bytes at rest are fine, or cannot
        // say): retry inside the budget, quarantine past it.
        if attempt >= retries {
            lock_recover(&inner.quarantine).insert(key);
            inner.obs.block_quarantined.inc();
            return Err(PgError::Faulted(format!(
                "transient fault persisted through {} attempts at vertices \
                 {start_vertex}..{end_vertex}: {err:#}",
                attempt + 1
            ))
            .into());
        }
        inner.obs.read_retries.inc();
        // Doubling backoff, capped at 2^10 so a generous retry budget
        // cannot compound into a multi-minute sleep.
        std::thread::sleep(backoff * 2u32.saturating_pow(attempt.min(10)));
        attempt += 1;
    }
}

/// Producer-side block decode: claim C_REQUESTED -> J_READING, decode
/// *straight into* the buffer's storage, publish J_READ_COMPLETED (or fail
/// back to C_IDLE). Returns true when the buffer holds a decoded block
/// (status J_READ_COMPLETED).
///
/// Zero-copy delivery: the claimed buffer's `BufferData` vectors are
/// pre-reserved off the Elias–Fano sidecar and handed to the decoder as a
/// [`DecodeSink`], so the default (`decode_workers == 1`) path materializes
/// no intermediate `DecodedBlock` and performs no post-decode memcpy — the
/// former `extend_from_slice` hand-off is gone, and every payload byte is
/// counted in [`GraphStats::copy_bytes_avoided`]. A weighted graph's
/// sidecar decodes its `f32`s straight into `data.weights` off the
/// borrowed file image (no intermediate byte vector) on the zero-copy
/// reader. Holding `buf.data` across the decode is safe: the status
/// protocol makes J_READING the producer's exclusive-ownership state.
///
/// With `decode_workers > 1` the decode fans out over chunk workers as
/// borrowed scoped jobs on the shared coordinator pool
/// ([`Decoder::decode_range_parallel_sink`]): the sink is pre-sized off
/// the offsets sidecar and each chunk writes its disjoint slice of the
/// buffer in place — no post-decode stitch, so
/// [`GraphStats::delivery_copy_bytes`] stays 0 on this path too (only the
/// oversized-block stitched fallback still counts there). Each chunk
/// worker carries its own virtual clock; the block's modeled decode time —
/// max over the chunk workers, plus the sequential weights phase — is
/// accumulated into [`GraphStats::decode_seconds`].
///
/// Every chunk decodes through its worker thread's persistent
/// [`DecodeScratch`](crate::formats::webgraph::DecodeScratch): the pool
/// threads outlive individual blocks, so steady-state block decode reuses
/// warmed parse/ring/residual buffers and performs no per-vertex heap
/// allocation.
#[allow(clippy::too_many_arguments)]
fn decode_into_buffer(
    inner: &GraphInner,
    buffer_id: usize,
    meta: BlockMeta,
    read_ctx: ReadCtx,
    scan: &dyn ScanEngine,
    decode_workers: usize,
    chunk_pool: &ThreadPool,
    req: &ReadRequest,
) -> bool {
    let buf = inner.pool.get(buffer_id);
    if !buf.try_claim(BufferStatus::CRequested, BufferStatus::JReading) {
        req.record_failure(format!("buffer {buffer_id} not in requested state"));
        return false;
    }
    let accounts: Vec<IoAccount> =
        (0..decode_workers.max(1)).map(|_| IoAccount::new()).collect();
    // The weights sidecar read is a sequential phase *after* the chunk
    // fan-out, so it gets its own account and composes additively with the
    // chunk-worker max — billing it to `accounts[0]` (as the pre-zero-copy
    // pipeline did) let it hide under a slower sibling chunk whenever
    // worker 0 was not the block's critical path.
    let weights_acct = IoAccount::new();
    let t0 = Instant::now();
    // The attempt body is re-runnable — `data.clear()` leads every attempt,
    // so a retry decodes into a clean buffer — which is what lets
    // `run_with_healing` drive it under the retry/quarantine policy.
    let result = run_with_healing(inner, read_ctx, meta.start_vertex, meta.end_vertex, || {
        let dec = Decoder::open(
            &inner.store,
            &inner.base,
            &inner.meta,
            &inner.offsets,
            read_ctx,
            &accounts[0],
        )?;
        // A sibling thread that panicked while holding this buffer's data
        // poisons the lock; this block is about to overwrite the payload
        // wholesale, so surface it as a failed block (`PgError::Closed`
        // through `record_failure`) rather than cascading the panic into
        // this dispatcher too.
        let mut data = lock_clean(&buf.data, "buffer data")?;
        data.clear();
        // Pre-reserve the exact block shape off the sidecar (capped by the
        // decoder's shared guard, so a forged sidecar cannot force an
        // unbounded allocation).
        data.offsets.reserve(meta.num_vertices() + 1);
        data.edges
            .reserve((meta.num_edges() as usize).min(webgraph::MAX_SIDECAR_RESERVE_EDGES));
        let stitched = {
            let buffer::BufferData { offsets, edges, .. } = &mut *data;
            let mut sink = DecodeSink::new(offsets, edges);
            dec.decode_range_parallel_sink(
                meta.start_vertex,
                meta.end_vertex,
                &accounts,
                scan,
                Some(chunk_pool),
                &mut sink,
            )?
        };
        // The stream's degrees are authoritative for the decode, but the
        // rest of the delivery pipeline (COO trims, sync assembly, edge
        // accounting) derives positions from the sidecar — a disagreement
        // must fail the block, not silently misplace edges.
        if data.offsets.len() != meta.num_vertices() + 1
            || *data.offsets.last().unwrap_or(&0) != meta.num_edges()
        {
            bail!(
                "decoded block shape disagrees with the offsets sidecar at vertices {}..{}",
                meta.start_vertex,
                meta.end_vertex
            );
        }
        if inner.gtype.weighted() {
            let name = format!("{}.weights", inner.base);
            let file = inner
                .store
                .open(&name)
                .with_context(|| format!("missing {name}"))?;
            read_weights_into(
                &file,
                meta.start_edge * 4,
                meta.num_edges() * 4,
                read_ctx,
                &weights_acct,
                &mut data.weights,
            )
            .with_context(|| {
                format!("weights sidecar at edges {}..{}", meta.start_edge, meta.end_edge)
            })?;
        }
        let payload = (data.offsets.len() * std::mem::size_of::<u64>()
            + data.edges.len() * std::mem::size_of::<VertexId>()
            + data.weights.len() * std::mem::size_of::<crate::graph::Weight>())
            as u64;
        Ok((payload, stitched))
    });
    match result {
        Ok((payload, stitched)) => {
            let modeled =
                crate::storage::vclock::phase_elapsed(&accounts) + weights_acct.elapsed_seconds();
            let real = t0.elapsed();
            inner.obs.decode_block_real.record_duration(real);
            inner.obs.decode_block_virt.record_secs(modeled);
            obs::tracer().record(
                "decode",
                "decode-block",
                t0,
                real,
                (modeled * 1e9) as u64,
                meta.start_vertex as u64,
            );
            inner.stats.decode_seconds.fetch_add((modeled * 1e9) as u64, Ordering::Relaxed);
            inner.stats.blocks_decoded.fetch_add(1, Ordering::Relaxed);
            inner.stats.edges_decoded.fetch_add(meta.num_edges(), Ordering::Relaxed);
            // Zero-copy accounting: the former pipeline memcpy'd the whole
            // payload from an owned block into the buffer; the sink path
            // writes in place on both worker shapes (stitched is 0 except
            // the oversized-block fallback).
            inner
                .stats
                .copy_bytes_avoided
                .fetch_add(payload.saturating_sub(stitched), Ordering::Relaxed);
            inner.stats.delivery_copy_bytes.fetch_add(stitched, Ordering::Relaxed);
            inner.stats.delivery_edges.fetch_add(meta.num_edges(), Ordering::Relaxed);
            inner
                .stats
                .delivery_wall_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            buf.set_status(BufferStatus::JReadCompleted);
            true
        }
        Err(e) => {
            inner.pool.recycle(buffer_id);
            req.record_failure_typed(&e);
            false
        }
    }
}

/// Decode a `.weights` sidecar span (little-endian `f32`s) straight into
/// `out` — no intermediate byte vector on the default zero-copy reader;
/// the managed `BufferedCopy` reader keeps its modeled staging pipeline.
///
/// A truncated or corrupt sidecar (short read past EOF, or a byte length
/// that is not a multiple of 4) is a [`PgError::Corrupt`] error, never a
/// panic: the store clamps out-of-range reads at EOF like `pread`, so a
/// truncated file surfaces here as `bytes.len() < byte_len` and must fail
/// the block cleanly. Reads go through the *fallible* store path, so an
/// injected [`IoFault`](crate::storage::IoFault) propagates untyped and
/// the healing policy treats it as transient. A short *result* is typed by
/// what the file actually holds: if the file has the requested bytes the
/// shortfall was a torn read (untyped ⇒ transient, retryable); only a file
/// that is genuinely too small is [`PgError::Corrupt`].
fn read_weights_into(
    file: &crate::storage::SimFile<'_>,
    byte_offset: u64,
    byte_len: u64,
    ctx: ReadCtx,
    acct: &IoAccount,
    out: &mut Vec<crate::graph::Weight>,
) -> Result<()> {
    out.clear();
    let bytes = file.try_read_borrowed(byte_offset, byte_len, ctx, acct)?;
    if bytes.len() as u64 != byte_len || bytes.len() % 4 != 0 {
        if byte_offset + byte_len <= file.len() {
            // The file holds the requested span, so the shortfall came from
            // the read itself — transient, let the healing policy retry.
            bail!(
                "torn weights read: wanted {byte_len} bytes at offset {byte_offset}, \
                 read yielded {}",
                bytes.len()
            );
        }
        return Err(PgError::Corrupt(format!(
            "weights sidecar truncated or torn: wanted {byte_len} bytes at offset \
             {byte_offset}, file yields {}",
            bytes.len()
        ))
        .into());
    }
    out.reserve(bytes.len() / 4);
    out.extend(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])));
    Ok(())
}

/// Producer-side partition decode: claim the buffer (C_REQUESTED ->
/// J_READING), decode the partition's rows, filter to its tile, and
/// recycle. The buffer serves as the decode-concurrency token only —
/// consumers own their partitions outright (multi-consumer hand-off
/// outlives any buffer reuse), so routing the decoded vectors through
/// `BufferData` would both strip the buffer's warmed capacity (hurting
/// the block-request path that relies on it) and add an unreachable
/// hand-off state. For the same reason partition decode deliberately stays
/// on the *owned* (`decode_range_parallel_on`) path rather than the
/// zero-copy `DecodeSink`: the decoded vectors ARE the deliverable the
/// consumer keeps, there is no second home to copy them into, and a sink
/// aimed at the recycled buffer would reintroduce exactly the hand-off
/// copy the sink exists to remove. The buffer is recycled on *every* exit
/// path — a leaked claim would shrink the pool for the rest of the run.
fn decode_partition(
    inner: &GraphInner,
    buffer_id: usize,
    part: Partition,
    read_ctx: ReadCtx,
    scan: &dyn ScanEngine,
    decode_workers: usize,
    chunk_pool: &ThreadPool,
) -> Result<LoadedPartition> {
    let buf = inner.pool.get(buffer_id);
    if !buf.try_claim(BufferStatus::CRequested, BufferStatus::JReading) {
        // Not ours to recycle: another owner holds the status.
        bail!("buffer {buffer_id} not in requested state");
    }
    let accounts: Vec<IoAccount> =
        (0..decode_workers.max(1)).map(|_| IoAccount::new()).collect();
    let t0 = Instant::now();
    let result = run_with_healing(inner, read_ctx, part.vertices.start, part.vertices.end, || {
        let dec = Decoder::open(
            &inner.store,
            &inner.base,
            &inner.meta,
            &inner.offsets,
            read_ctx,
            &accounts[0],
        )?;
        let rows = dec.decode_range_parallel_on(
            part.vertices.start,
            part.vertices.end,
            &accounts,
            scan,
            Some(chunk_pool),
        )?;
        let row_span = (
            inner.offsets.edge_offset(part.vertices.start),
            inner.offsets.edge_offset(part.vertices.end),
        );
        Ok(filter_partition_block(
            rows,
            &part,
            row_span,
            inner.meta.num_vertices,
        ))
    });
    match result {
        Ok(block) => {
            let modeled = crate::storage::vclock::phase_elapsed(&accounts);
            let real = t0.elapsed();
            inner.obs.decode_block_real.record_duration(real);
            inner.obs.decode_block_virt.record_secs(modeled);
            obs::tracer().record(
                "decode",
                "decode-partition",
                t0,
                real,
                (modeled * 1e9) as u64,
                part.index as u64,
            );
            inner.stats.decode_seconds.fetch_add((modeled * 1e9) as u64, Ordering::Relaxed);
            inner.stats.blocks_decoded.fetch_add(1, Ordering::Relaxed);
            inner.stats.edges_decoded.fetch_add(block.num_edges(), Ordering::Relaxed);
            inner.pool.recycle(buffer_id); // J_READING -> C_IDLE: token released
            Ok(LoadedPartition { part, block })
        }
        Err(e) => {
            inner.pool.recycle(buffer_id);
            Err(e)
        }
    }
}

/// Restrict a partition's decoded rows to its tile: drop edges whose
/// target falls outside `part.targets` (2D tiles) and edges outside
/// `part.edge_span` (COO splits). 1D partitions pass through untouched.
/// `row_span` is the global edge span of the decoded rows, which indexes
/// the block's edges globally.
fn filter_partition_block(
    rows: DecodedBlock,
    part: &Partition,
    row_span: (u64, u64),
    num_vertices: usize,
) -> DecodedBlock {
    let full_targets = part.targets.start == 0 && part.targets.end == num_vertices;
    let exact_span = part.edge_span == row_span;
    if full_targets && exact_span {
        return rows;
    }
    // Local window of the COO trim (the whole block when exact_span).
    let local_lo = (part.edge_span.0 - row_span.0) as usize;
    let local_hi = (part.edge_span.1 - row_span.0) as usize;
    let mut out = DecodedBlock {
        first_vertex: rows.first_vertex,
        offsets: Vec::with_capacity(rows.offsets.len()),
        edges: Vec::new(),
    };
    out.offsets.push(0);
    for i in 0..rows.num_vertices() {
        let (s, e) = rows.vertex_span(i);
        let (s, e) = (s.max(local_lo), e.min(local_hi));
        if s < e {
            let row = &rows.edges[s..e];
            if full_targets {
                out.edges.extend_from_slice(row);
            } else {
                // Rows are sorted: the tile's columns are one subslice.
                let lo = row.partition_point(|&d| (d as usize) < part.targets.start);
                let hi = row.partition_point(|&d| (d as usize) < part.targets.end);
                out.edges.extend_from_slice(&row[lo..hi]);
            }
        }
        out.offsets.push(out.edges.len() as u64);
    }
    out
}

/// Consumer-side completion: J_READ_COMPLETED -> C_USER_ACCESS, run the
/// user's callback, recycle the buffer to C_IDLE.
fn run_user_callback(
    inner: &GraphInner,
    buffer_id: usize,
    meta: BlockMeta,
    callback: &BlockCallback,
    req: &ReadRequest,
) {
    let buf = inner.pool.get(buffer_id);
    if !buf.try_claim(BufferStatus::JReadCompleted, BufferStatus::CUserAccess) {
        req.record_failure(format!("buffer {buffer_id} not completed"));
        return;
    }
    let mut span = SpanGuard::new("delivery", "user-callback");
    span.set_arg(meta.start_vertex as u64);
    {
        // A poisoned payload lock (panicked sibling) fails this block
        // cleanly and recycles — one bad dispatcher must not wedge every
        // later request on the handle.
        let data = match lock_clean(&buf.data, "buffer data") {
            Ok(d) => d,
            Err(e) => {
                req.record_failure_typed(&e.into());
                inner.pool.recycle(buffer_id);
                return;
            }
        };
        let blk = EdgeBlock {
            buffer_id,
            start_vertex: meta.start_vertex,
            end_vertex: meta.end_vertex,
            start_edge: meta.start_edge,
            offsets: &data.offsets,
            edges: &data.edges,
            weights: if data.weights.is_empty() { None } else { Some(&data.weights) },
        };
        // User panics must not wedge the pipeline: catch, fail the request,
        // still recycle the buffer.
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| callback(&blk)));
        if res.is_err() {
            req.record_failure("user callback panicked".into());
            inner.pool.recycle(buffer_id);
            return;
        }
    }
    inner.pool.recycle(buffer_id);
    req.record_block(meta.num_edges());
}
