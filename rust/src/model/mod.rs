//! The paper's §3 performance model of compressed-graph loading.
//!
//! With storage read bandwidth σ (bytes/s), compression ratio r (> 1) and
//! decompression bandwidth d (bytes of *uncompressed* output per second),
//! the achievable load bandwidth b (uncompressed bytes/s) satisfies
//!
//! ```text
//!     σ ≤ b ≤ min(σ·r, d)
//! ```
//!
//! (Fig. 1). Loading an uncompressed format is the r = 1, d = ∞ corner.
//! This module evaluates the model, generates the Fig. 1 curves, and
//! calibrates d from measured decode runs — used by the benches to check
//! measured numbers sit inside the model envelope.

use crate::util::json::Json;

/// Model inputs for one configuration.
#[derive(Debug, Clone, Copy)]
pub struct LoadModel {
    /// Storage read bandwidth, bytes/s.
    pub sigma: f64,
    /// Compression ratio r (uncompressed bytes / compressed bytes).
    pub r: f64,
    /// Decompression bandwidth, uncompressed bytes/s (f64::INFINITY for
    /// uncompressed formats).
    pub d: f64,
}

impl LoadModel {
    /// Upper bound on load bandwidth (uncompressed bytes/s): min(σ·r, d).
    pub fn upper_bound(&self) -> f64 {
        (self.sigma * self.r).min(self.d)
    }

    /// Lower bound: σ (the paper's b ≥ σ — compression never loses).
    pub fn lower_bound(&self) -> f64 {
        self.sigma
    }

    /// Is this configuration storage-bound (σ·r < d) or compute-bound?
    pub fn storage_bound(&self) -> bool {
        self.sigma * self.r < self.d
    }

    /// The compression ratio beyond which more compression stops helping
    /// (Fig. 1's knee): r* = d / σ.
    pub fn knee_ratio(&self) -> f64 {
        self.d / self.sigma
    }

    /// Expected load time for `uncompressed_bytes` of graph data, assuming
    /// the bound is achieved (used for sanity envelopes, not predictions).
    pub fn min_load_seconds(&self, uncompressed_bytes: u64) -> f64 {
        uncompressed_bytes as f64 / self.upper_bound()
    }
}

/// One point of a Fig. 1 curve.
#[derive(Debug, Clone, Copy)]
pub struct CurvePoint {
    pub r: f64,
    pub bound: f64,
}

/// Generate the Fig. 1 curve: load-bandwidth upper bound as a function of
/// compression ratio r ∈ [1, r_max], for given σ and d.
pub fn fig1_curve(sigma: f64, d: f64, r_max: f64, points: usize) -> Vec<CurvePoint> {
    let points = points.max(2);
    (0..points)
        .map(|i| {
            let r = 1.0 + (r_max - 1.0) * i as f64 / (points - 1) as f64;
            let m = LoadModel { sigma, r, d };
            CurvePoint { r, bound: m.upper_bound() }
        })
        .collect()
}

/// Calibrate d from a measured decode run: `uncompressed_bytes` produced in
/// `cpu_seconds` of decode CPU time across `workers` workers.
pub fn calibrate_d(uncompressed_bytes: u64, cpu_seconds: f64, workers: usize) -> f64 {
    if cpu_seconds <= 0.0 {
        return f64::INFINITY;
    }
    // Per-core decode bandwidth × workers = aggregate d.
    uncompressed_bytes as f64 / cpu_seconds * workers as f64
}

/// End-to-end elapsed time of a partitioned load interleaved with
/// execution: partition `i` takes `loads[i]` seconds to stage and
/// `consumes[i]` seconds to process, the loader may run at most `window`
/// partitions ahead of the consumer (the prefetch-window backpressure),
/// and partitions are consumed in order. The recurrence
///
/// ```text
///     S_i = max(C_{i-1}, L_i)                          (consume start)
///     L_i = max(L_{i-1}, S_{i-window}) + loads[i]      (pipeline + window)
///     C_i = S_i + consumes[i]
/// ```
///
/// yields the classic two-stage bounded-buffer pipeline, where `S_j`
/// (the gate) is the consume *start* of partition `j` — a staging slot is
/// freed at hand-off, matching the `PartitionStream` protocol. The result
/// is always ≥ max(Σloads, Σconsumes) (the §3 envelope floor — the slower
/// side is the bottleneck) and ≤ Σloads + Σconsumes (the load-then-execute
/// sequential baseline), with equality to the floor when the window hides
/// all of the faster side's latency.
pub fn interleaved_elapsed(loads: &[f64], consumes: &[f64], window: usize) -> f64 {
    assert_eq!(loads.len(), consumes.len(), "one consume per load");
    let window = window.max(1);
    let mut load_done = 0.0f64;
    let mut consume_done = 0.0f64;
    let mut consume_starts: Vec<f64> = Vec::with_capacity(loads.len());
    for i in 0..loads.len() {
        let gate = if i >= window { consume_starts[i - window] } else { 0.0 };
        load_done = load_done.max(gate) + loads[i];
        let start = consume_done.max(load_done);
        consume_starts.push(start);
        consume_done = start + consumes[i];
    }
    consume_done
}

/// The load-then-execute baseline the interleaved pipeline is measured
/// against: stage everything, then process everything.
pub fn sequential_elapsed(loads: &[f64], consumes: &[f64]) -> f64 {
    loads.iter().sum::<f64>() + consumes.iter().sum::<f64>()
}

/// Fraction of the smaller phase hidden by interleaving: 0 = fully serial,
/// 1 = perfect overlap (elapsed hit the max(Σl, Σc) floor).
pub fn overlap_fraction(loads: &[f64], consumes: &[f64], window: usize) -> f64 {
    let l: f64 = loads.iter().sum();
    let c: f64 = consumes.iter().sum();
    let hideable = l.min(c);
    if hideable <= 0.0 {
        return 0.0;
    }
    let saved = sequential_elapsed(loads, consumes) - interleaved_elapsed(loads, consumes, window);
    (saved / hideable).clamp(0.0, 1.0)
}

/// Serialize a curve for the bench JSON output.
pub fn curve_to_json(curve: &[CurvePoint]) -> Json {
    let mut arr = Json::Arr(vec![]);
    for p in curve {
        let mut o = Json::obj();
        o.set("r", p.r).set("bound", p.bound);
        arr.push(o);
    }
    arr
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: f64 = 1e6;
    const GB: f64 = 1e9;

    #[test]
    fn bounds_ordering() {
        let m = LoadModel { sigma: 160.0 * MB, r: 8.0, d: 1.0 * GB };
        assert!(m.lower_bound() <= m.upper_bound());
        assert_eq!(m.upper_bound(), 1.0 * GB); // min(1.28G, 1G) = d
        assert!(!m.storage_bound());
    }

    #[test]
    fn hdd_is_storage_bound_ssd_compute_bound() {
        // The paper's qualitative claim: on HDD the ratio dominates, on SSD
        // the decompression bandwidth does. Take d ≈ 1 GB/s of decode.
        let d = 1.0 * GB;
        let hdd = LoadModel { sigma: 160.0 * MB, r: 5.0, d };
        let ssd = LoadModel { sigma: 3.6 * GB, r: 5.0, d };
        assert!(hdd.storage_bound(), "HDD: σ·r = 0.8G < d");
        assert!(!ssd.storage_bound(), "SSD: σ·r = 18G > d");
        assert!(hdd.knee_ratio() > 5.0);
        assert!(ssd.knee_ratio() < 1.0);
    }

    #[test]
    fn fig1_curve_shape() {
        let curve = fig1_curve(160.0 * MB, 1.0 * GB, 35.0, 100);
        assert_eq!(curve.len(), 100);
        assert!((curve[0].bound - 160.0 * MB).abs() < 1e-3);
        // Monotone non-decreasing, capped at d.
        for w in curve.windows(2) {
            assert!(w[1].bound >= w[0].bound - 1e-9);
        }
        assert_eq!(curve.last().unwrap().bound, 1.0 * GB);
        // The knee sits at r* = d/σ = 6.25.
        let knee = 1.0 * GB / (160.0 * MB);
        let below = curve.iter().filter(|p| p.r < knee - 0.5).all(|p| p.bound < 1.0 * GB);
        assert!(below, "below the knee the curve must still climb");
    }

    #[test]
    fn calibration() {
        assert_eq!(calibrate_d(1_000_000, 1.0, 1), 1e6);
        assert_eq!(calibrate_d(1_000_000, 0.5, 4), 8e6);
        assert!(calibrate_d(1, 0.0, 1).is_infinite());
    }

    #[test]
    fn uncompressed_corner() {
        let m = LoadModel { sigma: 500.0 * MB, r: 1.0, d: f64::INFINITY };
        assert_eq!(m.upper_bound(), 500.0 * MB);
        assert!(m.storage_bound());
    }

    #[test]
    fn interleaved_pipeline_envelope() {
        let loads = vec![1.0; 8];
        let consumes = vec![0.5; 8];
        let seq = sequential_elapsed(&loads, &consumes);
        assert!((seq - 12.0).abs() < 1e-9);
        for window in [1usize, 2, 4, 8] {
            let t = interleaved_elapsed(&loads, &consumes, window);
            assert!(t < seq, "window {window}: {t} must beat sequential {seq}");
            assert!(t >= 8.0 - 1e-9, "window {window}: below the Σloads floor");
            assert!(t <= seq + 1e-9);
        }
        // Load-bound pipeline with any window ≥ 1 hides all consumption
        // except the last partition's: 8·1.0 + 0.5.
        let t1 = interleaved_elapsed(&loads, &consumes, 1);
        assert!((t1 - 8.5).abs() < 1e-9, "got {t1}");
        assert!(overlap_fraction(&loads, &consumes, 1) > 0.85);
    }

    #[test]
    fn interleaved_window_matters_when_consumer_is_slow() {
        // Consumer-bound: one slow consume stalls a window-1 loader, a
        // deeper window absorbs it.
        let loads = vec![1.0, 1.0, 1.0, 1.0];
        let consumes = vec![4.0, 0.1, 0.1, 4.0];
        let shallow = interleaved_elapsed(&loads, &consumes, 1);
        let deep = interleaved_elapsed(&loads, &consumes, 4);
        assert!(deep <= shallow + 1e-9, "deeper window cannot be slower");
        assert!(deep < sequential_elapsed(&loads, &consumes));
        let floor = 4.0f64.max(consumes.iter().sum::<f64>());
        assert!(deep >= floor - 1e-9);
    }

    #[test]
    fn interleaved_degenerate_inputs() {
        assert_eq!(interleaved_elapsed(&[], &[], 3), 0.0);
        let t = interleaved_elapsed(&[2.0], &[3.0], 1);
        assert!((t - 5.0).abs() < 1e-9, "single partition cannot overlap");
        assert_eq!(overlap_fraction(&[], &[], 1), 0.0);
    }
}

/// §6 "Network-Based Distributed Decompression": instead of every machine
/// decompressing independently, decompression is divided across `machines`
/// and results are exchanged over a network of bandwidth `net` (bytes/s of
/// uncompressed data). This extends the §3 model with a network limb:
///
/// ```text
///     b_dist ≤ min(σ·r, machines·d_one, net)
/// ```
///
/// Useful when d is the binding constraint and the network is faster than
/// a single machine's decompression.
#[derive(Debug, Clone, Copy)]
pub struct DistributedModel {
    pub base: LoadModel,
    /// Per-machine decompression bandwidth (uncompressed bytes/s).
    pub d_one: f64,
    pub machines: usize,
    /// Network bandwidth for sharing decompressed blocks.
    pub net: f64,
}

impl DistributedModel {
    pub fn upper_bound(&self) -> f64 {
        (self.base.sigma * self.base.r)
            .min(self.d_one * self.machines as f64)
            .min(self.net)
    }

    /// Does distributing help over single-machine decompression?
    pub fn beneficial(&self) -> bool {
        self.upper_bound() > LoadModel { d: self.d_one, ..self.base }.upper_bound()
    }

    /// Smallest machine count that saturates the other limbs.
    pub fn saturating_machines(&self) -> usize {
        let target = (self.base.sigma * self.base.r).min(self.net);
        (target / self.d_one).ceil().max(1.0) as usize
    }
}

#[cfg(test)]
mod dist_tests {
    use super::*;

    #[test]
    fn distribution_lifts_the_d_limb() {
        // SSD, decode-bound single machine: distributing decompression
        // raises throughput until the network limb binds.
        let base = LoadModel { sigma: 3.6e9, r: 8.0, d: 1e9 };
        let m = DistributedModel { base, d_one: 1e9, machines: 4, net: 10e9 };
        assert!(m.beneficial());
        assert_eq!(m.upper_bound(), 4e9);
        // With a slow network the new limb binds instead.
        let slow = DistributedModel { net: 2e9, ..m };
        assert_eq!(slow.upper_bound(), 2e9);
        assert!(slow.beneficial());
        // Storage-bound configs gain nothing.
        let hdd = DistributedModel {
            base: LoadModel { sigma: 160e6, r: 2.0, d: 1e9 },
            d_one: 1e9,
            machines: 8,
            net: 10e9,
        };
        assert!(!hdd.beneficial());
    }

    #[test]
    fn saturating_machine_count() {
        let base = LoadModel { sigma: 3.6e9, r: 4.0, d: 1e9 };
        let m = DistributedModel { base, d_one: 1e9, machines: 1, net: 12e9 };
        // σ·r = 14.4e9, net = 12e9 → need ceil(12) machines.
        assert_eq!(m.saturating_machines(), 12);
    }
}
