//! The worker process: one graph handle, one socket, tiles on demand.
//!
//! A worker is intentionally dumb: connect, receive the plan, admit it
//! against the *locally opened* graph, then decode whatever tile the
//! leader leases next through this process's own coordinator
//! ([`PgGraph::decode_partition_block`](crate::coordinator::PgGraph)).
//! Admission is strict (§ satellite 3): `PartitionPlan::from_json`
//! re-runs the structural `check()`, and `validate_plan` cross-checks
//! `(n, m)` *and* every tile span against this process's own Elias–Fano
//! sidecar before any decode is dispatched — a stale plan for a
//! different build of the same-named graph is a `Reject` at admission,
//! not a failure deep inside decode.
//!
//! Leader death is the worker's own fault path: transport EOF or a torn
//! frame releases the graph and exits nonzero (the coordinator's
//! shutdown drain joins library threads even mid-stream). A leader that
//! *silently* vanishes (SIGKILL'd process, dropped link — no FIN, so no
//! EOF) is covered by liveness timeouts: the socket reads with
//! [`READ_TIMEOUT`] and the worker exits cleanly once [`IDLE_BUDGET`] of
//! consecutive silence accumulates, instead of blocking in `recv` forever
//! as an orphan.

use std::net::TcpStream;
use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::wire::Msg;
use crate::coordinator::{GraphType, Options, Paragrapher};
use crate::partition::PartitionPlan;
use crate::storage::DeviceKind;

/// Deterministic fault injection, parsed from `--fault`:
///
/// * `kill-after:<n>` — exit(3) mid-tile: after *decoding* the tile that
///   would be the worker's `n`th result, before sending it. The leader
///   observes a transport EOF with a lease outstanding.
/// * `stall-after:<n>` — sleep for an hour at the same point, so the
///   leader's per-tile deadline (not EOF) is what fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerFault {
    KillAfter(u64),
    StallAfter(u64),
}

impl WorkerFault {
    pub fn parse(s: &str) -> Result<WorkerFault> {
        let (kind, n) = s
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("fault spec {s:?}: want kind:<n>"))?;
        let n: u64 = n.parse().with_context(|| format!("fault spec {s:?}"))?;
        match kind {
            "kill-after" => Ok(WorkerFault::KillAfter(n)),
            "stall-after" => Ok(WorkerFault::StallAfter(n)),
            _ => bail!("unknown fault kind {kind:?} (want kill-after or stall-after)"),
        }
    }
}

/// Everything a worker process needs, parsed from the argv the leader
/// builds (shared by `paragrapher worker` and the example's self-spawn).
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    /// Leader address (`host:port`).
    pub connect: String,
    pub dir: PathBuf,
    pub base: String,
    pub gtype: GraphType,
    pub device: DeviceKind,
    /// This worker's index (assigned by the leader at spawn).
    pub index: usize,
    pub fault: Option<WorkerFault>,
}

impl WorkerConfig {
    pub fn from_args(args: &[String]) -> Result<WorkerConfig> {
        let mut connect = None;
        let mut dir = None;
        let mut base = "graph".to_string();
        let mut gtype = GraphType::CsxWg400;
        let mut device = DeviceKind::Ssd;
        let mut index = 0usize;
        let mut fault = None;
        let mut it = args.iter();
        while let Some(flag) = it.next() {
            let mut val = || {
                it.next().ok_or_else(|| anyhow::anyhow!("{flag} needs a value")).cloned()
            };
            match flag.as_str() {
                "--connect" => connect = Some(val()?),
                "--dir" => dir = Some(PathBuf::from(val()?)),
                "--base" => base = val()?,
                "--graph-type" => {
                    let v = val()?;
                    gtype = GraphType::parse(&v)
                        .ok_or_else(|| anyhow::anyhow!("unknown graph type {v:?}"))?;
                }
                "--device" => {
                    let v = val()?;
                    device = DeviceKind::parse(&v)
                        .ok_or_else(|| anyhow::anyhow!("unknown device {v:?}"))?;
                }
                "--index" => index = val()?.parse().context("--index")?,
                "--fault" => fault = Some(WorkerFault::parse(&val()?)?),
                other => bail!("unknown worker flag {other:?}"),
            }
        }
        Ok(WorkerConfig {
            connect: connect.ok_or_else(|| anyhow::anyhow!("worker needs --connect"))?,
            dir: dir.ok_or_else(|| anyhow::anyhow!("worker needs --dir"))?,
            base,
            gtype,
            device,
            index,
            fault,
        })
    }
}

/// Per-read socket timeout: granularity at which a waiting worker rechecks
/// its idle budget. Short enough that a dead leader is noticed promptly,
/// long enough that the recheck itself is noise.
const READ_TIMEOUT: Duration = Duration::from_secs(2);

/// Consecutive leader silence a worker tolerates before concluding the
/// leader is gone and exiting cleanly. Must comfortably exceed the
/// leader's own per-tile deadline (seconds), so a leader that is merely
/// waiting out a *sibling* worker's stall never loses this one too.
const IDLE_BUDGET: Duration = Duration::from_secs(60);

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// The worker main loop. Exits `Ok` only after a clean `Done` from the
/// leader; every other exit releases the graph first so the coordinator's
/// threads join (shutdown-safe drain) and then surfaces the error.
pub fn run_worker(cfg: &WorkerConfig) -> Result<()> {
    let mut stream = TcpStream::connect(&cfg.connect)
        .with_context(|| format!("worker {}: connect {}", cfg.index, cfg.connect))?;
    let _ = stream.set_nodelay(true);
    // Liveness: never block in `recv` forever. Timeout-kinded errors tick
    // an idle budget instead of failing the worker outright.
    stream
        .set_read_timeout(Some(READ_TIMEOUT))
        .with_context(|| format!("worker {}: set read timeout", cfg.index))?;

    let mut idle = Duration::ZERO;
    let plan = loop {
        match Msg::recv(&mut stream) {
            Ok(Some(Msg::Plan { plan })) => break plan,
            Ok(other) => bail!("worker {}: expected the plan first, got {other:?}", cfg.index),
            Err(e) if is_timeout(&e) => {
                idle += READ_TIMEOUT;
                if idle >= IDLE_BUDGET {
                    bail!(
                        "worker {}: no plan within {IDLE_BUDGET:?}; leader presumed dead",
                        cfg.index
                    );
                }
            }
            Err(e) => return Err(anyhow::Error::from(e).context("worker transport")),
        }
    };
    // Structural admission (`from_json` re-runs `check()`)…
    let plan = PartitionPlan::from_json(&plan)
        .with_context(|| format!("worker {}: shipped plan failed check()", cfg.index))?;

    let pg = Paragrapher::init();
    let graph =
        pg.open_graph_from_dir(&cfg.dir, cfg.device, &cfg.base, cfg.gtype, Options::default())?;
    // …then the cross-check against THIS process's own sidecar. A reject
    // is reported to the leader (fatal for the run — a stale plan cannot
    // be outrun by retiling) before this worker bails.
    if let Err(e) = graph.validate_plan(&plan) {
        let _ = (Msg::Reject { worker: cfg.index, error: e.to_string() }).send(&mut stream);
        pg.release_graph(graph);
        return Err(e.context(format!("worker {}: plan rejected at admission", cfg.index)));
    }
    (Msg::Hello {
        worker: cfg.index,
        vertices: graph.num_vertices() as u64,
        edges: graph.num_edges(),
    })
    .send(&mut stream)?;

    let mut completed = 0u64;
    let mut idle = Duration::ZERO;
    let result = loop {
        match Msg::recv(&mut stream) {
            Ok(Some(Msg::Done)) => {
                // Final frame: ship this process's metrics snapshot so the
                // leader can merge tails across workers. Best-effort — a
                // leader that already hung up loses the frame, not the run.
                let _ = (Msg::Metrics {
                    worker: cfg.index,
                    snapshot: graph.metrics_snapshot().to_json(),
                })
                .send(&mut stream);
                break Ok(());
            }
            Ok(Some(Msg::Assign { tile })) => {
                idle = Duration::ZERO;
                let Some(part) = plan.parts.get(tile).copied() else {
                    break Err(anyhow::anyhow!(
                        "worker {}: leased tile {tile} outside the plan",
                        cfg.index
                    ));
                };
                let loaded = match graph.decode_partition_block(part, plan.kind) {
                    Ok(l) => l,
                    Err(e) => break Err(e.context(format!("tile {tile}"))),
                };
                let (edges, checksum) = super::edge_summary(loaded.iter_edges());
                // Faults fire *after* the decode and *before* the result
                // ships: the leader sees a worker that died (or stalled)
                // holding a lease — the exact mid-tile window retiling
                // must cover.
                match cfg.fault {
                    Some(WorkerFault::KillAfter(n)) if completed == n => {
                        std::process::exit(3);
                    }
                    Some(WorkerFault::StallAfter(n)) if completed == n => {
                        std::thread::sleep(Duration::from_secs(3600));
                    }
                    _ => {}
                }
                if let Err(e) = (Msg::TileResult { tile, edges, checksum }).send(&mut stream) {
                    break Err(anyhow::Error::from(e)
                        .context(format!("worker {}: send tile {tile}", cfg.index)));
                }
                completed += 1;
            }
            Ok(Some(other)) => {
                break Err(anyhow::anyhow!("worker {}: unexpected {other:?}", cfg.index))
            }
            Ok(None) => {
                break Err(anyhow::anyhow!(
                    "worker {}: leader transport closed mid-run",
                    cfg.index
                ))
            }
            Err(e) if is_timeout(&e) => {
                // Silence, not failure: tick the idle budget and keep
                // listening. A leader that died without a FIN (SIGKILL,
                // dropped link) never closes the socket, so this path is
                // what keeps the worker from lingering as an orphan.
                idle += READ_TIMEOUT;
                if idle >= IDLE_BUDGET {
                    break Err(anyhow::anyhow!(
                        "worker {}: {IDLE_BUDGET:?} of leader silence; presumed dead",
                        cfg.index
                    ));
                }
            }
            Err(e) => break Err(anyhow::Error::from(e).context("worker transport")),
        }
    };
    // Clean or not, drain the coordinator before exiting — a dying
    // worker must still join its library threads.
    pg.release_graph(graph);
    result
}
