//! The leader: plan once, lease tiles, survive workers.
//!
//! The leader opens the graph from its own directory only to compute the
//! 2D plan off the Elias–Fano sidecar (then releases it — the leader
//! never decodes), binds a loopback listener, spawns worker processes
//! pointed back at it, and serves each connection from a dedicated
//! thread. Tiles are never pre-assigned: each handler leases from the
//! shared [`TileLedger`] on demand, so a fast worker takes more tiles and
//! a dead one leaves only its in-flight lease to reclaim.
//!
//! Worker loss is detected three ways — transport EOF mid-tile, a torn
//! frame, or the per-tile read deadline ([`LeaderConfig::tile_timeout`])
//! — and always handled the same: orphan the worker's leases back to the
//! ledger, kill and reap the child, and let survivors pick the tiles up.
//! The ledger's per-tile attempt budget ([`LeaderConfig::max_attempts`])
//! turns an uncompletable tile into a loud [`Err`]; losing *every* worker
//! with tiles outstanding is equally loud. The leader never hangs on a
//! dead or stalled worker.

use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::wire::Msg;
use crate::coordinator::{lock_recover, GraphType, Options, Paragrapher};
use crate::obs::{self, names, MetricsSnapshot};
use crate::partition::{PartitionPlan, TileLedger};
use crate::storage::DeviceKind;

/// How long a worker may take to connect and say Hello before the run
/// proceeds without it.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(30);

/// A leader run over one on-disk graph directory.
#[derive(Debug, Clone)]
pub struct LeaderConfig {
    /// Graph directory every process opens independently.
    pub dir: PathBuf,
    pub base: String,
    pub gtype: GraphType,
    pub device: DeviceKind,
    /// Worker processes to spawn.
    pub workers: usize,
    /// 2D plan shape (`rows × cols` tiles).
    pub rows: usize,
    pub cols: usize,
    /// Read deadline per assigned tile; a worker that blows it is
    /// declared dead and its leases are retiled.
    pub tile_timeout: Duration,
    /// Leases any single tile may burn before the run fails loudly.
    pub max_attempts: usize,
    /// argv prefix of a worker process, e.g. `[exe, "worker"]` — the
    /// leader appends `--connect/--dir/--base/--graph-type/--device/
    /// --index` (and `--fault` where injected).
    pub worker_cmd: Vec<String>,
    /// Deterministic fault injection: `(worker index, WorkerFault spec)`.
    pub fault_args: Vec<(usize, String)>,
}

impl LeaderConfig {
    pub fn new(
        dir: impl Into<PathBuf>,
        base: &str,
        gtype: GraphType,
        device: DeviceKind,
        worker_cmd: Vec<String>,
    ) -> LeaderConfig {
        LeaderConfig {
            dir: dir.into(),
            base: base.to_string(),
            gtype,
            device,
            workers: 2,
            rows: 3,
            cols: 3,
            tile_timeout: Duration::from_secs(20),
            max_attempts: 3,
            worker_cmd,
            fault_args: Vec::new(),
        }
    }
}

/// One tile's merged result, as received from whichever worker
/// completed it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileOutcome {
    pub tile: usize,
    pub edges: u64,
    pub checksum: u64,
    /// Worker whose result was accepted (after any retiling).
    pub worker: usize,
}

/// What a completed distributed run delivered.
#[derive(Debug)]
pub struct RunReport {
    /// The plan that was shipped (tile `t` of [`Self::tiles`] is
    /// `plan.parts[t]`).
    pub plan: PartitionPlan,
    pub tiles: Vec<TileOutcome>,
    pub edges_delivered: u64,
    /// Tiles that went back to pending because their worker died.
    pub retiled_tiles: usize,
    pub workers_spawned: usize,
    pub workers_lost: usize,
    pub wall_seconds: f64,
    /// Final metrics snapshot of each worker that exited cleanly (shipped
    /// as the worker's last frame), sorted by worker index.
    pub worker_metrics: Vec<(usize, MetricsSnapshot)>,
    /// The worker snapshots merged by name (histograms bucket-wise), plus
    /// the leader's own `dist.*` counters — the cross-process aggregate.
    pub metrics: MetricsSnapshot,
}

/// State shared by every connection handler.
struct Shared {
    ledger: TileLedger,
    plan_msg: Msg,
    results: Mutex<HashMap<usize, TileOutcome>>,
    /// First unrecoverable error (plan rejection, attempt budget burned).
    fatal: Mutex<Option<String>>,
    lost: AtomicUsize,
    children: Mutex<HashMap<usize, Child>>,
    tile_timeout: Duration,
    /// Metrics frames collected from cleanly finished workers.
    worker_metrics: Mutex<Vec<(usize, MetricsSnapshot)>>,
}

fn set_fatal(sh: &Shared, why: String) {
    lock_recover(&sh.fatal).get_or_insert(why);
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Worker loss: reclaim its leases, kill and reap the process. Safe to
/// call for a worker that already exited (kill/wait errors are moot —
/// the tiles are what matter).
fn declare_dead(sh: &Shared, worker: usize, why: &str) {
    let orphaned = sh.ledger.orphan_worker(worker);
    if let Some(mut child) = lock_recover(&sh.children).remove(&worker) {
        let _ = child.kill();
        let _ = child.wait();
    }
    sh.lost.fetch_add(1, Ordering::AcqRel);
    obs::tracer().record(
        "distributed",
        "worker-lost",
        Instant::now(),
        Duration::ZERO,
        0,
        worker as u64,
    );
    eprintln!("leader: worker {worker} lost ({why}); {orphaned} tile(s) returned for retiling");
}

/// Close a worker cleanly: send `Done`, then collect the worker's final
/// metrics frame. Best-effort with a short deadline — a worker that dies
/// between `Done` and its metrics frame loses the frame, not the run.
fn finish_worker(stream: &mut TcpStream, sh: &Shared) {
    if Msg::Done.send(stream).is_err() {
        return;
    }
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    if let Ok(Some(Msg::Metrics { worker, snapshot })) = Msg::recv(stream) {
        if let Ok(snap) = MetricsSnapshot::from_json(&snapshot) {
            lock_recover(&sh.worker_metrics).push((worker, snap));
        }
    }
}

/// Serve one worker connection: ship the plan, then lease→assign→collect
/// until the ledger drains, the run turns fatal, or the worker dies.
fn serve_worker(mut stream: TcpStream, sh: &Shared) {
    let _ = stream.set_nodelay(true);
    if sh.plan_msg.send(&mut stream).is_err() {
        // Died before identifying itself: it holds no leases to reclaim,
        // and the spawn-order index is unknowable from here — the final
        // child sweep in `run_leader` reaps the process.
        return;
    }
    let _ = stream.set_read_timeout(Some(CONNECT_TIMEOUT));
    let worker = match Msg::recv(&mut stream) {
        Ok(Some(Msg::Hello { worker, .. })) => worker,
        Ok(Some(Msg::Reject { worker, error })) => {
            // An admission failure is a configuration error (stale plan,
            // wrong directory) — retrying elsewhere cannot help.
            set_fatal(sh, format!("worker {worker} rejected the plan: {error}"));
            return;
        }
        _ => return,
    };
    let _ = stream.set_read_timeout(Some(sh.tile_timeout));
    loop {
        if lock_recover(&sh.fatal).is_some() {
            finish_worker(&mut stream, sh);
            return;
        }
        let tile = match sh.ledger.lease(worker) {
            Err(e) => {
                set_fatal(sh, e);
                finish_worker(&mut stream, sh);
                return;
            }
            Ok(None) => {
                if sh.ledger.all_done() {
                    finish_worker(&mut stream, sh);
                    return;
                }
                // Tiles are all leased to siblings; one may yet be
                // orphaned back, so poll rather than leave early.
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
            Ok(Some(t)) => t,
        };
        let t_tile = Instant::now();
        if (Msg::Assign { tile }).send(&mut stream).is_err() {
            declare_dead(sh, worker, "send failed");
            return;
        }
        match Msg::recv(&mut stream) {
            Ok(Some(Msg::TileResult { tile: t, edges, checksum })) if t == tile => {
                // Lease turnaround: assign → accepted result, as seen from
                // the leader (includes the worker's decode + the wire).
                obs::tracer().record(
                    "distributed",
                    "tile-lease",
                    t_tile,
                    t_tile.elapsed(),
                    0,
                    tile as u64,
                );
                // `complete` is the authority: a result racing in after
                // this worker was declared dead elsewhere is dropped.
                if sh.ledger.complete(tile, worker) {
                    lock_recover(&sh.results)
                        .insert(tile, TileOutcome { tile, edges, checksum, worker });
                }
            }
            Ok(Some(other)) => {
                declare_dead(sh, worker, &format!("protocol violation: {other:?}"));
                return;
            }
            Ok(None) => {
                declare_dead(sh, worker, &format!("transport EOF mid-tile {tile}"));
                return;
            }
            Err(e) if is_timeout(&e) => {
                declare_dead(
                    sh,
                    worker,
                    &format!("tile {tile} timed out after {:?}", sh.tile_timeout),
                );
                return;
            }
            Err(e) => {
                declare_dead(sh, worker, &format!("transport error on tile {tile}: {e}"));
                return;
            }
        }
    }
}

/// Kill and reap every child still registered (stalled workers sleep for
/// an hour — the run must not leave them behind).
fn reap_children(sh: &Shared) {
    let mut kids = lock_recover(&sh.children);
    for child in kids.values_mut() {
        let _ = child.kill();
        let _ = child.wait();
    }
    kids.clear();
}

/// Run one distributed load end to end. See the module docs for the
/// protocol; the fault-handling contract is: worker loss retiles (never
/// hangs), and an uncompletable run errors loudly with the loss/retile
/// accounting in the message.
pub fn run_leader(cfg: &LeaderConfig) -> Result<RunReport> {
    let t0 = Instant::now();
    if cfg.worker_cmd.is_empty() {
        bail!("worker_cmd must name a worker program");
    }
    // Plan off the leader's own sidecar, then release — the leader never
    // decodes; workers do.
    let pg = Paragrapher::init();
    let graph =
        pg.open_graph_from_dir(&cfg.dir, cfg.device, &cfg.base, cfg.gtype, Options::default())?;
    let plan = PartitionPlan::two_d(graph.offsets_index(), cfg.rows, cfg.cols);
    pg.release_graph(graph);
    let num_tiles = plan.num_parts();

    let listener = TcpListener::bind("127.0.0.1:0").context("bind leader socket")?;
    let addr = listener.local_addr()?.to_string();
    listener.set_nonblocking(true)?;

    let sh = Arc::new(Shared {
        ledger: TileLedger::new(num_tiles, cfg.max_attempts),
        plan_msg: Msg::Plan { plan: plan.to_json() },
        results: Mutex::new(HashMap::new()),
        fatal: Mutex::new(None),
        lost: AtomicUsize::new(0),
        children: Mutex::new(HashMap::new()),
        tile_timeout: cfg.tile_timeout,
        worker_metrics: Mutex::new(Vec::new()),
    });

    let workers = cfg.workers.max(1);
    for i in 0..workers {
        let mut cmd = Command::new(&cfg.worker_cmd[0]);
        cmd.args(&cfg.worker_cmd[1..])
            .arg("--connect")
            .arg(&addr)
            .arg("--dir")
            .arg(&cfg.dir)
            .arg("--base")
            .arg(&cfg.base)
            .arg("--graph-type")
            .arg(super::gtype_flag(cfg.gtype))
            .arg("--device")
            .arg(cfg.device.name())
            .arg("--index")
            .arg(i.to_string())
            .stdin(Stdio::null())
            .stdout(Stdio::null());
        if let Some((_, fault)) = cfg.fault_args.iter().find(|(w, _)| *w == i) {
            cmd.arg("--fault").arg(fault);
        }
        let child = cmd.spawn().with_context(|| format!("spawn worker {i}"))?;
        lock_recover(&sh.children).insert(i, child);
    }

    // Accept until every spawned worker connected, the run finished
    // without some of them, or the connect window closed.
    let mut handlers = Vec::new();
    let deadline = Instant::now() + CONNECT_TIMEOUT;
    while handlers.len() < workers && Instant::now() < deadline {
        if lock_recover(&sh.fatal).is_some() || sh.ledger.all_done() {
            break;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let sh2 = Arc::clone(&sh);
                let h = std::thread::Builder::new()
                    .name("pg-leader-conn".into())
                    .spawn(move || serve_worker(stream, &sh2))
                    .context("spawn connection handler")?;
                handlers.push(h);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                reap_children(&sh);
                bail!("accept: {e}");
            }
        }
    }
    if handlers.is_empty() {
        reap_children(&sh);
        bail!("no worker connected within {CONNECT_TIMEOUT:?}");
    }
    for h in handlers {
        let _ = h.join();
    }
    reap_children(&sh);

    let workers_lost = sh.lost.load(Ordering::Acquire);
    if let Some(e) = lock_recover(&sh.fatal).take() {
        bail!(
            "distributed run failed after {workers_lost} worker loss(es), {} retile(s): {e}",
            sh.ledger.retiled()
        );
    }
    if !sh.ledger.all_done() {
        bail!(
            "{} of {num_tiles} tiles unfinished: every worker is gone \
             ({workers_lost} lost, {} tile(s) retiled, attempt bound {})",
            sh.ledger.unfinished(),
            sh.ledger.retiled(),
            cfg.max_attempts
        );
    }
    let results = lock_recover(&sh.results);
    let mut tiles = Vec::with_capacity(num_tiles);
    let mut edges_delivered = 0u64;
    for t in 0..num_tiles {
        let o = *results
            .get(&t)
            .ok_or_else(|| anyhow::anyhow!("tile {t} marked done but never recorded"))?;
        edges_delivered += o.edges;
        tiles.push(o);
    }
    let mut worker_metrics: Vec<(usize, MetricsSnapshot)> =
        lock_recover(&sh.worker_metrics).drain(..).collect();
    worker_metrics.sort_by_key(|(w, _)| *w);
    let mut metrics = MetricsSnapshot::default();
    for (_, snap) in &worker_metrics {
        metrics.merge(snap);
    }
    metrics.counters.insert(names::DIST_RETILES.to_string(), sh.ledger.retiled() as u64);
    metrics.counters.insert(names::DIST_WORKERS_LOST.to_string(), workers_lost as u64);
    Ok(RunReport {
        plan,
        tiles,
        edges_delivered,
        retiled_tiles: sh.ledger.retiled(),
        workers_spawned: workers,
        workers_lost,
        wall_seconds: t0.elapsed().as_secs_f64(),
        worker_metrics,
        metrics,
    })
}
