//! Leader↔worker wire protocol: typed messages over the length-prefixed
//! JSON frames of [`util::json`](crate::util::json).
//!
//! One frame carries one message; the `"type"` field discriminates. Tile
//! checksums travel as 16-digit hex strings because JSON numbers are
//! `f64`-backed (only 53 bits survive a numeric round-trip).

use std::io::{Read, Write};

use crate::util::json::{read_frame, write_frame, Json};

/// One protocol message. The conversation is:
///
/// ```text
/// leader → worker   Plan        (the full serialized PartitionPlan)
/// worker → leader   Hello | Reject
/// leader → worker   Assign*     (one tile lease at a time)
/// worker → leader   TileResult* (one per Assign, in order)
/// leader → worker   Done        (no more tiles; close cleanly)
/// ```
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// The serialized [`PartitionPlan`](crate::partition::PartitionPlan).
    Plan { plan: Json },
    /// Worker accepted the plan after local admission checks.
    Hello { worker: usize, vertices: u64, edges: u64 },
    /// Worker refused the plan (admission failure) — fatal for the run.
    Reject { worker: usize, error: String },
    /// Lease of one tile (an index into the plan's partitions).
    Assign { tile: usize },
    /// The decoded tile's merged result summary.
    TileResult { tile: usize, edges: u64, checksum: u64 },
    /// No more tiles; the worker should release its graph and exit 0.
    Done,
    /// The worker's final frame after `Done`: its metrics-registry
    /// snapshot (the [`MetricsSnapshot`](crate::obs::MetricsSnapshot)
    /// JSON schema), merged by name on the leader.
    Metrics { worker: usize, snapshot: Json },
}

impl Msg {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        match self {
            Msg::Plan { plan } => {
                o.set("type", "plan").set("plan", plan.clone());
            }
            Msg::Hello { worker, vertices, edges } => {
                o.set("type", "hello")
                    .set("worker", *worker)
                    .set("vertices", *vertices)
                    .set("edges", *edges);
            }
            Msg::Reject { worker, error } => {
                o.set("type", "reject").set("worker", *worker).set("error", error.as_str());
            }
            Msg::Assign { tile } => {
                o.set("type", "assign").set("tile", *tile);
            }
            Msg::TileResult { tile, edges, checksum } => {
                o.set("type", "tile_result")
                    .set("tile", *tile)
                    .set("edges", *edges)
                    .set("checksum", format!("{checksum:016x}"));
            }
            Msg::Done => {
                o.set("type", "done");
            }
            Msg::Metrics { worker, snapshot } => {
                o.set("type", "metrics").set("worker", *worker).set("snapshot", snapshot.clone());
            }
        }
        o
    }

    pub fn from_json(doc: &Json) -> Result<Msg, String> {
        let ty = doc
            .get("type")
            .and_then(Json::as_str)
            .ok_or_else(|| "message without a \"type\" field".to_string())?;
        let num = |key: &str| -> Result<u64, String> {
            doc.get(key)
                .and_then(Json::as_f64)
                .map(|v| v as u64)
                .ok_or_else(|| format!("{ty:?} message missing numeric {key:?}"))
        };
        match ty {
            "plan" => {
                let plan =
                    doc.get("plan").ok_or_else(|| "plan message without a plan".to_string())?;
                Ok(Msg::Plan { plan: plan.clone() })
            }
            "hello" => Ok(Msg::Hello {
                worker: num("worker")? as usize,
                vertices: num("vertices")?,
                edges: num("edges")?,
            }),
            "reject" => Ok(Msg::Reject {
                worker: num("worker")? as usize,
                error: doc
                    .get("error")
                    .and_then(Json::as_str)
                    .unwrap_or("unspecified")
                    .to_string(),
            }),
            "assign" => Ok(Msg::Assign { tile: num("tile")? as usize }),
            "tile_result" => {
                let hex = doc
                    .get("checksum")
                    .and_then(Json::as_str)
                    .ok_or_else(|| "tile_result without a checksum".to_string())?;
                let checksum = u64::from_str_radix(hex, 16)
                    .map_err(|_| format!("bad checksum {hex:?}"))?;
                Ok(Msg::TileResult { tile: num("tile")? as usize, edges: num("edges")?, checksum })
            }
            "done" => Ok(Msg::Done),
            "metrics" => Ok(Msg::Metrics {
                worker: num("worker")? as usize,
                snapshot: doc
                    .get("snapshot")
                    .cloned()
                    .ok_or_else(|| "metrics message without a snapshot".to_string())?,
            }),
            other => Err(format!("unknown message type {other:?}")),
        }
    }

    /// Write this message as one frame.
    pub fn send<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        write_frame(w, &self.to_json())
    }

    /// Read one message; `Ok(None)` is a clean close at a frame boundary.
    /// Timeout-kinded errors (`WouldBlock`/`TimedOut`) pass through so the
    /// leader can classify a stalled worker.
    pub fn recv<R: Read>(r: &mut R) -> std::io::Result<Option<Msg>> {
        match read_frame(r)? {
            None => Ok(None),
            Some(doc) => Msg::from_json(&doc)
                .map(Some)
                .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_variant_round_trips() {
        let mut plan = Json::obj();
        plan.set("kind", "2d:2x2").set("num_vertices", 10u64);
        let msgs = [
            Msg::Plan { plan },
            Msg::Hello { worker: 1, vertices: 10, edges: 35 },
            Msg::Reject { worker: 0, error: "plan is for a different graph".into() },
            Msg::Assign { tile: 3 },
            // A checksum with the top bit set would lose precision as a
            // JSON number — the hex-string lane must carry it exactly.
            Msg::TileResult { tile: 3, edges: 9, checksum: 0xdead_beef_cafe_f00d },
            Msg::Done,
            Msg::Metrics {
                worker: 2,
                snapshot: crate::obs::MetricsRegistry::new().snapshot().to_json(),
            },
        ];
        let mut wire = Vec::new();
        for m in &msgs {
            m.send(&mut wire).unwrap();
        }
        let mut r = wire.as_slice();
        for m in &msgs {
            assert_eq!(Msg::recv(&mut r).unwrap().as_ref(), Some(m));
        }
        assert_eq!(Msg::recv(&mut r).unwrap(), None);
    }

    #[test]
    fn garbage_is_invalid_data() {
        let mut doc = Json::obj();
        doc.set("type", "launch-the-missiles");
        let mut wire = Vec::new();
        crate::util::json::write_frame(&mut wire, &doc).unwrap();
        let mut r = wire.as_slice();
        let e = Msg::recv(&mut r).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::InvalidData);
    }
}
