//! Real multi-process distributed execution (§2's distributed-memory
//! consumers, made literal).
//!
//! PR 3 gave plans a JSON round-trip and PR 4 shipped them between
//! *threads*; this module ships them between *processes*. A leader
//! computes one 2D [`PartitionPlan`](crate::partition::PartitionPlan)
//! from its own Elias–Fano sidecar, serves it to worker processes over a
//! length-prefixed JSON socket ([`wire`]), and leases tiles one at a time
//! from a shared [`TileLedger`](crate::partition::TileLedger). Each
//! worker independently opens the same on-disk graph
//! (`open_graph_from_dir`), re-validates the shipped plan against its
//! *own* sidecar ([`PgGraph::validate_plan`](crate::coordinator::PgGraph)),
//! decodes assigned tiles through its own coordinator
//! (`decode_partition_block`), and streams per-tile summaries back.
//!
//! Fault handling is first-class: a worker that dies or stalls mid-tile
//! is detected by transport EOF or a per-tile read deadline, its leased
//! tiles return to the ledger for survivors (retile, never hang), and a
//! bounded per-tile attempt budget turns a tile that can never complete
//! into a loud error instead of an infinite reassignment loop.
//! [`WorkerFault`] injects deterministic kill/stall faults for tests and
//! the `--fault-inject` CLI mode.

pub mod leader;
pub mod wire;
pub mod worker;

pub use leader::{run_leader, LeaderConfig, RunReport, TileOutcome};
pub use worker::{run_worker, WorkerConfig, WorkerFault};

use anyhow::Result;

use crate::coordinator::{GraphType, PgGraph};
use crate::graph::VertexId;
use crate::partition::PartitionPlan;

/// splitmix64 finalizer: a cheap strong mix for edge fingerprints.
#[inline]
fn mix64(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Order-independent summary of an edge set: `(count, checksum)`. The
/// checksum is a wrapping sum of per-edge `mix64` fingerprints, so two
/// deliveries of the same tile compare equal regardless of the order the
/// decode emitted rows in — and a single dropped or duplicated edge flips
/// it with overwhelming probability.
pub fn edge_summary(edges: impl Iterator<Item = (VertexId, VertexId)>) -> (u64, u64) {
    let mut count = 0u64;
    let mut sum = 0u64;
    for (src, dst) in edges {
        count += 1;
        sum = sum.wrapping_add(mix64(((src as u64) << 32) | dst as u64));
    }
    (count, sum)
}

/// Single-process oracle: decode every tile of `plan` through this
/// graph's own partition stream and summarize each. Index `t` holds tile
/// `t`'s summary, so a distributed [`RunReport`] compares tile-for-tile.
pub fn oracle_tile_summaries(graph: &PgGraph, plan: PartitionPlan) -> Result<Vec<(u64, u64)>> {
    let mut out = vec![(0u64, 0u64); plan.num_parts()];
    let stream = graph.get_partitions(plan)?;
    while let Some(loaded) = stream.next()? {
        out[loaded.part.index] = edge_summary(loaded.iter_edges());
    }
    Ok(out)
}

/// The canonical spelling of a graph type for child-process argv.
pub(crate) fn gtype_flag(g: GraphType) -> &'static str {
    match g {
        GraphType::CsxWg400 => "WG400",
        GraphType::CsxWg800 => "WG800",
        GraphType::CsxWg404 => "WG404",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_summary_is_order_independent_and_collision_averse() {
        let fwd = [(0u32, 1u32), (1, 2), (2, 0)];
        let rev = [(2u32, 0u32), (1, 2), (0, 1)];
        assert_eq!(edge_summary(fwd.iter().copied()), edge_summary(rev.iter().copied()));
        // Dropping an edge changes both lanes; swapping src/dst changes
        // the checksum (the pair is position-encoded before mixing).
        let (n, c) = edge_summary(fwd.iter().copied());
        let (n2, c2) = edge_summary(fwd[..2].iter().copied());
        assert_ne!((n, c), (n2, c2));
        let swapped = [(1u32, 0u32), (1, 2), (2, 0)];
        assert_ne!(edge_summary(swapped.iter().copied()).1, c);
    }
}
