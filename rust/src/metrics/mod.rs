//! Experiment metrics: throughput accounting and table rendering shared by
//! the CLI, examples and benches.

use crate::storage::cache::CacheCounters;
use crate::storage::IoAccount;
use crate::util::json::Json;

/// Result of one measured load: modeled elapsed time plus derived rates.
#[derive(Debug, Clone, Copy)]
pub struct LoadMeasurement {
    /// Modeled elapsed seconds (virtual I/O + real CPU composition).
    pub elapsed: f64,
    /// Edges delivered.
    pub edges: u64,
    /// Bytes read from the device.
    pub device_bytes: u64,
}

impl LoadMeasurement {
    pub fn from_accounts(accounts: &[IoAccount], edges: u64, extra_seconds: f64) -> Self {
        let elapsed = crate::storage::vclock::phase_elapsed(accounts) + extra_seconds;
        let device_bytes = accounts.iter().map(|a| a.bytes_read()).sum();
        Self { elapsed, edges, device_bytes }
    }

    /// Throughput in Million Edges per Second — the paper's Fig. 5/7 unit.
    pub fn me_per_sec(&self) -> f64 {
        if self.elapsed <= 0.0 {
            return 0.0;
        }
        self.edges as f64 / self.elapsed / 1e6
    }

    /// Load bandwidth in device bytes/s (Fig. 5's right axis).
    pub fn device_bandwidth(&self) -> f64 {
        if self.elapsed <= 0.0 {
            return 0.0;
        }
        self.device_bytes as f64 / self.elapsed
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("elapsed_s", self.elapsed)
            .set("edges", self.edges)
            .set("device_bytes", self.device_bytes)
            .set("me_per_s", self.me_per_sec());
        o
    }
}

/// Fixed-width text table (the bench harness's human output).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{c:>w$} | ", w = w));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&format!(
            "|{}|",
            widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
        ));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Render [`DecodedCache`](crate::storage::DecodedCache) counters as JSON
/// (attached to bench results so cache efficacy shows up in the perf
/// trajectory alongside throughput).
pub fn cache_report(counters: &CacheCounters) -> Json {
    let mut o = Json::obj();
    o.set("hits", counters.hits)
        .set("misses", counters.misses)
        .set("evictions", counters.evictions)
        .set("resident_cost", counters.resident_cost)
        .set("blocks", counters.blocks)
        .set("hit_rate", counters.hit_rate());
    o
}

/// Render the Elias–Fano offsets index footprint against the former plain
/// `Vec<u64>` representation (attached to bench results; the ≤ 40% bar is
/// asserted in the webgraph tests).
pub fn offsets_report(offsets: &crate::formats::webgraph::WgOffsets) -> Json {
    let ef = offsets.size_bytes() as u64;
    let plain = offsets.plain_size_bytes() as u64;
    let mut o = Json::obj();
    o.set("ef_bytes", ef)
        .set("plain_bytes", plain)
        .set("ratio", ef as f64 / plain.max(1) as f64);
    o
}

/// Render partitioned-request health as JSON: plan balance, prefetch hit
/// rate, stall counts, and (when the caller computed one) the modeled
/// interleave overlap fraction. Attached to bench results and the CI job
/// summary.
pub fn partition_report(
    plan: &crate::partition::PartitionPlan,
    counters: &crate::partition::StreamCounters,
    overlap: Option<f64>,
) -> Json {
    let mut o = Json::obj();
    // Counters go out as exact integers (Json::Uint), not f64 — a long run
    // can push these past 2^53, where the cast would silently round.
    o.set("parts", plan.num_parts())
        .set("balance_factor", plan.balance_factor())
        .set("produced", counters.produced)
        .set("consumed", counters.consumed)
        .set("prefetch_hit_rate", counters.prefetch_hit_rate())
        .set("consumer_stalls", counters.consumer_stalls)
        .set("producer_stalls", counters.producer_stalls);
    if let Some(ov) = overlap {
        o.set("interleave_overlap", ov);
    }
    o
}

/// Format a cache hit rate for table output ("93.8% hit").
pub fn fmt_hit_rate(counters: &CacheCounters) -> String {
    format!("{:.1}% hit", counters.hit_rate() * 100.0)
}

/// Format a throughput as the paper does ("129 ME/s").
pub fn fmt_meps(v: f64) -> String {
    format!("{v:.1} ME/s")
}

/// Format bandwidth adaptively (MB/s vs GB/s, Fig. 5's right axis).
pub fn fmt_bw(bytes_per_sec: f64) -> String {
    if bytes_per_sec >= 1e9 {
        format!("{:.2} GB/s", bytes_per_sec / 1e9)
    } else {
        format!("{:.1} MB/s", bytes_per_sec / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurement_rates() {
        let accounts = vec![IoAccount::new(), IoAccount::new()];
        accounts[0].charge_io(2.0, 100);
        accounts[1].charge_io(1.0, 50);
        let m = LoadMeasurement::from_accounts(&accounts, 10_000_000, 0.0);
        assert!((m.elapsed - 2.0).abs() < 1e-9);
        assert!((m.me_per_sec() - 5.0).abs() < 1e-9);
        assert_eq!(m.device_bytes, 150);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("long-name"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_meps(129.04), "129.0 ME/s");
        assert_eq!(fmt_bw(3.6e9), "3.60 GB/s");
        assert_eq!(fmt_bw(160e6), "160.0 MB/s");
    }

    #[test]
    fn cache_report_renders() {
        let c = CacheCounters { hits: 3, misses: 1, evictions: 2, resident_cost: 40, blocks: 5 };
        assert_eq!(fmt_hit_rate(&c), "75.0% hit");
        let j = cache_report(&c);
        let s = j.to_string_pretty();
        assert!(s.contains("\"hits\""), "{s}");
        assert!(s.contains("\"hit_rate\""), "{s}");
    }
}
