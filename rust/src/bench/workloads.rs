//! Shared measured-workload drivers used by `benches/*` and examples.
//!
//! All loading measurements here use the *virtual-time* composition rule
//! (max over per-worker accounts of virtual I/O + real CPU, §3's overlap
//! model) so that thread-count effects are modeled faithfully even though
//! the simulation host may have a single physical core.

use anyhow::Result;

use crate::formats::webgraph;
use crate::formats::FormatKind;
use crate::metrics::LoadMeasurement;
use crate::runtime::ScanEngine;
use crate::storage::sim::ReadCtx;
use crate::storage::vclock::{phase_elapsed, phase_elapsed_with_cores};
use crate::storage::{IoAccount, SimStore};

/// Baseline (GAPBS-style) full load of `format`, `threads`-way parallel.
pub fn modeled_full_load(
    store: &SimStore,
    base: &str,
    format: FormatKind,
    threads: usize,
) -> Result<LoadMeasurement> {
    store.drop_cache();
    let ctx = ReadCtx { threads, ..ReadCtx::default() };
    let accounts: Vec<IoAccount> = (0..threads).map(|_| IoAccount::new()).collect();
    let loaded = format.load_full(store, base, ctx, &accounts)?;
    Ok(LoadMeasurement::from_accounts(&accounts, loaded.num_edges(), 0.0))
}

/// ParaGrapher-style load: plan vertex-aligned blocks of `buffer_edges`,
/// deal them round-robin to `workers` decoder workers, decode each block
/// selectively, charge a `dispatch_latency` per block (the paper's §5.5
/// scheduler-poll cost), and compose as max over workers plus the
/// sequential metadata phase. Optionally cap physical `cores`.
#[allow(clippy::too_many_arguments)]
pub fn modeled_paragrapher_load(
    store: &SimStore,
    base: &str,
    workers: usize,
    buffer_edges: u64,
    scan: &dyn ScanEngine,
    dispatch_latency: f64,
    cores: Option<usize>,
) -> Result<ParagrapherLoad> {
    store.drop_cache();
    let ctx = ReadCtx { threads: workers, ..ReadCtx::default() };

    // Sequential metadata phase (§5.6) — a single reader, so its I/O is
    // charged at single-stream bandwidth.
    let seq_ctx = ReadCtx { threads: 1, ..ctx };
    let seq_acct = IoAccount::new();
    let meta = seq_acct.time_cpu(|| webgraph::read_meta(store, base, seq_ctx, &seq_acct))?;
    let offsets =
        seq_acct.time_cpu(|| webgraph::read_offsets(store, base, seq_ctx, &seq_acct))?;
    let sequential = seq_acct.elapsed_seconds();

    // Plan blocks (vertex-aligned, ≤ buffer_edges each) straight off the
    // Elias–Fano sidecar index — O(blocks · log n), no plain vectors.
    let n = meta.num_vertices;
    let mut blocks: Vec<(usize, usize)> = Vec::new();
    let mut v = 0usize;
    while v < n {
        let limit = offsets.edge_offset(v) + buffer_edges.max(1);
        let mut end = offsets.edge_partition_point(|e| e <= limit) - 1;
        end = end.clamp(v + 1, n);
        blocks.push((v, end));
        v = end;
    }

    // Round-robin to workers; decode sequentially per worker. The device
    // sees at most min(workers, blocks) concurrent readers — using the
    // declared worker count when blocks are few would overcharge seek
    // interleaving on spindle devices.
    let effective = workers.max(1).min(blocks.len().max(1));
    // Workers read round-robin-assigned blocks: scattered, not sequential —
    // the device model charges real seeks per request.
    let ctx = ReadCtx { threads: effective, sequential: effective == 1, ..ctx };
    let accounts: Vec<IoAccount> = (0..workers.max(1)).map(|_| IoAccount::new()).collect();
    let mut edges = 0u64;
    for (i, &(bs, be)) in blocks.iter().enumerate() {
        let acct = &accounts[i % accounts.len()];
        let dec = webgraph::Decoder::open(store, base, &meta, &offsets, ctx, acct)?;
        let block = acct.time_cpu(|| dec.decode_range_with_scan(bs, be, acct, scan))?;
        edges += block.num_edges();
        acct.charge_io(dispatch_latency, 0); // scheduler roundtrip per block
    }
    if std::env::var("PG_DEBUG_ACCOUNTS").is_ok() {
        for (i, a) in accounts.iter().enumerate() {
            if a.elapsed_seconds() > 0.0 {
                eprintln!(
                    "    worker {i}: io={:.4}s cpu={:.4}s bytes={} reqs={}",
                    a.io_seconds(), a.cpu_seconds(), a.bytes_read(), a.requests()
                );
            }
        }
    }
    let parallel = match cores {
        Some(c) => phase_elapsed_with_cores(&accounts, c),
        None => phase_elapsed(&accounts),
    };
    let device_bytes: u64 =
        accounts.iter().map(|a| a.bytes_read()).sum::<u64>() + seq_acct.bytes_read();
    Ok(ParagrapherLoad {
        measurement: LoadMeasurement {
            elapsed: sequential + parallel,
            edges,
            device_bytes,
        },
        sequential_seconds: sequential,
        parallel_seconds: parallel,
        blocks: blocks.len(),
    })
}

/// Result of a modeled ParaGrapher load.
#[derive(Debug, Clone, Copy)]
pub struct ParagrapherLoad {
    pub measurement: LoadMeasurement,
    pub sequential_seconds: f64,
    pub parallel_seconds: f64,
    pub blocks: usize,
}

/// Result of one modeled interleaved-vs-sequential comparison.
#[derive(Debug, Clone, Copy)]
pub struct InterleaveRun {
    /// Modeled end-to-end seconds with loading overlapped by execution
    /// through a `window`-deep staging pipeline.
    pub interleaved: f64,
    /// Modeled load-then-execute baseline (Σ loads + Σ consumes).
    pub sequential: f64,
    /// Σ per-partition load seconds.
    pub load_seconds: f64,
    /// Σ per-partition consume seconds.
    pub consume_seconds: f64,
    /// Fraction of the smaller phase hidden by the pipeline.
    pub overlap: f64,
    pub parts: usize,
    pub window: usize,
}

impl InterleaveRun {
    /// The §3 pipeline floor: the slower phase bounds the interleaved run.
    pub fn envelope_floor(&self) -> f64 {
        self.load_seconds.max(self.consume_seconds)
    }

    pub fn speedup(&self) -> f64 {
        if self.interleaved <= 0.0 {
            return 1.0;
        }
        self.sequential / self.interleaved
    }
}

/// Model the paper's headline interleaving experiment on a simulated
/// tier: stage the plan's partitions one by one through a selective
/// decode (virtual I/O + real CPU per partition), charge the consumer
/// `consume_ns_per_edge` of processing per delivered edge, and compose
/// the per-partition times through the §3 bounded-window pipeline
/// ([`crate::model::interleaved_elapsed`]) against the load-then-execute
/// baseline. Deterministic given the store's device model.
///
/// 1D plans only: 2D tiles re-decode each row group once per column and
/// COO splits re-decode boundary rows, so pricing their partitions as
/// independent row decodes would inflate both sides of the comparison —
/// rejected rather than silently mis-modeled.
pub fn modeled_interleaved_run(
    store: &SimStore,
    base: &str,
    plan: &crate::partition::PartitionPlan,
    window: usize,
    consume_ns_per_edge: f64,
) -> Result<InterleaveRun> {
    anyhow::ensure!(
        matches!(plan.kind, crate::partition::PlanKind::OneD),
        "modeled_interleaved_run models 1D plans only (got {:?})",
        plan.kind
    );
    store.drop_cache();
    let seq_acct = IoAccount::new();
    let ctx = ReadCtx { threads: 1, ..ReadCtx::default() };
    let meta = webgraph::read_meta(store, base, ctx, &seq_acct)?;
    let offsets = webgraph::read_offsets(store, base, ctx, &seq_acct)?;
    let mut loads = Vec::with_capacity(plan.num_parts());
    let mut consumes = Vec::with_capacity(plan.num_parts());
    for part in &plan.parts {
        let acct = IoAccount::new();
        let dec = webgraph::Decoder::open(store, base, &meta, &offsets, ctx, &acct)?;
        let block = acct.time_cpu(|| {
            dec.decode_range_with_scan(
                part.vertices.start,
                part.vertices.end,
                &acct,
                &crate::runtime::NativeScan,
            )
        })?;
        loads.push(acct.elapsed_seconds());
        consumes.push(block.num_edges() as f64 * consume_ns_per_edge * 1e-9);
    }
    let interleaved = crate::model::interleaved_elapsed(&loads, &consumes, window);
    let sequential = crate::model::sequential_elapsed(&loads, &consumes);
    Ok(InterleaveRun {
        interleaved,
        sequential,
        load_seconds: loads.iter().sum(),
        consume_seconds: consumes.iter().sum(),
        overlap: crate::model::overlap_fraction(&loads, &consumes, window),
        parts: plan.num_parts(),
        window,
    })
}

/// In-memory bytes a full uncompressed load needs (the OOM model for the
/// "-1" bars of Figs. 5/6): offsets (u64) + edges (u32).
pub fn full_load_memory_bytes(num_vertices: usize, num_edges: u64) -> u64 {
    (num_vertices as u64 + 1) * 8 + num_edges * 4
}

/// Modeled speedup of `workers` processes decoding one shared-storage
/// graph, against the same §3 model single-process: every process reads
/// the same device (the σ·r limb is *shared*) but decompresses
/// independently (the d limb scales), so
///
/// ```text
///     speedup(w) = min(σ·r, w·d) / min(σ·r, d)
/// ```
///
/// — linear while decode-bound, flat once the storage limb binds. The
/// `distributed_scaling` ci-summary row prints this next to the measured
/// multi-process wall-clock ratio.
pub fn modeled_distributed_speedup(model: &crate::model::LoadModel, workers: usize) -> f64 {
    let one = model.upper_bound();
    if one <= 0.0 {
        return 1.0;
    }
    (model.sigma * model.r).min(model.d * workers.max(1) as f64) / one
}

/// Result of one decode-bandwidth calibration ([`calibrate_decode`]).
#[derive(Debug, Clone, Copy)]
pub struct DecodeCalibration {
    pub vertices: usize,
    pub edges: u64,
    /// Compressed stream size, bytes.
    pub stream_bytes: u64,
    /// Best-of-repeats wall seconds for one full single-threaded decode.
    pub best_seconds: f64,
    /// Decode-table symbols served fast-path / slow-path.
    pub table_hits: u64,
    pub table_misses: u64,
}

impl DecodeCalibration {
    /// The *achieved* single-core decompression bandwidth `d` of the §3
    /// model, in uncompressed-CSR bytes/s (4 B per decoded edge — the same
    /// convention as the model's `d` and the hot_path `calibrated-d`
    /// report).
    pub fn achieved_d(&self) -> f64 {
        self.edges as f64 * 4.0 / self.best_seconds
    }

    pub fn edges_per_sec(&self) -> f64 {
        self.edges as f64 / self.best_seconds
    }

    pub fn table_hit_rate(&self) -> f64 {
        crate::util::codes::hit_rate(self.table_hits, self.table_misses)
    }
}

/// Measure the achieved decompression bandwidth `d` on a seeded generated
/// graph: `repeats` single-threaded full-range decodes through one reused
/// [`webgraph::DecodeScratch`] (real wall clock, DRAM-resident store so the
/// measurement isolates the decode CPU), keeping the fastest. This is the
/// *measured* side of the §3 model's `d` — `paragrapher calibrate-decode`
/// and `ci-summary` print it next to the model's assumed value so the two
/// can drift apart loudly instead of silently.
pub fn calibrate_decode(scale: usize, seed: u64, repeats: usize) -> Result<DecodeCalibration> {
    use crate::storage::DeviceKind;

    let g = crate::graph::generators::barabasi_albert(20_000 * scale.max(1), 8, seed);
    let store = SimStore::new(DeviceKind::Dram);
    for (name, data) in webgraph::serialize(&g, "cal") {
        store.put(&name, data);
    }
    let stream_bytes = store.file_len("cal.graph").unwrap_or(0);
    let acct = IoAccount::new();
    let ctx = ReadCtx::default();
    let meta = webgraph::read_meta(&store, "cal", ctx, &acct)?;
    let offsets = webgraph::read_offsets(&store, "cal", ctx, &acct)?;
    let dec = webgraph::Decoder::open(&store, "cal", &meta, &offsets, ctx, &acct)?;
    let mut scratch = webgraph::DecodeScratch::new();
    let n = meta.num_vertices;
    let mut best = f64::INFINITY;
    let mut edges = 0u64;
    for _ in 0..repeats.max(1) {
        let t0 = std::time::Instant::now();
        let block =
            dec.decode_range_scratch(0, n, &acct, &crate::runtime::NativeScan, &mut scratch)?;
        let dt = t0.elapsed().as_secs_f64().max(1e-9);
        best = best.min(dt);
        edges = block.num_edges();
    }
    anyhow::ensure!(edges == g.num_edges(), "calibration decode lost edges");
    let (table_hits, table_misses) = scratch.table_counters();
    Ok(DecodeCalibration {
        vertices: n,
        edges,
        stream_bytes,
        best_seconds: best,
        table_hits,
        table_misses,
    })
}

/// Measured throughput of the decoder's phase-2 hot loop, Melem/s:
/// `(fused, split)` — the fused scan+validate+narrow pass
/// ([`ScanEngine::scan_validate_u32`](crate::runtime::ScanEngine::scan_validate_u32))
/// vs the former scan-then-validate shape — over a seeded `len`-element
/// gap array, best of `repeats`. The `ci-summary` regression canary.
pub fn measure_fused_scan(len: usize, repeats: usize) -> (f64, f64) {
    use crate::runtime::NativeScan;
    let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(5);
    let src: Vec<i64> = (0..len).map(|_| rng.next_below(48) as i64).collect();
    let upper = 1u64 << 40;
    let mut buf = vec![0i64; len];
    let mut out: Vec<u32> = Vec::new();
    let mut fused = f64::INFINITY;
    let mut split = f64::INFINITY;
    for _ in 0..repeats.max(1) {
        buf.copy_from_slice(&src);
        let t0 = std::time::Instant::now();
        let v = NativeScan.scan_validate_u32(&mut buf, upper, &mut out).expect("fused scan");
        fused = fused.min(t0.elapsed().as_secs_f64().max(1e-9));
        assert!(v.is_none(), "seeded gaps are in range");
        buf.copy_from_slice(&src);
        let t0 = std::time::Instant::now();
        scan_then_validate_reference(&mut buf, upper, &mut out);
        split = split.min(t0.elapsed().as_secs_f64().max(1e-9));
    }
    (len as f64 / fused / 1e6, len as f64 / split / 1e6)
}

/// The pre-fusion phase-2 reference shape — inclusive scan, then a
/// separate validate-and-narrow walk. One shared definition so the
/// `hot_path` bench and [`measure_fused_scan`] time the *same* baseline
/// (it is also the shape of the `ScanEngine` trait default). Panics on a
/// validation failure: baseline inputs are in range by construction.
pub fn scan_then_validate_reference(buf: &mut [i64], upper: u64, out: &mut Vec<u32>) {
    use crate::runtime::NativeScan;
    NativeScan.inclusive_scan_i64(buf).expect("scan");
    out.clear();
    out.reserve(buf.len());
    let hi = upper as i64;
    let mut prev = -1i64;
    for &s in buf.iter() {
        assert!(s >= 0 && s < hi && s >= prev, "validation");
        out.push(s as u32);
        prev = s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::runtime::NativeScan;
    use crate::storage::DeviceKind;

    #[test]
    fn paragrapher_load_counts_all_edges() {
        let g = generators::barabasi_albert(1000, 5, 3);
        let store = SimStore::new(DeviceKind::Hdd);
        FormatKind::WebGraph.write_to_store(&g, &store, "g");
        let r =
            modeled_paragrapher_load(&store, "g", 4, 2048, &NativeScan, 0.0, None).unwrap();
        assert_eq!(r.measurement.edges, g.num_edges());
        assert!(r.blocks > 1);
        assert!(r.sequential_seconds > 0.0);
        assert!(r.parallel_seconds > 0.0);
    }

    #[test]
    fn more_workers_less_modeled_time_on_parallel_device() {
        let g = generators::barabasi_albert(3000, 8, 5);
        let store = SimStore::new(DeviceKind::Ssd);
        FormatKind::WebGraph.write_to_store(&g, &store, "g");
        let one =
            modeled_paragrapher_load(&store, "g", 1, 4096, &NativeScan, 0.0, None).unwrap();
        let four =
            modeled_paragrapher_load(&store, "g", 4, 4096, &NativeScan, 0.0, None).unwrap();
        assert!(
            four.parallel_seconds < one.parallel_seconds,
            "4 workers {} vs 1 worker {}",
            four.parallel_seconds,
            one.parallel_seconds
        );
    }

    #[test]
    fn dispatch_latency_penalizes_small_buffers() {
        let g = generators::barabasi_albert(2000, 6, 7);
        let store = SimStore::new(DeviceKind::Ssd);
        FormatKind::WebGraph.write_to_store(&g, &store, "g");
        let small =
            modeled_paragrapher_load(&store, "g", 2, 256, &NativeScan, 1e-3, None).unwrap();
        let large =
            modeled_paragrapher_load(&store, "g", 2, 1 << 20, &NativeScan, 1e-3, None)
                .unwrap();
        assert!(small.blocks > large.blocks * 4);
        assert!(
            small.measurement.elapsed > large.measurement.elapsed,
            "small buffers pay dispatch: {} vs {}",
            small.measurement.elapsed,
            large.measurement.elapsed
        );
    }

    #[test]
    fn oom_model() {
        assert!(full_load_memory_bytes(1000, 1_000_000) > 4_000_000);
    }

    #[test]
    fn decode_calibration_is_sane() {
        // Tiny scale keeps the test fast; the CI job runs the real size.
        let cal = calibrate_decode(1, 42, 2).unwrap();
        assert!(cal.edges > 0);
        assert!(cal.best_seconds > 0.0);
        assert!(cal.achieved_d() > 0.0);
        assert!(cal.stream_bytes > 0);
        // γ-coded structure fields (degree, reference, blocks, interval
        // count) are short on any graph; residual ζ gaps on a 20k-vertex BA
        // graph are often beyond the 11-bit table, so the floor is
        // conservative — the CI summary tracks the actual rate.
        assert!(
            cal.table_hit_rate() > 0.15,
            "structure fields alone must clear the floor: {}",
            cal.table_hit_rate()
        );
    }

    #[test]
    fn fused_scan_measurement_is_sane() {
        let (fused, split) = measure_fused_scan(1 << 14, 2);
        assert!(fused > 0.0, "fused throughput measured");
        assert!(split > 0.0, "split throughput measured");
    }

    #[test]
    fn interleaved_beats_load_then_execute_on_hdd() {
        // The acceptance-criteria experiment: on a slow tier, a
        // window-pipelined partitioned run must land strictly below the
        // sequential baseline and inside the §3 envelope.
        let g = generators::barabasi_albert(3000, 8, 13);
        let store = SimStore::new(DeviceKind::Hdd);
        FormatKind::WebGraph.write_to_store(&g, &store, "g");
        let acct = IoAccount::new();
        let offs =
            webgraph::read_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
        let plan = crate::partition::PartitionPlan::one_d(&offs, 16);
        let run = modeled_interleaved_run(&store, "g", &plan, 3, 40.0).unwrap();
        assert!(
            run.interleaved < run.sequential,
            "interleaved {} must beat sequential {}",
            run.interleaved,
            run.sequential
        );
        assert!(run.interleaved >= run.envelope_floor() - 1e-12, "below the pipeline floor");
        assert!(run.interleaved <= run.sequential + 1e-12);
        assert!(run.overlap > 0.0);
        assert!(run.speedup() > 1.0);
    }
}

#[cfg(test)]
mod probe {
    use super::*;
    use crate::graph::generators::Dataset;
    use crate::runtime::NativeScan;
    use crate::storage::DeviceKind;

    #[test]
    fn dbg_probe_tw_hdd() {
        for ds in [Dataset::Tw, Dataset::Cw] {
        let g = ds.generate(2, 42);
        let store = SimStore::new_scaled(DeviceKind::Hdd);
        let wg = FormatKind::WebGraph.write_to_store(&g, &store, "w");
        let bin = FormatKind::BinCsx.write_to_store(&g, &store, "b");
        eprintln!("edges={} wg_bytes={} bin_bytes={}", g.num_edges(), wg, bin);
        store.drop_cache();
        for workers in [9usize, 36] {
        let r = modeled_paragrapher_load(&store, "w", workers, 64 << 10, &NativeScan, 2e-3, None).unwrap();
        eprintln!(
            "wg: seq={:.4}s par={:.4}s blocks={} meps={:.1} bytes={}",
            r.sequential_seconds, r.parallel_seconds, r.blocks,
            r.measurement.me_per_sec(), r.measurement.device_bytes
        );
        }
        let m = modeled_full_load(&store, "b", FormatKind::BinCsx, 8).unwrap();
        eprintln!("bin: elapsed={:.4}s meps={:.1} bytes={}", m.elapsed, m.me_per_sec(), m.device_bytes);
        }
    }
}
