//! Shared measured-workload drivers used by `benches/*` and examples.
//!
//! All loading measurements here use the *virtual-time* composition rule
//! (max over per-worker accounts of virtual I/O + real CPU, §3's overlap
//! model) so that thread-count effects are modeled faithfully even though
//! the simulation host may have a single physical core.

use anyhow::Result;

use crate::formats::webgraph;
use crate::formats::FormatKind;
use crate::metrics::LoadMeasurement;
use crate::runtime::ScanEngine;
use crate::storage::sim::ReadCtx;
use crate::storage::vclock::{phase_elapsed, phase_elapsed_with_cores};
use crate::storage::{IoAccount, SimStore};

/// Baseline (GAPBS-style) full load of `format`, `threads`-way parallel.
pub fn modeled_full_load(
    store: &SimStore,
    base: &str,
    format: FormatKind,
    threads: usize,
) -> Result<LoadMeasurement> {
    store.drop_cache();
    let ctx = ReadCtx { threads, ..ReadCtx::default() };
    let accounts: Vec<IoAccount> = (0..threads).map(|_| IoAccount::new()).collect();
    let loaded = format.load_full(store, base, ctx, &accounts)?;
    Ok(LoadMeasurement::from_accounts(&accounts, loaded.num_edges(), 0.0))
}

/// ParaGrapher-style load: plan vertex-aligned blocks of `buffer_edges`,
/// deal them round-robin to `workers` decoder workers, decode each block
/// selectively, charge a `dispatch_latency` per block (the paper's §5.5
/// scheduler-poll cost), and compose as max over workers plus the
/// sequential metadata phase. Optionally cap physical `cores`.
#[allow(clippy::too_many_arguments)]
pub fn modeled_paragrapher_load(
    store: &SimStore,
    base: &str,
    workers: usize,
    buffer_edges: u64,
    scan: &dyn ScanEngine,
    dispatch_latency: f64,
    cores: Option<usize>,
) -> Result<ParagrapherLoad> {
    store.drop_cache();
    let ctx = ReadCtx { threads: workers, ..ReadCtx::default() };

    // Sequential metadata phase (§5.6) — a single reader, so its I/O is
    // charged at single-stream bandwidth.
    let seq_ctx = ReadCtx { threads: 1, ..ctx };
    let seq_acct = IoAccount::new();
    let meta = seq_acct.time_cpu(|| webgraph::read_meta(store, base, seq_ctx, &seq_acct))?;
    let offsets =
        seq_acct.time_cpu(|| webgraph::read_offsets(store, base, seq_ctx, &seq_acct))?;
    let sequential = seq_acct.elapsed_seconds();

    // Plan blocks (vertex-aligned, ≤ buffer_edges each) straight off the
    // Elias–Fano sidecar index — O(blocks · log n), no plain vectors.
    let n = meta.num_vertices;
    let mut blocks: Vec<(usize, usize)> = Vec::new();
    let mut v = 0usize;
    while v < n {
        let limit = offsets.edge_offset(v) + buffer_edges.max(1);
        let mut end = offsets.edge_partition_point(|e| e <= limit) - 1;
        end = end.clamp(v + 1, n);
        blocks.push((v, end));
        v = end;
    }

    // Round-robin to workers; decode sequentially per worker. The device
    // sees at most min(workers, blocks) concurrent readers — using the
    // declared worker count when blocks are few would overcharge seek
    // interleaving on spindle devices.
    let effective = workers.max(1).min(blocks.len().max(1));
    // Workers read round-robin-assigned blocks: scattered, not sequential —
    // the device model charges real seeks per request.
    let ctx = ReadCtx { threads: effective, sequential: effective == 1, ..ctx };
    let accounts: Vec<IoAccount> = (0..workers.max(1)).map(|_| IoAccount::new()).collect();
    let mut edges = 0u64;
    for (i, &(bs, be)) in blocks.iter().enumerate() {
        let acct = &accounts[i % accounts.len()];
        let dec = webgraph::Decoder::open(store, base, &meta, &offsets, ctx, acct)?;
        let block = acct.time_cpu(|| dec.decode_range_with_scan(bs, be, acct, scan))?;
        edges += block.num_edges();
        acct.charge_io(dispatch_latency, 0); // scheduler roundtrip per block
    }
    if std::env::var("PG_DEBUG_ACCOUNTS").is_ok() {
        for (i, a) in accounts.iter().enumerate() {
            if a.elapsed_seconds() > 0.0 {
                eprintln!(
                    "    worker {i}: io={:.4}s cpu={:.4}s bytes={} reqs={}",
                    a.io_seconds(), a.cpu_seconds(), a.bytes_read(), a.requests()
                );
            }
        }
    }
    let parallel = match cores {
        Some(c) => phase_elapsed_with_cores(&accounts, c),
        None => phase_elapsed(&accounts),
    };
    let device_bytes: u64 =
        accounts.iter().map(|a| a.bytes_read()).sum::<u64>() + seq_acct.bytes_read();
    Ok(ParagrapherLoad {
        measurement: LoadMeasurement {
            elapsed: sequential + parallel,
            edges,
            device_bytes,
        },
        sequential_seconds: sequential,
        parallel_seconds: parallel,
        blocks: blocks.len(),
    })
}

/// Result of a modeled ParaGrapher load.
#[derive(Debug, Clone, Copy)]
pub struct ParagrapherLoad {
    pub measurement: LoadMeasurement,
    pub sequential_seconds: f64,
    pub parallel_seconds: f64,
    pub blocks: usize,
}

/// In-memory bytes a full uncompressed load needs (the OOM model for the
/// "-1" bars of Figs. 5/6): offsets (u64) + edges (u32).
pub fn full_load_memory_bytes(num_vertices: usize, num_edges: u64) -> u64 {
    (num_vertices as u64 + 1) * 8 + num_edges * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::runtime::NativeScan;
    use crate::storage::DeviceKind;

    #[test]
    fn paragrapher_load_counts_all_edges() {
        let g = generators::barabasi_albert(1000, 5, 3);
        let store = SimStore::new(DeviceKind::Hdd);
        FormatKind::WebGraph.write_to_store(&g, &store, "g");
        let r =
            modeled_paragrapher_load(&store, "g", 4, 2048, &NativeScan, 0.0, None).unwrap();
        assert_eq!(r.measurement.edges, g.num_edges());
        assert!(r.blocks > 1);
        assert!(r.sequential_seconds > 0.0);
        assert!(r.parallel_seconds > 0.0);
    }

    #[test]
    fn more_workers_less_modeled_time_on_parallel_device() {
        let g = generators::barabasi_albert(3000, 8, 5);
        let store = SimStore::new(DeviceKind::Ssd);
        FormatKind::WebGraph.write_to_store(&g, &store, "g");
        let one =
            modeled_paragrapher_load(&store, "g", 1, 4096, &NativeScan, 0.0, None).unwrap();
        let four =
            modeled_paragrapher_load(&store, "g", 4, 4096, &NativeScan, 0.0, None).unwrap();
        assert!(
            four.parallel_seconds < one.parallel_seconds,
            "4 workers {} vs 1 worker {}",
            four.parallel_seconds,
            one.parallel_seconds
        );
    }

    #[test]
    fn dispatch_latency_penalizes_small_buffers() {
        let g = generators::barabasi_albert(2000, 6, 7);
        let store = SimStore::new(DeviceKind::Ssd);
        FormatKind::WebGraph.write_to_store(&g, &store, "g");
        let small =
            modeled_paragrapher_load(&store, "g", 2, 256, &NativeScan, 1e-3, None).unwrap();
        let large =
            modeled_paragrapher_load(&store, "g", 2, 1 << 20, &NativeScan, 1e-3, None)
                .unwrap();
        assert!(small.blocks > large.blocks * 4);
        assert!(
            small.measurement.elapsed > large.measurement.elapsed,
            "small buffers pay dispatch: {} vs {}",
            small.measurement.elapsed,
            large.measurement.elapsed
        );
    }

    #[test]
    fn oom_model() {
        assert!(full_load_memory_bytes(1000, 1_000_000) > 4_000_000);
    }
}

#[cfg(test)]
mod probe {
    use super::*;
    use crate::graph::generators::Dataset;
    use crate::runtime::NativeScan;
    use crate::storage::DeviceKind;

    #[test]
    fn dbg_probe_tw_hdd() {
        for ds in [Dataset::Tw, Dataset::Cw] {
        let g = ds.generate(2, 42);
        let store = SimStore::new_scaled(DeviceKind::Hdd);
        let wg = FormatKind::WebGraph.write_to_store(&g, &store, "w");
        let bin = FormatKind::BinCsx.write_to_store(&g, &store, "b");
        eprintln!("edges={} wg_bytes={} bin_bytes={}", g.num_edges(), wg, bin);
        store.drop_cache();
        for workers in [9usize, 36] {
        let r = modeled_paragrapher_load(&store, "w", workers, 64 << 10, &NativeScan, 2e-3, None).unwrap();
        eprintln!(
            "wg: seq={:.4}s par={:.4}s blocks={} meps={:.1} bytes={}",
            r.sequential_seconds, r.parallel_seconds, r.blocks,
            r.measurement.me_per_sec(), r.measurement.device_bytes
        );
        }
        let m = modeled_full_load(&store, "b", FormatKind::BinCsx, 8).unwrap();
        eprintln!("bin: elapsed={:.4}s meps={:.1} bytes={}", m.elapsed, m.me_per_sec(), m.device_bytes);
        }
    }
}
