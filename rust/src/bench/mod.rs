//! In-repo micro-benchmark harness (criterion is unavailable offline).
//!
//! Usage pattern (in `benches/*.rs`, `harness = false`):
//!
//! ```ignore
//! let mut h = Harness::new("fig5_graph_loading");
//! h.bench("RD/HDD/webgraph", || { ... });
//! h.finish(); // prints the table, writes bench_results/<name>.json
//! ```
//!
//! Most of this repo's benches measure *modeled* (virtual-clock) time — the
//! closure returns a metric directly — so the harness supports both
//! wall-clock timing (`bench`) and reported metrics (`report`).

pub mod workloads;

use std::time::Instant;

use crate::util::json::Json;

/// Simple statistics over repeated wall-clock runs.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub median: f64,
    pub min: f64,
    pub max: f64,
    pub iters: usize,
}

fn stats(mut samples: Vec<f64>) -> Stats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    Stats { median: samples[n / 2], min: samples[0], max: samples[n - 1], iters: n }
}

/// One bench harness = one results file + one printed section.
pub struct Harness {
    name: String,
    results: Json,
    t0: Instant,
    /// Wall-clock budget hint per case (keeps full `cargo bench` bounded).
    pub max_iters: usize,
    pub min_iters: usize,
    pub target_seconds: f64,
}

impl Harness {
    pub fn new(name: &str) -> Self {
        println!("\n=== bench: {name} ===");
        Self {
            name: name.to_string(),
            results: Json::obj(),
            t0: Instant::now(),
            max_iters: 25,
            min_iters: 3,
            target_seconds: 2.0,
        }
    }

    /// Measure wall-clock time of `f` (median over adaptive iterations).
    pub fn bench<T>(&mut self, case: &str, mut f: impl FnMut() -> T) -> Stats {
        // Warmup.
        let _ = f();
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters
                && start.elapsed().as_secs_f64() < self.target_seconds)
        {
            let t = Instant::now();
            let _ = f();
            samples.push(t.elapsed().as_secs_f64());
        }
        let s = stats(samples);
        println!(
            "{case:<56} {:>12.6}s  (min {:.6}s, {} iters)",
            s.median, s.min, s.iters
        );
        let mut o = Json::obj();
        o.set("median_s", s.median).set("min_s", s.min).set("iters", s.iters);
        self.results.set(case, o);
        s
    }

    /// Record a metric computed by the experiment itself (e.g. modeled
    /// ME/s from the virtual clock).
    pub fn report(&mut self, case: &str, metric: &str, value: f64) {
        println!("{case:<56} {value:>12.3} {metric}");
        let mut o = Json::obj();
        o.set(metric, value);
        match &mut self.results {
            Json::Obj(map) => {
                if let Some(Json::Obj(existing)) = map.get_mut(case) {
                    existing.insert(metric.to_string(), Json::Num(value));
                } else {
                    map.insert(case.to_string(), o);
                }
            }
            _ => unreachable!(),
        }
    }

    /// Attach arbitrary JSON (e.g. a whole curve) under a key.
    pub fn attach(&mut self, key: &str, value: Json) {
        self.results.set(key, value);
    }

    /// Print a free-form note into the bench log.
    pub fn note(&mut self, text: &str) {
        println!("  # {text}");
    }

    /// Write results JSON and a footer. Call last.
    pub fn finish(self) {
        let dir = std::path::Path::new("bench_results");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{}.json", self.name));
        let mut wrapper = Json::obj();
        wrapper.set("bench", self.name.as_str()).set("results", self.results);
        let _ = std::fs::write(&path, wrapper.to_string_pretty());
        println!(
            "=== {} done in {:.1}s -> {} ===",
            self.name,
            self.t0.elapsed().as_secs_f64(),
            path.display()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_median() {
        let s = stats(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn harness_runs_and_reports() {
        let mut h = Harness::new("unit-test-harness");
        h.min_iters = 2;
        h.max_iters = 3;
        h.target_seconds = 0.01;
        let s = h.bench("noop", || 1 + 1);
        assert!(s.iters >= 2);
        h.report("modeled", "me_per_s", 42.0);
        // finish writes into bench_results/ — tolerate sandboxed CWD.
        h.finish();
    }
}
