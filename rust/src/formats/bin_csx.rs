//! Binary CSX — the GAPBS-style serialized CSR: a fixed header, the offsets
//! array (u64 LE), the edges array (u32 LE) and, for weighted graphs, an f32
//! weights array. Loading is embarrassingly parallel: each thread reads its
//! byte range of each array directly into place.

use anyhow::{bail, Context, Result};

use crate::graph::{CsrGraph, VertexId};
use crate::storage::sim::ReadCtx;
use crate::storage::{IoAccount, SimStore};
use crate::util::chunk_range;
use crate::util::pool::parallel_map;

const MAGIC: u32 = 0x4253_5843; // "CXSB"
const VERSION: u32 = 1;
const FLAG_WEIGHTED: u32 = 1;
/// Header: magic, version, flags, n (u64), m (u64).
const HEADER_LEN: usize = 4 + 4 + 4 + 8 + 8;

pub fn serialize(graph: &CsrGraph, base: &str) -> Vec<(String, Vec<u8>)> {
    let n = graph.num_vertices() as u64;
    let m = graph.num_edges();
    let weighted = graph.is_weighted();
    let mut out = Vec::with_capacity(
        HEADER_LEN + (n as usize + 1) * 8 + m as usize * 4 + if weighted { m as usize * 4 } else { 0 },
    );
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(if weighted { FLAG_WEIGHTED } else { 0 }).to_le_bytes());
    out.extend_from_slice(&n.to_le_bytes());
    out.extend_from_slice(&m.to_le_bytes());
    for &o in &graph.offsets {
        out.extend_from_slice(&o.to_le_bytes());
    }
    for &e in &graph.edges {
        out.extend_from_slice(&e.to_le_bytes());
    }
    for &w in &graph.weights {
        out.extend_from_slice(&w.to_le_bytes());
    }
    vec![(format!("{base}.bcsx"), out)]
}

pub fn load(
    store: &SimStore,
    base: &str,
    ctx: ReadCtx,
    accounts: &[IoAccount],
) -> Result<CsrGraph> {
    let name = format!("{base}.bcsx");
    let file = store.open(&name).with_context(|| format!("missing {name}"))?;
    if file.len() < HEADER_LEN as u64 {
        bail!("{name}: too short for header");
    }
    let header = file.read(0, HEADER_LEN as u64, ctx, &accounts[0]);
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    let version = u32::from_le_bytes(header[4..8].try_into().unwrap());
    let flags = u32::from_le_bytes(header[8..12].try_into().unwrap());
    if magic != MAGIC {
        bail!("{name}: bad magic {magic:#x}");
    }
    if version != VERSION {
        bail!("{name}: unsupported version {version}");
    }
    let weighted = flags & FLAG_WEIGHTED != 0;
    let n = u64::from_le_bytes(header[12..20].try_into().unwrap()) as usize;
    let m = u64::from_le_bytes(header[20..28].try_into().unwrap()) as usize;

    let offsets_pos = HEADER_LEN as u64;
    let edges_pos = offsets_pos + (n as u64 + 1) * 8;
    let weights_pos = edges_pos + m as u64 * 4;
    let expect_len = weights_pos + if weighted { m as u64 * 4 } else { 0 };
    if file.len() < expect_len {
        bail!("{name}: truncated ({} < {expect_len})", file.len());
    }

    let threads = accounts.len().max(1);

    // Offsets array (parallel ranged reads).
    let offsets: Vec<u64> = {
        let per: Vec<Vec<u64>> = parallel_map(threads, threads, |t| {
            let (s, e) = chunk_range(n + 1, threads, t);
            let bytes =
                file.read(offsets_pos + s as u64 * 8, (e - s) as u64 * 8, ctx, &accounts[t]);
            accounts[t].time_cpu(|| {
                bytes.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect()
            })
        });
        per.into_iter().flatten().collect()
    };

    // Edges array.
    let edges: Vec<VertexId> = {
        let per: Vec<Vec<VertexId>> = parallel_map(threads, threads, |t| {
            let (s, e) = chunk_range(m, threads, t);
            let bytes = file.read(edges_pos + s as u64 * 4, (e - s) as u64 * 4, ctx, &accounts[t]);
            accounts[t].time_cpu(|| {
                bytes.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect()
            })
        });
        per.into_iter().flatten().collect()
    };

    let weights: Vec<f32> = if weighted {
        let per: Vec<Vec<f32>> = parallel_map(threads, threads, |t| {
            let (s, e) = chunk_range(m, threads, t);
            let bytes =
                file.read(weights_pos + s as u64 * 4, (e - s) as u64 * 4, ctx, &accounts[t]);
            accounts[t].time_cpu(|| {
                bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
            })
        });
        per.into_iter().flatten().collect()
    } else {
        Vec::new()
    };

    let g = CsrGraph { offsets, edges, weights };
    g.validate().map_err(|e| anyhow::anyhow!("{name}: invalid CSX: {e}"))?;
    Ok(g)
}

/// Read only the offsets array — O(|V|) — without touching edge data.
/// Supports the §6 "loading from storage instead of processing" use case
/// (e.g. partitioning decisions before any edge is read).
pub fn load_offsets(
    store: &SimStore,
    base: &str,
    ctx: ReadCtx,
    acct: &IoAccount,
) -> Result<Vec<u64>> {
    let name = format!("{base}.bcsx");
    let file = store.open(&name).with_context(|| format!("missing {name}"))?;
    let header = file.read(0, HEADER_LEN as u64, ctx, acct);
    if header.len() < HEADER_LEN {
        bail!("{name}: too short");
    }
    let n = u64::from_le_bytes(header[12..20].try_into().unwrap()) as usize;
    let bytes = file.read(HEADER_LEN as u64, (n as u64 + 1) * 8, ctx, acct);
    Ok(bytes.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::storage::DeviceKind;

    fn accounts(n: usize) -> Vec<IoAccount> {
        (0..n).map(|_| IoAccount::new()).collect()
    }

    #[test]
    fn roundtrip_unweighted() {
        let g = generators::rmat(8, 8, 2);
        let store = SimStore::new(DeviceKind::Dram);
        for (name, data) in serialize(&g, "g") {
            store.put(&name, data);
        }
        for t in [1usize, 3, 8] {
            assert_eq!(load(&store, "g", ReadCtx::default(), &accounts(t)).unwrap(), g);
        }
    }

    #[test]
    fn roundtrip_weighted() {
        let g = CsrGraph::from_weighted_edges(5, &[(0, 4, 1.25), (4, 0, -7.5), (2, 3, 0.0)]);
        let store = SimStore::new(DeviceKind::Dram);
        for (name, data) in serialize(&g, "w") {
            store.put(&name, data);
        }
        assert_eq!(load(&store, "w", ReadCtx::default(), &accounts(2)).unwrap(), g);
    }

    #[test]
    fn offsets_only_reads_o_v_bytes() {
        // Large enough that the offsets array spans few cache pages while
        // the edge data spans many (page-granular charging).
        let g = generators::rmat(11, 16, 4);
        let store = SimStore::new(DeviceKind::Dram);
        for (name, data) in serialize(&g, "g") {
            store.put(&name, data);
        }
        let acct = IoAccount::new();
        let offs = load_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
        assert_eq!(offs, g.offsets);
        let full_size = store.file_len("g.bcsx").unwrap();
        assert!(
            acct.bytes_read() < full_size / 2,
            "offsets read {} of {full_size}",
            acct.bytes_read()
        );
    }

    #[test]
    fn corrupt_magic_rejected() {
        let g = generators::rmat(6, 4, 2);
        let store = SimStore::new(DeviceKind::Dram);
        let (name, mut data) = serialize(&g, "g").pop().unwrap();
        data[0] ^= 0xFF;
        store.put(&name, data);
        assert!(load(&store, "g", ReadCtx::default(), &accounts(1)).is_err());
    }

    #[test]
    fn truncated_rejected() {
        let g = generators::rmat(6, 4, 2);
        let store = SimStore::new(DeviceKind::Dram);
        let (name, mut data) = serialize(&g, "g").pop().unwrap();
        data.truncate(data.len() - 10);
        store.put(&name, data);
        assert!(load(&store, "g", ReadCtx::default(), &accounts(1)).is_err());
    }
}
