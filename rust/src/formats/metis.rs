//! Textual METIS format (Karypis & Kumar) — §2's "Textual Metis": a header
//! `n m [fmt]` followed by one line per vertex listing its (1-based)
//! neighbors. METIS counts each undirected edge once in the header but
//! lists it in both endpoint lines; we preserve that convention, so the
//! format is defined for symmetric graphs.

use anyhow::{bail, Context, Result};

use crate::graph::{CsrGraph, VertexId};
use crate::storage::sim::ReadCtx;
use crate::storage::{IoAccount, SimStore};

pub fn serialize(graph: &CsrGraph, base: &str) -> Vec<(String, Vec<u8>)> {
    let n = graph.num_vertices();
    let mut out = String::new();
    // Directed edge count must be even for a symmetric graph.
    out.push_str(&format!("{} {}\n", n, graph.num_edges() / 2));
    for v in 0..n {
        let mut first = true;
        for &d in graph.neighbors(v as VertexId) {
            if !first {
                out.push(' ');
            }
            out.push_str(&(d + 1).to_string());
            first = false;
        }
        out.push('\n');
    }
    vec![(format!("{base}.metis"), out.into_bytes())]
}

pub fn load(store: &SimStore, base: &str, ctx: ReadCtx, acct: &IoAccount) -> Result<CsrGraph> {
    let name = format!("{base}.metis");
    let file = store.open(&name).with_context(|| format!("missing {name}"))?;
    let bytes = file.read(0, file.len(), ctx, acct);
    let text = std::str::from_utf8(&bytes).context("metis not UTF-8")?;
    let mut lines = text.lines().filter(|l| !l.trim_start().starts_with('%'));
    let header = lines.next().context("empty file")?;
    let mut it = header.split_whitespace();
    let n: usize = it.next().context("n")?.parse()?;
    let m: u64 = it.next().context("m")?.parse()?;

    let mut offsets = Vec::with_capacity(n + 1);
    offsets.push(0u64);
    let mut edges: Vec<VertexId> = Vec::with_capacity(2 * m as usize);
    for v in 0..n {
        let line = lines.next().with_context(|| format!("missing line for vertex {v}"))?;
        for tok in line.split_whitespace() {
            let d: u64 = tok.parse().with_context(|| format!("vertex {v}: {tok:?}"))?;
            if d == 0 || d > n as u64 {
                bail!("{name}: 1-based neighbor {d} out of range at vertex {v}");
            }
            edges.push((d - 1) as VertexId);
        }
        offsets.push(edges.len() as u64);
    }
    if edges.len() as u64 != 2 * m {
        bail!("{name}: {} directed edges, header said {} undirected", edges.len(), m);
    }
    let mut g = CsrGraph { offsets, edges, weights: Vec::new() };
    g.sort_neighbors();
    g.validate().map_err(|e| anyhow::anyhow!("{name}: {e}"))?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::storage::DeviceKind;

    #[test]
    fn roundtrip_symmetric() {
        let g = generators::road_lattice(12, 10, 0, 1);
        let store = SimStore::new(DeviceKind::Dram);
        for (name, data) in serialize(&g, "g") {
            store.put(&name, data);
        }
        let acct = IoAccount::new();
        assert_eq!(load(&store, "g", ReadCtx::default(), &acct).unwrap(), g);
    }

    #[test]
    fn known_tiny_file() {
        // Triangle 1-2-3 (1-based METIS).
        let store = SimStore::new(DeviceKind::Dram);
        store.put("t.metis", b"3 3\n2 3\n1 3\n1 2\n".to_vec());
        let acct = IoAccount::new();
        let g = load(&store, "t", ReadCtx::default(), &acct).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 6);
        assert_eq!(g.neighbors(0), &[1, 2]);
    }

    #[test]
    fn header_mismatch_rejected() {
        let store = SimStore::new(DeviceKind::Dram);
        store.put("b.metis", b"3 5\n2 3\n1 3\n1 2\n".to_vec());
        let acct = IoAccount::new();
        assert!(load(&store, "b", ReadCtx::default(), &acct).is_err());
    }

    #[test]
    fn out_of_range_neighbor_rejected() {
        let store = SimStore::new(DeviceKind::Dram);
        store.put("r.metis", b"2 1\n2\n7\n".to_vec());
        let acct = IoAccount::new();
        assert!(load(&store, "r", ReadCtx::default(), &acct).is_err());
    }
}
