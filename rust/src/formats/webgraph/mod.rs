//! WebGraph-style compressed graph format (Boldi–Vigna), in Rust.
//!
//! The paper loads graphs published in WebGraph format through the Java
//! reference implementation; we implement the format family ourselves (the
//! paper's §7 notes WebGraph is being reimplemented in lower-level
//! languages). The encoder uses the four techniques §2 lists:
//!
//! 1. **gap (delta) encoding** of successor lists,
//! 2. **reference compression** — copy a subset of a previous vertex's
//!    list, described by alternating copy/skip blocks,
//! 3. **interval representation** — runs of ≥ `min_interval_len`
//!    consecutive successors stored as (left, len),
//! 4. **residuals** — everything else, ζ_k-coded gaps.
//!
//! Three files are produced (§4.4, §6):
//! * `{base}.graph` — the compressed bit stream,
//! * `{base}.offsets` — binary sidecar: per-vertex *bit* offsets into the
//!   stream plus the CSR *edge* offsets array (the paper stores offsets as
//!   a binary file to enable partitioning without touching the graph),
//! * `{base}.properties` — textual metadata (n, m, coding parameters).
//! * `{base}.weights` — optional f32 edge weights in CSR order (WG404).
//!
//! Random access (decode any vertex range without decoding the prefix) is
//! what makes ParaGrapher's *selective* loading possible; reference chains
//! are bounded by `max_ref_chain` at compression time so random access
//! never cascades more than a constant number of hops.

mod decode;
mod encode;
pub mod integrity;

pub use decode::{DecodedBlock, Decoder};
pub use encode::{compress, CompressionStats};

use anyhow::{bail, Context, Result};

use crate::graph::CsrGraph;
use crate::storage::sim::ReadCtx;
use crate::storage::{IoAccount, SimStore};
use crate::util::pool::parallel_map;
use crate::util::{chunk_range, codes::Code};

/// Encoder/decoder parameters (the `.properties` content).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WgParams {
    /// Reference window: vertex v may copy from v-1 .. v-window.
    pub window: u32,
    /// Maximum reference chain depth (bounds random-access cascades).
    pub max_ref_chain: u32,
    /// ζ parameter for residual gaps.
    pub zeta_k: u32,
    /// Minimum run length stored as an interval.
    pub min_interval_len: u32,
}

impl Default for WgParams {
    fn default() -> Self {
        Self { window: 7, max_ref_chain: 3, zeta_k: 3, min_interval_len: 3 }
    }
}

impl WgParams {
    pub fn residual_code(&self) -> Code {
        Code::Zeta(self.zeta_k)
    }
}

/// Parsed `.properties` + offsets sidecar header.
#[derive(Debug, Clone)]
pub struct WgMeta {
    pub num_vertices: usize,
    pub num_edges: u64,
    pub params: WgParams,
    pub weighted: bool,
}

/// Serialize a graph into the WebGraph file family.
pub fn serialize(graph: &CsrGraph, base: &str) -> Vec<(String, Vec<u8>)> {
    serialize_with(graph, base, WgParams::default())
}

pub fn serialize_with(graph: &CsrGraph, base: &str, params: WgParams) -> Vec<(String, Vec<u8>)> {
    let (stream, bit_offsets, _stats) = compress(graph, params);
    let n = graph.num_vertices();
    let m = graph.num_edges();

    // Offsets sidecar: header + γ-coded deltas, like WebGraph's `.offsets`
    // file (storing them raw would cost 16 B/vertex and dominate sparse
    // graphs). Bit-offset deltas are record lengths; edge-offset deltas are
    // degrees — both small, γ-friendly quantities. The whole sidecar is
    // decoded once at open time (the §5.6 sequential phase).
    let mut offsets = Vec::with_capacity(16 + (n + 1) * 2);
    offsets.extend_from_slice(&(n as u64).to_le_bytes());
    offsets.extend_from_slice(&m.to_le_bytes());
    let mut w = crate::util::bitstream::BitWriter::with_capacity((n + 1) * 2);
    let mut prev = 0u64;
    for &b in &bit_offsets {
        crate::util::codes::write_gamma(&mut w, b - prev);
        prev = b;
    }
    let mut prev = 0u64;
    for &e in &graph.offsets {
        crate::util::codes::write_gamma(&mut w, e - prev);
        prev = e;
    }
    offsets.extend_from_slice(&w.into_bytes());

    let properties = format!(
        "version=1\nnodes={}\narcs={}\nwindow={}\nmaxrefchain={}\nzetak={}\nminintervallength={}\nweighted={}\n",
        n, m, params.window, params.max_ref_chain, params.zeta_k, params.min_interval_len,
        graph.is_weighted()
    );

    let mut files = vec![
        (format!("{base}.graph"), stream),
        (format!("{base}.offsets"), offsets),
        (format!("{base}.properties"), properties.into_bytes()),
    ];
    if graph.is_weighted() {
        let mut w = Vec::with_capacity(graph.weights.len() * 4);
        for &x in &graph.weights {
            w.extend_from_slice(&x.to_le_bytes());
        }
        files.push((format!("{base}.weights"), w));
    }
    files
}

/// Read and parse `{base}.properties`.
pub fn read_meta(store: &SimStore, base: &str, ctx: ReadCtx, acct: &IoAccount) -> Result<WgMeta> {
    let name = format!("{base}.properties");
    let file = store.open(&name).with_context(|| format!("missing {name}"))?;
    let bytes = file.read(0, file.len(), ctx, acct);
    let text = String::from_utf8(bytes).context("properties not UTF-8")?;
    let mut n = None;
    let mut m = None;
    let mut params = WgParams::default();
    let mut weighted = false;
    for line in text.lines() {
        let Some((k, v)) = line.split_once('=') else { continue };
        match k.trim() {
            "nodes" => n = Some(v.trim().parse::<usize>().context("nodes")?),
            "arcs" => m = Some(v.trim().parse::<u64>().context("arcs")?),
            "window" => params.window = v.trim().parse().context("window")?,
            "maxrefchain" => params.max_ref_chain = v.trim().parse().context("maxrefchain")?,
            "zetak" => params.zeta_k = v.trim().parse().context("zetak")?,
            "minintervallength" => {
                params.min_interval_len = v.trim().parse().context("minintervallength")?
            }
            "weighted" => weighted = v.trim() == "true",
            _ => {}
        }
    }
    let (Some(num_vertices), Some(num_edges)) = (n, m) else {
        bail!("{name}: missing nodes/arcs");
    };
    Ok(WgMeta { num_vertices, num_edges, params, weighted })
}

/// Offsets sidecar, fully loaded: per-vertex bit offsets and edge offsets.
#[derive(Debug, Clone)]
pub struct WgOffsets {
    pub bit_offsets: Vec<u64>,
    pub edge_offsets: Vec<u64>,
}

/// Load the sidecar — an O(|V|) read, no graph data touched (§6's
/// "loading from storage instead of processing").
pub fn read_offsets(
    store: &SimStore,
    base: &str,
    ctx: ReadCtx,
    acct: &IoAccount,
) -> Result<WgOffsets> {
    let name = format!("{base}.offsets");
    let file = store.open(&name).with_context(|| format!("missing {name}"))?;
    let bytes = file.read(0, file.len(), ctx, acct);
    if bytes.len() < 16 {
        bail!("{name}: truncated header");
    }
    let n = u64::from_le_bytes(bytes[0..8].try_into().unwrap()) as usize;
    let mut r = crate::util::bitstream::BitReader::new(&bytes[16..]);
    let mut decode_prefix = |count: usize| -> Result<Vec<u64>> {
        let mut out = Vec::with_capacity(count);
        let mut acc = 0u64;
        for i in 0..count {
            let d = crate::util::codes::read_gamma(&mut r)
                .map_err(|e| anyhow::anyhow!("{name}: truncated at entry {i}: {e}"))?;
            acc += d;
            out.push(acc);
        }
        Ok(out)
    };
    let bit_offsets = decode_prefix(n + 1)?;
    let edge_offsets = decode_prefix(n + 1)?;
    Ok(WgOffsets { bit_offsets, edge_offsets })
}

/// Whole-graph parallel load (the use-case-A path used by the Fig. 5
/// baseline comparison; the coordinator uses `Decoder` directly for
/// selective loads).
pub fn load_full(
    store: &SimStore,
    base: &str,
    ctx: ReadCtx,
    accounts: &[IoAccount],
) -> Result<CsrGraph> {
    // Sequential metadata phase (§5.6 measures this as the scalability
    // bottleneck — keep it sequential on purpose, charged to account 0).
    let meta = read_meta(store, base, ctx, &accounts[0])?;
    let offsets = read_offsets(store, base, ctx, &accounts[0])?;
    let n = meta.num_vertices;
    let threads = accounts.len().max(1);

    // Parallel decode: split vertices into chunks balanced by edge count
    // (vertex boundaries chosen where the cumulative edge offset crosses
    // each thread's fair share).
    let boundaries: Vec<usize> = (0..=threads)
        .map(|t| {
            if t == 0 {
                0
            } else if t == threads {
                n
            } else {
                let (e_t, _) = chunk_range(meta.num_edges as usize, threads, t);
                offsets.edge_offsets.partition_point(|&e| e < e_t as u64).min(n)
            }
        })
        .collect();
    let blocks: Vec<DecodedBlock> = parallel_map(threads, threads, |t| {
        let (v_start, v_end) = (boundaries[t], boundaries[t + 1].max(boundaries[t]));
        Decoder::open(store, base, &meta, &offsets, ctx, &accounts[t]).and_then(|dec| {
            accounts[t].time_cpu(|| dec.decode_range(v_start, v_end, &accounts[t]))
        })
    })
    .into_iter()
    .collect::<Result<Vec<_>>>()?;

    // Stitch blocks into one CSR (charged to worker 0).
    accounts[0].time_cpu(|| {
        let m = meta.num_edges as usize;
        let mut edges = Vec::with_capacity(m);
        let mut offs = Vec::with_capacity(n + 1);
        offs.push(0u64);
        for b in &blocks {
            for i in 0..b.num_vertices() {
                let (s, e) = b.vertex_span(i);
                edges.extend_from_slice(&b.edges[s..e]);
                offs.push(edges.len() as u64);
            }
        }
        let weights = if meta.weighted {
            let name = format!("{base}.weights");
            let file = store.open(&name).with_context(|| format!("missing {name}"))?;
            let bytes = file.read(0, file.len(), ctx, &accounts[0]);
            bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
        } else {
            Vec::new()
        };
        let g = CsrGraph { offsets: offs, edges, weights };
        g.validate().map_err(|e| anyhow::anyhow!("decoded graph invalid: {e}"))?;
        Ok(g)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::storage::DeviceKind;

    fn accounts(n: usize) -> Vec<IoAccount> {
        (0..n).map(|_| IoAccount::new()).collect()
    }

    fn store_with(g: &CsrGraph, base: &str) -> SimStore {
        let store = SimStore::new(DeviceKind::Dram);
        for (name, data) in serialize(g, base) {
            store.put(&name, data);
        }
        store
    }

    #[test]
    fn roundtrip_rmat() {
        let g = generators::rmat(8, 8, 1);
        let store = store_with(&g, "g");
        for t in [1usize, 2, 4, 7] {
            let loaded = load_full(&store, "g", ReadCtx::default(), &accounts(t)).unwrap();
            assert_eq!(loaded, g, "threads={t}");
        }
    }

    #[test]
    fn roundtrip_all_generators() {
        for (i, g) in [
            generators::road_lattice(20, 20, 5, 2),
            generators::barabasi_albert(600, 5, 3),
            generators::erdos_renyi(300, 2000, 4),
            generators::similarity_blocks(300, 32, 8, 5),
        ]
        .into_iter()
        .enumerate()
        {
            let base = format!("g{i}");
            let store = store_with(&g, &base);
            let loaded = load_full(&store, &base, ReadCtx::default(), &accounts(3)).unwrap();
            assert_eq!(loaded, g, "generator {i}");
        }
    }

    #[test]
    fn compresses_better_than_4_bytes_per_edge() {
        let g = generators::barabasi_albert(4000, 10, 7);
        let store = store_with(&g, "g");
        let graph_bytes = store.file_len("g.graph").unwrap();
        let bpe = graph_bytes as f64 * 8.0 / g.num_edges() as f64;
        assert!(bpe < 16.0, "WebGraph stream should be well under 16 bits/edge, got {bpe:.1}");
    }

    #[test]
    fn road_graph_compresses_extremely_well() {
        // Locality + intervals: lattice rows are consecutive runs.
        let g = generators::road_lattice(60, 60, 0, 1);
        let store = store_with(&g, "g");
        let graph_bytes = store.file_len("g.graph").unwrap();
        let bpe = graph_bytes as f64 * 8.0 / g.num_edges() as f64;
        // Real-world reference point: Table 3's RD is ~16.8 bits/edge in
        // WebGraph; a clean lattice should land well under that.
        assert!(bpe < 14.0, "lattice should compress well, got {bpe:.1} bits/edge");
    }

    #[test]
    fn meta_and_offsets_roundtrip() {
        let g = generators::rmat(7, 6, 9);
        let store = store_with(&g, "g");
        let acct = IoAccount::new();
        let meta = read_meta(&store, "g", ReadCtx::default(), &acct).unwrap();
        assert_eq!(meta.num_vertices, g.num_vertices());
        assert_eq!(meta.num_edges, g.num_edges());
        assert!(!meta.weighted);
        let offs = read_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
        assert_eq!(offs.edge_offsets, g.offsets);
        assert_eq!(offs.bit_offsets.len(), g.num_vertices() + 1);
        // Bit offsets strictly increasing for non-empty vertices.
        for v in 0..g.num_vertices() {
            assert!(offs.bit_offsets[v] <= offs.bit_offsets[v + 1]);
        }
    }

    #[test]
    fn weighted_roundtrip() {
        let g = CsrGraph::from_weighted_edges(
            6,
            &[(0, 1, 0.5), (0, 2, 1.5), (1, 2, 2.5), (5, 0, -1.0), (2, 3, 3.5)],
        );
        let store = store_with(&g, "w");
        let loaded = load_full(&store, "w", ReadCtx::default(), &accounts(2)).unwrap();
        assert_eq!(loaded, g);
    }

    #[test]
    fn custom_params_roundtrip() {
        let g = generators::barabasi_albert(500, 6, 11);
        for params in [
            WgParams { window: 0, max_ref_chain: 0, zeta_k: 2, min_interval_len: 2 },
            WgParams { window: 1, max_ref_chain: 1, zeta_k: 4, min_interval_len: 8 },
            WgParams { window: 15, max_ref_chain: 8, zeta_k: 3, min_interval_len: 3 },
        ] {
            let store = SimStore::new(DeviceKind::Dram);
            for (name, data) in serialize_with(&g, "p", params) {
                store.put(&name, data);
            }
            let loaded = load_full(&store, "p", ReadCtx::default(), &accounts(2)).unwrap();
            assert_eq!(loaded, g, "params {params:?}");
        }
    }

    #[test]
    fn truncated_offsets_rejected() {
        let g = generators::rmat(6, 4, 2);
        let store = SimStore::new(DeviceKind::Dram);
        for (name, mut data) in serialize(&g, "g") {
            if name.ends_with(".offsets") {
                data.truncate(data.len() / 2);
            }
            store.put(&name, data);
        }
        let acct = IoAccount::new();
        assert!(read_offsets(&store, "g", ReadCtx::default(), &acct).is_err());
    }
}
