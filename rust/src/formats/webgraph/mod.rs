//! WebGraph-style compressed graph format (Boldi–Vigna), in Rust.
//!
//! The paper loads graphs published in WebGraph format through the Java
//! reference implementation; we implement the format family ourselves (the
//! paper's §7 notes WebGraph is being reimplemented in lower-level
//! languages). The encoder uses the four techniques §2 lists:
//!
//! 1. **gap (delta) encoding** of successor lists,
//! 2. **reference compression** — copy a subset of a previous vertex's
//!    list, described by alternating copy/skip blocks,
//! 3. **interval representation** — runs of ≥ `min_interval_len`
//!    consecutive successors stored as (left, len),
//! 4. **residuals** — everything else, ζ_k-coded gaps.
//!
//! Three files are produced (§4.4, §6):
//! * `{base}.graph` — the compressed bit stream,
//! * `{base}.offsets` — binary sidecar: per-vertex *bit* offsets into the
//!   stream plus the CSR *edge* offsets array (the paper stores offsets as
//!   a binary file to enable partitioning without touching the graph),
//! * `{base}.properties` — textual metadata (n, m, coding parameters).
//! * `{base}.weights` — optional f32 edge weights in CSR order (WG404).
//!
//! Random access (decode any vertex range without decoding the prefix) is
//! what makes ParaGrapher's *selective* loading possible; reference chains
//! are bounded by `max_ref_chain` at compression time so random access
//! never cascades more than a constant number of hops.

mod decode;
mod encode;
pub mod integrity;

pub use decode::{
    DecodeScratch, DecodeSink, DecodedBlock, Decoder, MAX_SIDECAR_RESERVE_EDGES,
};
pub use encode::{compress, compress_stream, CompressionStats, StreamedCompression};

use anyhow::{bail, Context, Result};

use crate::graph::CsrGraph;
use crate::storage::sim::ReadCtx;
use crate::storage::{IoAccount, SimStore};
use crate::util::codes::Code;
use crate::util::elias_fano::{EliasFano, EliasFanoBuilder};

/// Encoder/decoder parameters (the `.properties` content).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WgParams {
    /// Reference window: vertex v may copy from v-1 .. v-window.
    pub window: u32,
    /// Maximum reference chain depth (bounds random-access cascades).
    pub max_ref_chain: u32,
    /// ζ parameter for residual gaps.
    pub zeta_k: u32,
    /// Minimum run length stored as an interval.
    pub min_interval_len: u32,
}

impl Default for WgParams {
    fn default() -> Self {
        Self { window: 7, max_ref_chain: 3, zeta_k: 3, min_interval_len: 3 }
    }
}

impl WgParams {
    pub fn residual_code(&self) -> Code {
        Code::Zeta(self.zeta_k)
    }
}

/// Parsed `.properties` + offsets sidecar header.
#[derive(Debug, Clone)]
pub struct WgMeta {
    pub num_vertices: usize,
    pub num_edges: u64,
    pub params: WgParams,
    pub weighted: bool,
}

/// Magic of the v2 offsets sidecar header. The v1 sidecar starts with the
/// raw vertex count, which for any real graph is far below 2^56, so the
/// high byte (0xFF here) can never collide with a v1 file.
pub const OFFSETS_MAGIC_V2: u64 = u64::from_le_bytes(*b"WGOFF2\xF0\xFF");

/// Serialize a graph into the WebGraph file family.
pub fn serialize(graph: &CsrGraph, base: &str) -> Vec<(String, Vec<u8>)> {
    serialize_with(graph, base, WgParams::default())
}

pub fn serialize_with(graph: &CsrGraph, base: &str, params: WgParams) -> Vec<(String, Vec<u8>)> {
    let (stream, bit_offsets, _stats) = compress(graph, params);
    let checksums = integrity::build_checksums(&stream);
    let n = graph.num_vertices();
    let m = graph.num_edges();

    // Offsets sidecar: header + γ-coded deltas, like WebGraph's `.offsets`
    // file (storing them raw would cost 16 B/vertex and dominate sparse
    // graphs). Bit-offset deltas are record lengths; edge-offset deltas are
    // degrees — both small, γ-friendly quantities. The v2 header declares
    // the two universes (total stream bits and edge count) so open time can
    // stream the deltas straight into the Elias–Fano index without ever
    // materializing 16 B/vertex of plain offsets (the §5.6 sequential
    // phase stays O(|V|) time but drops to the compressed footprint).
    let total_bits = *bit_offsets.last().expect("n+1 bit offsets");
    let mut offsets = Vec::with_capacity(32 + (n + 1) * 2);
    offsets.extend_from_slice(&OFFSETS_MAGIC_V2.to_le_bytes());
    offsets.extend_from_slice(&(n as u64).to_le_bytes());
    offsets.extend_from_slice(&m.to_le_bytes());
    offsets.extend_from_slice(&total_bits.to_le_bytes());
    let mut w = crate::util::bitstream::BitWriter::with_capacity((n + 1) * 2);
    let mut prev = 0u64;
    for &b in &bit_offsets {
        crate::util::codes::write_gamma(&mut w, b - prev);
        prev = b;
    }
    let mut prev = 0u64;
    for &e in &graph.offsets {
        crate::util::codes::write_gamma(&mut w, e - prev);
        prev = e;
    }
    offsets.extend_from_slice(&w.into_bytes());

    let properties = format!(
        "version=1\nnodes={}\narcs={}\nwindow={}\nmaxrefchain={}\nzetak={}\nminintervallength={}\nweighted={}\n",
        n, m, params.window, params.max_ref_chain, params.zeta_k, params.min_interval_len,
        graph.is_weighted()
    );

    let mut files = vec![
        (format!("{base}.graph"), stream),
        (format!("{base}.offsets"), offsets),
        (format!("{base}.properties"), properties.into_bytes()),
        // Per-chunk checksum sidecar (§6, the MS-BioGraphs discipline):
        // what the self-healing read path classifies failures against.
        (format!("{base}.checksums"), checksums),
    ];
    if graph.is_weighted() {
        let mut w = Vec::with_capacity(graph.weights.len() * 4);
        for &x in &graph.weights {
            w.extend_from_slice(&x.to_le_bytes());
        }
        files.push((format!("{base}.weights"), w));
    }
    files
}

/// Stream-compress a generator-defined (unweighted) graph straight into
/// `dir` as the WebGraph file family — the graph never exists in memory.
/// `.graph` bytes hit the disk as they are encoded
/// ([`compress_stream`]'s flush cadence); the offsets sidecar is assembled
/// afterwards from the γ-compressed delta streams the encoder kept. Every
/// produced file is byte-identical to [`serialize_with`] over the same
/// successor lists, so all open paths read it unchanged — this is the
/// out-of-core fixture writer for graphs larger than the page-cache
/// budget (or RAM).
pub fn write_stream_to_dir(
    dir: &std::path::Path,
    base: &str,
    n: usize,
    params: WgParams,
    successors: impl FnMut(usize, &mut Vec<crate::graph::VertexId>),
) -> Result<StreamedCompression> {
    use std::io::Write;
    let graph_path = dir.join(format!("{base}.graph"));
    let mut graph_file = std::fs::File::create(&graph_path)
        .with_context(|| format!("create {}", graph_path.display()))?;
    // Checksum the stream as it flushes: the sidecar comes out
    // byte-identical to `build_checksums` over the whole stream without
    // ever buffering it (the out-of-core contract).
    let mut sums = integrity::ChecksumBuilder::new();
    let out = compress_stream(n, params, successors, |bytes| {
        sums.update(bytes);
        graph_file.write_all(bytes).context("write .graph stream")
    })?;
    drop(graph_file);
    let sums_path = dir.join(format!("{base}.checksums"));
    std::fs::write(&sums_path, sums.finish())
        .with_context(|| format!("write {}", sums_path.display()))?;

    // v2 sidecar: header + the two γ-delta streams joined at *bit*
    // granularity (their standalone byte forms are padded; re-packing
    // through one BitWriter reproduces `serialize_with`'s single unpadded
    // stream exactly).
    let mut offsets = Vec::with_capacity(32 + out.bit_deltas.len() + out.edge_deltas.len());
    offsets.extend_from_slice(&OFFSETS_MAGIC_V2.to_le_bytes());
    offsets.extend_from_slice(&(n as u64).to_le_bytes());
    offsets.extend_from_slice(&out.num_edges.to_le_bytes());
    offsets.extend_from_slice(&out.total_bits.to_le_bytes());
    let mut w = crate::util::bitstream::BitWriter::with_capacity(
        out.bit_deltas.len() + out.edge_deltas.len(),
    );
    append_bits(&mut w, &out.bit_deltas, out.bit_delta_bits)?;
    append_bits(&mut w, &out.edge_deltas, out.edge_delta_bits)?;
    offsets.extend_from_slice(&w.into_bytes());
    let offsets_path = dir.join(format!("{base}.offsets"));
    std::fs::write(&offsets_path, offsets)
        .with_context(|| format!("write {}", offsets_path.display()))?;

    let properties = format!(
        "version=1\nnodes={}\narcs={}\nwindow={}\nmaxrefchain={}\nzetak={}\nminintervallength={}\nweighted=false\n",
        n, out.num_edges, params.window, params.max_ref_chain, params.zeta_k,
        params.min_interval_len
    );
    let props_path = dir.join(format!("{base}.properties"));
    std::fs::write(&props_path, properties)
        .with_context(|| format!("write {}", props_path.display()))?;
    Ok(out)
}

/// Append the first `nbits` bits of `bytes` (an MSB-first, byte-padded
/// stream) onto `w`, preserving bit alignment across the join.
fn append_bits(
    w: &mut crate::util::bitstream::BitWriter,
    bytes: &[u8],
    nbits: u64,
) -> Result<()> {
    let mut r = crate::util::bitstream::BitReader::new(bytes);
    let mut left = nbits;
    while left > 0 {
        let take = left.min(64) as u32;
        w.write_bits(r.read_bits(take).map_err(|e| anyhow::anyhow!("{e}"))?, take);
        left -= u64::from(take);
    }
    Ok(())
}

/// Read and parse `{base}.properties`.
pub fn read_meta(store: &SimStore, base: &str, ctx: ReadCtx, acct: &IoAccount) -> Result<WgMeta> {
    let name = format!("{base}.properties");
    let file = store.open(&name).with_context(|| format!("missing {name}"))?;
    let bytes = file.try_read(0, file.len(), ctx, acct)?;
    let text = String::from_utf8(bytes).context("properties not UTF-8")?;
    let mut n = None;
    let mut m = None;
    let mut params = WgParams::default();
    let mut weighted = false;
    for line in text.lines() {
        let Some((k, v)) = line.split_once('=') else { continue };
        match k.trim() {
            "nodes" => n = Some(v.trim().parse::<usize>().context("nodes")?),
            "arcs" => m = Some(v.trim().parse::<u64>().context("arcs")?),
            "window" => params.window = v.trim().parse().context("window")?,
            "maxrefchain" => params.max_ref_chain = v.trim().parse().context("maxrefchain")?,
            "zetak" => params.zeta_k = v.trim().parse().context("zetak")?,
            "minintervallength" => {
                params.min_interval_len = v.trim().parse().context("minintervallength")?
            }
            "weighted" => weighted = v.trim() == "true",
            _ => {}
        }
    }
    let (Some(num_vertices), Some(num_edges)) = (n, m) else {
        bail!("{name}: missing nodes/arcs");
    };
    Ok(WgMeta { num_vertices, num_edges, params, weighted })
}

/// Offsets sidecar, resident as two Elias–Fano indexes: per-vertex *bit*
/// offsets into the compressed stream and the CSR *edge* offsets (n+1
/// entries each). Succinct (~10 bits/vertex instead of 128) with O(1)
/// quantum-sampled access — the structure that lets an opened graph scale
/// to the paper's Table 3 vertex counts without 16 B/vertex of sidecar RAM.
#[derive(Debug, Clone)]
pub struct WgOffsets {
    bits: EliasFano,
    edges: EliasFano,
}

impl WgOffsets {
    /// Build from plain vectors (tests, oracles, and in-memory conversion).
    /// Both slices must be monotone with `n+1` entries.
    pub fn from_vecs(bit_offsets: &[u64], edge_offsets: &[u64]) -> Result<Self> {
        if bit_offsets.len() != edge_offsets.len() || bit_offsets.is_empty() {
            bail!("offsets vectors must be non-empty and equal-length");
        }
        Ok(Self {
            bits: EliasFano::from_monotone(bit_offsets).map_err(|e| anyhow::anyhow!("{e}"))?,
            edges: EliasFano::from_monotone(edge_offsets).map_err(|e| anyhow::anyhow!("{e}"))?,
        })
    }

    pub fn num_vertices(&self) -> usize {
        self.bits.len() - 1
    }

    /// Total bits of the compressed stream (== `bit_offset(n)`).
    pub fn total_bits(&self) -> u64 {
        self.bits.get(self.bits.len() - 1)
    }

    pub fn num_edges(&self) -> u64 {
        self.edges.get(self.edges.len() - 1)
    }

    /// Bit position of vertex `v`'s record in the `.graph` stream.
    #[inline]
    pub fn bit_offset(&self, v: usize) -> u64 {
        self.bits.get(v)
    }

    /// CSR edge offset of vertex `v`.
    #[inline]
    pub fn edge_offset(&self, v: usize) -> u64 {
        self.edges.get(v)
    }

    /// Out-degree of vertex `v` — an O(1) sidecar lookup, no graph data.
    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        (self.edges.get(v + 1) - self.edges.get(v)) as usize
    }

    /// Materialize edge offsets `[start, end]` (inclusive) as a plain
    /// vector (`csx_get_offsets`).
    pub fn edge_offsets_vec(&self, start: usize, end_inclusive: usize) -> Vec<u64> {
        self.edges.to_vec_range(start, end_inclusive + 1)
    }

    /// `partition_point` over the edge-offsets sequence (indices `0..=n`).
    pub fn edge_partition_point(&self, pred: impl Fn(u64) -> bool) -> usize {
        self.edges.partition_point(pred)
    }

    /// `partition_point` over the bit-offsets sequence (indices `0..=n`).
    pub fn bit_partition_point(&self, pred: impl Fn(u64) -> bool) -> usize {
        self.bits.partition_point(pred)
    }

    /// Resident footprint of both indexes, bytes.
    pub fn size_bytes(&self) -> usize {
        self.bits.size_bytes() + self.edges.size_bytes()
    }

    /// Footprint of the former plain representation (two `Vec<u64>`).
    pub fn plain_size_bytes(&self) -> usize {
        self.bits.plain_size_bytes() + self.edges.plain_size_bytes()
    }

    /// Fail fast when the sidecar disagrees with `.properties` — otherwise
    /// a vertex-count mismatch would surface as an out-of-bounds offsets
    /// lookup (a panic) deep inside a decode, and an edge-count mismatch as
    /// wrong-range answers from the edge-granular APIs. Called by every
    /// open path.
    pub fn check_matches(&self, meta: &WgMeta) -> Result<()> {
        if self.num_vertices() != meta.num_vertices {
            bail!(
                "offsets sidecar has {} vertices but properties say {}",
                self.num_vertices(),
                meta.num_vertices
            );
        }
        if self.num_edges() != meta.num_edges {
            bail!(
                "offsets sidecar has {} edges but properties say {}",
                self.num_edges(),
                meta.num_edges
            );
        }
        Ok(())
    }
}

/// Load the sidecar — an O(|V|) read, no graph data touched (§6's
/// "loading from storage instead of processing"). Understands both sidecar
/// layouts:
///
/// * **v2** (current): `[magic][n][m][total_bits]` + γ-delta stream —
///   decoded *streaming* into the Elias–Fano builders (the universes are in
///   the header), peak memory = the compressed index itself;
/// * **v1** (legacy, pre-EF): `[n][m]` + the same γ-delta stream — decoded
///   through a transient plain vector, then compressed in memory.
pub fn read_offsets(
    store: &SimStore,
    base: &str,
    ctx: ReadCtx,
    acct: &IoAccount,
) -> Result<WgOffsets> {
    let name = format!("{base}.offsets");
    let file = store.open(&name).with_context(|| format!("missing {name}"))?;
    let bytes = file.try_read(0, file.len(), ctx, acct)?;
    if bytes.len() >= 8
        && u64::from_le_bytes(bytes[0..8].try_into().unwrap()) == OFFSETS_MAGIC_V2
    {
        if bytes.len() < 32 {
            bail!("{name}: truncated v2 header");
        }
        let n = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let m = u64::from_le_bytes(bytes[16..24].try_into().unwrap());
        let total_bits = u64::from_le_bytes(bytes[24..32].try_into().unwrap());
        // Plausibility: 2(n+1) γ codes need ≥ 2(n+1) bits, so a valid file
        // always has n < 4·len. Rejecting here bounds every allocation below
        // (a corrupt header must not translate into an OOM-sized reserve).
        if n >= bytes.len().saturating_mul(4) {
            bail!("{name}: implausible vertex count {n} for {} sidecar bytes", bytes.len());
        }
        let mut r = crate::util::bitstream::BitReader::new(&bytes[32..]);
        let mut decode_into = |universe: u64, what: &str| -> Result<EliasFano> {
            let mut b = EliasFanoBuilder::new(n + 1, universe);
            let mut acc = 0u64;
            for i in 0..=n {
                let d = crate::util::codes::read_gamma(&mut r)
                    .map_err(|e| anyhow::anyhow!("{name}: truncated at {what} {i}: {e}"))?;
                acc = acc
                    .checked_add(d)
                    .with_context(|| format!("{name}: {what} overflow at entry {i}"))?;
                b.push(acc).map_err(|e| anyhow::anyhow!("{name}: {e}"))?;
            }
            if acc != universe {
                bail!("{name}: {what} sum {acc} != declared universe {universe}");
            }
            b.finish().map_err(|e| anyhow::anyhow!("{name}: {e}"))
        };
        let bits = decode_into(total_bits, "bit offset")?;
        let edges = decode_into(m, "edge offset")?;
        Ok(WgOffsets { bits, edges })
    } else {
        // v1 compatibility path.
        if bytes.len() < 16 {
            bail!("{name}: truncated header");
        }
        let n = u64::from_le_bytes(bytes[0..8].try_into().unwrap()) as usize;
        let m = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
        if n >= bytes.len().saturating_mul(4) {
            bail!("{name}: implausible vertex count {n} for {} sidecar bytes", bytes.len());
        }
        let mut r = crate::util::bitstream::BitReader::new(&bytes[16..]);
        let mut decode_prefix = |count: usize| -> Result<Vec<u64>> {
            let mut out = Vec::with_capacity(count);
            let mut acc = 0u64;
            for i in 0..count {
                let d = crate::util::codes::read_gamma(&mut r)
                    .map_err(|e| anyhow::anyhow!("{name}: truncated at entry {i}: {e}"))?;
                acc = acc
                    .checked_add(d)
                    .with_context(|| format!("{name}: offset overflow at entry {i}"))?;
                out.push(acc);
            }
            Ok(out)
        };
        let bit_offsets = decode_prefix(n + 1)?;
        let edge_offsets = decode_prefix(n + 1)?;
        if *edge_offsets.last().unwrap() != m {
            bail!("{name}: edge offsets sum to {}, header says {m}", edge_offsets.last().unwrap());
        }
        WgOffsets::from_vecs(&bit_offsets, &edge_offsets)
    }
}

/// Whole-graph parallel load (the use-case-A path used by the Fig. 5
/// baseline comparison; the coordinator uses `Decoder` directly for
/// selective loads).
pub fn load_full(
    store: &SimStore,
    base: &str,
    ctx: ReadCtx,
    accounts: &[IoAccount],
) -> Result<CsrGraph> {
    // Sequential metadata phase (§5.6 measures this as the scalability
    // bottleneck — keep it sequential on purpose, charged to account 0).
    let meta = read_meta(store, base, ctx, &accounts[0])?;
    let offsets = read_offsets(store, base, ctx, &accounts[0])?;
    let n = meta.num_vertices;

    // Parallel decode through the shared fan-out primitive: one chunk per
    // account, boundaries balanced by compressed bits, results stitched in
    // vertex order, each worker's I/O + CPU on its own virtual clock.
    let dec = Decoder::open(store, base, &meta, &offsets, ctx, &accounts[0])?;
    let block = dec.decode_range_parallel(0, n, accounts, &crate::runtime::NativeScan)?;

    // Assemble the CSR (charged to worker 0): the full-range block's local
    // offsets are exactly the graph's CSR offsets.
    accounts[0].time_cpu(|| {
        let weights = if meta.weighted {
            let name = format!("{base}.weights");
            let file = store.open(&name).with_context(|| format!("missing {name}"))?;
            let bytes = file.try_read(0, file.len(), ctx, &accounts[0])?;
            bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
        } else {
            Vec::new()
        };
        let g = CsrGraph { offsets: block.offsets, edges: block.edges, weights };
        g.validate().map_err(|e| anyhow::anyhow!("decoded graph invalid: {e}"))?;
        Ok(g)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::storage::DeviceKind;

    fn accounts(n: usize) -> Vec<IoAccount> {
        (0..n).map(|_| IoAccount::new()).collect()
    }

    fn store_with(g: &CsrGraph, base: &str) -> SimStore {
        let store = SimStore::new(DeviceKind::Dram);
        for (name, data) in serialize(g, base) {
            store.put(&name, data);
        }
        store
    }

    #[test]
    fn roundtrip_rmat() {
        let g = generators::rmat(8, 8, 1);
        let store = store_with(&g, "g");
        for t in [1usize, 2, 4, 7] {
            let loaded = load_full(&store, "g", ReadCtx::default(), &accounts(t)).unwrap();
            assert_eq!(loaded, g, "threads={t}");
        }
    }

    #[test]
    fn roundtrip_all_generators() {
        for (i, g) in [
            generators::road_lattice(20, 20, 5, 2),
            generators::barabasi_albert(600, 5, 3),
            generators::erdos_renyi(300, 2000, 4),
            generators::similarity_blocks(300, 32, 8, 5),
        ]
        .into_iter()
        .enumerate()
        {
            let base = format!("g{i}");
            let store = store_with(&g, &base);
            let loaded = load_full(&store, &base, ReadCtx::default(), &accounts(3)).unwrap();
            assert_eq!(loaded, g, "generator {i}");
        }
    }

    #[test]
    fn compresses_better_than_4_bytes_per_edge() {
        let g = generators::barabasi_albert(4000, 10, 7);
        let store = store_with(&g, "g");
        let graph_bytes = store.file_len("g.graph").unwrap();
        let bpe = graph_bytes as f64 * 8.0 / g.num_edges() as f64;
        assert!(bpe < 16.0, "WebGraph stream should be well under 16 bits/edge, got {bpe:.1}");
    }

    #[test]
    fn road_graph_compresses_extremely_well() {
        // Locality + intervals: lattice rows are consecutive runs.
        let g = generators::road_lattice(60, 60, 0, 1);
        let store = store_with(&g, "g");
        let graph_bytes = store.file_len("g.graph").unwrap();
        let bpe = graph_bytes as f64 * 8.0 / g.num_edges() as f64;
        // Real-world reference point: Table 3's RD is ~16.8 bits/edge in
        // WebGraph; a clean lattice should land well under that.
        assert!(bpe < 14.0, "lattice should compress well, got {bpe:.1} bits/edge");
    }

    #[test]
    fn meta_and_offsets_roundtrip() {
        let g = generators::rmat(7, 6, 9);
        let store = store_with(&g, "g");
        let acct = IoAccount::new();
        let meta = read_meta(&store, "g", ReadCtx::default(), &acct).unwrap();
        assert_eq!(meta.num_vertices, g.num_vertices());
        assert_eq!(meta.num_edges, g.num_edges());
        assert!(!meta.weighted);
        let offs = read_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
        assert_eq!(offs.num_vertices(), g.num_vertices());
        assert_eq!(offs.num_edges(), g.num_edges());
        assert_eq!(offs.edge_offsets_vec(0, g.num_vertices()), g.offsets);
        // Bit offsets non-decreasing; degrees match.
        for v in 0..g.num_vertices() {
            assert!(offs.bit_offset(v) <= offs.bit_offset(v + 1));
            assert_eq!(offs.degree(v), g.degree(v as u32) as usize, "vertex {v}");
        }
    }

    #[test]
    fn v1_offsets_sidecar_still_readable() {
        // Pre-EF sidecar layout: [n][m] header + the same γ-delta stream.
        // read_offsets must parse it identically to the v2 file.
        let g = generators::barabasi_albert(700, 5, 3);
        let (_, bit_offsets, _) = compress(&g, WgParams::default());
        let mut v1 = Vec::new();
        v1.extend_from_slice(&(g.num_vertices() as u64).to_le_bytes());
        v1.extend_from_slice(&g.num_edges().to_le_bytes());
        let mut w = crate::util::bitstream::BitWriter::new();
        let mut prev = 0u64;
        for &b in &bit_offsets {
            crate::util::codes::write_gamma(&mut w, b - prev);
            prev = b;
        }
        let mut prev = 0u64;
        for &e in &g.offsets {
            crate::util::codes::write_gamma(&mut w, e - prev);
            prev = e;
        }
        v1.extend_from_slice(&w.into_bytes());

        let store = store_with(&g, "g");
        store.put("g.offsets", v1); // overwrite the v2 sidecar with v1 bytes
        let acct = IoAccount::new();
        let offs = read_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
        assert_eq!(offs.edge_offsets_vec(0, g.num_vertices()), g.offsets);
        for v in 0..=g.num_vertices() {
            assert_eq!(offs.bit_offset(v), bit_offsets[v], "vertex {v}");
        }
        // And the whole-graph load still round-trips through a v1 sidecar.
        let loaded = load_full(&store, "g", ReadCtx::default(), &accounts(2)).unwrap();
        assert_eq!(loaded, g);
    }

    #[test]
    fn elias_fano_offsets_are_small_and_exact() {
        let g = generators::barabasi_albert(20_000, 8, 11);
        let store = store_with(&g, "g");
        let acct = IoAccount::new();
        let offs = read_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
        // Acceptance bar: ≤ 40% of the two plain Vec<u64> (in practice far
        // less — ~10 bits/vertex against 128).
        assert!(
            offs.size_bytes() * 100 <= offs.plain_size_bytes() * 40,
            "EF offsets footprint {} must be ≤ 40% of plain {}",
            offs.size_bytes(),
            offs.plain_size_bytes()
        );
        // Exactness: every offset and the partition points agree with the
        // plain-vector oracle.
        for v in (0..=g.num_vertices()).step_by(97) {
            assert_eq!(offs.edge_offset(v), g.offsets[v]);
        }
        for probe in [0u64, 1, 7, g.num_edges() / 2, g.num_edges()] {
            assert_eq!(
                offs.edge_partition_point(|e| e < probe),
                g.offsets.partition_point(|&e| e < probe),
                "probe {probe}"
            );
        }
    }

    #[test]
    fn write_stream_to_dir_matches_serialize() {
        let n = 400usize;
        let mut edges = Vec::new();
        let mut list = Vec::new();
        for v in 0..n {
            generators::synthetic_successors(v, n, 12, 9, &mut list);
            for &d in &list {
                edges.push((v as crate::graph::VertexId, d));
            }
        }
        let g = CsrGraph::from_edges(n, &edges);
        let dir = std::env::temp_dir().join(format!("pg_stream_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let out = write_stream_to_dir(&dir, "s", n, WgParams::default(), |v, out| {
            generators::synthetic_successors(v, n, 12, 9, out)
        })
        .unwrap();
        assert_eq!(out.num_edges, g.num_edges());
        for (name, data) in serialize_with(&g, "s", WgParams::default()) {
            let ondisk = std::fs::read(dir.join(&name)).unwrap();
            assert_eq!(ondisk, data, "{name} must be byte-identical to the batch writer");
        }
        // And the real-file (mmap) store opens and decodes it.
        let store = crate::storage::GraphStore::open_dir(&dir, DeviceKind::Ssd).unwrap();
        let loaded = load_full(&store, "s", ReadCtx::default(), &accounts(2)).unwrap();
        assert_eq!(loaded, g);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn weighted_roundtrip() {
        let g = CsrGraph::from_weighted_edges(
            6,
            &[(0, 1, 0.5), (0, 2, 1.5), (1, 2, 2.5), (5, 0, -1.0), (2, 3, 3.5)],
        );
        let store = store_with(&g, "w");
        let loaded = load_full(&store, "w", ReadCtx::default(), &accounts(2)).unwrap();
        assert_eq!(loaded, g);
    }

    #[test]
    fn custom_params_roundtrip() {
        let g = generators::barabasi_albert(500, 6, 11);
        for params in [
            WgParams { window: 0, max_ref_chain: 0, zeta_k: 2, min_interval_len: 2 },
            WgParams { window: 1, max_ref_chain: 1, zeta_k: 4, min_interval_len: 8 },
            WgParams { window: 15, max_ref_chain: 8, zeta_k: 3, min_interval_len: 3 },
        ] {
            let store = SimStore::new(DeviceKind::Dram);
            for (name, data) in serialize_with(&g, "p", params) {
                store.put(&name, data);
            }
            let loaded = load_full(&store, "p", ReadCtx::default(), &accounts(2)).unwrap();
            assert_eq!(loaded, g, "params {params:?}");
        }
    }

    #[test]
    fn truncated_offsets_rejected() {
        let g = generators::rmat(6, 4, 2);
        let store = SimStore::new(DeviceKind::Dram);
        for (name, mut data) in serialize(&g, "g") {
            if name.ends_with(".offsets") {
                data.truncate(data.len() / 2);
            }
            store.put(&name, data);
        }
        let acct = IoAccount::new();
        assert!(read_offsets(&store, "g", ReadCtx::default(), &acct).is_err());
    }
}
