//! Integrity validation (§6): per-chunk checksums of the compressed
//! stream, published alongside the graph the way MS-BioGraphs ships
//! checksum files. The loader can validate any *requested edge block's*
//! byte range without reading the whole file — the selective analogue of
//! whole-file checksumming.

use anyhow::{bail, Context, Result};

use crate::storage::sim::ReadCtx;
use crate::storage::{IoAccount, SimStore};

/// Checksum chunk granularity (bytes of the `.graph` stream).
pub const CHUNK: u64 = 64 << 10;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// FNV-1a 64-bit — cheap, order-sensitive, adequate for storage-integrity
/// (not adversarial) checking.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Streaming builder of the `{base}.checksums` sidecar: feed the `.graph`
/// stream in arbitrary flush-sized pieces and get a sidecar byte-identical
/// to [`build_checksums`] over the concatenation. This is what lets
/// `write_stream_to_dir` emit checksums without buffering the stream.
#[derive(Debug)]
pub struct ChecksumBuilder {
    h: u64,
    filled: u64,
    sums: Vec<u64>,
}

impl ChecksumBuilder {
    pub fn new() -> ChecksumBuilder {
        ChecksumBuilder { h: FNV_OFFSET, filled: 0, sums: Vec::new() }
    }

    pub fn update(&mut self, mut bytes: &[u8]) {
        while !bytes.is_empty() {
            let take = ((CHUNK - self.filled) as usize).min(bytes.len());
            for &b in &bytes[..take] {
                self.h ^= b as u64;
                self.h = self.h.wrapping_mul(FNV_PRIME);
            }
            self.filled += take as u64;
            if self.filled == CHUNK {
                self.sums.push(self.h);
                self.h = FNV_OFFSET;
                self.filled = 0;
            }
            bytes = &bytes[take..];
        }
    }

    pub fn finish(mut self) -> Vec<u8> {
        if self.filled > 0 {
            self.sums.push(self.h);
        }
        let mut out = Vec::with_capacity(16 + self.sums.len() * 8);
        out.extend_from_slice(&CHUNK.to_le_bytes());
        out.extend_from_slice(&(self.sums.len() as u64).to_le_bytes());
        for s in &self.sums {
            out.extend_from_slice(&s.to_le_bytes());
        }
        out
    }
}

impl Default for ChecksumBuilder {
    fn default() -> Self {
        ChecksumBuilder::new()
    }
}

/// Typed outcome of a checksum classification — what the coordinator's
/// self-healing path branches on (DESIGN.md § Fault injection).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Verdict {
    /// Every chunk overlapping the range matches its recorded checksum:
    /// whatever failed was *transient* — the data at rest is good.
    Ok,
    /// A chunk disagrees with the sidecar: the data at rest is corrupt;
    /// retrying cannot help.
    Mismatch { chunk: u64 },
    /// No verdict possible (sidecar missing/malformed, range beyond the
    /// checksummed region). Callers treat this as transient — absence of
    /// a sidecar must never *create* a corruption error.
    Unverifiable(String),
}

/// Classify the byte range `[start, end)` of `{base}.graph` against the
/// checksums sidecar. Deliberately reads through the *infallible* store
/// paths: classification is an independent verification channel and must
/// return stable verdicts even while the fault plan is hammering
/// `try_read` (DESIGN.md § Fault injection).
pub fn classify_range(
    store: &SimStore,
    base: &str,
    start: u64,
    end: u64,
    ctx: ReadCtx,
    acct: &IoAccount,
) -> Verdict {
    let sums_name = format!("{base}.checksums");
    let Some(sums_file) = store.open(&sums_name) else {
        return Verdict::Unverifiable(format!("missing {sums_name}"));
    };
    let sums = sums_file.read(0, sums_file.len(), ctx, acct);
    if sums.len() < 16 {
        return Verdict::Unverifiable(format!("{sums_name}: truncated header"));
    }
    let chunk = u64::from_le_bytes(sums[0..8].try_into().unwrap());
    let count = u64::from_le_bytes(sums[8..16].try_into().unwrap());
    if chunk == 0 || sums.len() as u64 != 16 + count * 8 {
        return Verdict::Unverifiable(format!("{sums_name}: malformed"));
    }
    let graph_name = format!("{base}.graph");
    let Some(graph) = store.open(&graph_name) else {
        return Verdict::Unverifiable(format!("missing {graph_name}"));
    };
    let end = end.min(graph.len());
    if start >= end {
        return Verdict::Ok;
    }
    let first = start / chunk;
    let last = (end - 1) / chunk;
    if last >= count {
        return Verdict::Unverifiable(format!("{graph_name}: range beyond checksummed region"));
    }
    for c in first..=last {
        let off = c * chunk;
        let len = chunk.min(graph.len() - off);
        let bytes = graph.read(off, len, ctx, acct);
        let expect =
            u64::from_le_bytes(sums[16 + c as usize * 8..24 + c as usize * 8].try_into().unwrap());
        if fnv1a64(&bytes) != expect {
            return Verdict::Mismatch { chunk: c };
        }
    }
    Verdict::Ok
}

/// Build the `{base}.checksums` sidecar for a serialized `.graph` stream:
/// header (chunk size, count) + one u64 per chunk.
pub fn build_checksums(stream: &[u8]) -> Vec<u8> {
    let chunks = stream.chunks(CHUNK as usize);
    let count = chunks.len() as u64;
    let mut out = Vec::with_capacity(16 + count as usize * 8);
    out.extend_from_slice(&CHUNK.to_le_bytes());
    out.extend_from_slice(&count.to_le_bytes());
    for c in stream.chunks(CHUNK as usize) {
        out.extend_from_slice(&fnv1a64(c).to_le_bytes());
    }
    out
}

/// Verify the byte range `[start, end)` of `{base}.graph` against the
/// checksums sidecar (whole chunks overlapping the range are checked).
/// Reads only those chunks — O(range), not O(file).
pub fn verify_range(
    store: &SimStore,
    base: &str,
    start: u64,
    end: u64,
    ctx: ReadCtx,
    acct: &IoAccount,
) -> Result<()> {
    match classify_range(store, base, start, end, ctx, acct) {
        Verdict::Ok => Ok(()),
        Verdict::Mismatch { chunk } => {
            bail!("{base}.graph: checksum mismatch in chunk {chunk} (corrupt block)")
        }
        Verdict::Unverifiable(why) => bail!(why),
    }
}

/// Verify the entire `.graph` stream.
pub fn verify_all(store: &SimStore, base: &str, ctx: ReadCtx, acct: &IoAccount) -> Result<()> {
    let graph_name = format!("{base}.graph");
    let len =
        store.file_len(&graph_name).with_context(|| format!("missing {graph_name}"))?;
    verify_range(store, base, 0, len, ctx, acct)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::webgraph::serialize;
    use crate::graph::generators;
    use crate::storage::DeviceKind;

    fn setup(corrupt_at: Option<usize>) -> SimStore {
        let g = generators::barabasi_albert(6000, 9, 3);
        let store = SimStore::new(DeviceKind::Dram);
        let files = serialize(&g, "g");
        let stream = files.iter().find(|(n, _)| n.ends_with(".graph")).unwrap().1.clone();
        store.put("g.checksums", build_checksums(&stream));
        for (name, mut data) in files {
            if name.ends_with(".graph") {
                if let Some(at) = corrupt_at {
                    data[at] ^= 0x40;
                }
            }
            store.put(&name, data);
        }
        store
    }

    #[test]
    fn clean_file_verifies() {
        let store = setup(None);
        let acct = IoAccount::new();
        verify_all(&store, "g", ReadCtx::default(), &acct).unwrap();
        verify_range(&store, "g", 100, 200, ReadCtx::default(), &acct).unwrap();
    }

    #[test]
    fn corruption_detected_only_in_affected_chunk() {
        let len = {
            let s = setup(None);
            s.file_len("g.graph").unwrap()
        };
        assert!(len > CHUNK, "test graph must span multiple chunks, len {len}");
        // Corrupt a byte in the second chunk.
        let store = setup(Some(CHUNK as usize + 10));
        let acct = IoAccount::new();
        assert!(verify_all(&store, "g", ReadCtx::default(), &acct).is_err());
        // First chunk alone still verifies (selective validation).
        verify_range(&store, "g", 0, CHUNK - 1, ReadCtx::default(), &acct).unwrap();
        assert!(
            verify_range(&store, "g", CHUNK, CHUNK + 100, ReadCtx::default(), &acct).is_err()
        );
    }

    #[test]
    fn fnv_known_values() {
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_ne!(fnv1a64(b"a"), fnv1a64(b"b"));
        assert_ne!(fnv1a64(b"ab"), fnv1a64(b"ba"), "order-sensitive");
    }

    #[test]
    fn empty_range_is_ok() {
        let store = setup(None);
        let acct = IoAccount::new();
        verify_range(&store, "g", 50, 50, ReadCtx::default(), &acct).unwrap();
    }

    #[test]
    fn streaming_builder_matches_batch_checksums() {
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(77);
        for len in [0usize, 1, 100, CHUNK as usize - 1, CHUNK as usize, CHUNK as usize + 1, 300_000]
        {
            let data: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let mut b = ChecksumBuilder::new();
            // Feed in ragged pieces to cross chunk boundaries mid-update.
            let mut rest = &data[..];
            while !rest.is_empty() {
                let take = (1 + rng.next_below(40_000) as usize).min(rest.len());
                b.update(&rest[..take]);
                rest = &rest[take..];
            }
            assert_eq!(b.finish(), build_checksums(&data), "len {len}");
        }
    }

    #[test]
    fn classify_range_verdicts() {
        let acct = IoAccount::new();
        let ctx = ReadCtx::default();
        let clean = setup(None);
        let len = clean.file_len("g.graph").unwrap();
        assert_eq!(classify_range(&clean, "g", 0, len, ctx, &acct), Verdict::Ok);
        let corrupt = setup(Some(CHUNK as usize + 10));
        assert_eq!(
            classify_range(&corrupt, "g", CHUNK, CHUNK + 100, ctx, &acct),
            Verdict::Mismatch { chunk: 1 }
        );
        assert_eq!(classify_range(&corrupt, "g", 0, 100, ctx, &acct), Verdict::Ok);
        // No sidecar ⇒ Unverifiable, never Mismatch.
        corrupt.remove("g.checksums");
        assert!(matches!(
            classify_range(&corrupt, "g", 0, 100, ctx, &acct),
            Verdict::Unverifiable(_)
        ));
    }
}
