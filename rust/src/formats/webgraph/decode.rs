//! WebGraph-style decoder with random access.
//!
//! [`Decoder::decode_range`] decodes any consecutive vertex range without
//! decoding the prefix of the stream: the offsets sidecar gives the bit
//! position of every vertex, and reference chains (bounded at compression
//! time) are resolved by recursively decoding the referenced vertex — a
//! *selective* read of a few extra bytes, not a scan. This is the primitive
//! the ParaGrapher coordinator builds every use case (A–D, §4.1) on.
//!
//! Decoding is two-phase:
//!
//! 1. **Bit parse** (inherently sequential): instantaneous codes →
//!    [`AdjParts`] (copy blocks, intervals, residual *gaps*). The parse
//!    runs through the word-at-a-time [`BitReader`] and the table-driven
//!    [`CodeReader`]s (11-bit peek, slow-path fallback) — this phase bounds
//!    the paper's decompression bandwidth `d`, and the
//!    `paragrapher calibrate-decode` subcommand measures what it achieves.
//! 2. **Fused gap scan + validate + merge** (vectorizable): residual gaps →
//!    absolute IDs via an inclusive scan *fused* with the bounds validation
//!    and `u32` narrowing
//!    ([`ScanEngine::scan_validate_u32`](crate::runtime::ScanEngine::scan_validate_u32)
//!    — one batched pass over the block-level gap array instead of a scan
//!    plus a separate per-vertex validation walk), then a 3-way sorted
//!    merge. Engines with offloaded scans (the AOT-compiled Pallas kernel
//!    via PJRT) fall back to scan-then-validate through the trait default.
//!
//! All per-vertex state lives in a reusable [`DecodeScratch`]: parsed
//! [`AdjParts`] (inner vectors keep their capacity), the concatenated gap
//! array with its narrowed absolutes, and — instead of the former
//! `Vec<Vec<VertexId>>` copy-list ring — a flat ring of
//! `(vertex, start, end)` spans into the output edge vector (a decoded
//! vertex's final list is already contiguous in the output, so in-window
//! references need no copy at all). Steady-state block decode through a
//! warmed scratch performs zero heap allocation in the per-vertex loop.
//! Public entry points without an explicit scratch borrow a thread-local
//! one, so the coordinator's pool workers reuse their scratch across blocks
//! for free.
//!
//! **Zero-copy delivery:** every range decode bottoms out in a
//! [`DecodeSink`] — two caller-owned vectors the decode appends offsets and
//! edges into directly. [`Decoder::decode_range`] and friends pass the
//! fields of a fresh [`DecodedBlock`]; the coordinator passes its claimed
//! buffer's storage ([`decode_range_sink`](Decoder::decode_range_sink)), so
//! block delivery materializes no intermediate block and performs no
//! post-decode memcpy. The compressed stream bytes are likewise *borrowed*
//! from the store's page-cache image on the default zero-copy reader
//! (copied only under the managed `BufferedCopy` reader model).

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::{WgMeta, WgOffsets};
use crate::graph::VertexId;
use crate::runtime::ScanEngine;
use crate::storage::sim::{ReadCtx, SimFile};
use crate::storage::{IoAccount, SimStore};
use crate::util::bitstream::BitReader;
use crate::util::codes::{nat_to_int, Code, CodeReader};
use crate::util::pool::parallel_map;

/// A decoded consecutive block of vertices: a little CSR slice.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DecodedBlock {
    /// First vertex id in the block.
    pub first_vertex: usize,
    /// Local offsets, `num_vertices()+1` entries, starting at 0.
    pub offsets: Vec<u64>,
    /// Concatenated successor lists.
    pub edges: Vec<VertexId>,
}

impl DecodedBlock {
    pub fn num_vertices(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    pub fn num_edges(&self) -> u64 {
        *self.offsets.last().unwrap_or(&0)
    }

    /// Edge span (indices into `edges`) of local vertex `i`.
    pub fn vertex_span(&self, i: usize) -> (usize, usize) {
        (self.offsets[i] as usize, self.offsets[i + 1] as usize)
    }

    /// Successors of local vertex `i`.
    pub fn neighbors(&self, i: usize) -> &[VertexId] {
        let (s, e) = self.vertex_span(i);
        &self.edges[s..e]
    }
}

/// Cap (in edges) on any up-front allocation derived from sidecar
/// metadata, which is unvalidated against the stream at reserve time: a
/// forged self-consistent sidecar must not translate into an unbounded
/// allocation (fuzz-suite contract) — beyond the cap, ordinary doubling
/// growth takes over. One constant shared by the decoder's edge reserve,
/// the coordinator's buffer pre-reserve and the blocking-load assembly, so
/// the "pre-reserve makes the decode's reserve a no-op" zero-copy property
/// cannot silently diverge between the sites.
pub const MAX_SIDECAR_RESERVE_EDGES: usize = 1 << 22;

/// Caller-owned output storage a range decode writes into *directly* — the
/// zero-copy delivery primitive. The coordinator passes its claimed
/// buffer's `BufferData` vectors (pre-reserved off the Elias–Fano sidecar);
/// the owned-block entry points pass the fields of a fresh
/// [`DecodedBlock`]. Either way the decode is the same code path: no
/// intermediate block, no post-decode memcpy.
///
/// Contract: the decode **clears** both vectors, then writes `count + 1`
/// local offsets (starting at 0) and the concatenated successor lists.
/// Existing capacity is reused — a warmed buffer cycling through the
/// coordinator pool serves block after block allocation-free. On error the
/// vectors hold partial output and must not be interpreted.
pub struct DecodeSink<'a> {
    offsets: &'a mut Vec<u64>,
    edges: &'a mut Vec<VertexId>,
}

impl<'a> DecodeSink<'a> {
    pub fn new(offsets: &'a mut Vec<u64>, edges: &'a mut Vec<VertexId>) -> Self {
        Self { offsets, edges }
    }
}

/// Edge-output abstraction of the range-decode core: either a growable
/// vector (sequential sink decode, owned blocks) or a fixed pre-partitioned
/// window a fan-out chunk worker fills *in place*
/// ([`Decoder::decode_range_parallel_sink`]). Positions are relative to the
/// start of this store's output, which is what the decode ring and the
/// emitted local offsets speak anyway.
trait EdgeStore {
    /// Edges written so far (== the next write position).
    fn pos(&self) -> usize;
    fn push_edge(&mut self, v: usize, id: VertexId) -> Result<()>;
    fn extend_edges(&mut self, v: usize, ids: &[VertexId]) -> Result<()>;
    /// Re-borrow an already-written span (in-window reference resolution).
    fn span(&self, start: usize, end: usize) -> &[VertexId];
}

impl EdgeStore for Vec<VertexId> {
    fn pos(&self) -> usize {
        self.len()
    }

    fn push_edge(&mut self, _v: usize, id: VertexId) -> Result<()> {
        self.push(id);
        Ok(())
    }

    fn extend_edges(&mut self, _v: usize, ids: &[VertexId]) -> Result<()> {
        self.extend_from_slice(ids);
        Ok(())
    }

    fn span(&self, start: usize, end: usize) -> &[VertexId] {
        &self[start..end]
    }
}

/// A chunk worker's disjoint window of the pre-sized sink edge vector. The
/// window's length is the chunk's sidecar-declared edge span; a stream that
/// decodes past it can only be corrupt (or the sidecar forged), so
/// overflowing writes bail instead of growing.
struct FixedEdges<'b> {
    buf: &'b mut [VertexId],
    cursor: usize,
}

impl EdgeStore for FixedEdges<'_> {
    fn pos(&self) -> usize {
        self.cursor
    }

    fn push_edge(&mut self, v: usize, id: VertexId) -> Result<()> {
        if self.cursor >= self.buf.len() {
            bail!("decoded edges exceed the sidecar's edge span at vertex {v} (corrupt sidecar?)");
        }
        self.buf[self.cursor] = id;
        self.cursor += 1;
        Ok(())
    }

    fn extend_edges(&mut self, v: usize, ids: &[VertexId]) -> Result<()> {
        let end = self.cursor + ids.len();
        if end > self.buf.len() {
            bail!("decoded edges exceed the sidecar's edge span at vertex {v} (corrupt sidecar?)");
        }
        self.buf[self.cursor..end].copy_from_slice(ids);
        self.cursor = end;
        Ok(())
    }

    fn span(&self, start: usize, end: usize) -> &[VertexId] {
        &self.buf[start..end]
    }
}

/// Parsed (phase-1) adjacency of one vertex: everything except the residual
/// absolute values.
#[derive(Debug, Clone, Default)]
struct AdjParts {
    degree: usize,
    /// Reference distance (0 = none).
    reference: usize,
    /// Explicit copy/skip run lengths (first run is a copy run).
    blocks: Vec<u64>,
    /// Materialized interval successors (sorted).
    intervals: Vec<VertexId>,
    /// Residual gaps: `gaps[0]` is the *absolute* first residual;
    /// `gaps[i>0]` is `res_i - res_{i-1}` (so an inclusive scan over the
    /// whole vector yields the absolute residuals).
    gaps: Vec<i64>,
}

impl AdjParts {
    /// Reset for reuse, keeping the inner vectors' capacity.
    fn clear(&mut self) {
        self.degree = 0;
        self.reference = 0;
        self.blocks.clear();
        self.intervals.clear();
        self.gaps.clear();
    }
}

/// Reusable per-worker decode state. One scratch per thread (or one per
/// explicit caller) makes the steady-state per-vertex decode loop
/// allocation-free: every vector below retains its high-water capacity
/// across blocks.
pub struct DecodeScratch {
    /// Parsed adjacency records of the block (index = local vertex).
    parts: Vec<AdjParts>,
    /// Concatenated residual gaps of the whole block (one scan call).
    gap_array: Vec<i64>,
    /// Per-vertex `(start, end)` spans into `gap_array`.
    seg_bounds: Vec<(usize, usize)>,
    /// Copy-list ring: slot -> `(vertex, start, end)` span of that vertex's
    /// final list inside the output edge vector. Replaces the former
    /// `Vec<Vec<VertexId>>` — in-window references read the decoded output
    /// in place instead of keeping per-slot copies.
    ring: Vec<(usize, usize, usize)>,
    /// Expanded copy-list of the current vertex.
    copied: Vec<VertexId>,
    /// Narrowed absolute residuals of the whole block, produced by the
    /// fused scan+validate pass in one shot; per-vertex slices are indexed
    /// by `seg_bounds` (replaces the former per-vertex validated copy).
    abs_ids: Vec<VertexId>,
    /// Raw residual code values (batched run read).
    raw: Vec<u64>,
    /// Out-of-block reference lists (block-head references only).
    out_cache: HashMap<usize, Vec<VertexId>>,
    /// Table-driven γ reader (degrees, references, blocks, intervals).
    gamma: CodeReader,
    /// Table-driven residual reader (ζ_k by default), re-selected per
    /// stream via [`Self::set_residual_code`].
    residual: CodeReader,
}

impl Default for DecodeScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl DecodeScratch {
    pub fn new() -> Self {
        Self {
            parts: Vec::new(),
            gap_array: Vec::new(),
            seg_bounds: Vec::new(),
            ring: Vec::new(),
            copied: Vec::new(),
            abs_ids: Vec::new(),
            raw: Vec::new(),
            out_cache: HashMap::new(),
            gamma: CodeReader::new(Code::Gamma),
            residual: CodeReader::new(Code::Zeta(3)),
        }
    }

    /// Select the residual code once per stream (a no-op when unchanged —
    /// the common case of one scratch serving one graph). Accumulated
    /// hit/miss counters survive the switch: they describe the scratch's
    /// lifetime, not one stream.
    fn set_residual_code(&mut self, code: Code) {
        if self.residual.code() != code {
            let mut next = CodeReader::new(code);
            next.table_hits = self.residual.table_hits;
            next.table_misses = self.residual.table_misses;
            self.residual = next;
        }
    }

    /// Decode-table counters accumulated by this scratch: `(hits, misses)`.
    pub fn table_counters(&self) -> (u64, u64) {
        (
            self.gamma.table_hits + self.residual.table_hits,
            self.gamma.table_misses + self.residual.table_misses,
        )
    }

    /// Fraction of symbols decoded through the table fast path.
    pub fn table_hit_rate(&self) -> f64 {
        let (h, m) = self.table_counters();
        crate::util::codes::hit_rate(h, m)
    }
}

thread_local! {
    /// Per-thread scratch backing the scratch-less public entry points —
    /// coordinator pool workers decode block after block through the same
    /// warmed buffers.
    static THREAD_SCRATCH: RefCell<DecodeScratch> = RefCell::new(DecodeScratch::new());
}

/// Random-access decoder over one compressed graph.
pub struct Decoder<'a> {
    file: SimFile<'a>,
    meta: &'a WgMeta,
    offsets: &'a WgOffsets,
    ctx: ReadCtx,
}

impl<'a> Decoder<'a> {
    pub fn open(
        store: &'a SimStore,
        base: &str,
        meta: &'a WgMeta,
        offsets: &'a WgOffsets,
        ctx: ReadCtx,
        _acct: &IoAccount,
    ) -> Result<Self> {
        offsets.check_matches(meta)?;
        let name = format!("{base}.graph");
        let file = store.open(&name).with_context(|| format!("missing {name}"))?;
        Ok(Self { file, meta, offsets, ctx })
    }

    /// Decode vertices `[v_start, v_end)` with the native scan.
    pub fn decode_range(
        &self,
        v_start: usize,
        v_end: usize,
        acct: &IoAccount,
    ) -> Result<DecodedBlock> {
        self.decode_range_with_scan(v_start, v_end, acct, &crate::runtime::NativeScan)
    }

    /// Decode vertices `[v_start, v_end)`, running the gap→ID phase of all
    /// residuals of the block through `scan` in one batched call. Borrows
    /// the calling thread's [`DecodeScratch`].
    pub fn decode_range_with_scan(
        &self,
        v_start: usize,
        v_end: usize,
        acct: &IoAccount,
        scan: &dyn ScanEngine,
    ) -> Result<DecodedBlock> {
        THREAD_SCRATCH.with(|s| {
            self.decode_range_scratch(v_start, v_end, acct, scan, &mut s.borrow_mut())
        })
    }

    /// [`Self::decode_range_with_scan`] through an explicit caller-owned
    /// scratch (callers that thread their own scratch also get at its
    /// decode-table counters, e.g. `calibrate-decode`).
    pub fn decode_range_scratch(
        &self,
        v_start: usize,
        v_end: usize,
        acct: &IoAccount,
        scan: &dyn ScanEngine,
        scratch: &mut DecodeScratch,
    ) -> Result<DecodedBlock> {
        let mut block = DecodedBlock {
            first_vertex: v_start,
            offsets: Vec::new(),
            edges: Vec::new(),
        };
        let mut sink = DecodeSink::new(&mut block.offsets, &mut block.edges);
        self.decode_range_sink_scratch(v_start, v_end, acct, scan, scratch, &mut sink)?;
        Ok(block)
    }

    /// Decode vertices `[v_start, v_end)` straight into caller-owned
    /// storage (zero-copy delivery) through the calling thread's
    /// [`DecodeScratch`]. The coordinator's block pipeline passes the
    /// claimed buffer's vectors here, so delivery performs no intermediate
    /// `DecodedBlock` allocation and no post-decode memcpy.
    pub fn decode_range_sink(
        &self,
        v_start: usize,
        v_end: usize,
        acct: &IoAccount,
        scan: &dyn ScanEngine,
        sink: &mut DecodeSink<'_>,
    ) -> Result<()> {
        THREAD_SCRATCH.with(|s| {
            self.decode_range_sink_scratch(v_start, v_end, acct, scan, &mut s.borrow_mut(), sink)
        })
    }

    /// [`Self::decode_range_sink`] through an explicit caller-owned scratch
    /// — the primitive every range decode bottoms out in.
    pub fn decode_range_sink_scratch(
        &self,
        v_start: usize,
        v_end: usize,
        acct: &IoAccount,
        scan: &dyn ScanEngine,
        scratch: &mut DecodeScratch,
        sink: &mut DecodeSink<'_>,
    ) -> Result<()> {
        let n = self.meta.num_vertices;
        if v_start > v_end || v_end > n {
            bail!("bad vertex range {v_start}..{v_end} (n={n})");
        }
        let count = v_end - v_start;
        let out_offsets: &mut Vec<u64> = &mut *sink.offsets;
        let out_edges: &mut Vec<VertexId> = &mut *sink.edges;
        out_offsets.clear();
        out_edges.clear();
        out_offsets.reserve(count + 1);
        out_offsets.push(0);
        if count == 0 {
            return Ok(());
        }
        // The sidecar knows the block's exact edge total: reserve once,
        // capped by the shared forged-sidecar guard. (A sink whose caller
        // pre-reserved off the same sidecar makes this a no-op.)
        let total_edges =
            (self.offsets.edge_offset(v_end) - self.offsets.edge_offset(v_start)) as usize;
        out_edges.reserve(total_edges.min(MAX_SIDECAR_RESERVE_EDGES));
        self.decode_range_core(v_start, v_end, acct, scan, scratch, out_edges, &mut |pos| {
            out_offsets.push(pos)
        })
    }

    /// Phases 1–3 of a range decode into an [`EdgeStore`], emitting one
    /// cumulative store-relative edge count per vertex through
    /// `emit_offset`. The output-shape bookkeeping (clearing, reserving or
    /// pre-sizing, the leading 0 offset) belongs to the callers; here
    /// `v_start < v_end` always holds.
    fn decode_range_core<E: EdgeStore>(
        &self,
        v_start: usize,
        v_end: usize,
        acct: &IoAccount,
        scan: &dyn ScanEngine,
        scratch: &mut DecodeScratch,
        out_edges: &mut E,
        emit_offset: &mut dyn FnMut(u64),
    ) -> Result<()> {
        let n = self.meta.num_vertices;
        let count = v_end - v_start;

        // One ranged read covering the whole block's bits. On the default
        // zero-copy reader the bytes are *borrowed* from the store's
        // page-cache image — no per-block staging copy; the managed
        // `BufferedCopy` reader keeps its modeled staging pipeline (the
        // Fig. 10 contrast).
        let bit0 = self.offsets.bit_offset(v_start);
        let bit1 = self.offsets.bit_offset(v_end);
        let byte0 = bit0 / 8;
        let byte1 = (bit1 + 7) / 8;
        let bytes = self.file.try_read_borrowed(byte0, byte1 - byte0, self.ctx, acct)?;

        // Phase 1: bit-parse every vertex; stitch residual gaps into one
        // array (adjusting each segment head so a single inclusive scan
        // yields absolute IDs for the whole block). Records are
        // back-to-back, so one streaming reader serves the whole block; the
        // sidecar stays authoritative — on any position drift (corrupt
        // stream or sidecar) the reader re-seeks to the recorded offset,
        // preserving the historical per-vertex random-access behavior.
        if scratch.parts.len() < count {
            scratch.parts.resize_with(count, AdjParts::default);
        }
        scratch.set_residual_code(self.meta.params.residual_code());
        scratch.gap_array.clear();
        scratch.seg_bounds.clear();
        scratch.seg_bounds.reserve(count);
        {
            let DecodeScratch { parts, gap_array, seg_bounds, raw, gamma, residual, .. } =
                scratch;
            let mut reader = BitReader::at_bit(&bytes, bit0 - byte0 * 8)
                .map_err(|e| anyhow::anyhow!("bit seek: {e}"))?;
            let mut prev_last_abs: i64 = 0;
            for (i, v) in (v_start..v_end).enumerate() {
                let want = self.offsets.bit_offset(v) - byte0 * 8;
                if reader.bit_pos() != want {
                    reader = BitReader::at_bit(&bytes, want)
                        .map_err(|e| anyhow::anyhow!("bit seek: {e}"))?;
                }
                let p = &mut parts[i];
                self.read_parts_into(v, &mut reader, p, gamma, residual, raw)?;
                let seg_start = gap_array.len();
                if !p.gaps.is_empty() {
                    let first_abs = p.gaps[0];
                    let rest_sum: i64 = p.gaps[1..].iter().sum();
                    gap_array.push(first_abs - prev_last_abs);
                    gap_array.extend_from_slice(&p.gaps[1..]);
                    prev_last_abs = first_abs + rest_sum;
                }
                seg_bounds.push((seg_start, gap_array.len()));
            }
        }

        // Phase 2: one *fused* scan + validate + narrow call for the block
        // (native unrolled pass, or scan-then-validate on offload engines).
        // In-segment gaps are ≥ 1 by parse-time validation, so the range
        // check subsumes the old strict-monotonicity walk; mapping a
        // violation back to its vertex is the cold path.
        if let Some(bad) =
            scan.scan_validate_u32(&mut scratch.gap_array, n as u64, &mut scratch.abs_ids)?
        {
            let vi = scratch.seg_bounds.partition_point(|&(_, e)| e <= bad.index);
            bail!("residual {} out of range at vertex {}", bad.value, v_start + vi);
        }

        // Phase 3: resolve references and merge.
        //
        // Hot path: decoding is sequential, and a reference always points at
        // most `window` vertices back, so a fixed ring of the last
        // `window + 1` *output spans* answers every in-block reference by
        // slicing the output edges in place — no hashing, no per-vertex
        // allocation, and (since the flat-span rewrite) no list copying
        // either: the former `Vec<Vec<VertexId>>` ring duplicated every
        // decoded list once (EXPERIMENTS §Perf).
        let win = self.meta.params.window as usize + 1;
        scratch.ring.clear();
        scratch.ring.resize(win, (usize::MAX, 0, 0));
        scratch.out_cache.clear();
        for (i, v) in (v_start..v_end).enumerate() {
            let parts = &scratch.parts[i];
            scratch.copied.clear();
            if parts.reference > 0 {
                let target = v - parts.reference;
                if target >= v_start {
                    let (rv, s, e) = scratch.ring[target % win];
                    if rv != target {
                        bail!("reference window underflow at vertex {v} (corrupt stream?)");
                    }
                    let ref_list = out_edges.span(s, e);
                    apply_blocks_into(v, &parts.blocks, ref_list, &mut scratch.copied)?;
                } else if let Some(list) = scratch.out_cache.get(&target) {
                    apply_blocks_into(v, &parts.blocks, list, &mut scratch.copied)?;
                } else {
                    // Out-of-block reference: random-access decode (rare —
                    // only near the block head).
                    let mut c = HashMap::new();
                    let list = self.decode_one(target, &mut c, acct, 1)?;
                    apply_blocks_into(v, &parts.blocks, &list, &mut scratch.copied)?;
                    scratch.out_cache.insert(target, list);
                }
            }
            let (s, e) = scratch.seg_bounds[i];
            merge3_into(
                v,
                parts.degree,
                &scratch.copied,
                &parts.intervals,
                &scratch.abs_ids[s..e],
                out_edges,
            )?;
            emit_offset(out_edges.pos() as u64);
            // Park the final list's span in the ring for upcoming references.
            let start = out_edges.pos() - parts.degree;
            scratch.ring[v % win] = (v, start, out_edges.pos());
        }
        Ok(())
    }

    /// Decode vertices `[v_start, v_end)` in parallel: the range is split
    /// into one chunk per entry of `accounts`, with boundaries balanced by
    /// *compressed bits* (decode work tracks stream size, not vertex
    /// count), fanned out over scoped pool workers, and stitched back in
    /// vertex order. This is the paper's headline mechanism — selective
    /// loading is only *parallel* if independent WebGraph blocks decode
    /// concurrently.
    ///
    /// Each chunk decodes independently: in-chunk references resolve
    /// through the chunk's own decode ring, and references that cross the
    /// chunk head fall back to the bounded random-access recursion — no
    /// cross-chunk synchronization. Worker `t` charges all of its I/O and
    /// CPU to `accounts[t]`, so the §3 overlap model still composes: the
    /// modeled elapsed time of the call is the max over the accounts.
    pub fn decode_range_parallel(
        &self,
        v_start: usize,
        v_end: usize,
        accounts: &[IoAccount],
        scan: &dyn ScanEngine,
    ) -> Result<DecodedBlock> {
        self.decode_range_parallel_on(v_start, v_end, accounts, scan, None)
    }

    /// [`Self::decode_range_parallel`] with the fan-out executed on an
    /// existing [`ThreadPool`](crate::util::pool::ThreadPool) via borrowed
    /// scoped jobs instead of spawning one scoped OS thread per chunk. The
    /// caller always participates (`scoped_for`), so this is safe to call
    /// *from* a pool worker — which is exactly what the coordinator's
    /// per-block decode does when `decode_workers > 1`. Every worker
    /// decodes through its own thread-local [`DecodeScratch`], so repeated
    /// block decodes on a pool run allocation-free once warmed.
    pub fn decode_range_parallel_on(
        &self,
        v_start: usize,
        v_end: usize,
        accounts: &[IoAccount],
        scan: &dyn ScanEngine,
        pool: Option<&crate::util::pool::ThreadPool>,
    ) -> Result<DecodedBlock> {
        let mut block = DecodedBlock {
            first_vertex: v_start,
            offsets: Vec::new(),
            edges: Vec::new(),
        };
        let mut sink = DecodeSink::new(&mut block.offsets, &mut block.edges);
        self.decode_range_parallel_sink(v_start, v_end, accounts, scan, pool, &mut sink)?;
        Ok(block)
    }

    /// [`Self::decode_range_parallel_on`] into caller-owned storage.
    /// Returns the number of bytes *copied* into the sink after decode.
    /// Both fan-out shapes are zero-copy now: a single worker decodes
    /// straight into the sink, and the multi-worker path pre-sizes the sink
    /// off the Elias–Fano sidecar (which knows every chunk's exact edge
    /// span) and has each chunk worker decode *in place* into its disjoint
    /// slice of the output — the former vertex-order stitch copy is gone,
    /// so the return is 0 on both paths. The one exception: a range whose
    /// sidecar-declared edge total exceeds [`MAX_SIDECAR_RESERVE_EDGES`]
    /// (the shared forged-sidecar allocation guard) cannot be pre-sized
    /// from unvalidated metadata, so it falls back to owned per-chunk
    /// blocks plus a counted stitch. Coordinator blocks are bounded well
    /// under the guard, so delivery stays zero-copy end to end.
    pub fn decode_range_parallel_sink(
        &self,
        v_start: usize,
        v_end: usize,
        accounts: &[IoAccount],
        scan: &dyn ScanEngine,
        pool: Option<&crate::util::pool::ThreadPool>,
        sink: &mut DecodeSink<'_>,
    ) -> Result<u64> {
        let Some(first) = accounts.first() else {
            bail!("decode_range_parallel needs at least one account");
        };
        let workers = accounts.len();
        if v_start > v_end || v_end > self.meta.num_vertices {
            bail!("bad vertex range {v_start}..{v_end} (n={})", self.meta.num_vertices);
        }
        if workers == 1 || v_end - v_start < workers * 2 {
            first.time_cpu(|| self.decode_range_sink(v_start, v_end, first, scan, sink))?;
            return Ok(0);
        }
        let e0 = self.offsets.edge_offset(v_start);
        let total_edges = (self.offsets.edge_offset(v_end) - e0) as usize;
        if total_edges > MAX_SIDECAR_RESERVE_EDGES {
            return self.decode_range_parallel_stitched(v_start, v_end, accounts, scan, pool, sink);
        }
        let count = v_end - v_start;
        let bounds = self.chunk_bounds(v_start, v_end, workers);
        // Pre-size the sink off the sidecar. The zeroing is real CPU work
        // charged to worker 0's clock — it *replaces* the former stitch
        // charge, so the modeled load time keeps covering output assembly.
        first.time_cpu(|| {
            sink.offsets.clear();
            sink.edges.clear();
            sink.offsets.resize(count + 1, 0);
            sink.edges.resize(total_edges, 0);
        });
        // Carve the output into disjoint per-chunk windows, handed to the
        // workers through take-once slots (the pool's shared-closure
        // fan-out indexes a common `Fn`, so `&mut` slices cannot be moved
        // into per-worker closures directly). `offsets[0]` stays 0.
        struct ChunkTask<'x> {
            offsets: &'x mut [u64],
            edges: &'x mut [VertexId],
            /// Edges preceding this chunk within the range (offset rebase).
            e_base: u64,
        }
        let mut tasks: Vec<Mutex<Option<ChunkTask<'_>>>> = Vec::with_capacity(workers);
        let mut rem_off: &mut [u64] = &mut sink.offsets[1..];
        let mut rem_edges: &mut [VertexId] = sink.edges.as_mut_slice();
        for t in 0..workers {
            let (a, b) = (bounds[t], bounds[t + 1]);
            let e_base = self.offsets.edge_offset(a) - e0;
            let chunk_edges =
                (self.offsets.edge_offset(b) - self.offsets.edge_offset(a)) as usize;
            let (o, rest_o) = rem_off.split_at_mut(b - a);
            let (e, rest_e) = rem_edges.split_at_mut(chunk_edges);
            rem_off = rest_o;
            rem_edges = rest_e;
            tasks.push(Mutex::new(Some(ChunkTask { offsets: o, edges: e, e_base })));
        }
        let run = |t: usize| -> Result<()> {
            let task = tasks[t]
                .lock()
                .expect("chunk task lock")
                .take()
                .expect("chunk task is taken exactly once");
            let ChunkTask { offsets, edges, e_base } = task;
            let (a, b) = (bounds[t], bounds[t + 1]);
            accounts[t].time_cpu(|| {
                let mut fixed = FixedEdges { buf: edges, cursor: 0 };
                let mut filled = 0usize;
                if a < b {
                    THREAD_SCRATCH.with(|s| {
                        self.decode_range_core(
                            a,
                            b,
                            &accounts[t],
                            scan,
                            &mut s.borrow_mut(),
                            &mut fixed,
                            &mut |pos| {
                                offsets[filled] = e_base + pos;
                                filled += 1;
                            },
                        )
                    })?;
                }
                // The stream must land exactly on the sidecar's declared
                // spans — in-place delivery leaves no slack to absorb drift.
                if fixed.cursor != fixed.buf.len() || filled != offsets.len() {
                    bail!(
                        "chunk {a}..{b} decoded {}/{} edges and {}/{} offsets \
                         declared by the sidecar (corrupt sidecar?)",
                        fixed.cursor,
                        fixed.buf.len(),
                        filled,
                        offsets.len()
                    );
                }
                Ok(())
            })
        };
        let results = match pool {
            Some(pool) => crate::util::pool::parallel_map_on(pool, workers, workers - 1, run),
            None => parallel_map(workers, workers, run),
        };
        for r in results {
            r?;
        }
        Ok(0)
    }

    /// Owned-chunks fallback of [`Self::decode_range_parallel_sink`] for
    /// ranges whose sidecar-declared edge total exceeds the shared
    /// allocation guard: chunk workers decode into per-chunk owned blocks
    /// (ordinary doubling growth, each bounded by its own reserve guard)
    /// and the vertex-order stitch into the sink is counted and returned.
    fn decode_range_parallel_stitched(
        &self,
        v_start: usize,
        v_end: usize,
        accounts: &[IoAccount],
        scan: &dyn ScanEngine,
        pool: Option<&crate::util::pool::ThreadPool>,
        sink: &mut DecodeSink<'_>,
    ) -> Result<u64> {
        let first = accounts.first().expect("caller checked accounts");
        let workers = accounts.len();
        let bounds = self.chunk_bounds(v_start, v_end, workers);
        let chunk = |t: usize| {
            let (a, b) = (bounds[t], bounds[t + 1]);
            accounts[t].time_cpu(|| self.decode_range_with_scan(a, b, &accounts[t], scan))
        };
        let parts = match pool {
            Some(pool) => {
                crate::util::pool::parallel_map_on(pool, workers, workers - 1, chunk)
            }
            None => parallel_map(workers, workers, chunk),
        };
        let mut chunks = Vec::with_capacity(workers);
        for p in parts {
            chunks.push(p?);
        }
        // Stitch in vertex order (chunk boundaries are sorted). The O(m)
        // copy is real CPU work — charge it to worker 0's virtual clock so
        // the modeled load time keeps covering it (as the pre-fan-out
        // load_full stitch did).
        first.time_cpu(|| {
            let total_edges: usize = chunks.iter().map(|c| c.edges.len()).sum();
            let out_offsets: &mut Vec<u64> = &mut *sink.offsets;
            let out_edges: &mut Vec<VertexId> = &mut *sink.edges;
            out_offsets.clear();
            out_edges.clear();
            out_offsets.reserve(v_end - v_start + 1);
            out_edges.reserve(total_edges);
            out_offsets.push(0);
            let mut copied = 0u64;
            for c in &chunks {
                let base = out_edges.len() as u64;
                out_edges.extend_from_slice(&c.edges);
                out_offsets.extend(c.offsets[1..].iter().map(|o| base + o));
                copied += (c.edges.len() * std::mem::size_of::<VertexId>()
                    + (c.offsets.len() - 1) * std::mem::size_of::<u64>())
                    as u64;
            }
            Ok(copied)
        })
    }

    /// Chunk boundaries for [`Self::decode_range_parallel`]: `parts + 1`
    /// vertex ids splitting `[lo, hi)` so each chunk covers ~the same
    /// number of *compressed bits* (an O(parts · log n) sidecar search).
    fn chunk_bounds(&self, lo: usize, hi: usize, parts: usize) -> Vec<usize> {
        let b0 = self.offsets.bit_offset(lo);
        let b1 = self.offsets.bit_offset(hi);
        let mut bounds = Vec::with_capacity(parts + 1);
        bounds.push(lo);
        for t in 1..parts {
            let target =
                b0 + ((b1 - b0) as u128 * t as u128 / parts as u128) as u64;
            let v = self.offsets.bit_partition_point(|b| b < target);
            let prev = *bounds.last().expect("non-empty bounds");
            bounds.push(v.clamp(prev, hi));
        }
        bounds.push(hi);
        bounds
    }

    /// Decode a single vertex's successor list (the "down to a single
    /// vertex's neighbor list" granularity of §1).
    pub fn decode_vertex(&self, v: usize, acct: &IoAccount) -> Result<Vec<VertexId>> {
        let mut cache = HashMap::new();
        self.decode_one(v, &mut cache, acct, 0)
    }

    /// Random-access decode of one vertex (fetches its byte span, resolves
    /// references recursively).
    fn decode_one(
        &self,
        v: usize,
        cache: &mut HashMap<usize, Vec<VertexId>>,
        acct: &IoAccount,
        depth: u32,
    ) -> Result<Vec<VertexId>> {
        if let Some(list) = cache.get(&v) {
            return Ok(list.clone());
        }
        if depth > self.meta.params.max_ref_chain + 1 {
            bail!("reference chain exceeds bound at vertex {v} (corrupt stream?)");
        }
        let bit0 = self.offsets.bit_offset(v);
        let bit1 = self.offsets.bit_offset(v + 1);
        let byte0 = bit0 / 8;
        let byte1 = (bit1 + 7) / 8;
        let local = self.file.try_read(byte0, byte1 - byte0, self.ctx, acct)?;
        let mut reader = BitReader::at_bit(&local, bit0 - byte0 * 8)
            .map_err(|e| anyhow::anyhow!("bit seek: {e}"))?;
        let mut parts = AdjParts::default();
        let mut gamma = CodeReader::new(Code::Gamma);
        let mut residual = CodeReader::new(self.meta.params.residual_code());
        let mut raw = Vec::new();
        self.read_parts_into(v, &mut reader, &mut parts, &mut gamma, &mut residual, &mut raw)?;
        // Native scan of this vertex's gaps.
        let mut gaps = parts.gaps.clone();
        for i in 1..gaps.len() {
            gaps[i] += gaps[i - 1];
        }
        let copied: Vec<VertexId> = if parts.reference > 0 {
            let target = v - parts.reference;
            let ref_list = self.decode_one(target, cache, acct, depth + 1)?;
            cache.insert(target, ref_list.clone());
            apply_blocks(v, &parts.blocks, &ref_list)?
        } else {
            Vec::new()
        };
        let residuals = validate_residuals(v, &gaps, self.meta.num_vertices)?;
        let list = merge3(v, parts.degree, &copied, &parts.intervals, &residuals)?;
        cache.insert(v, list.clone());
        Ok(list)
    }

    /// Phase-1 bit parse of one adjacency record into a reusable
    /// [`AdjParts`] (cleared here), through the table-driven readers.
    fn read_parts_into(
        &self,
        v: usize,
        reader: &mut BitReader<'_>,
        parts: &mut AdjParts,
        gamma: &mut CodeReader,
        residual: &mut CodeReader,
        raw: &mut Vec<u64>,
    ) -> Result<()> {
        parts.clear();
        parts.degree =
            gamma.read(reader).map_err(|e| anyhow::anyhow!("degree: {e}"))? as usize;
        if parts.degree == 0 {
            return Ok(());
        }
        // Successor lists are strictly increasing vertex ids in [0, n), so a
        // degree above n can only come from a corrupt stream. Rejecting it
        // here bounds every downstream `reserve` (fuzz suite: a flipped bit
        // in a γ length must never translate into an unbounded allocation).
        if parts.degree > self.meta.num_vertices {
            let n = self.meta.num_vertices;
            bail!("implausible degree {} at vertex {v} (n={n})", parts.degree);
        }
        parts.reference =
            gamma.read(reader).map_err(|e| anyhow::anyhow!("reference: {e}"))? as usize;
        if parts.reference > v {
            bail!("reference {} before vertex 0 at vertex {v}", parts.reference);
        }
        let mut copied_estimate = 0usize;
        if parts.reference > 0 {
            let block_count =
                gamma.read(reader).map_err(|e| anyhow::anyhow!("block count: {e}"))? as usize;
            if block_count > self.meta.num_vertices {
                bail!("implausible block count {block_count} at vertex {v}");
            }
            parts.blocks.reserve(block_count);
            for i in 0..block_count {
                let raw_len = gamma.read(reader).map_err(|e| anyhow::anyhow!("block: {e}"))?;
                parts.blocks.push(if i == 0 { raw_len } else { raw_len + 1 });
            }
            // The copied count needs the reference list's length, which the
            // offsets sidecar answers in O(1) (degree of the target) — no
            // graph data and no reference resolution in phase 1.
            let target = v - parts.reference;
            let ref_degree = self.offsets.degree(target);
            let mut pos = 0usize;
            let mut is_copy = true;
            for &len in &parts.blocks {
                let len = len as usize;
                // `len > ref_degree` first: keeps `pos + len` (≤ 2·degree
                // afterwards) overflow-free on corrupt run lengths.
                if len > ref_degree || pos + len > ref_degree {
                    bail!("copy blocks overrun reference list at vertex {v}");
                }
                if is_copy {
                    copied_estimate += len;
                }
                pos += len;
                is_copy = !is_copy;
            }
            if is_copy && pos < ref_degree {
                copied_estimate += ref_degree - pos;
            }
        }

        // Intervals.
        let interval_count =
            gamma.read(reader).map_err(|e| anyhow::anyhow!("interval count: {e}"))? as usize;
        if interval_count > parts.degree {
            bail!("implausible interval count at vertex {v}");
        }
        // Interval fields are bounded at parse time like the residuals
        // below: every valid interval lies inside [0, n), so the zig-zag
        // left, inter-interval gap and length are all < 2n — checking the
        // raw code values first keeps the i64/u64 arithmetic overflow-free
        // on corrupt streams.
        let n_u = self.meta.num_vertices as u64;
        let mut prev_right: i64 = v as i64;
        for i in 0..interval_count {
            let left: i64 = if i == 0 {
                let z = gamma.read(reader).map_err(|e| anyhow::anyhow!("interval left: {e}"))?;
                if z >= 2 * n_u + 2 {
                    bail!("interval left out of range at vertex {v}");
                }
                v as i64 + nat_to_int(z)
            } else {
                let g = gamma.read(reader).map_err(|e| anyhow::anyhow!("interval gap: {e}"))?;
                if g >= n_u {
                    bail!("interval gap out of range at vertex {v}");
                }
                prev_right + 2 + g as i64
            };
            let len_raw =
                gamma.read(reader).map_err(|e| anyhow::anyhow!("interval len: {e}"))?;
            if len_raw > n_u {
                bail!("interval length out of range at vertex {v}");
            }
            let len = len_raw + self.meta.params.min_interval_len as u64;
            if left < 0 || (left as u64 + len) > self.meta.num_vertices as u64 {
                bail!("interval out of range at vertex {v}");
            }
            for x in left..left + len as i64 {
                parts.intervals.push(x as VertexId);
            }
            prev_right = left + len as i64 - 1;
        }

        // Residual gaps, decoded as one batched run through the residual
        // table. Each raw value is bounded before use: residuals are
        // strictly increasing ids in [0, n), so the first must land in that
        // range and every later gap is < n. Beyond semantic validation this
        // keeps the phase-1/2 i64 gap sums overflow-free on corrupt streams
        // (a flipped bit in a ζ code must not become an arithmetic panic) —
        // and the run length itself is bounded by the degree guard above,
        // so the batch read cannot over-allocate.
        let residual_count = parts
            .degree
            .checked_sub(copied_estimate + parts.intervals.len())
            .with_context(|| format!("degree accounting underflow at vertex {v}"))?;
        let n = self.meta.num_vertices as i64;
        raw.clear();
        residual
            .read_run(reader, residual_count, raw)
            .map_err(|e| anyhow::anyhow!("residual: {e}"))?;
        parts.gaps.reserve(residual_count);
        for (i, &z) in raw.iter().enumerate() {
            if i == 0 {
                let first = v as i64 + nat_to_int(z);
                if first < 0 || first >= n {
                    bail!("first residual {first} out of range at vertex {v}");
                }
                parts.gaps.push(first);
            } else {
                if z >= self.meta.num_vertices as u64 {
                    bail!("residual gap {z} out of range at vertex {v}");
                }
                parts.gaps.push(1 + z as i64);
            }
        }
        Ok(())
    }
}

/// Expand copy/skip runs against a materialized reference list.
fn apply_blocks(v: usize, blocks: &[u64], ref_list: &[VertexId]) -> Result<Vec<VertexId>> {
    let mut copied = Vec::new();
    apply_blocks_into(v, blocks, ref_list, &mut copied)?;
    Ok(copied)
}

/// [`apply_blocks`] into a reusable scratch buffer (hot path).
fn apply_blocks_into(
    v: usize,
    blocks: &[u64],
    ref_list: &[VertexId],
    out: &mut Vec<VertexId>,
) -> Result<()> {
    let mut pos = 0usize;
    let mut is_copy = true;
    for &len in blocks {
        let len = len as usize;
        if len > ref_list.len() || pos + len > ref_list.len() {
            bail!("copy blocks overrun reference list at vertex {v}");
        }
        if is_copy {
            out.extend_from_slice(&ref_list[pos..pos + len]);
        }
        pos += len;
        is_copy = !is_copy;
    }
    if is_copy && pos < ref_list.len() {
        out.extend_from_slice(&ref_list[pos..]);
    }
    Ok(())
}

/// Check scanned residuals are strictly increasing and in range — the
/// random-access (`decode_one`) validator. The block path folds this into
/// the fused scan pass instead
/// ([`ScanEngine::scan_validate_u32`](crate::runtime::ScanEngine::scan_validate_u32)).
fn validate_residuals(v: usize, scanned: &[i64], n: usize) -> Result<Vec<VertexId>> {
    let mut out = Vec::with_capacity(scanned.len());
    let mut prev = -1i64;
    for &r in scanned {
        if r < 0 || r as usize >= n {
            bail!("residual {r} out of range at vertex {v}");
        }
        if r <= prev {
            bail!("residuals not increasing at vertex {v}");
        }
        out.push(r as VertexId);
        prev = r;
    }
    Ok(out)
}

/// Merge three sorted successor sequences into the final list.
fn merge3(
    v: usize,
    degree: usize,
    copied: &[VertexId],
    intervals: &[VertexId],
    residuals: &[VertexId],
) -> Result<Vec<VertexId>> {
    let mut out = Vec::with_capacity(degree);
    merge3_into(v, degree, copied, intervals, residuals, &mut out)?;
    Ok(out)
}

/// Merge three sorted successor sequences, appending to `out` (any
/// [`EdgeStore`]: a growable vector or a fixed in-place window). Returns
/// the (start, end) span written. Fast paths: when only one sequence is
/// non-empty (the common case for reference-free vertices) the merge is a
/// bulk copy.
fn merge3_into<E: EdgeStore>(
    v: usize,
    degree: usize,
    copied: &[VertexId],
    intervals: &[VertexId],
    residuals: &[VertexId],
    out: &mut E,
) -> Result<(usize, usize)> {
    if copied.len() + intervals.len() + residuals.len() != degree {
        bail!(
            "degree mismatch at vertex {v}: {} + {} + {} != {degree}",
            copied.len(),
            intervals.len(),
            residuals.len()
        );
    }
    let start = out.pos();
    let non_empty =
        usize::from(!copied.is_empty()) + usize::from(!intervals.is_empty())
            + usize::from(!residuals.is_empty());
    if non_empty <= 1 {
        out.extend_edges(v, copied)?;
        out.extend_edges(v, intervals)?;
        out.extend_edges(v, residuals)?;
        return Ok((start, out.pos()));
    }
    let (mut a, mut b, mut c) = (0usize, 0usize, 0usize);
    for _ in 0..degree {
        let ca = copied.get(a).copied().unwrap_or(VertexId::MAX);
        let cb = intervals.get(b).copied().unwrap_or(VertexId::MAX);
        let cc = residuals.get(c).copied().unwrap_or(VertexId::MAX);
        let m = ca.min(cb).min(cc);
        if m == VertexId::MAX {
            bail!("ran out of successors while merging at vertex {v}");
        }
        if m == ca {
            a += 1;
        } else if m == cb {
            b += 1;
        } else {
            c += 1;
        }
        out.push_edge(v, m)?;
    }
    Ok((start, out.pos()))
}

#[cfg(test)]
mod tests {
    use super::super::{read_meta, read_offsets, serialize, serialize_with, WgParams};
    use super::*;
    use crate::graph::generators;
    use crate::storage::DeviceKind;

    fn setup(g: &crate::graph::CsrGraph) -> (SimStore, IoAccount) {
        let store = SimStore::new(DeviceKind::Dram);
        for (name, data) in serialize(g, "g") {
            store.put(&name, data);
        }
        (store, IoAccount::new())
    }

    #[test]
    fn single_vertex_random_access() {
        let g = generators::barabasi_albert(500, 6, 13);
        let (store, acct) = setup(&g);
        let meta = read_meta(&store, "g", ReadCtx::default(), &acct).unwrap();
        let offs = read_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
        let dec = Decoder::open(&store, "g", &meta, &offs, ReadCtx::default(), &acct).unwrap();
        for v in [0usize, 1, 17, 250, 499] {
            let list = dec.decode_vertex(v, &acct).unwrap();
            assert_eq!(list, g.neighbors(v as VertexId), "vertex {v}");
        }
    }

    #[test]
    fn range_decode_matches_full_graph() {
        let g = generators::rmat(8, 10, 21);
        let (store, acct) = setup(&g);
        let meta = read_meta(&store, "g", ReadCtx::default(), &acct).unwrap();
        let offs = read_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
        let dec = Decoder::open(&store, "g", &meta, &offs, ReadCtx::default(), &acct).unwrap();
        let n = g.num_vertices();
        for (a, b) in [(0, n), (10, 30), (100, 101), (n - 5, n), (0, 1), (37, 37)] {
            let block = dec.decode_range(a, b, &acct).unwrap();
            assert_eq!(block.num_vertices(), b - a);
            for (i, v) in (a..b).enumerate() {
                assert_eq!(block.neighbors(i), g.neighbors(v as VertexId), "vertex {v}");
            }
        }
    }

    #[test]
    fn scratch_reuse_is_equivalent_and_counts_table_hits() {
        // One scratch across many decodes (different ranges, twice each)
        // must give byte-identical blocks, and the decode tables must
        // actually serve the stream.
        let g = generators::similarity_blocks(800, 40, 12, 5);
        let (store, acct) = setup(&g);
        let meta = read_meta(&store, "g", ReadCtx::default(), &acct).unwrap();
        let offs = read_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
        let dec = Decoder::open(&store, "g", &meta, &offs, ReadCtx::default(), &acct).unwrap();
        let mut scratch = DecodeScratch::new();
        let n = g.num_vertices();
        for (a, b) in [(0, n), (13, 400), (700, n), (0, 1), (5, 5)] {
            let fresh = dec.decode_range(a, b, &acct).unwrap();
            let warm1 =
                dec.decode_range_scratch(a, b, &acct, &crate::runtime::NativeScan, &mut scratch)
                    .unwrap();
            let warm2 =
                dec.decode_range_scratch(a, b, &acct, &crate::runtime::NativeScan, &mut scratch)
                    .unwrap();
            assert_eq!(fresh, warm1, "range {a}..{b}");
            assert_eq!(fresh, warm2, "range {a}..{b} (reused scratch)");
        }
        let (hits, misses) = scratch.table_counters();
        assert!(hits > 0, "decode tables must serve a web-like stream");
        // On this 800-vertex similarity graph the residual gaps are small
        // (≈ n / degree ≈ 20), so most symbols sit inside the 11-bit
        // tables; keep the floor conservative anyway.
        assert!(
            scratch.table_hit_rate() > 0.3,
            "small symbols dominate: hit rate {} ({hits}/{misses})",
            scratch.table_hit_rate()
        );
    }

    #[test]
    fn scratch_survives_graph_switch() {
        // A thread-local (or otherwise shared) scratch must not leak state
        // between different graphs/streams.
        let g1 = generators::barabasi_albert(400, 6, 1);
        let g2 = generators::road_lattice(20, 20, 3, 2);
        let mut scratch = DecodeScratch::new();
        for g in [&g1, &g2, &g1] {
            let (store, acct) = setup(g);
            let meta = read_meta(&store, "g", ReadCtx::default(), &acct).unwrap();
            let offs = read_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
            let dec =
                Decoder::open(&store, "g", &meta, &offs, ReadCtx::default(), &acct).unwrap();
            let n = g.num_vertices();
            let block = dec
                .decode_range_scratch(0, n, &acct, &crate::runtime::NativeScan, &mut scratch)
                .unwrap();
            for (i, v) in (0..n).enumerate() {
                assert_eq!(block.neighbors(i), g.neighbors(v as VertexId), "vertex {v}");
            }
        }
    }

    #[test]
    fn sink_decode_matches_decode_range_oracle() {
        // Reference-chain-heavy stream (small window, deep chains): the
        // sink path must produce byte-identical output to the owned-block
        // oracle, including across sink reuse (stale capacity must never
        // leak into a later decode).
        let g = generators::similarity_blocks(900, 36, 12, 7);
        let store = SimStore::new(DeviceKind::Dram);
        let params = WgParams { window: 4, max_ref_chain: 6, ..WgParams::default() };
        for (name, data) in serialize_with(&g, "g", params) {
            store.put(&name, data);
        }
        let acct = IoAccount::new();
        let meta = read_meta(&store, "g", ReadCtx::default(), &acct).unwrap();
        let offs = read_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
        let dec = Decoder::open(&store, "g", &meta, &offs, ReadCtx::default(), &acct).unwrap();
        let n = g.num_vertices();
        let mut offsets: Vec<u64> = Vec::new();
        let mut edges: Vec<VertexId> = Vec::new();
        // Biggest range first so later (smaller) decodes run inside stale
        // capacity — the clearing contract is what keeps them correct.
        for (a, b) in [(0, n), (3, 500), (499, 503), (n - 20, n), (13, 13), (0, 1)] {
            let oracle = dec.decode_range(a, b, &acct).unwrap();
            let mut sink = DecodeSink::new(&mut offsets, &mut edges);
            dec.decode_range_sink(a, b, &acct, &crate::runtime::NativeScan, &mut sink)
                .unwrap();
            assert_eq!(offsets, oracle.offsets, "range {a}..{b}");
            assert_eq!(edges, oracle.edges, "range {a}..{b}");
        }
        // And the parallel sink path: both fan-out shapes are zero-copy —
        // a single worker decodes straight into the sink, multiple workers
        // write disjoint pre-partitioned slices of it in place.
        let one = [IoAccount::new()];
        let mut sink = DecodeSink::new(&mut offsets, &mut edges);
        let copied = dec
            .decode_range_parallel_sink(0, n, &one, &crate::runtime::NativeScan, None, &mut sink)
            .unwrap();
        assert_eq!(copied, 0, "single-worker sink decode is zero-copy");
        let oracle = dec.decode_range(0, n, &acct).unwrap();
        assert_eq!(offsets, oracle.offsets);
        assert_eq!(edges, oracle.edges);
        let four: Vec<IoAccount> = (0..4).map(|_| IoAccount::new()).collect();
        let mut sink = DecodeSink::new(&mut offsets, &mut edges);
        let copied = dec
            .decode_range_parallel_sink(0, n, &four, &crate::runtime::NativeScan, None, &mut sink)
            .unwrap();
        assert_eq!(copied, 0, "pre-partitioned fan-out writes the sink in place");
        assert_eq!(offsets, oracle.offsets);
        assert_eq!(edges, oracle.edges);
    }

    #[test]
    fn sink_decode_fails_like_the_oracle_on_corrupt_streams() {
        // Same corruption, same verdict: whenever the owned-block decode
        // errors, the sink decode must error too (and vice versa) — the
        // coordinator's failure path depends on this agreement.
        let g = generators::barabasi_albert(400, 6, 3);
        let store = SimStore::new(DeviceKind::Dram);
        for (name, mut data) in serialize(&g, "g") {
            if name.ends_with(".graph") {
                let mid = data.len() / 3;
                for b in data.iter_mut().skip(mid).take(48) {
                    *b = !*b;
                }
            }
            store.put(&name, data);
        }
        let acct = IoAccount::new();
        let meta = read_meta(&store, "g", ReadCtx::default(), &acct).unwrap();
        let offs = read_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
        let dec = Decoder::open(&store, "g", &meta, &offs, ReadCtx::default(), &acct).unwrap();
        let n = g.num_vertices();
        let mut offsets = Vec::new();
        let mut edges = Vec::new();
        for (a, b) in [(0, n), (50, 350), (0, 10)] {
            let oracle = dec.decode_range(a, b, &acct);
            let mut sink = DecodeSink::new(&mut offsets, &mut edges);
            let sunk = dec.decode_range_sink(a, b, &acct, &crate::runtime::NativeScan, &mut sink);
            assert_eq!(oracle.is_err(), sunk.is_err(), "range {a}..{b}");
            if let Ok(block) = oracle {
                assert_eq!(offsets, block.offsets, "range {a}..{b}");
                assert_eq!(edges, block.edges, "range {a}..{b}");
            }
        }
    }

    #[test]
    fn selective_read_is_selective() {
        // Decoding a small range must read a small fraction of the stream.
        let g = generators::barabasi_albert(5000, 8, 31);
        let (store, setup_acct) = setup(&g);
        let meta = read_meta(&store, "g", ReadCtx::default(), &setup_acct).unwrap();
        let offs = read_offsets(&store, "g", ReadCtx::default(), &setup_acct).unwrap();
        store.drop_cache();
        let acct = IoAccount::new();
        let dec = Decoder::open(&store, "g", &meta, &offs, ReadCtx::default(), &acct).unwrap();
        let block = dec.decode_range(2000, 2100, &acct).unwrap();
        assert_eq!(block.num_vertices(), 100);
        let graph_len = store.file_len("g.graph").unwrap();
        assert!(
            acct.bytes_read() < graph_len / 5,
            "read {} of {graph_len} for a 2% range",
            acct.bytes_read()
        );
    }

    #[test]
    fn cross_block_references_resolve() {
        // Force heavy referencing, then decode ranges that start right
        // after reference targets.
        let g = generators::similarity_blocks(600, 48, 16, 3);
        let store = SimStore::new(DeviceKind::Dram);
        let params = WgParams { window: 7, max_ref_chain: 5, ..WgParams::default() };
        for (name, data) in serialize_with(&g, "g", params) {
            store.put(&name, data);
        }
        let acct = IoAccount::new();
        let meta = read_meta(&store, "g", ReadCtx::default(), &acct).unwrap();
        let offs = read_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
        let dec = Decoder::open(&store, "g", &meta, &offs, ReadCtx::default(), &acct).unwrap();
        for start in [1usize, 5, 49, 100, 333] {
            let block = dec.decode_range(start, start + 20, &acct).unwrap();
            for (i, v) in (start..start + 20).enumerate() {
                assert_eq!(block.neighbors(i), g.neighbors(v as VertexId), "vertex {v}");
            }
        }
    }

    #[test]
    fn parallel_range_decode_matches_sequential() {
        // Heavy referencing makes chunk heads resolve out-of-chunk
        // references through the bounded recursion — the hard case.
        let g = generators::similarity_blocks(1200, 48, 16, 9);
        let store = SimStore::new(DeviceKind::Dram);
        let params = WgParams { window: 7, max_ref_chain: 5, ..WgParams::default() };
        for (name, data) in serialize_with(&g, "g", params) {
            store.put(&name, data);
        }
        let acct = IoAccount::new();
        let meta = read_meta(&store, "g", ReadCtx::default(), &acct).unwrap();
        let offs = read_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
        let dec = Decoder::open(&store, "g", &meta, &offs, ReadCtx::default(), &acct).unwrap();
        let n = g.num_vertices();
        for workers in [1usize, 2, 3, 4, 8] {
            let accounts: Vec<IoAccount> = (0..workers).map(|_| IoAccount::new()).collect();
            for (a, b) in [(0, n), (0, 1), (17, 17), (5, n - 3), (n / 2, n / 2 + 7)] {
                let par = dec
                    .decode_range_parallel(a, b, &accounts, &crate::runtime::NativeScan)
                    .unwrap();
                let seq = dec.decode_range(a, b, &acct).unwrap();
                assert_eq!(par, seq, "range {a}..{b} workers={workers}");
                assert_eq!(par.first_vertex, a);
                assert_eq!(par.num_vertices(), b - a);
            }
            // Every worker that decoded a chunk charged its own clock.
            let charged = accounts.iter().filter(|a| a.cpu_seconds() > 0.0).count();
            assert!(charged >= 1, "workers={workers}");
        }
    }

    #[test]
    fn parallel_decode_rejects_bad_input() {
        let g = generators::rmat(6, 4, 5);
        let (store, acct) = setup(&g);
        let meta = read_meta(&store, "g", ReadCtx::default(), &acct).unwrap();
        let offs = read_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
        let dec = Decoder::open(&store, "g", &meta, &offs, ReadCtx::default(), &acct).unwrap();
        let accounts: Vec<IoAccount> = (0..2).map(|_| IoAccount::new()).collect();
        let scan = crate::runtime::NativeScan;
        assert!(dec.decode_range_parallel(10, 5, &accounts, &scan).is_err());
        assert!(dec
            .decode_range_parallel(0, g.num_vertices() + 1, &accounts, &scan)
            .is_err());
        assert!(dec.decode_range_parallel(0, 5, &[], &scan).is_err(), "no accounts");
    }

    #[test]
    fn corrupt_stream_is_error_not_panic() {
        let g = generators::barabasi_albert(300, 5, 17);
        let store = SimStore::new(DeviceKind::Dram);
        for (name, mut data) in serialize(&g, "g") {
            if name.ends_with(".graph") {
                let mid = data.len() / 2;
                for b in data.iter_mut().skip(mid).take(64) {
                    *b = !*b;
                }
            }
            store.put(&name, data);
        }
        let acct = IoAccount::new();
        let meta = read_meta(&store, "g", ReadCtx::default(), &acct).unwrap();
        let offs = read_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
        let dec = Decoder::open(&store, "g", &meta, &offs, ReadCtx::default(), &acct).unwrap();
        // Either an error or a wrong-but-well-formed list; never a panic.
        for v in 0..300usize {
            let _ = dec.decode_vertex(v, &acct);
        }
        let _ = dec.decode_range(100, 250, &acct);
    }

    #[test]
    fn bad_range_rejected() {
        let g = generators::rmat(6, 4, 5);
        let (store, acct) = setup(&g);
        let meta = read_meta(&store, "g", ReadCtx::default(), &acct).unwrap();
        let offs = read_offsets(&store, "g", ReadCtx::default(), &acct).unwrap();
        let dec = Decoder::open(&store, "g", &meta, &offs, ReadCtx::default(), &acct).unwrap();
        assert!(dec.decode_range(10, 5, &acct).is_err());
        assert!(dec.decode_range(0, g.num_vertices() + 1, &acct).is_err());
    }
}
