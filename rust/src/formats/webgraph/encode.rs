//! WebGraph-style encoder.
//!
//! Per vertex v (all values on an MSB-first bit stream):
//!
//! ```text
//! outdegree                     γ
//! [if d > 0]
//!   reference r                 γ      (0 = none; else copy from v-r)
//!   [if r > 0]
//!     block_count               γ
//!     blocks[0]                 γ      (copy-run length, may be 0)
//!     blocks[i>0]               γ      (run length - 1, runs alternate
//!                                       copy/skip; the implicit final run
//!                                       extends to the end of the ref list
//!                                       and is a copy iff block_count even)
//!   interval_count              γ
//!   per interval:
//!     left(first)               γ(zig-zag(left - v))
//!     left(later)               γ(left - prev_right - 2)
//!     len - min_interval_len    γ
//!   residuals:
//!     first                     ζ_k(zig-zag(res - v))
//!     later                     ζ_k(gap - 1)
//! ```
//!
//! The encoder greedily picks, per vertex, the reference in the window that
//! minimizes the encoded size (including "no reference"), subject to the
//! `max_ref_chain` bound that keeps random access O(chain) — the knob that
//! trades compression ratio r against decompression bandwidth d (§3, §6).

use anyhow::Result;

use super::WgParams;
use crate::graph::{CsrGraph, VertexId};
use crate::util::bitstream::BitWriter;
use crate::util::codes::{int_to_nat, write_gamma, Code};

/// Compression statistics (per-technique accounting for DESIGN/EXPERIMENTS).
#[derive(Debug, Default, Clone)]
pub struct CompressionStats {
    pub vertices_with_reference: u64,
    pub copied_edges: u64,
    pub interval_edges: u64,
    pub residual_edges: u64,
    pub total_bits: u64,
    /// Deepest reference chain emitted (≤ `WgParams::max_ref_chain`); the
    /// random-access `successors()` tests assert the bound is actually
    /// exercised, not just configured.
    pub max_ref_chain_depth: u32,
}

/// Compress `graph`; returns (bit stream bytes, per-vertex bit offsets
/// (n+1 entries), stats). Neighbor lists must be sorted ascending —
/// [`CsrGraph`] constructors guarantee it.
pub fn compress(graph: &CsrGraph, params: WgParams) -> (Vec<u8>, Vec<u64>, CompressionStats) {
    let n = graph.num_vertices();
    let mut w = BitWriter::with_capacity(graph.num_edges() as usize / 2 + 64);
    let mut bit_offsets = Vec::with_capacity(n + 1);
    let mut stats = CompressionStats::default();
    // Reference chain depth per vertex (how many hops to fully resolve).
    let mut chain_depth = vec![0u32; n];

    for v in 0..n {
        bit_offsets.push(w.bit_len());
        let list = graph.neighbors(v as VertexId);
        write_gamma(&mut w, list.len() as u64);
        if list.is_empty() {
            continue;
        }

        // Candidate references: r in 1..=window with chain budget left.
        let mut best: Option<(u32, EncodedAdj)> = None;
        let no_ref = encode_adjacency(v as u64, list, &[], params);
        for r in 1..=params.window.min(v as u32) {
            let u = v - r as usize;
            if chain_depth[u] + 1 > params.max_ref_chain {
                continue;
            }
            let ref_list = graph.neighbors(u as VertexId);
            if ref_list.is_empty() {
                continue;
            }
            let enc = encode_adjacency(v as u64, list, ref_list, params);
            if enc.bits < best.as_ref().map(|(_, e)| e.bits).unwrap_or(u64::MAX) {
                best = Some((r, enc));
            }
        }

        let use_ref = match &best {
            Some((_, enc)) if enc.bits < no_ref.bits => true,
            _ => false,
        };
        let (r, enc) = if use_ref {
            let (r, enc) = best.unwrap();
            chain_depth[v] = chain_depth[v - r as usize] + 1;
            stats.max_ref_chain_depth = stats.max_ref_chain_depth.max(chain_depth[v]);
            stats.vertices_with_reference += 1;
            (r, enc)
        } else {
            (0u32, no_ref)
        };
        stats.copied_edges += enc.copied as u64;
        stats.interval_edges += enc.interval_edges as u64;
        stats.residual_edges += enc.residuals as u64;

        write_gamma(&mut w, r as u64);
        enc.write(&mut w, params);
    }
    bit_offsets.push(w.bit_len());
    stats.total_bits = w.bit_len();
    (w.into_bytes(), bit_offsets, stats)
}

/// Everything [`compress_stream`] keeps besides the emitted `.graph` bytes:
/// the (γ-compressed) offset-delta streams the sidecar is assembled from,
/// plus the usual counters. The delta streams are the streaming replacement
/// for `compress`'s plain `Vec<u64>` of bit offsets — ~3 B/vertex instead
/// of 16, so the writer's footprint never approaches the graph's.
pub struct StreamedCompression {
    pub num_edges: u64,
    pub total_bits: u64,
    /// γ-coded bit-offset deltas (n+1 entries; record lengths) and the
    /// exact bit count of that stream (its byte form is padded).
    pub bit_deltas: Vec<u8>,
    pub bit_delta_bits: u64,
    /// γ-coded edge-offset deltas (n+1 entries; the degrees).
    pub edge_deltas: Vec<u8>,
    pub edge_delta_bits: u64,
    pub stats: CompressionStats,
}

/// Compress a graph defined by a per-vertex successor oracle, streaming the
/// `.graph` bytes out through `emit` as they complete — the out-of-core
/// writer. Memory stays O(window · max degree) for the reference ring plus
/// the compressed offset-delta streams, never O(|E|). `successors` must
/// fill `out` (cleared by the caller) with a sorted duplicate-free list;
/// the produced stream is bit-identical to [`compress`] over the same
/// lists (same greedy reference choice, same chain-depth accounting).
pub fn compress_stream(
    n: usize,
    params: WgParams,
    mut successors: impl FnMut(usize, &mut Vec<VertexId>),
    mut emit: impl FnMut(&[u8]) -> Result<()>,
) -> Result<StreamedCompression> {
    const FLUSH_BYTES: usize = 1 << 20;
    let mut w = BitWriter::new();
    let mut bits_w = BitWriter::new();
    let mut edges_w = BitWriter::new();
    let mut stats = CompressionStats::default();
    let wcap = params.window as usize;
    // Reference ring: the last `window` lists with their chain depths in
    // slot `u % wcap`. Candidates r in 1..=min(window, v) touch exactly
    // the wcap most recent vertices, so slots never collide in a window.
    let mut ring: Vec<(Vec<VertexId>, u32)> = (0..wcap).map(|_| (Vec::new(), 0)).collect();
    let mut cur: Vec<VertexId> = Vec::new();
    let mut pending: Vec<u8> = Vec::new();
    let mut prev_bit = 0u64;
    let mut m = 0u64;
    let mut prev_edges = 0u64;
    for v in 0..n {
        write_gamma(&mut bits_w, w.bit_len() - prev_bit);
        prev_bit = w.bit_len();
        write_gamma(&mut edges_w, m - prev_edges);
        prev_edges = m;
        cur.clear();
        successors(v, &mut cur);
        debug_assert!(cur.windows(2).all(|p| p[0] < p[1]), "successor lists must be sorted");
        m += cur.len() as u64;
        write_gamma(&mut w, cur.len() as u64);
        if cur.is_empty() {
            if wcap > 0 {
                let slot = &mut ring[v % wcap];
                slot.0.clear();
                slot.1 = 0;
            }
            continue;
        }
        // Same greedy reference choice as `compress`, against the ring.
        let mut best: Option<(u32, u32, EncodedAdj)> = None;
        let no_ref = encode_adjacency(v as u64, &cur, &[], params);
        for r in 1..=params.window.min(v as u32) {
            let (ref_list, depth) = &ring[(v - r as usize) % wcap];
            if *depth + 1 > params.max_ref_chain || ref_list.is_empty() {
                continue;
            }
            let enc = encode_adjacency(v as u64, &cur, ref_list, params);
            if enc.bits < best.as_ref().map(|(_, _, e)| e.bits).unwrap_or(u64::MAX) {
                best = Some((r, depth + 1, enc));
            }
        }
        let (r, depth, enc) = match best {
            Some((r, d, enc)) if enc.bits < no_ref.bits => {
                stats.vertices_with_reference += 1;
                stats.max_ref_chain_depth = stats.max_ref_chain_depth.max(d);
                (r, d, enc)
            }
            _ => (0, 0, no_ref),
        };
        stats.copied_edges += enc.copied as u64;
        stats.interval_edges += enc.interval_edges as u64;
        stats.residual_edges += enc.residuals as u64;
        write_gamma(&mut w, r as u64);
        enc.write(&mut w, params);
        if wcap > 0 {
            let slot = &mut ring[v % wcap];
            std::mem::swap(&mut slot.0, &mut cur);
            slot.1 = depth;
        }
        w.drain_full_bytes_into(&mut pending);
        if pending.len() >= FLUSH_BYTES {
            emit(&pending)?;
            pending.clear();
        }
    }
    // Final sidecar entries (offsets have n+1 of each), then the padded
    // stream tail.
    write_gamma(&mut bits_w, w.bit_len() - prev_bit);
    write_gamma(&mut edges_w, m - prev_edges);
    let total_bits = w.bit_len();
    stats.total_bits = total_bits;
    pending.extend_from_slice(&w.into_bytes());
    if !pending.is_empty() {
        emit(&pending)?;
    }
    Ok(StreamedCompression {
        num_edges: m,
        total_bits,
        bit_delta_bits: bits_w.bit_len(),
        bit_deltas: bits_w.into_bytes(),
        edge_delta_bits: edges_w.bit_len(),
        edge_deltas: edges_w.into_bytes(),
        stats,
    })
}

/// One vertex's encoded adjacency description (pre-serialization).
struct EncodedAdj {
    /// Alternating copy/skip run lengths over the reference list (first run
    /// is a copy run; trailing implicit run omitted).
    blocks: Vec<u64>,
    has_reference: bool,
    /// (left, len) intervals over the remaining successors.
    intervals: Vec<(u64, u64)>,
    /// Remaining residual successors.
    residual_list: Vec<u64>,
    /// Vertex id (for zig-zag bases).
    vertex: u64,
    /// Estimated encoded size in bits (excludes outdegree + reference γ).
    bits: u64,
    copied: usize,
    interval_edges: usize,
    residuals: usize,
}

impl EncodedAdj {
    fn write(&self, w: &mut BitWriter, params: WgParams) {
        if self.has_reference {
            write_gamma(w, self.blocks.len() as u64);
            for (i, &b) in self.blocks.iter().enumerate() {
                write_gamma(w, if i == 0 { b } else { b - 1 });
            }
        }
        write_gamma(w, self.intervals.len() as u64);
        let mut prev_right: i64 = self.vertex as i64; // sentinel, first uses zig-zag
        for (i, &(left, len)) in self.intervals.iter().enumerate() {
            if i == 0 {
                write_gamma(w, int_to_nat(left as i64 - self.vertex as i64));
            } else {
                write_gamma(w, (left as i64 - prev_right - 2) as u64);
            }
            write_gamma(w, len - params.min_interval_len as u64);
            prev_right = left as i64 + len as i64 - 1;
        }
        let code = params.residual_code();
        let mut prev: i64 = -1;
        for (i, &res) in self.residual_list.iter().enumerate() {
            if i == 0 {
                code.write(w, int_to_nat(res as i64 - self.vertex as i64));
            } else {
                code.write(w, (res as i64 - prev - 1) as u64);
            }
            prev = res as i64;
        }
    }
}

/// Build the adjacency description of `list` (successors of `vertex`)
/// against `ref_list` (empty slice = no reference).
fn encode_adjacency(
    vertex: u64,
    list: &[VertexId],
    ref_list: &[VertexId],
    params: WgParams,
) -> EncodedAdj {
    let has_reference = !ref_list.is_empty();

    // 1. Copy blocks: which entries of ref_list appear in list?
    let mut copied_mask = vec![false; ref_list.len()];
    let mut copied: Vec<u64> = Vec::new();
    if has_reference {
        let mut i = 0usize;
        for (j, &r) in ref_list.iter().enumerate() {
            while i < list.len() && list[i] < r {
                i += 1;
            }
            if i < list.len() && list[i] == r {
                copied_mask[j] = true;
                copied.push(r as u64);
                i += 1;
            }
        }
    }
    // Runs over the mask, alternating copy/skip, starting with copy.
    let mut blocks: Vec<u64> = Vec::new();
    if has_reference {
        let mut run_is_copy = true;
        let mut run_len = 0u64;
        for &c in &copied_mask {
            if c == run_is_copy {
                run_len += 1;
            } else {
                blocks.push(run_len);
                run_is_copy = !run_is_copy;
                run_len = 1;
            }
        }
        blocks.push(run_len);
        // Drop the trailing run: implicit (extends to end of ref list).
        blocks.pop();
        // All runs after the first have length >= 1 by construction.
    }

    // 2. Remaining successors (not copied).
    let mut rest: Vec<u64> = Vec::with_capacity(list.len() - copied.len());
    {
        let mut ci = 0usize;
        for &x in list {
            if ci < copied.len() && copied[ci] == x as u64 {
                ci += 1;
            } else {
                rest.push(x as u64);
            }
        }
    }

    // 3. Intervals: maximal runs of consecutive integers of length >= L.
    let min_len = params.min_interval_len.max(2) as usize;
    let mut intervals: Vec<(u64, u64)> = Vec::new();
    let mut residual_list: Vec<u64> = Vec::new();
    let mut i = 0usize;
    while i < rest.len() {
        let mut j = i + 1;
        while j < rest.len() && rest[j] == rest[j - 1] + 1 {
            j += 1;
        }
        if j - i >= min_len {
            intervals.push((rest[i], (j - i) as u64));
        } else {
            residual_list.extend_from_slice(&rest[i..j]);
        }
        i = j;
    }

    // 4. Cost model (exact: same codes as the writer).
    let mut bits = 0u64;
    if has_reference {
        bits += Code::Gamma.len_bits(blocks.len() as u64);
        for (i, &b) in blocks.iter().enumerate() {
            bits += Code::Gamma.len_bits(if i == 0 { b } else { b - 1 });
        }
    }
    bits += Code::Gamma.len_bits(intervals.len() as u64);
    let mut prev_right: i64 = vertex as i64;
    for (i, &(left, len)) in intervals.iter().enumerate() {
        if i == 0 {
            bits += Code::Gamma.len_bits(int_to_nat(left as i64 - vertex as i64));
        } else {
            bits += Code::Gamma.len_bits((left as i64 - prev_right - 2) as u64);
        }
        bits += Code::Gamma.len_bits(len - params.min_interval_len as u64);
        prev_right = left as i64 + len as i64 - 1;
    }
    let code = params.residual_code();
    let mut prev: i64 = -1;
    for (i, &res) in residual_list.iter().enumerate() {
        if i == 0 {
            bits += code.len_bits(int_to_nat(res as i64 - vertex as i64));
        } else {
            bits += code.len_bits((res as i64 - prev - 1) as u64);
        }
        prev = res as i64;
    }

    let interval_edges: usize = intervals.iter().map(|&(_, l)| l as usize).sum();
    EncodedAdj {
        blocks,
        has_reference,
        intervals,
        residuals: residual_list.len(),
        residual_list,
        vertex,
        bits,
        copied: copied.len(),
        interval_edges,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn stats_partition_edges() {
        let g = generators::barabasi_albert(800, 6, 3);
        let (_, offsets, stats) = compress(&g, WgParams::default());
        assert_eq!(offsets.len(), g.num_vertices() + 1);
        assert_eq!(
            stats.copied_edges + stats.interval_edges + stats.residual_edges,
            g.num_edges(),
            "every edge is exactly one of copied/interval/residual"
        );
    }

    #[test]
    fn references_used_on_similar_lists() {
        let g = generators::similarity_blocks(400, 32, 8, 1);
        let (_, _, stats) = compress(&g, WgParams::default());
        assert!(
            stats.vertices_with_reference > (g.num_vertices() / 4) as u64,
            "similarity graph should trigger reference compression: {} of {}",
            stats.vertices_with_reference,
            g.num_vertices()
        );
        assert!(stats.copied_edges > 0);
    }

    #[test]
    fn intervals_used_on_lattice() {
        let g = generators::road_lattice(30, 30, 0, 1);
        let (_, _, stats) = compress(&g, WgParams::default());
        // Lattice neighbors are {v-w, v-1, v+1, v+w}: not long runs, but
        // interval code must at least not fire incorrectly; check instead on
        // an explicit run-heavy graph.
        let mut edges = Vec::new();
        for d in 10..200u32 {
            edges.push((0u32, d));
        }
        let run = crate::graph::CsrGraph::from_edges(201, &edges);
        let (_, _, s2) = compress(&run, WgParams::default());
        assert!(s2.interval_edges >= 180, "long run must be intervalized");
        let _ = stats;
    }

    #[test]
    fn streamed_compression_is_bit_identical_to_batch() {
        use crate::graph::VertexId;
        use crate::util::bitstream::BitReader;
        use crate::util::codes::read_gamma;
        let g = generators::web_locality(600, 8, 0.9, 0.6, 3);
        let (stream, bit_offsets, batch_stats) = compress(&g, WgParams::default());
        let mut streamed = Vec::new();
        let out = compress_stream(
            g.num_vertices(),
            WgParams::default(),
            |v, out| out.extend_from_slice(g.neighbors(v as VertexId)),
            |bytes| {
                streamed.extend_from_slice(bytes);
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(streamed, stream, "streamed .graph bytes must match batch");
        assert_eq!(out.num_edges, g.num_edges());
        assert_eq!(out.total_bits, *bit_offsets.last().unwrap());
        assert_eq!(out.stats.vertices_with_reference, batch_stats.vertices_with_reference);
        assert_eq!(out.stats.total_bits, batch_stats.total_bits);
        // The γ-delta streams decode back to the batch offsets arrays.
        let mut r = BitReader::new(&out.bit_deltas);
        let mut acc = 0u64;
        for (v, &want) in bit_offsets.iter().enumerate() {
            acc += read_gamma(&mut r).unwrap();
            assert_eq!(acc, want, "bit offset {v}");
        }
        let mut r = BitReader::new(&out.edge_deltas);
        let mut acc = 0u64;
        for (v, &want) in g.offsets.iter().enumerate() {
            acc += read_gamma(&mut r).unwrap();
            assert_eq!(acc, want, "edge offset {v}");
        }
    }

    #[test]
    fn window_zero_disables_references() {
        let g = generators::similarity_blocks(200, 16, 4, 2);
        let p = WgParams { window: 0, ..WgParams::default() };
        let (_, _, stats) = compress(&g, p);
        assert_eq!(stats.vertices_with_reference, 0);
        assert_eq!(stats.copied_edges, 0);
    }

    #[test]
    fn bigger_window_rarely_larger_stream() {
        // Greedy per-vertex reference choice under the chain-depth budget is
        // not globally optimal, so a larger window is not *strictly*
        // monotone — but it must not be materially worse.
        let g = generators::barabasi_albert(500, 8, 5);
        let small = compress(&g, WgParams { window: 1, ..WgParams::default() }).2.total_bits;
        let large = compress(&g, WgParams { window: 15, ..WgParams::default() }).2.total_bits;
        assert!(
            (large as f64) <= small as f64 * 1.02,
            "larger window should not hurt by >2%: {large} vs {small}"
        );
    }

    #[test]
    fn chain_bound_respected() {
        // With max_ref_chain = 1, a referenced vertex must itself be
        // reference-free; indirectly tested via decode, but the depth
        // accounting is internal — validate by compressing a pathological
        // graph where every vertex has identical neighbors.
        let mut edges = Vec::new();
        for v in 0..50u32 {
            for d in [100u32, 101, 102, 103] {
                edges.push((v, d));
            }
        }
        let g = crate::graph::CsrGraph::from_edges(104, &edges);
        // max_ref_chain = 0 disables referencing entirely.
        let p0 = WgParams { max_ref_chain: 0, ..WgParams::default() };
        let (_, _, s0) = compress(&g, p0);
        assert_eq!(s0.vertices_with_reference, 0);
        // max_ref_chain = 1 with window W: every referencing vertex must
        // point at a chain-free one, so each window of W+1 vertices keeps
        // at least one non-referencing "anchor".
        let p1 = WgParams { window: 7, max_ref_chain: 1, ..WgParams::default() };
        let (_, _, s1) = compress(&g, p1);
        let n = g.num_vertices() as u64;
        assert!(s1.vertices_with_reference <= n - n / 8, "anchors required: {}", s1.vertices_with_reference);
        // Unbounded chains reference almost everything on this graph.
        let pu = WgParams { max_ref_chain: 100, ..WgParams::default() };
        let (_, _, su) = compress(&g, pu);
        assert!(su.vertices_with_reference >= s1.vertices_with_reference);
        assert!(su.vertices_with_reference >= 45, "unbounded chain references nearly all: {}", su.vertices_with_reference);
    }
}
