//! Textual COO (edge-list / Matrix-Market-style) format and its GAPBS-style
//! two-pass parallel loader.
//!
//! One line per edge: `src dst\n` (0-based decimal IDs; weighted graphs add
//! a third column). Loading splits the byte range into per-thread chunks
//! aligned to line boundaries; pass 1 counts edges per chunk, a prefix sum
//! assigns output slots, pass 2 parses in place — exactly the parallel
//! pattern §2 "Parallel Loading" describes.

use anyhow::{bail, Context, Result};

use crate::graph::{CooEdges, CsrGraph, VertexId};
use crate::storage::sim::ReadCtx;
use crate::storage::{IoAccount, SimStore};
use crate::util::pool::parallel_map;
use crate::util::{chunk_range, prefix::exclusive_prefix_sum};

/// Serialize to `{base}.el`. A Matrix-Market-style size comment preserves
/// the vertex count (trailing isolated vertices are otherwise
/// unrepresentable in an edge list).
pub fn serialize(graph: &CsrGraph, base: &str) -> Vec<(String, Vec<u8>)> {
    let mut out = String::new();
    out.push_str(&format!("# vertices {}\n", graph.num_vertices()));
    if graph.is_weighted() {
        for v in 0..graph.num_vertices() {
            let ns = graph.neighbors(v as VertexId);
            let ws = graph.neighbor_weights(v as VertexId);
            for (d, w) in ns.iter().zip(ws) {
                out.push_str(&format!("{} {} {}\n", v, d, w));
            }
        }
    } else {
        for (s, d) in graph.iter_edges() {
            out.push_str(&format!("{} {}\n", s, d));
        }
    }
    vec![(format!("{base}.el"), out.into_bytes())]
}

/// GAPBS-style parallel two-pass load.
pub fn load(
    store: &SimStore,
    base: &str,
    ctx: ReadCtx,
    accounts: &[IoAccount],
) -> Result<CsrGraph> {
    let name = format!("{base}.el");
    let file = store.open(&name).with_context(|| format!("missing {name}"))?;
    let len = file.len();
    let threads = accounts.len().max(1);

    // Read raw chunks in parallel (ranged reads, like dividing the file's
    // total size between threads).
    let chunks: Vec<Vec<u8>> = parallel_map(threads, threads, |i| {
        let (s, e) = chunk_range(len as usize, threads, i);
        file.read(s as u64, (e - s) as u64, ctx, &accounts[i])
    });

    // Align chunk boundaries to newlines: each chunk owns lines that *start*
    // inside it; a line spanning into the next chunk is completed from there.
    let mut parts: Vec<Vec<u8>> = Vec::with_capacity(threads);
    for i in 0..threads {
        let mut part = Vec::new();
        let cur = &chunks[i];
        // A line belongs to the chunk where it *starts*. Chunk i's first
        // bytes are a partial line (owned by an earlier chunk) unless the
        // previous non-empty chunk ended exactly on a newline.
        let prev_ends_at_newline = i == 0
            || chunks[..i]
                .iter()
                .rev()
                .find(|c| !c.is_empty())
                .map(|c| *c.last().unwrap() == b'\n')
                .unwrap_or(true);
        let start = if prev_ends_at_newline {
            0
        } else {
            match cur.iter().position(|&b| b == b'\n') {
                Some(p) => p + 1,
                None => cur.len(),
            }
        };
        part.extend_from_slice(&cur[start..]);
        // Complete the trailing partial line from following chunks.
        if !part.is_empty() && *part.last().unwrap() != b'\n' {
            for next in chunks.iter().skip(i + 1) {
                match next.iter().position(|&b| b == b'\n') {
                    Some(p) => {
                        part.extend_from_slice(&next[..=p]);
                        break;
                    }
                    None => part.extend_from_slice(next),
                }
            }
        }
        parts.push(part);
    }

    // Pass 1: count edges per chunk (parallel, real CPU charged).
    let counts: Vec<u64> = parallel_map(threads, threads, |i| {
        accounts[i].time_cpu(|| count_lines(&parts[i]) as u64)
    });
    let mut offsets = counts.clone();
    let total = exclusive_prefix_sum(&mut offsets) as usize;

    // Pass 2: parse into place.
    let weighted = detect_weighted(&parts);
    let mut src = vec![0 as VertexId; total];
    let mut dst = vec![0 as VertexId; total];
    let mut wts = if weighted { vec![0f32; total] } else { Vec::new() };
    {
        let src_ptr = SyncSlice(src.as_mut_ptr());
        let dst_ptr = SyncSlice(dst.as_mut_ptr());
        let wts_ptr = SyncSlice(wts.as_mut_ptr());
        let errs: Vec<Option<String>> = parallel_map(threads, threads, |i| {
            accounts[i].time_cpu(|| {
                let mut idx = offsets[i] as usize;
                for line in parts[i].split(|&b| b == b'\n') {
                    if line.is_empty() || line[0] == b'#' || line[0] == b'%' {
                        continue;
                    }
                    match parse_line(line, weighted) {
                        Ok((s, d, w)) => unsafe {
                            // SAFETY: chunk i owns [offsets[i], offsets[i]+counts[i]).
                            src_ptr.write(idx, s);
                            dst_ptr.write(idx, d);
                            if weighted {
                                wts_ptr.write(idx, w);
                            }
                            idx += 1;
                        },
                        Err(e) => return Some(e),
                    }
                }
                None
            })
        });
        if let Some(e) = errs.into_iter().flatten().next() {
            bail!("parse error in {name}: {e}");
        }
    }

    // Vertex count: the size comment if present, else 1 + max endpoint.
    let declared = parts.first().and_then(|p| parse_vertices_comment(p));
    let num_vertices = declared
        .unwrap_or(0)
        .max(src.iter().chain(dst.iter()).map(|&v| v as usize + 1).max().unwrap_or(0));
    let coo = CooEdges { num_vertices, src, dst, weights: wts };
    // CSR build is the "framework side" cost; charge to worker 0.
    Ok(accounts[0].time_cpu(|| coo.to_csr()))
}

/// Parse a leading `# vertices <n>` size comment.
fn parse_vertices_comment(part: &[u8]) -> Option<usize> {
    let first = part.split(|&b| b == b'\n').next()?;
    let text = std::str::from_utf8(first).ok()?;
    let rest = text.strip_prefix("# vertices ")?;
    rest.trim().parse::<usize>().ok()
}

fn count_lines(bytes: &[u8]) -> usize {
    bytes
        .split(|&b| b == b'\n')
        .filter(|l| !l.is_empty() && l[0] != b'#' && l[0] != b'%')
        .count()
}

fn detect_weighted(parts: &[Vec<u8>]) -> bool {
    for part in parts {
        for line in part.split(|&b| b == b'\n') {
            if line.is_empty() || line[0] == b'#' || line[0] == b'%' {
                continue;
            }
            return line.split(|&b| b == b' ').filter(|t| !t.is_empty()).count() >= 3;
        }
    }
    false
}

fn parse_line(line: &[u8], weighted: bool) -> std::result::Result<(VertexId, VertexId, f32), String> {
    let mut it = line.split(|&b| b == b' ').filter(|t| !t.is_empty());
    let s = parse_u32(it.next().ok_or("missing src")?)?;
    let d = parse_u32(it.next().ok_or("missing dst")?)?;
    let w = if weighted {
        let t = it.next().ok_or("missing weight")?;
        std::str::from_utf8(t)
            .map_err(|e| e.to_string())?
            .trim()
            .parse::<f32>()
            .map_err(|e| e.to_string())?
    } else {
        0.0
    };
    Ok((s, d, w))
}

fn parse_u32(token: &[u8]) -> std::result::Result<u32, String> {
    let mut v: u64 = 0;
    if token.is_empty() {
        return Err("empty token".into());
    }
    for &b in token {
        if b == b'\r' {
            continue;
        }
        if !b.is_ascii_digit() {
            return Err(format!("bad digit {:?}", b as char));
        }
        v = v * 10 + (b - b'0') as u64;
        if v > u32::MAX as u64 {
            return Err("vertex id overflows u32".into());
        }
    }
    Ok(v as u32)
}

struct SyncSlice<T>(*mut T);
unsafe impl<T> Send for SyncSlice<T> {}
unsafe impl<T> Sync for SyncSlice<T> {}
impl<T> SyncSlice<T> {
    /// # Safety
    /// Disjoint index ranges per thread.
    unsafe fn write(&self, idx: usize, v: T) {
        *self.0.add(idx) = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::storage::DeviceKind;

    fn accounts(n: usize) -> Vec<IoAccount> {
        (0..n).map(|_| IoAccount::new()).collect()
    }

    #[test]
    fn roundtrip_various_thread_counts() {
        let g = generators::rmat(7, 8, 5);
        let store = SimStore::new(DeviceKind::Dram);
        for (name, data) in serialize(&g, "g") {
            store.put(&name, data);
        }
        for t in [1usize, 2, 3, 8] {
            let acc = accounts(t);
            let loaded = load(&store, "g", ReadCtx::default(), &acc).unwrap();
            assert_eq!(loaded, g, "threads={t}");
        }
    }

    #[test]
    fn weighted_roundtrip() {
        let g = CsrGraph::from_weighted_edges(4, &[(0, 1, 1.5), (1, 2, -2.0), (3, 0, 0.5)]);
        let store = SimStore::new(DeviceKind::Dram);
        for (name, data) in serialize(&g, "w") {
            store.put(&name, data);
        }
        let loaded = load(&store, "w", ReadCtx::default(), &accounts(2)).unwrap();
        assert_eq!(loaded, g);
    }

    #[test]
    fn comments_and_blank_lines_skipped() {
        let store = SimStore::new(DeviceKind::Dram);
        store.put("c.el", b"# comment\n0 1\n\n% other\n1 2\n".to_vec());
        let g = load(&store, "c", ReadCtx::default(), &accounts(2)).unwrap();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.neighbors(0), &[1]);
    }

    #[test]
    fn garbage_is_an_error() {
        let store = SimStore::new(DeviceKind::Dram);
        store.put("bad.el", b"0 xyz\n".to_vec());
        assert!(load(&store, "bad", ReadCtx::default(), &accounts(1)).is_err());
    }

    #[test]
    fn missing_file_is_an_error() {
        let store = SimStore::new(DeviceKind::Dram);
        assert!(load(&store, "nope", ReadCtx::default(), &accounts(1)).is_err());
    }

    #[test]
    fn empty_file_loads_empty_graph() {
        let store = SimStore::new(DeviceKind::Dram);
        store.put("e.el", Vec::new());
        let g = load(&store, "e", ReadCtx::default(), &accounts(2)).unwrap();
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.num_vertices(), 0);
    }
}
