//! Textual CSX ("AdjacencyGraph", PBBS/Ligra-style) format.
//!
//! ```text
//! AdjacencyGraph
//! <n>
//! <m>
//! <offset_0> ... <offset_{n-1}>      (one per line)
//! <edge_0> ... <edge_{m-1}>          (one per line)
//! ```
//!
//! Weighted variant uses header `WeightedAdjacencyGraph` and appends m
//! weight lines. Parsing is chunk-parallel over the numeric lines.

use anyhow::{bail, Context, Result};

use crate::graph::{CsrGraph, VertexId};
use crate::storage::sim::ReadCtx;
use crate::storage::{IoAccount, SimStore};
use crate::util::chunk_range;
use crate::util::pool::parallel_map;

pub fn serialize(graph: &CsrGraph, base: &str) -> Vec<(String, Vec<u8>)> {
    let n = graph.num_vertices();
    let m = graph.num_edges();
    let mut out = String::new();
    out.push_str(if graph.is_weighted() { "WeightedAdjacencyGraph\n" } else { "AdjacencyGraph\n" });
    out.push_str(&format!("{n}\n{m}\n"));
    for v in 0..n {
        out.push_str(&format!("{}\n", graph.offsets[v]));
    }
    for &e in &graph.edges {
        out.push_str(&format!("{e}\n"));
    }
    for &w in &graph.weights {
        out.push_str(&format!("{w}\n"));
    }
    vec![(format!("{base}.adj"), out.into_bytes())]
}

pub fn load(
    store: &SimStore,
    base: &str,
    ctx: ReadCtx,
    accounts: &[IoAccount],
) -> Result<CsrGraph> {
    let name = format!("{base}.adj");
    let file = store.open(&name).with_context(|| format!("missing {name}"))?;
    let len = file.len();
    let threads = accounts.len().max(1);

    // Parallel ranged read of the whole file (text must be tokenized before
    // we know where sections start, but the I/O itself is parallel).
    let chunks: Vec<Vec<u8>> = parallel_map(threads, threads, |i| {
        let (s, e) = chunk_range(len as usize, threads, i);
        file.read(s as u64, (e - s) as u64, ctx, &accounts[i])
    });
    let mut bytes = Vec::with_capacity(len as usize);
    for c in &chunks {
        bytes.extend_from_slice(c);
    }

    // Header.
    let mut lines = bytes.split(|&b| b == b'\n');
    let header = lines.next().context("empty file")?;
    let weighted = match header {
        b"AdjacencyGraph" => false,
        b"WeightedAdjacencyGraph" => true,
        h => bail!("bad header {:?}", String::from_utf8_lossy(h)),
    };
    let n: usize = parse_num(lines.next().context("missing n")?)? as usize;
    let m: usize = parse_num(lines.next().context("missing m")?)? as usize;

    // Find byte offsets of each numeric section so the parse can go
    // chunk-parallel: index the start of every line once (cheap single scan,
    // charged as CPU), then parse ranges in parallel.
    let header_len = header.len() + 1;
    let body = &bytes[header_len..];
    let line_starts: Vec<usize> = accounts[0].time_cpu(|| {
        let mut starts = vec![0usize];
        for (i, &b) in body.iter().enumerate() {
            if b == b'\n' && i + 1 < body.len() {
                starts.push(i + 1);
            }
        }
        starts
    });
    let expected = 2 + n + m + if weighted { m } else { 0 };
    if line_starts.len() < expected {
        bail!("truncated file: {} lines, expected {expected}", line_starts.len());
    }
    let line_at = |idx: usize| -> &[u8] {
        let s = line_starts[idx];
        let e = body[s..]
            .iter()
            .position(|&b| b == b'\n')
            .map(|p| s + p)
            .unwrap_or(body.len());
        &body[s..e]
    };

    // Parse offsets (lines 2..2+n) and edges (2+n..2+n+m) in parallel.
    let offsets: Vec<u64> = {
        let per: Vec<Vec<u64>> = parallel_map(threads, threads, |t| {
            let (s, e) = chunk_range(n, threads, t);
            accounts[t].time_cpu(|| {
                (s..e).map(|i| parse_num(line_at(2 + i)).unwrap_or(u64::MAX)).collect()
            })
        });
        per.into_iter().flatten().collect()
    };
    let edges: Vec<VertexId> = {
        let per: Vec<Vec<VertexId>> = parallel_map(threads, threads, |t| {
            let (s, e) = chunk_range(m, threads, t);
            accounts[t].time_cpu(|| {
                (s..e)
                    .map(|i| parse_num(line_at(2 + n + i)).unwrap_or(u64::MAX) as VertexId)
                    .collect()
            })
        });
        per.into_iter().flatten().collect()
    };
    let weights: Vec<f32> = if weighted {
        let per: Vec<Vec<f32>> = parallel_map(threads, threads, |t| {
            let (s, e) = chunk_range(m, threads, t);
            accounts[t].time_cpu(|| {
                (s..e)
                    .map(|i| {
                        std::str::from_utf8(line_at(2 + n + m + i))
                            .ok()
                            .and_then(|s| s.trim().parse::<f32>().ok())
                            .unwrap_or(f32::NAN)
                    })
                    .collect()
            })
        });
        per.into_iter().flatten().collect()
    } else {
        Vec::new()
    };

    if offsets.iter().any(|&o| o == u64::MAX) {
        bail!("bad offset line");
    }
    let mut full_offsets = offsets;
    full_offsets.push(m as u64);
    let g = CsrGraph { offsets: full_offsets, edges, weights };
    g.validate().map_err(|e| anyhow::anyhow!("invalid CSX: {e}"))?;
    Ok(g)
}

fn parse_num(line: &[u8]) -> Result<u64> {
    let mut v: u64 = 0;
    let mut any = false;
    for &b in line {
        if b == b'\r' {
            continue;
        }
        if !b.is_ascii_digit() {
            bail!("bad digit in {:?}", String::from_utf8_lossy(line));
        }
        v = v * 10 + (b - b'0') as u64;
        any = true;
    }
    if !any {
        bail!("empty numeric line");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::storage::DeviceKind;

    fn accounts(n: usize) -> Vec<IoAccount> {
        (0..n).map(|_| IoAccount::new()).collect()
    }

    #[test]
    fn roundtrip() {
        let g = generators::barabasi_albert(400, 3, 1);
        let store = SimStore::new(DeviceKind::Dram);
        for (name, data) in serialize(&g, "g") {
            store.put(&name, data);
        }
        for t in [1usize, 2, 5] {
            let loaded = load(&store, "g", ReadCtx::default(), &accounts(t)).unwrap();
            assert_eq!(loaded, g);
        }
    }

    #[test]
    fn weighted_roundtrip() {
        let g = CsrGraph::from_weighted_edges(3, &[(0, 1, 0.5), (2, 0, 4.0)]);
        let store = SimStore::new(DeviceKind::Dram);
        for (name, data) in serialize(&g, "w") {
            store.put(&name, data);
        }
        let loaded = load(&store, "w", ReadCtx::default(), &accounts(2)).unwrap();
        assert_eq!(loaded, g);
    }

    #[test]
    fn truncated_is_error() {
        let store = SimStore::new(DeviceKind::Dram);
        store.put("t.adj", b"AdjacencyGraph\n3\n5\n0\n1\n".to_vec());
        assert!(load(&store, "t", ReadCtx::default(), &accounts(1)).is_err());
    }

    #[test]
    fn bad_header_is_error() {
        let store = SimStore::new(DeviceKind::Dram);
        store.put("h.adj", b"NotAGraph\n1\n0\n0\n".to_vec());
        assert!(load(&store, "h", ReadCtx::default(), &accounts(1)).is_err());
    }
}
