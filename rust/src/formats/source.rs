//! [`GraphSource`]: the uniform loading interface the rest of the system
//! programs against.
//!
//! The paper's selective-loading claim (§4.1) is that *any* granularity of
//! request — a whole graph, a vertex range, a single vertex's neighbor
//! list — can be served without decoding the stream prefix. This trait
//! makes that contract explicit and lets algorithms run unchanged over:
//!
//! * the WebGraph decoder ([`WebGraphSource`]) — compressed, random-access,
//!   with a [`DecodedCache`] so hot vertices skip re-decompression;
//! * an in-memory [`CsrGraph`] (every baseline CSX/COO loader produces
//!   one) — the oracle implementation;
//! * an opened coordinator handle
//!   ([`PgGraph`](crate::coordinator::PgGraph)) — random access, block
//!   streaming, and pull-based partitioned requests
//!   ([`PgGraph::get_partitions`](crate::coordinator::PgGraph::get_partitions),
//!   which serves [`PartitionPlan`](crate::partition::PartitionPlan)s as
//!   multi-consumer streams) over the same graph.
//!
//! `successors(v)` resolves bounded reference chains exactly like the
//! webgraph-rs random-access reader: seek to the vertex's bit offset via
//! the sidecar, decode, and recursively materialize at most
//! `max_ref_chain` referenced lists (bounded at compression time).

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::formats::webgraph::{self, DecodedBlock, Decoder, WgMeta, WgOffsets};
use crate::graph::{CsrGraph, VertexId};
use crate::storage::cache::{CacheCounters, CacheTag, DecodedCache};
use crate::storage::sim::ReadCtx;
use crate::storage::{IoAccount, SimStore};

/// A graph that can serve adjacency at any granularity.
///
/// Implementations must agree with each other: for every vertex `v`,
/// `successors(v)` equals the `v` row of `decode_range(lo, hi)` for any
/// range containing `v` (property-tested in `tests/`).
pub trait GraphSource {
    fn num_vertices(&self) -> usize;

    fn num_edges(&self) -> u64;

    /// Random access: the sorted successor list of one vertex.
    fn successors(&self, v: usize) -> Result<Vec<VertexId>>;

    /// Range access: vertices `[lo, hi)` as a CSR slice.
    fn decode_range(&self, lo: usize, hi: usize) -> Result<DecodedBlock>;
}

impl GraphSource for CsrGraph {
    fn num_vertices(&self) -> usize {
        CsrGraph::num_vertices(self)
    }

    fn num_edges(&self) -> u64 {
        CsrGraph::num_edges(self)
    }

    fn successors(&self, v: usize) -> Result<Vec<VertexId>> {
        if v >= CsrGraph::num_vertices(self) {
            bail!("vertex {v} out of range (n={})", CsrGraph::num_vertices(self));
        }
        Ok(self.neighbors(v as VertexId).to_vec())
    }

    fn decode_range(&self, lo: usize, hi: usize) -> Result<DecodedBlock> {
        let n = CsrGraph::num_vertices(self);
        if lo > hi || hi > n {
            bail!("bad vertex range {lo}..{hi} (n={n})");
        }
        let base = self.offsets[lo];
        Ok(DecodedBlock {
            first_vertex: lo,
            offsets: self.offsets[lo..=hi].iter().map(|o| o - base).collect(),
            edges: self.edges[base as usize..self.offsets[hi] as usize].to_vec(),
        })
    }
}

/// Cost of keeping a decoded block resident (cache capacity unit).
pub fn block_cost(b: &DecodedBlock) -> u64 {
    b.num_edges() + b.offsets.len() as u64
}

/// Shared random-access engine behind every cached `successors()`
/// implementation ([`WebGraphSource`] and the coordinator's `PgGraph`):
/// serve `v` from the block-aligned [`DecodedCache`], calling `decode` for
/// the aligned `[lo, hi)` range on a miss and parking the result.
pub fn cached_successors(
    cache: &DecodedCache<DecodedBlock>,
    block_vertices: usize,
    num_vertices: usize,
    v: usize,
    decode: impl FnOnce(usize, usize) -> Result<DecodedBlock>,
) -> Result<Vec<VertexId>> {
    cached_successors_tagged(cache, block_vertices, num_vertices, v, None, decode)
}

/// [`cached_successors`] with the lookup/insert billed to a per-tenant
/// [`CacheTag`] — the serve layer's quota-aware entry point: hits count on
/// the tenant's own counter and inserts are charged against its resident
/// quota.
pub fn cached_successors_tagged(
    cache: &DecodedCache<DecodedBlock>,
    block_vertices: usize,
    num_vertices: usize,
    v: usize,
    tag: Option<CacheTag>,
    decode: impl FnOnce(usize, usize) -> Result<DecodedBlock>,
) -> Result<Vec<VertexId>> {
    if v >= num_vertices {
        bail!("vertex {v} out of range (n={num_vertices})");
    }
    let block_vertices = block_vertices.max(1);
    let bid = (v / block_vertices) as u64;
    let block = match cache.get_tagged(bid, tag) {
        Some(b) => b,
        None => {
            let lo = bid as usize * block_vertices;
            let hi = (lo + block_vertices).min(num_vertices);
            let block = Arc::new(decode(lo, hi)?);
            cache.insert_tagged(bid, Arc::clone(&block), tag);
            block
        }
    };
    Ok(block.neighbors(v - block.first_vertex).to_vec())
}

/// Configuration of a [`WebGraphSource`].
#[derive(Debug, Clone, Copy)]
pub struct SourceConfig {
    /// Vertices per cached decode unit. Random access decodes the aligned
    /// block containing the requested vertex, so neighboring hot vertices
    /// share one decode; 1 degenerates to per-vertex decoding.
    pub block_vertices: usize,
    /// [`DecodedCache`] capacity in cost units (≈ edges); 0 disables
    /// caching (cold-decode baseline for benches).
    pub cache_cost: u64,
    /// Declared I/O pattern for the storage model.
    pub ctx: ReadCtx,
}

impl Default for SourceConfig {
    fn default() -> Self {
        Self { block_vertices: 64, cache_cost: 4 << 20, ctx: ReadCtx::default() }
    }
}

/// Random-access [`GraphSource`] over a WebGraph-serialized store entry,
/// backed by a decoded-block LRU cache.
pub struct WebGraphSource<'s> {
    store: &'s SimStore,
    base: String,
    meta: WgMeta,
    offsets: WgOffsets,
    ctx: ReadCtx,
    block_vertices: usize,
    cache: DecodedCache<DecodedBlock>,
    acct: IoAccount,
}

impl<'s> WebGraphSource<'s> {
    /// Open `base` in `store`: loads the metadata + offsets sidecar (the
    /// §5.6 sequential phase), after which every access is selective.
    pub fn open(store: &'s SimStore, base: &str, config: SourceConfig) -> Result<Self> {
        let acct = IoAccount::new();
        let meta = webgraph::read_meta(store, base, config.ctx, &acct)?;
        let offsets = webgraph::read_offsets(store, base, config.ctx, &acct)?;
        offsets.check_matches(&meta).with_context(|| base.to_string())?;
        Ok(Self {
            store,
            base: base.to_string(),
            meta,
            offsets,
            ctx: config.ctx,
            block_vertices: config.block_vertices.max(1),
            cache: DecodedCache::new(config.cache_cost, block_cost),
            acct,
        })
    }

    fn decoder(&self) -> Result<Decoder<'_>> {
        Decoder::open(self.store, &self.base, &self.meta, &self.offsets, self.ctx, &self.acct)
    }

    /// Decoded-block cache counters (hit/miss/eviction, resident cost).
    pub fn cache_counters(&self) -> CacheCounters {
        self.cache.counters()
    }

    /// Virtual-I/O + CPU account charged by this source's reads.
    pub fn io_account(&self) -> &IoAccount {
        &self.acct
    }

    /// Drop cached decoded blocks (counters survive).
    pub fn drop_decoded_cache(&self) {
        self.cache.clear();
    }
}

impl GraphSource for WebGraphSource<'_> {
    fn num_vertices(&self) -> usize {
        self.meta.num_vertices
    }

    fn num_edges(&self) -> u64 {
        self.meta.num_edges
    }

    fn successors(&self, v: usize) -> Result<Vec<VertexId>> {
        cached_successors(&self.cache, self.block_vertices, self.meta.num_vertices, v, |lo, hi| {
            self.decoder()?.decode_range(lo, hi, &self.acct)
        })
    }

    fn decode_range(&self, lo: usize, hi: usize) -> Result<DecodedBlock> {
        self.decoder()?.decode_range(lo, hi, &self.acct)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::storage::DeviceKind;

    fn store_with(g: &CsrGraph, base: &str) -> SimStore {
        let store = SimStore::new(DeviceKind::Dram);
        for (name, data) in webgraph::serialize(g, base) {
            store.put(&name, data);
        }
        store
    }

    #[test]
    fn csr_source_matches_inherent_accessors() {
        let g = generators::rmat(7, 6, 5);
        let src: &dyn GraphSource = &g;
        assert_eq!(src.num_vertices(), g.num_vertices());
        assert_eq!(src.num_edges(), g.num_edges());
        for v in [0usize, 1, 17, g.num_vertices() - 1] {
            assert_eq!(src.successors(v).unwrap(), g.neighbors(v as VertexId));
        }
        let block = src.decode_range(10, 30).unwrap();
        assert_eq!(block.num_vertices(), 20);
        for (i, v) in (10..30).enumerate() {
            assert_eq!(block.neighbors(i), g.neighbors(v as VertexId));
        }
        assert!(src.successors(g.num_vertices()).is_err());
        assert!(src.decode_range(5, 3).is_err());
    }

    #[test]
    fn webgraph_source_successors_match_graph() {
        let g = generators::barabasi_albert(800, 6, 17);
        let store = store_with(&g, "g");
        let src = WebGraphSource::open(&store, "g", SourceConfig::default()).unwrap();
        for v in 0..g.num_vertices() {
            assert_eq!(src.successors(v).unwrap(), g.neighbors(v as VertexId), "vertex {v}");
        }
        assert!(src.successors(g.num_vertices()).is_err());
    }

    #[test]
    fn repeated_access_hits_decoded_cache() {
        let g = generators::barabasi_albert(500, 5, 23);
        let store = store_with(&g, "g");
        let src = WebGraphSource::open(&store, "g", SourceConfig::default()).unwrap();
        let _ = src.successors(42).unwrap();
        let cold = src.cache_counters();
        assert_eq!(cold.hits, 0);
        assert_eq!(cold.misses, 1);
        for _ in 0..5 {
            let _ = src.successors(42).unwrap();
            let _ = src.successors(43).unwrap(); // same 64-vertex block
        }
        let warm = src.cache_counters();
        assert_eq!(warm.misses, 1, "block decoded exactly once");
        assert_eq!(warm.hits, 10);
    }

    #[test]
    fn zero_capacity_cache_always_decodes() {
        let g = generators::barabasi_albert(300, 4, 29);
        let store = store_with(&g, "g");
        let cfg = SourceConfig { cache_cost: 0, ..SourceConfig::default() };
        let src = WebGraphSource::open(&store, "g", cfg).unwrap();
        for _ in 0..3 {
            assert_eq!(src.successors(7).unwrap(), g.neighbors(7));
        }
        let c = src.cache_counters();
        assert_eq!(c.hits, 0);
        assert_eq!(c.misses, 3);
    }

    #[test]
    fn single_vertex_blocks_resolve_reference_chains() {
        // block_vertices = 1 forces per-vertex random access, so every
        // reference is resolved through the bounded-chain recursion.
        let g = generators::similarity_blocks(400, 40, 12, 3);
        let store = store_with(&g, "s");
        let cfg = SourceConfig { block_vertices: 1, ..SourceConfig::default() };
        let src = WebGraphSource::open(&store, "s", cfg).unwrap();
        for v in 0..g.num_vertices() {
            assert_eq!(src.successors(v).unwrap(), g.neighbors(v as VertexId), "vertex {v}");
        }
    }
}
