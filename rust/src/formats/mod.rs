//! On-storage graph formats.
//!
//! The paper compares four families (§2, Table 1):
//!
//! | format        | ~bits/edge | module      |
//! |---------------|-----------:|-------------|
//! | Textual COO   |       82.9 | [`txt_coo`] |
//! | Textual CSX   |       84.5 | [`txt_csx`] |
//! | Binary CSX    |       32.8 | [`bin_csx`] |
//! | WebGraph      |       13.2 | [`webgraph`]|
//!
//! The textual/binary loaders mirror GAPBS's readers (the baseline
//! framework): chunked two-pass parallel text parsing, ranged parallel
//! binary reads. The [`webgraph`] module is our Rust implementation of a
//! WebGraph-style compressed format (γ/δ/ζ codes, reference compression,
//! intervals, residual gaps) with a binary offsets sidecar enabling random
//! access — the property ParaGrapher's selective loading builds on.
//!
//! The [`source`] module abstracts over all of them: [`GraphSource`] serves
//! both per-vertex random access (`successors`) and range decoding
//! (`decode_range`) from any backing format.

pub mod bin_csx;
pub mod matrix_market;
pub mod metis;
pub mod source;
pub mod txt_coo;
pub mod txt_csx;
pub mod webgraph;

pub use source::{GraphSource, SourceConfig, WebGraphSource};

use crate::graph::CsrGraph;
use crate::storage::sim::ReadCtx;
use crate::storage::{IoAccount, SimStore};

/// The format families of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FormatKind {
    TxtCoo,
    TxtCsx,
    BinCsx,
    WebGraph,
}

impl FormatKind {
    pub const ALL: [FormatKind; 4] =
        [FormatKind::TxtCoo, FormatKind::TxtCsx, FormatKind::BinCsx, FormatKind::WebGraph];

    pub fn name(&self) -> &'static str {
        match self {
            FormatKind::TxtCoo => "Txt. COO",
            FormatKind::TxtCsx => "Txt. CSX",
            FormatKind::BinCsx => "Bin. CSX",
            FormatKind::WebGraph => "WebGraph",
        }
    }

    pub fn parse(s: &str) -> Option<FormatKind> {
        match s.to_ascii_lowercase().replace(['.', ' ', '-'], "").as_str() {
            "txtcoo" | "coo" => Some(FormatKind::TxtCoo),
            "txtcsx" | "csx" => Some(FormatKind::TxtCsx),
            "bincsx" | "bin" | "binary" => Some(FormatKind::BinCsx),
            "webgraph" | "wg" => Some(FormatKind::WebGraph),
            _ => None,
        }
    }

    /// Serialize `graph` into the store under `base` (one or more files).
    /// Returns total bytes written.
    pub fn write_to_store(&self, graph: &CsrGraph, store: &SimStore, base: &str) -> u64 {
        let files = match self {
            FormatKind::TxtCoo => txt_coo::serialize(graph, base),
            FormatKind::TxtCsx => txt_csx::serialize(graph, base),
            FormatKind::BinCsx => bin_csx::serialize(graph, base),
            FormatKind::WebGraph => webgraph::serialize(graph, base),
        };
        let mut total = 0;
        for (name, data) in files {
            total += data.len() as u64;
            store.put(&name, data);
        }
        total
    }

    /// Total on-storage bytes of the format's files for `base`.
    pub fn stored_bytes(&self, store: &SimStore, base: &str) -> u64 {
        self.file_names(base)
            .iter()
            .filter_map(|n| store.file_len(n))
            .sum()
    }

    /// Names of the files this format stores under `base`.
    pub fn file_names(&self, base: &str) -> Vec<String> {
        match self {
            FormatKind::TxtCoo => vec![format!("{base}.el")],
            FormatKind::TxtCsx => vec![format!("{base}.adj")],
            FormatKind::BinCsx => vec![format!("{base}.bcsx")],
            FormatKind::WebGraph => vec![
                format!("{base}.graph"),
                format!("{base}.offsets"),
                format!("{base}.properties"),
            ],
        }
    }

    /// Full (whole-graph) parallel load, GAPBS-style for the baselines and
    /// through the decoder for WebGraph. Charges per-worker accounts.
    pub fn load_full(
        &self,
        store: &SimStore,
        base: &str,
        ctx: ReadCtx,
        accounts: &[IoAccount],
    ) -> anyhow::Result<CsrGraph> {
        match self {
            FormatKind::TxtCoo => txt_coo::load(store, base, ctx, accounts),
            FormatKind::TxtCsx => txt_csx::load(store, base, ctx, accounts),
            FormatKind::BinCsx => bin_csx::load(store, base, ctx, accounts),
            FormatKind::WebGraph => webgraph::load_full(store, base, ctx, accounts),
        }
    }

    /// Bits per edge of this serialization for `graph` (Table 1).
    pub fn bits_per_edge(&self, graph: &CsrGraph, store: &SimStore, base: &str) -> f64 {
        let bytes = self.stored_bytes(store, base);
        bytes as f64 * 8.0 / graph.num_edges().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::storage::DeviceKind;

    #[test]
    fn parse_aliases() {
        assert_eq!(FormatKind::parse("Txt. COO"), Some(FormatKind::TxtCoo));
        assert_eq!(FormatKind::parse("webgraph"), Some(FormatKind::WebGraph));
        assert_eq!(FormatKind::parse("bin-csx"), Some(FormatKind::BinCsx));
        assert_eq!(FormatKind::parse("???"), None);
    }

    #[test]
    fn all_formats_roundtrip_same_graph() {
        let g = generators::rmat(8, 8, 3);
        let store = SimStore::new(DeviceKind::Dram);
        let accounts: Vec<IoAccount> = (0..4).map(|_| IoAccount::new()).collect();
        for fk in FormatKind::ALL {
            let base = format!("g-{}", fk.name());
            let written = fk.write_to_store(&g, &store, &base);
            assert!(written > 0);
            assert_eq!(fk.stored_bytes(&store, &base), written);
            let loaded = fk.load_full(&store, &base, ReadCtx::default(), &accounts).unwrap();
            assert_eq!(loaded, g, "{} must round-trip", fk.name());
        }
    }

    #[test]
    fn compression_ordering_matches_table1() {
        // WebGraph < Binary CSX < textual formats, like Table 1.
        let g = generators::barabasi_albert(3000, 8, 9);
        let store = SimStore::new(DeviceKind::Dram);
        let mut bpe = std::collections::HashMap::new();
        for fk in FormatKind::ALL {
            let base = format!("t1-{}", fk.name());
            fk.write_to_store(&g, &store, &base);
            bpe.insert(fk, fk.bits_per_edge(&g, &store, &base));
        }
        assert!(bpe[&FormatKind::WebGraph] < bpe[&FormatKind::BinCsx]);
        assert!(bpe[&FormatKind::BinCsx] < bpe[&FormatKind::TxtCoo]);
        assert!(bpe[&FormatKind::BinCsx] < bpe[&FormatKind::TxtCsx]);
    }
}
