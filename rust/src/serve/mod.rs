//! Multi-tenant serving front-end: one [`GraphServer`] multiplexes many
//! tenants over many open graphs.
//!
//! The paper frames ParaGrapher as a *library* one analytics process links
//! against; this module answers the operational question that framing
//! leaves open — what happens when the same loaded graphs serve many
//! independent clients at once ("millions of users", ROADMAP). Three
//! mechanisms, layered on the existing coordinator contracts:
//!
//! * **Admission control** ([`admission`]) — every request names a tenant;
//!   each tenant owns a bounded FIFO queue drained by deficit round-robin
//!   over *work units* (estimated edges touched), so an abusive tenant
//!   flooding cheap requests cannot starve a well-behaved one issuing
//!   large scans. A submit that would overflow the tenant's queue is shed
//!   with a typed [`PgError::Overloaded`] whose `retry_after` comes from
//!   the §3 load model: the current queued backlog in uncompressed bytes
//!   divided by the graph's modeled load bandwidth — the honest "come
//!   back when the backlog could have drained" answer, not a magic
//!   constant. Requests carry deadlines; one that expires while queued is
//!   cancelled with [`PgError::Expired`] and *billed* to the tenant's
//!   latency histogram — an overloaded server must not look fast.
//! * **Per-tenant accounting** — each tenant gets
//!   `serve.tenant.<name>.{admitted,shed,completed,expired,failed}`
//!   counters and an end-to-end latency histogram in the server's
//!   registry, plus a per-graph [`CacheTag`] so decoded-cache hits and
//!   evictions are attributed (`cache.decoded.{hits,evictions}.<name>`)
//!   and the tenant's resident cache footprint is capped by its quota
//!   (the cache evicts the over-quota tenant's own LRU entries first).
//! * **Graceful churn** — [`GraphServer::close`] removes a graph while
//!   traffic is in flight: its buffer pool closes, which poisons that
//!   graph's partition streams into typed [`PgError::Closed`] failures
//!   (never hangs), queued requests against it fail typed at dispatch,
//!   and *other* graphs' tenants are untouched. [`GraphServer::reopen`]
//!   replays the recorded open spec under a fresh epoch; requests
//!   admitted against the old epoch fail typed rather than silently
//!   landing on a different incarnation.
//!
//! Dispatch is asynchronous: `submit` returns a [`Ticket`] immediately;
//! a dispatcher thread sweeps deadlines and feeds a fixed executor pool.
//! Executors re-check the deadline and re-resolve the graph by
//! (name, epoch) at execution time, and a panic in an executor settles
//! the ticket with `Closed` instead of leaving a waiter hung (the pool
//! catches the unwind; the settle guard runs during it).

pub mod admission;
pub mod stress;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::{
    lock_clean, lock_recover, BlockCallback, GraphType, Options, Paragrapher, PgError, PgGraph,
    VertexRange,
};
use crate::graph::VertexId;
use crate::obs::{names, HistSnapshot, MetricsRegistry, MetricsSnapshot};
use crate::storage::cache::CacheTag;
use crate::storage::{DeviceKind, SimStore};
use crate::util::pool::ThreadPool;

pub use admission::{TenantQuotas, TenantStats};
use admission::{drr_pick, Queued, TenantState};

/// Server-wide knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServerOptions {
    /// Executor threads shared by every tenant (per-tenant concurrency is
    /// bounded separately by [`TenantQuotas::max_in_flight`]).
    pub exec_workers: usize,
    /// Deadline applied when `submit` is called without one.
    pub default_deadline: Duration,
    /// How often the dispatcher wakes to sweep expired requests when no
    /// work is pending.
    pub sweep_interval: Duration,
}

impl Default for ServerOptions {
    fn default() -> Self {
        Self {
            exec_workers: 4,
            default_deadline: Duration::from_secs(30),
            sweep_interval: Duration::from_millis(5),
        }
    }
}

/// One request against a named open graph.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeRequest {
    /// Random access: one vertex's successor list.
    Successors { vertex: usize },
    /// Vertex-range subgraph (blocking CSX path); replies with the edge
    /// count it decoded.
    CsxRange { lo: usize, hi: usize },
    /// Edge-range request (COO path); replies with edges delivered.
    CooRange { lo_edge: u64, hi_edge: u64 },
    /// Full partitioned drain with `parts` partitions; replies with the
    /// total edge count streamed.
    Partitions { parts: usize },
}

/// A completed request's payload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeReply {
    Successors(Vec<VertexId>),
    /// Edges decoded/streamed by a range or partition request.
    Edges(u64),
}

enum TicketSlot {
    Pending,
    Done(Result<ServeReply>),
    Taken,
}

struct TicketInner {
    slot: Mutex<TicketSlot>,
    cv: Condvar,
}

impl TicketInner {
    /// First completion wins; later calls (e.g. the panic guard after a
    /// normal settle) are no-ops.
    fn complete(&self, result: Result<ServeReply>) {
        let mut s = lock_recover(&self.slot);
        if matches!(*s, TicketSlot::Pending) {
            *s = TicketSlot::Done(result);
            self.cv.notify_all();
        }
    }
}

/// Handle to one submitted request. The result is single-consumer:
/// [`wait`](Ticket::wait) takes it, a second wait reports `Closed`.
pub struct Ticket {
    inner: Arc<TicketInner>,
}

impl Ticket {
    fn new() -> Self {
        Self {
            inner: Arc::new(TicketInner {
                slot: Mutex::new(TicketSlot::Pending),
                cv: Condvar::new(),
            }),
        }
    }

    /// Block until the request settles (completion, expiry, or failure —
    /// every admitted request settles; see the dispatcher contract).
    pub fn wait(&self) -> Result<ServeReply> {
        let mut s = lock_recover(&self.inner.slot);
        loop {
            match std::mem::replace(&mut *s, TicketSlot::Taken) {
                TicketSlot::Done(r) => return r,
                TicketSlot::Taken => {
                    return Err(PgError::Closed("ticket result already taken".into()).into());
                }
                TicketSlot::Pending => {
                    *s = TicketSlot::Pending;
                    s = self
                        .inner
                        .cv
                        .wait(s)
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                }
            }
        }
    }

    /// Like [`wait`](Self::wait) but gives up after `timeout`, leaving the
    /// ticket pending. `None` = still pending.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Result<ServeReply>> {
        let deadline = Instant::now() + timeout;
        let mut s = lock_recover(&self.inner.slot);
        loop {
            match std::mem::replace(&mut *s, TicketSlot::Taken) {
                TicketSlot::Done(r) => return Some(r),
                TicketSlot::Taken => {
                    return Some(Err(PgError::Closed("ticket result already taken".into()).into()));
                }
                TicketSlot::Pending => {
                    *s = TicketSlot::Pending;
                    let now = Instant::now();
                    if now >= deadline {
                        return None;
                    }
                    let (g, _) = self
                        .inner
                        .cv
                        .wait_timeout(s, deadline - now)
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                    s = g;
                }
            }
        }
    }

    /// Has the request settled (result still available to take)?
    pub fn is_done(&self) -> bool {
        matches!(*lock_recover(&self.inner.slot), TicketSlot::Done(_))
    }
}

/// Everything needed to re-execute an open (the [`GraphServer::reopen`]
/// churn path).
#[derive(Clone)]
enum OpenSpec {
    Store { store: Arc<SimStore>, base: String, gtype: GraphType, options: Options },
    Dir { dir: PathBuf, device: DeviceKind, base: String, gtype: GraphType, options: Options },
}

struct GraphEntry {
    graph: Arc<PgGraph>,
    /// Bumped on every (re)open; queued requests carry the epoch they were
    /// admitted against and fail typed if it no longer matches.
    epoch: u64,
    spec: OpenSpec,
    /// Per-tenant cache tags, indexed by tenant slot.
    tags: Vec<Option<CacheTag>>,
}

struct ServeJob {
    graph: String,
    epoch: u64,
    req: ServeRequest,
    ticket: Arc<TicketInner>,
}

struct ServerState {
    tenants: Vec<TenantState<ServeJob>>,
    names: HashMap<String, usize>,
    graphs: HashMap<String, GraphEntry>,
    /// DRR rotation position + whether its tenant received its arrival
    /// top-up (see [`admission::drr_pick`]).
    cursor: usize,
    topped: bool,
    epoch: u64,
}

struct ServerInner {
    state: Mutex<ServerState>,
    /// Signalled on submit, completion, churn, and shutdown.
    work: Condvar,
    metrics: Arc<MetricsRegistry>,
    opts: ServerOptions,
    shutdown: AtomicBool,
}

/// The multi-tenant serving front-end. See the module docs for the model.
pub struct GraphServer {
    inner: Arc<ServerInner>,
    exec: Option<Arc<ThreadPool>>,
    dispatcher: Option<JoinHandle<()>>,
}

impl GraphServer {
    pub fn new(opts: ServerOptions) -> Self {
        let inner = Arc::new(ServerInner {
            state: Mutex::new(ServerState {
                tenants: Vec::new(),
                names: HashMap::new(),
                graphs: HashMap::new(),
                cursor: 0,
                topped: false,
                epoch: 0,
            }),
            work: Condvar::new(),
            metrics: Arc::new(MetricsRegistry::new()),
            opts,
            shutdown: AtomicBool::new(false),
        });
        let exec = Arc::new(ThreadPool::new(opts.exec_workers.max(1)));
        let dispatcher = {
            let inner = Arc::clone(&inner);
            let exec = Arc::clone(&exec);
            std::thread::Builder::new()
                .name("pg-serve-dispatch".into())
                .spawn(move || dispatcher_loop(inner, exec))
                .expect("spawn serve dispatcher")
        };
        Self { inner, exec: Some(exec), dispatcher: Some(dispatcher) }
    }

    /// Register tenant `name` (or update its quotas if already known).
    /// Resolves the tenant's serve counters in the server registry and a
    /// cache tag on every open graph; graphs opened later pick the tenant
    /// up at open time.
    pub fn register_tenant(&self, name: &str, quotas: TenantQuotas) -> Result<()> {
        let metrics = Arc::clone(&self.inner.metrics);
        let mut st = lock_recover(&self.inner.state);
        let st = &mut *st;
        if let Some(&slot) = st.names.get(name) {
            st.tenants[slot].quotas = quotas;
            for e in st.graphs.values_mut() {
                let tag = e.graph.register_cache_tenant(name, quotas.cache_quota_cost);
                if e.tags.len() <= slot {
                    e.tags.resize(slot + 1, None);
                }
                e.tags[slot] = Some(tag);
            }
            return Ok(());
        }
        let slot = st.tenants.len();
        st.tenants.push(TenantState {
            name: name.to_string(),
            quotas,
            queue: std::collections::VecDeque::new(),
            deficit: 0,
            in_flight: 0,
            queued_bytes: 0,
            admitted: metrics.counter(&names::serve_tenant_admitted(name)),
            shed: metrics.counter(&names::serve_tenant_shed(name)),
            completed: metrics.counter(&names::serve_tenant_completed(name)),
            expired: metrics.counter(&names::serve_tenant_expired(name)),
            failed: metrics.counter(&names::serve_tenant_failed(name)),
            lat: metrics.histogram(&names::serve_tenant_lat(name)),
        });
        st.names.insert(name.to_string(), slot);
        for e in st.graphs.values_mut() {
            let tag = e.graph.register_cache_tenant(name, quotas.cache_quota_cost);
            if e.tags.len() <= slot {
                e.tags.resize(slot + 1, None);
            }
            e.tags[slot] = Some(tag);
        }
        Ok(())
    }

    /// Open `base` from `store` as graph `name`.
    pub fn open_store(
        &self,
        name: &str,
        store: Arc<SimStore>,
        base: &str,
        gtype: GraphType,
        options: Options,
    ) -> Result<()> {
        let graph =
            Paragrapher::init().open_graph(Arc::clone(&store), base, gtype, options.clone())?;
        self.install(
            name,
            graph,
            OpenSpec::Store { store, base: base.to_string(), gtype, options },
        )
    }

    /// Open `base` from an on-disk directory as graph `name`.
    pub fn open_dir(
        &self,
        name: &str,
        dir: &Path,
        device: DeviceKind,
        base: &str,
        gtype: GraphType,
        options: Options,
    ) -> Result<()> {
        let graph =
            Paragrapher::init().open_graph_from_dir(dir, device, base, gtype, options.clone())?;
        self.install(
            name,
            graph,
            OpenSpec::Dir {
                dir: dir.to_path_buf(),
                device,
                base: base.to_string(),
                gtype,
                options,
            },
        )
    }

    fn install(&self, name: &str, graph: PgGraph, spec: OpenSpec) -> Result<()> {
        let graph = Arc::new(graph);
        let mut st = lock_recover(&self.inner.state);
        if st.graphs.contains_key(name) {
            drop(st);
            // Don't leak the freshly opened graph's threads.
            graph.shutdown_and_join();
            bail!("graph '{name}' is already open");
        }
        let tags = st
            .tenants
            .iter()
            .map(|t| Some(graph.register_cache_tenant(&t.name, t.quotas.cache_quota_cost)))
            .collect();
        st.epoch += 1;
        let epoch = st.epoch;
        st.graphs.insert(name.to_string(), GraphEntry { graph, epoch, spec, tags });
        drop(st);
        self.inner.work.notify_all();
        Ok(())
    }

    /// Close graph `name` with traffic possibly in flight: the entry is
    /// unlinked first, then the graph's threads are joined *outside* the
    /// state lock (executors settling requests need that lock). Closing
    /// the buffer pool poisons the graph's in-flight partition streams
    /// into typed [`PgError::Closed`]; still-queued requests against it
    /// fail typed at dispatch. Other graphs are unaffected.
    pub fn close(&self, name: &str) -> Result<()> {
        let entry = {
            let mut st = lock_recover(&self.inner.state);
            st.graphs.remove(name).with_context(|| format!("graph '{name}' is not open"))?
        };
        entry.graph.shutdown_and_join();
        self.inner.work.notify_all();
        Ok(())
    }

    /// Close and re-open graph `name` from its recorded open spec, under a
    /// fresh epoch. Requests admitted against the old epoch fail typed.
    pub fn reopen(&self, name: &str) -> Result<()> {
        let spec = {
            let st = lock_recover(&self.inner.state);
            st.graphs
                .get(name)
                .with_context(|| format!("graph '{name}' is not open"))?
                .spec
                .clone()
        };
        self.close(name)?;
        match spec {
            OpenSpec::Store { store, base, gtype, options } => {
                self.open_store(name, store, &base, gtype, options)
            }
            OpenSpec::Dir { dir, device, base, gtype, options } => {
                self.open_dir(name, &dir, device, &base, gtype, options)
            }
        }
    }

    /// The live handle for graph `name` (e.g. to install a fault plan on
    /// its store, or to drive partition streams directly in tests).
    pub fn graph(&self, name: &str) -> Option<Arc<PgGraph>> {
        let st = lock_recover(&self.inner.state);
        st.graphs.get(name).map(|e| Arc::clone(&e.graph))
    }

    /// Names of currently open graphs.
    pub fn graph_names(&self) -> Vec<String> {
        let st = lock_recover(&self.inner.state);
        st.graphs.keys().cloned().collect()
    }

    /// Submit with the server's default deadline.
    pub fn submit(&self, tenant: &str, graph: &str, req: ServeRequest) -> Result<Ticket> {
        self.submit_with_deadline(tenant, graph, req, self.inner.opts.default_deadline)
    }

    /// Admit one request, or shed it. Sheds are typed: a full tenant queue
    /// returns [`PgError::Overloaded`] with `retry_after` = the §3 model's
    /// minimum time to drain the currently queued bytes; an unknown graph
    /// or a shut-down server returns [`PgError::Closed`].
    pub fn submit_with_deadline(
        &self,
        tenant: &str,
        graph: &str,
        req: ServeRequest,
        deadline: Duration,
    ) -> Result<Ticket> {
        let mut st = lock_clean(&self.inner.state, "server state")?;
        if self.inner.shutdown.load(Ordering::Acquire) {
            return Err(PgError::Closed("server is shutting down".into()).into());
        }
        let slot = match st.names.get(tenant) {
            Some(&s) => s,
            None => bail!("unknown tenant '{tenant}'"),
        };
        let (cost, bytes, epoch, model) = match st.graphs.get(graph) {
            Some(e) => {
                let (c, b) = estimate_cost(&e.graph, &req);
                (c, b, e.epoch, e.graph.load_model())
            }
            None => return Err(PgError::Closed(format!("graph '{graph}' is not open")).into()),
        };
        if st.tenants[slot].queue.len() >= st.tenants[slot].quotas.max_queue {
            let backlog: u64 =
                st.tenants.iter().map(|t| t.queued_bytes).sum::<u64>().saturating_add(bytes);
            st.tenants[slot].shed.inc();
            let secs = model.min_load_seconds(backlog).clamp(1e-3, 600.0);
            return Err(PgError::Overloaded { retry_after: Duration::from_secs_f64(secs) }.into());
        }
        let now = Instant::now();
        let ticket = Ticket::new();
        let t = &mut st.tenants[slot];
        t.queue.push_back(Queued {
            job: ServeJob {
                graph: graph.to_string(),
                epoch,
                req,
                ticket: Arc::clone(&ticket.inner),
            },
            cost,
            bytes,
            enqueued: now,
            deadline: now + deadline,
        });
        t.queued_bytes += bytes;
        t.admitted.inc();
        drop(st);
        self.inner.work.notify_all();
        Ok(ticket)
    }

    /// Convenience: submit and block for the reply.
    pub fn call(&self, tenant: &str, graph: &str, req: ServeRequest) -> Result<ServeReply> {
        self.submit(tenant, graph, req)?.wait()
    }

    /// Point-in-time serving counters for one tenant.
    pub fn tenant_stats(&self, name: &str) -> Option<TenantStats> {
        let st = lock_recover(&self.inner.state);
        st.names.get(name).map(|&s| st.tenants[s].stats())
    }

    /// Snapshot of one tenant's end-to-end latency histogram.
    pub fn tenant_latency(&self, name: &str) -> Option<HistSnapshot> {
        let st = lock_recover(&self.inner.state);
        st.names.get(name).map(|&s| st.tenants[s].lat.snapshot())
    }

    /// The server's metrics registry (`serve.tenant.*`).
    pub fn metrics_registry(&self) -> &Arc<MetricsRegistry> {
        &self.inner.metrics
    }

    /// Snapshot of every server metric.
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.inner.metrics.snapshot()
    }

    /// Stop admitting, fail everything still queued with typed `Closed`,
    /// join the dispatcher and executors (in-flight requests settle
    /// first), then close every graph. Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        if self.dispatcher.is_none() && self.exec.is_none() {
            return;
        }
        self.inner.shutdown.store(true, Ordering::Release);
        self.inner.work.notify_all();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
        // The dispatcher held the only other pool handle and has been
        // joined, so this drop is the last reference: it closes the queue
        // and joins the executor workers, letting in-flight requests
        // settle before their graphs go away below.
        drop(self.exec.take());
        let entries: Vec<GraphEntry> = {
            let mut st = lock_recover(&self.inner.state);
            st.graphs.drain().map(|(_, e)| e).collect()
        };
        for e in entries {
            e.graph.shutdown_and_join();
        }
    }
}

impl Drop for GraphServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Work-unit + byte estimate for admission: edges the request will touch
/// (DRR cost) and the uncompressed bytes it will move (§3 backlog unit).
/// Estimates only — degree skew is invisible before decoding — but
/// monotone in request size, which is all fairness needs.
fn estimate_cost(graph: &PgGraph, req: &ServeRequest) -> (u64, u64) {
    let n = graph.num_vertices().max(1) as u64;
    let m = graph.num_edges();
    let deg = (m / n).max(1);
    let edges = match req {
        ServeRequest::Successors { .. } => deg,
        ServeRequest::CsxRange { lo, hi } => (hi.saturating_sub(*lo) as u64).saturating_mul(deg),
        ServeRequest::CooRange { lo_edge, hi_edge } => hi_edge.saturating_sub(*lo_edge),
        ServeRequest::Partitions { .. } => m,
    }
    .max(1);
    (edges, edges.saturating_mul(8))
}

fn dispatcher_loop(inner: Arc<ServerInner>, exec: Arc<ThreadPool>) {
    loop {
        let shutting_down = inner.shutdown.load(Ordering::Acquire);
        let mut to_expire: Vec<(ServeJob, Duration)> = Vec::new();
        let mut to_abort: Vec<ServeJob> = Vec::new();
        let mut pick = None;
        {
            let mut st = lock_recover(&inner.state);
            let now = Instant::now();
            for t in st.tenants.iter_mut() {
                for (job, waited) in t.sweep_expired(now) {
                    t.expired.inc();
                    t.lat.record_duration(waited);
                    to_expire.push((job, waited));
                }
            }
            if shutting_down {
                for t in st.tenants.iter_mut() {
                    while let Some(q) = t.queue.pop_front() {
                        t.queued_bytes = t.queued_bytes.saturating_sub(q.bytes);
                        t.failed.inc();
                        t.lat.record_duration(now.saturating_duration_since(q.enqueued));
                        to_abort.push(q.job);
                    }
                }
            } else {
                let s = &mut *st;
                pick = drr_pick(&mut s.tenants, &mut s.cursor, &mut s.topped);
                if pick.is_none() && to_expire.is_empty() {
                    let _ = inner
                        .work
                        .wait_timeout(st, inner.opts.sweep_interval)
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                }
            }
        }
        for (job, waited) in to_expire {
            job.ticket.complete(Err(PgError::Expired { waited }.into()));
        }
        for job in to_abort {
            let e = PgError::Closed("server shut down with request queued".into());
            job.ticket.complete(Err(e.into()));
        }
        if let Some((slot, q)) = pick {
            let inner = Arc::clone(&inner);
            exec.execute(move || execute_job(inner, slot, q));
        }
        if shutting_down {
            return;
        }
    }
}

/// Bills the tenant and settles the ticket exactly once — including when
/// the executor panics (the drop arm fires during the pool's
/// catch-unwind), so a `Ticket::wait` never hangs on a dead request.
struct SettleGuard {
    inner: Arc<ServerInner>,
    slot: usize,
    ticket: Arc<TicketInner>,
    enqueued: Instant,
    armed: bool,
}

impl SettleGuard {
    fn settle(mut self, result: Result<ServeReply>) {
        self.armed = false;
        settle(&self.inner, self.slot, &self.ticket, self.enqueued, result);
    }
}

impl Drop for SettleGuard {
    fn drop(&mut self) {
        if self.armed {
            settle(
                &self.inner,
                self.slot,
                &self.ticket,
                self.enqueued,
                Err(PgError::Closed("request executor panicked".into()).into()),
            );
        }
    }
}

fn settle(
    inner: &ServerInner,
    slot: usize,
    ticket: &TicketInner,
    enqueued: Instant,
    result: Result<ServeReply>,
) {
    let expired = matches!(
        result.as_ref().err().and_then(|e| e.downcast_ref::<PgError>()),
        Some(PgError::Expired { .. })
    );
    {
        let mut st = lock_recover(&inner.state);
        let t = &mut st.tenants[slot];
        t.lat.record_duration(enqueued.elapsed());
        match &result {
            Ok(_) => t.completed.inc(),
            Err(_) if expired => t.expired.inc(),
            Err(_) => t.failed.inc(),
        }
        t.in_flight = t.in_flight.saturating_sub(1);
    }
    inner.work.notify_all();
    ticket.complete(result);
}

fn execute_job(inner: Arc<ServerInner>, slot: usize, q: Queued<ServeJob>) {
    let Queued { job, enqueued, deadline, .. } = q;
    let ServeJob { graph: graph_name, epoch, req, ticket } = job;
    let guard = SettleGuard { inner, slot, ticket, enqueued, armed: true };
    // ticket was moved into the guard; settle through it from here on.
    let now = Instant::now();
    if now >= deadline {
        let waited = now.saturating_duration_since(enqueued);
        guard.settle(Err(PgError::Expired { waited }.into()));
        return;
    }
    let resolved = {
        let st = lock_recover(&guard.inner.state);
        st.graphs
            .get(&graph_name)
            .filter(|e| e.epoch == epoch)
            .map(|e| (Arc::clone(&e.graph), e.tags.get(slot).copied().flatten()))
    };
    let result = match resolved {
        Some((graph, tag)) => run_request(&graph, tag, &req),
        None => Err(PgError::Closed(format!(
            "graph '{graph_name}' was closed while the request was queued"
        ))
        .into()),
    };
    guard.settle(result);
}

fn run_request(graph: &PgGraph, tag: Option<CacheTag>, req: &ServeRequest) -> Result<ServeReply> {
    match req {
        ServeRequest::Successors { vertex } => {
            Ok(ServeReply::Successors(graph.successors_tagged(*vertex, tag)?))
        }
        ServeRequest::CsxRange { lo, hi } => {
            let block = graph.csx_get_subgraph_sync(VertexRange::new(*lo, *hi))?;
            Ok(ServeReply::Edges(block.num_edges()))
        }
        ServeRequest::CooRange { lo_edge, hi_edge } => {
            let cb: BlockCallback = Arc::new(|_blk| {});
            let r = graph.coo_get_edges(*lo_edge, *hi_edge, cb)?;
            r.wait();
            if r.is_failed() {
                if let Some(pg) = r.error_kind() {
                    return Err(pg.into());
                }
                let msg = r.error().unwrap_or_else(|| "no error recorded".into());
                bail!("coo request failed: {msg}");
            }
            Ok(ServeReply::Edges(r.edges_delivered()))
        }
        ServeRequest::Partitions { parts } => {
            let stream = graph.csx_get_partitions(*parts)?;
            let mut edges = 0u64;
            while let Some(p) = stream.next()? {
                edges += p.num_edges();
            }
            Ok(ServeReply::Edges(edges))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::webgraph;
    use crate::graph::generators;

    fn open_test_server(n: usize, seed: u64) -> (GraphServer, crate::graph::CsrGraph) {
        let g = generators::barabasi_albert(n, 4, seed);
        let store = Arc::new(SimStore::new(DeviceKind::Dram));
        for (name, data) in webgraph::serialize(&g, "g") {
            store.put(&name, data);
        }
        let server = GraphServer::new(ServerOptions::default());
        let opts = Options { buffers: 2, buffer_edges: 4096, ..Options::default() };
        server.open_store("g", store, "g", GraphType::CsxWg400, opts).unwrap();
        (server, g)
    }

    #[test]
    fn serves_successors_csx_coo_and_partitions() {
        let (server, g) = open_test_server(300, 11);
        server.register_tenant("t", TenantQuotas::default()).unwrap();
        match server.call("t", "g", ServeRequest::Successors { vertex: 7 }).unwrap() {
            ServeReply::Successors(s) => assert_eq!(s, g.neighbors(7)),
            other => panic!("unexpected reply {other:?}"),
        }
        let m = g.num_edges();
        for req in [
            ServeRequest::CsxRange { lo: 0, hi: g.num_vertices() },
            ServeRequest::CooRange { lo_edge: 0, hi_edge: m },
            ServeRequest::Partitions { parts: 3 },
        ] {
            match server.call("t", "g", req).unwrap() {
                ServeReply::Edges(e) => assert_eq!(e, m),
                other => panic!("unexpected reply {other:?}"),
            }
        }
        let stats = server.tenant_stats("t").unwrap();
        assert_eq!(stats.completed, 4);
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.shed, 0);
    }

    #[test]
    fn full_queue_sheds_with_typed_overloaded() {
        let (server, _g) = open_test_server(200, 13);
        server
            .register_tenant("t", TenantQuotas { max_queue: 0, ..TenantQuotas::default() })
            .unwrap();
        let err = server.submit("t", "g", ServeRequest::Successors { vertex: 0 }).unwrap_err();
        match err.downcast_ref::<PgError>() {
            Some(PgError::Overloaded { retry_after }) => {
                assert!(*retry_after > Duration::ZERO, "retry_after must be positive");
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert_eq!(server.tenant_stats("t").unwrap().shed, 1);
    }

    #[test]
    fn queued_past_deadline_expires_and_is_billed() {
        let (server, _g) = open_test_server(200, 17);
        // max_in_flight = 0: nothing ever dispatches, so the request can
        // only leave the queue through the deadline sweep.
        server
            .register_tenant("t", TenantQuotas { max_in_flight: 0, ..TenantQuotas::default() })
            .unwrap();
        let t = server
            .submit_with_deadline(
                "t",
                "g",
                ServeRequest::Successors { vertex: 0 },
                Duration::from_millis(5),
            )
            .unwrap();
        let err = t.wait().unwrap_err();
        match err.downcast_ref::<PgError>() {
            Some(PgError::Expired { waited }) => assert!(*waited >= Duration::from_millis(5)),
            other => panic!("expected Expired, got {other:?}"),
        }
        let stats = server.tenant_stats("t").unwrap();
        assert_eq!(stats.expired, 1);
        assert_eq!(stats.completed, 0);
        let lat = server.tenant_latency("t").unwrap();
        assert_eq!(lat.total, 1, "expiry must be billed to the latency histogram");
    }

    #[test]
    fn request_queued_across_close_fails_typed() {
        let (server, _g) = open_test_server(200, 19);
        // Hold the request in the queue (no dispatch), close the graph,
        // then let it dispatch: the epoch check must fail it typed.
        server
            .register_tenant("t", TenantQuotas { max_in_flight: 0, ..TenantQuotas::default() })
            .unwrap();
        let t = server.submit("t", "g", ServeRequest::Successors { vertex: 0 }).unwrap();
        server.close("g").unwrap();
        server.register_tenant("t", TenantQuotas::default()).unwrap();
        let err = t.wait().unwrap_err();
        match err.downcast_ref::<PgError>() {
            Some(PgError::Closed(_)) => {}
            other => panic!("expected Closed, got {other:?}"),
        }
        assert_eq!(server.tenant_stats("t").unwrap().failed, 1);
    }

    #[test]
    fn unknown_tenant_and_unknown_graph_are_rejected() {
        let (server, _g) = open_test_server(200, 23);
        server.register_tenant("t", TenantQuotas::default()).unwrap();
        assert!(server.submit("ghost", "g", ServeRequest::Successors { vertex: 0 }).is_err());
        let err = server.submit("t", "nope", ServeRequest::Successors { vertex: 0 }).unwrap_err();
        assert!(matches!(err.downcast_ref::<PgError>(), Some(PgError::Closed(_))));
    }

    #[test]
    fn reopen_bumps_epoch_and_keeps_serving() {
        let (server, g) = open_test_server(250, 29);
        server.register_tenant("t", TenantQuotas::default()).unwrap();
        let before = server.call("t", "g", ServeRequest::Successors { vertex: 3 }).unwrap();
        server.reopen("g").unwrap();
        let after = server.call("t", "g", ServeRequest::Successors { vertex: 3 }).unwrap();
        assert_eq!(before, after);
        assert_eq!(after, ServeReply::Successors(g.neighbors(3).to_vec()));
    }

    #[test]
    fn shutdown_fails_queued_requests_typed() {
        let (mut server, _g) = open_test_server(200, 31);
        server
            .register_tenant("t", TenantQuotas { max_in_flight: 0, ..TenantQuotas::default() })
            .unwrap();
        let t = server.submit("t", "g", ServeRequest::Successors { vertex: 0 }).unwrap();
        server.shutdown();
        let err = t.wait().unwrap_err();
        assert!(matches!(err.downcast_ref::<PgError>(), Some(PgError::Closed(_))));
        // Post-shutdown submits are rejected typed, not hung.
        let err = server.submit("t", "g", ServeRequest::Successors { vertex: 0 }).unwrap_err();
        assert!(matches!(err.downcast_ref::<PgError>(), Some(PgError::Closed(_))));
    }

    #[test]
    fn estimate_is_monotone_in_request_size() {
        let (server, _g) = open_test_server(300, 37);
        let graph = server.graph("g").unwrap();
        let (c1, b1) = estimate_cost(&graph, &ServeRequest::CsxRange { lo: 0, hi: 10 });
        let (c2, b2) = estimate_cost(&graph, &ServeRequest::CsxRange { lo: 0, hi: 100 });
        assert!(c2 > c1 && b2 > b1);
        let (cp, _) = estimate_cost(&graph, &ServeRequest::Partitions { parts: 4 });
        assert_eq!(cp, graph.num_edges());
    }
}
