//! `serve-stress`: the multi-tenant serving bench/campaign.
//!
//! Drives one [`GraphServer`] with a mixed request storm — successors,
//! CSX ranges, COO ranges, and partition drains — from several tenants
//! over two graphs, with one deliberately abusive tenant, mid-run churn
//! (close + reopen of one graph under traffic) and a fault window (every
//! read of one graph's store fails) — then checks the serving contracts
//! end to end:
//!
//! * the abusive tenant is shed with typed `Overloaded` (and nothing
//!   else is);
//! * well-behaved tenants' p99 stays within a configured factor of their
//!   solo (uncontended) p99;
//! * two equally-weighted tenants running the same workload finish in
//!   comparable wall time (the DRR fairness ratio);
//! * churn and faults on one graph never fail a request on the other;
//! * every admitted request settles and every buffer returns to its pool
//!   — zero leaks, zero wedged streams.
//!
//! The campaign is seeded and deterministic in its request mix (timing
//! naturally varies); [`StressReport`] renders the per-tenant tail table
//! for the CI job summary and the `BENCH_serve.json` artifact.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::{GraphType, Options, PgError};
use crate::formats::webgraph;
use crate::graph::generators;
use crate::storage::{DeviceKind, FaultPlan, SimStore};
use crate::util::json::Json;
use crate::util::rng::Xoshiro256;

use super::{GraphServer, ServeRequest, ServerOptions, TenantQuotas, Ticket};

/// Campaign knobs (`paragrapher serve-stress`).
#[derive(Debug, Clone, Copy)]
pub struct StressConfig {
    pub seed: u64,
    /// Graph-size multiplier (g1 = 3000·scale vertices, g2 = 2000·scale).
    pub scale: usize,
    /// Requests per well-behaved tenant in the contended phase; the
    /// abusive tenant fires 3× this many.
    pub requests: usize,
    pub exec_workers: usize,
    /// Contended p99 must stay ≤ this factor × solo p99 (+ a small
    /// absolute slack for scheduler jitter).
    pub p99_factor: f64,
    /// Close + reopen g2 under traffic.
    pub churn: bool,
    /// Run the fault window against g2's store.
    pub faults: bool,
}

impl Default for StressConfig {
    fn default() -> Self {
        Self {
            seed: 42,
            scale: 1,
            requests: 400,
            exec_workers: 4,
            p99_factor: 2.0,
            churn: true,
            faults: true,
        }
    }
}

/// One tenant's row in the report.
pub struct TenantRow {
    pub tenant: String,
    pub phase: &'static str,
    pub admitted: u64,
    pub completed: u64,
    pub shed: u64,
    pub expired: u64,
    pub failed: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

/// Campaign outcome: per-tenant tails plus the headline contract numbers.
pub struct StressReport {
    pub seed: u64,
    pub g1_vertices: usize,
    pub g1_edges: u64,
    pub g2_vertices: usize,
    pub g2_edges: u64,
    pub rows: Vec<TenantRow>,
    pub solo_p99_ms: f64,
    pub contended_p99_ms: f64,
    pub p99_limit_ms: f64,
    /// max/min wall time of the two equal-workload tenants (1.0 = perfect).
    pub fairness_ratio: f64,
    pub churn_reopens: u64,
    pub fault_failures: u64,
    pub total_settled: u64,
}

impl StressReport {
    /// Markdown for the CI job summary, chaos-bench style.
    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "### serve-stress (seed {}, g1 {}v/{}e, g2 {}v/{}e)\n\n",
            self.seed, self.g1_vertices, self.g1_edges, self.g2_vertices, self.g2_edges
        ));
        s.push_str("| tenant | phase | admitted | completed | shed | expired | failed ");
        s.push_str("| p50 ms | p95 ms | p99 ms |\n");
        s.push_str("|---|---|---|---|---|---|---|---|---|---|\n");
        for r in &self.rows {
            s.push_str(&format!(
                "| {} | {} | {} | {} | {} | {} | {} | {:.3} | {:.3} | {:.3} |\n",
                r.tenant, r.phase, r.admitted, r.completed, r.shed, r.expired, r.failed,
                r.p50_ms, r.p95_ms, r.p99_ms
            ));
        }
        s.push_str("\n| contract | value |\n|---|---|\n");
        s.push_str(&format!(
            "| well-behaved p99 | {:.3} ms (solo {:.3} ms, limit {:.3} ms) |\n",
            self.contended_p99_ms, self.solo_p99_ms, self.p99_limit_ms
        ));
        s.push_str(&format!(
            "| fairness ratio (wall time, equal workloads) | {:.2} |\n",
            self.fairness_ratio
        ));
        s.push_str(&format!("| churn reopens under traffic | {} |\n", self.churn_reopens));
        s.push_str(&format!(
            "| fault-window typed failures (g2 only) | {} |\n",
            self.fault_failures
        ));
        s.push_str(&format!(
            "| requests settled | {} (every ticket; zero wedged) |\n",
            self.total_settled
        ));
        s
    }

    /// The `BENCH_serve.json` payload.
    pub fn to_json(&self) -> Json {
        let mut tenants = Json::Arr(vec![]);
        for r in &self.rows {
            let mut row = Json::obj();
            row.set("tenant", r.tenant.as_str())
                .set("phase", r.phase)
                .set("admitted", r.admitted)
                .set("completed", r.completed)
                .set("shed", r.shed)
                .set("expired", r.expired)
                .set("failed", r.failed)
                .set("p50_ms", r.p50_ms)
                .set("p95_ms", r.p95_ms)
                .set("p99_ms", r.p99_ms);
            tenants.push(row);
        }
        let mut summary = Json::obj();
        summary
            .set("solo_p99_ms", self.solo_p99_ms)
            .set("contended_p99_ms", self.contended_p99_ms)
            .set("p99_limit_ms", self.p99_limit_ms)
            .set("fairness_ratio", self.fairness_ratio)
            .set("churn_reopens", self.churn_reopens)
            .set("fault_failures", self.fault_failures)
            .set("total_settled", self.total_settled);
        let mut root = Json::obj();
        root.set("bench", "serve")
            .set("seed", self.seed)
            .set("g1_vertices", self.g1_vertices)
            .set("g1_edges", self.g1_edges)
            .set("g2_vertices", self.g2_vertices)
            .set("g2_edges", self.g2_edges)
            .set("tenants", tenants)
            .set("summary", summary);
        root
    }
}

/// What one client saw, classified by typed error.
#[derive(Debug, Default, Clone, Copy)]
struct ClientOutcome {
    ok: u64,
    shed: u64,
    closed: u64,
    faulted: u64,
    expired: u64,
    other: u64,
}

impl ClientOutcome {
    fn settled(&self) -> u64 {
        self.ok + self.shed + self.closed + self.faulted + self.expired + self.other
    }

    fn classify(&mut self, e: &anyhow::Error) {
        match e.downcast_ref::<PgError>() {
            Some(PgError::Overloaded { .. }) => self.shed += 1,
            Some(PgError::Closed(_)) => self.closed += 1,
            Some(PgError::Faulted(_)) => self.faulted += 1,
            Some(PgError::Expired { .. }) => self.expired += 1,
            _ => self.other += 1,
        }
    }
}

/// Seeded mixed request: mostly cheap random access, some vertex/edge
/// ranges, the occasional full partition drain.
fn mixed_request(rng: &mut Xoshiro256, n: usize, m: u64) -> ServeRequest {
    match rng.next_below(100) {
        0..=79 => ServeRequest::Successors { vertex: rng.next_below(n as u64) as usize },
        80..=92 => {
            let lo = rng.next_below(n as u64) as usize;
            let hi = (lo + 1 + rng.next_below(256) as usize).min(n);
            ServeRequest::CsxRange { lo, hi }
        }
        93..=98 => {
            let lo = rng.next_below(m.max(1));
            let hi = (lo + 1 + rng.next_below(4096)).min(m);
            ServeRequest::CooRange { lo_edge: lo, hi_edge: hi }
        }
        _ => ServeRequest::Partitions { parts: 4 },
    }
}

fn settle_one(pending: &mut VecDeque<Ticket>, out: &mut ClientOutcome) -> Result<()> {
    let t = pending.pop_front().expect("pending non-empty");
    match t.wait_timeout(Duration::from_secs(120)) {
        Some(Ok(_)) => out.ok += 1,
        Some(Err(e)) => out.classify(&e),
        None => bail!("request did not settle within 120s — wedged ticket"),
    }
    Ok(())
}

/// One client: `count` seeded mixed requests round-robined over `graphs`,
/// pipelined `depth` deep. Typed failures are tolerated and classified
/// (under churn and shedding they are the expected outcome); a ticket
/// that never settles is the one hard error.
fn run_client(
    server: &GraphServer,
    tenant: &str,
    graphs: &[&str],
    count: usize,
    seed: u64,
    depth: usize,
) -> Result<ClientOutcome> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut out = ClientOutcome::default();
    let dims: Vec<(String, usize, u64)> = graphs
        .iter()
        .map(|g| {
            let h = server.graph(g).with_context(|| format!("graph '{g}' not open"))?;
            Ok((g.to_string(), h.num_vertices(), h.num_edges()))
        })
        .collect::<Result<_>>()?;
    let mut pending: VecDeque<Ticket> = VecDeque::new();
    for i in 0..count {
        let (gname, n, m) = &dims[i % dims.len()];
        let req = mixed_request(&mut rng, *n, *m);
        match server.submit(tenant, gname, req) {
            Ok(t) => pending.push_back(t),
            Err(e) => out.classify(&e),
        }
        while pending.len() >= depth.max(1) {
            settle_one(&mut pending, &mut out)?;
        }
    }
    while !pending.is_empty() {
        settle_one(&mut pending, &mut out)?;
    }
    Ok(out)
}

fn sim_store_with(g: &crate::graph::CsrGraph, base: &str) -> Arc<SimStore> {
    let store = Arc::new(SimStore::new(DeviceKind::Dram));
    for (name, data) in webgraph::serialize(g, base) {
        store.put(&name, data);
    }
    store
}

fn tenant_row(server: &GraphServer, tenant: &str, phase: &'static str) -> TenantRow {
    let stats = server.tenant_stats(tenant).unwrap_or_default();
    let lat = server.tenant_latency(tenant).unwrap_or_else(crate::obs::HistSnapshot::empty);
    let ms = |q: f64| lat.percentile(q) as f64 / 1e6;
    TenantRow {
        tenant: tenant.to_string(),
        phase,
        admitted: stats.admitted,
        completed: stats.completed,
        shed: stats.shed,
        expired: stats.expired,
        failed: stats.failed,
        p50_ms: ms(0.50),
        p95_ms: ms(0.95),
        p99_ms: ms(0.99),
    }
}

/// Run the campaign. Every contract violation is a hard `Err`; the `Ok`
/// report carries the numbers for the CI summary and `BENCH_serve.json`.
pub fn run(cfg: StressConfig) -> Result<StressReport> {
    let scale = cfg.scale.max(1);
    let g1 = generators::barabasi_albert(3000 * scale, 6, cfg.seed);
    let g2 = generators::barabasi_albert(2000 * scale, 5, cfg.seed ^ 0x5EED);
    let opts = Options { buffers: 4, buffer_edges: 4096, ..Options::default() };
    let server = GraphServer::new(ServerOptions {
        exec_workers: cfg.exec_workers.max(1),
        ..ServerOptions::default()
    });
    server.open_store("g1", sim_store_with(&g1, "g1"), "g1", GraphType::CsxWg400, opts.clone())?;
    server.open_store("g2", sim_store_with(&g2, "g2"), "g2", GraphType::CsxWg400, opts.clone())?;

    let wide = TenantQuotas {
        max_in_flight: 4,
        max_queue: 512,
        cache_quota_cost: 1 << 20,
        weight: 1 << 16,
    };
    server.register_tenant("a-solo", wide)?;
    server.register_tenant("alpha", wide)?;
    server.register_tenant("beta", wide)?;
    server.register_tenant("gamma", wide)?;
    // The abusive tenant: equal weight but a shallow queue — floods get
    // shed instead of queued, and DRR caps its share regardless.
    server.register_tenant("abuse", TenantQuotas { max_queue: 16, max_in_flight: 2, ..wide })?;

    // Phase A — solo baseline on an otherwise idle server. Under
    // capacity, nothing may shed.
    let solo = run_client(&server, "a-solo", &["g1"], cfg.requests, cfg.seed ^ 1, 4)?;
    ensure!(solo.settled() == cfg.requests as u64, "solo client lost requests: {solo:?}");
    let solo_stats = server.tenant_stats("a-solo").context("a-solo stats")?;
    ensure!(solo_stats.shed == 0, "under-capacity baseline shed {} requests", solo_stats.shed);
    ensure!(solo.ok == cfg.requests as u64, "solo requests failed on an idle server: {solo:?}");
    let solo_p99_ms =
        server.tenant_latency("a-solo").context("a-solo latency")?.percentile(0.99) as f64 / 1e6;

    // Phase B — contention: alpha+beta (equal workloads, p99-asserted,
    // g1 only), gamma (mixed over both graphs, rides through churn),
    // abuse (flooding g1), and an optional churn thread bouncing g2.
    let mut churn_reopens = 0u64;
    let (alpha, alpha_wall, beta, beta_wall, gamma, abuse) = std::thread::scope(|s| {
        let alpha_h = s.spawn(|| {
            let t0 = Instant::now();
            run_client(&server, "alpha", &["g1"], cfg.requests, cfg.seed ^ 2, 8)
                .map(|o| (o, t0.elapsed()))
        });
        let beta_h = s.spawn(|| {
            let t0 = Instant::now();
            run_client(&server, "beta", &["g1"], cfg.requests, cfg.seed ^ 3, 8)
                .map(|o| (o, t0.elapsed()))
        });
        let gamma_h = s.spawn(|| {
            run_client(&server, "gamma", &["g1", "g2"], cfg.requests / 2, cfg.seed ^ 4, 8)
        });
        let abuse_h = s.spawn(|| {
            run_client(&server, "abuse", &["g1"], cfg.requests * 3, cfg.seed ^ 5, 32)
        });
        let churn_h = cfg.churn.then(|| {
            s.spawn(|| {
                let mut ok = 0u64;
                for _ in 0..3 {
                    std::thread::sleep(Duration::from_millis(20));
                    if server.reopen("g2").is_ok() {
                        ok += 1;
                    }
                }
                ok
            })
        });
        let (alpha, alpha_wall) = alpha_h.join().expect("alpha client panicked")?;
        let (beta, beta_wall) = beta_h.join().expect("beta client panicked")?;
        let gamma = gamma_h.join().expect("gamma client panicked")?;
        let abuse = abuse_h.join().expect("abuse client panicked")?;
        if let Some(h) = churn_h {
            churn_reopens = h.join().expect("churn thread panicked");
        }
        Ok::<_, anyhow::Error>((alpha, alpha_wall, beta, beta_wall, gamma, abuse))
    })?;

    // Contracts of the contended phase.
    for (name, o, count) in [("alpha", &alpha, cfg.requests), ("beta", &beta, cfg.requests)] {
        ensure!(o.settled() == count as u64, "{name} lost requests: {o:?}");
        ensure!(o.ok == count as u64, "{name} (well-behaved, stable graph) saw failures: {o:?}");
    }
    ensure!(gamma.settled() == (cfg.requests / 2) as u64, "gamma lost requests: {gamma:?}");
    if cfg.churn {
        ensure!(churn_reopens > 0, "no churn reopen succeeded under traffic");
    }
    ensure!(abuse.settled() == (cfg.requests * 3) as u64, "abuse client lost requests: {abuse:?}");
    ensure!(abuse.shed > 0, "the flooding tenant was never shed with Overloaded");
    let abuse_stats = server.tenant_stats("abuse").context("abuse stats")?;
    ensure!(abuse_stats.shed == abuse.shed, "server and client disagree on shed count");

    let contended_p99_ms =
        server.tenant_latency("alpha").context("alpha latency")?.percentile(0.99) as f64 / 1e6;
    // Small absolute slack: on a busy CI runner the solo baseline can be
    // tens of microseconds, where scheduler jitter alone exceeds 2×.
    let p99_limit_ms = solo_p99_ms * cfg.p99_factor + 25.0;
    ensure!(
        contended_p99_ms <= p99_limit_ms,
        "well-behaved p99 {contended_p99_ms:.3}ms exceeds limit {p99_limit_ms:.3}ms \
         (solo {solo_p99_ms:.3}ms × {})",
        cfg.p99_factor
    );
    let fairness_ratio = {
        let (a, b) = (alpha_wall.as_secs_f64().max(1e-9), beta_wall.as_secs_f64().max(1e-9));
        a.max(b) / a.min(b)
    };
    ensure!(
        fairness_ratio < 3.0,
        "equal-weight equal-workload tenants finished {fairness_ratio:.2}x apart"
    );

    // Fault window — every g2 read faults; gamma's g2 requests must fail
    // typed while alpha's g1 requests keep succeeding untouched.
    let mut fault_failures = 0u64;
    if cfg.faults {
        let g2_handle = server.graph("g2").context("g2 not open after churn")?;
        g2_handle
            .store()
            .set_fault_plan(Some(Arc::new(FaultPlan::parse("eio:*.graph@count=inf", cfg.seed)?)));
        for i in 0..12usize {
            let lo = (i * 97) % (g2.num_vertices() - 64);
            let r = server.call("gamma", "g2", ServeRequest::CsxRange { lo, hi: lo + 64 });
            let e = match r {
                Ok(_) => bail!("g2 request succeeded under an infinite fault plan"),
                Err(e) => e,
            };
            match e.downcast_ref::<PgError>() {
                Some(PgError::Faulted(_)) | Some(PgError::Closed(_)) => fault_failures += 1,
                other => bail!("fault window produced an untyped failure: {other:?}"),
            }
            let w = server.call("alpha", "g1", ServeRequest::Successors { vertex: (i * 31) % 100 });
            ensure!(w.is_ok(), "fault on g2 leaked into a g1 request: {:?}", w.err());
        }
        g2_handle.store().set_fault_plan(None);
        g2_handle.clear_quarantine();
        // The degraded graph recovers for its own tenants too.
        server
            .call("gamma", "g2", ServeRequest::CsxRange { lo: 0, hi: 64 })
            .context("g2 did not recover after the fault plan was cleared")?;
    }

    // Zero-leak contract: every buffer back in its pool on both graphs.
    for name in ["g1", "g2"] {
        let h = server.graph(name).with_context(|| format!("{name} not open at teardown"))?;
        let buffers = h.options().buffers;
        ensure!(
            h.idle_buffers() == buffers,
            "buffer leak on {name}: {}/{} idle after the campaign",
            h.idle_buffers(),
            buffers
        );
    }

    let rows = vec![
        tenant_row(&server, "a-solo", "solo"),
        tenant_row(&server, "alpha", "contended"),
        tenant_row(&server, "beta", "contended"),
        tenant_row(&server, "gamma", "contended+churn"),
        tenant_row(&server, "abuse", "contended"),
    ];
    let total_settled = solo.settled()
        + alpha.settled()
        + beta.settled()
        + gamma.settled()
        + abuse.settled()
        + fault_failures;
    Ok(StressReport {
        seed: cfg.seed,
        g1_vertices: g1.num_vertices(),
        g1_edges: g1.num_edges(),
        g2_vertices: g2.num_vertices(),
        g2_edges: g2.num_edges(),
        rows,
        solo_p99_ms,
        contended_p99_ms,
        p99_limit_ms,
        fairness_ratio,
        churn_reopens,
        fault_failures,
        total_settled,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_campaign_holds_every_contract() {
        let cfg = StressConfig { requests: 60, scale: 1, ..StressConfig::default() };
        let report = run(cfg).expect("stress campaign");
        assert!(report.rows.iter().any(|r| r.tenant == "abuse" && r.shed > 0));
        assert!(report.fairness_ratio >= 1.0);
        assert_eq!(report.rows.len(), 5);
        let json = report.to_json();
        assert_eq!(json.get("bench").and_then(|j| j.as_str()), Some("serve"));
        assert_eq!(json.get("tenants").and_then(|t| t.as_arr()).map(|a| a.len()), Some(5));
        let md = report.to_markdown();
        assert!(md.contains("| abuse |"));
        assert!(md.contains("fairness ratio"));
    }
}
