//! Admission control: bounded per-tenant queues drained by deficit
//! round-robin (DRR).
//!
//! Every tenant owns one FIFO admission queue with a hard depth bound
//! ([`TenantQuotas::max_queue`]); a request that would overflow it is
//! rejected *at submit time* with a typed
//! [`PgError::Overloaded`](crate::coordinator::PgError) carrying a
//! `retry_after` derived from the §3 load model (see
//! [`GraphServer::submit`](super::GraphServer::submit)) — the queue never
//! grows unboundedly and a hostile client learns to back off.
//!
//! Dispatch is deficit round-robin over *work units* (estimated edges
//! touched), not request counts: each rotation visit tops a tenant's
//! deficit up by its quantum ([`TenantQuotas::weight`]) at most once, and
//! the tenant may dispatch while its deficit covers the head request's
//! cost. Bandwidth share therefore converges to the quantum ratio even
//! when one tenant submits exclusively huge partition drains and another
//! submits single-vertex lookups — the classic DRR fairness argument.
//! A per-tenant in-flight cap ([`TenantQuotas::max_in_flight`]) bounds how
//! much executor concurrency any one tenant can hold at once.
//!
//! Expired requests are swept before every pick: a request whose deadline
//! passed while queued completes with a typed
//! [`PgError::Expired`](crate::coordinator::PgError) and is *billed* — the
//! tenant's latency histogram records the time it spent queued and its
//! `expired` counter increments. Silent drops would make an overloaded
//! server look fast.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::obs::{Counter, Histo};

/// Per-tenant resource bounds. `Default` gives a well-behaved interactive
/// tenant: shallow queue, a few concurrent requests, no cache quota.
#[derive(Debug, Clone, Copy)]
pub struct TenantQuotas {
    /// Requests this tenant may have executing at once.
    pub max_in_flight: usize,
    /// Admission-queue depth; submits beyond it shed with `Overloaded`.
    pub max_queue: usize,
    /// Decoded-cache resident-cost ceiling (cost units — edges + offsets
    /// of cached blocks; 0 = no per-tenant quota). Enforced by the cache
    /// itself: the tenant's own LRU entries evict first
    /// ([`DecodedCache::insert_tagged`](crate::storage::cache)).
    pub cache_quota_cost: u64,
    /// DRR quantum, work units (estimated edges) added per rotation visit.
    pub weight: u64,
}

impl Default for TenantQuotas {
    fn default() -> Self {
        Self { max_in_flight: 4, max_queue: 64, cache_quota_cost: 0, weight: 1 << 16 }
    }
}

/// Point-in-time view of one tenant's serving counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TenantStats {
    pub admitted: u64,
    pub shed: u64,
    pub completed: u64,
    pub expired: u64,
    pub failed: u64,
    pub queued: usize,
    pub in_flight: usize,
}

/// One queued request, as the dispatcher sees it (the server attaches the
/// actual work closure/ticket alongside via the same queue slot).
pub(crate) struct Queued<J> {
    pub job: J,
    /// Estimated work units (edges touched) — the DRR cost.
    pub cost: u64,
    /// Estimated *uncompressed* bytes the request will move — the §3
    /// backlog unit behind `retry_after`.
    pub bytes: u64,
    pub enqueued: Instant,
    pub deadline: Instant,
}

/// Per-tenant admission state. Owned by the server's state mutex.
pub(crate) struct TenantState<J> {
    pub name: String,
    pub quotas: TenantQuotas,
    pub queue: VecDeque<Queued<J>>,
    pub deficit: u64,
    pub in_flight: usize,
    /// Sum of `bytes` over the queue (kept incrementally).
    pub queued_bytes: u64,
    // Registry-resolved counters (`serve.tenant.<name>.*`).
    pub admitted: Counter,
    pub shed: Counter,
    pub completed: Counter,
    pub expired: Counter,
    pub failed: Counter,
    /// End-to-end latency, submit → completion (expiries billed too).
    pub lat: Histo,
}

impl<J> TenantState<J> {
    pub fn stats(&self) -> TenantStats {
        TenantStats {
            admitted: self.admitted.get(),
            shed: self.shed.get(),
            completed: self.completed.get(),
            expired: self.expired.get(),
            failed: self.failed.get(),
            queued: self.queue.len(),
            in_flight: self.in_flight,
        }
    }

    /// Pop every queue-head-to-tail request whose deadline has passed.
    /// Returns the expired jobs with how long each waited; the caller
    /// completes their tickets (billed) outside the state lock.
    pub fn sweep_expired(&mut self, now: Instant) -> Vec<(J, Duration)> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.queue.len() {
            if self.queue[i].deadline <= now {
                let q = self.queue.remove(i).expect("index in bounds");
                self.queued_bytes = self.queued_bytes.saturating_sub(q.bytes);
                out.push((q.job, now.saturating_duration_since(q.enqueued)));
            } else {
                i += 1;
            }
        }
        out
    }
}

/// One DRR pick across `tenants`: classic deficit round-robin, one
/// dequeued request per call.
///
/// `cursor` is the tenant the rotation currently sits on and `topped`
/// whether that tenant has already received its *arrival* top-up — both
/// live in the server state so the burst structure survives across calls.
/// On arriving at a tenant its deficit grows by one quantum, once; it then
/// dispatches requests (one per call, cursor parked) while the deficit
/// covers the head cost and in-flight headroom remains. When it can no
/// longer afford its head the rotation moves on *without* another top-up —
/// this is what stops a cheap-request tenant from monopolizing: its
/// service per rotation is bounded by its quantum, so long-run bandwidth
/// share converges to the quantum (weight) ratio. An emptied queue resets
/// the deficit (DRR's anti-banking rule: credit does not accumulate while
/// idle). Returns the tenant index and the dequeued request.
pub(crate) fn drr_pick<J>(
    tenants: &mut [TenantState<J>],
    cursor: &mut usize,
    topped: &mut bool,
) -> Option<(usize, Queued<J>)> {
    let n = tenants.len();
    if n == 0 {
        return None;
    }
    // At most one full rotation (every tenant visited once) per call.
    for _ in 0..=n {
        let idx = *cursor % n;
        let t = &mut tenants[idx];
        if t.queue.is_empty() {
            t.deficit = 0;
            *cursor = (idx + 1) % n;
            *topped = false;
            continue;
        }
        if t.in_flight >= t.quotas.max_in_flight {
            // Concurrency-capped: skip without a top-up so a blocked
            // tenant does not bank credit while it cannot run anyway.
            *cursor = (idx + 1) % n;
            *topped = false;
            continue;
        }
        let head_cost = t.queue.front().expect("non-empty").cost;
        if !*topped {
            // Arrival top-up, ceilinged at one quantum (or the head cost,
            // whichever is larger, so every request is affordable after a
            // single top-up): credit never banks without bound, which
            // caps the post-idle burst at max(quantum, head_cost).
            let quantum = t.quotas.weight.max(1);
            t.deficit = t.deficit.saturating_add(quantum).min(quantum.max(head_cost));
            *topped = true;
        }
        if t.deficit >= head_cost {
            let q = t.queue.pop_front().expect("head present");
            t.deficit -= head_cost;
            t.queued_bytes = t.queued_bytes.saturating_sub(q.bytes);
            t.in_flight += 1;
            return Some((idx, q));
        }
        *cursor = (idx + 1) % n;
        *topped = false;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tenant(name: &str, weight: u64, max_in_flight: usize) -> TenantState<u32> {
        TenantState {
            name: name.to_string(),
            quotas: TenantQuotas { weight, max_in_flight, ..Default::default() },
            queue: VecDeque::new(),
            deficit: 0,
            in_flight: 0,
            queued_bytes: 0,
            admitted: Counter::detached(),
            shed: Counter::detached(),
            completed: Counter::detached(),
            expired: Counter::detached(),
            failed: Counter::detached(),
            lat: Histo::detached(),
        }
    }

    fn enqueue(t: &mut TenantState<u32>, job: u32, cost: u64) {
        let now = Instant::now();
        t.queue.push_back(Queued {
            job,
            cost,
            bytes: cost * 8,
            enqueued: now,
            deadline: now + Duration::from_secs(60),
        });
        t.queued_bytes += cost * 8;
    }

    #[test]
    fn drr_shares_by_weight_not_request_count() {
        // Tenant a: many cheap requests; tenant b: few huge ones, equal
        // weights — served work units should stay balanced, so the huge
        // requests are NOT starved and the cheap ones do NOT monopolize.
        let mut ts = vec![tenant("a", 100, usize::MAX), tenant("b", 100, usize::MAX)];
        for i in 0..100 {
            enqueue(&mut ts[0], i, 10);
        }
        for i in 0..10 {
            enqueue(&mut ts[1], 1000 + i, 100);
        }
        let mut cursor = 0;
        let mut topped = false;
        let mut served = [0u64, 0u64];
        for _ in 0..10_000 {
            // Completion is immediate in this model.
            match drr_pick(&mut ts, &mut cursor, &mut topped) {
                Some((idx, q)) => {
                    served[idx] += q.cost;
                    ts[idx].in_flight -= 1;
                }
                None => {
                    if ts.iter().all(|t| t.queue.is_empty()) {
                        break;
                    }
                }
            }
        }
        assert_eq!(served, [1000, 1000], "equal weights -> equal work served");
    }

    #[test]
    fn drr_respects_in_flight_cap() {
        let mut ts = vec![tenant("a", 1000, 2)];
        for i in 0..5 {
            enqueue(&mut ts[0], i, 1);
        }
        let mut cursor = 0;
        let mut topped = false;
        assert!(drr_pick(&mut ts, &mut cursor, &mut topped).is_some());
        assert!(drr_pick(&mut ts, &mut cursor, &mut topped).is_some());
        assert!(
            drr_pick(&mut ts, &mut cursor, &mut topped).is_none(),
            "third pick blocked by max_in_flight=2"
        );
        ts[0].in_flight = 0;
        assert!(drr_pick(&mut ts, &mut cursor, &mut topped).is_some());
    }

    #[test]
    fn weighted_tenant_gets_proportional_share() {
        let mut ts = vec![tenant("heavy", 300, usize::MAX), tenant("light", 100, usize::MAX)];
        for i in 0..400 {
            enqueue(&mut ts[0], i, 10);
            enqueue(&mut ts[1], i, 10);
        }
        let mut cursor = 0;
        let mut topped = false;
        let mut served = [0u64, 0u64];
        // Stop while both queues are still non-empty so the shares
        // reflect steady-state competition, not one queue draining.
        for _ in 0..200 {
            if let Some((idx, q)) = drr_pick(&mut ts, &mut cursor, &mut topped) {
                served[idx] += q.cost;
                ts[idx].in_flight -= 1;
            }
        }
        assert!(!ts[0].queue.is_empty() && !ts[1].queue.is_empty());
        let ratio = served[0] as f64 / served[1] as f64;
        assert!(
            (2.0..=4.0).contains(&ratio),
            "3:1 weights -> ~3:1 served work, got {ratio} ({served:?})"
        );
    }

    #[test]
    fn sweep_expired_bills_and_removes() {
        let mut t = tenant("a", 100, 4);
        let now = Instant::now();
        t.queue.push_back(Queued {
            job: 1,
            cost: 1,
            bytes: 8,
            enqueued: now,
            deadline: now, // already expired
        });
        t.queue.push_back(Queued {
            job: 2,
            cost: 1,
            bytes: 8,
            enqueued: now,
            deadline: now + Duration::from_secs(60),
        });
        t.queued_bytes = 16;
        let expired = t.sweep_expired(Instant::now());
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].0, 1);
        assert_eq!(t.queue.len(), 1);
        assert_eq!(t.queued_bytes, 8);
    }
}
