//! ParaGrapher CLI — the leader entrypoint.
//!
//! ```text
//! paragrapher generate   --dataset TW --scale 2            # build dataset suite
//! paragrapher info       --dataset all                     # Table 3: sizes per format
//! paragrapher model      [--sigma 160e6 --d 1e9]           # Fig. 1 curve points
//! paragrapher load       --dataset G5 --device SSD --format webgraph [--threads 8]
//! paragrapher wcc        --dataset RD --device HDD --format webgraph
//! paragrapher bench-storage --device SSD                   # Fig. 4 grid
//! paragrapher sweep      --dataset TW --device HDD         # Fig. 8 grid
//! paragrapher end-to-end [--scale 1]                       # headline table
//! ```
//!
//! (Hand-rolled argument parsing: the offline build has no clap.)

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use paragrapher::coordinator::{GraphType, Options, Paragrapher, VertexRange};
use paragrapher::formats::FormatKind;
use paragrapher::graph::generators::Dataset;
use paragrapher::metrics::{fmt_bw, fmt_meps, LoadMeasurement, Table};
use paragrapher::model::{fig1_curve, LoadModel};
use paragrapher::storage::sim::ReadCtx;
use paragrapher::storage::{DeviceKind, IoAccount, ReadMethod, SimStore};
use paragrapher::util::{fmt_bytes, fmt_count};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        print_usage();
        std::process::exit(2);
    }
    let cmd = args[0].clone();
    let flags = parse_flags(&args[1..]);
    let result = match cmd.as_str() {
        "generate" => cmd_generate(&flags),
        "info" => cmd_info(&flags),
        "model" => cmd_model(&flags),
        "load" => cmd_load(&flags),
        "wcc" => cmd_wcc(&flags),
        "bench-storage" => cmd_bench_storage(&flags),
        "sweep" => cmd_sweep(&flags),
        "end-to-end" => cmd_end_to_end(&flags),
        "calibrate-decode" => cmd_calibrate_decode(&flags),
        "ci-summary" => cmd_ci_summary(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            Err(anyhow::anyhow!("unknown command {other:?}"))
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    eprintln!(
        "paragrapher — selective parallel loading of compressed graphs (paper reproduction)

commands:
  generate      --dataset <RD|TW|G5|SH|CW|MS|all> [--scale N] [--seed N]
  info          --dataset <..|all> [--scale N]            Table 3 sizes/bits-per-edge
  model         [--sigma B/s] [--d B/s] [--rmax R]        §3 / Fig. 1 curve
  load          --dataset D --device <HDD|SSD|NAS|NVMM|DDR4> --format <coo|csx|bin|webgraph>
                [--threads N] [--buffer-edges N] [--scale N]
  wcc           --dataset D --device DEV --format F       Fig. 6 style end-to-end WCC
  bench-storage [--device DEV]                            Fig. 4 bandwidth grid
  sweep         --dataset D --device DEV                  Fig. 8 threads×buffer grid
  end-to-end    [--scale N]                               full pipeline + headline table
  calibrate-decode [--scale N] [--seed N] [--repeats N] [--d B/s]
                                                          measured vs modeled decompression bandwidth d
  ci-summary                                              markdown health metrics for CI"
    );
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let value = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            flags.insert(key.to_string(), value);
        }
        i += 1;
    }
    flags
}

fn flag<'a>(flags: &'a HashMap<String, String>, key: &str, default: &'a str) -> &'a str {
    flags.get(key).map(|s| s.as_str()).unwrap_or(default)
}

fn flag_usize(flags: &HashMap<String, String>, key: &str, default: usize) -> usize {
    flags.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn flag_f64(flags: &HashMap<String, String>, key: &str, default: f64) -> f64 {
    flags.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn datasets_from(flags: &HashMap<String, String>) -> Result<Vec<Dataset>> {
    let spec = flag(flags, "dataset", "all");
    if spec.eq_ignore_ascii_case("all") {
        return Ok(Dataset::ALL.to_vec());
    }
    let mut out = Vec::new();
    for part in spec.split(',') {
        out.push(Dataset::parse(part).with_context(|| format!("unknown dataset {part:?}"))?);
    }
    Ok(out)
}

fn cmd_generate(flags: &HashMap<String, String>) -> Result<()> {
    let scale = flag_usize(flags, "scale", 1);
    let seed = flag_usize(flags, "seed", 42) as u64;
    for d in datasets_from(flags)? {
        let g = d.generate(scale, seed);
        println!(
            "{}: |V| = {} |E| = {}",
            d.abbr(),
            fmt_count(g.num_vertices() as u64),
            fmt_count(g.num_edges())
        );
    }
    Ok(())
}

fn cmd_info(flags: &HashMap<String, String>) -> Result<()> {
    let scale = flag_usize(flags, "scale", 1);
    let seed = flag_usize(flags, "seed", 42) as u64;
    let store = SimStore::new(DeviceKind::Dram);
    let mut table = Table::new(&[
        "Abbr", "|V|", "|E|", "Txt. COO", "Txt. CSX", "Bin. CSX", "WebGraph", "WG bits/edge",
    ]);
    for d in datasets_from(flags)? {
        let g = d.generate(scale, seed);
        let mut sizes = Vec::new();
        let mut wg_bpe = 0.0;
        for fk in FormatKind::ALL {
            let base = format!("{}-{:?}", d.abbr(), fk);
            let bytes = fk.write_to_store(&g, &store, &base);
            sizes.push(fmt_bytes(bytes));
            if fk == FormatKind::WebGraph {
                wg_bpe = fk.bits_per_edge(&g, &store, &base);
            }
        }
        table.row(&[
            d.abbr().to_string(),
            fmt_count(g.num_vertices() as u64),
            fmt_count(g.num_edges()),
            sizes[0].clone(),
            sizes[1].clone(),
            sizes[2].clone(),
            sizes[3].clone(),
            format!("{wg_bpe:.1}"),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_model(flags: &HashMap<String, String>) -> Result<()> {
    let sigma = flag_f64(flags, "sigma", 160e6);
    let d = flag_f64(flags, "d", 1.0e9);
    let rmax = flag_f64(flags, "rmax", 35.0);
    println!("load bandwidth model: sigma <= b <= min(sigma*r, d)   (Fig. 1)");
    println!("sigma = {}, d = {}", fmt_bw(sigma), fmt_bw(d));
    let m = LoadModel { sigma, r: rmax, d };
    println!("knee at r* = d/sigma = {:.2}", m.knee_ratio());
    let mut table = Table::new(&["r", "upper bound"]);
    for p in fig1_curve(sigma, d, rmax, 12) {
        table.row(&[format!("{:.1}", p.r), fmt_bw(p.bound)]);
    }
    println!("{}", table.render());
    Ok(())
}

/// Prepare a store holding `dataset` in `format`, return (graph, store, base).
fn prepare(
    dataset: Dataset,
    device: DeviceKind,
    format: FormatKind,
    scale: usize,
    seed: u64,
) -> (paragrapher::graph::CsrGraph, Arc<SimStore>, String) {
    let g = dataset.generate(scale, seed);
    let store = Arc::new(SimStore::new(device));
    let base = dataset.abbr().to_string();
    format.write_to_store(&g, &store, &base);
    store.drop_cache();
    (g, store, base)
}

fn cmd_load(flags: &HashMap<String, String>) -> Result<()> {
    let dataset =
        Dataset::parse(flag(flags, "dataset", "RD")).context("unknown --dataset")?;
    let device =
        DeviceKind::parse(flag(flags, "device", "SSD")).context("unknown --device")?;
    let format =
        FormatKind::parse(flag(flags, "format", "webgraph")).context("unknown --format")?;
    let threads = flag_usize(flags, "threads", 4);
    let scale = flag_usize(flags, "scale", 1);
    let buffer_edges = flag_usize(flags, "buffer-edges", 1 << 20) as u64;
    let (g, store, base) = prepare(dataset, device, format, scale, 42);

    let measurement = if format == FormatKind::WebGraph {
        // Through the coordinator (the ParaGrapher path).
        let pg = Paragrapher::init();
        let opts = Options {
            buffers: threads,
            buffer_edges,
            read_ctx: ReadCtx { threads, ..ReadCtx::default() },
            ..Options::default()
        };
        let graph = pg.open_graph(Arc::clone(&store), &base, GraphType::CsxWg400, opts)?;
        let t0 = std::time::Instant::now();
        let block = graph.load_whole_graph()?;
        let wall = t0.elapsed().as_secs_f64();
        let seq = graph.sequential_seconds();
        println!(
            "decoded {} edges (wall {:.3}s, sequential open {:.3}s)",
            fmt_count(block.num_edges()),
            wall,
            seq
        );
        LoadMeasurement {
            elapsed: wall + seq,
            edges: block.num_edges(),
            device_bytes: store.device_bytes(),
        }
    } else {
        // GAPBS-style baseline full load.
        let accounts: Vec<IoAccount> = (0..threads).map(|_| IoAccount::new()).collect();
        let ctx = ReadCtx { threads, ..ReadCtx::default() };
        let loaded = format.load_full(&store, &base, ctx, &accounts)?;
        LoadMeasurement::from_accounts(&accounts, loaded.num_edges(), 0.0)
    };
    println!(
        "{} / {} / {}: {} ({} modeled)",
        dataset.abbr(),
        device.name(),
        format.name(),
        fmt_meps(measurement.me_per_sec()),
        fmt_bw(measurement.device_bandwidth()),
    );
    let _ = g;
    Ok(())
}

fn cmd_wcc(flags: &HashMap<String, String>) -> Result<()> {
    let dataset =
        Dataset::parse(flag(flags, "dataset", "RD")).context("unknown --dataset")?;
    let device =
        DeviceKind::parse(flag(flags, "device", "SSD")).context("unknown --device")?;
    let format =
        FormatKind::parse(flag(flags, "format", "webgraph")).context("unknown --format")?;
    let threads = flag_usize(flags, "threads", 4);
    let scale = flag_usize(flags, "scale", 1);
    let (g, store, base) = prepare(dataset, device, format, scale, 42);

    let components = if format == FormatKind::WebGraph {
        // ParaGrapher + streaming JT-CC over async blocks (§5.3).
        let pg = Paragrapher::init();
        let opts = Options {
            buffers: threads,
            read_ctx: ReadCtx { threads, ..ReadCtx::default() },
            ..Options::default()
        };
        let graph = pg.open_graph(Arc::clone(&store), &base, GraphType::CsxWg400, opts)?;
        let uf = Arc::new(paragrapher::algorithms::jtcc::JtUnionFind::new(
            graph.num_vertices(),
            7,
        ));
        let uf2 = Arc::clone(&uf);
        let req = graph.csx_get_subgraph(
            VertexRange::new(0, graph.num_vertices()),
            Arc::new(move |blk| {
                for (s, d) in blk.iter_edges() {
                    uf2.union(s, d);
                }
            }),
        )?;
        req.wait();
        if let Some(e) = req.error() {
            bail!("load failed: {e}");
        }
        uf.count_components()
    } else {
        // Baseline: full load then Afforest.
        let accounts: Vec<IoAccount> = (0..threads).map(|_| IoAccount::new()).collect();
        let ctx = ReadCtx { threads, ..ReadCtx::default() };
        let loaded = format.load_full(&store, &base, ctx, &accounts)?;
        let labels = paragrapher::algorithms::afforest::afforest(&loaded, 7);
        paragrapher::algorithms::count_components(&labels)
    };
    println!(
        "{} / {} / {}: {} weakly-connected components ({} vertices, {} edges)",
        dataset.abbr(),
        device.name(),
        format.name(),
        components,
        fmt_count(g.num_vertices() as u64),
        fmt_count(g.num_edges()),
    );
    Ok(())
}

fn cmd_bench_storage(flags: &HashMap<String, String>) -> Result<()> {
    let devices: Vec<DeviceKind> = match flags.get("device") {
        Some(d) => vec![DeviceKind::parse(d).context("unknown --device")?],
        None => vec![DeviceKind::Hdd, DeviceKind::Ssd],
    };
    for device in devices {
        println!("\n{} read bandwidth (modeled, Fig. 4 grid):", device.name());
        let m = device.model();
        let mut table = Table::new(&["block", "threads", "method", "bandwidth"]);
        for &block in &[4u64 << 10, 4 << 20] {
            for &threads in &[1usize, 18, 36] {
                for method in ReadMethod::ALL {
                    let bw = m.aggregate_bandwidth(threads, block, method, true);
                    table.row(&[
                        fmt_bytes(block),
                        threads.to_string(),
                        method.name().to_string(),
                        fmt_bw(bw),
                    ]);
                }
            }
        }
        println!("{}", table.render());
    }
    Ok(())
}

fn cmd_sweep(flags: &HashMap<String, String>) -> Result<()> {
    let dataset =
        Dataset::parse(flag(flags, "dataset", "TW")).context("unknown --dataset")?;
    let device =
        DeviceKind::parse(flag(flags, "device", "HDD")).context("unknown --device")?;
    let scale = flag_usize(flags, "scale", 1);
    let (_g, store, base) = prepare(dataset, device, FormatKind::WebGraph, scale, 42);
    let pg = Paragrapher::init();
    let mut table = Table::new(&["threads", "buffer edges", "throughput"]);
    for &threads in &[2usize, 4, 9] {
        for &buffer_edges in &[64u64 << 10, 512 << 10, 1 << 20] {
            store.drop_cache();
            let opts = Options {
                buffers: threads,
                buffer_edges,
                read_ctx: ReadCtx { threads, ..ReadCtx::default() },
                ..Options::default()
            };
            let graph =
                pg.open_graph(Arc::clone(&store), &base, GraphType::CsxWg400, opts)?;
            let t0 = std::time::Instant::now();
            let block = graph.load_whole_graph()?;
            let elapsed = t0.elapsed().as_secs_f64() + graph.sequential_seconds();
            let meps = block.num_edges() as f64 / elapsed / 1e6;
            table.row(&[threads.to_string(), fmt_count(buffer_edges), fmt_meps(meps)]);
        }
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_end_to_end(flags: &HashMap<String, String>) -> Result<()> {
    let scale = flag_usize(flags, "scale", 1);
    println!(
        "running the end-to-end pipeline at scale {scale} — see examples/end_to_end.rs for the full driver"
    );
    // Compact inline version: one dataset, all formats, two devices.
    let dataset = Dataset::Tw;
    for device in [DeviceKind::Hdd, DeviceKind::Ssd] {
        let mut table = Table::new(&["format", "throughput", "bandwidth"]);
        for format in FormatKind::ALL {
            let (g, store, base) = prepare(dataset, device, format, scale, 42);
            let threads = 4;
            let accounts: Vec<IoAccount> = (0..threads).map(|_| IoAccount::new()).collect();
            let ctx = ReadCtx { threads, ..ReadCtx::default() };
            let loaded = format.load_full(&store, &base, ctx, &accounts)?;
            assert_eq!(loaded.num_edges(), g.num_edges());
            let m = LoadMeasurement::from_accounts(&accounts, loaded.num_edges(), 0.0);
            table.row(&[
                format.name().to_string(),
                fmt_meps(m.me_per_sec()),
                fmt_bw(m.device_bandwidth()),
            ]);
        }
        println!("\nTW on {} (modeled):", device.name());
        println!("{}", table.render());
    }
    Ok(())
}

/// `calibrate-decode`: measure the achieved single-core decompression
/// bandwidth `d` (the §3 model's sequential-phase bound) on a seeded
/// generated graph and print it next to the model's assumed value — the
/// feedback loop that keeps the performance model honest about what the
/// word-at-a-time decode engine actually delivers. Markdown output so the
/// CI job summary can ingest it directly.
fn cmd_calibrate_decode(flags: &HashMap<String, String>) -> Result<()> {
    let scale = flag_usize(flags, "scale", 1);
    let seed = flag_usize(flags, "seed", 42) as u64;
    let repeats = flag_usize(flags, "repeats", 5);
    let assumed_d = flag_f64(flags, "d", 1.0e9); // the §3 default assumption
    let cal = paragrapher::bench::workloads::calibrate_decode(scale, seed, repeats)?;
    println!(
        "### decode calibration (BA {}×8, seed {seed}, best of {repeats})\n",
        fmt_count(cal.vertices as u64)
    );
    println!("| metric | value |");
    println!("|---|---|");
    println!("| decoded_edges | {} |", fmt_count(cal.edges));
    println!("| compressed_stream | {} |", fmt_bytes(cal.stream_bytes));
    println!("| decode_throughput | {} |", fmt_meps(cal.edges_per_sec() / 1e6));
    println!("| measured_d | {} |", fmt_bw(cal.achieved_d()));
    println!("| model_assumed_d | {} |", fmt_bw(assumed_d));
    println!("| measured_over_assumed | {:.2}x |", cal.achieved_d() / assumed_d);
    println!(
        "| decode_table_hit_rate | {:.1}% ({} hits / {} misses) |",
        cal.table_hit_rate() * 100.0,
        cal.table_hits,
        cal.table_misses
    );
    Ok(())
}

/// `ci-summary`: markdown health metrics for the CI job summary — encoder
/// reference-chain depth, decoded-block cache hit rate, and the Elias–Fano
/// offsets footprint, on a fixed seeded graph so drift is comparable
/// across PRs.
fn cmd_ci_summary(_flags: &HashMap<String, String>) -> Result<()> {
    use paragrapher::formats::webgraph::{self, WgParams};
    use paragrapher::formats::{GraphSource, SourceConfig, WebGraphSource};
    use paragrapher::graph::generators;
    use paragrapher::storage::SimStore;

    let g = generators::barabasi_albert(20_000, 8, 42);
    let (_, _, stats) = webgraph::compress(&g, WgParams::default());

    let store = SimStore::new(DeviceKind::Dram);
    FormatKind::WebGraph.write_to_store(&g, &store, "ci");
    let src = WebGraphSource::open(&store, "ci", SourceConfig::default())
        .context("open webgraph source")?;
    // Zipf-ish probe mix: a hot block plus scattered cold vertices.
    let mut rng = paragrapher::util::rng::Xoshiro256::seed_from_u64(7);
    for i in 0..4000usize {
        let v = if i % 4 == 0 {
            rng.next_below(g.num_vertices() as u64) as usize
        } else {
            (i * 13) % 256 // hot set
        };
        let _ = src.successors(v)?;
    }
    let cache = src.cache_counters();
    let acct = IoAccount::new();
    let offs =
        webgraph::read_offsets(&store, "ci", paragrapher::storage::sim::ReadCtx::default(), &acct)?;

    println!("### paragrapher health metrics (BA 20k×8, seed 42)\n");
    println!("| metric | value |");
    println!("|---|---|");
    println!("| max_ref_chain_depth | {} |", stats.max_ref_chain_depth);
    println!("| vertices_with_reference | {} |", stats.vertices_with_reference);
    println!("| bits_per_edge | {:.2} |", stats.total_bits as f64 / g.num_edges() as f64);
    println!(
        "| decoded_cache_hit_rate | {} |",
        paragrapher::metrics::fmt_hit_rate(&cache)
    );
    println!("| decoded_cache (hits/misses/evictions) | {}/{}/{} |",
        cache.hits, cache.misses, cache.evictions);
    println!(
        "| ef_offsets_footprint | {} of {} plain ({:.1}%) |",
        fmt_bytes(offs.size_bytes() as u64),
        fmt_bytes(offs.plain_size_bytes() as u64),
        offs.size_bytes() as f64 * 100.0 / offs.plain_size_bytes() as f64
    );

    // Decode-bandwidth calibration: measured d vs the §3 model's assumed
    // d, plus the decode-table hit rate — the regression canary for the
    // word-at-a-time decode engine.
    {
        let assumed_d = 1.0e9;
        let cal = paragrapher::bench::workloads::calibrate_decode(1, 42, 3)?;
        println!(
            "| decode_measured_d | {} ({:.2}x of assumed {}) |",
            fmt_bw(cal.achieved_d()),
            cal.achieved_d() / assumed_d,
            fmt_bw(assumed_d)
        );
        println!(
            "| decode_table_hit_rate | {:.1}% ({} hits / {} misses) |",
            cal.table_hit_rate() * 100.0,
            cal.table_hits,
            cal.table_misses
        );
    }

    // Zero-copy delivery canaries: a full block-request load through the
    // coordinator — payload bytes delivered without a post-decode copy,
    // the post-decode copies themselves (invariant: 0 on the default
    // single-worker path), delivery throughput — plus the fused phase-2
    // scan throughput against the former scan-then-validate shape.
    {
        let store = Arc::new(SimStore::new(DeviceKind::Dram));
        FormatKind::WebGraph.write_to_store(&g, &store, "ci");
        let pg = Paragrapher::init();
        let graph = pg.open_graph(
            Arc::clone(&store),
            "ci",
            GraphType::CsxWg400,
            Options::default(),
        )?;
        let block = graph.load_whole_graph()?;
        anyhow::ensure!(block.num_edges() == graph.num_edges(), "ci load lost edges");
        anyhow::ensure!(
            graph.delivery_copy_bytes() == 0,
            "zero-copy invariant violated: {} bytes copied post-decode",
            graph.delivery_copy_bytes()
        );
        println!("| copy_bytes_avoided | {} |", fmt_bytes(graph.copy_bytes_avoided()));
        println!("| delivery_copy_bytes | {} (invariant: 0) |", graph.delivery_copy_bytes());
        println!(
            "| delivery_throughput | {} |",
            fmt_meps(graph.delivery_throughput() / 1e6)
        );
        let (fused, split) = paragrapher::bench::workloads::measure_fused_scan(1 << 20, 5);
        println!(
            "| fused_scan_throughput | {fused:.0} Melem/s ({:.2}x vs scan-then-validate {split:.0} Melem/s) |",
            fused / split
        );
    }

    // Partitioned-request health: a real 8-partition stream drained by two
    // consumers through the coordinator (prefetch hit rate), plus the
    // modeled HDD interleave overlap (deterministic virtual time).
    let plan = paragrapher::partition::PartitionPlan::one_d(&offs, 8);
    println!("| partition_plan_balance_factor | {:.3} |", plan.balance_factor());
    {
        let store = Arc::new(SimStore::new(DeviceKind::Dram));
        FormatKind::WebGraph.write_to_store(&g, &store, "ci");
        let pg = Paragrapher::init();
        let graph = pg.open_graph(
            Arc::clone(&store),
            "ci",
            GraphType::CsxWg400,
            Options::default(),
        )?;
        let stream = graph.csx_get_partitions(8)?;
        let edges = std::sync::atomic::AtomicU64::new(0);
        paragrapher::algorithms::partitioned::for_each_partition(&stream, 2, |p| {
            edges.fetch_add(p.num_edges(), std::sync::atomic::Ordering::Relaxed);
            Ok(())
        })?;
        anyhow::ensure!(
            edges.load(std::sync::atomic::Ordering::Relaxed) == graph.num_edges(),
            "partition stream must deliver every edge exactly once"
        );
        let c = stream.counters();
        println!(
            "| partition_prefetch_hit_rate | {:.1}% ({} hits / {} stalls) |",
            c.prefetch_hit_rate() * 100.0,
            c.prefetch_hits,
            c.consumer_stalls
        );
        println!("| partition_prefetch_window | {} |", graph.auto_prefetch_window());
    }
    {
        let store = SimStore::new(DeviceKind::Hdd);
        FormatKind::WebGraph.write_to_store(&g, &store, "ci");
        let run = paragrapher::bench::workloads::modeled_interleaved_run(
            &store, "ci", &plan, 4, 40.0,
        )?;
        println!(
            "| interleave_overlap (HDD, modeled) | {:.1}% ({:.2}× vs load-then-execute) |",
            run.overlap * 100.0,
            run.speedup()
        );
    }
    Ok(())
}
