//! ParaGrapher CLI — the leader entrypoint.
//!
//! ```text
//! paragrapher generate   --dataset TW --scale 2            # build dataset suite
//! paragrapher info       --dataset all                     # Table 3: sizes per format
//! paragrapher model      [--sigma 160e6 --d 1e9]           # Fig. 1 curve points
//! paragrapher load       --dataset G5 --device SSD --format webgraph [--threads 8]
//! paragrapher wcc        --dataset RD --device HDD --format webgraph
//! paragrapher bench-storage --device SSD                   # Fig. 4 grid
//! paragrapher sweep      --dataset TW --device HDD         # Fig. 8 grid
//! paragrapher end-to-end [--scale 1]                       # headline table
//! paragrapher trace      [--out trace.json --scale 1]      # dual-clock Chrome trace
//! ```
//!
//! (Hand-rolled argument parsing: the offline build has no clap.)

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use paragrapher::coordinator::{GraphType, Options, Paragrapher, VertexRange};
use paragrapher::formats::FormatKind;
use paragrapher::graph::generators::Dataset;
use paragrapher::metrics::{fmt_bw, fmt_meps, LoadMeasurement, Table};
use paragrapher::model::{fig1_curve, LoadModel};
use paragrapher::storage::sim::ReadCtx;
use paragrapher::storage::{DeviceKind, IoAccount, ReadMethod, SimStore};
use paragrapher::util::{fmt_bytes, fmt_count};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        print_usage();
        std::process::exit(2);
    }
    let cmd = args[0].clone();
    let flags = parse_flags(&args[1..]);
    let result = match cmd.as_str() {
        "generate" => cmd_generate(&flags),
        "info" => cmd_info(&flags),
        "model" => cmd_model(&flags),
        "load" => cmd_load(&flags),
        "wcc" => cmd_wcc(&flags),
        "bench-storage" => cmd_bench_storage(&flags),
        "sweep" => cmd_sweep(&flags),
        "end-to-end" => cmd_end_to_end(&flags),
        "calibrate-decode" => cmd_calibrate_decode(&flags),
        "out-of-core" => cmd_out_of_core(&flags),
        "distributed" => cmd_distributed(&flags),
        // The worker subcommand parses its own argv (the leader builds
        // it): the generic --flag map would eat positional mistakes.
        "worker" => cmd_worker(&args[1..]),
        "trace" => cmd_trace(&flags),
        "chaos" => cmd_chaos(&flags),
        "serve-stress" => cmd_serve_stress(&flags),
        "ci-summary" => cmd_ci_summary(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            Err(anyhow::anyhow!("unknown command {other:?}"))
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    eprintln!(
        "paragrapher — selective parallel loading of compressed graphs (paper reproduction)

commands:
  generate      --dataset <RD|TW|G5|SH|CW|MS|all> [--scale N] [--seed N]
  info          --dataset <..|all> [--scale N]            Table 3 sizes/bits-per-edge
  model         [--sigma B/s] [--d B/s] [--rmax R]        §3 / Fig. 1 curve
  load          --dataset D --device <HDD|SSD|NAS|NVMM|DDR4> --format <coo|csx|bin|webgraph>
                [--threads N] [--buffer-edges N] [--scale N]
  wcc           --dataset D --device DEV --format F       Fig. 6 style end-to-end WCC
  bench-storage [--device DEV]                            Fig. 4 bandwidth grid
  sweep         --dataset D --device DEV                  Fig. 8 threads×buffer grid
  end-to-end    [--scale N]                               full pipeline + headline table
  calibrate-decode [--scale N] [--seed N] [--repeats N] [--d B/s]
                                                          measured vs modeled decompression bandwidth d
  out-of-core   [--vertices N] [--degree D] [--budget-mb N] [--device DEV] [--workers N]
                [--seed N] [--dir PATH] [--assert-rss] [--keep]
                                                          larger-than-budget load via the mmap store
  distributed   [--workers N] [--rows R] [--cols C] [--dataset D] [--device DEV] [--scale N]
                [--seed N] [--tile-timeout-ms N] [--max-attempts N]
                [--fault-inject kill-worker:<n>|stall-worker:<n>] [--dir PATH] [--keep]
                                                          multi-process leader/worker load,
                                                          modeled-vs-measured scaling + oracle check
  worker        --connect HOST:PORT --dir PATH [--base B] [--graph-type T] [--device DEV]
                [--index N] [--fault SPEC]                one worker process (spawned by the leader)
  trace         [--out PATH] [--scale N] [--seed N]       run a seeded load exercising every
                                                          request kind, export the dual-clock
                                                          Chrome trace (Perfetto-viewable)
  chaos         [--seed N] [--vertices N] [--timeout-s N] [--dir PATH] [--keep]
                                                          seeded fault-injection campaign over a
                                                          real on-disk graph: checksum-classified
                                                          retries, quarantine, mmap->pread
                                                          degradation, oracle-checked recovery
  serve-stress  [--seed N] [--scale N] [--requests N] [--exec-workers N] [--p99-factor F]
                [--json PATH] [--timeout-s N] [--no-churn] [--no-faults]
                                                          multi-tenant serving campaign: DRR
                                                          fairness, overload shedding, deadline
                                                          expiry, mid-run graph churn, fault
                                                          isolation; per-tenant tail latencies
  ci-summary    [--scale N] [--seed N] [--json PATH]      markdown health metrics for CI;
                                                          --json also writes the merged
                                                          metrics-registry snapshot

most load-path commands also take --cache-mb N (simulated page-cache budget, default 8192)
set PG_OBS=off to disable span/histogram recording (counters stay on)"
    );
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let value = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            flags.insert(key.to_string(), value);
        }
        i += 1;
    }
    flags
}

fn flag<'a>(flags: &'a HashMap<String, String>, key: &str, default: &'a str) -> &'a str {
    flags.get(key).map(|s| s.as_str()).unwrap_or(default)
}

fn flag_usize(flags: &HashMap<String, String>, key: &str, default: usize) -> usize {
    flags.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn flag_f64(flags: &HashMap<String, String>, key: &str, default: f64) -> f64 {
    flags.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// `--cache-mb N` → a simulated page-cache budget in bytes for
/// [`Options::cache_budget`]; absent = keep the store's default (8 GiB).
fn cache_budget_flag(flags: &HashMap<String, String>) -> Option<u64> {
    flags.get("cache-mb").and_then(|s| s.parse::<u64>().ok()).map(|mb| mb << 20)
}

fn datasets_from(flags: &HashMap<String, String>) -> Result<Vec<Dataset>> {
    let spec = flag(flags, "dataset", "all");
    if spec.eq_ignore_ascii_case("all") {
        return Ok(Dataset::ALL.to_vec());
    }
    let mut out = Vec::new();
    for part in spec.split(',') {
        out.push(Dataset::parse(part).with_context(|| format!("unknown dataset {part:?}"))?);
    }
    Ok(out)
}

fn cmd_generate(flags: &HashMap<String, String>) -> Result<()> {
    let scale = flag_usize(flags, "scale", 1);
    let seed = flag_usize(flags, "seed", 42) as u64;
    for d in datasets_from(flags)? {
        let g = d.generate(scale, seed);
        println!(
            "{}: |V| = {} |E| = {}",
            d.abbr(),
            fmt_count(g.num_vertices() as u64),
            fmt_count(g.num_edges())
        );
    }
    Ok(())
}

fn cmd_info(flags: &HashMap<String, String>) -> Result<()> {
    let scale = flag_usize(flags, "scale", 1);
    let seed = flag_usize(flags, "seed", 42) as u64;
    let store = SimStore::new(DeviceKind::Dram);
    let mut table = Table::new(&[
        "Abbr", "|V|", "|E|", "Txt. COO", "Txt. CSX", "Bin. CSX", "WebGraph", "WG bits/edge",
    ]);
    for d in datasets_from(flags)? {
        let g = d.generate(scale, seed);
        let mut sizes = Vec::new();
        let mut wg_bpe = 0.0;
        for fk in FormatKind::ALL {
            let base = format!("{}-{:?}", d.abbr(), fk);
            let bytes = fk.write_to_store(&g, &store, &base);
            sizes.push(fmt_bytes(bytes));
            if fk == FormatKind::WebGraph {
                wg_bpe = fk.bits_per_edge(&g, &store, &base);
            }
        }
        table.row(&[
            d.abbr().to_string(),
            fmt_count(g.num_vertices() as u64),
            fmt_count(g.num_edges()),
            sizes[0].clone(),
            sizes[1].clone(),
            sizes[2].clone(),
            sizes[3].clone(),
            format!("{wg_bpe:.1}"),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_model(flags: &HashMap<String, String>) -> Result<()> {
    let sigma = flag_f64(flags, "sigma", 160e6);
    let d = flag_f64(flags, "d", 1.0e9);
    let rmax = flag_f64(flags, "rmax", 35.0);
    println!("load bandwidth model: sigma <= b <= min(sigma*r, d)   (Fig. 1)");
    println!("sigma = {}, d = {}", fmt_bw(sigma), fmt_bw(d));
    let m = LoadModel { sigma, r: rmax, d };
    println!("knee at r* = d/sigma = {:.2}", m.knee_ratio());
    let mut table = Table::new(&["r", "upper bound"]);
    for p in fig1_curve(sigma, d, rmax, 12) {
        table.row(&[format!("{:.1}", p.r), fmt_bw(p.bound)]);
    }
    println!("{}", table.render());
    Ok(())
}

/// Prepare a store holding `dataset` in `format`, return (graph, store, base).
fn prepare(
    dataset: Dataset,
    device: DeviceKind,
    format: FormatKind,
    scale: usize,
    seed: u64,
) -> (paragrapher::graph::CsrGraph, Arc<SimStore>, String) {
    let g = dataset.generate(scale, seed);
    let store = Arc::new(SimStore::new(device));
    let base = dataset.abbr().to_string();
    format.write_to_store(&g, &store, &base);
    store.drop_cache();
    (g, store, base)
}

fn cmd_load(flags: &HashMap<String, String>) -> Result<()> {
    let dataset =
        Dataset::parse(flag(flags, "dataset", "RD")).context("unknown --dataset")?;
    let device =
        DeviceKind::parse(flag(flags, "device", "SSD")).context("unknown --device")?;
    let format =
        FormatKind::parse(flag(flags, "format", "webgraph")).context("unknown --format")?;
    let threads = flag_usize(flags, "threads", 4);
    let scale = flag_usize(flags, "scale", 1);
    let buffer_edges = flag_usize(flags, "buffer-edges", 1 << 20) as u64;
    let (g, store, base) = prepare(dataset, device, format, scale, 42);

    let measurement = if format == FormatKind::WebGraph {
        // Through the coordinator (the ParaGrapher path).
        let pg = Paragrapher::init();
        let opts = Options {
            buffers: threads,
            buffer_edges,
            read_ctx: ReadCtx { threads, ..ReadCtx::default() },
            cache_budget: cache_budget_flag(flags),
            ..Options::default()
        };
        let graph = pg.open_graph(Arc::clone(&store), &base, GraphType::CsxWg400, opts)?;
        let t0 = std::time::Instant::now();
        let block = graph.load_whole_graph()?;
        let wall = t0.elapsed().as_secs_f64();
        let seq = graph.sequential_seconds();
        println!(
            "decoded {} edges (wall {:.3}s, sequential open {:.3}s)",
            fmt_count(block.num_edges()),
            wall,
            seq
        );
        LoadMeasurement {
            elapsed: wall + seq,
            edges: block.num_edges(),
            device_bytes: store.device_bytes(),
        }
    } else {
        // GAPBS-style baseline full load.
        let accounts: Vec<IoAccount> = (0..threads).map(|_| IoAccount::new()).collect();
        let ctx = ReadCtx { threads, ..ReadCtx::default() };
        let loaded = format.load_full(&store, &base, ctx, &accounts)?;
        LoadMeasurement::from_accounts(&accounts, loaded.num_edges(), 0.0)
    };
    println!(
        "{} / {} / {}: {} ({} modeled)",
        dataset.abbr(),
        device.name(),
        format.name(),
        fmt_meps(measurement.me_per_sec()),
        fmt_bw(measurement.device_bandwidth()),
    );
    let _ = g;
    Ok(())
}

fn cmd_wcc(flags: &HashMap<String, String>) -> Result<()> {
    let dataset =
        Dataset::parse(flag(flags, "dataset", "RD")).context("unknown --dataset")?;
    let device =
        DeviceKind::parse(flag(flags, "device", "SSD")).context("unknown --device")?;
    let format =
        FormatKind::parse(flag(flags, "format", "webgraph")).context("unknown --format")?;
    let threads = flag_usize(flags, "threads", 4);
    let scale = flag_usize(flags, "scale", 1);
    let (g, store, base) = prepare(dataset, device, format, scale, 42);

    let components = if format == FormatKind::WebGraph {
        // ParaGrapher + streaming JT-CC over async blocks (§5.3).
        let pg = Paragrapher::init();
        let opts = Options {
            buffers: threads,
            read_ctx: ReadCtx { threads, ..ReadCtx::default() },
            cache_budget: cache_budget_flag(flags),
            ..Options::default()
        };
        let graph = pg.open_graph(Arc::clone(&store), &base, GraphType::CsxWg400, opts)?;
        let uf = Arc::new(paragrapher::algorithms::jtcc::JtUnionFind::new(
            graph.num_vertices(),
            7,
        ));
        let uf2 = Arc::clone(&uf);
        let req = graph.csx_get_subgraph(
            VertexRange::new(0, graph.num_vertices()),
            Arc::new(move |blk| {
                for (s, d) in blk.iter_edges() {
                    uf2.union(s, d);
                }
            }),
        )?;
        req.wait();
        if let Some(e) = req.error() {
            bail!("load failed: {e}");
        }
        uf.count_components()
    } else {
        // Baseline: full load then Afforest.
        let accounts: Vec<IoAccount> = (0..threads).map(|_| IoAccount::new()).collect();
        let ctx = ReadCtx { threads, ..ReadCtx::default() };
        let loaded = format.load_full(&store, &base, ctx, &accounts)?;
        let labels = paragrapher::algorithms::afforest::afforest(&loaded, 7);
        paragrapher::algorithms::count_components(&labels)
    };
    println!(
        "{} / {} / {}: {} weakly-connected components ({} vertices, {} edges)",
        dataset.abbr(),
        device.name(),
        format.name(),
        components,
        fmt_count(g.num_vertices() as u64),
        fmt_count(g.num_edges()),
    );
    Ok(())
}

fn cmd_bench_storage(flags: &HashMap<String, String>) -> Result<()> {
    let devices: Vec<DeviceKind> = match flags.get("device") {
        Some(d) => vec![DeviceKind::parse(d).context("unknown --device")?],
        None => vec![DeviceKind::Hdd, DeviceKind::Ssd],
    };
    for device in devices {
        println!("\n{} read bandwidth (modeled, Fig. 4 grid):", device.name());
        let m = device.model();
        let mut table = Table::new(&["block", "threads", "method", "bandwidth"]);
        for &block in &[4u64 << 10, 4 << 20] {
            for &threads in &[1usize, 18, 36] {
                for method in ReadMethod::ALL {
                    let bw = m.aggregate_bandwidth(threads, block, method, true);
                    table.row(&[
                        fmt_bytes(block),
                        threads.to_string(),
                        method.name().to_string(),
                        fmt_bw(bw),
                    ]);
                }
            }
        }
        println!("{}", table.render());
    }
    Ok(())
}

fn cmd_sweep(flags: &HashMap<String, String>) -> Result<()> {
    let dataset =
        Dataset::parse(flag(flags, "dataset", "TW")).context("unknown --dataset")?;
    let device =
        DeviceKind::parse(flag(flags, "device", "HDD")).context("unknown --device")?;
    let scale = flag_usize(flags, "scale", 1);
    let (_g, store, base) = prepare(dataset, device, FormatKind::WebGraph, scale, 42);
    let pg = Paragrapher::init();
    let mut table = Table::new(&["threads", "buffer edges", "throughput"]);
    for &threads in &[2usize, 4, 9] {
        for &buffer_edges in &[64u64 << 10, 512 << 10, 1 << 20] {
            store.drop_cache();
            let opts = Options {
                buffers: threads,
                buffer_edges,
                read_ctx: ReadCtx { threads, ..ReadCtx::default() },
                cache_budget: cache_budget_flag(flags),
                ..Options::default()
            };
            let graph =
                pg.open_graph(Arc::clone(&store), &base, GraphType::CsxWg400, opts)?;
            let t0 = std::time::Instant::now();
            let block = graph.load_whole_graph()?;
            let elapsed = t0.elapsed().as_secs_f64() + graph.sequential_seconds();
            let meps = block.num_edges() as f64 / elapsed / 1e6;
            table.row(&[threads.to_string(), fmt_count(buffer_edges), fmt_meps(meps)]);
        }
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_end_to_end(flags: &HashMap<String, String>) -> Result<()> {
    let scale = flag_usize(flags, "scale", 1);
    println!(
        "running the end-to-end pipeline at scale {scale} — see examples/end_to_end.rs for the full driver"
    );
    // Compact inline version: one dataset, all formats, two devices.
    let dataset = Dataset::Tw;
    for device in [DeviceKind::Hdd, DeviceKind::Ssd] {
        let mut table = Table::new(&["format", "throughput", "bandwidth"]);
        for format in FormatKind::ALL {
            let (g, store, base) = prepare(dataset, device, format, scale, 42);
            let threads = 4;
            let accounts: Vec<IoAccount> = (0..threads).map(|_| IoAccount::new()).collect();
            let ctx = ReadCtx { threads, ..ReadCtx::default() };
            let loaded = format.load_full(&store, &base, ctx, &accounts)?;
            assert_eq!(loaded.num_edges(), g.num_edges());
            let m = LoadMeasurement::from_accounts(&accounts, loaded.num_edges(), 0.0);
            table.row(&[
                format.name().to_string(),
                fmt_meps(m.me_per_sec()),
                fmt_bw(m.device_bandwidth()),
            ]);
        }
        println!("\nTW on {} (modeled):", device.name());
        println!("{}", table.render());
    }
    Ok(())
}

/// `calibrate-decode`: measure the achieved single-core decompression
/// bandwidth `d` (the §3 model's sequential-phase bound) on a seeded
/// generated graph and print it next to the model's assumed value — the
/// feedback loop that keeps the performance model honest about what the
/// word-at-a-time decode engine actually delivers. Markdown output so the
/// CI job summary can ingest it directly.
fn cmd_calibrate_decode(flags: &HashMap<String, String>) -> Result<()> {
    let scale = flag_usize(flags, "scale", 1);
    let seed = flag_usize(flags, "seed", 42) as u64;
    let repeats = flag_usize(flags, "repeats", 5);
    let assumed_d = flag_f64(flags, "d", 1.0e9); // the §3 default assumption
    let cal = paragrapher::bench::workloads::calibrate_decode(scale, seed, repeats)?;
    println!(
        "### decode calibration (BA {}×8, seed {seed}, best of {repeats})\n",
        fmt_count(cal.vertices as u64)
    );
    println!("| metric | value |");
    println!("|---|---|");
    println!("| decoded_edges | {} |", fmt_count(cal.edges));
    println!("| compressed_stream | {} |", fmt_bytes(cal.stream_bytes));
    println!("| decode_throughput | {} |", fmt_meps(cal.edges_per_sec() / 1e6));
    println!("| measured_d | {} |", fmt_bw(cal.achieved_d()));
    println!("| model_assumed_d | {} |", fmt_bw(assumed_d));
    println!("| measured_over_assumed | {:.2}x |", cal.achieved_d() / assumed_d);
    println!(
        "| decode_table_hit_rate | {:.1}% ({} hits / {} misses) |",
        cal.table_hit_rate() * 100.0,
        cal.table_hits,
        cal.table_misses
    );
    Ok(())
}

/// `out-of-core`: the larger-than-RAM proof. Stream-write a compressed
/// graph bigger than the configured page-cache budget to real files (the
/// graph never exists in memory), load it back through the mmap-backed
/// store with the budget enforced — model eviction mirrored to
/// `madvise(DONTNEED)` so real residency tracks the virtual cache — and
/// verify every decoded edge against the regenerating oracle. One decode
/// pass per read method gives the mmap-vs-pread comparison on the same
/// fixture. Markdown output for the CI job summary.
fn cmd_out_of_core(flags: &HashMap<String, String>) -> Result<()> {
    use paragrapher::formats::webgraph::{self, DecodeSink, Decoder, WgParams};
    use paragrapher::graph::generators;
    use paragrapher::storage::reader::ReaderImpl;
    use paragrapher::storage::GraphStore;

    let n = flag_usize(flags, "vertices", 1 << 22);
    let deg = flag_usize(flags, "degree", 16);
    let budget = (flag_usize(flags, "budget-mb", 16) as u64) << 20;
    let device =
        DeviceKind::parse(flag(flags, "device", "SSD")).context("unknown --device")?;
    let workers = flag_usize(flags, "workers", 4).max(1);
    let seed = flag_usize(flags, "seed", 42) as u64;
    let dir = match flags.get("dir") {
        Some(d) => std::path::PathBuf::from(d),
        None => std::env::temp_dir().join("pg_out_of_core"),
    };
    std::fs::create_dir_all(&dir).with_context(|| format!("create {}", dir.display()))?;

    // Phase 1: stream the fixture to disk (generator → encoder window →
    // 1 MiB flushes; footprint stays O(window · degree) + the γ-compressed
    // offset deltas).
    let t0 = std::time::Instant::now();
    let streamed =
        webgraph::write_stream_to_dir(&dir, "ooc", n, WgParams::default(), |v, out| {
            generators::synthetic_successors(v, n, deg, seed, out)
        })?;
    let gen_wall = t0.elapsed().as_secs_f64();
    let m = streamed.num_edges;
    let compressed: u64 = ["ooc.graph", "ooc.offsets", "ooc.properties"]
        .iter()
        .map(|f| std::fs::metadata(dir.join(f)).map(|md| md.len()).unwrap_or(0))
        .sum();

    // Phase 2: chunked decode through the mmap store under the budget.
    // ~1M-edge chunks keep the resident working set far below the fixture.
    let chunk_v = (((1u64 << 20) * n as u64) / m.max(1)).max(1) as usize;
    let mut rows: Vec<(&str, f64, f64)> = Vec::new();
    for (label, method, reader, verify) in [
        ("load/mmap", ReadMethod::Mmap, ReaderImpl::ZeroCopy, true),
        ("load/pread", ReadMethod::Pread, ReaderImpl::ZeroCopy, false),
        ("load/buffered-copy", ReadMethod::Pread, ReaderImpl::BufferedCopy, false),
    ] {
        let store = GraphStore::open_dir_with(&dir, device.model(), budget)?;
        let acct0 = IoAccount::new();
        let ctx =
            ReadCtx { threads: workers, method, reader_impl: reader, ..ReadCtx::default() };
        let meta = webgraph::read_meta(&store, "ooc", ctx, &acct0)?;
        let offsets = webgraph::read_offsets(&store, "ooc", ctx, &acct0)?;
        let dec = Decoder::open(&store, "ooc", &meta, &offsets, ctx, &acct0)?;
        let accounts: Vec<IoAccount> = (0..workers).map(|_| IoAccount::new()).collect();
        let scan = paragrapher::runtime::NativeScan;
        let mut off_buf: Vec<u64> = Vec::new();
        let mut edge_buf: Vec<paragrapher::graph::VertexId> = Vec::new();
        let mut oracle: Vec<paragrapher::graph::VertexId> = Vec::new();
        let mut stitched = 0u64;
        let mut edges_seen = 0u64;
        let t = std::time::Instant::now();
        let mut vs = 0usize;
        while vs < n {
            let ve = (vs + chunk_v).min(n);
            let mut sink = DecodeSink::new(&mut off_buf, &mut edge_buf);
            stitched +=
                dec.decode_range_parallel_sink(vs, ve, &accounts, &scan, None, &mut sink)?;
            edges_seen += *off_buf.last().unwrap_or(&0);
            if verify {
                for v in vs..ve {
                    let (a, b) = (off_buf[v - vs] as usize, off_buf[v - vs + 1] as usize);
                    generators::synthetic_successors(v, n, deg, seed, &mut oracle);
                    anyhow::ensure!(
                        edge_buf[a..b] == oracle[..],
                        "decode disagrees with the oracle at vertex {v}"
                    );
                }
            }
            vs = ve;
        }
        let wall = t.elapsed().as_secs_f64();
        anyhow::ensure!(edges_seen == m, "{label}: decoded {edges_seen} of {m} edges");
        anyhow::ensure!(stitched == 0, "{label}: fan-out copied {stitched} bytes post-decode");
        let io = accounts.iter().map(|a| a.io_seconds()).sum::<f64>() + acct0.io_seconds();
        rows.push((label, wall, io));
    }
    let peak = peak_rss_bytes();

    println!("### out-of-core load (mmap-backed real-file store)\n");
    println!("| metric | value |");
    println!("|---|---|");
    println!(
        "| graph | {} vertices, {} edges (synthetic stream, seed {seed}) |",
        fmt_count(n as u64),
        fmt_count(m)
    );
    println!(
        "| compressed_on_disk | {} ({:.2} bits/edge) |",
        fmt_bytes(compressed),
        streamed.total_bits as f64 / m.max(1) as f64
    );
    println!("| page_cache_budget | {} ({}) |", fmt_bytes(budget), device.name());
    println!("| generate_wall | {gen_wall:.2}s (streamed, never materialized) |");
    for (label, wall, io) in &rows {
        println!(
            "| {label} | {wall:.2}s wall ({}), modeled I/O {io:.2}s |",
            fmt_meps(m as f64 / wall / 1e6)
        );
    }
    println!("| oracle | every edge verified on the mmap pass |");
    println!("| delivery_copy_bytes | 0 (pre-partitioned fan-out, {workers} workers) |");
    if let Some(p) = peak {
        println!(
            "| peak_rss | {} ({:.0}% of compressed) |",
            fmt_bytes(p),
            p as f64 * 100.0 / compressed.max(1) as f64
        );
    }
    if flags.contains_key("assert-rss") {
        let p = peak.context("VmHWM unavailable; cannot --assert-rss")?;
        anyhow::ensure!(
            p < compressed,
            "peak RSS {} is not below the {} compressed fixture",
            fmt_bytes(p),
            fmt_bytes(compressed)
        );
        println!("| rss_assertion | PASS (peak RSS below the on-disk fixture) |");
    }
    if !flags.contains_key("keep") && !flags.contains_key("dir") {
        std::fs::remove_dir_all(&dir).ok();
    }
    Ok(())
}

/// `worker`: one distributed worker process. Spawned by a leader
/// (`distributed`, the rewritten example, or the tests) — never by hand.
fn cmd_worker(args: &[String]) -> Result<()> {
    let cfg = paragrapher::distributed::WorkerConfig::from_args(args)?;
    paragrapher::distributed::run_worker(&cfg)
}

/// `--fault-inject kill-worker:<n>` / `stall-worker:<n>` → the worker
/// fault spec the leader forwards: the named worker completes one tile,
/// then dies (or stalls) mid-second-tile — the deterministic retile
/// exercise.
fn parse_fault_inject(spec: &str) -> Result<(usize, String)> {
    let (kind, n) = spec
        .split_once(':')
        .with_context(|| format!("--fault-inject {spec:?}: want kind:<worker>"))?;
    let worker: usize = n.parse().with_context(|| format!("--fault-inject {spec:?}"))?;
    match kind {
        "kill-worker" => Ok((worker, "kill-after:1".to_string())),
        "stall-worker" => Ok((worker, "stall-after:1".to_string())),
        _ => bail!("--fault-inject {spec:?}: want kill-worker:<n> or stall-worker:<n>"),
    }
}

/// `distributed`: real multi-process loading of one on-disk graph — a
/// 1-worker baseline run, then the requested worker count (with optional
/// fault injection), every tile checked against the single-process
/// full-load oracle, and measured scaling printed next to the §3 modeled
/// bound min(σ·r, w·d)/min(σ·r, d).
fn cmd_distributed(flags: &HashMap<String, String>) -> Result<()> {
    use paragrapher::bench::workloads::modeled_distributed_speedup;
    use paragrapher::distributed::{oracle_tile_summaries, run_leader, LeaderConfig};
    use paragrapher::formats::webgraph;

    let dataset = Dataset::parse(flag(flags, "dataset", "TW")).context("unknown --dataset")?;
    let device = DeviceKind::parse(flag(flags, "device", "SSD")).context("unknown --device")?;
    let workers = flag_usize(flags, "workers", 2).max(1);
    let rows = flag_usize(flags, "rows", 3);
    let cols = flag_usize(flags, "cols", 3);
    let scale = flag_usize(flags, "scale", 1);
    let seed = flag_usize(flags, "seed", 42) as u64;
    let tile_timeout =
        std::time::Duration::from_millis(flag_usize(flags, "tile-timeout-ms", 20_000) as u64);
    let max_attempts = flag_usize(flags, "max-attempts", 3);
    let fault_args = match flags.get("fault-inject") {
        Some(spec) => vec![parse_fault_inject(spec)?],
        None => Vec::new(),
    };
    let dir = match flags.get("dir") {
        Some(d) => std::path::PathBuf::from(d),
        None => std::env::temp_dir().join(format!("pg_distributed_{}", std::process::id())),
    };
    std::fs::create_dir_all(&dir).with_context(|| format!("create {}", dir.display()))?;

    // Every process opens this same on-disk fixture independently.
    let g = dataset.generate(scale, seed);
    for (name, data) in webgraph::serialize(&g, "dist") {
        std::fs::write(dir.join(&name), &data).with_context(|| name.clone())?;
    }
    let exe = std::env::current_exe().context("current_exe")?;
    let mut cfg = LeaderConfig::new(
        &dir,
        "dist",
        GraphType::CsxWg400,
        device,
        vec![exe.to_string_lossy().into_owned(), "worker".to_string()],
    );
    cfg.rows = rows;
    cfg.cols = cols;
    cfg.tile_timeout = tile_timeout;
    cfg.max_attempts = max_attempts;

    let one = run_leader(&LeaderConfig { workers: 1, ..cfg.clone() })?;
    cfg.workers = workers;
    cfg.fault_args = fault_args;
    let multi = run_leader(&cfg)?;

    // Single-process oracle over the same plan, plus the §3 model.
    let pg = Paragrapher::init();
    let graph =
        pg.open_graph_from_dir(&dir, device, "dist", GraphType::CsxWg400, Options::default())?;
    let oracle = oracle_tile_summaries(&graph, multi.plan.clone())?;
    let model = graph.load_model();
    pg.release_graph(graph);
    for t in &multi.tiles {
        anyhow::ensure!(
            (t.edges, t.checksum) == oracle[t.tile],
            "tile {} disagrees with the single-process oracle",
            t.tile
        );
    }
    anyhow::ensure!(
        multi.edges_delivered == one.edges_delivered,
        "worker counts disagree on total edges delivered"
    );

    let measured = one.wall_seconds / multi.wall_seconds.max(1e-9);
    let modeled = modeled_distributed_speedup(&model, workers);
    let mut table = Table::new(&["run", "workers", "tiles", "edges", "lost", "retiled", "wall"]);
    for (label, r) in [("baseline", &one), ("scaled", &multi)] {
        table.row(&[
            label.to_string(),
            r.workers_spawned.to_string(),
            r.tiles.len().to_string(),
            fmt_count(r.edges_delivered),
            r.workers_lost.to_string(),
            r.retiled_tiles.to_string(),
            format!("{:.2}s", r.wall_seconds),
        ]);
    }
    println!("{}", table.render());
    println!(
        "every tile matches the single-process oracle; {workers}-worker speedup {measured:.2}x \
         measured vs {modeled:.2}x modeled (min(sigma*r, w*d)/min(sigma*r, d))"
    );
    if !multi.worker_metrics.is_empty() {
        println!(
            "\nlatency histograms merged from {} worker metrics frames \
             (retiles {}, workers lost {}):",
            multi.worker_metrics.len(),
            multi.metrics.counters.get(paragrapher::obs::names::DIST_RETILES).copied().unwrap_or(0),
            multi
                .metrics
                .counters
                .get(paragrapher::obs::names::DIST_WORKERS_LOST)
                .copied()
                .unwrap_or(0),
        );
        let mut mtable = Table::new(&["metric", "samples", "p50", "p95", "p99", "max"]);
        let rows = paragrapher::obs::names::REQUEST_KINDS.into_iter().chain([
            ("buffer-claim", paragrapher::obs::names::BUFFER_CLAIM_WAIT),
            ("decode-block (real)", paragrapher::obs::names::DECODE_BLOCK_REAL),
            ("decode-block (virt)", paragrapher::obs::names::DECODE_BLOCK_VIRT),
        ]);
        for (label, key) in rows {
            if let Some(h) = multi.metrics.hists.get(key) {
                if h.total > 0 {
                    mtable.row(&[
                        label.to_string(),
                        h.total.to_string(),
                        fmt_ns(h.percentile(0.5)),
                        fmt_ns(h.percentile(0.95)),
                        fmt_ns(h.percentile(0.99)),
                        fmt_ns(h.max),
                    ]);
                }
            }
        }
        println!("{}", mtable.render());
    }
    if !flags.contains_key("keep") && !flags.contains_key("dir") {
        std::fs::remove_dir_all(&dir).ok();
    }
    Ok(())
}

/// Process-lifetime peak RSS (`VmHWM`) from /proc — the out-of-core
/// measurement. `None` off Linux.
fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 =
        line.trim_start_matches("VmHWM:").trim().trim_end_matches("kB").trim().parse().ok()?;
    Some(kb * 1024)
}

/// Human nanoseconds for the latency tables (`850ns`, `1.2µs`, `3.45ms`).
fn fmt_ns(ns: u64) -> String {
    let ns = ns as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.1}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// `trace`: run one seeded load that exercises every request kind (whole
/// CSX, COO edge range, successors probes, a drained partition stream)
/// and export the always-on tracer's dual-clock Chrome trace via
/// [`Options::trace_path`]. The library records these spans regardless —
/// this command just packages a representative workload with the export,
/// so CI (and humans) get a Perfetto-viewable timeline in one shot.
fn cmd_trace(flags: &HashMap<String, String>) -> Result<()> {
    use paragrapher::graph::generators;
    use paragrapher::obs;

    let scale = flag_usize(flags, "scale", 1).max(1);
    let seed = flag_usize(flags, "seed", 42) as u64;
    let out = std::path::PathBuf::from(flag(flags, "out", "trace.json"));

    let g = generators::barabasi_albert(10_000 * scale, 8, seed);
    let store = Arc::new(SimStore::new(DeviceKind::Dram));
    FormatKind::WebGraph.write_to_store(&g, &store, "trace");
    let pg = Paragrapher::init();
    let opts = Options { trace_path: Some(out.clone()), ..Options::default() };
    let graph = pg.open_graph(Arc::clone(&store), "trace", GraphType::CsxWg400, opts)?;

    // Whole-graph CSX load: request + buffer + decode + delivery spans.
    let block = graph.load_whole_graph()?;
    anyhow::ensure!(block.num_edges() == g.num_edges(), "trace load lost edges");
    // A COO edge-range request.
    let coo_edges = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let coo_edges2 = Arc::clone(&coo_edges);
    let req = graph.coo_get_edges(
        0,
        graph.num_edges().min(50_000),
        Arc::new(move |blk| {
            coo_edges2.fetch_add(blk.num_edges(), std::sync::atomic::Ordering::Relaxed);
        }),
    )?;
    req.wait();
    if let Some(e) = req.error() {
        bail!("trace coo load failed: {e}");
    }
    // Random-access successors probes.
    let stride = (graph.num_vertices() / 64).max(1);
    for v in (0..graph.num_vertices()).step_by(stride) {
        let _ = graph.successors(v)?;
    }
    // A drained partition stream (stream-category spans on contention).
    let stream = graph.csx_get_partitions(8)?;
    let part_edges = std::sync::atomic::AtomicU64::new(0);
    paragrapher::algorithms::partitioned::for_each_partition(&stream, 2, |p| {
        part_edges.fetch_add(p.num_edges(), std::sync::atomic::Ordering::Relaxed);
        Ok(())
    })?;
    anyhow::ensure!(
        part_edges.load(std::sync::atomic::Ordering::Relaxed) == g.num_edges(),
        "trace partition stream lost edges"
    );

    let snap = graph.metrics_snapshot();
    // Release exports the trace (Options::trace_path).
    pg.release_graph(graph);

    let (spans, dropped) = obs::tracer().snapshot();
    let mut cats: std::collections::BTreeMap<&str, usize> = std::collections::BTreeMap::new();
    for s in &spans {
        *cats.entry(s.cat).or_insert(0) += 1;
    }
    anyhow::ensure!(
        cats.len() >= 4,
        "expected spans from at least 4 categories, got {cats:?}"
    );
    println!(
        "wrote {} — {} spans retained ({} dropped by the rings), seed {seed}",
        out.display(),
        spans.len(),
        dropped
    );
    let mut table = Table::new(&["span category", "spans"]);
    for (cat, n) in &cats {
        table.row(&[cat.to_string(), n.to_string()]);
    }
    println!("{}", table.render());
    let mut lat = Table::new(&["request kind", "samples", "p50", "p95", "p99", "max"]);
    for (label, key) in paragrapher::obs::names::REQUEST_KINDS {
        if let Some(h) = snap.hists.get(key) {
            if h.total > 0 {
                lat.row(&[
                    label.to_string(),
                    h.total.to_string(),
                    fmt_ns(h.percentile(0.5)),
                    fmt_ns(h.percentile(0.95)),
                    fmt_ns(h.percentile(0.99)),
                    fmt_ns(h.max),
                ]);
            }
        }
    }
    println!("{}", lat.render());
    Ok(())
}

/// `chaos`: a seeded fault-injection campaign over a real on-disk graph.
///
/// Four phases under one watchdog, each asserting the self-healing
/// contract (every request terminates with bit-exact-vs-oracle data or a
/// clean typed error — never silently wrong data, never a wedged pool):
///
/// 1. **heal** — a one-shot injected EIO on the `.graph` stream; the
///    request must succeed on retry and match the oracle.
/// 2. **quarantine** — a persistent EIO; the retry budget must exhaust
///    into [`PgError::Faulted`], quarantine the block, degrade the mmapped
///    file to pread, and fail fast on the next request.
/// 3. **corrupt** — a second fixture whose checksums sidecar disagrees
///    with the stream past the header chunk; a failing read there must
///    classify as [`PgError::Corrupt`] without burning retries.
/// 4. **mixed** — probabilistic EIO + stall garnish (seeded) under
///    successors/CSX/COO/partition traffic; outcomes are tallied, the
///    buffer pool must come back whole. Bit-flips and short reads are
///    deliberately absent here: an undetected flip could decode to
///    plausible-but-wrong data, which is exactly what the store unit
///    tests and `fault_tests.rs` pin down in isolation.
///
/// Then the plan is cleared, quarantines lifted, and the same handle must
/// serve clean oracle-equal requests — the self-healing state machine
/// leaves no permanent scar.
fn cmd_chaos(flags: &HashMap<String, String>) -> Result<()> {
    use paragrapher::coordinator::PgError;
    use paragrapher::formats::webgraph;
    use paragrapher::graph::generators;
    use paragrapher::obs::names;
    use paragrapher::storage::FaultPlan;

    let seed = flag_usize(flags, "seed", 42) as u64;
    // Floor: phase 3 needs the `.graph` stream to span 2+ checksum chunks
    // (64 KiB each) so a non-header chunk exists to corrupt.
    let n = flag_usize(flags, "vertices", 40_000).max(1 << 15);
    let timeout =
        std::time::Duration::from_secs(flag_usize(flags, "timeout-s", 240).max(10) as u64);
    let dir = match flags.get("dir") {
        Some(d) => std::path::PathBuf::from(d),
        None => std::env::temp_dir().join(format!("pg_chaos_{}", std::process::id())),
    };
    std::fs::create_dir_all(&dir).with_context(|| format!("create {}", dir.display()))?;

    // Watchdog: termination is part of the contract — a wedged buffer pool
    // or a retry loop that never gives up is itself a failed campaign.
    let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
    let watchdog = std::thread::spawn(move || {
        if done_rx.recv_timeout(timeout).is_err() {
            eprintln!("chaos: watchdog fired after {timeout:?} — campaign wedged");
            std::process::exit(9);
        }
    });

    // Fixture: a seeded graph on real files, checksums sidecar included.
    let g = generators::barabasi_albert(n, 8, seed);
    for (name, data) in webgraph::serialize(&g, "chaos") {
        std::fs::write(dir.join(&name), &data).with_context(|| name.clone())?;
    }
    let pg = Paragrapher::init();
    let opts = Options {
        read_ctx: ReadCtx { method: ReadMethod::Mmap, ..ReadCtx::default() },
        ..Options::default()
    };
    let graph = pg.open_graph_from_dir(
        &dir,
        DeviceKind::Ssd,
        "chaos",
        GraphType::CsxWg400,
        opts.clone(),
    )?;
    let store = Arc::clone(graph.store());
    let buffers = graph.options().buffers;
    let check_vertex = |v: usize, got: &[paragrapher::graph::VertexId]| -> Result<()> {
        anyhow::ensure!(got == g.neighbors(v as u32), "vertex {v} disagrees with the oracle");
        Ok(())
    };

    // Phase 1 — heal: one injected EIO, then the rule is spent; the
    // healing retry must deliver oracle-exact data.
    let v_heal = 17usize;
    store.set_fault_plan(Some(Arc::new(FaultPlan::parse("eio:*.graph@count=1", seed)?)));
    check_vertex(v_heal, &graph.successors(v_heal)?)?;
    let snap = graph.metrics_snapshot();
    let retries_after_heal = snap.counters.get(names::READ_RETRIES).copied().unwrap_or(0);
    anyhow::ensure!(retries_after_heal >= 1, "healed read burned no retry");

    // Phase 2 — quarantine + degradation: every `.graph` read faults; the
    // retry budget must exhaust into Faulted, the block quarantine, and the
    // repeatedly-faulting mmapped file degrade to pread.
    let v_quar = n / 2;
    store.set_fault_plan(Some(FaultPlan::parse("eio:*.graph@count=inf", seed)?.into()));
    let err = graph.successors(v_quar).expect_err("persistent EIO cannot succeed");
    anyhow::ensure!(
        matches!(err.downcast_ref::<PgError>(), Some(PgError::Faulted(_))),
        "expected PgError::Faulted, got: {err:#}"
    );
    anyhow::ensure!(graph.quarantined_blocks() >= 1, "no block was quarantined");
    let fail_fast = std::time::Instant::now();
    anyhow::ensure!(graph.successors(v_quar).is_err(), "quarantined block served data");
    let fail_fast = fail_fast.elapsed();
    anyhow::ensure!(store.degraded_files() >= 1, "repeated mmap faults did not degrade");

    // Phase 3 — corrupt: a sibling fixture whose checksums sidecar
    // disagrees with the stream past the header chunk. A failing read
    // there must classify as Corrupt (no retries burned on corruption).
    let dir2 = dir.join("corrupt");
    std::fs::create_dir_all(&dir2).context("create corrupt fixture dir")?;
    for (name, data) in webgraph::serialize(&g, "chaos") {
        std::fs::write(dir2.join(&name), &data).with_context(|| name.clone())?;
    }
    let sums_path = dir2.join("chaos.checksums");
    let mut sums = std::fs::read(&sums_path).context("read checksums sidecar")?;
    let chunk_count = u64::from_le_bytes(sums[8..16].try_into().unwrap()) as usize;
    anyhow::ensure!(chunk_count >= 2, "fixture must span 2+ checksum chunks, got {chunk_count}");
    for c in 1..chunk_count {
        sums[16 + c * 8] ^= 0x01; // header chunk stays valid (open-time gate)
    }
    std::fs::write(&sums_path, &sums).context("write corrupted sidecar")?;
    let graph2 = pg.open_graph_from_dir(
        &dir2,
        DeviceKind::Ssd,
        "chaos",
        GraphType::CsxWg400,
        opts.clone(),
    )?;
    graph2
        .store()
        .set_fault_plan(Some(FaultPlan::parse("eio:*.graph@count=inf", seed)?.into()));
    let err = graph2.successors(n - 2).expect_err("corrupt-classified read cannot succeed");
    anyhow::ensure!(
        matches!(err.downcast_ref::<PgError>(), Some(PgError::Corrupt(_))),
        "expected PgError::Corrupt from the mismatching sidecar, got: {err:#}"
    );
    let corrupt_retries = graph2
        .metrics_snapshot()
        .counters
        .get(names::READ_RETRIES)
        .copied()
        .unwrap_or(0);
    anyhow::ensure!(corrupt_retries == 0, "corruption burned {corrupt_retries} retries");
    pg.release_graph(graph2);

    // Phase 4 — mixed traffic under seeded probabilistic EIO + stalls.
    store.set_fault_plan(Some(
        FaultPlan::parse("eio:*.graph@prob=0.04;stall-ms:*.graph@prob=0.04,ms=2", seed)?.into(),
    ));
    let (mut ok_reqs, mut failed_reqs) = (0u64, 0u64);
    let mut rng = paragrapher::util::rng::Xoshiro256::seed_from_u64(seed ^ 0xC0FFEE);
    for _ in 0..120 {
        let v = rng.next_below(n as u64) as usize;
        match graph.successors(v) {
            Ok(list) => {
                check_vertex(v, &list)?;
                ok_reqs += 1;
            }
            Err(_) => failed_reqs += 1,
        }
    }
    for _ in 0..8 {
        let lo = rng.next_below((n - 64) as u64) as usize;
        let hi = (lo + 1 + rng.next_below(2048) as usize).min(n);
        match graph.csx_get_subgraph_sync(VertexRange::new(lo, hi)) {
            Ok(block) => {
                for i in 0..(hi - lo) {
                    let (a, b) = block.vertex_span(i);
                    anyhow::ensure!(
                        block.edges[a..b] == *g.neighbors((lo + i) as u32),
                        "csx block vertex {} disagrees with the oracle",
                        lo + i
                    );
                }
                ok_reqs += 1;
            }
            Err(_) => failed_reqs += 1,
        }
    }
    {
        let edges = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let edges2 = Arc::clone(&edges);
        let req = graph.coo_get_edges(
            0,
            graph.num_edges().min(100_000),
            Arc::new(move |blk| {
                edges2.fetch_add(blk.num_edges(), std::sync::atomic::Ordering::Relaxed);
            }),
        )?;
        req.wait();
        if req.error().is_some() {
            failed_reqs += 1;
        } else {
            ok_reqs += 1;
        }
    }
    {
        let stream = graph.csx_get_partitions(6)?;
        let edges = std::sync::atomic::AtomicU64::new(0);
        let drained = paragrapher::algorithms::partitioned::for_each_partition(&stream, 2, |p| {
            edges.fetch_add(p.num_edges(), std::sync::atomic::Ordering::Relaxed);
            Ok(())
        });
        match drained {
            Ok(()) => {
                anyhow::ensure!(
                    edges.load(std::sync::atomic::Ordering::Relaxed) == g.num_edges(),
                    "partition stream delivered a partial edge set without erroring"
                );
                ok_reqs += 1;
            }
            Err(_) => failed_reqs += 1,
        }
    }
    anyhow::ensure!(ok_reqs > 0, "the mixed campaign healed nothing — fault mix too hot");

    // Snapshot the fault counters *before* recovery: clearing the plan
    // resets the store-owned gauges (injected count lives on the plan,
    // degradation is lifted), which is itself part of the contract.
    let snap = graph.metrics_snapshot();
    let counter = |k: &str| snap.counters.get(k).copied().unwrap_or(0);
    anyhow::ensure!(counter(names::FAULT_INJECTED) > 0, "no fault was injected");
    anyhow::ensure!(counter(names::READ_RETRIES) > 0, "no read was retried");
    anyhow::ensure!(counter(names::BLOCK_QUARANTINED) > 0, "no block was quarantined");
    anyhow::ensure!(counter(names::READ_DEGRADED) > 0, "no file degraded mmap->pread");

    // Recovery: clear the plan, lift quarantines; the surviving handle
    // must serve clean oracle-equal requests and the pool must be whole.
    store.set_fault_plan(None);
    let lifted = graph.clear_quarantine();
    check_vertex(v_heal, &graph.successors(v_heal)?)?;
    check_vertex(v_quar, &graph.successors(v_quar)?)?;
    let block = graph.csx_get_subgraph_sync(VertexRange::new(0, n.min(4096)))?;
    anyhow::ensure!(block.num_edges() > 0, "post-campaign clean request was empty");
    anyhow::ensure!(
        graph.idle_buffers() == buffers,
        "buffer leak: {} of {buffers} idle after the campaign",
        graph.idle_buffers()
    );

    println!("### chaos campaign (seed {seed}, {} vertices)\n", fmt_count(n as u64));
    println!("| metric | value |");
    println!("|---|---|");
    println!("| fault.injected | {} |", counter(names::FAULT_INJECTED));
    println!("| read.retries | {} |", counter(names::READ_RETRIES));
    println!("| read.degraded | {} |", counter(names::READ_DEGRADED));
    println!("| block.quarantined | {} |", counter(names::BLOCK_QUARANTINED));
    println!("| quarantine_fail_fast | {:.2}ms (no retry budget re-paid) |",
        fail_fast.as_secs_f64() * 1e3);
    println!("| corrupt_fixture | PgError::Corrupt, 0 retries burned |");
    println!("| mixed_requests | {ok_reqs} healed+exact, {failed_reqs} typed failures |");
    println!("| quarantines_lifted | {lifted} |");
    println!("| post_campaign | clean requests oracle-equal, {buffers}/{buffers} buffers idle |");

    pg.release_graph(graph);
    let _ = done_tx.send(());
    let _ = watchdog.join();
    if !flags.contains_key("keep") && !flags.contains_key("dir") {
        std::fs::remove_dir_all(&dir).ok();
    }
    Ok(())
}

/// `ci-summary`: markdown health metrics for the CI job summary — encoder
/// reference-chain depth, decoded-block cache hit rate, and the Elias–Fano
/// offsets footprint, on a seeded graph (`--scale` / `--seed`) so drift is
/// comparable across PRs. `--json PATH` additionally writes the merged
/// metrics-registry snapshot (the `BENCH_metrics.json` schema).
fn cmd_ci_summary(flags: &HashMap<String, String>) -> Result<()> {
    use paragrapher::formats::webgraph::{self, WgParams};
    use paragrapher::formats::{GraphSource, SourceConfig, WebGraphSource};
    use paragrapher::graph::generators;
    use paragrapher::storage::SimStore;

    let scale = flag_usize(flags, "scale", 1).max(1);
    let seed = flag_usize(flags, "seed", 42) as u64;
    // Every coordinator this run opens contributes its registry snapshot;
    // the distributed runs contribute the leader-merged worker snapshots.
    let mut merged = paragrapher::obs::MetricsSnapshot::default();

    let g = generators::barabasi_albert(20_000 * scale, 8, seed);
    let (_, _, stats) = webgraph::compress(&g, WgParams::default());

    let store = SimStore::new(DeviceKind::Dram);
    FormatKind::WebGraph.write_to_store(&g, &store, "ci");
    let src = WebGraphSource::open(&store, "ci", SourceConfig::default())
        .context("open webgraph source")?;
    // Zipf-ish probe mix: a hot block plus scattered cold vertices.
    let mut rng = paragrapher::util::rng::Xoshiro256::seed_from_u64(7);
    for i in 0..4000usize {
        let v = if i % 4 == 0 {
            rng.next_below(g.num_vertices() as u64) as usize
        } else {
            (i * 13) % 256 // hot set
        };
        let _ = src.successors(v)?;
    }
    let cache = src.cache_counters();
    let acct = IoAccount::new();
    let offs =
        webgraph::read_offsets(&store, "ci", paragrapher::storage::sim::ReadCtx::default(), &acct)?;

    println!(
        "### paragrapher health metrics (BA {}×8, seed {seed})\n",
        fmt_count(g.num_vertices() as u64)
    );
    println!("| metric | value |");
    println!("|---|---|");
    println!("| max_ref_chain_depth | {} |", stats.max_ref_chain_depth);
    println!("| vertices_with_reference | {} |", stats.vertices_with_reference);
    println!("| bits_per_edge | {:.2} |", stats.total_bits as f64 / g.num_edges() as f64);
    println!(
        "| decoded_cache_hit_rate | {} |",
        paragrapher::metrics::fmt_hit_rate(&cache)
    );
    println!("| decoded_cache (hits/misses/evictions) | {}/{}/{} |",
        cache.hits, cache.misses, cache.evictions);
    println!(
        "| ef_offsets_footprint | {} of {} plain ({:.1}%) |",
        fmt_bytes(offs.size_bytes() as u64),
        fmt_bytes(offs.plain_size_bytes() as u64),
        offs.size_bytes() as f64 * 100.0 / offs.plain_size_bytes() as f64
    );

    // Decode-bandwidth calibration: measured d vs the §3 model's assumed
    // d, plus the decode-table hit rate — the regression canary for the
    // word-at-a-time decode engine.
    {
        let assumed_d = 1.0e9;
        let cal = paragrapher::bench::workloads::calibrate_decode(scale, seed, 3)?;
        println!(
            "| decode_measured_d | {} ({:.2}x of assumed {}) |",
            fmt_bw(cal.achieved_d()),
            cal.achieved_d() / assumed_d,
            fmt_bw(assumed_d)
        );
        println!(
            "| decode_table_hit_rate | {:.1}% ({} hits / {} misses) |",
            cal.table_hit_rate() * 100.0,
            cal.table_hits,
            cal.table_misses
        );
    }

    // Real-file store canaries: the configurable page-cache budget and a
    // warm mmap-vs-pread round-trip over the same on-disk fixture through
    // the mmap-backed store.
    {
        let dir = std::env::temp_dir().join(format!("pg_ci_store_{}", std::process::id()));
        std::fs::create_dir_all(&dir).context("create ci store dir")?;
        let store = paragrapher::storage::GraphStore::open_dir_with(
            &dir,
            DeviceKind::Ssd.model(),
            64 << 20,
        )?;
        for (name, data) in webgraph::serialize(&g, "ci") {
            store.put(&name, data);
        }
        println!(
            "| page_cache_budget | {} (default {}) |",
            fmt_bytes(store.cache_capacity_bytes()),
            fmt_bytes(paragrapher::storage::DEFAULT_CACHE_BYTES)
        );
        let run = |method: ReadMethod| -> Result<f64> {
            let ctx = paragrapher::storage::ReadCtx { method, ..Default::default() };
            let accounts: Vec<IoAccount> = (0..2).map(|_| IoAccount::new()).collect();
            let warm = webgraph::load_full(&store, "ci", ctx, &accounts)?;
            anyhow::ensure!(warm.num_edges() == g.num_edges(), "ci store load lost edges");
            let t = std::time::Instant::now();
            webgraph::load_full(&store, "ci", ctx, &accounts)?;
            Ok(t.elapsed().as_secs_f64())
        };
        let mmap_w = run(ReadMethod::Mmap)?;
        let pread_w = run(ReadMethod::Pread)?;
        println!(
            "| mmap_vs_pread (warm, on-disk fixture) | {:.1}ms vs {:.1}ms ({:.2}x) |",
            mmap_w * 1e3,
            pread_w * 1e3,
            mmap_w / pread_w
        );
        drop(store);
        std::fs::remove_dir_all(&dir).ok();
    }

    // Zero-copy delivery canaries: a full block-request load through the
    // coordinator — payload bytes delivered without a post-decode copy,
    // the post-decode copies themselves (invariant: 0 on the default
    // single-worker path), delivery throughput — plus the fused phase-2
    // scan throughput against the former scan-then-validate shape.
    {
        let store = Arc::new(SimStore::new(DeviceKind::Dram));
        FormatKind::WebGraph.write_to_store(&g, &store, "ci");
        let pg = Paragrapher::init();
        let graph = pg.open_graph(
            Arc::clone(&store),
            "ci",
            GraphType::CsxWg400,
            Options::default(),
        )?;
        let block = graph.load_whole_graph()?;
        anyhow::ensure!(block.num_edges() == graph.num_edges(), "ci load lost edges");
        anyhow::ensure!(
            graph.delivery_copy_bytes() == 0,
            "zero-copy invariant violated: {} bytes copied post-decode",
            graph.delivery_copy_bytes()
        );
        println!("| copy_bytes_avoided | {} |", fmt_bytes(graph.copy_bytes_avoided()));
        println!("| delivery_copy_bytes | {} (invariant: 0) |", graph.delivery_copy_bytes());
        // Multi-worker fan-out now pre-partitions the sink and writes
        // disjoint slices in place — the invariant holds there too.
        let graph_mw = pg.open_graph(
            Arc::clone(&store),
            "ci",
            GraphType::CsxWg400,
            Options { decode_workers: 4, ..Options::default() },
        )?;
        let block_mw = graph_mw.load_whole_graph()?;
        anyhow::ensure!(block_mw.num_edges() == g.num_edges(), "multi-worker ci load lost edges");
        anyhow::ensure!(
            graph_mw.delivery_copy_bytes() == 0,
            "multi-worker zero-copy invariant violated: {} bytes stitched",
            graph_mw.delivery_copy_bytes()
        );
        println!(
            "| delivery_copy_bytes (4 decode workers) | {} (invariant: 0) |",
            graph_mw.delivery_copy_bytes()
        );
        println!(
            "| delivery_throughput | {} |",
            fmt_meps(graph.delivery_throughput() / 1e6)
        );
        let (fused, split) = paragrapher::bench::workloads::measure_fused_scan(1 << 20, 5);
        println!(
            "| fused_scan_throughput | {fused:.0} Melem/s ({:.2}x vs scan-then-validate {split:.0} Melem/s) |",
            fused / split
        );
        merged.merge(&graph.metrics_snapshot());
        merged.merge(&graph_mw.metrics_snapshot());
    }

    // Partitioned-request health: a real 8-partition stream drained by two
    // consumers through the coordinator (prefetch hit rate), plus the
    // modeled HDD interleave overlap (deterministic virtual time).
    let plan = paragrapher::partition::PartitionPlan::one_d(&offs, 8);
    println!("| partition_plan_balance_factor | {:.3} |", plan.balance_factor());
    {
        let store = Arc::new(SimStore::new(DeviceKind::Dram));
        FormatKind::WebGraph.write_to_store(&g, &store, "ci");
        let pg = Paragrapher::init();
        let graph = pg.open_graph(
            Arc::clone(&store),
            "ci",
            GraphType::CsxWg400,
            Options::default(),
        )?;
        let stream = graph.csx_get_partitions(8)?;
        let edges = std::sync::atomic::AtomicU64::new(0);
        paragrapher::algorithms::partitioned::for_each_partition(&stream, 2, |p| {
            edges.fetch_add(p.num_edges(), std::sync::atomic::Ordering::Relaxed);
            Ok(())
        })?;
        anyhow::ensure!(
            edges.load(std::sync::atomic::Ordering::Relaxed) == graph.num_edges(),
            "partition stream must deliver every edge exactly once"
        );
        let c = stream.counters();
        println!(
            "| partition_prefetch_hit_rate | {:.1}% ({} hits / {} stalls) |",
            c.prefetch_hit_rate() * 100.0,
            c.prefetch_hits,
            c.consumer_stalls
        );
        println!("| partition_prefetch_window | {} |", graph.auto_prefetch_window());
        merged.merge(&graph.metrics_snapshot());
    }
    {
        let store = SimStore::new(DeviceKind::Hdd);
        FormatKind::WebGraph.write_to_store(&g, &store, "ci");
        let run = paragrapher::bench::workloads::modeled_interleaved_run(
            &store, "ci", &plan, 4, 40.0,
        )?;
        println!(
            "| interleave_overlap (HDD, modeled) | {:.1}% ({:.2}× vs load-then-execute) |",
            run.overlap * 100.0,
            run.speedup()
        );
    }

    // Distributed-harness canaries: real multi-process runs over an
    // on-disk fixture — 2-worker scaling vs the §3 modeled bound with
    // oracle equality, then a deterministic kill-worker-mid-tile run
    // proving retiling recovers full coverage.
    {
        use paragrapher::distributed::{oracle_tile_summaries, run_leader, LeaderConfig};

        let dir = std::env::temp_dir().join(format!("pg_ci_dist_{}", std::process::id()));
        std::fs::create_dir_all(&dir).context("create ci dist dir")?;
        for (name, data) in webgraph::serialize(&g, "ci") {
            std::fs::write(dir.join(&name), &data).with_context(|| name.clone())?;
        }
        let exe = std::env::current_exe().context("current_exe")?;
        let cfg = LeaderConfig::new(
            &dir,
            "ci",
            GraphType::CsxWg400,
            DeviceKind::Ssd,
            vec![exe.to_string_lossy().into_owned(), "worker".to_string()],
        );
        let one = run_leader(&LeaderConfig { workers: 1, ..cfg.clone() })?;
        let two = run_leader(&LeaderConfig { workers: 2, ..cfg.clone() })?;
        let pg = Paragrapher::init();
        let graph = pg.open_graph_from_dir(
            &dir,
            DeviceKind::Ssd,
            "ci",
            GraphType::CsxWg400,
            Options::default(),
        )?;
        let oracle = oracle_tile_summaries(&graph, two.plan.clone())?;
        let model = graph.load_model();
        pg.release_graph(graph);
        for t in &two.tiles {
            anyhow::ensure!(
                (t.edges, t.checksum) == oracle[t.tile],
                "ci distributed tile {} disagrees with the single-process oracle",
                t.tile
            );
        }
        anyhow::ensure!(
            two.edges_delivered == one.edges_delivered,
            "ci distributed runs disagree on total edges delivered"
        );
        let measured = one.wall_seconds / two.wall_seconds.max(1e-9);
        let modeled = paragrapher::bench::workloads::modeled_distributed_speedup(&model, 2);
        println!(
            "| distributed_scaling | 2 workers: {measured:.2}x measured vs {modeled:.2}x \
             modeled ({} tiles, {} edges, oracle equality held) |",
            two.tiles.len(),
            fmt_count(two.edges_delivered)
        );
        // Tail latency merged across the worker processes' shipped
        // metrics frames — the cross-process aggregation canary.
        anyhow::ensure!(
            two.worker_metrics.len() >= 2,
            "expected metrics frames from both workers, got {}",
            two.worker_metrics.len()
        );
        let h = two
            .metrics
            .hists
            .get(paragrapher::obs::names::REQ_PARTITION)
            .cloned()
            .unwrap_or_else(paragrapher::obs::HistSnapshot::empty);
        println!(
            "| distributed_req_partition (merged from {} worker snapshots) | {} samples, \
             p50 {} / p99 {} / max {} |",
            two.worker_metrics.len(),
            h.total,
            fmt_ns(h.percentile(0.5)),
            fmt_ns(h.percentile(0.99)),
            fmt_ns(h.max)
        );
        merged.merge(&two.metrics);

        let faulted = run_leader(&LeaderConfig {
            workers: 2,
            fault_args: vec![(0, "kill-after:1".to_string())],
            ..cfg
        })?;
        anyhow::ensure!(faulted.workers_lost >= 1, "fault injection lost no worker");
        anyhow::ensure!(faulted.retiled_tiles >= 1, "worker death retiled no tiles");
        for t in &faulted.tiles {
            anyhow::ensure!(
                (t.edges, t.checksum) == oracle[t.tile],
                "post-retile tile {} disagrees with the single-process oracle",
                t.tile
            );
        }
        println!(
            "| retiled_tiles | {} (kill-worker:0 mid-tile, {} worker lost, oracle equality \
             held) |",
            faulted.retiled_tiles, faulted.workers_lost
        );
        merged.merge(&faulted.metrics);
        std::fs::remove_dir_all(&dir).ok();
    }

    // Request tail latency, merged across every coordinator this run
    // opened plus the distributed workers' shipped snapshots.
    println!("\n### request tail latency (merged registries)\n");
    println!("| kind | samples | p50 | p95 | p99 | p99.9 | max |");
    println!("|---|---|---|---|---|---|---|");
    let rows = paragrapher::obs::names::REQUEST_KINDS.into_iter().chain([
        ("buffer-claim", paragrapher::obs::names::BUFFER_CLAIM_WAIT),
        ("decode-block (real)", paragrapher::obs::names::DECODE_BLOCK_REAL),
        ("decode-block (virt)", paragrapher::obs::names::DECODE_BLOCK_VIRT),
    ]);
    for (label, key) in rows {
        let h = merged
            .hists
            .get(key)
            .cloned()
            .unwrap_or_else(paragrapher::obs::HistSnapshot::empty);
        println!(
            "| {label} | {} | {} | {} | {} | {} | {} |",
            h.total,
            fmt_ns(h.percentile(0.5)),
            fmt_ns(h.percentile(0.95)),
            fmt_ns(h.percentile(0.99)),
            fmt_ns(h.percentile(0.999)),
            fmt_ns(h.max)
        );
    }

    // Fault-path counters on the clean baseline: ci-summary injects no
    // store faults, so every one of these must be exactly zero — any drift
    // means the healing path fired (or was miscounted) on healthy I/O.
    println!("\n### fault counters (clean baseline)\n");
    println!("| counter | value |");
    println!("|---|---|");
    for key in paragrapher::obs::names::FAULT_COUNTERS {
        let v = merged.counters.get(key).copied().unwrap_or(0);
        anyhow::ensure!(v == 0, "clean ci-summary run moved fault counter {key}: {v}");
        println!("| {key} | {v} |");
    }

    if let Some(path) = flags.get("json") {
        std::fs::write(path, merged.to_json().to_string_pretty())
            .with_context(|| format!("write metrics snapshot {path}"))?;
        eprintln!("wrote the merged metrics snapshot to {path}");
    }
    Ok(())
}

/// `serve-stress`: the multi-tenant serving campaign — four tenants (one
/// abusive) over two live graphs with mid-run churn and a fault window,
/// published as per-tenant tail-latency rows plus the contract table.
/// `--json PATH` writes the `BENCH_serve.json` report.
fn cmd_serve_stress(flags: &HashMap<String, String>) -> Result<()> {
    use paragrapher::serve::stress::{run, StressConfig};

    let timeout =
        std::time::Duration::from_secs(flag_usize(flags, "timeout-s", 240).max(10) as u64);
    // Watchdog: a wedged dispatcher or an unsettled ticket is itself a
    // failed campaign — terminate loudly instead of hanging CI.
    let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
    let watchdog = std::thread::spawn(move || {
        if done_rx.recv_timeout(timeout).is_err() {
            eprintln!("serve-stress: watchdog fired after {timeout:?} — campaign wedged");
            std::process::exit(9);
        }
    });

    let cfg = StressConfig {
        seed: flag_usize(flags, "seed", 42) as u64,
        scale: flag_usize(flags, "scale", 1).max(1),
        requests: flag_usize(flags, "requests", 400).max(40),
        exec_workers: flag_usize(flags, "exec-workers", 4).max(1),
        p99_factor: flag_f64(flags, "p99-factor", 2.0),
        churn: !flags.contains_key("no-churn"),
        faults: !flags.contains_key("no-faults"),
    };
    let report = run(cfg)?;
    println!("{}", report.to_markdown());
    if let Some(path) = flags.get("json") {
        std::fs::write(path, report.to_json().to_string_pretty())
            .with_context(|| format!("write serve bench report {path}"))?;
        eprintln!("wrote the serve bench report to {path}");
    }
    let _ = done_tx.send(());
    let _ = watchdog.join();
    Ok(())
}
