//! The graph store: named files served through the device model, backed by
//! either in-memory images (simulation) or real memory-mapped files.
//!
//! One store type unifies the two backends behind the [`Backing`] enum:
//!
//! * **Mem** — the historical simulated store: each file is one `Vec<u8>`
//!   image. Fast, hermetic, RAM-bounded; what every test and bench used
//!   through PR 5.
//! * **Mapped** — a real file under the store's root directory, mapped
//!   read-only ([`MmapRegion`]). `read_borrowed` under `ReadMethod::Mmap`
//!   hands out true zero-copy slices of the mapping; the pread-family
//!   methods issue real positioned reads on the backing descriptor. This is
//!   what lets a graph larger than RAM load through the same `StoreFile`
//!   surface.
//!
//! Either way, every read charges *modeled* I/O time to the caller's
//! [`IoAccount`] through the same [`PageCache`] + [`DeviceModel`] pipeline,
//! so the §3 model assertions hold identically over both backends. On a
//! rooted store the model additionally *drives residency*: when the model's
//! page cache evicts a page, the store forwards `MADV_DONTNEED` for that
//! page range, so the mapping's real resident set tracks the configured
//! cache budget — the out-of-core bounded-RSS mechanism.
//!
//! Mapping lifetime/ownership rules (DESIGN.md §Store abstraction): a file
//! is never mutated or truncated while mapped (`put` on a rooted store
//! writes a temp file and `rename`s it over, leaving live mappings on the
//! old inode); borrowed slices live at most as long as their [`StoreFile`],
//! which keeps the mapping's `Arc` alive even across `remove`/`put`.

use std::collections::HashMap;
use std::fs::File;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::{bail, Result};

use super::cache::{PageCache, CACHE_PAGE};
use super::device::DeviceModel;
use super::fault::{FaultAction, FaultPlan, IoFault};
use super::mmap::{Advice, MmapRegion};
use super::reader::{ReadMethod, ReaderImpl};
use super::vclock::IoAccount;
use crate::storage::DeviceKind;

/// Default model page-cache budget: 8 GiB of RAM (a fraction of the
/// paper's 256 GB machines, matching our scaled datasets). Configurable
/// per-store ([`GraphStore::set_cache_capacity`]) and per-run (the
/// `--cache-mb` CLI flag).
pub const DEFAULT_CACHE_BYTES: u64 = 8u64 << 30;

/// Declared read pattern for an experiment: how many concurrent readers
/// share the device, the request block size, the syscall method, and
/// whether each reader scans a contiguous chunk.
#[derive(Debug, Clone, Copy)]
pub struct ReadCtx {
    pub threads: usize,
    pub block: u64,
    pub method: ReadMethod,
    pub sequential: bool,
    pub reader_impl: ReaderImpl,
}

impl Default for ReadCtx {
    fn default() -> Self {
        Self {
            threads: 1,
            block: 4 << 20,
            method: ReadMethod::Pread,
            sequential: true,
            reader_impl: ReaderImpl::ZeroCopy,
        }
    }
}

impl ReadCtx {
    /// Reject contexts that name an access path with no real semantics.
    /// `mmap+O_DIRECT` is a label from the paper's Fig. 4 grid, but an
    /// `mmap` of an O_DIRECT descriptor just page-faults through the cache
    /// like plain `mmap` — there is no uncached mmap path to implement, so
    /// graph-open entry points fail fast instead of silently behaving like
    /// `Mmap`. (The pure device-*model* grids keep the axis for Fig. 4.)
    pub fn validate(&self) -> Result<()> {
        if matches!(self.method, ReadMethod::MmapDirect) {
            bail!(
                "ReadMethod::MmapDirect has no real access path: mmap of an \
                 O_DIRECT descriptor still faults through the page cache. \
                 Use `mmap` (cached) or `pread+O_DIRECT` (uncached)."
            );
        }
        Ok(())
    }
}

/// Pattern-advice state of a mapping (avoid re-issuing `madvise` per read).
const ADVICE_NONE: u8 = 0;
const ADVICE_SEQ: u8 = 1;
const ADVICE_RANDOM: u8 = 2;

/// A real file: the descriptor (pread path), its read-only mapping
/// (mmap/borrow path) and the last pattern hint applied.
#[derive(Debug)]
struct MappedFile {
    file: File,
    map: MmapRegion,
    advice: AtomicU8,
}

impl MappedFile {
    /// Positioned read of `[start, end)` via real `pread(2)` calls on the
    /// descriptor (the non-mmap methods' code path). Falls back to copying
    /// from the mapping if the descriptor read fails — same bytes, the
    /// method axis only changes *how* they travel.
    fn pread(&self, start: u64, end: u64) -> Vec<u8> {
        let len = (end - start) as usize;
        let mut out = vec![0u8; len];
        #[cfg(unix)]
        {
            use std::os::unix::fs::FileExt;
            let mut done = 0usize;
            while done < len {
                match self.file.read_at(&mut out[done..], start + done as u64) {
                    Ok(0) => break,
                    Ok(k) => done += k,
                    Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => break,
                }
            }
            if done == len {
                return out;
            }
        }
        out.copy_from_slice(&self.map.as_slice()[start as usize..end as usize]);
        out
    }
}

/// Storage backing of one named file — the store abstraction's pivot.
#[derive(Debug)]
enum Backing {
    /// Simulated: one in-memory image.
    Mem(Vec<u8>),
    /// Real: a mapped file under the store root.
    Mapped(MappedFile),
}

/// Mapped-read faults tolerated on one file before its `Mmap` reads are
/// degraded to `Pread` (the per-file mmap→pread fallback).
const MMAP_DEGRADE_AFTER: u64 = 2;

#[derive(Debug)]
struct FileImage {
    id: u64,
    /// Store name, carried so the fault plan can pattern-match reads.
    name: String,
    backing: Backing,
    /// Injected faults observed under `ReadMethod::Mmap` on this file.
    mmap_faults: AtomicU64,
    /// Once set, `try_read*` rewrites `Mmap` to `Pread` for this file.
    degraded: AtomicBool,
}

impl FileImage {
    fn len(&self) -> u64 {
        match &self.backing {
            Backing::Mem(d) => d.len() as u64,
            Backing::Mapped(m) => m.map.len() as u64,
        }
    }

    fn bytes(&self) -> &[u8] {
        match &self.backing {
            Backing::Mem(d) => d,
            Backing::Mapped(m) => m.map.as_slice(),
        }
    }
}

#[derive(Debug)]
struct StoreInner {
    files: HashMap<String, Arc<FileImage>>,
    /// Reverse index for eviction mirroring (model page id → file).
    by_id: HashMap<u64, Arc<FileImage>>,
    next_id: u64,
}

impl StoreInner {
    fn insert(&mut self, name: &str, backing: Backing) -> Arc<FileImage> {
        let id = self.next_id;
        self.next_id += 1;
        let img = Arc::new(FileImage {
            id,
            name: name.to_string(),
            backing,
            mmap_faults: AtomicU64::new(0),
            degraded: AtomicBool::new(false),
        });
        if let Some(old) = self.files.insert(name.to_string(), Arc::clone(&img)) {
            self.by_id.remove(&old.id);
        }
        self.by_id.insert(id, Arc::clone(&img));
        img
    }
}

/// One machine's storage: a device model, a (model) page cache and a set of
/// named files — in-memory images, or real mapped files when the store is
/// rooted at a directory ([`GraphStore::open_dir`]).
pub struct GraphStore {
    device: DeviceModel,
    cache: PageCache,
    inner: RwLock<StoreInner>,
    /// Total virtual bytes charged to the device (all readers).
    device_bytes: AtomicU64,
    /// Directory real files live under (`None` = purely simulated store).
    root: Option<PathBuf>,
    /// Fast-path gate for fault injection: `try_read*` consults the plan
    /// only when set, so fault-free stores pay one relaxed load per read.
    fault_active: AtomicBool,
    fault_plan: RwLock<Option<Arc<FaultPlan>>>,
    /// Files whose `Mmap` reads have been degraded to `Pread`.
    degraded_files: AtomicU64,
}

impl GraphStore {
    pub fn new(kind: DeviceKind) -> Self {
        Self::with_device(kind.model())
    }

    /// Store for *scaled* experiments: seek latency shrunk to match the
    /// dataset scale-down (see `DeviceModel::new_scaled`).
    pub fn new_scaled(kind: DeviceKind) -> Self {
        Self::with_device(DeviceModel::new_scaled(kind))
    }

    pub fn with_device(device: DeviceModel) -> Self {
        Self::with_device_and_cache(device, DEFAULT_CACHE_BYTES)
    }

    pub fn with_cache_capacity(kind: DeviceKind, cache_bytes: u64) -> Self {
        Self::with_device_and_cache(kind.model(), cache_bytes)
    }

    pub fn with_device_and_cache(device: DeviceModel, cache_bytes: u64) -> Self {
        Self {
            device,
            cache: PageCache::new(cache_bytes),
            inner: RwLock::new(StoreInner {
                files: HashMap::new(),
                by_id: HashMap::new(),
                next_id: 1,
            }),
            device_bytes: AtomicU64::new(0),
            root: None,
            fault_active: AtomicBool::new(false),
            fault_plan: RwLock::new(None),
            degraded_files: AtomicU64::new(0),
        }
    }

    /// Install (or clear) a fault plan. Clearing also lifts every file's
    /// mmap→pread degradation — the operator replaced the flaky medium.
    pub fn set_fault_plan(&self, plan: Option<Arc<FaultPlan>>) {
        let active = plan.is_some();
        *self.fault_plan.write().expect("fault plan lock") = plan;
        self.fault_active.store(active, Ordering::Relaxed);
        if !active {
            let inner = self.inner.read().expect("store lock");
            for img in inner.files.values() {
                img.mmap_faults.store(0, Ordering::Relaxed);
                img.degraded.store(false, Ordering::Relaxed);
            }
            self.degraded_files.store(0, Ordering::Relaxed);
        }
    }

    /// Total faults the installed plan has injected (0 when no plan).
    pub fn fault_injected(&self) -> u64 {
        self.fault_plan
            .read()
            .expect("fault plan lock")
            .as_ref()
            .map_or(0, |p| p.injected())
    }

    /// Files currently degraded from `Mmap` to `Pread`.
    pub fn degraded_files(&self) -> u64 {
        self.degraded_files.load(Ordering::Relaxed)
    }

    /// Open a store rooted at `dir`: every name resolves to a real file
    /// under `dir`, mapped read-only on first open. Billing is identical to
    /// the simulated store; in addition, model-cache evictions are
    /// forwarded as `MADV_DONTNEED` so real residency tracks `cache_bytes`.
    pub fn open_dir(dir: impl AsRef<Path>, kind: DeviceKind) -> Result<Self> {
        Self::open_dir_with(dir, kind.model(), DEFAULT_CACHE_BYTES)
    }

    pub fn open_dir_with(
        dir: impl AsRef<Path>,
        device: DeviceModel,
        cache_bytes: u64,
    ) -> Result<Self> {
        let dir = dir.as_ref();
        if !dir.is_dir() {
            bail!("store root {} is not a directory", dir.display());
        }
        let mut s = Self::with_device_and_cache(device, cache_bytes);
        s.root = Some(dir.to_path_buf());
        Ok(s)
    }

    pub fn device(&self) -> &DeviceModel {
        &self.device
    }

    /// Root directory of a real-file store (`None` when simulated).
    pub fn root(&self) -> Option<&Path> {
        self.root.as_deref()
    }

    /// Model page-cache budget, bytes.
    pub fn cache_capacity_bytes(&self) -> u64 {
        self.cache.capacity_bytes()
    }

    /// Re-budget the model page cache. Shrinking evicts immediately (and,
    /// on a rooted store, releases the evicted pages' real residency).
    pub fn set_cache_capacity(&self, cache_bytes: u64) {
        let mut evicted = Vec::new();
        self.cache.set_capacity(cache_bytes, &mut evicted);
        self.release_pages(&evicted);
    }

    /// Install a file. On a rooted store the data is persisted under the
    /// root (write temp + rename, so a concurrently mapped old version
    /// keeps its inode) and served through a fresh mapping; otherwise it
    /// becomes an in-memory image.
    pub fn put(&self, name: &str, data: Vec<u8>) {
        if let Some(root) = &self.root {
            if let Ok(backing) = Self::persist(root, name, &data) {
                self.inner.write().expect("store lock").insert(name, backing);
                return;
            }
        }
        self.inner.write().expect("store lock").insert(name, Backing::Mem(data));
    }

    fn persist(root: &Path, name: &str, data: &[u8]) -> Result<Backing> {
        let path = root.join(name);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let tmp = root.join(format!("{name}.tmp~"));
        std::fs::write(&tmp, data)?;
        std::fs::rename(&tmp, &path)?;
        let file = File::open(&path)?;
        let map = MmapRegion::map(&file)?;
        Ok(Backing::Mapped(MappedFile { file, map, advice: AtomicU8::new(ADVICE_NONE) }))
    }

    pub fn open(&self, name: &str) -> Option<StoreFile<'_>> {
        {
            let inner = self.inner.read().expect("store lock");
            if let Some(img) = inner.files.get(name) {
                return Some(StoreFile { img: Arc::clone(img), store: self });
            }
        }
        // Rooted store: map the real file lazily on first open.
        let root = self.root.as_ref()?;
        let file = File::open(root.join(name)).ok()?;
        let map = MmapRegion::map(&file).ok()?;
        let mut inner = self.inner.write().expect("store lock");
        // Lost the race to another opener: serve their mapping.
        if let Some(img) = inner.files.get(name) {
            return Some(StoreFile { img: Arc::clone(img), store: self });
        }
        let backing =
            Backing::Mapped(MappedFile { file, map, advice: AtomicU8::new(ADVICE_NONE) });
        let img = inner.insert(name, backing);
        Some(StoreFile { img, store: self })
    }

    pub fn file_len(&self, name: &str) -> Option<u64> {
        self.open(name).map(|f| f.len())
    }

    pub fn remove(&self, name: &str) -> bool {
        let removed = {
            let mut inner = self.inner.write().expect("store lock");
            match inner.files.remove(name) {
                Some(img) => {
                    inner.by_id.remove(&img.id);
                    true
                }
                None => false,
            }
        };
        if let Some(root) = &self.root {
            let on_disk = std::fs::remove_file(root.join(name)).is_ok();
            return removed || on_disk;
        }
        removed
    }

    /// Names currently resident in the store (on a rooted store: the files
    /// opened or put so far, not a directory listing).
    pub fn list(&self) -> Vec<String> {
        let inner = self.inner.read().expect("store lock");
        let mut names: Vec<String> = inner.files.keys().cloned().collect();
        names.sort();
        names
    }

    /// Drop the simulated OS page cache (the paper's flushcache
    /// discipline). On a rooted store this also releases every mapping's
    /// real residency (`MADV_DONTNEED`), so a cold-cache experiment is cold
    /// for real too.
    pub fn drop_cache(&self) {
        self.cache.drop_cache();
        if self.root.is_some() {
            let inner = self.inner.read().expect("store lock");
            for img in inner.files.values() {
                if let Backing::Mapped(m) = &img.backing {
                    m.map.advise(Advice::DontNeed);
                }
            }
        }
    }

    pub fn cache_stats(&self) -> (u64, u64) {
        self.cache.stats()
    }

    /// Bytes the model page cache currently holds resident.
    pub fn cache_resident_bytes(&self) -> u64 {
        self.cache.resident_bytes()
    }

    pub fn device_bytes(&self) -> u64 {
        self.device_bytes.load(Ordering::Relaxed)
    }

    /// Forward model-cache evictions to the real mappings: each evicted
    /// (file, page) becomes `MADV_DONTNEED` over that page range, bounding
    /// the mappings' resident set by the model's cache budget.
    fn release_pages(&self, evicted: &[(u64, u64)]) {
        if evicted.is_empty() || self.root.is_none() {
            return;
        }
        let inner = self.inner.read().expect("store lock");
        for &(fid, page) in evicted {
            if let Some(img) = inner.by_id.get(&fid) {
                if let Backing::Mapped(m) = &img.backing {
                    m.map.advise_range(page * CACHE_PAGE, CACHE_PAGE, Advice::DontNeed);
                }
            }
        }
    }
}

impl std::fmt::Debug for GraphStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GraphStore")
            .field("root", &self.root)
            .field("cache_capacity_bytes", &self.cache.capacity_bytes())
            .finish_non_exhaustive()
    }
}

/// Handle to one stored file (either backing).
pub struct StoreFile<'s> {
    img: Arc<FileImage>,
    store: &'s GraphStore,
}

impl<'s> StoreFile<'s> {
    pub fn len(&self) -> u64 {
        self.img.len()
    }

    pub fn is_empty(&self) -> bool {
        self.img.len() == 0
    }

    /// Whether this file is served by a real mapping (vs a memory image).
    pub fn is_mapped(&self) -> bool {
        matches!(self.img.backing, Backing::Mapped(_))
    }

    fn clamp(&self, offset: u64, len: u64) -> (u64, u64) {
        let file_len = self.img.len();
        let start = offset.min(file_len);
        let end = offset.saturating_add(len).min(file_len);
        (start, end)
    }

    /// Model billing shared by every read path: page-cache accounting (with
    /// eviction mirroring on rooted stores), then device or DRAM time.
    fn bill(&self, start: u64, actual: u64, ctx: ReadCtx, acct: &IoAccount) {
        if actual == 0 {
            return;
        }
        let file_len = self.img.len();
        let populate = ctx.method.buffered();
        let cold = if self.store.root.is_some() {
            let mut evicted = Vec::new();
            let cold = self.store.cache.access_reporting(
                self.img.id,
                start,
                actual,
                populate,
                file_len,
                &mut evicted,
            );
            self.store.release_pages(&evicted);
            cold
        } else {
            self.store.cache.access(self.img.id, start, actual, populate, file_len)
        };
        if cold > 0 {
            // Charged at the *actual* request granularity: small scattered
            // requests pay proportionally more seek.
            let t = self.store.device.request_time(
                cold,
                ctx.threads,
                cold.min(ctx.block.max(1)),
                ctx.method,
                ctx.sequential,
            );
            acct.charge_io(t, cold);
            self.store.device_bytes.fetch_add(cold, Ordering::Relaxed);
        } else {
            // Warm hit: charge DRAM-speed access instead of device speed.
            let dram = DeviceKind::Dram.model();
            let t = dram.request_time(actual, ctx.threads, ctx.block, ctx.method, true);
            acct.charge_io(t, 0);
        }
    }

    /// On a mapped file accessed through `mmap`, keep the kernel's pattern
    /// hint in sync with the declared access pattern (issued only when it
    /// changes — the common case of one pattern per experiment is free).
    fn sync_pattern_hint(&self, ctx: ReadCtx) {
        if ctx.method != ReadMethod::Mmap {
            return;
        }
        if let Backing::Mapped(m) = &self.img.backing {
            let want = if ctx.sequential { ADVICE_SEQ } else { ADVICE_RANDOM };
            if m.advice.swap(want, Ordering::Relaxed) != want {
                m.map.advise(if ctx.sequential { Advice::Sequential } else { Advice::Random });
            }
        }
    }

    /// Read `[offset, offset+len)` into a fresh Vec, charging virtual time.
    /// Out-of-range reads are truncated at EOF like `pread`. On a mapped
    /// file the pread-family methods issue real positioned reads on the
    /// descriptor; `mmap` copies out of the mapping.
    pub fn read(&self, offset: u64, len: u64, ctx: ReadCtx, acct: &IoAccount) -> Vec<u8> {
        match ctx.reader_impl {
            ReaderImpl::ZeroCopy => {
                if let Backing::Mapped(m) = &self.img.backing {
                    if !matches!(ctx.method, ReadMethod::Mmap | ReadMethod::MmapDirect) {
                        let (start, end) = self.clamp(offset, len);
                        self.bill(start, end - start, ctx, acct);
                        return m.pread(start, end);
                    }
                }
                self.read_zero_copy(offset, len, ctx, acct).to_vec()
            }
            ReaderImpl::BufferedCopy => {
                let slice = self.read_zero_copy(offset, len, ctx, acct);
                // Managed-style path: stage through an intermediate buffer in
                // bounded sub-copies (the JVM ByteBuffer pipeline), costing
                // real CPU that the account measures.
                acct.time_cpu(|| {
                    let mut out = Vec::with_capacity(slice.len());
                    let mut staged = vec![0u8; 64 << 10];
                    for chunk in slice.chunks(staged.len()) {
                        let staged = &mut staged[..chunk.len()];
                        staged.copy_from_slice(chunk);
                        // Bounds-checked element-wise append, deliberately
                        // not a memcpy: models managed-runtime overhead.
                        for &b in staged.iter() {
                            out.push(b);
                        }
                    }
                    out
                })
            }
        }
    }

    /// Read `[offset, offset+len)` honoring the declared reader model in
    /// one place: *borrowed* bytes on the default zero-copy reader, a
    /// staged owned copy under the managed `BufferedCopy` model (the
    /// Fig. 10 contrast). On a mapped file the borrow additionally requires
    /// `ReadMethod::Mmap` — the method axis finally selects a real code
    /// path: mmap borrows a slice of the mapping, the pread-family methods
    /// return a real positioned read's buffer. Every lane of the zero-copy
    /// delivery pipeline (graph stream, weights sidecar, future property
    /// lanes) should read through this helper rather than re-rolling the
    /// dispatch.
    pub fn read_borrowed(
        &self,
        offset: u64,
        len: u64,
        ctx: ReadCtx,
        acct: &IoAccount,
    ) -> std::borrow::Cow<'_, [u8]> {
        match ctx.reader_impl {
            ReaderImpl::ZeroCopy => {
                if matches!(self.img.backing, Backing::Mapped(_))
                    && !matches!(ctx.method, ReadMethod::Mmap | ReadMethod::MmapDirect)
                {
                    std::borrow::Cow::Owned(self.read(offset, len, ctx, acct))
                } else {
                    std::borrow::Cow::Borrowed(self.read_zero_copy(offset, len, ctx, acct))
                }
            }
            ReaderImpl::BufferedCopy => std::borrow::Cow::Owned(self.read(offset, len, ctx, acct)),
        }
    }

    /// Borrow the bytes directly (the C-like path) while still charging
    /// virtual I/O for the cold fraction of the range. On a mapped file
    /// this is a slice of the real mapping (page faults do the I/O).
    pub fn read_zero_copy(
        &self,
        offset: u64,
        len: u64,
        ctx: ReadCtx,
        acct: &IoAccount,
    ) -> &[u8] {
        let (start, end) = self.clamp(offset, len);
        if end > start {
            self.sync_pattern_hint(ctx);
            self.bill(start, end - start, ctx, acct);
        }
        &self.img.bytes()[start as usize..end as usize]
    }

    /// Whether this file's `Mmap` reads have been degraded to `Pread`.
    pub fn is_degraded(&self) -> bool {
        self.img.degraded.load(Ordering::Relaxed)
    }

    /// Rewrite `Mmap` to `Pread` once the file is degraded: the mapping
    /// stays alive (borrows remain valid) but new reads travel through the
    /// descriptor, dodging whatever poisoned the mapped path.
    fn effective_ctx(&self, ctx: ReadCtx) -> ReadCtx {
        if ctx.method == ReadMethod::Mmap && self.img.degraded.load(Ordering::Relaxed) {
            ReadCtx { method: ReadMethod::Pread, ..ctx }
        } else {
            ctx
        }
    }

    /// Consult the store's fault plan for this read (cheap no-op gate when
    /// no plan is installed).
    fn decide_fault(&self, offset: u64, len: u64) -> Option<FaultAction> {
        if !self.store.fault_active.load(Ordering::Relaxed) {
            return None;
        }
        let plan = Arc::clone(self.store.fault_plan.read().expect("fault plan lock").as_ref()?);
        plan.decide(&self.img.name, offset, len)
    }

    /// Count an injected fault against the mapped path; past the tolerance
    /// the file flips to degraded and subsequent `try_read*` calls under
    /// `Mmap` go through `Pread` instead.
    fn note_mmap_fault(&self, ctx: ReadCtx) {
        if ctx.method != ReadMethod::Mmap {
            return;
        }
        let n = self.img.mmap_faults.fetch_add(1, Ordering::Relaxed) + 1;
        if n >= MMAP_DEGRADE_AFTER && !self.img.degraded.swap(true, Ordering::Relaxed) {
            self.store.degraded_files.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Fallible read: [`Self::read`] plus the fault surface. This is the
    /// entry production call sites use — injection happens *below*
    /// `StoreFile` and *above* the backing, so `mmap` and `pread` requests
    /// share one fault schedule.
    pub fn try_read(
        &self,
        offset: u64,
        len: u64,
        ctx: ReadCtx,
        acct: &IoAccount,
    ) -> std::result::Result<Vec<u8>, IoFault> {
        let eff = self.effective_ctx(ctx);
        match self.decide_fault(offset, len) {
            None => Ok(self.read(offset, len, eff, acct)),
            Some(FaultAction::Eio) => {
                self.note_mmap_fault(ctx);
                Err(IoFault { file: self.img.name.clone(), offset, len })
            }
            Some(FaultAction::Stall { ms }) => {
                std::thread::sleep(std::time::Duration::from_millis(ms));
                Ok(self.read(offset, len, eff, acct))
            }
            Some(FaultAction::ShortRead { keep }) => {
                self.note_mmap_fault(ctx);
                let mut out = self.read(offset, len, eff, acct);
                out.truncate(keep as usize);
                Ok(out)
            }
            Some(FaultAction::BitFlip { pos, mask }) => {
                self.note_mmap_fault(ctx);
                let mut out = self.read(offset, len, eff, acct);
                if let Some(b) = out.get_mut(pos as usize) {
                    *b ^= mask;
                }
                Ok(out)
            }
        }
    }

    /// Fallible borrow: [`Self::read_borrowed`] plus the fault surface.
    /// Corrupting faults force `Cow::Owned` — the store's own image is
    /// never mutated, only the copy handed to the caller.
    pub fn try_read_borrowed(
        &self,
        offset: u64,
        len: u64,
        ctx: ReadCtx,
        acct: &IoAccount,
    ) -> std::result::Result<std::borrow::Cow<'_, [u8]>, IoFault> {
        let eff = self.effective_ctx(ctx);
        match self.decide_fault(offset, len) {
            None => Ok(self.read_borrowed(offset, len, eff, acct)),
            Some(FaultAction::Eio) => {
                self.note_mmap_fault(ctx);
                Err(IoFault { file: self.img.name.clone(), offset, len })
            }
            Some(FaultAction::Stall { ms }) => {
                std::thread::sleep(std::time::Duration::from_millis(ms));
                Ok(self.read_borrowed(offset, len, eff, acct))
            }
            Some(FaultAction::ShortRead { keep }) => {
                self.note_mmap_fault(ctx);
                let mut out = self.read(offset, len, eff, acct);
                out.truncate(keep as usize);
                Ok(std::borrow::Cow::Owned(out))
            }
            Some(FaultAction::BitFlip { pos, mask }) => {
                self.note_mmap_fault(ctx);
                let mut out = self.read(offset, len, eff, acct);
                if let Some(b) = out.get_mut(pos as usize) {
                    *b ^= mask;
                }
                Ok(std::borrow::Cow::Owned(out))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store_with_file(kind: DeviceKind, len: usize) -> GraphStore {
        let s = GraphStore::new(kind);
        let data: Vec<u8> = (0..len).map(|i| (i % 251) as u8).collect();
        s.put("f", data);
        s
    }

    fn rooted_store_with_file(kind: DeviceKind, len: usize) -> (GraphStore, PathBuf) {
        let mut dir = std::env::temp_dir();
        dir.push(format!("pg_store_test_{}_{}", std::process::id(), len));
        std::fs::create_dir_all(&dir).unwrap();
        let data: Vec<u8> = (0..len).map(|i| (i % 251) as u8).collect();
        std::fs::write(dir.join("f"), data).unwrap();
        let s = GraphStore::open_dir(&dir, kind).unwrap();
        (s, dir)
    }

    #[test]
    fn read_returns_correct_bytes() {
        let s = store_with_file(DeviceKind::Ssd, 10_000);
        let f = s.open("f").unwrap();
        let acct = IoAccount::new();
        let got = f.read(100, 50, ReadCtx::default(), &acct);
        let expect: Vec<u8> = (100..150).map(|i| (i % 251) as u8).collect();
        assert_eq!(got, expect);
        assert!(acct.io_seconds() > 0.0);
    }

    #[test]
    fn eof_truncation() {
        let s = store_with_file(DeviceKind::Ssd, 100);
        let f = s.open("f").unwrap();
        let acct = IoAccount::new();
        assert_eq!(f.read(90, 50, ReadCtx::default(), &acct).len(), 10);
        assert_eq!(f.read(200, 10, ReadCtx::default(), &acct).len(), 0);
    }

    #[test]
    fn hdd_slower_than_ssd() {
        let acct_h = IoAccount::new();
        let acct_s = IoAccount::new();
        let sh = store_with_file(DeviceKind::Hdd, 4 << 20);
        let ss = store_with_file(DeviceKind::Ssd, 4 << 20);
        sh.open("f").unwrap().read(0, 4 << 20, ReadCtx::default(), &acct_h);
        ss.open("f").unwrap().read(0, 4 << 20, ReadCtx::default(), &acct_s);
        assert!(acct_h.io_seconds() > 5.0 * acct_s.io_seconds());
    }

    #[test]
    fn warm_reads_are_cheap_until_drop() {
        let s = store_with_file(DeviceKind::Hdd, 2 << 20);
        let f = s.open("f").unwrap();
        let cold = IoAccount::new();
        f.read(0, 2 << 20, ReadCtx::default(), &cold);
        let warm = IoAccount::new();
        f.read(0, 2 << 20, ReadCtx::default(), &warm);
        assert!(warm.io_seconds() < cold.io_seconds() / 100.0);
        s.drop_cache();
        let cold2 = IoAccount::new();
        f.read(0, 2 << 20, ReadCtx::default(), &cold2);
        assert!(cold2.io_seconds() > cold.io_seconds() * 0.5);
    }

    #[test]
    fn read_borrowed_honors_the_reader_model() {
        let s = store_with_file(DeviceKind::Dram, 4096);
        let f = s.open("f").unwrap();
        let acct = IoAccount::new();
        let ctx = ReadCtx::default();
        let zc = f.read_borrowed(10, 100, ctx, &acct);
        assert!(matches!(zc, std::borrow::Cow::Borrowed(_)), "default reader borrows");
        let ctx2 = ReadCtx { reader_impl: ReaderImpl::BufferedCopy, ..ctx };
        let bc = f.read_borrowed(10, 100, ctx2, &acct);
        assert!(matches!(bc, std::borrow::Cow::Owned(_)), "managed reader stages a copy");
        assert_eq!(&*zc, &*bc, "both reader models return identical bytes");
        assert_eq!(zc.len(), 100);
    }

    #[test]
    fn buffered_copy_costs_cpu() {
        let s = store_with_file(DeviceKind::Dram, 4 << 20);
        let f = s.open("f").unwrap();
        let zc = IoAccount::new();
        let ctx = ReadCtx::default();
        let a = f.read(0, 4 << 20, ctx, &zc);
        s.drop_cache();
        let bc = IoAccount::new();
        let ctx2 = ReadCtx { reader_impl: ReaderImpl::BufferedCopy, ..ctx };
        let b = f.read(0, 4 << 20, ctx2, &bc);
        assert_eq!(a, b, "both reader impls must return identical bytes");
        assert!(bc.cpu_seconds() > zc.cpu_seconds());
    }

    #[test]
    fn store_listing_and_removal() {
        let s = GraphStore::new(DeviceKind::Ssd);
        s.put("b", vec![1]);
        s.put("a", vec![2]);
        assert_eq!(s.list(), vec!["a".to_string(), "b".to_string()]);
        assert_eq!(s.file_len("a"), Some(1));
        assert!(s.remove("a"));
        assert!(!s.remove("a"));
        assert!(s.open("a").is_none());
    }

    #[test]
    fn mmap_direct_rejected_by_validation() {
        let bad = ReadCtx { method: ReadMethod::MmapDirect, ..ReadCtx::default() };
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("MmapDirect"), "{err}");
        for m in ReadMethod::ALL {
            if m != ReadMethod::MmapDirect {
                assert!(ReadCtx { method: m, ..ReadCtx::default() }.validate().is_ok());
            }
        }
    }

    #[test]
    fn rooted_store_serves_identical_bytes_with_identical_billing() {
        let (rooted, dir) = rooted_store_with_file(DeviceKind::Ssd, 300_000);
        let sim = store_with_file(DeviceKind::Ssd, 300_000);
        for method in [ReadMethod::Pread, ReadMethod::Mmap, ReadMethod::PreadDirect] {
            let ctx = ReadCtx { method, ..ReadCtx::default() };
            rooted.drop_cache();
            sim.drop_cache();
            let (ar, asim) = (IoAccount::new(), IoAccount::new());
            let fr = rooted.open("f").unwrap();
            let fs = sim.open("f").unwrap();
            let br = fr.read(1000, 200_000, ctx, &ar);
            let bs = fs.read(1000, 200_000, ctx, &asim);
            assert_eq!(br, bs, "{method:?}: bytes must match the sim oracle");
            assert!(
                (ar.io_seconds() - asim.io_seconds()).abs() < 1e-12,
                "{method:?}: modeled I/O must be backing-independent"
            );
            assert_eq!(ar.bytes_read(), asim.bytes_read(), "{method:?}");
        }
        drop(rooted);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn rooted_borrow_follows_the_method_axis() {
        let (s, dir) = rooted_store_with_file(DeviceKind::Dram, 65_536);
        let f = s.open("f").unwrap();
        assert!(f.is_mapped());
        let acct = IoAccount::new();
        let mmap_ctx = ReadCtx { method: ReadMethod::Mmap, ..ReadCtx::default() };
        let got = f.read_borrowed(64, 4096, mmap_ctx, &acct);
        assert!(matches!(got, std::borrow::Cow::Borrowed(_)), "mmap borrows the mapping");
        let pread_ctx = ReadCtx::default();
        let got2 = f.read_borrowed(64, 4096, pread_ctx, &acct);
        assert!(matches!(got2, std::borrow::Cow::Owned(_)), "pread copies via the fd");
        assert_eq!(&*got, &*got2);
        drop(got);
        drop(got2);
        drop(f);
        drop(s);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn rooted_put_persists_and_reopens() {
        let mut dir = std::env::temp_dir();
        dir.push(format!("pg_store_put_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let s = GraphStore::open_dir(&dir, DeviceKind::Ssd).unwrap();
        s.put("x.bin", vec![9u8; 5000]);
        assert_eq!(s.file_len("x.bin"), Some(5000));
        drop(s);
        // A second store over the same root sees the persisted file.
        let s2 = GraphStore::open_dir(&dir, DeviceKind::Ssd).unwrap();
        let f = s2.open("x.bin").unwrap();
        let acct = IoAccount::new();
        assert_eq!(f.read(0, 5000, ReadCtx::default(), &acct), vec![9u8; 5000]);
        assert!(s2.remove("x.bin"));
        drop(f);
        drop(s2);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn try_read_without_a_plan_matches_read() {
        let s = store_with_file(DeviceKind::Dram, 4096);
        let f = s.open("f").unwrap();
        let acct = IoAccount::new();
        let a = f.try_read(100, 64, ReadCtx::default(), &acct).unwrap();
        let b = f.read(100, 64, ReadCtx::default(), &acct);
        assert_eq!(a, b);
        assert_eq!(s.fault_injected(), 0);
    }

    #[test]
    fn fault_plan_drives_try_read() {
        let s = store_with_file(DeviceKind::Dram, 4096);
        s.set_fault_plan(Some(Arc::new(FaultPlan::parse("eio:f@nth=2", 1).unwrap())));
        let f = s.open("f").unwrap();
        let acct = IoAccount::new();
        let ctx = ReadCtx::default();
        assert!(f.try_read(0, 64, ctx, &acct).is_ok());
        let err = f.try_read(0, 64, ctx, &acct).unwrap_err();
        assert_eq!((err.file.as_str(), err.offset, err.len), ("f", 0, 64));
        assert!(f.try_read(0, 64, ctx, &acct).is_ok(), "nth=2 fires exactly once");
        assert_eq!(s.fault_injected(), 1);
        // Infallible paths never consult the plan.
        s.set_fault_plan(Some(Arc::new(FaultPlan::parse("eio:f@count=inf", 1).unwrap())));
        assert_eq!(f.read(0, 64, ctx, &acct).len(), 64);
        s.set_fault_plan(None);
        assert_eq!(s.fault_injected(), 0);
    }

    #[test]
    fn corrupting_faults_alter_only_the_returned_copy() {
        let s = store_with_file(DeviceKind::Dram, 4096);
        let f = s.open("f").unwrap();
        let acct = IoAccount::new();
        let ctx = ReadCtx::default();
        let clean = f.read(0, 256, ctx, &acct);
        s.set_fault_plan(Some(Arc::new(
            FaultPlan::parse("bit-flip:f@nth=1; short-read:f@nth=2", 3).unwrap(),
        )));
        let flipped = f.try_read(0, 256, ctx, &acct).unwrap();
        assert_ne!(flipped, clean, "bit flip must corrupt the copy");
        assert_eq!(flipped.len(), clean.len());
        let torn = f.try_read(0, 256, ctx, &acct).unwrap();
        assert!(torn.len() < clean.len(), "short read truncates");
        assert_eq!(torn[..], clean[..torn.len()], "torn prefix is genuine data");
        s.set_fault_plan(None);
        assert_eq!(f.read(0, 256, ctx, &acct), clean, "backing image untouched");
    }

    #[test]
    fn repeated_mmap_faults_degrade_the_file_to_pread() {
        let (s, dir) = rooted_store_with_file(DeviceKind::Dram, 65_536);
        s.set_fault_plan(Some(Arc::new(FaultPlan::parse("eio:f@count=2", 5).unwrap())));
        let f = s.open("f").unwrap();
        let acct = IoAccount::new();
        let mmap_ctx = ReadCtx { method: ReadMethod::Mmap, ..ReadCtx::default() };
        assert!(f.try_read(0, 64, mmap_ctx, &acct).is_err());
        assert!(!f.is_degraded(), "one fault is tolerated");
        assert!(f.try_read(0, 64, mmap_ctx, &acct).is_err());
        assert!(f.is_degraded(), "second mapped fault degrades the file");
        assert_eq!(s.degraded_files(), 1);
        // Degraded + plan exhausted: reads succeed, and the borrow path
        // travels the descriptor (owned buffer), not the mapping.
        let got = f.try_read_borrowed(0, 64, mmap_ctx, &acct).unwrap();
        assert!(matches!(got, std::borrow::Cow::Owned(_)), "degraded mmap reads via pread");
        assert_eq!(got.len(), 64);
        s.set_fault_plan(None);
        assert!(!f.is_degraded(), "clearing the plan lifts degradation");
        assert_eq!(s.degraded_files(), 0);
        drop(got);
        drop(f);
        drop(s);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn cache_budget_is_configurable_and_bounds_residency() {
        let budget = 8 * CACHE_PAGE;
        let s = GraphStore::with_cache_capacity(DeviceKind::Ssd, budget);
        assert_eq!(s.cache_capacity_bytes(), budget);
        s.put("f", vec![0u8; (64 * CACHE_PAGE) as usize]);
        let f = s.open("f").unwrap();
        let acct = IoAccount::new();
        f.read(0, 64 * CACHE_PAGE, ReadCtx::default(), &acct);
        assert!(
            s.cache_resident_bytes() <= budget,
            "resident {} must respect budget {budget}",
            s.cache_resident_bytes()
        );
        s.set_cache_capacity(2 * CACHE_PAGE);
        assert!(s.cache_resident_bytes() <= 2 * CACHE_PAGE, "shrink evicts immediately");
    }
}
