//! Read methods and reader implementations.
//!
//! [`ReadMethod`] mirrors the §5.1 axis (read/pread/mmap ± O_DIRECT) of
//! Fig. 4. [`ReaderImpl`] mirrors the Fig. 10 axis: the paper compares the
//! *Java* buffered reader against the *C* implementation (78–101 % of C);
//! our analogue compares a zero-copy slice reader against a managed-style
//! reader that pays an extra bounds-checked copy per request.

/// System call / access method used for reads (Fig. 4 axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReadMethod {
    /// `read(2)` on a shared descriptor (kernel offset, buffered).
    Read,
    /// `pread(2)` at explicit offsets (buffered).
    Pread,
    /// `pread` with O_DIRECT (no page cache, no readahead).
    PreadDirect,
    /// `mmap(2)` + page-fault driven access.
    Mmap,
    /// `mmap` of a file opened with O_DIRECT.
    MmapDirect,
}

impl ReadMethod {
    pub const ALL: [ReadMethod; 5] = [
        ReadMethod::Read,
        ReadMethod::Pread,
        ReadMethod::PreadDirect,
        ReadMethod::Mmap,
        ReadMethod::MmapDirect,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ReadMethod::Read => "read",
            ReadMethod::Pread => "pread",
            ReadMethod::PreadDirect => "pread+O_DIRECT",
            ReadMethod::Mmap => "mmap",
            ReadMethod::MmapDirect => "mmap+O_DIRECT",
        }
    }

    /// Whether the method goes through the OS page cache (and so benefits
    /// from readahead and cached re-reads).
    pub fn buffered(&self) -> bool {
        matches!(self, ReadMethod::Read | ReadMethod::Pread | ReadMethod::Mmap)
    }
}

/// Reader implementation style (Fig. 10 axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReaderImpl {
    /// Zero-copy: hand out slices of the (simulated) mapped file. "C-like".
    ZeroCopy,
    /// Managed-style: copy through an intermediate heap buffer with bounds
    /// checks, like a JVM `ByteBuffer` pipeline. "Java-like".
    BufferedCopy,
}

impl ReaderImpl {
    pub fn name(&self) -> &'static str {
        match self {
            ReaderImpl::ZeroCopy => "zero-copy (C-like)",
            ReaderImpl::BufferedCopy => "buffered-copy (Java-like)",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffered_classification() {
        assert!(ReadMethod::Read.buffered());
        assert!(ReadMethod::Pread.buffered());
        assert!(ReadMethod::Mmap.buffered());
        assert!(!ReadMethod::PreadDirect.buffered());
        assert!(!ReadMethod::MmapDirect.buffered());
    }

    #[test]
    fn names_unique() {
        let names: Vec<_> = ReadMethod::ALL.iter().map(|m| m.name()).collect();
        let mut dedup = names.clone();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len());
    }
}
