//! Virtual-time accounting.
//!
//! Each loading worker carries an [`IoAccount`]: virtual I/O seconds (from
//! the device model) plus real measured CPU seconds (decode work). The
//! modeled elapsed time of a parallel phase is the max over workers, which
//! is how the paper's overlap model (§3) composes: a worker that reads and
//! decodes its blocks back-to-back has elapsed = io + cpu; the *experiment*
//! elapsed is the slowest worker (plus any sequential phases).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Per-worker time account. Cheap to clone-snapshot; thread-safe adds.
#[derive(Debug, Default)]
pub struct IoAccount {
    io_ns: AtomicU64,
    cpu_ns: AtomicU64,
    bytes: AtomicU64,
    requests: AtomicU64,
}

impl IoAccount {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge virtual I/O seconds (+bytes, +1 request).
    pub fn charge_io(&self, seconds: f64, bytes: u64) {
        self.io_ns.fetch_add((seconds * 1e9) as u64, Ordering::Relaxed);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Charge real CPU seconds.
    pub fn charge_cpu(&self, seconds: f64) {
        self.cpu_ns.fetch_add((seconds * 1e9) as u64, Ordering::Relaxed);
    }

    /// Run `f`, measuring its wall time as CPU work on this account.
    pub fn time_cpu<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.charge_cpu(t0.elapsed().as_secs_f64());
        out
    }

    pub fn io_seconds(&self) -> f64 {
        self.io_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn cpu_seconds(&self) -> f64 {
        self.cpu_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Worker elapsed time: I/O and CPU are serial within one worker
    /// (read block, decode block, repeat). Overlap across workers comes from
    /// taking the max at the phase level.
    pub fn elapsed_seconds(&self) -> f64 {
        self.io_seconds() + self.cpu_seconds()
    }

    pub fn bytes_read(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.io_ns.store(0, Ordering::Relaxed);
        self.cpu_ns.store(0, Ordering::Relaxed);
        self.bytes.store(0, Ordering::Relaxed);
        self.requests.store(0, Ordering::Relaxed);
    }
}

/// Modeled elapsed time of a parallel phase over per-worker accounts,
/// assuming the workers run concurrently on distinct (virtual) cores:
/// max over workers of per-worker elapsed.
pub fn phase_elapsed(accounts: &[IoAccount]) -> f64 {
    accounts.iter().map(|a| a.elapsed_seconds()).fold(0.0, f64::max)
}

/// Modeled elapsed time when only `cores` physical cores execute `accounts`
/// worth of CPU work: I/O still overlaps, CPU serializes beyond `cores`.
/// Used by the scalability experiment (Fig. 9), where decode is
/// compute-bound and worker count exceeds core count.
pub fn phase_elapsed_with_cores(accounts: &[IoAccount], cores: usize) -> f64 {
    let cores = cores.max(1) as f64;
    let max_single = phase_elapsed(accounts);
    let total_cpu: f64 = accounts.iter().map(|a| a.cpu_seconds()).sum();
    let max_io = accounts.iter().map(|a| a.io_seconds()).fold(0.0, f64::max);
    // Lower bounds: the slowest single worker, and total CPU spread over cores
    // overlapped with the longest I/O stream.
    max_single.max(total_cpu / cores).max(max_io)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charges_accumulate() {
        let a = IoAccount::new();
        a.charge_io(0.5, 1000);
        a.charge_io(0.25, 500);
        a.charge_cpu(0.1);
        assert!((a.io_seconds() - 0.75).abs() < 1e-9);
        assert!((a.cpu_seconds() - 0.1).abs() < 1e-9);
        assert!((a.elapsed_seconds() - 0.85).abs() < 1e-9);
        assert_eq!(a.bytes_read(), 1500);
        assert_eq!(a.requests(), 2);
        a.reset();
        assert_eq!(a.elapsed_seconds(), 0.0);
    }

    #[test]
    fn time_cpu_measures_something() {
        let a = IoAccount::new();
        let v = a.time_cpu(|| {
            let mut s = 0u64;
            for i in 0..100_000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(v > 0);
        assert!(a.cpu_seconds() > 0.0);
    }

    #[test]
    fn phase_is_max_of_workers() {
        let a = IoAccount::new();
        let b = IoAccount::new();
        a.charge_io(1.0, 1);
        b.charge_io(0.2, 1);
        b.charge_cpu(0.3);
        let accs = [a, b];
        assert!((phase_elapsed(&accs) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn limited_cores_serialize_cpu() {
        // 8 workers, each 1s CPU, no I/O: with 2 cores it takes >= 4s.
        let accs: Vec<IoAccount> = (0..8)
            .map(|_| {
                let a = IoAccount::new();
                a.charge_cpu(1.0);
                a
            })
            .collect();
        let t = phase_elapsed_with_cores(&accs, 2);
        assert!((t - 4.0).abs() < 1e-9);
        let t8 = phase_elapsed_with_cores(&accs, 8);
        assert!((t8 - 1.0).abs() < 1e-9);
    }
}
