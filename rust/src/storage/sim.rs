//! The simulated store: named file images served through the device model.
//!
//! All format loaders read through [`SimFile::read`], which returns real
//! bytes and charges virtual I/O time to the caller's [`IoAccount`]. A read
//! context ([`ReadCtx`]) captures the experiment's declared parallelism and
//! access method — the knobs of the paper's Fig. 4/Fig. 8 sweeps.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use super::cache::PageCache;
use super::device::DeviceModel;
use super::reader::{ReadMethod, ReaderImpl};
use super::vclock::IoAccount;
use crate::storage::DeviceKind;

/// Declared read pattern for an experiment: how many concurrent readers
/// share the device, the request block size, the syscall method, and
/// whether each reader scans a contiguous chunk.
#[derive(Debug, Clone, Copy)]
pub struct ReadCtx {
    pub threads: usize,
    pub block: u64,
    pub method: ReadMethod,
    pub sequential: bool,
    pub reader_impl: ReaderImpl,
}

impl Default for ReadCtx {
    fn default() -> Self {
        Self {
            threads: 1,
            block: 4 << 20,
            method: ReadMethod::Pread,
            sequential: true,
            reader_impl: ReaderImpl::ZeroCopy,
        }
    }
}

#[derive(Debug)]
struct StoreInner {
    files: HashMap<String, Arc<FileImage>>,
    next_id: u64,
}

#[derive(Debug)]
struct FileImage {
    id: u64,
    data: Vec<u8>,
}

/// One simulated machine's storage: a device model, a page cache and a set
/// of file images.
pub struct SimStore {
    device: DeviceModel,
    cache: PageCache,
    inner: RwLock<StoreInner>,
    /// Total virtual bytes charged to the device (all readers).
    device_bytes: AtomicU64,
}

impl SimStore {
    pub fn new(kind: DeviceKind) -> Self {
        // 8 GiB of model page-cache RAM by default (a fraction of the
        // paper's 256 GB machines, matching our scaled datasets).
        Self::with_device(kind.model())
    }

    /// Store for *scaled* experiments: seek latency shrunk to match the
    /// dataset scale-down (see `DeviceModel::new_scaled`).
    pub fn new_scaled(kind: DeviceKind) -> Self {
        Self::with_device(DeviceModel::new_scaled(kind))
    }

    pub fn with_device(device: DeviceModel) -> Self {
        Self {
            device,
            cache: PageCache::new(8u64 << 30),
            inner: RwLock::new(StoreInner { files: HashMap::new(), next_id: 1 }),
            device_bytes: AtomicU64::new(0),
        }
    }

    pub fn with_cache_capacity(kind: DeviceKind, cache_bytes: u64) -> Self {
        Self {
            device: kind.model(),
            cache: PageCache::new(cache_bytes),
            inner: RwLock::new(StoreInner { files: HashMap::new(), next_id: 1 }),
            device_bytes: AtomicU64::new(0),
        }
    }

    pub fn device(&self) -> &DeviceModel {
        &self.device
    }

    /// Install a file image.
    pub fn put(&self, name: &str, data: Vec<u8>) {
        let mut inner = self.inner.write().expect("store lock");
        let id = inner.next_id;
        inner.next_id += 1;
        inner.files.insert(name.to_string(), Arc::new(FileImage { id, data }));
    }

    pub fn open(&self, name: &str) -> Option<SimFile<'_>> {
        let inner = self.inner.read().expect("store lock");
        inner.files.get(name).map(|img| SimFile { img: Arc::clone(img), store: self })
    }

    pub fn file_len(&self, name: &str) -> Option<u64> {
        let inner = self.inner.read().expect("store lock");
        inner.files.get(name).map(|img| img.data.len() as u64)
    }

    pub fn remove(&self, name: &str) -> bool {
        let mut inner = self.inner.write().expect("store lock");
        inner.files.remove(name).is_some()
    }

    pub fn list(&self) -> Vec<String> {
        let inner = self.inner.read().expect("store lock");
        let mut names: Vec<String> = inner.files.keys().cloned().collect();
        names.sort();
        names
    }

    /// Drop the simulated OS page cache (the paper's flushcache discipline).
    pub fn drop_cache(&self) {
        self.cache.drop_cache();
    }

    pub fn cache_stats(&self) -> (u64, u64) {
        self.cache.stats()
    }

    pub fn device_bytes(&self) -> u64 {
        self.device_bytes.load(Ordering::Relaxed)
    }
}

/// Handle to one simulated file.
pub struct SimFile<'s> {
    img: Arc<FileImage>,
    store: &'s SimStore,
}

impl<'s> SimFile<'s> {
    pub fn len(&self) -> u64 {
        self.img.data.len() as u64
    }

    pub fn is_empty(&self) -> bool {
        self.img.data.is_empty()
    }

    /// Read `[offset, offset+len)` into a fresh Vec, charging virtual time.
    /// Out-of-range reads are truncated at EOF like `pread`.
    pub fn read(&self, offset: u64, len: u64, ctx: ReadCtx, acct: &IoAccount) -> Vec<u8> {
        let slice = self.read_zero_copy(offset, len, ctx, acct);
        match ctx.reader_impl {
            ReaderImpl::ZeroCopy => slice.to_vec(),
            ReaderImpl::BufferedCopy => {
                // Managed-style path: stage through an intermediate buffer in
                // bounded sub-copies (the JVM ByteBuffer pipeline), costing
                // real CPU that the account measures.
                acct.time_cpu(|| {
                    let mut out = Vec::with_capacity(slice.len());
                    let mut staged = vec![0u8; 64 << 10];
                    for chunk in slice.chunks(staged.len()) {
                        let staged = &mut staged[..chunk.len()];
                        staged.copy_from_slice(chunk);
                        // Bounds-checked element-wise append, deliberately
                        // not a memcpy: models managed-runtime overhead.
                        for &b in staged.iter() {
                            out.push(b);
                        }
                    }
                    out
                })
            }
        }
    }

    /// Read `[offset, offset+len)` honoring the declared reader model in
    /// one place: *borrowed* bytes on the default zero-copy reader,
    /// a staged owned copy under the managed `BufferedCopy` model (the
    /// Fig. 10 contrast). Every lane of the zero-copy delivery pipeline
    /// (graph stream, weights sidecar, future property lanes) should read
    /// through this helper rather than re-rolling the dispatch — calling
    /// plain [`read`](Self::read) would silently take the copy path even
    /// under the zero-copy reader.
    pub fn read_borrowed(
        &self,
        offset: u64,
        len: u64,
        ctx: ReadCtx,
        acct: &IoAccount,
    ) -> std::borrow::Cow<'_, [u8]> {
        match ctx.reader_impl {
            ReaderImpl::ZeroCopy => {
                std::borrow::Cow::Borrowed(self.read_zero_copy(offset, len, ctx, acct))
            }
            ReaderImpl::BufferedCopy => std::borrow::Cow::Owned(self.read(offset, len, ctx, acct)),
        }
    }

    /// Borrow the bytes directly (the C-like path) while still charging
    /// virtual I/O for the cold fraction of the range.
    pub fn read_zero_copy(
        &self,
        offset: u64,
        len: u64,
        ctx: ReadCtx,
        acct: &IoAccount,
    ) -> &[u8] {
        let file_len = self.img.data.len() as u64;
        let start = offset.min(file_len);
        let end = offset.saturating_add(len).min(file_len);
        let actual = end - start;
        if actual > 0 {
            let populate = ctx.method.buffered();
            let cold =
                self.store.cache.access(self.img.id, start, actual, populate, file_len);
            if cold > 0 {
                // Charged at the *actual* request granularity: small
                // scattered requests pay proportionally more seek.
                let t = self.store.device.request_time(
                    cold,
                    ctx.threads,
                    cold.min(ctx.block.max(1)),
                    ctx.method,
                    ctx.sequential,
                );
                acct.charge_io(t, cold);
                self.store.device_bytes.fetch_add(cold, Ordering::Relaxed);
            } else {
                // Warm hit: charge DRAM-speed access instead of device speed.
                let dram = DeviceKind::Dram.model();
                let t = dram.request_time(actual, ctx.threads, ctx.block, ctx.method, true);
                acct.charge_io(t, 0);
            }
        }
        &self.img.data[start as usize..end as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store_with_file(kind: DeviceKind, len: usize) -> SimStore {
        let s = SimStore::new(kind);
        let data: Vec<u8> = (0..len).map(|i| (i % 251) as u8).collect();
        s.put("f", data);
        s
    }

    #[test]
    fn read_returns_correct_bytes() {
        let s = store_with_file(DeviceKind::Ssd, 10_000);
        let f = s.open("f").unwrap();
        let acct = IoAccount::new();
        let got = f.read(100, 50, ReadCtx::default(), &acct);
        let expect: Vec<u8> = (100..150).map(|i| (i % 251) as u8).collect();
        assert_eq!(got, expect);
        assert!(acct.io_seconds() > 0.0);
    }

    #[test]
    fn eof_truncation() {
        let s = store_with_file(DeviceKind::Ssd, 100);
        let f = s.open("f").unwrap();
        let acct = IoAccount::new();
        assert_eq!(f.read(90, 50, ReadCtx::default(), &acct).len(), 10);
        assert_eq!(f.read(200, 10, ReadCtx::default(), &acct).len(), 0);
    }

    #[test]
    fn hdd_slower_than_ssd() {
        let acct_h = IoAccount::new();
        let acct_s = IoAccount::new();
        let sh = store_with_file(DeviceKind::Hdd, 4 << 20);
        let ss = store_with_file(DeviceKind::Ssd, 4 << 20);
        sh.open("f").unwrap().read(0, 4 << 20, ReadCtx::default(), &acct_h);
        ss.open("f").unwrap().read(0, 4 << 20, ReadCtx::default(), &acct_s);
        assert!(acct_h.io_seconds() > 5.0 * acct_s.io_seconds());
    }

    #[test]
    fn warm_reads_are_cheap_until_drop() {
        let s = store_with_file(DeviceKind::Hdd, 2 << 20);
        let f = s.open("f").unwrap();
        let cold = IoAccount::new();
        f.read(0, 2 << 20, ReadCtx::default(), &cold);
        let warm = IoAccount::new();
        f.read(0, 2 << 20, ReadCtx::default(), &warm);
        assert!(warm.io_seconds() < cold.io_seconds() / 100.0);
        s.drop_cache();
        let cold2 = IoAccount::new();
        f.read(0, 2 << 20, ReadCtx::default(), &cold2);
        assert!(cold2.io_seconds() > cold.io_seconds() * 0.5);
    }

    #[test]
    fn read_borrowed_honors_the_reader_model() {
        let s = store_with_file(DeviceKind::Dram, 4096);
        let f = s.open("f").unwrap();
        let acct = IoAccount::new();
        let ctx = ReadCtx::default();
        let zc = f.read_borrowed(10, 100, ctx, &acct);
        assert!(matches!(zc, std::borrow::Cow::Borrowed(_)), "default reader borrows");
        let ctx2 = ReadCtx { reader_impl: ReaderImpl::BufferedCopy, ..ctx };
        let bc = f.read_borrowed(10, 100, ctx2, &acct);
        assert!(matches!(bc, std::borrow::Cow::Owned(_)), "managed reader stages a copy");
        assert_eq!(&*zc, &*bc, "both reader models return identical bytes");
        assert_eq!(zc.len(), 100);
    }

    #[test]
    fn buffered_copy_costs_cpu() {
        let s = store_with_file(DeviceKind::Dram, 4 << 20);
        let f = s.open("f").unwrap();
        let zc = IoAccount::new();
        let ctx = ReadCtx::default();
        let a = f.read(0, 4 << 20, ctx, &zc);
        s.drop_cache();
        let bc = IoAccount::new();
        let ctx2 = ReadCtx { reader_impl: ReaderImpl::BufferedCopy, ..ctx };
        let b = f.read(0, 4 << 20, ctx2, &bc);
        assert_eq!(a, b, "both reader impls must return identical bytes");
        assert!(bc.cpu_seconds() > zc.cpu_seconds());
    }

    #[test]
    fn store_listing_and_removal() {
        let s = SimStore::new(DeviceKind::Ssd);
        s.put("b", vec![1]);
        s.put("a", vec![2]);
        assert_eq!(s.list(), vec!["a".to_string(), "b".to_string()]);
        assert_eq!(s.file_len("a"), Some(1));
        assert!(s.remove("a"));
        assert!(!s.remove("a"));
        assert!(s.open("a").is_none());
    }
}
