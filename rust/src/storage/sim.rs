//! Compatibility alias for the historical simulated-store names.
//!
//! The store grew a real-file (mmap) backing and moved to
//! [`super::store`]; `SimStore`/`SimFile` are now the same type as
//! [`GraphStore`](super::store::GraphStore)/[`StoreFile`](super::store::StoreFile)
//! with the in-memory backing selected by the constructors. Existing code
//! (and the module path `storage::sim::ReadCtx`) keeps compiling unchanged.

pub use super::store::{GraphStore as SimStore, ReadCtx, StoreFile as SimFile};
