//! OS page-cache model.
//!
//! §4.1 requires the library to leave the machine as it found it — including
//! *dropping the OS cache of storage contents* (the paper calls
//! `/proc/sys/vm/drop_caches` / `flushcache`). The simulator models the
//! cache so that (a) warm re-reads are DRAM-speed, which would silently
//! invalidate every bandwidth measurement, and (b) `drop_cache()` restores
//! cold-read behaviour — tests assert both.

use std::collections::{HashMap, VecDeque};
use std::sync::Mutex;

/// Page granularity of the model (16 KiB "super-pages": coarse enough to
/// keep bookkeeping cheap, fine enough that small files span several).
pub const CACHE_PAGE: u64 = 16 << 10;

#[derive(Debug)]
struct CacheInner {
    /// (file_id, page_index) -> resident
    pages: HashMap<(u64, u64), ()>,
    /// FIFO eviction order (good enough for streaming workloads).
    order: VecDeque<(u64, u64)>,
    capacity_pages: u64,
    hits: u64,
    misses: u64,
}

/// Shared page-cache model for one simulated machine.
#[derive(Debug)]
pub struct PageCache {
    inner: Mutex<CacheInner>,
}

impl PageCache {
    /// `capacity_bytes` models the RAM available for caching.
    pub fn new(capacity_bytes: u64) -> Self {
        Self {
            inner: Mutex::new(CacheInner {
                pages: HashMap::new(),
                order: VecDeque::new(),
                capacity_pages: (capacity_bytes / CACHE_PAGE).max(1),
                hits: 0,
                misses: 0,
            }),
        }
    }

    /// Record an access to `[offset, offset+len)` of `file_id`; returns the
    /// number of bytes that *missed* and must be charged to the device.
    ///
    /// Buffered I/O (`populate = true`) works in whole pages: a missed page
    /// is charged at full page size (the OS reads — and caches — the whole
    /// page, like real readahead), capped at `file_len`. O_DIRECT
    /// (`populate = false`) bypasses the cache and is charged exactly the
    /// requested bytes.
    pub fn access(
        &self,
        file_id: u64,
        offset: u64,
        len: u64,
        populate: bool,
        file_len: u64,
    ) -> u64 {
        if len == 0 {
            return 0;
        }
        if !populate {
            return len;
        }
        let first = offset / CACHE_PAGE;
        let last = (offset + len - 1) / CACHE_PAGE;
        let mut inner = self.inner.lock().expect("cache lock");
        let mut missed_bytes = 0u64;
        for p in first..=last {
            if inner.pages.contains_key(&(file_id, p)) {
                inner.hits += 1;
            } else {
                inner.misses += 1;
                // Whole-page transfer, truncated at EOF.
                let page_start = p * CACHE_PAGE;
                missed_bytes += CACHE_PAGE.min(file_len.saturating_sub(page_start));
                if inner.order.len() as u64 >= inner.capacity_pages {
                    if let Some(old) = inner.order.pop_front() {
                        inner.pages.remove(&old);
                    }
                }
                inner.pages.insert((file_id, p), ());
                inner.order.push_back((file_id, p));
            }
        }
        missed_bytes
    }

    /// Drop everything — the `flushcache` discipline between experiments.
    pub fn drop_cache(&self) {
        let mut inner = self.inner.lock().expect("cache lock");
        inner.pages.clear();
        inner.order.clear();
    }

    pub fn stats(&self) -> (u64, u64) {
        let inner = self.inner.lock().expect("cache lock");
        (inner.hits, inner.misses)
    }

    pub fn resident_bytes(&self) -> u64 {
        let inner = self.inner.lock().expect("cache lock");
        inner.pages.len() as u64 * CACHE_PAGE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FLEN: u64 = 1 << 30;

    #[test]
    fn cold_then_warm_then_dropped() {
        let c = PageCache::new(64 * CACHE_PAGE);
        let missed = c.access(1, 0, 4 * CACHE_PAGE, true, FLEN);
        assert_eq!(missed, 4 * CACHE_PAGE, "cold read misses everything");
        let missed = c.access(1, 0, 4 * CACHE_PAGE, true, FLEN);
        assert_eq!(missed, 0, "warm read is free");
        c.drop_cache();
        let missed = c.access(1, 0, 4 * CACHE_PAGE, true, FLEN);
        assert_eq!(missed, 4 * CACHE_PAGE, "drop_cache restores cold behaviour");
    }

    #[test]
    fn o_direct_does_not_populate() {
        let c = PageCache::new(64 * CACHE_PAGE);
        assert_eq!(c.access(1, 0, CACHE_PAGE, false, FLEN), CACHE_PAGE);
        let missed = c.access(1, 0, CACHE_PAGE, true, FLEN);
        assert_eq!(missed, CACHE_PAGE, "O_DIRECT read did not populate");
    }

    #[test]
    fn capacity_evicts_fifo() {
        let c = PageCache::new(2 * CACHE_PAGE);
        c.access(1, 0, CACHE_PAGE, true, FLEN); // page 0
        c.access(1, CACHE_PAGE, CACHE_PAGE, true, FLEN); // page 1
        c.access(1, 2 * CACHE_PAGE, CACHE_PAGE, true, FLEN); // evicts page 0
        assert_eq!(c.access(1, 0, CACHE_PAGE, true, FLEN), CACHE_PAGE, "page 0 evicted");
    }

    #[test]
    fn distinct_files_do_not_collide() {
        let c = PageCache::new(64 * CACHE_PAGE);
        c.access(1, 0, CACHE_PAGE, true, FLEN);
        assert_eq!(c.access(2, 0, CACHE_PAGE, true, FLEN), CACHE_PAGE);
    }

    #[test]
    fn small_read_charges_whole_page_and_caches_it() {
        let c = PageCache::new(64 * CACHE_PAGE);
        // A tiny buffered read faults in (and pays for) the whole page —
        // a later read of that page is then legitimately warm.
        assert_eq!(c.access(3, 10, 100, true, FLEN), CACHE_PAGE);
        assert_eq!(c.access(3, 10, 100, true, FLEN), 0);
        assert_eq!(c.access(3, CACHE_PAGE / 2, 8, true, FLEN), 0, "same page");
    }

    #[test]
    fn page_charge_truncates_at_eof() {
        let c = PageCache::new(64 * CACHE_PAGE);
        let flen = CACHE_PAGE + 100; // file ends 100 B into its second page
        assert_eq!(c.access(4, CACHE_PAGE, 50, true, flen), 100);
    }
}
