//! Cache layers: the OS page-cache model and the decoded-block cache.
//!
//! [`PageCache`]: §4.1 requires the library to leave the machine as it found
//! it — including *dropping the OS cache of storage contents* (the paper
//! calls `/proc/sys/vm/drop_caches` / `flushcache`). The simulator models
//! the cache so that (a) warm re-reads are DRAM-speed, which would silently
//! invalidate every bandwidth measurement, and (b) `drop_cache()` restores
//! cold-read behaviour — tests assert both.
//!
//! [`DecodedCache`]: an LRU over *decoded* blocks keyed by block id, sitting
//! above the page cache. The page cache makes re-reads of compressed bytes
//! cheap; the decoded cache makes repeated random accesses to hot vertices
//! skip re-decompression entirely (the `GraphSource::successors` fast path).
//! It is generic over the cached value so the storage layer stays free of
//! format types; formats instantiate it with `DecodedBlock`.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::coordinator::lock_recover;
use crate::obs::Counter;

/// Page granularity of the model (16 KiB "super-pages": coarse enough to
/// keep bookkeeping cheap, fine enough that small files span several).
pub const CACHE_PAGE: u64 = 16 << 10;

#[derive(Debug)]
struct CacheInner {
    /// (file_id, page_index) -> resident
    pages: HashMap<(u64, u64), ()>,
    /// FIFO eviction order (good enough for streaming workloads).
    order: VecDeque<(u64, u64)>,
    capacity_pages: u64,
    hits: u64,
    misses: u64,
}

/// Shared page-cache model for one simulated machine.
#[derive(Debug)]
pub struct PageCache {
    inner: Mutex<CacheInner>,
}

impl PageCache {
    /// `capacity_bytes` models the RAM available for caching.
    pub fn new(capacity_bytes: u64) -> Self {
        Self {
            inner: Mutex::new(CacheInner {
                pages: HashMap::new(),
                order: VecDeque::new(),
                capacity_pages: (capacity_bytes / CACHE_PAGE).max(1),
                hits: 0,
                misses: 0,
            }),
        }
    }

    /// Record an access to `[offset, offset+len)` of `file_id`; returns the
    /// number of bytes that *missed* and must be charged to the device.
    ///
    /// Buffered I/O (`populate = true`) works in whole pages: a missed page
    /// is charged at full page size (the OS reads — and caches — the whole
    /// page, like real readahead), capped at `file_len`. O_DIRECT
    /// (`populate = false`) bypasses the cache and is charged exactly the
    /// requested bytes.
    pub fn access(
        &self,
        file_id: u64,
        offset: u64,
        len: u64,
        populate: bool,
        file_len: u64,
    ) -> u64 {
        self.access_impl(file_id, offset, len, populate, file_len, None)
    }

    /// Like [`access`](Self::access), but also appends every evicted
    /// `(file_id, page_index)` to `evicted`. Rooted stores use this to
    /// mirror model evictions onto real mappings (`MADV_DONTNEED`), so the
    /// mappings' resident set tracks the modeled cache budget.
    pub fn access_reporting(
        &self,
        file_id: u64,
        offset: u64,
        len: u64,
        populate: bool,
        file_len: u64,
        evicted: &mut Vec<(u64, u64)>,
    ) -> u64 {
        self.access_impl(file_id, offset, len, populate, file_len, Some(evicted))
    }

    fn access_impl(
        &self,
        file_id: u64,
        offset: u64,
        len: u64,
        populate: bool,
        file_len: u64,
        mut evicted: Option<&mut Vec<(u64, u64)>>,
    ) -> u64 {
        if len == 0 {
            return 0;
        }
        if !populate {
            return len;
        }
        let first = offset / CACHE_PAGE;
        let last = (offset + len - 1) / CACHE_PAGE;
        let mut inner = lock_recover(&self.inner);
        let mut missed_bytes = 0u64;
        for p in first..=last {
            if inner.pages.contains_key(&(file_id, p)) {
                inner.hits += 1;
            } else {
                inner.misses += 1;
                // Whole-page transfer, truncated at EOF.
                let page_start = p * CACHE_PAGE;
                missed_bytes += CACHE_PAGE.min(file_len.saturating_sub(page_start));
                if inner.order.len() as u64 >= inner.capacity_pages {
                    if let Some(old) = inner.order.pop_front() {
                        inner.pages.remove(&old);
                        if let Some(out) = evicted.as_deref_mut() {
                            out.push(old);
                        }
                    }
                }
                inner.pages.insert((file_id, p), ());
                inner.order.push_back((file_id, p));
            }
        }
        missed_bytes
    }

    /// Modeled cache budget, bytes (page-granular).
    pub fn capacity_bytes(&self) -> u64 {
        let inner = lock_recover(&self.inner);
        inner.capacity_pages * CACHE_PAGE
    }

    /// Re-budget the cache; shrinking evicts FIFO immediately, reporting
    /// the evicted `(file_id, page_index)` pairs.
    pub fn set_capacity(&self, capacity_bytes: u64, evicted: &mut Vec<(u64, u64)>) {
        let mut inner = lock_recover(&self.inner);
        inner.capacity_pages = (capacity_bytes / CACHE_PAGE).max(1);
        while inner.order.len() as u64 > inner.capacity_pages {
            if let Some(old) = inner.order.pop_front() {
                inner.pages.remove(&old);
                evicted.push(old);
            } else {
                break;
            }
        }
    }

    /// Drop everything — the `flushcache` discipline between experiments.
    pub fn drop_cache(&self) {
        let mut inner = lock_recover(&self.inner);
        inner.pages.clear();
        inner.order.clear();
    }

    pub fn stats(&self) -> (u64, u64) {
        let inner = lock_recover(&self.inner);
        (inner.hits, inner.misses)
    }

    pub fn resident_bytes(&self) -> u64 {
        let inner = lock_recover(&self.inner);
        inner.pages.len() as u64 * CACHE_PAGE
    }
}

/// Aggregate counters of a [`DecodedCache`] (metrics surface).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheCounters {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Sum of the cost function over resident entries.
    pub resident_cost: u64,
    /// Resident entry count.
    pub blocks: u64,
}

impl CacheCounters {
    /// Hit fraction over all lookups (0 when the cache was never queried).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }
}

/// Handle to a per-tenant accounting slot of one [`DecodedCache`], returned
/// by [`DecodedCache::register_tag`]. Tags attribute resident cost, hits and
/// evictions to the tenant that inserted each entry, and carry an optional
/// *quota*: a per-tenant resident-cost ceiling enforced by evicting that
/// tenant's own LRU entries first, so one hot tenant cannot evict everyone
/// else's working set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheTag(usize);

struct TagState {
    name: String,
    /// Per-tenant resident-cost ceiling; 0 = no per-tenant quota.
    quota_cost: u64,
    resident_cost: u64,
    hits: Counter,
    evictions: Counter,
}

struct DecodedEntry<T> {
    value: Arc<T>,
    cost: u64,
    last_used: u64,
    /// Accounting slot of the tenant that inserted this entry.
    tag: Option<usize>,
}

struct DecodedInner<T> {
    map: HashMap<u64, DecodedEntry<T>>,
    /// Recency index: `last_used` tick -> key. Ticks are unique (monotonic
    /// counter), so the first entry is always the exact LRU — eviction and
    /// recency refresh are O(log n) instead of a full-map scan.
    order: BTreeMap<u64, u64>,
    tick: u64,
    resident_cost: u64,
    /// Per-tenant accounting slots (indexed by `CacheTag.0`).
    tags: Vec<TagState>,
}

impl<T> DecodedInner<T> {
    /// Remove `key` (present) from the map/order, fix global + tag resident
    /// cost, and count the eviction on both the global and the tag counter.
    fn evict_key(&mut self, key: u64, global_evictions: &Counter) {
        let entry = match self.map.remove(&key) {
            Some(e) => e,
            None => return,
        };
        self.order.remove(&entry.last_used);
        self.resident_cost -= entry.cost;
        if let Some(t) = entry.tag {
            let tag = &mut self.tags[t];
            tag.resident_cost = tag.resident_cost.saturating_sub(entry.cost);
            tag.evictions.inc();
        }
        global_evictions.inc();
    }

    /// First key in LRU order matching `pred`, skipping `skip`.
    fn lru_matching(
        &self,
        skip: u64,
        mut pred: impl FnMut(&DecodedEntry<T>) -> bool,
    ) -> Option<u64> {
        self.order
            .values()
            .copied()
            .filter(|k| *k != skip)
            .find(|k| self.map.get(k).map(&mut pred).unwrap_or(false))
    }
}


/// LRU cache of decoded blocks keyed by block id.
///
/// Capacity is expressed through a caller-supplied *cost* function (formats
/// use edges + vertices of a `DecodedBlock`); entries are evicted
/// least-recently-used-first once the total cost exceeds `capacity_cost`.
/// A capacity of 0 disables the cache (every `insert` is a no-op), which is
/// how benches measure the cold-decode baseline. All operations take `&self`
/// and the cache is `Send + Sync` when `T` is.
pub struct DecodedCache<T> {
    inner: Mutex<DecodedInner<T>>,
    hits: Counter,
    misses: Counter,
    evictions: Counter,
    capacity_cost: u64,
    cost: fn(&T) -> u64,
}

impl<T> DecodedCache<T> {
    pub fn new(capacity_cost: u64, cost: fn(&T) -> u64) -> Self {
        Self::with_counters(
            capacity_cost,
            cost,
            Counter::detached(),
            Counter::detached(),
            Counter::detached(),
        )
    }

    /// Construct with registry-resolved counter handles, so the cache's
    /// hit/miss/eviction counts show up in the owning graph's
    /// [`crate::obs::MetricsRegistry`] snapshot as well as in
    /// [`counters`](Self::counters).
    pub fn with_counters(
        capacity_cost: u64,
        cost: fn(&T) -> u64,
        hits: Counter,
        misses: Counter,
        evictions: Counter,
    ) -> Self {
        Self {
            inner: Mutex::new(DecodedInner {
                map: HashMap::new(),
                order: BTreeMap::new(),
                tick: 0,
                resident_cost: 0,
                tags: Vec::new(),
            }),
            hits,
            misses,
            evictions,
            capacity_cost,
            cost,
        }
    }

    pub fn capacity_cost(&self) -> u64 {
        self.capacity_cost
    }

    pub fn is_enabled(&self) -> bool {
        self.capacity_cost > 0
    }

    /// Register (or re-budget) a per-tenant accounting slot. Entries
    /// inserted under the returned [`CacheTag`] bill their resident cost to
    /// the tenant; `quota_cost > 0` caps that tenant's resident cost by
    /// evicting *its own* LRU entries first. `hits`/`evictions` are counter
    /// handles (typically registry-resolved under
    /// `cache.decoded.{hits,evictions}.<tenant>`) so quota enforcement is
    /// observable per tenant. Registering an existing name updates its
    /// quota and returns the same tag.
    pub fn register_tag(
        &self,
        name: &str,
        quota_cost: u64,
        hits: Counter,
        evictions: Counter,
    ) -> CacheTag {
        let mut inner = lock_recover(&self.inner);
        if let Some(i) = inner.tags.iter().position(|t| t.name == name) {
            inner.tags[i].quota_cost = quota_cost;
            return CacheTag(i);
        }
        inner.tags.push(TagState {
            name: name.to_string(),
            quota_cost,
            resident_cost: 0,
            hits,
            evictions,
        });
        CacheTag(inner.tags.len() - 1)
    }

    /// Look up `key`; counts a hit or miss and refreshes recency on hit
    /// (single map probe — this is the `successors()` fast path).
    pub fn get(&self, key: u64) -> Option<Arc<T>> {
        self.get_tagged(key, None)
    }

    /// [`get`](Self::get) with the hit also billed to `tag`'s counter.
    pub fn get_tagged(&self, key: u64, tag: Option<CacheTag>) -> Option<Arc<T>> {
        let mut guard = lock_recover(&self.inner);
        guard.tick += 1;
        let tick = guard.tick;
        let inner = &mut *guard;
        match inner.map.get_mut(&key) {
            Some(entry) => {
                inner.order.remove(&entry.last_used);
                entry.last_used = tick;
                inner.order.insert(tick, key);
                self.hits.inc();
                if let Some(CacheTag(t)) = tag {
                    if let Some(tag) = inner.tags.get(t) {
                        tag.hits.inc();
                    }
                }
                Some(Arc::clone(&entry.value))
            }
            None => {
                self.misses.inc();
                None
            }
        }
    }

    /// Insert (or replace) `key`, then evict LRU entries until the resident
    /// cost fits the capacity again. The entry just inserted is never the
    /// LRU, so a single oversized block stays resident rather than thrashing.
    pub fn insert(&self, key: u64, value: Arc<T>) {
        self.insert_tagged(key, value, None)
    }

    /// [`insert`](Self::insert) billed to `tag`. Eviction is quota-aware,
    /// in two passes:
    ///
    /// 1. while `tag` is over its own quota, evict *that tenant's* LRU
    ///    entries (never the one just inserted) — the hot tenant pays for
    ///    its own overflow;
    /// 2. while the cache is over global capacity, evict over-quota
    ///    tenants' LRU entries first, falling back to the global LRU only
    ///    when every remaining tenant is within budget.
    pub fn insert_tagged(&self, key: u64, value: Arc<T>, tag: Option<CacheTag>) {
        if self.capacity_cost == 0 {
            return;
        }
        let cost = (self.cost)(&value);
        let mut inner = lock_recover(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        let tag_idx = tag.map(|CacheTag(t)| t).filter(|t| *t < inner.tags.len());
        if let Some(old) =
            inner.map.insert(key, DecodedEntry { value, cost, last_used: tick, tag: tag_idx })
        {
            inner.resident_cost -= old.cost;
            inner.order.remove(&old.last_used);
            if let Some(t) = old.tag {
                inner.tags[t].resident_cost =
                    inner.tags[t].resident_cost.saturating_sub(old.cost);
            }
        }
        inner.order.insert(tick, key);
        inner.resident_cost += cost;
        if let Some(t) = tag_idx {
            inner.tags[t].resident_cost += cost;
        }
        // Pass 1: per-tenant quota — the inserting tenant sheds its own LRU.
        if let Some(t) = tag_idx {
            while inner.tags[t].quota_cost > 0
                && inner.tags[t].resident_cost > inner.tags[t].quota_cost
            {
                match inner.lru_matching(key, |e| e.tag == Some(t)) {
                    Some(victim) => inner.evict_key(victim, &self.evictions),
                    None => break, // only the fresh insert remains oversized
                }
            }
        }
        // Pass 2: global capacity — over-quota tenants evict first.
        while inner.resident_cost > self.capacity_cost && inner.map.len() > 1 {
            let over_quota = inner.lru_matching(key, |e| match e.tag {
                Some(t) => {
                    let tag = &inner.tags[t];
                    tag.quota_cost > 0 && tag.resident_cost > tag.quota_cost
                }
                None => false,
            });
            let victim = match over_quota.or_else(|| inner.lru_matching(key, |_| true)) {
                Some(k) => k,
                None => break, // only the fresh insert left
            };
            inner.evict_key(victim, &self.evictions);
        }
    }

    /// Resident cost currently billed to `tag` (tests + quota inspection).
    pub fn tag_resident_cost(&self, tag: CacheTag) -> u64 {
        let inner = lock_recover(&self.inner);
        inner.tags.get(tag.0).map(|t| t.resident_cost).unwrap_or(0)
    }

    pub fn len(&self) -> usize {
        lock_recover(&self.inner).map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all resident entries (counters are preserved).
    pub fn clear(&self) {
        let mut inner = lock_recover(&self.inner);
        inner.map.clear();
        inner.order.clear();
        inner.resident_cost = 0;
    }

    pub fn counters(&self) -> CacheCounters {
        let inner = lock_recover(&self.inner);
        CacheCounters {
            hits: self.hits.get(),
            misses: self.misses.get(),
            evictions: self.evictions.get(),
            resident_cost: inner.resident_cost,
            blocks: inner.map.len() as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FLEN: u64 = 1 << 30;

    #[test]
    fn cold_then_warm_then_dropped() {
        let c = PageCache::new(64 * CACHE_PAGE);
        let missed = c.access(1, 0, 4 * CACHE_PAGE, true, FLEN);
        assert_eq!(missed, 4 * CACHE_PAGE, "cold read misses everything");
        let missed = c.access(1, 0, 4 * CACHE_PAGE, true, FLEN);
        assert_eq!(missed, 0, "warm read is free");
        c.drop_cache();
        let missed = c.access(1, 0, 4 * CACHE_PAGE, true, FLEN);
        assert_eq!(missed, 4 * CACHE_PAGE, "drop_cache restores cold behaviour");
    }

    #[test]
    fn o_direct_does_not_populate() {
        let c = PageCache::new(64 * CACHE_PAGE);
        assert_eq!(c.access(1, 0, CACHE_PAGE, false, FLEN), CACHE_PAGE);
        let missed = c.access(1, 0, CACHE_PAGE, true, FLEN);
        assert_eq!(missed, CACHE_PAGE, "O_DIRECT read did not populate");
    }

    #[test]
    fn capacity_evicts_fifo() {
        let c = PageCache::new(2 * CACHE_PAGE);
        c.access(1, 0, CACHE_PAGE, true, FLEN); // page 0
        c.access(1, CACHE_PAGE, CACHE_PAGE, true, FLEN); // page 1
        c.access(1, 2 * CACHE_PAGE, CACHE_PAGE, true, FLEN); // evicts page 0
        assert_eq!(c.access(1, 0, CACHE_PAGE, true, FLEN), CACHE_PAGE, "page 0 evicted");
    }

    #[test]
    fn eviction_reporting_and_rebudget() {
        let c = PageCache::new(2 * CACHE_PAGE);
        let mut ev = Vec::new();
        c.access_reporting(1, 0, 2 * CACHE_PAGE, true, FLEN, &mut ev);
        assert!(ev.is_empty(), "no evictions while under budget");
        c.access_reporting(1, 2 * CACHE_PAGE, CACHE_PAGE, true, FLEN, &mut ev);
        assert_eq!(ev, vec![(1, 0)], "FIFO eviction reported");
        assert_eq!(c.capacity_bytes(), 2 * CACHE_PAGE);
        let mut ev2 = Vec::new();
        c.set_capacity(CACHE_PAGE, &mut ev2);
        assert_eq!(ev2.len(), 1, "shrink evicts the overflow immediately");
        assert!(c.resident_bytes() <= CACHE_PAGE);
    }

    #[test]
    fn distinct_files_do_not_collide() {
        let c = PageCache::new(64 * CACHE_PAGE);
        c.access(1, 0, CACHE_PAGE, true, FLEN);
        assert_eq!(c.access(2, 0, CACHE_PAGE, true, FLEN), CACHE_PAGE);
    }

    #[test]
    fn small_read_charges_whole_page_and_caches_it() {
        let c = PageCache::new(64 * CACHE_PAGE);
        // A tiny buffered read faults in (and pays for) the whole page —
        // a later read of that page is then legitimately warm.
        assert_eq!(c.access(3, 10, 100, true, FLEN), CACHE_PAGE);
        assert_eq!(c.access(3, 10, 100, true, FLEN), 0);
        assert_eq!(c.access(3, CACHE_PAGE / 2, 8, true, FLEN), 0, "same page");
    }

    #[test]
    fn page_charge_truncates_at_eof() {
        let c = PageCache::new(64 * CACHE_PAGE);
        let flen = CACHE_PAGE + 100; // file ends 100 B into its second page
        assert_eq!(c.access(4, CACHE_PAGE, 50, true, flen), 100);
    }

    fn unit_cost(_v: &u32) -> u64 {
        1
    }

    #[test]
    fn decoded_cache_hits_and_misses() {
        let c: DecodedCache<u32> = DecodedCache::new(10, unit_cost);
        assert!(c.get(1).is_none());
        c.insert(1, Arc::new(11));
        assert_eq!(c.get(1).as_deref(), Some(&11));
        let s = c.counters();
        assert_eq!((s.hits, s.misses, s.blocks), (1, 1, 1));
        assert!((c.counters().hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn decoded_cache_evicts_lru_by_cost() {
        fn cost(v: &u32) -> u64 {
            *v as u64
        }
        let c: DecodedCache<u32> = DecodedCache::new(10, cost);
        c.insert(1, Arc::new(4));
        c.insert(2, Arc::new(4));
        // Touch 1 so 2 becomes the LRU.
        assert!(c.get(1).is_some());
        c.insert(3, Arc::new(4)); // 12 > 10: evict key 2
        assert!(c.get(2).is_none(), "LRU entry evicted");
        assert!(c.get(1).is_some());
        assert!(c.get(3).is_some());
        let s = c.counters();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.resident_cost, 8);
    }

    #[test]
    fn decoded_cache_keeps_oversized_newest_entry() {
        fn cost(v: &u32) -> u64 {
            *v as u64
        }
        let c: DecodedCache<u32> = DecodedCache::new(5, cost);
        c.insert(7, Arc::new(100)); // alone over capacity: stays resident
        assert!(c.get(7).is_some());
        c.insert(8, Arc::new(1)); // evicts the oversized LRU
        assert!(c.get(7).is_none());
        assert!(c.get(8).is_some());
    }

    #[test]
    fn decoded_cache_zero_capacity_disabled() {
        let c: DecodedCache<u32> = DecodedCache::new(0, unit_cost);
        assert!(!c.is_enabled());
        c.insert(1, Arc::new(1));
        assert!(c.get(1).is_none());
        assert!(c.is_empty());
    }

    #[test]
    fn decoded_cache_replace_updates_cost() {
        fn cost(v: &u32) -> u64 {
            *v as u64
        }
        let c: DecodedCache<u32> = DecodedCache::new(100, cost);
        c.insert(1, Arc::new(30));
        c.insert(1, Arc::new(10));
        assert_eq!(c.counters().resident_cost, 10);
        assert_eq!(c.len(), 1);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.counters().resident_cost, 0);
    }
}
