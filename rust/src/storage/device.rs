//! Analytical device models calibrated to the paper's §5.1 measurements.

use super::reader::ReadMethod;

/// The storage tiers evaluated in the paper (§5.1, §5.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    /// 7200 RPM SATA HDD — σ ≈ 160 MB/s (§5.1).
    Hdd,
    /// PCIe4 NVMe SSD — σ ≈ 3.6 GB/s aggregate, ~2.1 GB/s single stream.
    Ssd,
    /// 4×HDD NAS behind a network switch — link-bound.
    Nas,
    /// Non-volatile memory DIMMs (§5.4).
    Nvmm,
    /// DDR4 DRAM (§5.4, §5.6 "datasets stored on memory").
    Dram,
}

impl DeviceKind {
    pub const ALL: [DeviceKind; 5] =
        [DeviceKind::Hdd, DeviceKind::Ssd, DeviceKind::Nas, DeviceKind::Nvmm, DeviceKind::Dram];

    pub fn name(&self) -> &'static str {
        match self {
            DeviceKind::Hdd => "HDD",
            DeviceKind::Ssd => "SSD",
            DeviceKind::Nas => "NAS",
            DeviceKind::Nvmm => "NVMM",
            DeviceKind::Dram => "DDR4",
        }
    }

    pub fn parse(s: &str) -> Option<DeviceKind> {
        match s.to_ascii_uppercase().as_str() {
            "HDD" => Some(DeviceKind::Hdd),
            "SSD" => Some(DeviceKind::Ssd),
            "NAS" => Some(DeviceKind::Nas),
            "NVMM" => Some(DeviceKind::Nvmm),
            "DDR4" | "DRAM" => Some(DeviceKind::Dram),
            _ => None,
        }
    }

    pub fn model(&self) -> DeviceModel {
        DeviceModel::new(*self)
    }
}

/// Parametric model of one device. Times are seconds, sizes bytes,
/// bandwidths bytes/second.
#[derive(Debug, Clone, Copy)]
pub struct DeviceModel {
    pub kind: DeviceKind,
    /// Sustained media/stream bandwidth of one internal channel.
    pub stream_bw: f64,
    /// Aggregate ceiling over all channels/queues.
    pub peak_bw: f64,
    /// Full random-access latency per request.
    pub seek: f64,
    /// Fraction of `seek` charged when a single sequential stream runs
    /// (track-to-track / readahead hides most of it).
    pub sequential_seek_factor: f64,
    /// Concurrency half-saturation constant for queue-parallel devices:
    /// aggregate(t) = peak * t / (t + k). (SSD/NVMM/DRAM.)
    pub concurrency_k: f64,
    /// True for single-spindle-like devices where concurrent readers
    /// interleave and *degrade* throughput (HDD, NAS-of-HDDs).
    pub spindle: bool,
    /// OS readahead window for buffered (non-direct) methods.
    pub readahead: u64,
    /// Seek-time scale factor. The paper's experiments run on multi-GB
    /// files where a 64M-edge request (~100 MB) dwarfs an 8 ms seek; our
    /// datasets are ~10^3 smaller, so scaled experiments shrink the seek by
    /// the same factor to preserve the request-size/seek ratio (DESIGN §3).
    pub seek_scale: f64,
}

impl DeviceModel {
    /// Model for *scaled* experiments (datasets ~10^3 smaller than the
    /// paper's): seek shrinks by the same factor so request-size/seek
    /// trade-offs are preserved.
    pub fn new_scaled(kind: DeviceKind) -> Self {
        DeviceModel { seek_scale: 1e-3, ..Self::new(kind) }
    }

    pub fn new(kind: DeviceKind) -> Self {
        // Calibration sources: §5.1 ("160 MB/s HDD, 3.6 GB/s SSD, single
        // threaded SSD read ≈ 2–2.1 GB/s", "HDD saturated by one thread,
        // degraded by more", "mmap reduces SSD bandwidth"), §5.2 (NAS binary
        // CSX ≈ 98 MB/s implied by 179 ME/s = 7.3× compressed), §5.4 (NVMM,
        // DDR4: ParaGrapher peaks at 3.8 GB/s decode-bound).
        match kind {
            DeviceKind::Hdd => DeviceModel {
                kind,
                stream_bw: 168e6,
                peak_bw: 168e6,
                seek: 8e-3,
                sequential_seek_factor: 0.05,
                concurrency_k: 0.0,
                spindle: true,
                readahead: 1 << 20,
                seek_scale: 1.0,
            },
            DeviceKind::Ssd => DeviceModel {
                kind,
                stream_bw: 2.55e9,
                peak_bw: 3.6e9,
                seek: 60e-6,
                sequential_seek_factor: 0.25,
                concurrency_k: 0.72,
                spindle: false,
                readahead: 512 << 10,
                seek_scale: 1.0,
            },
            DeviceKind::Nas => DeviceModel {
                kind,
                // 4 spindles behind a ~1 GbE-class shared link: the link is
                // the ceiling; latency includes network round trip.
                stream_bw: 110e6,
                peak_bw: 110e6,
                seek: 12e-3,
                sequential_seek_factor: 0.08,
                concurrency_k: 0.0,
                spindle: true,
                readahead: 1 << 20,
                seek_scale: 1.0,
            },
            DeviceKind::Nvmm => DeviceModel {
                kind,
                stream_bw: 6.5e9,
                peak_bw: 15e9,
                seek: 1.5e-6,
                sequential_seek_factor: 0.5,
                concurrency_k: 1.3,
                spindle: false,
                readahead: 256 << 10,
                seek_scale: 1.0,
            },
            DeviceKind::Dram => DeviceModel {
                kind,
                stream_bw: 18e9,
                peak_bw: 80e9,
                seek: 0.1e-6,
                sequential_seek_factor: 0.5,
                concurrency_k: 3.5,
                spindle: false,
                readahead: 0,
                seek_scale: 1.0,
            },
        }
    }

    /// Effective request size after OS readahead coalescing: buffered
    /// methods reading sequentially get requests batched up to the
    /// readahead window; O_DIRECT and random access do not.
    fn effective_block(&self, block: u64, method: ReadMethod, sequential: bool) -> u64 {
        if sequential && method.buffered() && self.readahead > 0 {
            block.max(self.readahead)
        } else {
            block.max(1)
        }
    }

    /// Method-dependent efficiency (Fig. 4: mmap costs SSD ~40 %, and
    /// O_DIRECT does not rescue it; rotational devices don't care).
    fn method_factor(&self, method: ReadMethod) -> f64 {
        match (self.kind, method) {
            (DeviceKind::Ssd, ReadMethod::Mmap) => 0.58,
            (DeviceKind::Ssd, ReadMethod::MmapDirect) => 0.61,
            (DeviceKind::Nvmm, ReadMethod::Mmap | ReadMethod::MmapDirect) => 0.85,
            (DeviceKind::Dram, _) => 1.0,
            (_, ReadMethod::Mmap | ReadMethod::MmapDirect) => 0.97,
            _ => 1.0,
        }
    }

    /// Aggregate device bandwidth (bytes/s) for `threads` concurrent readers
    /// issuing requests of `block` bytes with `method`, each scanning its own
    /// contiguous chunk (`sequential = true`, the paper's partitioned-file
    /// pattern) or hopping randomly.
    pub fn aggregate_bandwidth(
        &self,
        threads: usize,
        block: u64,
        method: ReadMethod,
        sequential: bool,
    ) -> f64 {
        let threads = threads.max(1);
        let block = self.effective_block(block, method, sequential);
        let xfer = block as f64 / self.stream_bw;
        let seek = self.seek * self.seek_scale;
        let bw = if self.spindle {
            // One head: requests serialize. A single sequential reader pays
            // almost no seeks; concurrent readers force a seek per request
            // switch (fraction grows with thread count), and deep queues add
            // head-thrash pressure (the Fig. 8 HDD degradation).
            let seek_fraction = if threads == 1 && sequential {
                self.sequential_seek_factor
            } else {
                let interleave = 1.0 - 1.0 / (threads as f64 + 0.3);
                self.sequential_seek_factor
                    + (1.0 - self.sequential_seek_factor) * interleave
            };
            // Concurrent streams also depress the *sustained* rate (head
            // repositioning inside large transfers) — a scale-invariant
            // penalty, unlike the absolute seek term.
            let stream_penalty = 1.0 + 0.012 * (threads as f64 - 1.0);
            let per_request = seek * seek_fraction + xfer * stream_penalty;
            block as f64 / per_request
        } else {
            // Queue-parallel device: per-thread stream rate bounded by one
            // channel; aggregate follows a saturating curve toward peak.
            let seek_fraction =
                if sequential { self.sequential_seek_factor } else { 1.0 };
            let per_thread = block as f64 / (seek * seek_fraction + xfer);
            let per_thread = per_thread.min(self.stream_bw);
            let curve = threads as f64 / (threads as f64 + self.concurrency_k);
            (per_thread * threads as f64).min(self.peak_bw * curve)
        };
        bw * self.method_factor(method)
    }

    /// Virtual-time cost (seconds) of one request of `size` bytes when
    /// `threads` readers share the device: each reader sees 1/threads of the
    /// aggregate bandwidth, plus its share of request latency.
    pub fn request_time(
        &self,
        size: u64,
        threads: usize,
        block: u64,
        method: ReadMethod,
        sequential: bool,
    ) -> f64 {
        let threads = threads.max(1) as f64;
        let agg = self.aggregate_bandwidth(threads as usize, block, method, sequential);
        size as f64 / (agg / threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    const MB: f64 = 1e6;
    const GB: f64 = 1e9;

    #[test]
    fn hdd_saturated_by_single_thread() {
        let m = DeviceKind::Hdd.model();
        let bw1 = m.aggregate_bandwidth(1, 4 << 20, ReadMethod::Pread, true);
        assert!(bw1 > 140.0 * MB && bw1 < 170.0 * MB, "HDD 1-thread {bw1}");
    }

    #[test]
    fn hdd_degrades_with_threads() {
        let m = DeviceKind::Hdd.model();
        let bw1 = m.aggregate_bandwidth(1, 4 << 20, ReadMethod::Pread, true);
        let bw18 = m.aggregate_bandwidth(18, 4 << 20, ReadMethod::Pread, true);
        let bw36 = m.aggregate_bandwidth(36, 4 << 20, ReadMethod::Pread, true);
        assert!(bw18 < bw1, "HDD must degrade: {bw1} -> {bw18}");
        assert!(bw36 <= bw18 * 1.01);
        assert!(bw36 > 80.0 * MB, "degradation is moderate for 4MB blocks: {bw36}");
    }

    #[test]
    fn ssd_needs_threads_to_saturate() {
        let m = DeviceKind::Ssd.model();
        let bw1 = m.aggregate_bandwidth(1, 4 << 20, ReadMethod::Pread, true);
        let bw18 = m.aggregate_bandwidth(18, 4 << 20, ReadMethod::Pread, true);
        assert!(bw1 > 1.9 * GB && bw1 < 2.3 * GB, "SSD single stream ≈ 2–2.1 GB/s, got {bw1}");
        assert!(bw18 > 3.3 * GB && bw18 <= 3.6 * GB, "SSD saturates ≈ 3.6 GB/s, got {bw18}");
    }

    #[test]
    fn ssd_mmap_penalty() {
        let m = DeviceKind::Ssd.model();
        let pread = m.aggregate_bandwidth(18, 4 << 20, ReadMethod::Pread, true);
        let mmap = m.aggregate_bandwidth(18, 4 << 20, ReadMethod::Mmap, true);
        let mmap_direct = m.aggregate_bandwidth(18, 4 << 20, ReadMethod::MmapDirect, true);
        assert!(mmap < 0.7 * pread, "mmap must cost SSD bandwidth");
        assert!((mmap_direct - mmap).abs() / mmap < 0.15, "O_DIRECT doesn't rescue mmap");
    }

    #[test]
    fn small_blocks_hurt_without_readahead() {
        let m = DeviceKind::Ssd.model();
        let direct_4k = m.aggregate_bandwidth(1, 4 << 10, ReadMethod::PreadDirect, true);
        let direct_4m = m.aggregate_bandwidth(1, 4 << 20, ReadMethod::PreadDirect, true);
        assert!(direct_4k < 0.25 * direct_4m, "4KB O_DIRECT stalls on latency");
        // Buffered 4KB sequential is rescued by readahead.
        let buf_4k = m.aggregate_bandwidth(1, 4 << 10, ReadMethod::Pread, true);
        assert!(buf_4k > 0.5 * direct_4m);
    }

    #[test]
    fn nas_is_link_bound() {
        let m = DeviceKind::Nas.model();
        let bw = m.aggregate_bandwidth(8, 4 << 20, ReadMethod::Pread, true);
        assert!(bw < 115.0 * MB, "NAS capped by the link: {bw}");
    }

    #[test]
    fn tier_ordering() {
        // Peak achievable bandwidth must respect the hardware hierarchy.
        let best = |k: DeviceKind| {
            let m = k.model();
            m.aggregate_bandwidth(64, 16 << 20, ReadMethod::Pread, true)
        };
        assert!(best(DeviceKind::Hdd) < best(DeviceKind::Ssd));
        assert!(best(DeviceKind::Ssd) < best(DeviceKind::Nvmm));
        assert!(best(DeviceKind::Nvmm) < best(DeviceKind::Dram));
        assert!(best(DeviceKind::Nas) < best(DeviceKind::Hdd));
    }

    #[test]
    fn request_time_scales_with_size() {
        let m = DeviceKind::Hdd.model();
        let t1 = m.request_time(4 << 20, 1, 4 << 20, ReadMethod::Pread, true);
        let t2 = m.request_time(8 << 20, 1, 4 << 20, ReadMethod::Pread, true);
        assert!((t2 / t1 - 2.0).abs() < 0.01);
        assert!(t1 > 0.0);
    }

    #[test]
    fn parse_names() {
        for k in DeviceKind::ALL {
            assert_eq!(DeviceKind::parse(k.name()), Some(k));
        }
        assert_eq!(DeviceKind::parse("dram"), Some(DeviceKind::Dram));
        assert_eq!(DeviceKind::parse("floppy"), None);
    }
}
