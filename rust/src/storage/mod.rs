//! Virtual-time storage simulator.
//!
//! The paper's evaluation runs on physical HDD/SSD/NAS/NVMM/DRAM. Those are
//! not available here, so every experiment reads bytes through a *simulated
//! device*: the bytes themselves are real (in-memory file images served
//! through the same code path the loaders use), while the elapsed I/O time is
//! *virtual*, computed from a per-device analytical model calibrated to the
//! bandwidth surfaces the paper measures in §5.1/Fig. 4:
//!
//! * HDD — single spindle, ~160 MB/s sequential, 8 ms seeks; saturated by
//!   one thread, *degraded* by concurrent readers (seek interleaving).
//! * SSD — ~3.6 GB/s aggregate, ~2.0–2.1 GB/s for a single stream; needs
//!   many in-flight requests to saturate; `mmap` costs it ~40 %.
//! * NAS — 4 HDDs behind a network link: link-bound (~110 MB/s).
//! * NVMM / DRAM — byte-addressable tiers used in §5.4/§5.6.
//!
//! Decode (decompression) time stays *real measured CPU time*, so the
//! storage-bound vs compute-bound crossover the paper's §3 model describes
//! emerges from the same mechanics: total = max over workers of
//! (virtual I/O + real CPU), plus sequential phases.

pub mod cache;
pub mod device;
pub mod fault;
pub mod mmap;
pub mod reader;
pub mod sim;
pub mod store;
pub mod vclock;

pub use cache::{CacheCounters, DecodedCache};
pub use device::{DeviceKind, DeviceModel};
pub use fault::{FaultAction, FaultPlan, IoFault};
pub use reader::ReadMethod;
pub use sim::{SimFile, SimStore};
pub use store::{GraphStore, ReadCtx, StoreFile, DEFAULT_CACHE_BYTES};
pub use vclock::IoAccount;
