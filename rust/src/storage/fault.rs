//! Deterministic fault injection below [`StoreFile`](super::store::StoreFile).
//!
//! A [`FaultPlan`] sits between the store's read surface and its Mem/Mapped
//! backing, so `mmap` and `pread` share one fault surface: a rule fires on
//! the *logical* read (`file`, `offset`, `len`) before the backing is
//! consulted, regardless of which syscall path would serve it. The plan is
//! parsed from spec strings extending the distributed layer's
//! `--fault-inject` vocabulary:
//!
//! ```text
//! kind:pattern[@key=value,...]
//!
//! kinds     short-read | bit-flip | eio | stall-ms
//! pattern   file name with `*` globs (e.g. `*.graph`)
//! keys      nth=N      first matching read that fires (1-based, default 1)
//!           count=N    how many consecutive matches fire (default 1, `inf`)
//!           range=A..B only reads overlapping bytes [A, B) match
//!           prob=P     fire with probability P per eligible match (default 1)
//!           ms=N       stall duration for `stall-ms` (default 1)
//! ```
//!
//! Multiple rules are `;`-separated; the first rule that fires on a read
//! wins. Determinism: every rule carries its own match counter and its own
//! seeded PRNG stream, so under sequential traffic the *exact* reads that
//! fault are reproducible from `(seed, specs)`; under concurrent traffic
//! the fault *count and kind mix* are reproducible while interleaving is
//! not (chaos campaigns assert structural invariants, not exact traces).
//!
//! Only [`FaultAction::Eio`] surfaces as an error ([`IoFault`]); the other
//! kinds corrupt or delay the returned bytes and let the checksum layer do
//! the catching — that split is what exercises both halves of the
//! coordinator's classify-then-retry path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::util::rng::Xoshiro256;

/// What a fired rule does to the read it hit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// The read fails outright with an [`IoFault`].
    Eio,
    /// The read returns only the first `keep` bytes (torn read).
    ShortRead { keep: u64 },
    /// One byte of the returned copy is XORed with `mask` at buffer
    /// offset `pos` (silent corruption — only checksums can tell).
    BitFlip { pos: u64, mask: u8 },
    /// The read completes normally after a real `ms`-millisecond sleep.
    Stall { ms: u64 },
}

/// A failed injected read: the only fault kind that surfaces as an `Err`.
/// Implements [`std::error::Error`] so it rides inside `anyhow::Error` and
/// can be recovered by `downcast_ref` at the classification site.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IoFault {
    pub file: String,
    pub offset: u64,
    pub len: u64,
}

impl std::fmt::Display for IoFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "injected I/O error (EIO) on {} at [{}, {})",
            self.file,
            self.offset,
            self.offset + self.len
        )
    }
}

impl std::error::Error for IoFault {}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FaultKind {
    ShortRead,
    BitFlip,
    Eio,
    Stall,
}

/// One parsed rule. Counters are per-rule so independent rules do not
/// perturb each other's firing schedule.
struct FaultRule {
    kind: FaultKind,
    pattern: String,
    nth: u64,
    count: u64,
    range: Option<(u64, u64)>,
    prob: f64,
    ms: u64,
    matches: AtomicU64,
    fired: AtomicU64,
    rng: Mutex<Xoshiro256>,
}

impl FaultRule {
    fn parse(spec: &str, rng: Xoshiro256) -> Result<FaultRule> {
        let (kind, rest) = spec
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("fault spec {spec:?}: want kind:pattern[@k=v,..]"))?;
        let kind = match kind {
            "short-read" => FaultKind::ShortRead,
            "bit-flip" => FaultKind::BitFlip,
            "eio" => FaultKind::Eio,
            "stall-ms" => FaultKind::Stall,
            other => bail!(
                "fault spec {spec:?}: unknown kind {other:?} \
                 (want short-read|bit-flip|eio|stall-ms)"
            ),
        };
        let (pattern, params) = match rest.split_once('@') {
            Some((p, q)) => (p, Some(q)),
            None => (rest, None),
        };
        if pattern.is_empty() {
            bail!("fault spec {spec:?}: empty file pattern");
        }
        let mut rule = FaultRule {
            kind,
            pattern: pattern.to_string(),
            nth: 1,
            count: 1,
            range: None,
            prob: 1.0,
            ms: 1,
            matches: AtomicU64::new(0),
            fired: AtomicU64::new(0),
            rng: Mutex::new(rng),
        };
        for kv in params.unwrap_or("").split(',').filter(|s| !s.is_empty()) {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault spec {spec:?}: bad param {kv:?}"))?;
            match k {
                "nth" => {
                    rule.nth = v.parse().with_context(|| format!("fault spec {spec:?}: nth"))?;
                    if rule.nth == 0 {
                        bail!("fault spec {spec:?}: nth is 1-based");
                    }
                }
                "count" => {
                    rule.count = if v == "inf" {
                        u64::MAX
                    } else {
                        v.parse().with_context(|| format!("fault spec {spec:?}: count"))?
                    }
                }
                "range" => {
                    let (a, b) = v.split_once("..").ok_or_else(|| {
                        anyhow::anyhow!("fault spec {spec:?}: range wants A..B")
                    })?;
                    let a: u64 =
                        a.parse().with_context(|| format!("fault spec {spec:?}: range"))?;
                    let b: u64 =
                        b.parse().with_context(|| format!("fault spec {spec:?}: range"))?;
                    if b <= a {
                        bail!("fault spec {spec:?}: empty range");
                    }
                    rule.range = Some((a, b));
                }
                "prob" => {
                    rule.prob =
                        v.parse().with_context(|| format!("fault spec {spec:?}: prob"))?;
                    if !(0.0..=1.0).contains(&rule.prob) {
                        bail!("fault spec {spec:?}: prob outside [0, 1]");
                    }
                }
                "ms" => {
                    rule.ms = v.parse().with_context(|| format!("fault spec {spec:?}: ms"))?;
                }
                other => bail!("fault spec {spec:?}: unknown param {other:?}"),
            }
        }
        Ok(rule)
    }

    /// Does this rule's (pattern, range) select the read at all?
    fn selects(&self, file: &str, offset: u64, len: u64) -> bool {
        if !glob_match(&self.pattern, file) {
            return false;
        }
        match self.range {
            None => true,
            Some((a, b)) => offset < b && offset.saturating_add(len) > a,
        }
    }

    /// Count the match and decide whether it fires; build the action.
    fn decide(&self, offset: u64, len: u64) -> Option<FaultAction> {
        let m = self.matches.fetch_add(1, Ordering::Relaxed) + 1;
        if m < self.nth || m - self.nth >= self.count {
            return None;
        }
        let mut rng = self.rng.lock().expect("fault rule rng");
        if self.prob < 1.0 && !rng.next_bool(self.prob) {
            return None;
        }
        let action = match self.kind {
            FaultKind::Eio => FaultAction::Eio,
            FaultKind::Stall => FaultAction::Stall { ms: self.ms },
            FaultKind::ShortRead => {
                if len == 0 {
                    return None;
                }
                FaultAction::ShortRead { keep: rng.next_below(len) }
            }
            FaultKind::BitFlip => {
                if len == 0 {
                    return None;
                }
                // Flip inside the (range ∩ read) window so `range=` rules
                // corrupt exactly the chunk they target.
                let (lo, hi) = match self.range {
                    Some((a, b)) => (a.max(offset), b.min(offset + len)),
                    None => (offset, offset + len),
                };
                let pos = lo + rng.next_below(hi - lo) - offset;
                let mask = 1u8 << rng.next_below(8);
                FaultAction::BitFlip { pos, mask }
            }
        };
        self.fired.fetch_add(1, Ordering::Relaxed);
        Some(action)
    }
}

impl std::fmt::Debug for FaultRule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultRule")
            .field("kind", &self.kind)
            .field("pattern", &self.pattern)
            .field("nth", &self.nth)
            .field("count", &self.count)
            .field("matches", &self.matches.load(Ordering::Relaxed))
            .field("fired", &self.fired.load(Ordering::Relaxed))
            .finish_non_exhaustive()
    }
}

/// A seeded set of fault rules, installed on a
/// [`GraphStore`](super::store::GraphStore) via `set_fault_plan`.
#[derive(Debug)]
pub struct FaultPlan {
    rules: Vec<FaultRule>,
    injected: AtomicU64,
    seed: u64,
}

impl FaultPlan {
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { rules: Vec::new(), injected: AtomicU64::new(0), seed }
    }

    /// Parse a `;`-separated list of rule specs.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan> {
        let mut plan = FaultPlan::new(seed);
        for rule in spec.split(';').map(str::trim).filter(|s| !s.is_empty()) {
            plan.push(rule)?;
        }
        if plan.rules.is_empty() {
            bail!("fault plan {spec:?}: no rules");
        }
        Ok(plan)
    }

    /// Append one rule; its PRNG stream is derived from `(seed, index)` so
    /// rule order — not push timing — defines the streams.
    pub fn push(&mut self, spec: &str) -> Result<()> {
        let idx = self.rules.len() as u64;
        let stream =
            Xoshiro256::seed_from_u64(self.seed ^ idx.wrapping_mul(0x9E3779B97F4A7C15));
        self.rules.push(FaultRule::parse(spec, stream)?);
        Ok(())
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn rules(&self) -> usize {
        self.rules.len()
    }

    /// Total faults this plan has injected (all kinds).
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// The store's per-read hook: first rule that fires wins. Rules that
    /// merely *select* the read still advance their match counters, so
    /// `nth=` schedules stay independent across rules.
    pub fn decide(&self, file: &str, offset: u64, len: u64) -> Option<FaultAction> {
        let mut hit = None;
        for rule in &self.rules {
            if !rule.selects(file, offset, len) {
                continue;
            }
            if let Some(action) = rule.decide(offset, len) {
                if hit.is_none() {
                    hit = Some(action);
                }
            }
        }
        if hit.is_some() {
            self.injected.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }
}

/// `*`-only glob match (no escapes, no character classes).
fn glob_match(pattern: &str, name: &str) -> bool {
    if !pattern.contains('*') {
        return pattern == name;
    }
    let parts: Vec<&str> = pattern.split('*').collect();
    let (first, last) = (parts[0], parts[parts.len() - 1]);
    if !name.starts_with(first) || name.len() < first.len() + last.len() {
        return false;
    }
    let mut rest = &name[first.len()..name.len() - last.len()];
    if !name.ends_with(last) {
        return false;
    }
    for part in &parts[1..parts.len() - 1] {
        if part.is_empty() {
            continue;
        }
        match rest.find(part) {
            Some(i) => rest = &rest[i + part.len()..],
            None => return false,
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glob_semantics() {
        assert!(glob_match("g.graph", "g.graph"));
        assert!(!glob_match("g.graph", "g.offsets"));
        assert!(glob_match("*.graph", "g.graph"));
        assert!(!glob_match("*.graph", "g.graphx"));
        assert!(glob_match("*", "anything"));
        assert!(glob_match("a*b*c", "aXbYc"));
        assert!(!glob_match("a*b*c", "aXcYb"));
        assert!(glob_match("g*", "g.checksums"));
        assert!(!glob_match("ab*ba", "aba"), "overlapping affixes must not double-count");
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "eio",
            "typo:*.graph",
            "eio:",
            "eio:*.graph@nth=0",
            "eio:*.graph@range=9..9",
            "eio:*.graph@prob=1.5",
            "eio:*.graph@wat=1",
            "",
        ] {
            assert!(FaultPlan::parse(bad, 1).is_err(), "{bad:?} should not parse");
        }
        let plan = FaultPlan::parse(
            "eio:g.graph@nth=3,count=inf; bit-flip:*@range=0..10,prob=0.5; stall-ms:*@ms=7",
            1,
        )
        .unwrap();
        assert_eq!(plan.rules(), 3);
    }

    #[test]
    fn nth_and_count_schedule_firing() {
        let plan = FaultPlan::parse("eio:g@nth=3,count=2", 42).unwrap();
        let hits: Vec<bool> =
            (0..6).map(|_| plan.decide("g", 0, 100).is_some()).collect();
        assert_eq!(hits, [false, false, true, true, false, false]);
        assert_eq!(plan.injected(), 2);
    }

    #[test]
    fn count_inf_fires_forever() {
        let plan = FaultPlan::parse("eio:g@nth=2,count=inf", 42).unwrap();
        let hits = (0..10).filter(|_| plan.decide("g", 0, 1).is_some()).count();
        assert_eq!(hits, 9);
    }

    #[test]
    fn range_filter_gates_matching() {
        let plan = FaultPlan::parse("eio:g@range=100..200", 42).unwrap();
        assert!(plan.decide("g", 0, 50).is_none(), "disjoint below");
        assert!(plan.decide("g", 200, 50).is_none(), "disjoint above");
        assert!(plan.decide("g", 150, 10).is_some(), "overlap fires");
        assert!(plan.decide("g", 150, 10).is_none(), "count=1 spent");
    }

    #[test]
    fn bit_flip_lands_inside_the_requested_window() {
        let plan = FaultPlan::parse("bit-flip:g@count=inf", 7).unwrap();
        for _ in 0..100 {
            match plan.decide("g", 1000, 64) {
                Some(FaultAction::BitFlip { pos, mask }) => {
                    assert!(pos < 64, "pos {pos} must be buffer-relative");
                    assert_eq!(mask.count_ones(), 1);
                }
                other => panic!("expected BitFlip, got {other:?}"),
            }
        }
        // Ranged flips land inside (range ∩ read).
        let plan = FaultPlan::parse("bit-flip:g@range=1010..1020,count=inf", 7).unwrap();
        for _ in 0..100 {
            match plan.decide("g", 1000, 64) {
                Some(FaultAction::BitFlip { pos, .. }) => {
                    assert!((10..20).contains(&pos), "pos {pos} must fall in the range window");
                }
                other => panic!("expected BitFlip, got {other:?}"),
            }
        }
    }

    #[test]
    fn short_read_keeps_a_strict_prefix() {
        let plan = FaultPlan::parse("short-read:g@count=inf", 9).unwrap();
        for _ in 0..100 {
            match plan.decide("g", 0, 512) {
                Some(FaultAction::ShortRead { keep }) => assert!(keep < 512),
                other => panic!("expected ShortRead, got {other:?}"),
            }
        }
    }

    #[test]
    fn same_seed_same_decisions() {
        let spec = "eio:*.graph@prob=0.3,count=inf; bit-flip:*@prob=0.2,count=inf";
        let a = FaultPlan::parse(spec, 1234).unwrap();
        let b = FaultPlan::parse(spec, 1234).unwrap();
        let c = FaultPlan::parse(spec, 4321).unwrap();
        let run = |p: &FaultPlan| -> Vec<Option<FaultAction>> {
            (0..200).map(|i| p.decide("g.graph", i * 64, 64)).collect()
        };
        let (ra, rb, rc) = (run(&a), run(&b), run(&c));
        assert_eq!(ra, rb, "same seed replays the same fault trace");
        assert_ne!(ra, rc, "different seeds diverge");
        assert!(a.injected() > 0);
    }

    #[test]
    fn first_firing_rule_wins_but_all_count() {
        let plan = FaultPlan::parse("stall-ms:g@ms=5,count=inf; eio:g@nth=2,count=inf", 1).unwrap();
        assert_eq!(plan.decide("g", 0, 8), Some(FaultAction::Stall { ms: 5 }));
        // Second read: both rules fire; the first in spec order wins, but
        // the eio rule still advanced past its nth gate.
        assert_eq!(plan.decide("g", 0, 8), Some(FaultAction::Stall { ms: 5 }));
        assert_eq!(plan.injected(), 2);
    }
}
